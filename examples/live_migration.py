"""Live migration at both scales the paper cares about:

(1) kernel scale — the paper's §6.3 case study: an iterative tiled matmul
    migrated across backends mid-run (H100 -> 9070 XT -> Tenstorrent
    becomes vectorized -> pallas -> interp);
(2) job scale — a training run checkpointed topology-neutrally and resumed
    on a *different* mesh layout (elastic restart).

    PYTHONPATH=src python examples/live_migration.py
"""
import time

import jax
import numpy as np

from repro.core import Engine, Snapshot, get_backend
from repro.core import kernels_suite as suite


def kernel_migration():
    print("== kernel-scale migration (paper §6.3) ==")
    M, K, N, TK = 16, 64, 32, 8
    rng = np.random.default_rng(1)
    A = rng.normal(size=(M, K)).astype(np.float32)
    B = rng.normal(size=(K, N)).astype(np.float32)
    args = {"A": A.reshape(-1), "B": B.reshape(-1),
            "C": np.zeros(M * N, np.float32),
            "K": K, "N": N, "ktiles": K // TK}
    prog, oracle = suite.matmul_tiled(TK)

    chain = ["vectorized", "pallas", "interp"]
    eng = Engine(prog, get_backend(chain[0]), M, N, dict(args))
    eng.run(max_segments=5)
    for dst in chain[1:]:
        t0 = time.perf_counter()
        blob = eng.snapshot().to_bytes()
        eng = Engine.resume(prog, get_backend(dst),
                            Snapshot.from_bytes(blob))
        downtime = (time.perf_counter() - t0) * 1e3
        print(f"  migrated to {dst:11s} downtime={downtime:6.1f} ms "
              f"payload={len(blob)/1024:.1f} kB")
        eng.run(max_segments=4)
    eng.run()
    ok = np.allclose(eng.result("C"), oracle(dict(args))["C"], atol=1e-4)
    print(f"  final result correct across 2 migrations: {ok}")


def job_migration():
    print("\n== job-scale migration (topology-neutral checkpoint) ==")
    from repro import configs
    from repro.configs.base import ShapeCfg
    from repro.runtime.train_loop import Trainer

    cfg = configs.get_smoke_config("llama3.2-3b")
    shape = ShapeCfg("tiny", 32, 4, "train")
    n = len(jax.devices())
    mesh_a = jax.make_mesh((n, 1), ("data", "model"))
    mesh_b = jax.make_mesh((1, n), ("data", "model"))

    tr = Trainer(cfg, shape, mesh_a, seed=7)
    rep = tr.run(3)
    print(f"  mesh A {mesh_a.devices.shape}: losses {['%.3f' % l for l in rep.losses]}")
    t0 = time.perf_counter()
    tr.resize(mesh_b)   # live migration: snapshot -> re-fit specs -> reshard
    print(f"  resized to mesh B {mesh_b.devices.shape} in "
          f"{(time.perf_counter()-t0)*1e3:.0f} ms")
    rep2 = tr.run(3)
    print(f"  mesh B continues: losses {['%.3f' % l for l in rep2.losses]}")


if __name__ == "__main__":
    kernel_migration()
    job_migration()
