"""Self-healing fleet demo — the paper's live-migration claim as an
*operational* property, not an API call:

(1) a heterogeneous 3-worker fleet (interp + vectorized) serves a batch
    of launches; one worker is SIGKILLed mid-kernel by the fault
    injector, and the coordinator detects, requeues, and replays the
    lost launches on the survivors — bit-identical to a fault-free run;
(2) policy-driven migration: drain a worker for "maintenance" (its
    in-flight launches move live, via checkpoint/restore, across
    backends) and rebalance the survivors;
(3) the coordinator itself "crashes" and a fresh one recovers every
    unacked launch from the durable retry queue.

    PYTHONPATH=src python examples/fleet_demo.py
"""
import tempfile
from pathlib import Path

import numpy as np

from repro.core.fleet import MID_KERNEL, FleetCoordinator
from repro.core.kernels_suite import example_launch


def main():
    prog, _oracle, grid, block, args, outs = example_launch("dyn_matmul")
    with tempfile.TemporaryDirectory() as td:
        qdir = Path(td) / "queue"

        # -- fault-free reference results --------------------------------
        with FleetCoordinator(backends=("interp",), queue_dir=None,
                              fault_plan=[]) as ref:
            ref.register(prog)
            t = ref.submit(prog.name, grid, block, args)
            ref.wait_all(timeout=120)
            reference = {n: t.result(n) for n in outs}
        print("reference computed on a 1-worker fleet")

        # -- (1) chaos: kill -9 a worker mid-kernel ----------------------
        plan = [{"point": MID_KERNEL, "worker": 0,
                 "kernel": prog.name, "nth": 1, "after_segments": 2}]
        with FleetCoordinator(backends=("interp", "vectorized", "interp"),
                              queue_dir=qdir, fault_plan=plan,
                              fault_seed=42) as fleet:
            fleet.register(prog)
            tickets = [fleet.submit(prog.name, grid, block, args)
                       for _ in range(6)]
            fleet.wait_all(timeout=180)
            st = fleet.fleet_stats()
            print(f"chaos run: workers_lost={st['workers_lost']} "
                  f"evacuated={st['evacuated']} retried={st['retried']} "
                  f"completed={st['completed']} "
                  f"recovery_ms_max={st.get('recovery_ms_max', 0):.0f}")
            assert all(np.array_equal(t.result(n), reference[n])
                       for t in tickets for n in outs)
            print("every result bit-identical to the reference")

            # -- (2) policy-driven migration -----------------------------
            more = [fleet.submit(prog.name, grid, block, args)
                    for _ in range(4)]
            fleet.pump()
            alive = [w.wid for w in fleet.workers.values() if w.alive]
            moved = fleet.drain(alive[0])
            print(f"drained worker {alive[0]}: {moved} launch(es) "
                  "migrated live (checkpoint/restore)")
            fleet.rebalance()
            fleet.wait_all(timeout=180)
            assert all(np.array_equal(t.result(n), reference[n])
                       for t in more for n in outs)
            print(f"after drain+rebalance: migrated="
                  f"{fleet.fleet_stats()['migrated']}, still bit-identical")

        # -- (3) coordinator crash + recovery ----------------------------
        fleet = FleetCoordinator(backends=("interp",), queue_dir=qdir,
                                 slice_segments=1, fault_plan=[])
        fleet.register(prog)
        victim = fleet.submit(prog.name, grid, block, args)
        fleet.pump()                     # dispatched, mid-flight
        fleet.shutdown()                 # "crash": queue dir survives
        print(f"coordinator died with {victim.launch_id} in flight")

        with FleetCoordinator(backends=("interp",), queue_dir=qdir,
                              fault_plan=[]) as fleet2:
            recovered = fleet2.recover()
            fleet2.register(prog)
            fleet2.wait_all(timeout=120)
            assert len(recovered) == 1 and recovered[0].finished
            assert all(np.array_equal(recovered[0].result(n), reference[n])
                       for n in outs)
            print(f"new coordinator replayed {recovered[0].launch_id} "
                  f"(attempt {recovered[0].attempts}) — bit-identical")
    print("fleet demo OK")


if __name__ == "__main__":
    main()
