"""Quickstart: one hetIR binary, three backends, live migration.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import Engine, HetSession, get_backend, migrate
from repro.core import kernels_suite as suite


def main():
    # --- write once ------------------------------------------------------
    prog, oracle = suite.vadd()
    print("hetIR assembly for vadd:\n")
    print(prog.to_text())

    rng = np.random.default_rng(0)
    args = {"A": rng.normal(size=256).astype(np.float32),
            "B": rng.normal(size=256).astype(np.float32),
            "C": np.zeros(256, np.float32), "n": 256}

    # --- run anywhere ------------------------------------------------------
    print("\nrunning the same binary on every backend:")
    for backend in ("interp", "vectorized", "pallas"):
        eng = Engine(prog, get_backend(backend), 8, 32, dict(args))
        eng.run()
        ok = np.allclose(eng.result("C"), args["A"] + args["B"])
        print(f"  {backend:12s} correct={ok}")

    # --- migrate mid-kernel ------------------------------------------------
    print("\nlive migration of a persistent kernel "
          "(vectorized -> pallas at iteration barrier):")
    prog2, oracle2 = suite.persistent_counter()
    args2 = {"State": rng.normal(size=64).astype(np.float32), "iters": 6}
    src, dst = HetSession("vectorized"), HetSession("pallas")
    src.load_kernel(prog2)
    dst.load_kernel(prog2)
    rec = src.launch("persistent_counter", grid=2, block=32,
                     args=dict(args2), blocking=False)
    rec.engine.run(max_segments=3)          # pause mid-loop
    new = migrate(rec, src, dst, "persistent_counter")
    dst.run_to_completion(new)
    expect = oracle2(dict(args2))
    print("  migrated result correct:",
          np.allclose(new.engine.result("State"), expect["State"],
                      atol=1e-4))
    print("  migration stats:", dst.stats["last_migration"])


if __name__ == "__main__":
    main()
