"""Quickstart: one hetIR binary, three backends, live migration.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import Engine, HetSession, get_backend, migrate
from repro.core import kernels_suite as suite


def main():
    # --- write once ------------------------------------------------------
    prog, oracle = suite.vadd()
    print("hetIR assembly for vadd:\n")
    print(prog.to_text())

    rng = np.random.default_rng(0)
    args = {"A": rng.normal(size=256).astype(np.float32),
            "B": rng.normal(size=256).astype(np.float32),
            "C": np.zeros(256, np.float32), "n": 256}

    # --- run anywhere ------------------------------------------------------
    print("\nrunning the same binary on every backend:")
    for backend in ("interp", "vectorized", "pallas"):
        eng = Engine(prog, get_backend(backend), 8, 32, dict(args))
        eng.run()
        ok = np.allclose(eng.result("C"), args["A"] + args["B"])
        print(f"  {backend:12s} correct={ok}")

    # --- migrate mid-kernel ------------------------------------------------
    # driver-style API: load -> Function, alloc -> DeviceBuffer (mutated
    # in place), launch_async -> LaunchRecord on a Stream.  See
    # examples/driver_api_demo.py and docs/API.md for the full surface.
    print("\nlive migration of a persistent kernel "
          "(vectorized -> pallas at iteration barrier):")
    prog2, oracle2 = suite.persistent_counter()
    init = rng.normal(size=64).astype(np.float32)
    src, dst = HetSession("vectorized"), HetSession("pallas")
    counter = src.load(prog2).function()
    dst.load(prog2)
    state = src.alloc(64).copy_from_host(init)
    rec = counter.launch_async(grid=2, block=32,
                               args={"State": state, "iters": 6})
    src.step(3)                             # drive 3 segments, pause mid-loop
    new = migrate(rec, src, dst, "persistent_counter")
    dst.synchronize()
    expect = oracle2({"State": init.copy(), "iters": 6})
    print("  migrated result correct:",
          np.allclose(new.buffer("State").copy_to_host(),
                      expect["State"], atol=1e-4))
    print("  migration stats:", dst.stats["last_migration"])


if __name__ == "__main__":
    main()
