"""Batched serving: prefill a batch of prompts, then step the decoder with
a KV cache (windowed / recurrent state depending on architecture).

    PYTHONPATH=src python examples/serve_decode.py --arch h2o-danube-3-4b

``--het-tier`` instead serves decode-step matvecs (the
``decode_gemv`` suite kernel) through the hetGPU multi-tenant serving
tier: weighted tenants on sticky streams, quota-based admission,
pooled buffers, async D2H of each result:

    PYTHONPATH=src python examples/serve_decode.py --het-tier

``--zoo`` decodes a tiny model whose attention is the model-zoo
``attn_decode`` kernel (repro.zoo) running on the hetGPU runtime: every
token's flash-decode launch goes through the serving tier, one token's
launch is checkpointed *mid-softmax* and live-migrated
interp -> vectorized -> pallas across a shared cache fabric, and every
token's logits are asserted bit-identical to the NumPy oracle:

    PYTHONPATH=src python examples/serve_decode.py --zoo
"""
import argparse
import time

import numpy as np


def het_tier(requests_per_tenant: int = 24) -> None:
    """Multi-tenant decode serving on the hetGPU runtime (no jax)."""
    from repro.core import HetSession, ServingFrontEnd, TranslationCache
    from repro.core import kernels_suite as suite

    GRID, BLOCK, K, KTILES = 4, 16, 32, 4   # 64 output rows, 4 segments
    M = GRID * BLOCK
    prog, oracle = suite.decode_gemv()
    s = HetSession("vectorized", cache=TranslationCache())
    fn = s.load(prog).function()
    front = ServingFrontEnd(s, default_quota=8, slo_ms=1000.0)
    tenants = {"bronze": 1.0, "silver": 2.0, "gold": 4.0}
    for name, w in tenants.items():
        front.tenant(name, weight=w)

    rng = np.random.default_rng(7)
    W = s.alloc(M * K).copy_from_host(
        rng.normal(size=M * K).astype(np.float32) * 0.1)   # shared weights
    wx, wr, wo = s.alloc(K), s.alloc(M), s.alloc(M)
    fn.launch(GRID, BLOCK, {"W": W, "X": wx, "R": wr, "Out": wo,
                            "K": K, "ktiles": KTILES})     # pay JIT once
    for b in (wx, wr, wo):
        b.free()
    live, results = [], []
    submitted = {n: 0 for n in tenants}
    t0 = time.perf_counter()
    while len(results) < requests_per_tenant * len(tenants) or live:
        for name in tenants:
            t = front.tenants[name]
            while (submitted[name] < requests_per_tenant
                   and len(t.inflight) < t.max_inflight):
                x = rng.normal(size=K).astype(np.float32)
                r = rng.normal(size=M).astype(np.float32)
                xb = s.alloc(K).copy_from_host(x)
                rb = s.alloc(M).copy_from_host(r)
                ob = s.alloc(M)
                tk = front.submit(name, fn, GRID, BLOCK,
                                  {"W": W, "X": xb, "R": rb, "Out": ob,
                                   "K": K, "ktiles": KTILES})
                d2h = ob.copy_to_host_async(stream=t.stream)
                live.append((tk, d2h, (xb, rb, ob), (x, r)))
                submitted[name] += 1
        front.pump(32)
        still = []
        for tk, d2h, bufs, host in live:
            if tk.done() and d2h.done():
                results.append((tk, d2h.result(), host))
                for b in bufs:
                    b.free()
            else:
                still.append((tk, d2h, bufs, host))
        live = still
    front.drain()
    dt = time.perf_counter() - t0

    # spot-check a handful of results against the oracle
    for tk, out, (x, r) in results[::17]:
        want = oracle({"W": W.copy_to_host(), "X": x, "R": r,
                       "Out": np.zeros(M, np.float32),
                       "K": K, "ktiles": KTILES})["Out"]
        np.testing.assert_allclose(out, want, atol=1e-4, rtol=1e-4)
    agg = front.stats()
    pool = s.pool_stats()
    print(f"served {agg['completed']} decode matvecs from "
          f"{len(tenants)} tenants in {dt*1e3:.0f} ms "
          f"(p50 {agg.get('p50_ms', 0):.2f} / "
          f"p99 {agg.get('p99_ms', 0):.2f} ms, "
          f"{agg['rejected']} shed, "
          f"pool reuse {pool['reuse_rate']:.0%})")
    for t in agg["tenants"]:
        print(f"  {t['tenant']:<7} w={t['weight']:.0f} "
              f"completed={t['completed']} p99={t.get('p99_ms', 0):.2f}ms")
    print("results verified against the decode_gemv oracle")


def zoo_demo(new_tokens: int = 6) -> None:
    """Greedy decode with the model-zoo attention kernel, live-migrated
    mid-token across all three backends (no jax).

    The "model" is deliberately tiny — an embedding table, a circular
    KV window and an output projection in host numpy — because the star
    is the attention kernel: one hetIR ``attn_decode`` Program serves
    every token through the ServingFrontEnd, and on the migration token
    the in-flight launch is paused inside the online-softmax tile loop
    (m/l/acc in the regfile, the probability tile in shared memory),
    checkpointed, and resumed interp -> vectorized -> pallas.  Because
    the zoo oracle reproduces the kernel's exact float32 op order, the
    logits are asserted **bit-identical** every token, migrated or not.
    """
    import tempfile

    import repro.zoo as zoo
    from repro.core import HetSession, ServingFrontEnd, migrate

    H, D, T, NT = 4, zoo.ATTN_D, zoo.ATTN_T, 3
    CTX = NT * T                     # fixed circular KV window
    VOCAB = 16
    GRID, BLOCK = H, T

    prog, oracle = zoo.attn_decode()
    fabric = tempfile.mkdtemp(prefix="zoo-fabric-")
    sessions = {n: HetSession(n, shared=fabric)
                for n in ("interp", "vectorized", "pallas")}
    for s in sessions.values():
        s.load(prog)
    src = sessions["interp"]
    fn = src.load(prog).function()
    front = ServingFrontEnd(src, default_quota=4, slo_ms=5000.0)
    front.tenant("decoder", weight=1.0)

    rng = np.random.default_rng(42)
    emb = (rng.normal(size=(VOCAB, H * D)) * 0.3).astype(np.float32)
    w_out = (rng.normal(size=(H * D, VOCAB)) * 0.2).astype(np.float32)
    kcache = (rng.normal(size=(H, CTX, D)) * 0.3).astype(np.float32)
    vcache = (rng.normal(size=(H, CTX, D)) * 0.3).astype(np.float32)
    scale = float(np.float32(1.0 / np.sqrt(D)))

    token, migrations, tokens_out = 3, 0, []
    migrate_step = 1                 # this token's launch takes the tour
    for step in range(new_tokens):
        x = emb[token]
        kcache[:, step % CTX, :] = (x * 0.5).reshape(H, D)
        vcache[:, step % CTX, :] = np.tanh(x).reshape(H, D).astype(
            np.float32)
        host = {"Q": x.copy(), "K": kcache.reshape(-1).copy(),
                "V": vcache.reshape(-1).copy(),
                "O": np.zeros(H * D, np.float32),
                "ntiles": NT, "scale": scale}
        expect_o = oracle({k: (v.copy() if isinstance(v, np.ndarray)
                               else v) for k, v in host.items()})["O"]

        bufs = {k: src.alloc(v.size).copy_from_host(v)
                for k, v in host.items() if isinstance(v, np.ndarray)}
        tk = front.submit("decoder", fn, GRID, BLOCK,
                          {**bufs, "ntiles": NT, "scale": scale})
        if step == migrate_step:
            # pause inside the online-softmax tile loop, then hop twice
            rec = tk.record
            rec.advance(max_segments=3)
            rec = migrate(rec, src, sessions["vectorized"], "attn_decode")
            migrations += 1
            rec.advance(max_segments=2)
            rec = migrate(rec, sessions["vectorized"],
                          sessions["pallas"], "attn_decode")
            migrations += 1
            sessions["pallas"].run_to_completion(rec)
            got_o = rec.buffer("O").copy_to_host()
            mig = sessions["pallas"].stats["last_migration"]
            print(f"  token {step}: migrated mid-softmax "
                  f"interp->vectorized->pallas "
                  f"(payload {mig['payload_bytes']/1024:.1f} kB, "
                  f"fabric translations restored: "
                  f"{mig['cache_restored']})")
        else:
            while not tk.done():
                front.pump(16)
            got_o = bufs["O"].copy_to_host()
        for b in bufs.values():
            if step != migrate_step:     # migrated buffers moved sessions
                b.free()

        logits = got_o @ w_out
        want = expect_o @ w_out
        np.testing.assert_array_equal(
            logits, want,
            err_msg=f"token {step}: served logits diverge from oracle")
        token = int(np.argmax(logits))
        tokens_out.append(token)

    front.drain()
    assert migrations >= 2, "demo must migrate the decode at least twice"
    print(f"decoded {new_tokens} tokens via zoo attn_decode "
          f"({migrations} cross-backend mid-decode migrations); "
          f"logits bit-identical to the oracle every token")
    print("sampled ids:", tokens_out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--het-tier", action="store_true",
                    help="serve decode matvecs through the hetGPU "
                         "multi-tenant serving tier instead of jax")
    ap.add_argument("--requests", type=int, default=24,
                    help="(--het-tier) requests per tenant")
    ap.add_argument("--zoo", action="store_true",
                    help="decode through the model-zoo attn_decode "
                         "kernel with mid-token cross-backend migration")
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    if args.zoo:
        zoo_demo(min(args.new_tokens, 8))
        return
    if args.het_tier:
        het_tier(args.requests)
        return

    import jax
    import jax.numpy as jnp

    from repro import configs
    from repro.models import decode_step, prefill
    if args.arch not in configs.list_archs():
        ap.error(f"unknown --arch {args.arch}")

    cfg = configs.get_smoke_config(args.arch)
    rng = np.random.default_rng(0)
    from repro.models import init_params
    params = init_params(jax.random.key(0), cfg)

    B, S = args.batch, args.prompt_len
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)}
    if cfg.encoder_decoder:
        batch["enc_embeds"] = jnp.asarray(
            rng.normal(size=(B, S, cfg.d_model)) * 0.02,
            jnp.dtype(cfg.compute_dtype))
    if cfg.frontend == "patch":
        batch["embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.frontend_tokens, cfg.d_model)) * 0.02,
            jnp.dtype(cfg.compute_dtype))
        batch["tokens"] = batch["tokens"][:, cfg.frontend_tokens:]

    cache_len = S + args.new_tokens
    t0 = time.perf_counter()
    logits, caches = jax.jit(
        lambda p, b: prefill(p, b, cfg, cache_len=cache_len))(params, batch)
    print(f"prefill {B}x{S}: {(time.perf_counter()-t0)*1e3:.0f} ms")

    step = jax.jit(lambda p, t, c, q: decode_step(p, t, c, q, cfg))
    toks = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    out = [toks]
    t0 = time.perf_counter()
    for i in range(args.new_tokens - 1):
        logits, caches = step(params, toks, caches,
                              jnp.asarray(S + i, jnp.int32))
        toks = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        out.append(toks)
    jax.block_until_ready(toks)
    dt = time.perf_counter() - t0
    n = (args.new_tokens - 1) * B
    print(f"decode: {n} tokens in {dt*1e3:.0f} ms "
          f"({n/dt:.1f} tok/s greedy, batch={B})")
    print("sampled ids:", np.asarray(jnp.concatenate(out, axis=1))[0][:12])


if __name__ == "__main__":
    main()
