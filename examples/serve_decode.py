"""Batched serving: prefill a batch of prompts, then step the decoder with
a KV cache (windowed / recurrent state depending on architecture).

    PYTHONPATH=src python examples/serve_decode.py --arch h2o-danube-3-4b

``--het-tier`` instead serves decode-step matvecs (the
``decode_gemv`` suite kernel) through the hetGPU multi-tenant serving
tier: weighted tenants on sticky streams, quota-based admission,
pooled buffers, async D2H of each result:

    PYTHONPATH=src python examples/serve_decode.py --het-tier
"""
import argparse
import time

import numpy as np


def het_tier(requests_per_tenant: int = 24) -> None:
    """Multi-tenant decode serving on the hetGPU runtime (no jax)."""
    from repro.core import HetSession, ServingFrontEnd, TranslationCache
    from repro.core import kernels_suite as suite

    GRID, BLOCK, K, KTILES = 4, 16, 32, 4   # 64 output rows, 4 segments
    M = GRID * BLOCK
    prog, oracle = suite.decode_gemv()
    s = HetSession("vectorized", cache=TranslationCache())
    fn = s.load(prog).function()
    front = ServingFrontEnd(s, default_quota=8, slo_ms=1000.0)
    tenants = {"bronze": 1.0, "silver": 2.0, "gold": 4.0}
    for name, w in tenants.items():
        front.tenant(name, weight=w)

    rng = np.random.default_rng(7)
    W = s.alloc(M * K).copy_from_host(
        rng.normal(size=M * K).astype(np.float32) * 0.1)   # shared weights
    wx, wr, wo = s.alloc(K), s.alloc(M), s.alloc(M)
    fn.launch(GRID, BLOCK, {"W": W, "X": wx, "R": wr, "Out": wo,
                            "K": K, "ktiles": KTILES})     # pay JIT once
    for b in (wx, wr, wo):
        b.free()
    live, results = [], []
    submitted = {n: 0 for n in tenants}
    t0 = time.perf_counter()
    while len(results) < requests_per_tenant * len(tenants) or live:
        for name in tenants:
            t = front.tenants[name]
            while (submitted[name] < requests_per_tenant
                   and len(t.inflight) < t.max_inflight):
                x = rng.normal(size=K).astype(np.float32)
                r = rng.normal(size=M).astype(np.float32)
                xb = s.alloc(K).copy_from_host(x)
                rb = s.alloc(M).copy_from_host(r)
                ob = s.alloc(M)
                tk = front.submit(name, fn, GRID, BLOCK,
                                  {"W": W, "X": xb, "R": rb, "Out": ob,
                                   "K": K, "ktiles": KTILES})
                d2h = ob.copy_to_host_async(stream=t.stream)
                live.append((tk, d2h, (xb, rb, ob), (x, r)))
                submitted[name] += 1
        front.pump(32)
        still = []
        for tk, d2h, bufs, host in live:
            if tk.done() and d2h.done():
                results.append((tk, d2h.result(), host))
                for b in bufs:
                    b.free()
            else:
                still.append((tk, d2h, bufs, host))
        live = still
    front.drain()
    dt = time.perf_counter() - t0

    # spot-check a handful of results against the oracle
    for tk, out, (x, r) in results[::17]:
        want = oracle({"W": W.copy_to_host(), "X": x, "R": r,
                       "Out": np.zeros(M, np.float32),
                       "K": K, "ktiles": KTILES})["Out"]
        np.testing.assert_allclose(out, want, atol=1e-4, rtol=1e-4)
    agg = front.stats()
    pool = s.pool_stats()
    print(f"served {agg['completed']} decode matvecs from "
          f"{len(tenants)} tenants in {dt*1e3:.0f} ms "
          f"(p50 {agg.get('p50_ms', 0):.2f} / "
          f"p99 {agg.get('p99_ms', 0):.2f} ms, "
          f"{agg['rejected']} shed, "
          f"pool reuse {pool['reuse_rate']:.0%})")
    for t in agg["tenants"]:
        print(f"  {t['tenant']:<7} w={t['weight']:.0f} "
              f"completed={t['completed']} p99={t.get('p99_ms', 0):.2f}ms")
    print("results verified against the decode_gemv oracle")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--het-tier", action="store_true",
                    help="serve decode matvecs through the hetGPU "
                         "multi-tenant serving tier instead of jax")
    ap.add_argument("--requests", type=int, default=24,
                    help="(--het-tier) requests per tenant")
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    if args.het_tier:
        het_tier(args.requests)
        return

    import jax
    import jax.numpy as jnp

    from repro import configs
    from repro.models import decode_step, prefill
    if args.arch not in configs.list_archs():
        ap.error(f"unknown --arch {args.arch}")

    cfg = configs.get_smoke_config(args.arch)
    rng = np.random.default_rng(0)
    from repro.models import init_params
    params = init_params(jax.random.key(0), cfg)

    B, S = args.batch, args.prompt_len
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)}
    if cfg.encoder_decoder:
        batch["enc_embeds"] = jnp.asarray(
            rng.normal(size=(B, S, cfg.d_model)) * 0.02,
            jnp.dtype(cfg.compute_dtype))
    if cfg.frontend == "patch":
        batch["embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.frontend_tokens, cfg.d_model)) * 0.02,
            jnp.dtype(cfg.compute_dtype))
        batch["tokens"] = batch["tokens"][:, cfg.frontend_tokens:]

    cache_len = S + args.new_tokens
    t0 = time.perf_counter()
    logits, caches = jax.jit(
        lambda p, b: prefill(p, b, cfg, cache_len=cache_len))(params, batch)
    print(f"prefill {B}x{S}: {(time.perf_counter()-t0)*1e3:.0f} ms")

    step = jax.jit(lambda p, t, c, q: decode_step(p, t, c, q, cfg))
    toks = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    out = [toks]
    t0 = time.perf_counter()
    for i in range(args.new_tokens - 1):
        logits, caches = step(params, toks, caches,
                              jnp.asarray(S + i, jnp.int32))
        toks = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        out.append(toks)
    jax.block_until_ready(toks)
    dt = time.perf_counter() - t0
    n = (args.new_tokens - 1) * B
    print(f"decode: {n} tokens in {dt*1e3:.0f} ms "
          f"({n/dt:.1f} tok/s greedy, batch={B})")
    print("sampled ids:", np.asarray(jnp.concatenate(out, axis=1))[0][:12])


if __name__ == "__main__":
    main()
