"""Batched serving: prefill a batch of prompts, then step the decoder with
a KV cache (windowed / recurrent state depending on architecture).

    PYTHONPATH=src python examples/serve_decode.py --arch h2o-danube-3-4b
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import decode_step, prefill


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b",
                    choices=configs.list_archs())
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = configs.get_smoke_config(args.arch)
    rng = np.random.default_rng(0)
    from repro.models import init_params
    params = init_params(jax.random.key(0), cfg)

    B, S = args.batch, args.prompt_len
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)}
    if cfg.encoder_decoder:
        batch["enc_embeds"] = jnp.asarray(
            rng.normal(size=(B, S, cfg.d_model)) * 0.02,
            jnp.dtype(cfg.compute_dtype))
    if cfg.frontend == "patch":
        batch["embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.frontend_tokens, cfg.d_model)) * 0.02,
            jnp.dtype(cfg.compute_dtype))
        batch["tokens"] = batch["tokens"][:, cfg.frontend_tokens:]

    cache_len = S + args.new_tokens
    t0 = time.perf_counter()
    logits, caches = jax.jit(
        lambda p, b: prefill(p, b, cfg, cache_len=cache_len))(params, batch)
    print(f"prefill {B}x{S}: {(time.perf_counter()-t0)*1e3:.0f} ms")

    step = jax.jit(lambda p, t, c, q: decode_step(p, t, c, q, cfg))
    toks = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    out = [toks]
    t0 = time.perf_counter()
    for i in range(args.new_tokens - 1):
        logits, caches = step(params, toks, caches,
                              jnp.asarray(S + i, jnp.int32))
        toks = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        out.append(toks)
    jax.block_until_ready(toks)
    dt = time.perf_counter() - t0
    n = (args.new_tokens - 1) * B
    print(f"decode: {n} tokens in {dt*1e3:.0f} ms "
          f"({n/dt:.1f} tok/s greedy, batch={B})")
    print("sampled ids:", np.asarray(jnp.concatenate(out, axis=1))[0][:12])


if __name__ == "__main__":
    main()
