"""Driver-style API demo — the CI api-surface smoke.

Exercises the whole object model end to end: Module/Function loading with
typed parameter metadata, DeviceBuffer allocation + explicit transfers,
two genuinely asynchronous Streams whose segments interleave, Event-based
cross-stream ordering, and one live migration of an in-flight async
launch to another backend.  Exits non-zero on any mismatch.

    PYTHONPATH=src python examples/driver_api_demo.py
"""
import sys

import numpy as np

from repro.core import HetSession, migrate
from repro.core import kernels_suite as suite


def main() -> int:
    failures = []

    def check(label, ok):
        print(f"  {'ok ' if ok else 'FAIL'} {label}")
        if not ok:
            failures.append(label)

    session = HetSession("vectorized")
    rng = np.random.default_rng(0)

    # --- modules and typed functions -----------------------------------
    print("module loading + typed metadata:")
    counter_prog, counter_oracle = suite.persistent_counter()
    mod = session.load([suite.vadd()[0], counter_prog])
    vadd = mod.function("vadd")
    counter = mod.function("persistent_counter")
    print(f"  {vadd}")
    check("param metadata", vadd.param("A").kind == "buffer"
          and vadd.param("n").dtype == "i32")

    # --- two streams + segment interleaving ----------------------------
    print("two async streams (segment-granularity interleaving):")
    st1, st2 = session.stream(), session.stream()
    init1 = rng.normal(size=64).astype(np.float32)
    init2 = rng.normal(size=64).astype(np.float32)
    s1 = session.alloc(64).copy_from_host(init1)
    s2 = session.alloc(64).copy_from_host(init2)
    session.sched_trace.clear()
    counter.launch_async(2, 32, {"State": s1, "iters": 6}, stream=st1)
    counter.launch_async(2, 32, {"State": s2, "iters": 6}, stream=st2)
    session.synchronize()
    ids = [t["stream"] for t in session.sched_trace]
    n_overlap = 2 * min(ids.count(st1.sid), ids.count(st2.sid))
    alternated = n_overlap >= 8 and all(
        a != b for a, b in zip(ids[:n_overlap], ids[:n_overlap][1:]))
    print(f"  trace (stream ids): {ids}")
    check("streams alternate per segment", alternated)
    check("stream-1 result", np.allclose(
        s1.copy_to_host(),
        counter_oracle({"State": init1.copy(), "iters": 6})["State"],
        atol=1e-4))

    # --- events: cross-stream ordering ----------------------------------
    print("event-ordered cross-stream dependency:")
    c = session.alloc(64)
    r1 = counter.launch_async(2, 32, {"State": s1, "iters": 4},
                              stream=st1)
    ev = st1.record_event()
    st2.wait_event(ev)                     # st2 waits for st1's counter
    vadd.launch_async(2, 32, {"A": s1, "B": s1, "C": c, "n": 64},
                      stream=st2)
    session.synchronize()
    check("event wait ordered the read", np.allclose(
        c.copy_to_host(), 2 * s1.copy_to_host(), atol=1e-5))
    check("event completed", ev.query() and r1.done())

    # --- live migration of an in-flight async launch --------------------
    print("async launch migrated mid-kernel (vectorized -> pallas):")
    dst = HetSession("pallas")
    dst.load(counter_prog)
    init3 = rng.normal(size=64).astype(np.float32)
    s3 = session.alloc(64).copy_from_host(init3)
    rec = counter.launch_async(2, 32, {"State": s3, "iters": 6},
                               stream=st1)
    session.step(3)                        # pause point: mid-kernel
    check("launch is in flight",
          rec.started and not rec.finished)
    new = migrate(rec, session, dst, "persistent_counter")
    dst.synchronize()
    expect = counter_oracle({"State": init3.copy(), "iters": 6})["State"]
    check("migrated result", np.allclose(
        new.buffer("State").copy_to_host(), expect, atol=1e-4))
    check("buffer identity stable across the hop",
          new.buffer("State").uid == s3.uid)
    print(f"  migration stats: {dst.stats['last_migration']}")

    print(f"\n{'ALL OK' if not failures else 'FAILED: ' + str(failures)}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
