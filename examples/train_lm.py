"""End-to-end training driver: train an assigned-architecture LM with the
full runtime (sharded data pipeline, AdamW, checkpointing, fault
tolerance).

CPU-friendly default: the ~100M-class xlstm-125m at reduced width for a
few hundred steps.  Any registered arch works at its smoke scale:

    PYTHONPATH=src python examples/train_lm.py --arch llama3.2-3b \
        --steps 50 --checkpoint-every 20 --ckpt /tmp/ck
    # full-size configs (for real TPU meshes):
    PYTHONPATH=src python examples/train_lm.py --arch xlstm-125m --full
"""
import argparse

import jax

from repro import configs
from repro.configs.base import ParallelCfg, ShapeCfg
from repro.runtime.train_loop import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-125m",
                    choices=configs.list_archs())
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--checkpoint-every", type=int, default=0)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--full", action="store_true",
                    help="use the full-size config (needs a real mesh)")
    args = ap.parse_args()

    cfg = (configs.get_config(args.arch) if args.full
           else configs.get_smoke_config(args.arch))
    shape = ShapeCfg("train", args.seq, args.batch, "train")
    n_dev = len(jax.devices())
    mesh = jax.make_mesh((n_dev, 1), ("data", "model"))

    trainer = Trainer(cfg, shape, mesh, ckpt_dir=args.ckpt, seed=0,
                      pcfg=ParallelCfg(grad_accum=1, remat=True))
    resumed = trainer.maybe_restore()
    from repro.models.registry import param_count
    print(f"arch={cfg.name} params={param_count(cfg)/1e6:.1f}M  "
          + ("resumed at step %d" % trainer.step if resumed else "fresh"))

    done = 0
    while done < args.steps:
        chunk = min(20, args.steps - done)
        rep = trainer.run(chunk,
                          checkpoint_every=args.checkpoint_every)
        done += rep.steps_run
        print(f"step {trainer.step:5d}  loss {rep.losses[-1]:.4f}  "
              f"(stragglers={rep.straggler_events})")
    if args.ckpt:
        trainer.save_checkpoint()
        print("final checkpoint at step", trainer.step)


if __name__ == "__main__":
    main()
