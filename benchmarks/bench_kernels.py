"""Kernel microbenchmarks: Pallas (interpret) vs jnp oracle wall time plus
the structural VMEM/MXU accounting that matters on real TPU (the CPU
timings validate correctness paths, not TPU speed — see DESIGN.md)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.flash_attention.kernel import flash_attention_fwd
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.rglru_scan.kernel import rglru_scan_fwd
from repro.kernels.rglru_scan.ref import rglru_scan_ref


def _time(fn, reps=3):
    fn()
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps * 1e3


#: tiled-capable suite kernels and a large launch for each: (builder kwargs,
#: grid, block, args builder).  ``grid * block`` is the element count.
_BLOCK_CASES = {
    "vadd": ({}, 64, 256, lambda rng, n: {
        "A": rng.normal(size=n).astype(np.float32),
        "B": rng.normal(size=n).astype(np.float32),
        "C": np.zeros(n, np.float32), "n": n}),
    "saxpy": ({}, 64, 256, lambda rng, n: {
        "X": rng.normal(size=n).astype(np.float32),
        "Y": rng.normal(size=n).astype(np.float32),
        "n": n, "a": 1.5}),
    "stencil_1d": ({}, 64, 256, lambda rng, n: {
        "A": rng.normal(size=n).astype(np.float32),
        "Out": np.zeros(n, np.float32), "n": n}),
    "poly_eval": ({}, 64, 256, lambda rng, n: {
        "X": rng.normal(size=n).astype(np.float32),
        "Coef": rng.normal(size=7).astype(np.float32),
        "Out": np.zeros(n, np.float32), "n": n}),
    "swizzle_copy": ({"size": 16384}, 64, 256, lambda rng, n: {
        "A": rng.normal(size=n).astype(np.float32),
        "Out": np.zeros(n, np.float32)}),
    "dyn_fir": ({"size": 16384}, 64, 256, lambda rng, n: {
        "A": rng.normal(size=n).astype(np.float32),
        "W": rng.normal(size=8).astype(np.float32),
        "Out": np.zeros(n, np.float32), "taps": 4}),
}


def run_het_block() -> list:
    """Scalar-per-thread vs block-tiled pallas codegen on the tiled-capable
    suite kernels at large geometry.  Each mode gets a fresh private
    TranslationCache; the timed run is the warm (cache-hit) launch, so the
    numbers compare executed kernels, not tracing.  ``sched_steps`` is the
    number of pallas grid steps the segment schedules — the structural
    scheduled-op reduction the tiled path buys (each step runs the same
    per-segment op list, just over a wider tile)."""
    import os

    from repro.core import kernels_suite as suite
    from repro.core.backends.pallas_backend import PallasBackend
    from repro.core.cache import TranslationCache
    from repro.core.engine import Engine

    rows = []
    rng = np.random.default_rng(7)

    def measure(enabled, prog, grid, block, args):
        old = os.environ.get("HETGPU_BLOCK_LOWER")
        os.environ["HETGPU_BLOCK_LOWER"] = "1" if enabled else "0"
        try:
            backend = PallasBackend(cache=TranslationCache())
            # cold run populates the cache; warm run is what we time
            Engine(prog, backend, grid, block,
                   {k: (v.copy() if isinstance(v, np.ndarray) else v)
                    for k, v in args.items()}).run()
            t0 = time.perf_counter()
            eng = Engine(prog, backend, grid, block,
                         {k: (v.copy() if isinstance(v, np.ndarray) else v)
                          for k, v in args.items()})
            eng.run()
            ms = (time.perf_counter() - t0) * 1e3
            stats = dict(backend.block_stats)
            return eng, ms, stats
        finally:
            if old is None:
                os.environ.pop("HETGPU_BLOCK_LOWER", None)
            else:
                os.environ["HETGPU_BLOCK_LOWER"] = old

    for name, (kwargs, grid, block, mk) in _BLOCK_CASES.items():
        prog, _oracle = suite.SUITE[name](**kwargs) \
            if kwargs else suite.SUITE[name]()
        n = grid * block
        args = mk(rng, n)
        eng_s, scalar_ms, _ = measure(False, prog, grid, block, args)
        eng_t, tiled_ms, tstats = measure(True, prog, grid, block, args)
        # conformance: the tiled path must be bit-identical to scalar
        identical = all(
            np.array_equal(np.asarray(eng_s.result(o)),
                           np.asarray(eng_t.result(o)))
            for o in (p.name for p in prog.buffers()))
        # scheduled grid steps: scalar path walks one step per hetIR block,
        # the tiled path one step per BLOCK-wide element tile
        from repro.core.passes import choose_block
        cand = choose_block(n) or n
        sched_scalar, sched_tiled = grid, max(1, n // cand)
        rows.append({
            "bench": "het_block", "kernel": name, "n": n,
            "tiled_segments": tstats.get("tiled", 0),
            "scalar_ms": round(scalar_ms, 2),
            "tiled_ms": round(tiled_ms, 2),
            "speedup": round(scalar_ms / max(tiled_ms, 1e-9), 2),
            "sched_steps_scalar": sched_scalar,
            "sched_steps_tiled": sched_tiled,
            "sched_reduction": round(sched_scalar / sched_tiled, 1),
            "bit_identical": identical,
        })
    return rows


def run_zoo() -> list:
    """Model-zoo section: one row per zoo kernel (repro.zoo) — the
    schedule-derived byte/FLOP totals (same accounting as the roofline),
    the interp backend's true divergence-aware step count at O0 vs
    OPT_MAX, and the pallas block-lowering verdict: tiled segment count
    when the fast path fires, else the named refusal categories from
    ``repro.core.passes.REFUSAL_REASONS``."""
    import repro.zoo as zoo  # noqa: F401  (import registers the kernels)
    from benchmarks.roofline import (_ELEM_BYTES, _FLOP_WEIGHT, _MEM_WEIGHT,
                                     _schedule_histogram)
    from repro.core import Engine, OPT_MAX, get_backend
    from repro.core import kernels_suite as suite
    from repro.core.backends.pallas_backend import PallasBackend
    from repro.core.cache import TranslationCache

    rows = []
    for name in sorted(zoo.ZOO):
        steps = {}
        flops = nbytes = threads = 0
        for level in (0, OPT_MAX):
            prog, _oracle, grid, block, args, _outs = suite.example_launch(
                name, rng=np.random.default_rng(11))
            be = get_backend("interp", cache=TranslationCache())
            eng = Engine(prog, be, grid, block, dict(args), opt_level=level)
            eng.run()
            steps[level] = be.steps_executed
            if level == 0:
                hist = _schedule_histogram(eng.nodes, eng.launch.scalars)
                threads = grid * block
                flops = sum(_FLOP_WEIGHT.get(op, 0) * c
                            for op, c in hist.items()) * threads
                nbytes = sum(_MEM_WEIGHT.get(op, 0) * c
                             for op, c in hist.items()) * threads * _ELEM_BYTES

        prog, _oracle, grid, block, args, _outs = suite.example_launch(
            name, rng=np.random.default_rng(11))
        backend = PallasBackend(cache=TranslationCache())
        Engine(prog, backend, grid, block, dict(args)).run()
        stats = backend.block_stats
        verdict = ("tiled" if stats.get("tiled")
                   else "+".join(sorted(stats.get("reasons", {}))) or "scalar")
        rows.append({
            "bench": "zoo", "kernel": name, "threads": threads,
            "flops": int(flops), "bytes": int(nbytes),
            "intensity": round(flops / nbytes, 4) if nbytes else None,
            "interp_steps_o0": steps[0],
            "interp_steps_omax": steps[OPT_MAX],
            "interp_step_cut": round(
                1 - steps[OPT_MAX] / max(steps[0], 1), 3),
            "tiled_segments": stats.get("tiled", 0),
            "scalar_segments": stats.get("scalar", 0),
            "block_verdict": verdict,
        })
    return rows


def run() -> list:
    rows = []
    rng = np.random.default_rng(3)

    B, H, S, d = 1, 2, 512, 64
    q, k, v = (jnp.asarray(rng.normal(size=(B, H, S, d)), jnp.float32)
               for _ in range(3))
    ref_ms = _time(lambda: jax.block_until_ready(
        attention_ref(q, k, v, causal=True)))
    # structural accounting for the kernel (TPU contract)
    bq = bk = 128
    vmem_bytes = (bq * d + 2 * bk * d) * 4 + bq * d * 4 + 2 * bq * 4
    flops_per_tile = 2 * bq * bk * d * 2
    rows.append({"bench": "kernels", "kernel": "flash_attention",
                 "ref_ms": round(ref_ms, 1),
                 "vmem_working_set_kb": round(vmem_bytes / 1024, 1),
                 "mxu_flops_per_tile": flops_per_tile,
                 "hbm_traffic_ratio_vs_naive":
                 round((S * S) / (S * d), 1)})

    B, S, D = 2, 1024, 256
    a = jnp.asarray(rng.uniform(0.9, 0.999, (B, S, D)), jnp.float32)
    x = jnp.asarray(rng.normal(size=(B, S, D)) * 0.1, jnp.float32)
    h0 = jnp.zeros((B, D), jnp.float32)
    ref_ms = _time(lambda: jax.block_until_ready(
        rglru_scan_ref(a, x, h0)[0]))
    rows.append({"bench": "kernels", "kernel": "rglru_scan",
                 "ref_ms": round(ref_ms, 1),
                 "hbm_bytes_per_elem_kernel": 3 * 4,
                 "hbm_bytes_per_elem_scan": "O(steps) roundtrips"})
    return rows
