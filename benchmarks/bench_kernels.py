"""Kernel microbenchmarks: Pallas (interpret) vs jnp oracle wall time plus
the structural VMEM/MXU accounting that matters on real TPU (the CPU
timings validate correctness paths, not TPU speed — see DESIGN.md)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.flash_attention.kernel import flash_attention_fwd
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.rglru_scan.kernel import rglru_scan_fwd
from repro.kernels.rglru_scan.ref import rglru_scan_ref


def _time(fn, reps=3):
    fn()
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps * 1e3


def run() -> list:
    rows = []
    rng = np.random.default_rng(3)

    B, H, S, d = 1, 2, 512, 64
    q, k, v = (jnp.asarray(rng.normal(size=(B, H, S, d)), jnp.float32)
               for _ in range(3))
    ref_ms = _time(lambda: jax.block_until_ready(
        attention_ref(q, k, v, causal=True)))
    # structural accounting for the kernel (TPU contract)
    bq = bk = 128
    vmem_bytes = (bq * d + 2 * bk * d) * 4 + bq * d * 4 + 2 * bq * 4
    flops_per_tile = 2 * bq * bk * d * 2
    rows.append({"bench": "kernels", "kernel": "flash_attention",
                 "ref_ms": round(ref_ms, 1),
                 "vmem_working_set_kb": round(vmem_bytes / 1024, 1),
                 "mxu_flops_per_tile": flops_per_tile,
                 "hbm_traffic_ratio_vs_naive":
                 round((S * S) / (S * d), 1)})

    B, S, D = 2, 1024, 256
    a = jnp.asarray(rng.uniform(0.9, 0.999, (B, S, D)), jnp.float32)
    x = jnp.asarray(rng.normal(size=(B, S, D)) * 0.1, jnp.float32)
    h0 = jnp.zeros((B, D), jnp.float32)
    ref_ms = _time(lambda: jax.block_until_ready(
        rglru_scan_ref(a, x, h0)[0]))
    rows.append({"bench": "kernels", "kernel": "rglru_scan",
                 "ref_ms": round(ref_ms, 1),
                 "hbm_bytes_per_elem_kernel": 3 * 4,
                 "hbm_bytes_per_elem_scan": "O(steps) roundtrips"})
    return rows
