"""One function per paper table. Prints ``bench,key=value,...`` CSV rows.

``--json PATH`` additionally writes every row (all sections, including the
roofline rows) as one JSON document — the machine-readable artifact CI
uploads on every run.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import List, Optional


def _emit(rows, sink: Optional[List[dict]] = None) -> None:
    for r in rows:
        if sink is not None:
            sink.append(dict(r))
        r = dict(r)
        bench = r.pop("bench")
        kv = ",".join(f"{k}={v}" for k, v in r.items())
        print(f"{bench},{kv}")


def main(argv: Optional[List[str]] = None) -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--json", metavar="PATH", default=None,
                        help="also write all rows as one JSON document")
    parser.add_argument("--cluster", action="store_true",
                        help="also run the multi-process cluster cache "
                             "fabric scenario (spawns fresh interpreters; "
                             "slow, so opt-in)")
    opts = parser.parse_args(argv)

    from benchmarks import (bench_fleet, bench_kernels, bench_migration,
                            bench_overhead, bench_portability,
                            bench_serving, bench_streams,
                            bench_translation, roofline)

    sink: Optional[List[dict]] = [] if opts.json else None
    print("# hetGPU reproduction benchmarks (one per paper table)")
    print("# -- paper 6.1: portability matrix --")
    _emit(bench_portability.run(), sink)
    print("# -- paper 6.2: overhead vs native --")
    _emit(bench_overhead.run(), sink)
    print("# -- paper 6.2: translation/JIT cost --")
    _emit(bench_translation.run(), sink)
    print("# -- paper 4.2: pass pipeline (per-pass stats, interp steps) --")
    _emit(bench_translation.run_pass_pipeline(), sink)
    print("# -- paper 4.2: launch-time specialization (generic vs bound) --")
    _emit(bench_translation.run_specialization(), sink)
    print("# -- paper 4.2: persistent cache, cold vs warm start --")
    _emit(bench_translation.run_cold_warm(), sink)
    if opts.cluster:
        print("# -- paper 4.2: cluster cache fabric (translate once "
              "per fleet) --")
        _emit(bench_translation.run_cluster(), sink)
    print("# -- paper 6.3: live migration downtime --")
    _emit(bench_migration.run(), sink)
    print("# -- paper 4.3: stream scheduler (async overlap + overhead) --")
    _emit(bench_streams.run(), sink)
    print("# -- paper 4.3: multi-tenant serving tier (fair share, pool, "
          "shedding) --")
    _emit(bench_serving.run(), sink)
    print("# -- paper 6.3: self-healing fleet (kill -9 recovery latency) --")
    _emit(bench_fleet.run(), sink)
    print("# -- kernel structural benchmarks --")
    _emit(bench_kernels.run(), sink)
    print("# -- block-tiled vs scalar-per-thread codegen --")
    _emit(bench_kernels.run_het_block(), sink)
    print("# -- model zoo: attention/MoE/recurrent kernels (structural) --")
    _emit(bench_kernels.run_zoo(), sink)
    print("# -- roofline (measured het kernels + dry-run artifacts) --")
    _emit(roofline.run(), sink)

    if opts.json:
        with open(opts.json, "w") as fh:
            json.dump({"rows": sink}, fh, indent=1)
        print(f"# wrote {len(sink)} rows to {opts.json}", file=sys.stderr)


if __name__ == '__main__':
    main()
