"""One function per paper table. Prints ``bench,key=value,...`` CSV rows."""
from __future__ import annotations

import sys


def _emit(rows) -> None:
    for r in rows:
        bench = r.pop("bench")
        kv = ",".join(f"{k}={v}" for k, v in r.items())
        print(f"{bench},{kv}")


def main() -> None:
    from benchmarks import (bench_fleet, bench_kernels, bench_migration,
                            bench_overhead, bench_portability,
                            bench_serving, bench_streams,
                            bench_translation, roofline)

    print("# hetGPU reproduction benchmarks (one per paper table)")
    print("# -- paper 6.1: portability matrix --")
    _emit(bench_portability.run())
    print("# -- paper 6.2: overhead vs native --")
    _emit(bench_overhead.run())
    print("# -- paper 6.2: translation/JIT cost --")
    _emit(bench_translation.run())
    print("# -- paper 4.2: pass pipeline (per-pass stats, interp steps) --")
    _emit(bench_translation.run_pass_pipeline())
    print("# -- paper 4.2: launch-time specialization (generic vs bound) --")
    _emit(bench_translation.run_specialization())
    print("# -- paper 4.2: persistent cache, cold vs warm start --")
    _emit(bench_translation.run_cold_warm())
    print("# -- paper 6.3: live migration downtime --")
    _emit(bench_migration.run())
    print("# -- paper 4.3: stream scheduler (async overlap + overhead) --")
    _emit(bench_streams.run())
    print("# -- paper 4.3: multi-tenant serving tier (fair share, pool, "
          "shedding) --")
    _emit(bench_serving.run())
    print("# -- paper 6.3: self-healing fleet (kill -9 recovery latency) --")
    _emit(bench_fleet.run())
    print("# -- kernel structural benchmarks --")
    _emit(bench_kernels.run())
    print("# -- roofline (from dry-run artifacts; see EXPERIMENTS.md) --")
    _emit(roofline.run())


if __name__ == '__main__':
    main()
