"""Serving-tier benchmark — the multi-tenant coordinator under load
(paper §4.3's abstraction held to serving scale): thousands of
concurrent launches across many tenant streams, weighted-fair segment
shares measured from ``sched_trace``, steady-state buffer-pool reuse,
closed-loop latency against an SLO, and quota-based load shedding
(rejected-with-error, never a lost in-flight request).

``python -m benchmarks.bench_serving --smoke`` runs the same phases and
*asserts* the serving acceptance criteria (CI smoke job).
"""
from __future__ import annotations

import sys
import time

import numpy as np

from repro.core import (HetSession, QuotaExceeded, ServingFrontEnd,
                        TranslationCache)
from repro.core import kernels_suite as suite

# one request = one persistent_counter launch of ITERS segments on a
# 64-element state buffer (allocated per request, freed on completion —
# the alloc/free churn is what the pool has to absorb)
ITERS = 4
STATE = 64


def _mk_front(n_tenants, weights, quota, **session_kw):
    s = HetSession("vectorized", cache=TranslationCache(), **session_kw)
    fn = s.load(suite.persistent_counter()[0]).function()
    front = ServingFrontEnd(s, max_inflight=n_tenants * quota,
                            default_quota=quota)
    names = []
    for i in range(n_tenants):
        name = f"t{i}"
        front.tenant(name, weight=weights[i % len(weights)])
        names.append(name)
    return s, fn, front, names


def _submit_one(s, fn, front, name, live):
    db = s.alloc(STATE)
    ticket = front.submit(name, fn, 2, 32, {"State": db, "iters": ITERS})
    live.append((ticket, db))
    return ticket


def _reap_free(live):
    still = []
    for ticket, db in live:
        if ticket.done():
            db.free()
        else:
            still.append((ticket, db))
    live[:] = still


def run(n_tenants: int = 8, total_launches: int = 1200,
        per_tenant_backlog: int = 16) -> list:
    rows = []
    weights = [1.0, 1.0, 2.0, 2.0, 3.0, 3.0, 4.0, 4.0]

    # ---- phase 1: sustained multi-tenant load ----------------------------
    # closed loop per tenant: keep `per_tenant_backlog` requests in
    # flight each, reap-and-free on completion, until `total_launches`
    # have been admitted.  Mid-run (every tenant saturated) we measure
    # the weighted-fair share split over a fixed trace window.
    s, fn, front, names = _mk_front(n_tenants, weights,
                                    quota=per_tenant_backlog)
    fn.launch(2, 32, {"State": s.alloc(STATE), "iters": ITERS})  # warm
    live: list = []
    submitted = 0
    t0 = time.perf_counter()
    # saturate every tenant first
    for name in names:
        for _ in range(per_tenant_backlog):
            _submit_one(s, fn, front, name, live)
            submitted += 1

    # steady state starts here: the pool has seen the cold allocations
    pool0 = s.pool_stats()

    # fairness window: all tenants backlogged, count segments per stream
    s.sched_trace.clear()
    window = 50 * n_tenants
    s.step(window)
    counts = {front.tenants[n].stream.sid: 0 for n in names}
    for t in s.sched_trace:
        if t["stream"] in counts:
            counts[t["stream"]] += 1
    total_segs = sum(counts.values()) or 1
    total_w = sum(front.tenants[n].stream.weight for n in names)
    max_rel_err = 0.0
    for n in names:
        st = front.tenants[n].stream
        want = st.weight / total_w
        got = counts[st.sid] / total_segs
        max_rel_err = max(max_rel_err, abs(got - want) / want)

    # keep the closed loop going until the launch target is admitted
    while submitted < total_launches or live:
        front.pump(64)
        _reap_free(live)
        for name in names:
            t = front.tenants[name]
            while (submitted < total_launches
                   and len(t.inflight) < per_tenant_backlog):
                _submit_one(s, fn, front, name, live)
                submitted += 1
    front.drain()
    _reap_free(live)
    elapsed = time.perf_counter() - t0

    agg = front.stats()
    pool = s.pool_stats()
    dh = pool["hits"] - pool0["hits"]
    dm = pool["misses"] - pool0["misses"]
    steady_reuse = dh / max(dh + dm, 1)
    lost = agg["admitted"] - agg["completed"] - agg["inflight"]
    rows.append({
        "bench": "serving",
        "case": f"{n_tenants}tenants_x{total_launches}launches",
        "launches": agg["admitted"],
        "tenants": n_tenants,
        "elapsed_ms": round(elapsed * 1e3, 1),
        "throughput_lps": round(agg["completed"] / max(elapsed, 1e-9), 1),
        "fair_share_max_rel_err": round(max_rel_err, 3),
        "pool_reuse_rate": round(pool["reuse_rate"], 3),
        "pool_steady_reuse_rate": round(steady_reuse, 3),
        "p50_ms": agg.get("p50_ms", 0.0),
        "p99_ms": agg.get("p99_ms", 0.0),
        "lost_inflight": lost,
        "sched_trace_dropped": s.stats["sched_trace_dropped"],
    })

    # ---- phase 2: oversubscription -> quota shedding ---------------------
    # tiny quotas, a burst far above them: the excess is rejected with
    # QuotaExceeded *at admission*; everything admitted still completes.
    s2, fn2, front2, names2 = _mk_front(n_tenants, weights, quota=4)
    live2: list = []
    rejected = 0
    for _ in range(8):                    # 8 bursts of n_tenants*8
        for name in names2:
            for _ in range(8):
                try:
                    _submit_one(s2, fn2, front2, name, live2)
                except QuotaExceeded:
                    rejected += 1
        front2.pump(16)                   # a trickle of service
        _reap_free(live2)
    front2.drain()
    _reap_free(live2)
    agg2 = front2.stats()
    rows.append({
        "bench": "serving",
        "case": "oversubscribed_shedding",
        "offered": agg2["admitted"] + agg2["rejected"],
        "admitted": agg2["admitted"],
        "rejected": agg2["rejected"],
        "completed": agg2["completed"],
        "lost_inflight": agg2["admitted"] - agg2["completed"],
    })
    assert rejected == agg2["rejected"]
    return rows


def smoke(slo_p99_ms: float = 2000.0) -> None:
    """CI smoke: run both phases and assert the acceptance criteria."""
    rows = run()
    load, shed = rows[0], rows[1]
    assert load["launches"] >= 1000, load
    assert load["tenants"] >= 8, load
    assert load["fair_share_max_rel_err"] <= 0.15, \
        f"weighted shares off by >15%: {load}"
    assert load["pool_steady_reuse_rate"] >= 0.90, \
        f"steady-state pool reuse below 90%: {load}"
    assert load["lost_inflight"] == 0, load
    assert load["p99_ms"] <= slo_p99_ms, \
        f"p99 {load['p99_ms']}ms blew the {slo_p99_ms}ms smoke SLO: {load}"
    assert shed["rejected"] > 0, \
        f"oversubscription did not shed: {shed}"
    assert shed["lost_inflight"] == 0, \
        f"shedding lost admitted work: {shed}"
    for r in rows:
        print(r)
    print("serving smoke OK")


if __name__ == "__main__":
    if "--smoke" in sys.argv:
        smoke()
    else:
        for r in run():
            print(r)
