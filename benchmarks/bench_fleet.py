"""Fleet benchmark — the self-healing control plane under closed-loop
load with a real kill -9 mid-run (paper §6.3's migration claim at
production scale, measured instead of merely survived).

Three worker processes serve a closed-loop stream of ``dyn_matmul`` /
``decode_gemv`` launches; halfway through, one worker is SIGKILLed by
the in-worker :class:`~repro.core.fleet.FaultInjector` (fixed seed, so
the schedule is reproducible).  Reported per phase:

* throughput (launches/s) and total segment slices pumped;
* **recovery latency**: detect → requeue → replay → complete, per
  evacuated launch (max and mean, from the coordinator's failure log);
* loss accounting: submitted vs completed vs duplicate acks.

``python -m benchmarks.bench_fleet --smoke`` runs a scaled-down run and
*asserts* zero lost and zero double-acked launches plus full bit-parity
of every surviving result with a single-process oracle (CI chaos job).
"""
from __future__ import annotations

import sys
import tempfile
import time
from pathlib import Path

import numpy as np

from repro.core.fleet import MID_KERNEL, FleetCoordinator
from repro.core.kernels_suite import example_launch
from repro.core.runtime import HetSession

KERNELS = ("dyn_matmul", "decode_gemv")


def _examples():
    out = {}
    for kernel in KERNELS:
        prog, _oracle, grid, block, args, outs = example_launch(kernel)
        out[kernel] = (prog, grid, block, args, outs)
    return out


def _oracle_outputs(examples):
    """Single-process reference results for bit-parity checks."""
    oracles = {}
    sess = HetSession("interp")
    for kernel, (prog, grid, block, args, outs) in examples.items():
        sess.load(prog)
        fn = sess.function(kernel)
        eng_args = {}
        for p in fn.params:
            v = args[p.name]
            if p.kind == "buffer":
                arr = np.asarray(v)
                db = sess.alloc(arr.size, arr.dtype)
                db.copy_from_host(arr)
                eng_args[p.name] = db
            else:
                eng_args[p.name] = v
        rec = fn.launch_async(grid, block, eng_args)
        sess.synchronize()
        oracles[kernel] = {n: rec.buffer(n).copy_to_host() for n in outs}
    return oracles


def _drive(fleet, examples, total, kill_after=None):
    """Closed-loop: keep ~8 launches in flight until ``total`` complete.
    ``kill_after`` arms nothing here — the injected fault plan fires on
    its own once the matching launch reaches its segment threshold."""
    tickets = []
    submitted = 0
    t0 = time.perf_counter()
    while fleet.counters["completed"] < total:
        while submitted < total and \
                len(fleet.queue.unacked()) < 8:
            kernel = KERNELS[submitted % len(KERNELS)]
            prog, grid, block, args, _outs = examples[kernel]
            tickets.append(fleet.submit(kernel, grid, block, args))
            submitted += 1
        fleet.pump()
    wall = time.perf_counter() - t0
    return tickets, wall


def run(total: int = 60, fault_seed: int = 42) -> list:
    examples = _examples()
    rows = []
    with tempfile.TemporaryDirectory() as td:
        # phase 1: healthy fleet throughput baseline
        with FleetCoordinator(backends=("interp",) * 3,
                              queue_dir=Path(td) / "q1",
                              fault_plan=[]) as fleet:
            fleet.register([examples[k][0] for k in KERNELS])
            _tickets, wall = _drive(fleet, examples, total)
            st = fleet.fleet_stats()
            rows.append({
                "bench": "fleet_healthy", "workers": 3, "launches": total,
                "wall_s": round(wall, 3),
                "launches_per_s": round(total / wall, 1),
                "migrated": st["migrated"], "retried": st["retried"]})

        # phase 2: same load, one worker SIGKILLed mid-kernel
        plan = [{"point": MID_KERNEL, "worker": 0,
                 "kernel": "dyn_matmul", "nth": max(1, total // 4),
                 "after_segments": 2}]
        with FleetCoordinator(backends=("interp",) * 3,
                              queue_dir=Path(td) / "q2",
                              fault_plan=plan,
                              fault_seed=fault_seed) as fleet:
            fleet.register([examples[k][0] for k in KERNELS])
            tickets, wall = _drive(fleet, examples, total)
            st = fleet.fleet_stats()
            row = {
                "bench": "fleet_chaos", "workers": 3, "launches": total,
                "wall_s": round(wall, 3),
                "launches_per_s": round(total / wall, 1),
                "workers_lost": st["workers_lost"],
                "evacuated": st["evacuated"], "retried": st["retried"],
                "completed": st["completed"],
                "duplicate_acks": st["duplicate_acks"]}
            if "recovery_ms_max" in st:
                row["recovery_ms_max"] = round(st["recovery_ms_max"], 1)
                row["recovery_ms_mean"] = round(st["recovery_ms_mean"], 1)
            rows.append(row)
            rows.append({
                "bench": "fleet_loss_audit",
                "submitted": st["submitted"],
                "acked": st["queue"]["acked"],
                "unacked": len(fleet.queue.unacked()),
                "lost": st["submitted"] - st["queue"]["acked"]})
    return rows


def smoke(total: int = 20) -> None:
    """CI smoke: scaled-down chaos run; assert zero lost launches, zero
    duplicate acks, at least one real kill, and bit-parity of every
    result with the single-process oracle."""
    examples = _examples()
    oracles = _oracle_outputs(examples)
    plan = [{"point": MID_KERNEL, "worker": 0, "kernel": "dyn_matmul",
             "nth": 3, "after_segments": 2}]
    with tempfile.TemporaryDirectory() as td:
        with FleetCoordinator(backends=("interp",) * 3,
                              queue_dir=Path(td) / "q",
                              fault_plan=plan, fault_seed=42) as fleet:
            fleet.register([examples[k][0] for k in KERNELS])
            tickets, wall = _drive(fleet, examples, total)
            st = fleet.fleet_stats()
            assert st["workers_lost"] == 1, st
            assert st["completed"] == total, st
            assert st["duplicate_acks"] == 0, st
            lost = st["submitted"] - st["queue"]["acked"]
            assert lost == 0 and not fleet.queue.unacked(), st
            assert st["evacuated"] >= 1 and st["retried"] >= 1, st
            assert "recovery_ms_max" in st, st
            for t in tickets:
                for name, expect in oracles[t.kernel].items():
                    assert np.array_equal(t.result(name), expect), \
                        f"{t.kernel}.{name} diverged after recovery"
    print(f"fleet smoke OK: {total} launches, 1 kill -9, 0 lost, "
          f"recovery_ms_max={st['recovery_ms_max']:.0f}")


if __name__ == "__main__":
    if "--smoke" in sys.argv:
        smoke()
    else:
        for r in run():
            bench = r.pop("bench")
            print(bench + "," + ",".join(f"{k}={v}"
                                         for k, v in r.items()))
