"""Paper §6.2 — overhead of the hetGPU abstraction vs native execution.

Native = the same math as a direct jitted-jnp program; hetGPU = the hetIR
binary executed through the vectorized backend.  The paper reports <10%
on compute-bound kernels for its translation path; ours adds the engine /
segment machinery, measured here per launch (cached translation).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Engine, get_backend
from repro.core import kernels_suite as suite


def _time(fn, reps=20):
    fn()  # warm
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps * 1e3


def run() -> list:
    rows = []
    rng = np.random.default_rng(0)
    n = 1 << 16
    grid, block = n // 256, 256

    # --- vadd ---------------------------------------------------------
    A = rng.normal(size=n).astype(np.float32)
    B = rng.normal(size=n).astype(np.float32)
    native = jax.jit(lambda a, b: a + b)
    aj, bj = jnp.asarray(A), jnp.asarray(B)
    native_ms = _time(lambda: jax.block_until_ready(native(aj, bj)))

    prog, _ = suite.vadd()
    be = get_backend("vectorized")
    args = {"A": A, "B": B, "C": np.zeros(n, np.float32), "n": n}
    eng = Engine(prog, be, grid, block, dict(args))
    eng.run()  # warm/translate

    def het():
        e = Engine(prog, be, grid, block, dict(args))
        e.run()

    het_ms = _time(het, reps=5)
    rows.append({"bench": "overhead", "kernel": "vadd",
                 "native_ms": round(native_ms, 3),
                 "hetgpu_ms": round(het_ms, 3),
                 "ratio": round(het_ms / max(native_ms, 1e-9), 1)})

    # --- dot product ----------------------------------------------------
    native_dot = jax.jit(lambda a, b: jnp.dot(a, b))
    native_ms = _time(lambda: jax.block_until_ready(native_dot(aj, bj)))
    prog, _ = suite.dot_product()
    args = {"A": A, "B": B, "Out": np.zeros(1, np.float32), "n": n}
    eng = Engine(prog, be, grid, block, dict(args))
    eng.run()

    def het2():
        e = Engine(prog, be, grid, block, dict(args))
        e.run()

    het_ms = _time(het2, reps=5)
    rows.append({"bench": "overhead", "kernel": "dot_product",
                 "native_ms": round(native_ms, 3),
                 "hetgpu_ms": round(het_ms, 3),
                 "ratio": round(het_ms / max(native_ms, 1e-9), 1)})

    # --- matmul (compute-bound) ------------------------------------------
    M, K, N = 64, 256, 256
    Am = rng.normal(size=(M, K)).astype(np.float32)
    Bm = rng.normal(size=(K, N)).astype(np.float32)
    native_mm = jax.jit(lambda a, b: a @ b)
    amj, bmj = jnp.asarray(Am), jnp.asarray(Bm)
    native_ms = _time(lambda: jax.block_until_ready(native_mm(amj, bmj)))
    prog, _ = suite.matmul_tiled(tile_k=8)
    args = {"A": Am.reshape(-1), "B": Bm.reshape(-1),
            "C": np.zeros(M * N, np.float32),
            "K": K, "N": N, "ktiles": K // 8}
    eng = Engine(prog, be, M, N, dict(args))
    eng.run()

    def het3():
        e = Engine(prog, be, M, N, dict(args))
        e.run()

    het_ms = _time(het3, reps=3)
    rows.append({"bench": "overhead", "kernel": "matmul",
                 "native_ms": round(native_ms, 3),
                 "hetgpu_ms": round(het_ms, 3),
                 "ratio": round(het_ms / max(native_ms, 1e-9), 1)})
    return rows
