"""Paper §6.3 — live migration downtime.

(a) Kernel-level: the paper's iterative tiled matmul, paused at a k-tile
    barrier on one backend and resumed on another, with the
    checkpoint/transfer/restore breakdown the paper reports (their H100 ->
    9070 XT -> Tenstorrent chain becomes vectorized -> pallas -> interp).
(b) Job-level: a training job live-migrated across meshes through the
    topology-neutral checkpoint (the cluster-scale analogue).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import Engine, HetSession, Snapshot, get_backend, migrate
from repro.core import kernels_suite as suite


def run() -> list:
    rows = []
    rng = np.random.default_rng(5)

    # ---- (a) kernel-level chain migration --------------------------------
    M, K, N, TK = 16, 64, 32, 8
    A = rng.normal(size=(M, K)).astype(np.float32)
    B = rng.normal(size=(K, N)).astype(np.float32)
    args = {"A": A.reshape(-1), "B": B.reshape(-1),
            "C": np.zeros(M * N, np.float32),
            "K": K, "N": N, "ktiles": K // TK}
    prog, oracle = suite.matmul_tiled(TK)

    ref = Engine(prog, get_backend("vectorized"), M, N, dict(args))
    t0 = time.perf_counter()
    ref.run()
    baseline_ms = (time.perf_counter() - t0) * 1e3

    chain = ["vectorized", "pallas", "interp"]
    eng = Engine(prog, get_backend(chain[0]), M, N, dict(args))
    eng.run(max_segments=5)
    total_down = 0.0
    for hop, dst in enumerate(chain[1:], 1):
        t0 = time.perf_counter()
        blob = eng.snapshot().to_bytes()          # checkpoint
        t1 = time.perf_counter()
        snap = Snapshot.from_bytes(blob)          # "transfer"
        eng = Engine.resume(prog, get_backend(dst), snap)   # restore
        t2 = time.perf_counter()
        rows.append({"bench": "migration", "case": f"hop{hop}->{dst}",
                     "checkpoint_ms": round((t1 - t0) * 1e3, 2),
                     "restore_ms": round((t2 - t1) * 1e3, 2),
                     "payload_kb": round(len(blob) / 1024, 1)})
        total_down += (t2 - t0) * 1e3
        if dst != chain[-1]:
            eng.run(max_segments=4)
    eng.run()
    expect = oracle(dict(args))
    ok = np.allclose(eng.result("C"), expect["C"], atol=1e-4, rtol=1e-4)
    rows.append({"bench": "migration", "case": "chain_total",
                 "correct": bool(ok),
                 "downtime_ms": round(total_down, 2),
                 "baseline_run_ms": round(baseline_ms, 2)})

    # ---- (a2) driver-API: migrate an in-flight *async* launch while a
    # second stream keeps serving — the paper's migration-under-load shape
    prog, oracle = suite.persistent_counter()
    init_m = rng.normal(size=64).astype(np.float32)
    init_s = rng.normal(size=64).astype(np.float32)
    s_src, s_dst = HetSession("vectorized"), HetSession("pallas")
    counter = s_src.load(prog).function()
    s_dst.load(prog)
    moving = s_src.alloc(64).copy_from_host(init_m)
    staying = s_src.alloc(64).copy_from_host(init_s)
    st_mig, st_bg = s_src.stream(), s_src.stream()
    rec = counter.launch_async(2, 32, {"State": moving, "iters": 8},
                               stream=st_mig)
    counter.launch_async(2, 32, {"State": staying, "iters": 8},
                         stream=st_bg)
    s_src.step(3)                       # both in flight, interleaved
    t0 = time.perf_counter()
    new = migrate(rec, s_src, s_dst, "persistent_counter")
    downtime = (time.perf_counter() - t0) * 1e3
    s_src.synchronize()                 # background stream finishes on src
    s_dst.synchronize()                 # migrated launch finishes on dst
    ok = np.allclose(
        new.buffer("State").copy_to_host(),
        oracle({"State": init_m.copy(), "iters": 8})["State"],
        atol=1e-4) and np.allclose(
        staying.copy_to_host(),
        oracle({"State": init_s.copy(), "iters": 8})["State"],
        atol=1e-4)
    rows.append({"bench": "migration", "case": "async_under_load",
                 "correct": bool(ok),
                 "downtime_ms": round(downtime, 2),
                 "payload_kb": round(
                     s_dst.stats["last_migration"]["payload_bytes"] / 1024,
                     1)})

    # ---- (b) training-job migration (topology-neutral state) -------------
    import jax
    from repro import configs
    from repro.configs.base import ShapeCfg
    from repro.runtime.train_loop import Trainer

    cfg = configs.get_smoke_config("llama3.2-3b")
    shape = ShapeCfg("tiny", 32, 4, "train")
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    tr = Trainer(cfg, shape, mesh, seed=9)
    tr.run(2)
    t0 = time.perf_counter()
    tr.resize(mesh)  # full snapshot -> reshard -> rebind path
    mig_ms = (time.perf_counter() - t0) * 1e3
    rep = tr.run(1)
    rows.append({"bench": "migration", "case": "train_job_resize",
                 "migrate_ms": round(mig_ms, 1),
                 "loss_after": round(rep.losses[0], 4)})
    return rows
