"""Paper §6.2 — translation/JIT cost per backend (first launch vs cached),
plus the cluster-lifetime cold-start vs warm-start scenario.

The paper reports 10-200 ms per kernel for PTX/SPIR-V/Metalium paths; here
translation = staging hetIR segments through the pass pipeline plus
jax.export tracing (vectorized / pallas) or plan staging (interp).
Each (kernel, backend) pair gets a fresh shared
:class:`~repro.core.cache.TranslationCache` and launches twice: the first
launch pays translation (all misses), the relaunch must run entirely from
cache (hit_rate > 0).  Rows also carry the pass-pipeline op reduction so
the optimize-then-translate pipeline is visible in one table.

``run_cold_warm`` measures what persistence buys (paper §4.2: JIT cost is
per *cluster lifetime*, not per process): a **cold** start translates the
suite into a fresh :class:`~repro.core.cache.DiskStore`; a **warm** start
rebuilds the in-memory cache from scratch against the now-populated store,
so every segment is a disk restore.  "Translation time" is the cache's own
accounting — ``translate_ms`` (wall-time inside translation factories:
staging + jax.export tracing) for cold, ``translate_ms + restore_ms``
(any fresh translation plus deserialize/revive time) for warm — and the
table also reports end-to-end launch wall time for both phases.  Warm
timing is further split into what kind of work the restart actually paid:
``warm_trace_ms`` (Python re-trace — must be ~0, that is what PR 3's
StableHLO persistence bought) vs ``warm_compile_ms`` (XLA compile paid
during restores — must be ~0 *only* because store format v2 persists the
AOT-compiled executable; conflating the two made the AOT win invisible).

``run_cluster`` is the fleet version of the same claim: N fresh
*processes* over one :class:`~repro.core.cache.SharedStore` fabric,
exactly one translation per (kernel, backend) fleet-wide, everyone else
fetch-and-warm-starts with ~0 compile, bit-identical to a cold
single-process oracle.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import subprocess
import sys
import tempfile
import time
from pathlib import Path

import numpy as np

from repro.core import DiskStore, Engine, OPT_MAX, SharedStore, \
    TranslationCache, get_backend
from repro.core import kernels_suite as suite


def _case(name, rng):
    if name == "vadd":
        return ({"A": rng.normal(size=128).astype(np.float32),
                 "B": rng.normal(size=128).astype(np.float32),
                 "C": np.zeros(128, np.float32), "n": 128}, 4, 32)
    if name == "reduction":
        return ({"A": rng.normal(size=128).astype(np.float32),
                 "Out": np.zeros(1, np.float32), "n": 128,
                 "log2t": 5}, 4, 32)
    if name == "matmul_tiled":
        return ({"A": np.ones(8 * 16, np.float32),
                 "B": np.ones(16 * 16, np.float32),
                 "C": np.zeros(8 * 16, np.float32),
                 "K": 16, "N": 16, "ktiles": 2}, 8, 16)
    if name == "poly_eval":
        return ({"X": rng.normal(size=128).astype(np.float32),
                 "Coef": rng.normal(size=7).astype(np.float32),
                 "Out": np.zeros(128, np.float32), "n": 128}, 4, 32)
    if name == "swizzle_copy":
        return ({"A": rng.normal(size=128).astype(np.float32),
                 "Out": np.zeros(128, np.float32)}, 4, 32)
    if name == "tap_filter":
        return ({"A": rng.normal(size=64).astype(np.float32),
                 "W": rng.normal(size=4).astype(np.float32),
                 "Tmp": np.zeros(64, np.float32),
                 "Out": np.zeros(64, np.float32)}, 2, 32)
    if name == "dyn_matmul":
        return ({"A": rng.normal(size=4 * 32).astype(np.float32),
                 "B": rng.normal(size=32 * 16).astype(np.float32),
                 "C": np.zeros(4 * 16, np.float32),
                 "K": 32, "N": 16, "ktiles": 4, "tk": 8}, 4, 16)
    if name == "dyn_fir":
        return ({"A": rng.normal(size=64).astype(np.float32),
                 "W": rng.normal(size=4).astype(np.float32),
                 "Out": np.zeros(64, np.float32), "taps": 4}, 2, 32)
    return ({"Count": np.zeros(1, np.float32)}, 2, 32)


def run() -> list:
    rows = []
    rng = np.random.default_rng(1)
    for name in ("vadd", "reduction", "matmul_tiled", "montecarlo_pi"):
        prog_fn = suite.SUITE[name]
        for backend in ("interp", "vectorized", "pallas"):
            prog, _ = prog_fn()
            args, grid, block = _case(name, rng)
            cache = TranslationCache()
            be = get_backend(backend, cache=cache)

            t0 = time.perf_counter()
            eng = Engine(prog, be, grid, block, dict(args))
            eng.run()
            first_ms = (time.perf_counter() - t0) * 1e3
            misses_after_first = cache.stats()["misses"]

            t0 = time.perf_counter()
            eng2 = Engine(prog, be, grid, block, dict(args))
            eng2.run()
            cached_ms = (time.perf_counter() - t0) * 1e3

            st = cache.stats()
            opt = eng.opt_stats
            rows.append({
                "bench": "translation", "kernel": name, "backend": backend,
                "first_ms": round(first_ms, 1),
                "cached_ms": round(cached_ms, 1),
                "cache_entries": be.translation_cache_size(),
                "hits": st["hits"], "misses": st["misses"],
                "hit_rate": round(st["hit_rate"], 3),
                "relaunch_misses": st["misses"] - misses_after_first,
                "ops_before": opt.ops_before, "ops_after": opt.ops_after,
            })
    return rows


# ---------------------------------------------------------------------------
# pass-pipeline table: per-pass stats + interp executed-step deltas
# ---------------------------------------------------------------------------

PIPELINE_KERNELS = ("poly_eval", "tap_filter", "matmul_tiled",
                    "swizzle_copy", "montecarlo_pi")


def run_pass_pipeline(kernels=PIPELINE_KERNELS) -> list:
    """What the phase-2 pipeline buys, per kernel: static op delta,
    executed-op-schedule delta, the interp backend's *true* dynamically
    counted per-thread step delta (O0 vs OPT_MAX), and which passes fired.
    The CI smoke asserts ``ops_removed > 0`` in aggregate and a strict
    interp step reduction on the loop-heavy kernels."""
    rows = []
    rng = np.random.default_rng(1)
    for name in kernels:
        args, grid, block = _case(name, rng)
        steps = {}
        sched = {}
        stats = None
        for level in (0, OPT_MAX):
            prog, _ = suite.SUITE[name]()
            be = get_backend("interp", cache=TranslationCache())
            eng = Engine(prog, be, grid, block, dict(args),
                         opt_level=level)
            eng.run()
            steps[level] = be.steps_executed
            sched[level] = eng.executed_ops
            if level:
                stats = eng.opt_stats
        fired = {k: v for k, v in stats.per_pass.items() if v}
        rows.append({
            "bench": "pass_pipeline", "kernel": name, "level": OPT_MAX,
            "ops_before": stats.ops_before, "ops_after": stats.ops_after,
            "ops_removed": stats.ops_removed,
            "sched_o0": sched[0], "sched_omax": sched[OPT_MAX],
            "interp_steps_o0": steps[0],
            "interp_steps_omax": steps[OPT_MAX],
            "interp_step_cut": round(
                1 - steps[OPT_MAX] / max(steps[0], 1), 3),
            "opt_ms": round(sum(stats.per_pass_ms.values()), 2),
            "passes": "+".join(sorted(fired)),
        })
    return rows


# ---------------------------------------------------------------------------
# launch-time specialization table: generic vs specialized executed work
# ---------------------------------------------------------------------------

SPECIALIZATION_KERNELS = ("dyn_matmul", "dyn_fir")


def run_specialization(kernels=SPECIALIZATION_KERNELS) -> list:
    """What binding the launch scalars buys on the dynamic-trip kernels:
    the same program is launched generic (``specialize=False``) and
    specialized (``specialize=True``) on the interp backend, and the table
    reports the *executed* deltas — the per-thread op schedule
    (``Engine.executed_ops``, whose reduction is the dynamic
    ``ops_removed``) and the interp backend's true divergence-aware step
    count — plus how many scalars were bound and whether the outputs were
    bit-identical (they must be; the CI smoke asserts it)."""
    rows = []
    rng = np.random.default_rng(1)
    for name in kernels:
        prog, _ = suite.SUITE[name]()
        args, grid, block = _case(name, rng)
        outs = prog.buffers()
        steps, sched, results = {}, {}, {}
        bound = 0
        for spec in (False, True):
            be = get_backend("interp", cache=TranslationCache())
            eng = Engine(prog, be, grid, block, dict(args),
                         opt_level=OPT_MAX, specialize=spec)
            eng.run()
            steps[spec] = be.steps_executed
            sched[spec] = eng.executed_ops
            results[spec] = [np.asarray(eng.result(p.name)) for p in outs]
            if spec:
                bound = eng.opt_stats.per_pass.get(
                    "bind_launch_scalars", 0)
        rows.append({
            "bench": "specialization", "kernel": name,
            "scalars_bound": bound,
            "sched_generic": sched[False], "sched_spec": sched[True],
            "ops_removed": sched[False] - sched[True],
            "interp_steps_generic": steps[False],
            "interp_steps_spec": steps[True],
            "interp_step_cut": round(
                1 - steps[True] / max(steps[False], 1), 3),
            "bit_identical": all(
                np.array_equal(a, b)
                for a, b in zip(results[False], results[True])),
        })
    return rows


DEFAULT_COLD_WARM_KERNELS = ("vadd", "reduction", "matmul_tiled",
                             "montecarlo_pi")


def _launch_suite(cache: TranslationCache, backend: str,
                  kernels) -> float:
    """Launch every kernel once against ``cache``; returns wall ms."""
    rng = np.random.default_rng(1)
    be = get_backend(backend, cache=cache)
    t0 = time.perf_counter()
    for name in kernels:
        prog, _ = suite.SUITE[name]()
        args, grid, block = _case(name, rng)
        eng = Engine(prog, be, grid, block, dict(args))
        eng.run()
    return (time.perf_counter() - t0) * 1e3


def run_cold_warm(kernels=DEFAULT_COLD_WARM_KERNELS,
                  backends=("interp", "vectorized", "pallas"),
                  store_dir=None) -> list:
    """Cold-start vs warm-start translation cost over a shared DiskStore.

    Cold: empty store, fresh cache — every segment is translated and
    persisted.  Warm: a *new* cache instance (simulating a process restart
    or a migration-destination node) against the same store — every
    segment must be a disk restore, never a re-translation.
    """
    tmp = store_dir or tempfile.mkdtemp(prefix="hetgpu-bench-store-")
    rows = []
    total_cold = total_warm = 0.0
    try:
        for backend in backends:
            cold = TranslationCache(store=DiskStore(tmp))
            cold_wall = _launch_suite(cold, backend, kernels)
            cst = cold.stats()

            warm = TranslationCache(store=DiskStore(tmp))
            warm_wall = _launch_suite(warm, backend, kernels)
            wst = warm.stats()

            cold_translation = cst["translate_ms"]
            warm_translation = wst["translate_ms"] + wst["restore_ms"]
            total_cold += cold_translation
            total_warm += warm_translation
            rows.append({
                "bench": "translation_cold_warm", "backend": backend,
                "kernels": len(kernels),
                "cold_translation_ms": round(cold_translation, 1),
                "warm_translation_ms": round(warm_translation, 1),
                "cold_wall_ms": round(cold_wall, 1),
                "warm_wall_ms": round(warm_wall, 1),
                "cold_translated": cst["translated"],
                "warm_translated": wst["translated"],
                "warm_restored": wst["restored"],
                # the split: what kind of work each phase actually paid.
                # trace = Python trace + jax.export; compile = XLA compile
                # (translate-side for cold, restore-side recompiles for
                # warm — ~0 when the persisted AOT executable revives).
                "cold_trace_ms": round(cst["trace_ms"], 1),
                "cold_compile_ms": round(cst["compile_ms"], 1),
                "warm_trace_ms": round(wst["trace_ms"], 1),
                "warm_compile_ms": round(
                    wst["compile_ms"] + wst["restore_compile_ms"], 1),
                "warm_aot_restored": wst["aot_restored"],
                "warm_aot_fallbacks": wst["aot_fallback_restores"],
                "speedup": round(
                    cold_translation / max(warm_translation, 1e-6), 1),
            })
        rows.append({
            "bench": "translation_cold_warm", "backend": "ALL",
            "kernels": len(kernels),
            "cold_translation_ms": round(total_cold, 1),
            "warm_translation_ms": round(total_warm, 1),
            "speedup": round(total_cold / max(total_warm, 1e-6), 1),
        })
    finally:
        if store_dir is None:
            shutil.rmtree(tmp, ignore_errors=True)
    return rows


# ---------------------------------------------------------------------------
# cluster scenario: N fresh processes, one translation fleet-wide
# ---------------------------------------------------------------------------

DEFAULT_CLUSTER_KERNELS = ("vadd", "reduction")

# Runs in a *fresh interpreter* (spawned, never forked — jax is
# fork-unsafe): one cluster node coming up cold against the shared
# fabric.  argv: src_path backend shared_dir node_dir kernels_csv.
# Prints one JSON object on the last stdout line.
_CLUSTER_NODE = r"""
import hashlib, json, sys, time
import numpy as np

sys.path.insert(0, sys.argv[1])
from repro.core import DiskStore, Engine, SharedStore, TranslationCache, \
    get_backend
from repro.core import kernels_suite as suite

backend, shared_dir, node_dir = sys.argv[2], sys.argv[3], sys.argv[4]
kernels = sys.argv[5].split(",")

cache = TranslationCache(store=DiskStore(node_dir),
                         shared=SharedStore(shared_dir))
be = get_backend(backend, cache=cache)
digests = {}
t0 = time.perf_counter()
for name in kernels:
    prog, _oracle, grid, block, args, outs = suite.example_launch(
        name, rng=np.random.default_rng(0))
    eng = Engine(prog, be, grid, block,
                 {k: np.array(v, copy=True) for k, v in args.items()})
    eng.run()
    h = hashlib.sha256()
    for o in outs:
        h.update(np.ascontiguousarray(np.asarray(eng.result(o))).tobytes())
    digests[name] = h.hexdigest()
wall_ms = (time.perf_counter() - t0) * 1e3
st = cache.stats()
print(json.dumps({
    "digests": digests, "wall_ms": wall_ms,
    "translated": st["translated"], "restored": st["restored"],
    "shared_fetches": st["shared_fetches"],
    "shared_publishes": st["shared_publishes"],
    "aot_restored": st["aot_restored"],
    "aot_fallbacks": st["aot_fallback_restores"],
    "translate_ms": st["translate_ms"], "restore_ms": st["restore_ms"],
    "trace_ms": st["trace_ms"], "compile_ms": st["compile_ms"],
    "restore_compile_ms": st["restore_compile_ms"],
}))
"""


def _oracle_digests(backend: str, kernels) -> tuple:
    """Cold single-process oracle: fresh memory-only cache.  Returns
    (digests, cache stats) — the bit-identity reference and the cold
    translation cost the fleet amortizes."""
    import hashlib as _hashlib
    cache = TranslationCache()
    be = get_backend(backend, cache=cache)
    digests = {}
    for name in kernels:
        prog, _oracle, grid, block, args, outs = suite.example_launch(
            name, rng=np.random.default_rng(0))
        eng = Engine(prog, be, grid, block,
                     {k: np.array(v, copy=True) for k, v in args.items()})
        eng.run()
        h = _hashlib.sha256()
        for o in outs:
            h.update(np.ascontiguousarray(
                np.asarray(eng.result(o))).tobytes())
        digests[name] = h.hexdigest()
    return digests, cache.stats()


def _spawn_nodes(n: int, backend: str, shared: Path, root: Path,
                 kernels, tag: str) -> list:
    """Launch ``n`` fresh cluster-node interpreters concurrently and
    return their parsed JSON reports (raises on any node failure)."""
    src = str(Path(suite.__file__).resolve().parents[2])
    script = root / "cluster_node.py"
    script.write_text(_CLUSTER_NODE)
    procs = []
    for i in range(n):
        node_dir = root / f"{tag}-node{i}"
        procs.append(subprocess.Popen(
            [sys.executable, str(script), src, backend, str(shared),
             str(node_dir), ",".join(kernels)],
            stdout=subprocess.PIPE, stderr=subprocess.PIPE,
            env={**os.environ, "JAX_PLATFORMS": "cpu"}))
    reports = []
    for p in procs:
        out, err = p.communicate(timeout=600)
        if p.returncode != 0:
            raise RuntimeError(f"cluster node failed:\n{err.decode()}")
        reports.append(json.loads(out.decode().strip().splitlines()[-1]))
    return reports


def run_cluster(kernels=DEFAULT_CLUSTER_KERNELS, backends=("pallas",),
                nprocs: int = 4, shared_dir=None) -> list:
    """The fabric's headline claim, measured: ``nprocs`` fresh processes
    race up cold against one :class:`SharedStore` (fleet-wide
    single-flight dedupes every translation), then one more fresh
    late-joiner warm-starts purely from the fabric.

    Per backend the row reports: ``fleet_translated`` (must equal the
    cold oracle's ``expected_translations`` — exactly one translation per
    cache key fleet-wide), ``bit_identical`` (every node's outputs match
    the cold single-process oracle), and the late-joiner's split warm
    cost — ``warm_trace_ms`` / ``warm_compile_ms`` both ~0 (it fetched
    AOT executables) with ``speedup`` = oracle cold translation over its
    warm translation (restore) cost."""
    root = Path(shared_dir or tempfile.mkdtemp(prefix="hetgpu-cluster-"))
    rows = []
    total_cold = total_warm = 0.0
    try:
        for backend in backends:
            shared = root / f"fabric-{backend}"
            oracle, cst = _oracle_digests(backend, kernels)
            race = _spawn_nodes(nprocs, backend, shared, root, kernels,
                                tag=f"race-{backend}")
            warm = _spawn_nodes(1, backend, shared, root, kernels,
                                tag=f"warm-{backend}")[0]
            fleet_translated = sum(r["translated"] for r in race)
            bit_identical = all(r["digests"] == oracle
                                for r in race + [warm])
            cold_translation = cst["translate_ms"]
            warm_translation = warm["translate_ms"] + warm["restore_ms"]
            total_cold += cold_translation
            total_warm += warm_translation
            rows.append({
                "bench": "translation_cluster", "backend": backend,
                "kernels": len(kernels), "procs": nprocs + 1,
                "expected_translations": cst["translated"],
                "fleet_translated": fleet_translated,
                "race_warm_procs": sum(1 for r in race
                                       if r["translated"] == 0),
                "bit_identical": bit_identical,
                "cold_translation_ms": round(cold_translation, 1),
                "warm_translation_ms": round(warm_translation, 1),
                "warm_translated": warm["translated"],
                "warm_restored": warm["restored"],
                "warm_fetched": warm["shared_fetches"],
                "warm_aot_restored": warm["aot_restored"],
                "warm_trace_ms": round(warm["trace_ms"], 1),
                "warm_compile_ms": round(
                    warm["compile_ms"] + warm["restore_compile_ms"], 1),
                "speedup": round(
                    cold_translation / max(warm_translation, 1e-6), 1),
            })
        rows.append({
            "bench": "translation_cluster", "backend": "ALL",
            "kernels": len(kernels), "procs": nprocs + 1,
            "cold_translation_ms": round(total_cold, 1),
            "warm_translation_ms": round(total_warm, 1),
            "speedup": round(total_cold / max(total_warm, 1e-6), 1),
        })
    finally:
        if shared_dir is None:
            shutil.rmtree(root, ignore_errors=True)
    return rows
