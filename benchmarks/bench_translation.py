"""Paper §6.2 — translation/JIT cost per backend (first launch vs cached).

The paper reports 10-200 ms per kernel for PTX/SPIR-V/Metalium paths; here
translation = staging hetIR segments through jax.jit (vectorized) or
pl.pallas_call (pallas).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import Engine, get_backend
from repro.core import kernels_suite as suite


def run() -> list:
    rows = []
    rng = np.random.default_rng(1)
    for name in ("vadd", "reduction", "matmul_tiled", "montecarlo_pi"):
        prog_fn = suite.SUITE[name]
        for backend in ("vectorized", "pallas"):
            prog, _ = prog_fn()
            be = get_backend(backend)
            if name == "vadd":
                args = {"A": rng.normal(size=128).astype(np.float32),
                        "B": rng.normal(size=128).astype(np.float32),
                        "C": np.zeros(128, np.float32), "n": 128}
                grid, block = 4, 32
            elif name == "reduction":
                args = {"A": rng.normal(size=128).astype(np.float32),
                        "Out": np.zeros(1, np.float32), "n": 128,
                        "log2t": 5}
                grid, block = 4, 32
            elif name == "matmul_tiled":
                args = {"A": np.ones(8 * 16, np.float32),
                        "B": np.ones(16 * 16, np.float32),
                        "C": np.zeros(8 * 16, np.float32),
                        "K": 16, "N": 16, "ktiles": 2}
                grid, block = 8, 16
            else:
                args = {"Count": np.zeros(1, np.float32)}
                grid, block = 2, 32

            t0 = time.perf_counter()
            eng = Engine(prog, be, grid, block, dict(args))
            eng.run()
            first_ms = (time.perf_counter() - t0) * 1e3
            t0 = time.perf_counter()
            eng2 = Engine(prog, be, grid, block, dict(args))
            eng2.run()
            cached_ms = (time.perf_counter() - t0) * 1e3
            rows.append({"bench": "translation", "kernel": name,
                         "backend": backend,
                         "first_ms": round(first_ms, 1),
                         "cached_ms": round(cached_ms, 1),
                         "cache_entries":
                         be.translation_cache_size()})
    return rows
