"""Paper §6.2 — translation/JIT cost per backend (first launch vs cached).

The paper reports 10-200 ms per kernel for PTX/SPIR-V/Metalium paths; here
translation = staging hetIR segments through the pass pipeline plus
jax.jit (vectorized), pl.pallas_call (pallas), or closure staging (interp).
Each (kernel, backend) pair gets a fresh shared
:class:`~repro.core.cache.TranslationCache` and launches twice: the first
launch pays translation (all misses), the relaunch must run entirely from
cache (hit_rate > 0).  Rows also carry the pass-pipeline op reduction so
the optimize-then-translate pipeline is visible in one table.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import Engine, TranslationCache, get_backend
from repro.core import kernels_suite as suite


def _case(name, rng):
    if name == "vadd":
        return ({"A": rng.normal(size=128).astype(np.float32),
                 "B": rng.normal(size=128).astype(np.float32),
                 "C": np.zeros(128, np.float32), "n": 128}, 4, 32)
    if name == "reduction":
        return ({"A": rng.normal(size=128).astype(np.float32),
                 "Out": np.zeros(1, np.float32), "n": 128,
                 "log2t": 5}, 4, 32)
    if name == "matmul_tiled":
        return ({"A": np.ones(8 * 16, np.float32),
                 "B": np.ones(16 * 16, np.float32),
                 "C": np.zeros(8 * 16, np.float32),
                 "K": 16, "N": 16, "ktiles": 2}, 8, 16)
    return ({"Count": np.zeros(1, np.float32)}, 2, 32)


def run() -> list:
    rows = []
    rng = np.random.default_rng(1)
    for name in ("vadd", "reduction", "matmul_tiled", "montecarlo_pi"):
        prog_fn = suite.SUITE[name]
        for backend in ("interp", "vectorized", "pallas"):
            prog, _ = prog_fn()
            args, grid, block = _case(name, rng)
            cache = TranslationCache()
            be = get_backend(backend, cache=cache)

            t0 = time.perf_counter()
            eng = Engine(prog, be, grid, block, dict(args))
            eng.run()
            first_ms = (time.perf_counter() - t0) * 1e3
            misses_after_first = cache.stats()["misses"]

            t0 = time.perf_counter()
            eng2 = Engine(prog, be, grid, block, dict(args))
            eng2.run()
            cached_ms = (time.perf_counter() - t0) * 1e3

            st = cache.stats()
            opt = eng.opt_stats
            rows.append({
                "bench": "translation", "kernel": name, "backend": backend,
                "first_ms": round(first_ms, 1),
                "cached_ms": round(cached_ms, 1),
                "cache_entries": be.translation_cache_size(),
                "hits": st["hits"], "misses": st["misses"],
                "hit_rate": round(st["hit_rate"], 3),
                "relaunch_misses": st["misses"] - misses_after_first,
                "ops_before": opt.ops_before, "ops_after": opt.ops_after,
            })
    return rows
