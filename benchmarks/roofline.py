"""§Roofline — three-term roofline per (arch × shape × mesh) cell from the
dry-run artifacts.

    compute term    = per-device HLO FLOPs (loop-weighted) / 197 TF/s
    memory term     = per-device HLO bytes / 819 GB/s
    collective term = per-device collective bytes (ring model) / 50 GB/s

Roofline fraction = compute / max(compute, memory, collective): 1.0 means
the cell is compute-bound at the hardware's peak — the hillclimb target.
Also reports MODEL_FLOPS = 6·N_active·D (train) or 2·N_active·D (inference)
against the compiled FLOPs to expose remat/redundancy waste.
"""
from __future__ import annotations

import glob
import json
from pathlib import Path

ART = Path(__file__).resolve().parent / "artifacts" / "dryrun"


def _advice(dom: str, d: dict) -> str:
    arch, shape = d["arch"], d["shape"]
    if dom == "collective":
        return ("reduce FSDP regathers / use reduce-scatter paths; "
                "EP all-to-all for MoE" if "moe" in arch or "mixtral"
                in arch or "granite" in arch else
                "cut per-microbatch weight gathers (larger microbatch, "
                "TP-only layers) and grad all-reduce size")
    if dom == "memory":
        if arch.startswith("xlstm") or arch.startswith("recurrentgemma"):
            return ("chunked recurrence kernel (mlstm_chunk/rglru_scan) "
                    "instead of per-step scan traffic")
        return ("fuse attention (flash kernel), drop f32 intermediates, "
                "rematerialize less")
    return "already compute-bound; tune MXU tiling / kernel fusion"


def load_rows(tag: str = "baseline"):
    rows = []
    for f in sorted(glob.glob(str(ART / f"*__{tag}.json"))):
        d = json.loads(Path(f).read_text())
        name = Path(f).name.replace(f"__{tag}.json", "")
        if d.get("status") == "skipped":
            rows.append({"cell": name, "status": "skipped",
                         "reason": d["reason"][:60]})
            continue
        if d.get("status") != "ok":
            rows.append({"cell": name, "status": d.get("status")})
            continue
        r = d["roofline"]
        terms = {"compute": r["compute_s"] or 0.0,
                 "memory": r["memory_s"] or 0.0,
                 "collective": r["collective_s"] or 0.0}
        dom = max(terms, key=terms.get)
        frac = terms["compute"] / max(max(terms.values()), 1e-12)
        model_per_chip = d["model_flops"] / d["n_chips"]
        rows.append({
            "cell": name, "status": "ok",
            "compute_s": round(terms["compute"], 3),
            "memory_s": round(terms["memory"], 3),
            "collective_s": round(terms["collective"], 3),
            "dominant": dom,
            "roofline_frac": round(frac, 4),
            "useful_flops_ratio": round(
                model_per_chip / d["hlo_flops"], 3)
            if d["hlo_flops"] > 0 else None,
            "advice": _advice(dom, d),
        })
    return rows


def run(tag: str = "baseline") -> list:
    rows = load_rows(tag)
    out = []
    for r in rows:
        if r.get("status") != "ok":
            continue
        out.append({"bench": "roofline", "cell": r["cell"],
                    "compute_s": r["compute_s"],
                    "memory_s": r["memory_s"],
                    "collective_s": r["collective_s"],
                    "dominant": r["dominant"],
                    "roofline_frac": r["roofline_frac"]})
    return out


def markdown_table(tag: str = "baseline") -> str:
    rows = load_rows(tag)
    lines = ["| cell | compute s | memory s | collective s | bottleneck | "
             "roofline frac | useful-FLOPs ratio | what would move it |",
             "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r.get("status") == "skipped":
            lines.append(f"| {r['cell']} | — | — | — | skipped | — | — | "
                         f"{r['reason']} |")
        elif r.get("status") == "ok":
            lines.append(
                f"| {r['cell']} | {r['compute_s']} | {r['memory_s']} | "
                f"{r['collective_s']} | {r['dominant']} | "
                f"{r['roofline_frac']} | {r['useful_flops_ratio']} | "
                f"{r['advice']} |")
        else:
            lines.append(f"| {r['cell']} | — | — | — | {r['status']} | — "
                         f"| — | — |")
    return "\n".join(lines)


if __name__ == "__main__":
    print(markdown_table())
