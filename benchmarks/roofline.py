"""§Roofline — the ROADMAP's perf grader, in two modes.

**Measured het-kernel mode** (:func:`run_het`, the default rows): for every
suite kernel, derive the launch's byte and FLOP totals from the *segment
schedule* (:func:`repro.core.segments.dynamic_op_histogram` summed over the
engine's node walk with resolved trip counts) and grade them against
env-configurable peak terms:

    compute term = FLOPs / HETGPU_PEAK_FLOPS   (default 197 TF/s)
    memory term  = bytes / HETGPU_PEAK_GBS     (default 819 GB/s)

Roofline fraction = compute / max(compute, memory): 1.0 means the kernel is
compute-bound at the machine peak.  The model counts every ALU/FMA op as a
FLOP and every global LD/ST (scalar or block form) as one 4-byte element
per thread — an upper bound on traffic the block-tiled fast path can only
tighten, never exceed.

**Artifact mode** (:func:`load_rows`): the original dry-run artifact reader
(three-term roofline per (arch × shape × mesh) cell).  The artifact
directory ships empty in this repo; instead of silently returning zero rows
(the bug this PR fixes), an explicit ``status=no-artifacts`` row reports
the empty glob and where it looked.
"""
from __future__ import annotations

import glob
import json
import os
from pathlib import Path
from typing import Dict, List, Optional, Sequence

ART = Path(__file__).resolve().parent / "artifacts" / "dryrun"

#: default peak terms (TPU v5p-ish): override with HETGPU_PEAK_FLOPS /
#: HETGPU_PEAK_GBS to grade against different hardware
_DEFAULT_PEAK_FLOPS = 197e12
_DEFAULT_PEAK_GBS = 819e9

#: element size of every hetIR dtype that moves through global memory
_ELEM_BYTES = 4

#: FLOPs charged per executed op (FMA is two roundings)
_FLOP_WEIGHT = {"ADD": 1, "SUB": 1, "MUL": 1, "DIV": 1, "MIN": 1, "MAX": 1,
                "NEG": 1, "ABS": 1, "SQRT": 1, "EXP": 1, "FMA": 2}

#: global-memory opcodes and how many element transfers each one is
#: (ATOMIC_ADD is a read-modify-write)
_MEM_WEIGHT = {"LD_GLOBAL": 1, "ST_GLOBAL": 1,
               "BLOCK_LD": 1, "BLOCK_ST": 1, "ATOMIC_ADD": 2}


def _peaks() -> Dict[str, float]:
    return {"flops": float(os.environ.get("HETGPU_PEAK_FLOPS",
                                          _DEFAULT_PEAK_FLOPS)),
            "gbs": float(os.environ.get("HETGPU_PEAK_GBS",
                                        _DEFAULT_PEAK_GBS))}


def _schedule_histogram(nodes, scalars) -> Dict[str, int]:
    """Opcode histogram of one launch's full executed schedule: every
    SegNode's per-thread histogram, multiplied through the enclosing
    engine-level loop trip counts."""
    from repro.core.segments import (LoopEnd, LoopStart, SegNode,
                                     dynamic_op_histogram,
                                     resolve_trip_count)
    hist: Dict[str, int] = {}
    trips_stack: List[int] = []
    for n in nodes:
        if isinstance(n, LoopStart):
            t = resolve_trip_count(n.count, scalars)
            trips_stack.append(max(0, 1 if t is None else t))
        elif isinstance(n, LoopEnd):
            trips_stack.pop()
        elif isinstance(n, SegNode):
            mult = 1
            for t in trips_stack:
                mult *= t
            if mult:
                for op, c in dynamic_op_histogram(n.stmts, scalars).items():
                    hist[op] = hist.get(op, 0) + c * mult
    return hist


def run_het(kernels: Optional[Sequence[str]] = None) -> List[dict]:
    """Measured roofline rows for the hetIR kernel suite — one row per
    kernel, derived from the segment schedule (no artifacts needed)."""
    import numpy as np

    from repro.core.backends.interp import InterpBackend
    from repro.core.cache import TranslationCache
    from repro.core.engine import Engine
    from repro.core import kernels_suite as ks

    peaks = _peaks()
    names = sorted(ks.EXAMPLES) if kernels is None else list(kernels)
    rows: List[dict] = []
    for name in names:
        prog, _oracle, grid, block, host_args, _outs = ks.example_launch(
            name, rng=np.random.default_rng(0))
        eng = Engine(prog, InterpBackend(cache=TranslationCache()),
                     grid, block, dict(host_args))
        hist = _schedule_histogram(eng.nodes, eng.launch.scalars)
        threads = grid * block
        flops = sum(_FLOP_WEIGHT.get(op, 0) * c
                    for op, c in hist.items()) * threads
        nbytes = sum(_MEM_WEIGHT.get(op, 0) * c
                     for op, c in hist.items()) * threads * _ELEM_BYTES
        compute_s = flops / peaks["flops"]
        memory_s = nbytes / peaks["gbs"]
        bound = max(compute_s, memory_s, 1e-30)
        rows.append({
            "bench": "roofline", "cell": name, "status": "ok",
            "mode": "het-kernel",
            "threads": threads,
            "flops": int(flops), "bytes": int(nbytes),
            "intensity": round(flops / nbytes, 4) if nbytes else None,
            "compute_s": compute_s, "memory_s": memory_s,
            "dominant": "compute" if compute_s >= memory_s else "memory",
            "roofline_frac": round(compute_s / bound, 4),
        })
    return rows


def run_zoo() -> List[dict]:
    """Measured roofline rows for the model-zoo kernels (:mod:`repro.zoo`)
    — the same schedule-derived accounting as :func:`run_het`, tagged
    ``mode="zoo-kernel"`` so the suite census (one ``het-kernel`` row per
    ``EXAMPLES`` entry) stays closed."""
    import repro.zoo as zoo  # noqa: F401  (import registers the kernels)

    rows = run_het(sorted(zoo.ZOO))
    for r in rows:
        r["mode"] = "zoo-kernel"
    return rows


# ---------------------------------------------------------------------------
# artifact mode (dry-run cells)
# ---------------------------------------------------------------------------

def _advice(dom: str, d: dict) -> str:
    arch, shape = d["arch"], d["shape"]
    if dom == "collective":
        return ("reduce FSDP regathers / use reduce-scatter paths; "
                "EP all-to-all for MoE" if "moe" in arch or "mixtral"
                in arch or "granite" in arch else
                "cut per-microbatch weight gathers (larger microbatch, "
                "TP-only layers) and grad all-reduce size")
    if dom == "memory":
        if arch.startswith("xlstm") or arch.startswith("recurrentgemma"):
            return ("chunked recurrence kernel (mlstm_chunk/rglru_scan) "
                    "instead of per-step scan traffic")
        return ("fuse attention (flash kernel), drop f32 intermediates, "
                "rematerialize less")
    return "already compute-bound; tune MXU tiling / kernel fusion"


def load_rows(tag: str = "baseline"):
    files = sorted(glob.glob(str(ART / f"*__{tag}.json")))
    if not files:
        # the empty-glob bug fix: report the miss instead of a silent []
        return [{"cell": f"*__{tag}", "status": "no-artifacts",
                 "reason": f"no {ART.name}/*__{tag}.json under {ART}"}]
    rows = []
    for f in files:
        d = json.loads(Path(f).read_text())
        name = Path(f).name.replace(f"__{tag}.json", "")
        if d.get("status") == "skipped":
            rows.append({"cell": name, "status": "skipped",
                         "reason": d["reason"][:60]})
            continue
        if d.get("status") != "ok":
            rows.append({"cell": name, "status": d.get("status")})
            continue
        r = d["roofline"]
        terms = {"compute": r["compute_s"] or 0.0,
                 "memory": r["memory_s"] or 0.0,
                 "collective": r["collective_s"] or 0.0}
        dom = max(terms, key=terms.get)
        frac = terms["compute"] / max(max(terms.values()), 1e-12)
        model_per_chip = d["model_flops"] / d["n_chips"]
        rows.append({
            "cell": name, "status": "ok",
            "compute_s": round(terms["compute"], 3),
            "memory_s": round(terms["memory"], 3),
            "collective_s": round(terms["collective"], 3),
            "dominant": dom,
            "roofline_frac": round(frac, 4),
            "useful_flops_ratio": round(
                model_per_chip / d["hlo_flops"], 3)
            if d["hlo_flops"] > 0 else None,
            "advice": _advice(dom, d),
        })
    return rows


def run(tag: str = "baseline") -> list:
    """All roofline rows: the measured het-kernel suite first (always
    non-empty), then the model-zoo kernels, then any dry-run artifact
    cells (an explicit ``no-artifacts`` row when the directory ships
    empty)."""
    out = list(run_het())
    out.extend(run_zoo())
    for r in load_rows(tag):
        if r.get("status") == "ok":
            out.append({"bench": "roofline", "cell": r["cell"],
                        "status": "ok", "mode": "artifact",
                        "compute_s": r["compute_s"],
                        "memory_s": r["memory_s"],
                        "collective_s": r["collective_s"],
                        "dominant": r["dominant"],
                        "roofline_frac": r["roofline_frac"]})
        else:
            out.append({"bench": "roofline", "cell": r["cell"],
                        "status": r.get("status"), "mode": "artifact",
                        "reason": r.get("reason")})
    return out


def markdown_table(tag: str = "baseline") -> str:
    lines = ["| cell | mode | FLOPs | bytes | compute s | memory s | "
             "bottleneck | roofline frac |",
             "|---|---|---|---|---|---|---|---|"]
    for r in run_het() + run_zoo():
        lines.append(
            f"| {r['cell']} | {r['mode']} | {r['flops']} | {r['bytes']} | "
            f"{r['compute_s']:.3e} | {r['memory_s']:.3e} | "
            f"{r['dominant']} | {r['roofline_frac']} |")
    for r in load_rows(tag):
        if r.get("status") == "ok":
            lines.append(
                f"| {r['cell']} | artifact | — | — | {r['compute_s']} | "
                f"{r['memory_s']} | {r['dominant']} | "
                f"{r['roofline_frac']} |")
        else:
            lines.append(f"| {r['cell']} | artifact | — | — | — | — | "
                         f"{r.get('status')} | — |")
    return "\n".join(lines)


if __name__ == "__main__":
    print(markdown_table())
