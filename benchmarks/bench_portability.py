"""Paper §6.1 — portability matrix: one hetIR binary, every backend.

Mirrors the paper's 10-kernel suite × {NVIDIA, AMD, Intel, Tenstorrent}
with our suite × {interp (MIMD), vectorized (SIMT-emu), pallas (TPU)}.
Reports correctness and per-launch wall time.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import Engine, get_backend
from repro.core import kernels_suite as suite

CASES = {
    "vadd": (4, 32, lambda rng: {
        "A": rng.normal(size=128).astype(np.float32),
        "B": rng.normal(size=128).astype(np.float32),
        "C": np.zeros(128, np.float32), "n": 128}, ["C"]),
    "saxpy": (4, 32, lambda rng: {
        "X": rng.normal(size=128).astype(np.float32),
        "Y": rng.normal(size=128).astype(np.float32),
        "n": 128, "a": 1.5}, ["Y"]),
    "matmul_tiled": (8, 16, lambda rng: {
        "A": rng.normal(size=(8, 16)).astype(np.float32).reshape(-1),
        "B": rng.normal(size=(16, 16)).astype(np.float32).reshape(-1),
        "C": np.zeros(128, np.float32), "K": 16, "N": 16, "ktiles": 2},
        ["C"]),
    "reduction": (4, 32, lambda rng: {
        "A": rng.normal(size=128).astype(np.float32),
        "Out": np.zeros(1, np.float32), "n": 128, "log2t": 5}, ["Out"]),
    "inclusive_scan": (4, 32, lambda rng: {
        "A": rng.normal(size=128).astype(np.float32),
        "Out": np.zeros(128, np.float32),
        "BlockSums": np.zeros(4, np.float32), "n": 128},
        ["Out", "BlockSums"]),
    "bitcount_vote": (4, 32, lambda rng: {
        "A": rng.normal(size=128).astype(np.float32),
        "Out": np.zeros(4, np.float32), "n": 128, "thresh": 0.0}, ["Out"]),
    "montecarlo_pi": (2, 32, lambda rng: {
        "Count": np.zeros(1, np.float32)}, ["Count"]),
    "nn_layer": (4, 16, lambda rng: {
        "W": rng.normal(size=(4, 32)).astype(np.float32).reshape(-1),
        "X": rng.normal(size=32).astype(np.float32),
        "Bias": rng.normal(size=4).astype(np.float32),
        "Out": np.zeros(4, np.float32), "K": 32, "kchunks": 2}, ["Out"]),
    "stencil_1d": (2, 32, lambda rng: {
        "A": rng.normal(size=64).astype(np.float32),
        "Out": np.zeros(64, np.float32), "n": 64}, ["Out"]),
    "persistent_counter": (2, 32, lambda rng: {
        "State": rng.normal(size=64).astype(np.float32), "iters": 4},
        ["State"]),
}

BACKENDS = ["interp", "vectorized", "pallas"]


def run() -> list:
    rows = []
    for name, (grid, block, mk, outs) in CASES.items():
        prog, oracle = suite.SUITE[name]()
        expect = None
        for backend in BACKENDS:
            rng = np.random.default_rng(42)
            args = mk(rng)
            oracle_args = dict(args)
            oracle_args["_num_blocks"], oracle_args["_block_size"] = \
                grid, block
            expect = oracle(oracle_args)

            be = get_backend(backend)
            # warm (includes translation)
            eng = Engine(prog, be, grid, block, dict(args))
            t0 = time.perf_counter()
            eng.run()
            first_ms = (time.perf_counter() - t0) * 1e3
            ok = all(np.allclose(eng.result(o), expect[o], atol=1e-4,
                                 rtol=1e-4) for o in outs)
            # cached launch
            t0 = time.perf_counter()
            eng2 = Engine(prog, be, grid, block, dict(args))
            eng2.run()
            cached_ms = (time.perf_counter() - t0) * 1e3
            rows.append({
                "bench": "portability", "kernel": name, "backend": backend,
                "correct": ok, "first_launch_ms": round(first_ms, 2),
                "cached_ms": round(cached_ms, 2),
            })
    return rows
