"""Paper §6.1 — portability matrix: one hetIR binary, every backend.

Mirrors the paper's 10-kernel suite × {NVIDIA, AMD, Intel, Tenstorrent}
with our suite × {interp (MIMD), vectorized (SIMT-emu), pallas (TPU)}.
Reports correctness and per-launch wall time.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import Engine, get_backend
from repro.core import kernels_suite as suite

# Canonical per-kernel example launches live next to the kernels
# themselves (suite.EXAMPLES) — shared with the driver-API demo and
# stream tests, so the portability matrix always covers the full suite.
CASES = suite.EXAMPLES

BACKENDS = ["interp", "vectorized", "pallas"]


def run() -> list:
    rows = []
    for name, (grid, block, mk, outs) in CASES.items():
        prog, oracle = suite.SUITE[name]()
        expect = None
        for backend in BACKENDS:
            rng = np.random.default_rng(42)
            args = mk(rng)
            oracle_args = dict(args)
            oracle_args["_num_blocks"], oracle_args["_block_size"] = \
                grid, block
            expect = oracle(oracle_args)

            be = get_backend(backend)
            # warm (includes translation)
            eng = Engine(prog, be, grid, block, dict(args))
            t0 = time.perf_counter()
            eng.run()
            first_ms = (time.perf_counter() - t0) * 1e3
            ok = all(np.allclose(eng.result(o), expect[o], atol=1e-4,
                                 rtol=1e-4) for o in outs)
            # cached launch
            t0 = time.perf_counter()
            eng2 = Engine(prog, be, grid, block, dict(args))
            eng2.run()
            cached_ms = (time.perf_counter() - t0) * 1e3
            rows.append({
                "bench": "portability", "kernel": name, "backend": backend,
                "correct": ok, "first_launch_ms": round(first_ms, 2),
                "cached_ms": round(cached_ms, 2),
            })
    return rows
