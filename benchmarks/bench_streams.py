"""Stream-scheduler benchmark — the abstraction-layer cost of genuine
asynchrony (paper §4.3: "a uniform abstraction of threads, memory, and
synchronization" — here measured as what the cooperative round-robin
segment scheduler charges on top of back-to-back blocking launches, and
how well concurrent streams actually interleave)."""
from __future__ import annotations

import time

import numpy as np

from repro.core import HetSession, TranslationCache
from repro.core import kernels_suite as suite


def _sessions_and_buffers(n_launches: int, iters: int):
    s = HetSession("vectorized", cache=TranslationCache())
    fn = s.load(suite.persistent_counter()[0]).function()
    rng = np.random.default_rng(17)
    bufs = [s.alloc(64).copy_from_host(
        rng.normal(size=64).astype(np.float32)) for _ in range(n_launches)]
    return s, fn, bufs, iters


def run(n_streams: int = 4, iters: int = 8) -> list:
    rows = []

    # ---- serial: one blocking launch after another -----------------------
    s, fn, bufs, _ = _sessions_and_buffers(n_streams, iters)
    fn.launch(2, 32, {"State": bufs[0], "iters": iters})   # warm cache
    t0 = time.perf_counter()
    for buf in bufs:
        fn.launch(2, 32, {"State": buf, "iters": iters})
    serial_ms = (time.perf_counter() - t0) * 1e3

    # ---- async: one launch per stream, round-robin interleaved -----------
    s, fn, bufs, _ = _sessions_and_buffers(n_streams, iters)
    fn.launch(2, 32, {"State": bufs[0], "iters": iters})   # warm cache
    streams = [s.stream() for _ in range(n_streams)]
    s.sched_trace.clear()
    t0 = time.perf_counter()
    for buf, st in zip(bufs, streams):
        fn.launch_async(2, 32, {"State": buf, "iters": iters}, stream=st)
    s.synchronize()
    async_ms = (time.perf_counter() - t0) * 1e3

    ids = [t["stream"] for t in s.sched_trace]
    switches = sum(1 for a, b in zip(ids, ids[1:]) if a != b)
    segs = len(ids)
    rows.append({
        "bench": "streams", "case": f"{n_streams}streams_x{iters}iters",
        "serial_ms": round(serial_ms, 2),
        "async_ms": round(async_ms, 2),
        "scheduler_overhead": round(async_ms / max(serial_ms, 1e-9), 2),
        "segments": segs,
        "stream_switches": switches,
        # 1.0 = perfect round-robin alternation, 0 = serial completion
        "interleave_factor": round(switches / max(segs - 1, 1), 2),
    })

    # ---- per-segment scheduler cost --------------------------------------
    rows.append({
        "bench": "streams", "case": "per_segment",
        "async_us_per_segment": round(async_ms * 1e3 / max(segs, 1), 1),
    })
    return rows
