"""Docs hygiene gate (CI `docs` job).

Checks that
  1. every relative markdown link in README.md and docs/*.md points at a
     file that exists in the repo, and
  2. every module under src/repro/core (including backends/) carries a
     module docstring — the paper-grounded headers are part of the
     documented architecture contract (docs/ARCHITECTURE.md).

Exits non-zero listing every violation.
"""
from __future__ import annotations

import ast
import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)#\s]+)(?:#[^)\s]*)?\)")


def check_links() -> list:
    errors = []
    for md in [REPO / "README.md", *sorted((REPO / "docs").glob("*.md"))]:
        if not md.exists():
            errors.append(f"{md.relative_to(REPO)}: missing file")
            continue
        for target in LINK_RE.findall(md.read_text()):
            if "://" in target or target.startswith("mailto:"):
                continue  # external
            resolved = (md.parent / target).resolve()
            if not resolved.exists():
                errors.append(
                    f"{md.relative_to(REPO)}: broken link -> {target}")
    return errors


def check_docstrings() -> list:
    errors = []
    core = REPO / "src" / "repro" / "core"
    for py in sorted(core.rglob("*.py")):
        tree = ast.parse(py.read_text())
        if ast.get_docstring(tree) is None:
            errors.append(
                f"{py.relative_to(REPO)}: missing module docstring")
    return errors


def main() -> int:
    errors = check_links() + check_docstrings()
    for e in errors:
        print(f"ERROR: {e}")
    if not errors:
        print("docs check: all links resolve, all core modules documented")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
