"""Block-codegen acceptance gate (CI `block-codegen` job).

Fails (exit non-zero) when either regresses:
  1. fewer than MIN_TILED suite kernels take the block-tiled pallas fast
     path end-to-end (every segment of the kernel lowered), or
  2. any tiled kernel's output diverges from the interpreter by a single
     bit (the tiled path must be a pure re-tiling, never a re-ordering).

Prints a per-kernel census either way, including the refusal reason for
every kernel that stays on the scalar-per-thread path.
"""
from __future__ import annotations

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import numpy as np  # noqa: E402

from repro.core import Engine, get_backend  # noqa: E402
from repro.core import kernels_suite as suite  # noqa: E402
from repro.core.backends.pallas_backend import PallasBackend  # noqa: E402
from repro.core.cache import TranslationCache  # noqa: E402

MIN_TILED = 4


def census() -> tuple:
    fully_tiled, conform_fail = [], []
    rows = []
    for name in sorted(suite.EXAMPLES):
        prog, _oracle, grid, block, args, outs = suite.example_launch(
            name, rng=np.random.default_rng(0))
        ref = Engine(prog, get_backend("interp"), grid, block, dict(args))
        ref.run()
        backend = PallasBackend(cache=TranslationCache())
        eng = Engine(prog, backend, grid, block, dict(args))
        eng.run()
        stats = backend.block_stats
        tiled, scalar = stats["tiled"], stats["scalar"]
        ok = all(np.array_equal(np.asarray(eng.result(o)),
                                np.asarray(ref.result(o))) for o in outs)
        if tiled and not scalar:
            fully_tiled.append(name)
            if not ok:
                conform_fail.append(name)
        reasons = ";".join(sorted(stats["reasons"])) or "-"
        rows.append(f"{name:20s} tiled={tiled} scalar={scalar} "
                    f"bit_identical={ok} reasons={reasons}")
    return fully_tiled, conform_fail, rows


def main() -> int:
    fully_tiled, conform_fail, rows = census()
    print("\n".join(rows))
    print(f"\nfully tiled: {len(fully_tiled)} "
          f"({', '.join(fully_tiled)}); gate requires >= {MIN_TILED}")
    rc = 0
    if len(fully_tiled) < MIN_TILED:
        print(f"FAIL: only {len(fully_tiled)} suite kernels take the "
              f"tiled path (need {MIN_TILED})", file=sys.stderr)
        rc = 1
    if conform_fail:
        print(f"FAIL: tiled path diverges from interp on: "
              f"{', '.join(conform_fail)}", file=sys.stderr)
        rc = 1
    return rc


if __name__ == "__main__":
    sys.exit(main())
