"""Model-zoo acceptance gate (CI `model-zoo` job).

Fails (exit non-zero) when any of these regress:
  1. a zoo kernel's output on any backend (interp, vectorized, pallas)
     at O0 or OPT_MAX differs from its bit-exact NumPy oracle by a
     single bit;
  2. a zoo kernel neither block-tiles at least one segment nor records
     a refusal reason for every scalar segment;
  3. a recorded refusal name falls outside the stable, documented
     ``repro.core.passes.REFUSAL_REASONS`` vocabulary.

Prints a per-kernel census either way: conformance verdict per backend,
tiled/scalar segment counts and the refusal categories.
"""
from __future__ import annotations

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import numpy as np  # noqa: E402

import repro.zoo as zoo  # noqa: E402  (import registers the zoo kernels)
from repro.core import Engine, get_backend  # noqa: E402
from repro.core import kernels_suite as suite  # noqa: E402
from repro.core.backends.pallas_backend import PallasBackend  # noqa: E402
from repro.core.cache import TranslationCache  # noqa: E402
from repro.core.passes import OPT_MAX, REFUSAL_REASONS  # noqa: E402

BACKENDS = ("interp", "vectorized", "pallas")


def census() -> tuple:
    conform_fail, unaccounted, unknown_names = [], [], []
    rows = []
    for name in sorted(zoo.ZOO):
        prog, oracle, grid, block, args, outs = suite.example_launch(
            name, rng=np.random.default_rng(0))
        expect = oracle({k: (np.array(v, copy=True)
                             if isinstance(v, np.ndarray) else v)
                         for k, v in args.items()})
        bad = []
        for backend in BACKENDS:
            for opt in (0, OPT_MAX):
                eng = Engine(prog, get_backend(backend), grid, block,
                             dict(args), opt_level=opt)
                eng.run()
                if not all(np.array_equal(np.asarray(eng.result(o)),
                                          np.asarray(expect[o]))
                           for o in outs):
                    bad.append(f"{backend}@O{opt}")
        if bad:
            conform_fail.append(f"{name} ({', '.join(bad)})")

        pb = PallasBackend(cache=TranslationCache())
        Engine(prog, pb, grid, block, dict(args)).run()
        stats = pb.block_stats
        if not stats["tiled"] and not stats["reasons"]:
            unaccounted.append(name)
        bogus = set(stats["reasons"]) - set(REFUSAL_REASONS)
        if bogus:
            unknown_names.append(f"{name}: {sorted(bogus)}")
        reasons = ";".join(sorted(stats["reasons"])) or "-"
        rows.append(f"{name:16s} oracle_bit_identical={not bad} "
                    f"tiled={stats['tiled']} scalar={stats['scalar']} "
                    f"reasons={reasons}")
    return conform_fail, unaccounted, unknown_names, rows


def main() -> int:
    conform_fail, unaccounted, unknown_names, rows = census()
    print("\n".join(rows))
    rc = 0
    if conform_fail:
        print(f"FAIL: zoo kernels diverge from their oracle: "
              f"{'; '.join(conform_fail)}", file=sys.stderr)
        rc = 1
    if unaccounted:
        print(f"FAIL: scalar fallback with no recorded refusal reason: "
              f"{', '.join(unaccounted)}", file=sys.stderr)
        rc = 1
    if unknown_names:
        print(f"FAIL: refusal names outside REFUSAL_REASONS: "
              f"{'; '.join(unknown_names)}", file=sys.stderr)
        rc = 1
    if rc == 0:
        print(f"\nall {len(zoo.ZOO)} zoo kernels bit-identical to their "
              f"oracles on {len(BACKENDS)} backends at O0 and "
              f"O{OPT_MAX}; every scalar segment's refusal is named")
    return rc


if __name__ == "__main__":
    sys.exit(main())
