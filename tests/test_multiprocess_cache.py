"""Process-safety tests for the persistent cache tier.

``test_persistent_cache.py`` covers thread-safe writers inside one
process; a fleet (ISSUE 7) makes N *processes* share one
:class:`~repro.core.cache.DiskStore`, which is a different contract:

* atomic publishes must never yield torn/corrupt reads under concurrent
  re-publication of the same key;
* the corrupt-entry quarantine must never unlink a healthy entry that
  another process republished between the failed read and the unlink
  (the stat-guard in ``_read_envelope``);
* ``get_or_translate`` must be cross-process *single-flight*: N
  processes missing on the same key produce exactly one translation
  (the per-key ``flock`` in :meth:`DiskStore.lock`), everyone else
  restores the published entry;
* with a :class:`~repro.core.cache.SharedStore` fabric attached the
  same bar goes *fleet-wide*: one translation per key across fresh
  processes that share nothing but the fabric directory, survivors of
  a SIGKILL mid-publish see a clean miss (never corruption, never a
  wedged lock), and ``gc()`` sweeps the orphaned lock sidecars the
  protocol deliberately never unlinks on release.

The directed tests below run in tier-1; the N-process stress tests are
marked ``slow`` and run in CI's chaos job.  Subprocess workers are
spawned from a script written to ``tmp_path`` (spawn cannot import
pytest test modules).
"""
import json
import os
import pickle
import subprocess
import sys
import threading
import time
from pathlib import Path

import pytest

from repro.core.cache import (DiskStore, SharedStore, TranslationCache,
                              register_reviver)

SRC = str(Path(__file__).resolve().parents[1] / "src")


# ---------------------------------------------------------------------------
# directed (tier-1) coverage of the new locking surface
# ---------------------------------------------------------------------------

def test_lock_is_exclusive_and_reentrant_across_keys(tmp_path):
    store = DiskStore(tmp_path, tag="t")
    order = []

    def hold(key, label, dwell):
        with store.lock(key) as locked:
            assert locked
            order.append(("enter", label))
            time.sleep(dwell)
            order.append(("exit", label))

    t1 = threading.Thread(target=hold, args=("k", "a", 0.15))
    t1.start()
    time.sleep(0.05)
    # a different key does not contend
    hold("other", "other", 0.0)
    # the same key must wait for the holder
    hold("k", "b", 0.0)
    t1.join()
    assert order.index(("exit", "a")) < order.index(("enter", "b"))
    # lock files persist (never unlinked — see DiskStore.lock docstring)
    assert list(store.dir.glob("*.lock"))


def test_single_flight_translation_threads(tmp_path):
    """Two threads missing on one key: one translation, one restore."""
    register_reviver("mpstress", lambda p: p)
    store = DiskStore(tmp_path, tag="t")
    caches = [TranslationCache(store=store) for _ in range(2)]
    key = ("mpstress", "shared-key")
    started = threading.Barrier(2)
    calls = []

    def translate():
        calls.append(1)
        time.sleep(0.1)     # widen the race window
        return {"v": 42}, ("mpstress", {"v": 42})

    def run(cache):
        started.wait()
        assert cache.get_or_translate(key, translate) == {"v": 42}

    ts = [threading.Thread(target=run, args=(c,)) for c in caches]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert len(calls) == 1
    assert sum(c.translated for c in caches) == 1
    assert sum(c.restored for c in caches) == 1


def test_quarantine_spares_republished_entry(tmp_path):
    """A corrupt read must not unlink the path if a healthy entry was
    atomically republished there in the meantime."""
    store = DiskStore(tmp_path, tag="t")
    key = ("k",)
    path = store._path(key)
    path.write_bytes(b"garbage \x00 bytes")

    real_read = Path.read_bytes
    healthy = {"done": False}

    def read_then_republish(self):
        blob = real_read(self)
        if self == path and not healthy["done"]:
            healthy["done"] = True
            # another process wins the race: republish a good entry
            # after our read, before our quarantine unlink
            store.save(key, "kind", {"ok": True})
        return blob

    try:
        Path.read_bytes = read_then_republish
        assert store.load(key) is None      # the garbled read: a miss
    finally:
        Path.read_bytes = real_read
    assert store.corrupt == 1
    # the republished healthy entry survived the quarantine
    env = store.load(key)
    assert env is not None and env["payload"] == {"ok": True}


def test_quarantine_still_removes_stable_corruption(tmp_path):
    store = DiskStore(tmp_path, tag="t")
    key = ("k",)
    path = store._path(key)
    path.write_bytes(b"garbage")
    assert store.load(key) is None
    assert not path.exists()                # stable corruption: unlinked
    assert store.corrupt == 1


def test_single_flight_opt_out(tmp_path, monkeypatch):
    monkeypatch.setenv("HETGPU_CACHE_SINGLE_FLIGHT", "0")
    register_reviver("mpstress", lambda p: p)
    store = DiskStore(tmp_path, tag="t")
    cache = TranslationCache(store=store)
    v = cache.get_or_translate(("mpstress", "x"),
                               lambda: (1, ("mpstress", 1)))
    assert v == 1 and cache.translated == 1
    assert not list(store.dir.glob("*.lock"))   # lock never taken


def test_gc_sweeps_orphan_lock_sidecars(tmp_path):
    """Lock sidecars are never unlinked on release (that would split the
    lock), so they accumulate; ``gc()`` sweeps the ones whose entry is
    gone — under a non-blocking flock, so a sidecar someone holds *right
    now* is never touched."""
    store = DiskStore(tmp_path, tag="t")
    store.save(("live",), "kind", {"v": 1})
    with store.lock(("live",)):
        pass                            # sidecar with a matching .tce
    with store.lock(("orphan",)):
        pass                            # sidecar whose entry never landed
    assert len(list(store.dir.glob("*.lock"))) == 2
    store.gc()
    assert store.lock_sweeps == 1
    remaining = list(store.dir.glob("*.lock"))
    assert len(remaining) == 1
    assert remaining[0].with_suffix(".tce").exists()


def test_gc_never_sweeps_a_held_lock(tmp_path):
    store = DiskStore(tmp_path, tag="t")
    entered, release = threading.Event(), threading.Event()

    def hold():
        with store.lock(("held",)):
            entered.set()
            release.wait(5)

    t = threading.Thread(target=hold)
    t.start()
    assert entered.wait(5)
    store.gc()                          # sidecar is held: must survive
    assert store.lock_sweeps == 0
    assert list(store.dir.glob("*.lock"))
    release.set()
    t.join()
    store.gc()                          # now orphaned and free: swept
    assert store.lock_sweeps == 1
    assert not list(store.dir.glob("*.lock"))


def test_shared_tier_fetch_on_miss_and_replicate(tmp_path):
    """The fabric contract end-to-end in one process: node 1 translates
    and publishes; node 2 (fresh local store, same fabric) fetches on
    miss and replicates locally; node 3 then warm-starts from node 2's
    local store alone — the fabric is a fill path, not a dependency."""
    register_reviver("mpstress", lambda p: p)
    shared = SharedStore(tmp_path / "fabric", tag="t")
    key = ("mpstress", "k")

    def boom():
        raise AssertionError("fleet already translated this key")

    c1 = TranslationCache(store=DiskStore(tmp_path / "n1", tag="t"),
                          shared=shared)
    v = c1.get_or_translate(key, lambda: ({"v": 7}, ("mpstress", {"v": 7})))
    assert v == {"v": 7} and c1.translated == 1
    assert shared.publishes == 1 and c1.shared_publishes == 1

    store2 = DiskStore(tmp_path / "n2", tag="t")
    c2 = TranslationCache(store=store2, shared=shared)
    assert c2.get_or_translate(key, boom) == {"v": 7}
    assert c2.translated == 0 and c2.restored == 1
    assert c2.shared_fetches == 1 and c2.replicated == 1

    c3 = TranslationCache(store=store2)
    assert c3.get_or_translate(key, boom) == {"v": 7}
    assert c3.translated == 0 and c3.restored == 1


# ---------------------------------------------------------------------------
# N-process stress (slow; CI chaos job)
# ---------------------------------------------------------------------------

_WORKER = r"""
import json, os, sys, time
sys.path.insert(0, {src!r})
import numpy as np
from repro.core.cache import DiskStore, TranslationCache, register_reviver

root, out_path, nkeys, seed = sys.argv[1:5]
nkeys, seed = int(nkeys), int(seed)
register_reviver("mpstress", lambda p: p)
store = DiskStore(root, tag="stress")
cache = TranslationCache(store=store)

def make_translate(i):
    def translate():
        time.sleep(0.02)    # widen the cross-process race window
        payload = {{"key": i, "data": [i * 3, i * 3 + 1]}}
        return payload, ("mpstress", payload)
    return translate

rng = np.random.default_rng(seed)
order = rng.permutation(nkeys)
values = {{}}
for i in order:
    i = int(i)
    v = cache.get_or_translate(("mpstress", i), make_translate(i))
    values[i] = v
ok = all(values[i] == {{"key": i, "data": [i * 3, i * 3 + 1]}}
         for i in range(nkeys))
json.dump({{"pid": os.getpid(), "ok": ok,
           "translated": cache.translated, "restored": cache.restored,
           "hits": cache.hits, "corrupt": store.corrupt,
           "load_misses": store.load_misses}}, open(out_path, "w"))
"""


@pytest.mark.slow
def test_nproc_get_or_translate_single_flight(tmp_path):
    """6 processes x 8 keys against one store: every process sees every
    value intact, zero corrupt reads, and the fleet translates each key
    exactly once (single-flight) — the rest restore from disk."""
    nproc, nkeys = 6, 8
    script = tmp_path / "worker.py"
    script.write_text(_WORKER.format(src=SRC))
    store_dir = tmp_path / "store"
    procs, outs = [], []
    for i in range(nproc):
        out = tmp_path / f"out{i}.json"
        outs.append(out)
        procs.append(subprocess.Popen(
            [sys.executable, str(script), str(store_dir), str(out),
             str(nkeys), str(100 + i)],
            stdout=subprocess.PIPE, stderr=subprocess.PIPE))
    for p in procs:
        _, err = p.communicate(timeout=120)
        assert p.returncode == 0, err.decode()
    results = [json.loads(o.read_text()) for o in outs]
    assert all(r["ok"] for r in results)
    assert all(r["corrupt"] == 0 for r in results)
    # the single-flight bar: one translation per key across the fleet
    assert sum(r["translated"] for r in results) == nkeys
    # everyone served every key: translated locally or restored from disk
    for r in results:
        assert r["translated"] + r["restored"] == nkeys
    # and the store holds exactly the distinct keys
    store = DiskStore(store_dir, tag="stress")
    assert store.entry_count() == nkeys


_REPUBLISHER = r"""
import sys, time
sys.path.insert(0, {src!r})
from repro.core.cache import DiskStore

root, rounds = sys.argv[1], int(sys.argv[2])
store = DiskStore(root, tag="stress")
for n in range(rounds):
    store.save(("hot",), "kind", {{"round": n, "blob": "x" * (n % 7) * 512}})
"""


@pytest.mark.slow
def test_nproc_republish_never_tears(tmp_path):
    """Writers hammer one key with differently-sized payloads while
    readers poll it: every read is either a miss (impossible here after
    the first publish) or a *complete* envelope — atomic publishes never
    yield torn bytes, and nothing healthy gets quarantined."""
    script = tmp_path / "writer.py"
    script.write_text(_REPUBLISHER.format(src=SRC))
    store_dir = tmp_path / "store"
    store = DiskStore(store_dir, tag="stress")
    store.save(("hot",), "kind", {"round": -1, "blob": ""})
    writers = [subprocess.Popen(
        [sys.executable, str(script), str(store_dir), "200"],
        stdout=subprocess.PIPE, stderr=subprocess.PIPE)
        for _ in range(3)]
    reads = 0
    try:
        while any(p.poll() is None for p in writers):
            env = store.load(("hot",))
            assert env is not None, "torn or quarantined read"
            assert set(env["payload"]) == {"round", "blob"}
            reads += 1
    finally:
        for p in writers:
            _, err = p.communicate(timeout=60)
            assert p.returncode == 0, err.decode()
    assert store.corrupt == 0
    assert reads > 10   # the loop really overlapped the writers


_TORN_PUBLISHER = r"""
import os, sys, time
from pathlib import Path
sys.path.insert(0, {src!r})
from repro.core.cache import DiskStore, SharedStore, TranslationCache, \
    register_reviver

shared_dir, node_dir, marker = sys.argv[1:4]
register_reviver("mpstress", lambda p: p)
cache = TranslationCache(store=DiskStore(node_dir, tag="stress"),
                         shared=SharedStore(shared_dir, tag="stress"))

real_replace = os.replace
def torn_replace(srcp, dstp):
    # Freeze only the *shared-tier* publish, after the temp file is fully
    # written but before the atomic rename — the parent SIGKILLs us here,
    # while we also still hold the fleet-wide translation flock.
    if str(dstp).startswith(shared_dir):
        Path(marker).write_text("mid-publish")
        time.sleep(120)
    real_replace(srcp, dstp)
os.replace = torn_replace

cache.get_or_translate(("mpstress", "torn"),
                       lambda: ({{"v": 1}}, ("mpstress", {{"v": 1}})))
"""


@pytest.mark.slow
def test_sigkill_mid_publish_is_a_clean_miss(tmp_path):
    """SIGKILL a process between writing the shared-tier temp file and the
    atomic rename (while it holds the fleet-wide translation lock):
    readers must see a clean miss — never a torn envelope, never
    quarantine churn — the orphaned temp file is swept on the next store
    startup, and the flock dies with the process so a fresh node can
    immediately translate and publish the same key."""
    script = tmp_path / "torn.py"
    script.write_text(_TORN_PUBLISHER.format(src=SRC))
    shared_dir = tmp_path / "fabric"
    marker = tmp_path / "marker"
    proc = subprocess.Popen(
        [sys.executable, str(script), str(shared_dir),
         str(tmp_path / "n1"), str(marker)],
        stdout=subprocess.PIPE, stderr=subprocess.PIPE)
    deadline = time.monotonic() + 60
    while not marker.exists():
        assert proc.poll() is None, proc.communicate()[1].decode()
        assert time.monotonic() < deadline, "publisher never reached rename"
        time.sleep(0.01)
    proc.kill()                                     # SIGKILL, mid-publish
    proc.wait(timeout=30)

    fab = next(p for p in shared_dir.iterdir() if p.is_dir())
    assert not list(fab.glob("*.tce"))              # rename never happened
    torn = list(fab.glob("*.tmp"))
    assert torn                                     # the torn temp remains

    shared = SharedStore(shared_dir, tag="stress")
    # startup's temp sweep is age-gated (it must never race a *live*
    # writer), so the fresh orphan survives...
    assert list(shared.dir.glob("*.tmp"))
    assert shared.fetch(("mpstress", "torn")) is None   # clean miss
    assert shared.corrupt == 0
    # ...and once it is stale, the next startup sweeps it
    old = time.time() - 7200
    os.utime(torn[0], (old, old))
    SharedStore(shared_dir, tag="stress")
    assert not list(shared.dir.glob("*.tmp"))

    # the flock died with the process: a fresh node translates right away
    register_reviver("mpstress", lambda p: p)
    c2 = TranslationCache(store=DiskStore(tmp_path / "n2", tag="stress"),
                          shared=shared)
    v = c2.get_or_translate(("mpstress", "torn"),
                            lambda: ({"v": 2}, ("mpstress", {"v": 2})))
    assert v == {"v": 2} and c2.translated == 1
    assert shared.publishes == 1
    assert shared.fetch(("mpstress", "torn"))["payload"] == {"v": 2}


@pytest.mark.fleet
def test_fleet_prewarm_publishes_to_fabric(tmp_path):
    """`FleetCoordinator.prewarm()` translates every registered program
    once and publishes to the fabric; a fresh node sharing nothing but
    the fabric directory then warm-starts without translating."""
    from repro.core import FleetCoordinator, HetSession
    from repro.core import kernels_suite as suite

    prog = suite.vadd()[0]
    shared = tmp_path / "fabric"
    with FleetCoordinator(backends=("interp",), queue_dir=tmp_path / "q",
                          shared_dir=shared, fault_plan=[]) as fleet:
        fleet.register(prog)
        report = fleet.prewarm()
        assert report["interp"]["translated"] > 0

    node = HetSession("interp", shared=str(shared))
    rep = node.warmup([prog], grids=((2, 32),))
    assert rep["translated"] == 0
    assert rep["restored"] > 0
    assert rep["fetched"] == rep["restored"]


@pytest.mark.slow
def test_cluster_fabric_translate_once_fleet_wide():
    """ISSUE 9 acceptance: >=4 fresh processes over one shared fabric,
    exactly one translation per cache key fleet-wide, a late-joining
    fifth process warm-starts with ~0 trace *and* ~0 XLA compile (AOT
    executables came over the fabric), bit-identical to a cold
    single-process oracle, >=5x cheaper than translating."""
    from benchmarks.bench_translation import run_cluster
    row = run_cluster(nprocs=4)[0]
    assert row["fleet_translated"] == row["expected_translations"]
    assert row["bit_identical"]
    assert row["warm_translated"] == 0
    assert row["warm_fetched"] == row["expected_translations"]
    assert row["warm_aot_restored"] == row["expected_translations"]
    assert row["warm_trace_ms"] <= 5.0
    assert row["warm_compile_ms"] <= 5.0
    assert row["speedup"] >= 5.0
