"""Shared TranslationCache tests (paper §4.2 JIT cache).

Relaunching an identical kernel must hit; changing launch geometry, buffer
dtype, or the program body must miss; counters are exposed through
``HetSession``; and a checkpoint taken from an *optimized* program must
restore correctly on the other backend (node indices address the optimized
segmented program).
"""
import numpy as np
import pytest

from repro.core import (Engine, HetSession, OPT_MAX, Snapshot,
                        TranslationCache, get_backend, global_cache)
from repro.core import hetir as ir
from repro.core import kernels_suite as suite
from repro.core.hetir import Builder, Ptr, Scalar

RNG = np.random.default_rng(11)


def _vadd_args(n=128):
    return {"A": RNG.normal(size=n).astype(np.float32),
            "B": RNG.normal(size=n).astype(np.float32),
            "C": np.zeros(n, np.float32), "n": n}


def _launch(backend, grid=4, block=32, args=None, level=OPT_MAX):
    prog, _ = suite.vadd()
    eng = Engine(prog, backend, grid, block,
                 dict(args or _vadd_args()), opt_level=level)
    assert eng.run()
    return eng


# ---------------------------------------------------------------------------
# hit/miss behaviour
# ---------------------------------------------------------------------------


@pytest.mark.fast
@pytest.mark.parametrize("name", ["interp", "vectorized", "pallas"])
def test_relaunch_hits_cache(name):
    cache = TranslationCache()
    be = get_backend(name, cache=cache)
    _launch(be)
    st = cache.stats()
    assert st["misses"] >= 1 and st["hits"] == 0
    misses_first = st["misses"]
    _launch(be)  # identical relaunch: translation fully cached
    st = cache.stats()
    assert st["misses"] == misses_first
    assert st["hits"] >= 1
    assert st["hit_rate"] > 0


@pytest.mark.fast
def test_geometry_change_misses():
    cache = TranslationCache()
    be = get_backend("vectorized", cache=cache)
    _launch(be, grid=4, block=32)
    misses = cache.stats()["misses"]
    _launch(be, grid=2, block=64)  # same program, new geometry
    assert cache.stats()["misses"] > misses


def _mini_prog(dtype):
    b = Builder("mini", [Ptr("A", dtype), Ptr("Out", dtype)])
    i = b.global_id(0)
    b.store("Out", i, b.load("A", i))
    return b.done()


@pytest.mark.fast
def test_dtype_change_misses():
    cache = TranslationCache()
    be = get_backend("pallas", cache=cache)
    for dtype, np_dt in ((ir.F32, np.float32), (ir.I32, np.int32)):
        prog = _mini_prog(dtype)
        args = {"A": np.arange(64).astype(np_dt),
                "Out": np.zeros(64, np_dt)}
        eng = Engine(prog, be, 2, 32, args)
        assert eng.run()
    st = cache.stats()
    assert st["hits"] == 0 and st["misses"] == 2  # distinct fingerprints


@pytest.mark.fast
def test_identical_programs_share_translations():
    """Content-addressed keys: two independently *built* programs with the
    same structure share one cache entry (the seed's id()-keyed per-backend
    dicts could never hit here)."""
    cache = TranslationCache()
    be = get_backend("vectorized", cache=cache)
    for _ in range(2):
        prog, _ = suite.saxpy()  # fresh Program object each time
        eng = Engine(prog, be, 3, 32,
                     {"X": np.ones(96, np.float32),
                      "Y": np.ones(96, np.float32), "n": 96, "a": 2.0})
        assert eng.run()
    st = cache.stats()
    assert st["hits"] >= 1
    assert be.translation_cache_size() == st["misses"]


@pytest.mark.fast
def test_opt_levels_do_not_collide():
    cache = TranslationCache()
    be = get_backend("vectorized", cache=cache)
    _launch(be, level=0)
    _launch(be, level=OPT_MAX)  # different body -> different fingerprint
    assert cache.stats()["hits"] == 0


@pytest.mark.fast
def test_lru_eviction_counted():
    cache = TranslationCache(capacity=1)
    be = get_backend("vectorized", cache=cache)
    _launch(be, grid=4, block=32)
    _launch(be, grid=2, block=64)
    st = cache.stats()
    assert st["evictions"] >= 1
    assert st["entries"] == 1


@pytest.mark.fast
def test_cache_shared_across_backends():
    """One cache serves every backend; keys lead with the backend name so
    per-backend sizes stay separable."""
    cache = TranslationCache()
    interp = get_backend("interp", cache=cache)
    vect = get_backend("vectorized", cache=cache)
    _launch(interp)
    _launch(vect)
    assert cache.size("interp") >= 1
    assert cache.size("vectorized") >= 1
    assert cache.size() == cache.size("interp") + cache.size("vectorized")


# ---------------------------------------------------------------------------
# HetSession surface
# ---------------------------------------------------------------------------


@pytest.mark.fast
def test_session_exposes_cache_counters():
    s = HetSession("vectorized", cache=TranslationCache())
    prog, _ = suite.vadd()
    s.load_kernel(prog)
    args = _vadd_args(64)
    s.launch("vadd", grid=2, block=32, args=args)
    assert s.stats["cache_misses"] >= 1
    assert s.stats["cache_hits"] == 0
    assert s.stats["last_opt"]["level"] == s.opt_level
    s.launch("vadd", grid=2, block=32, args=args)
    assert s.stats["cache_hits"] >= 1
    assert s.cache_stats()["hit_rate"] > 0


@pytest.mark.fast
def test_session_defaults_to_global_cache():
    s = HetSession("interp")
    assert s.cache is global_cache()


# ---------------------------------------------------------------------------
# migration of an optimized program
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("src,dst", [("vectorized", "interp"),
                                     ("interp", "vectorized"),
                                     ("vectorized", "pallas")])
def test_optimized_checkpoint_restores_on_other_backend(src, dst):
    """Checkpoint taken mid-kernel from an OPT_MAX-optimized program must
    resume on a different backend and finish bit-identical to the
    non-migrated optimized run (snapshot carries the opt level; the
    deterministic pipeline re-creates the same segmented program)."""
    prog, _ = suite.persistent_counter()
    args = {"State": RNG.normal(size=64).astype(np.float32), "iters": 6}

    ref = Engine(prog, get_backend(src), 2, 32, dict(args),
                 opt_level=OPT_MAX)
    assert ref.run()

    eng = Engine(prog, get_backend(src), 2, 32, dict(args),
                 opt_level=OPT_MAX)
    assert not eng.run(max_segments=3), "should pause mid-kernel"
    blob = eng.snapshot().to_bytes()
    snap = Snapshot.from_bytes(blob)
    assert snap.opt_level == OPT_MAX
    eng2 = Engine.resume(prog, get_backend(dst), snap)
    assert eng2.opt_level == OPT_MAX
    assert eng2.run()
    np.testing.assert_allclose(eng2.result("State"), ref.result("State"),
                               atol=1e-5, rtol=1e-5)


@pytest.mark.fast
def test_snapshot_roundtrip_preserves_f32_scalars():
    """np.float32 scalar params must survive serialization exactly (they
    are not Python floats; a naive isinstance check truncated them)."""
    prog, _ = suite.saxpy()
    eng = Engine(prog, get_backend("interp"), 1, 4,
                 {"X": np.ones(4, np.float32),
                  "Y": np.zeros(4, np.float32), "n": 4, "a": 2.5},
                 opt_level=0)
    snap = Snapshot.from_bytes(eng.snapshot().to_bytes())
    assert snap.scalars["a"] == 2.5


@pytest.mark.fast
def test_snapshot_roundtrip_preserves_opt_level():
    prog, _ = suite.persistent_counter()
    args = {"State": np.ones(64, np.float32), "iters": 4}
    eng = Engine(prog, get_backend("interp"), 2, 32, dict(args),
                 opt_level=1)
    eng.run(max_segments=1)
    back = Snapshot.from_bytes(eng.snapshot().to_bytes())
    assert back.opt_level == 1
