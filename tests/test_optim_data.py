"""Optimizer, schedule, and data-pipeline unit tests."""
import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.configs.base import ShapeCfg
from repro.data import SyntheticLMData
from repro.optim import adamw_init, adamw_update, warmup_cosine


def test_adamw_first_step_matches_hand_computation():
    p = {"w": jnp.asarray([1.0, -2.0])}
    g = {"w": jnp.asarray([0.5, 0.25])}
    opt = adamw_init(p)
    lr, b1, b2, eps, wd = 0.1, 0.9, 0.95, 1e-8, 0.1
    new_p, new_opt, metrics = adamw_update(
        g, opt, p, lr=lr, b1=b1, b2=b2, eps=eps, weight_decay=wd,
        clip_norm=1e9)
    gnp = np.array([0.5, 0.25])
    m = (1 - b1) * gnp
    v = (1 - b2) * gnp ** 2
    mhat = m / (1 - b1)
    vhat = v / (1 - b2)
    want = np.array([1.0, -2.0]) - lr * (
        mhat / (np.sqrt(vhat) + eps) + wd * np.array([1.0, -2.0]))
    np.testing.assert_allclose(np.asarray(new_p["w"]), want, rtol=1e-6)
    assert int(new_opt["count"]) == 1
    assert float(metrics["grad_norm"]) > 0


def test_adamw_clipping_scales_update():
    p = {"w": jnp.ones(4)}
    g_small = {"w": jnp.full(4, 1e-3)}
    g_big = {"w": jnp.full(4, 1e3)}
    opt = adamw_init(p)
    p1, _, m1 = adamw_update(g_big, opt, p, lr=0.1, clip_norm=1.0,
                             weight_decay=0.0)
    # clipped huge grads act like unit-norm grads
    assert float(m1["grad_norm"]) > 1.0
    assert np.all(np.isfinite(np.asarray(p1["w"])))


def test_warmup_cosine_shape():
    lrs = [float(warmup_cosine(jnp.asarray(s), peak_lr=1.0,
                               warmup_steps=10, total_steps=100))
           for s in range(0, 100, 5)]
    assert lrs[0] < lrs[1]                  # warming up
    assert max(lrs) <= 1.0 + 1e-6
    assert lrs[-1] < lrs[4]                 # decaying
    assert lrs[-1] >= 0.1 - 1e-6            # min_ratio floor


def test_data_pipeline_is_seekable_and_deterministic():
    cfg = configs.get_smoke_config("llama3.2-3b")
    shape = ShapeCfg("t", 16, 4, "train")
    d1 = SyntheticLMData(cfg, shape, seed=3)
    d2 = SyntheticLMData(cfg, shape, seed=3)
    b_a = d1.batch_at(7)
    _ = d1.batch_at(3)   # no iterator state: order must not matter
    b_b = d2.batch_at(7)
    np.testing.assert_array_equal(np.asarray(b_a["tokens"]),
                                  np.asarray(b_b["tokens"]))
    # different steps differ
    assert not np.array_equal(np.asarray(d1.batch_at(8)["tokens"]),
                              np.asarray(b_a["tokens"]))


def test_data_pipeline_modality_batches():
    shape = ShapeCfg("t", 16, 2, "train")
    vlm = configs.get_smoke_config("internvl2-2b")
    b = SyntheticLMData(vlm, shape, seed=0).batch_at(0)
    assert b["embeds"].shape[1] == vlm.frontend_tokens
    assert b["tokens"].shape[1] == 16 - vlm.frontend_tokens
    aud = configs.get_smoke_config("whisper-large-v3")
    b = SyntheticLMData(aud, shape, seed=0).batch_at(0)
    assert b["enc_embeds"].shape == (2, 16, aud.d_model)
