"""Live-migration tests — the paper's §6.3 use case as a test suite.

A kernel is paused at a barrier on backend A, its device-neutral snapshot is
serialized, and execution resumes on backend B.  The final result must match
a non-migrated run exactly (same traced fp semantics) or to fp tolerance
(scalar interpreter's independent accumulation order).
"""
import itertools

import numpy as np
import pytest

from repro.core import Engine, HetSession, Snapshot, get_backend, migrate
from repro.core import kernels_suite as suite

RNG = np.random.default_rng(1)
PAIRS = list(itertools.permutations(["interp", "vectorized", "pallas"], 2))


def _mk_counter_args():
    return {"State": RNG.normal(size=64).astype(np.float32), "iters": 6}


@pytest.mark.parametrize("src,dst", PAIRS)
def test_migrate_persistent_counter(src, dst):
    prog, oracle = suite.persistent_counter()
    args = _mk_counter_args()

    # ground truth: non-migrated run
    ref = Engine(prog, get_backend(src), 2, 32, dict(args))
    assert ref.run()

    # migrated run: pause mid-loop after 3 segments, resume elsewhere
    eng = Engine(prog, get_backend(src), 2, 32, dict(args))
    finished = eng.run(max_segments=3)
    assert not finished, "should have paused mid-kernel"
    snap = eng.snapshot()
    blob = snap.to_bytes()  # serialize across the 'wire'
    eng2 = Engine.resume(prog, get_backend(dst), Snapshot.from_bytes(blob))
    assert eng2.run()

    np.testing.assert_allclose(eng2.result("State"), ref.result("State"),
                               rtol=1e-5, atol=1e-5)
    # and both match the oracle
    expect = oracle(dict(args))
    np.testing.assert_allclose(eng2.result("State"), expect["State"],
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("src,dst", [("vectorized", "pallas"),
                                     ("pallas", "interp")])
def test_migrate_matmul_mid_tile(src, dst):
    """The paper's §6.3 headline: iterative tiled matmul migrated midway."""
    M, K, N, TK = 4, 32, 16, 8
    A = RNG.normal(size=(M, K)).astype(np.float32)
    B = RNG.normal(size=(K, N)).astype(np.float32)
    args = {"A": A.reshape(-1), "B": B.reshape(-1),
            "C": np.zeros(M * N, np.float32),
            "K": K, "N": N, "ktiles": K // TK}
    prog, oracle = suite.matmul_tiled(TK)

    eng = Engine(prog, get_backend(src), M, N, dict(args))
    assert not eng.run(max_segments=5)  # pause inside the k-tile loop
    eng2 = Engine.resume(prog, get_backend(dst),
                         Snapshot.from_bytes(eng.snapshot().to_bytes()))
    assert eng2.run()
    expect = oracle(dict(args))
    np.testing.assert_allclose(eng2.result("C"), expect["C"],
                               rtol=1e-4, atol=1e-4)


def test_double_migration_chain():
    """H100 -> AMD -> Tenstorrent in the paper; here
    vectorized -> pallas -> interp."""
    prog, oracle = suite.persistent_counter()
    args = _mk_counter_args()
    e1 = Engine(prog, get_backend("vectorized"), 2, 32, dict(args))
    assert not e1.run(max_segments=2)
    e2 = Engine.resume(prog, get_backend("pallas"), e1.snapshot())
    assert not e2.run(max_segments=2)
    e3 = Engine.resume(prog, get_backend("interp"), e2.snapshot())
    assert e3.run()
    expect = oracle(dict(args))
    np.testing.assert_allclose(e3.result("State"), expect["State"],
                               rtol=1e-4, atol=1e-4)


def test_pause_flag_cooperative_checkpoint():
    """The paper's pause-flag protocol: flag set while running; kernel
    stops at the next barrier, not immediately."""
    prog, _ = suite.persistent_counter()
    args = _mk_counter_args()
    eng = Engine(prog, get_backend("vectorized"), 2, 32, dict(args))
    calls = {"n": 0}

    def flag():
        calls["n"] += 1
        return calls["n"] >= 2  # request pause after the second barrier

    finished = eng.run(pause_flag=flag)
    assert not finished
    assert 0 < eng.node_idx < len(eng.nodes)


def test_session_migrate_api():
    """End-to-end through the HetSession abstraction layer (paper §4.3)."""
    prog, oracle = suite.persistent_counter()
    args = _mk_counter_args()

    src = HetSession("vectorized")
    dst = HetSession("pallas")
    src.load_kernel(prog)
    dst.load_kernel(prog)

    src.pause_flag = False
    rec = src.launch("persistent_counter", grid=2, block=32,
                     args=dict(args), blocking=False)
    # drive a few segments, then set the pause flag (cooperative checkpoint)
    rec.engine.run(max_segments=3)
    new_rec = migrate(rec, src, dst, "persistent_counter")
    dst.run_to_completion(new_rec)
    assert new_rec.finished

    expect = oracle(dict(args))
    np.testing.assert_allclose(new_rec.engine.result("State"),
                               expect["State"], rtol=1e-4, atol=1e-4)
    assert dst.stats["last_migration"]["payload_bytes"] > 0


def test_snapshot_roundtrip_identity():
    prog, _ = suite.persistent_counter()
    args = _mk_counter_args()
    eng = Engine(prog, get_backend("vectorized"), 2, 32, dict(args))
    eng.run(max_segments=2)
    snap = eng.snapshot()
    back = Snapshot.from_bytes(snap.to_bytes())
    assert back.node_idx == snap.node_idx
    assert back.loop_counters == snap.loop_counters
    assert set(back.regs) == set(snap.regs)
    for k in snap.regs:
        np.testing.assert_array_equal(back.regs[k], snap.regs[k])
    for k in snap.globals_:
        np.testing.assert_array_equal(back.globals_[k], snap.globals_[k])
