"""Property tests for the fleet's two-tier retry queue.

The queue is the self-healing invariant's source of truth, so its
contract is tested as a *property* over arbitrary event interleavings —
enqueue, dispatch, worker crash (requeue), graceful reassign, coordinator
crash + restart (reload from disk, demote stale inflight), ack — rather
than as a handful of happy paths:

* **nothing is ever lost**: every enqueued launch remains reachable
  (pending / inflight / acked) through any interleaving, including a
  coordinator restart over the same directory;
* **nothing is delivered twice**: ``ack`` returns True exactly once per
  launch, no matter how many replays raced it;
* **durability round-trips bit-exactly**: ndarray args come back from the
  JSON tier with identical bytes, dtype, and shape.

Runs in two modes, like ``test_fuzz_differential.py``: a fixed-seed
random corpus (no third-party dependency, deterministic) always runs;
when hypothesis is installed the same simulation becomes a shrinking
property test (``derandomize=True`` keeps CI reproducible).
"""
import numpy as np
import pytest

from repro.core.fleet import RetryQueue, _decode_value, _encode_value

try:
    import hypothesis
    from hypothesis import strategies as st
except ImportError:  # container without hypothesis: corpus still runs
    hypothesis = None


# ---------------------------------------------------------------------------
# model-based simulation: drive a real RetryQueue (durable tier on disk)
# against a trivial in-memory reference model, then check the invariants
# ---------------------------------------------------------------------------

#: event alphabet: (op, target-index) — target resolved modulo the
#: relevant launch population at replay time
OPS = ("enqueue", "dispatch", "crash_worker", "reassign", "ack",
       "restart", "duplicate_ack")


def run_simulation(events, qdir):
    """Apply ``events`` to a durable RetryQueue; return the queue plus
    the reference model ``{launch_id: acked?}`` and the ack log."""
    q = RetryQueue(qdir)
    model = {}          # launch_id -> acked (reference truth)
    deliveries = []     # launch_ids whose ack() returned True
    n = 0
    for op, tgt in events:
        if op == "enqueue":
            lid = f"L{n:04d}"
            n += 1
            q.enqueue(lid, "dyn_matmul", 4, 16,
                      {"A": np.arange(4, dtype=np.float32), "K": 32},
                      ("A",))
            model[lid] = False
        elif op == "dispatch":
            pending = q.pending()
            if pending:
                q.mark_inflight(pending[tgt % len(pending)],
                                worker=tgt % 3)
        elif op == "crash_worker":
            # a worker died: everything it held goes back to pending
            for lid in q.inflight(worker=tgt % 3):
                q.requeue(lid)
        elif op == "reassign":
            inflight = q.inflight()
            if inflight:
                q.reassign(inflight[tgt % len(inflight)],
                           worker=(tgt + 1) % 3)
        elif op == "ack":
            inflight = q.inflight()
            if inflight:
                lid = inflight[tgt % len(inflight)]
                if q.ack(lid):
                    deliveries.append(lid)
                model[lid] = True
        elif op == "duplicate_ack":
            # a raced result for an already-acked launch: must be refused
            acked = [lid for lid, done in model.items() if done]
            if acked:
                lid = acked[tgt % len(acked)]
                assert q.ack(lid) is False
                assert q.is_acked(lid)
        elif op == "restart":
            # the coordinator dies: a fresh queue over the same dir
            # must reload every record; stale inflight demotes to pending
            q = RetryQueue(qdir)
            q.recover()
    return q, model, deliveries


def check_invariants(q, model, deliveries, qdir):
    # 1. nothing lost: every enqueued launch is still in the queue,
    #    and unacked ones are reachable for (re)dispatch
    assert len(q) == len(model)
    for lid, acked in model.items():
        rec = q.get(lid)
        assert rec["state"] == ("acked" if acked else rec["state"])
        if not acked:
            assert rec["state"] in ("pending", "inflight")
    assert sorted(q.unacked()) == sorted(
        lid for lid, acked in model.items() if not acked)
    # 2. exactly-once delivery: one True ack per acked launch, ever
    assert sorted(deliveries) == sorted(
        lid for lid, acked in model.items() if acked)
    assert len(set(deliveries)) == len(deliveries)
    # 3. a final restart loses nothing and changes no ack state
    q2 = RetryQueue(qdir)
    demoted = q2.recover()
    assert len(q2) == len(model)
    for lid, acked in model.items():
        assert q2.is_acked(lid) == acked
        assert q2.get(lid)["state"] == \
            ("acked" if acked else "pending")
    assert all(not model[lid] for lid in demoted)
    # 4. durable args round-trip bit-exactly
    for lid in model:
        args = q2.decode_args(lid)
        ref = np.arange(4, dtype=np.float32)
        assert args["A"].dtype == ref.dtype
        assert args["A"].tobytes() == ref.tobytes()
        assert args["K"] == 32
    # 5. enqueue order survives restarts
    order = [q2.get(lid)["seq"] for lid in sorted(model)]
    assert order == sorted(order)


def _random_events(rng, length):
    # enqueue-weighted so interleavings act on a real population
    weights = {"enqueue": 4, "dispatch": 4, "crash_worker": 2,
               "reassign": 1, "ack": 3, "restart": 1, "duplicate_ack": 1}
    ops = [op for op, w in weights.items() for _ in range(w)]
    return [(ops[rng.integers(len(ops))], int(rng.integers(64)))
            for _ in range(length)]


# -- fixed-seed corpus (always runs; deterministic) -------------------------

@pytest.mark.parametrize("seed", range(20))
def test_retry_queue_interleavings_corpus(tmp_path, seed):
    rng = np.random.default_rng(1000 + seed)
    events = _random_events(rng, int(rng.integers(10, 60)))
    qdir = tmp_path / "q"
    q, model, deliveries = run_simulation(events, qdir)
    check_invariants(q, model, deliveries, qdir)


# -- hypothesis mode (shrinking; CI installs it) ----------------------------

if hypothesis is not None:
    @hypothesis.settings(max_examples=60, deadline=None,
                         derandomize=True)
    @hypothesis.given(events=st.lists(
        st.tuples(st.sampled_from(OPS), st.integers(0, 63)),
        min_size=1, max_size=60))
    def test_retry_queue_interleavings_hypothesis(tmp_path_factory, events):
        qdir = tmp_path_factory.mktemp("rq") / "q"
        q, model, deliveries = run_simulation(events, qdir)
        check_invariants(q, model, deliveries, qdir)


# ---------------------------------------------------------------------------
# directed unit cases for the sharp edges
# ---------------------------------------------------------------------------

def test_ack_consumes_exactly_once(tmp_path):
    q = RetryQueue(tmp_path / "q")
    q.enqueue("L1", "k", 1, 1, {}, ())
    q.mark_inflight("L1", worker=0)
    assert q.ack("L1") is True
    assert q.ack("L1") is False          # the double-ack guard
    assert q.requeue("L1") is False      # a late death cannot resurrect
    assert q.is_acked("L1")


def test_mark_inflight_counts_attempts(tmp_path):
    q = RetryQueue(tmp_path / "q")
    q.enqueue("L1", "k", 1, 1, {}, ())
    assert q.mark_inflight("L1", worker=0) == 1
    assert q.requeue("L1") is True
    assert q.mark_inflight("L1", worker=1) == 2
    with pytest.raises(ValueError):
        q.enqueue("L1", "k", 1, 1, {}, ())   # duplicate accept refused
    q.ack("L1")
    with pytest.raises(ValueError):
        q.mark_inflight("L1", worker=0)      # acked is terminal


def test_restart_demotes_stale_inflight(tmp_path):
    q = RetryQueue(tmp_path / "q")
    q.enqueue("L1", "k", 1, 1, {}, ())
    q.enqueue("L2", "k", 1, 1, {}, ())
    q.mark_inflight("L1", worker=0)
    q.ack("L1")
    q.mark_inflight("L2", worker=1)
    q2 = RetryQueue(tmp_path / "q")          # coordinator restart
    assert q2.recover() == ["L2"]            # only the stale inflight
    assert q2.get("L2")["state"] == "pending"
    assert q2.is_acked("L1")


def test_torn_record_is_skipped_not_fatal(tmp_path):
    qdir = tmp_path / "q"
    q = RetryQueue(qdir)
    q.enqueue("L1", "k", 1, 1, {}, ())
    (qdir / "garbage.json").write_text("{not json")
    (qdir / "foreign.json").write_text('{"launch_id": "X"}')  # bad state
    q2 = RetryQueue(qdir)
    assert sorted(r for r in q2.unacked()) == ["L1"]


def test_memory_only_mode_keeps_semantics(tmp_path):
    q = RetryQueue(None)
    q.enqueue("L1", "k", 1, 1, {"x": np.float32(2.5)}, ())
    assert q.mark_inflight("L1", 0) == 1
    assert q.requeue("L1") and q.pending() == ["L1"]
    assert q.stats()["durable"] is False


def test_ndarray_codec_bit_exact():
    for arr in (np.arange(7, dtype=np.float32),
                np.linspace(-1, 1, 12, dtype=np.float64).reshape(3, 4),
                np.array([], dtype=np.int32),
                np.array([[1, 2], [3, 4]], dtype=np.uint8)):
        back = _decode_value(_encode_value(arr))
        assert back.dtype == arr.dtype and back.shape == arr.shape
        assert back.tobytes() == arr.tobytes()
    assert _decode_value(_encode_value(np.float32(1.5))) == np.float32(1.5)
    assert _decode_value(_encode_value(None)) is None
    with pytest.raises(TypeError):
        _encode_value(object())
