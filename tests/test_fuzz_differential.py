"""Generative differential fuzz harness for the hetIR pass pipeline.

Random *well-formed* hetIR programs — loops with constant and dynamic trip
counts, power-of-two and odd multiplies/divides/mods, shifts, predication,
barriers (cross-segment reuse), shared memory, collectives and atomics —
are executed at O0 and at OPT_MAX on the interp and vectorized backends
(where OPT_MAX also exercises launch-time specialization: the auto policy
binds the launch scalars of any program with a barrier-free dynamic-trip
loop, which the generator emits routinely).  A second *memory-op corpus*
(``mem=True`` profile) stresses the alias-aware passes with overlapping
and disjoint LOAD/STORE patterns, including same-buffer read-after-write
inside loops.
The property: **outputs are bit-identical per backend across opt levels**.
The pipeline may remove or rearrange work; it may never change a computed
bit.

The generator is written against a tiny "chooser" interface so the same
program-construction logic backs two harnesses:

* a **fixed-seed corpus** (``_RngChooser`` over ``np.random.Generator``)
  — fully deterministic, no third-party dependency, sized by
  ``HETGPU_FUZZ_EXAMPLES`` (default 210 ≥ the 200-program acceptance bar);
  this is the CI profile;
* a **hypothesis strategy** (``_DrawChooser`` over ``st.data()``) — when
  hypothesis is installed, the same generator becomes a shrinking
  property-based test (``derandomize=True`` keeps it reproducible).

Generator legality rules (what makes a random program *well-formed*):
global/shared indices are always wrapped by a power-of-two bound, so no
backend ever sees an out-of-range access; integer divisors, moduli and
shift amounts are non-zero constants in range; barriers only appear at the
top level (never under @PRED); a value defined under a predicate or
inside a possibly-zero-trip loop only escapes its region when a write is
*guaranteed* before the first read (the predicated loop-carry pattern
below, whose iteration-0 write is unconditional) — otherwise mutation of
pre-declared accumulators is how divergent writes become visible, exactly
the discipline the kernel suite follows.

Bugs this harness (or its construction) has already caught: numpy folding
integer ``x/0`` to 0 while XLA computes a platform value (fold guard in
``passes.fold_constants``), and XLA CPU contracting mul+add chains into
hardware FMAs *graph-shape-dependently*, so a rolled loop and its unrolled
form disagreed in the low bits (product pinning in
``backends/semantics._mul_exact``).
"""
from __future__ import annotations

import os

import numpy as np
import pytest

from repro.core import Engine, OPT_MAX, TranslationCache, get_backend
from repro.core import hetir as ir
from repro.core.hetir import Builder, Ptr, Scalar

try:
    import hypothesis
    from hypothesis import strategies as st
except ImportError:  # container without hypothesis: corpus still runs
    hypothesis = None

N_EXAMPLES = int(os.environ.get("HETGPU_FUZZ_EXAMPLES", "210"))
MEM_EXAMPLES = int(os.environ.get("HETGPU_FUZZ_MEM_EXAMPLES", "210"))
ATTN_EXAMPLES = int(os.environ.get("HETGPU_FUZZ_ATTN_EXAMPLES", "70"))
CHUNKS = 7
SEED0 = 20260728
MEM_SEED0 = 20270115
ATTN_SEED0 = 20260807
BACKENDS = ("interp", "vectorized")


# ---------------------------------------------------------------------------
# chooser interface: one generator, two harnesses
# ---------------------------------------------------------------------------


class _RngChooser:
    def __init__(self, rng: np.random.Generator):
        self.rng = rng

    def randint(self, lo: int, hi: int) -> int:
        """Uniform int in [lo, hi] inclusive."""
        return int(self.rng.integers(lo, hi + 1))

    def pick(self, seq):
        return seq[self.randint(0, len(seq) - 1)]

    def chance(self, p: float) -> bool:
        return float(self.rng.random()) < p


class _DrawChooser:
    def __init__(self, draw):
        self.draw = draw

    def randint(self, lo: int, hi: int) -> int:
        return self.draw(st.integers(min_value=lo, max_value=hi))

    def pick(self, seq):
        return seq[self.randint(0, len(seq) - 1)]

    def chance(self, p: float) -> bool:
        return self.draw(st.booleans()) if 0 < p < 1 else p >= 1


# ---------------------------------------------------------------------------
# program generator
# ---------------------------------------------------------------------------

_INT_CONSTS = (1, 2, 3, 4, 5, 7, 8, 15, 16, 31, 32, 100, -1, -3, -8, 0)
_ODD_DIVS = (3, 5, 6, 7, 9)
_POW2_DIVS = (2, 4, 8, 16)
_F32_CONSTS = (0.0, 1.0, -1.0, 0.5, 2.0, 4.0, -0.25, 3.1415927,
               1e6, 1e-6, -3.0, 8.0)


class _ProgramGen:
    """Builds one random well-formed hetIR program via a chooser.

    With ``mem=True`` the generator shifts into its *memory-op profile*:
    programs are salted with LOAD/STORE statements over overlapping and
    disjoint buffer access patterns — including same-buffer
    read-after-write inside loops, the exact shapes that make the
    alias-aware ``hoist_invariant_loads`` pass dangerous — and the input
    buffers ``F``/``I`` join the compared outputs so a misplaced store is
    caught even when no later load observes it.  Plain (non-atomic) store
    indices are always *launch-injective* — a bijection of the global
    thread id (odd-stride affine or xor mod the pow-2 launch size),
    optionally shifted by a uniform loop-term — because colliding plain
    stores have no defined winner across backends (XLA scatter picks an
    arbitrary duplicate; the interpreter is last-thread-wins).  Loads may
    target anything in range.  ``G`` is never stored to, keeping a
    provably alias-free invariant-load candidate in every program."""

    def __init__(self, ch, tag: str, mem: bool = False, attn: bool = False):
        self.ch = ch
        self.tag = tag
        self.mem = mem
        self.attn = attn
        self.ops_budget = 60

    # -- expression pools (scoped: regions push/pop their additions) -------
    def _push_scope(self):
        return len(self.ints), len(self.floats), len(self.bools)

    def _pop_scope(self, mark):
        del self.ints[mark[0]:]
        del self.floats[mark[1]:]
        del self.bools[mark[2]:]

    def _spend(self, n: int = 1) -> bool:
        self.ops_budget -= n
        return self.ops_budget > 0

    # -- leaves ------------------------------------------------------------
    def _wrap_idx(self, v):
        """In-range non-negative index: floor-mod by a power of two ≤ N."""
        b, ch = self.b, self.ch
        bound = ch.pick([p for p in (4, 8, 16, 32, 64) if p <= self.N]
                        or [self.N])
        return v % b.const(bound)

    def int_expr(self, depth: int = 0):
        b, ch = self.b, self.ch
        if depth >= 3 or not self._spend() or ch.chance(0.35):
            if ch.chance(0.25):
                return b.const(ch.pick(_INT_CONSTS))
            return ch.pick(self.ints)
        kind = ch.pick(["add", "sub", "mul", "divmod", "shift", "bit",
                        "minmax", "neg", "select", "cvt", "u32", "load"])
        if kind in ("add", "sub", "mul"):
            a, c = self.int_expr(depth + 1), self.int_expr(depth + 1)
            return a + c if kind == "add" else \
                (a - c if kind == "sub" else a * c)
        if kind == "divmod":
            a = self.int_expr(depth + 1)
            d = b.const(ch.pick(_ODD_DIVS + _POW2_DIVS))
            return a / d if ch.chance(0.5) else a % d
        if kind == "shift":
            a = self.int_expr(depth + 1)
            k = b.const(ch.randint(0, 8))
            return a << k if ch.chance(0.5) else a >> k
        if kind == "bit":
            a, c = self.int_expr(depth + 1), self.int_expr(depth + 1)
            w = ch.pick("&|^")
            return a & c if w == "&" else (a | c if w == "|" else a ^ c)
        if kind == "minmax":
            a, c = self.int_expr(depth + 1), self.int_expr(depth + 1)
            return b.minimum(a, c) if ch.chance(0.5) else b.maximum(a, c)
        if kind == "neg":
            return -self.int_expr(depth + 1)
        if kind == "select":
            return b.select(self.bool_expr(depth + 1),
                            self.int_expr(depth + 1),
                            self.int_expr(depth + 1))
        if kind == "cvt":
            return self.float_expr(depth + 1).astype(ir.I32)
        if kind == "u32":
            u = self.int_expr(depth + 1).astype(ir.U32)
            w = ch.pick(["add", "shr", "div", "mul"])
            if w == "add":
                u = u + b.const(ch.pick((1, 2, 8)), ir.U32)
            elif w == "shr":
                u = u >> b.const(ch.randint(0, 8), ir.U32)
            elif w == "div":
                u = u / b.const(ch.pick(_POW2_DIVS), ir.U32)
            else:
                u = u * b.const(ch.pick((3, 4)), ir.U32)
            return u.astype(ir.I32)
        # load from the int buffer at a wrapped index
        return self.b.load("I", self._wrap_idx(self.int_expr(depth + 1)))

    def float_expr(self, depth: int = 0):
        b, ch = self.b, self.ch
        if depth >= 3 or not self._spend() or ch.chance(0.35):
            if ch.chance(0.25):
                return b.const(ch.pick(_F32_CONSTS), ir.F32)
            return ch.pick(self.floats)
        kind = ch.pick(["add", "sub", "mul", "div", "minmax", "un",
                        "select", "cvt", "fma", "load"])
        if kind in ("add", "sub", "mul", "div"):
            a, c = self.float_expr(depth + 1), self.float_expr(depth + 1)
            if kind == "div" and ch.chance(0.5):
                c = b.const(ch.pick((2.0, 4.0, 0.5, 3.0, -8.0)), ir.F32)
            return a + c if kind == "add" else \
                (a - c if kind == "sub" else
                 (a * c if kind == "mul" else a / c))
        if kind == "minmax":
            a, c = self.float_expr(depth + 1), self.float_expr(depth + 1)
            return b.minimum(a, c) if ch.chance(0.5) else b.maximum(a, c)
        if kind == "un":
            a = self.float_expr(depth + 1)
            w = ch.pick(["neg", "sqrt", "exp"])
            return -a if w == "neg" else \
                (b.sqrt(a) if w == "sqrt" else b.exp(a))
        if kind == "select":
            return b.select(self.bool_expr(depth + 1),
                            self.float_expr(depth + 1),
                            self.float_expr(depth + 1))
        if kind == "cvt":
            return self.int_expr(depth + 1).astype(ir.F32)
        if kind == "fma":
            return b.fma(self.float_expr(depth + 1),
                         self.float_expr(depth + 1),
                         self.float_expr(depth + 1))
        return b.load(ch.pick(("F", "G")),
                      self._wrap_idx(self.int_expr(depth + 1)))

    def bool_expr(self, depth: int = 0):
        b, ch = self.b, self.ch
        if depth >= 3 or not self._spend() or (self.bools
                                               and ch.chance(0.4)):
            if self.bools and ch.chance(0.6):
                return ch.pick(self.bools)
            a, c = self.int_expr(depth + 1), self.int_expr(depth + 1)
            w = ch.pick(["lt", "le", "gt", "ge", "eq", "ne"])
            return {"lt": lambda: a < c, "le": lambda: a <= c,
                    "gt": lambda: a > c, "ge": lambda: a >= c,
                    "eq": lambda: a.eq(c), "ne": lambda: a.ne(c)}[w]()
        if ch.chance(0.4):
            a, c = self.float_expr(depth + 1), self.float_expr(depth + 1)
            return a < c if ch.chance(0.5) else a >= c
        p, q = self.bool_expr(depth + 1), self.bool_expr(depth + 1)
        w = ch.pick("&|^")
        return p & q if w == "&" else (p | q if w == "|" else p ^ q)

    def _store_idx(self, j=None):
        """Launch-injective store index: a bijection of gid over the pow-2
        launch size (odd-stride affine / xor mask), optionally shifted by
        a uniform multiple of the loop variable — overlapping *across*
        iterations (read-after-write), never colliding across threads."""
        b, ch = self.b, self.ch
        N = self.N
        w = ch.pick(["id", "aff", "xor"])
        if w == "aff":
            idx = self.gid * b.const(ch.pick((3, 5, 7))) \
                + b.const(ch.randint(0, 7))
        elif w == "xor":
            idx = self.gid ^ b.const(ch.randint(0, N - 1))
        else:
            idx = self.gid
        if j is not None and ch.chance(0.5):
            idx = idx + j * b.const(ch.pick((1, 2, 4)))
        return idx % b.const(N)

    def gen_memrw(self, j=None) -> None:
        """Same-buffer store-then-load (RAW when indices overlap, disjoint
        when they don't — the chooser decides per program)."""
        b, ch = self.b, self.ch
        if ch.chance(0.5):
            buf = ch.pick(("OutF", "F"))
            b.store(buf, self._store_idx(j), self.float_expr())
            self.floats.append(
                b.load(buf, self._wrap_idx(self.int_expr())))
        else:
            buf = ch.pick(("OutI", "I"))
            b.store(buf, self._store_idx(j), self.int_expr())
            self.ints.append(
                b.load(buf, self._wrap_idx(self.int_expr())))

    def gen_memloop(self, depth: int) -> None:
        """A loop (constant or dynamic trip) whose body stores and loads
        the same buffer each iteration, plus an invariant-load candidate
        over the never-stored ``G`` — the memory-motion torture shape."""
        b, ch = self.b, self.ch
        count = "t" if ch.chance(0.3) else ch.randint(1, 10)
        mark = self._push_scope()
        with b.loop(count, hint="M") as j:
            self.gen_memrw(j)
            if ch.chance(0.5):
                inv = b.load("G",
                             b.const(ch.randint(0, min(3, self.N - 1))))
                b.assign(ch.pick(self.mut_f), ch.pick(self.mut_f) + inv)
            if self.use_shared and ch.chance(0.4):
                tid = b.thread_id()
                b.store_shared(tid, self.float_expr())
                self.floats.append(b.load_shared(
                    (tid + b.const(ch.randint(0, 3)))
                    % b.const(self.block)))
            if ch.chance(0.5):
                self.gen_stmts(1, depth + 1, top=False)
        self._pop_scope(mark)

    def gen_attn_tile(self) -> None:
        """The attention inner shape (``attn=True`` profile): a shared
        score tile, an EXP/REDUCE_MAX online-softmax fold with running
        max/correction carried across iterations, and a barriered
        PV-style accumulate — the exact collective-over-shared-tile
        composition the zoo's ``attn_decode`` is built from, which the
        general profile never emits (its collectives are int-typed and
        top-level only).  Collectives stay outside @PRED so every lane
        is active: the cross-backend property below depends on the
        lane-order folds seeing identical active sets."""
        b, ch = self.b, self.ch
        tid = b.thread_id()
        count = "t" if ch.chance(0.25) else ch.randint(1, 3)
        m = ch.pick(self.mut_f)
        mark = self._push_scope()
        with b.loop(count, hint="A"):
            sv = self.float_expr()
            mn = b.maximum(m, b.reduce_max(sv))
            p = b.exp(sv - mn)
            corr = b.exp(m - mn)
            b.assign(m, mn)
            b.store_shared(tid, p)
            b.barrier("attn-p")
            pv = b.load_shared((tid + b.const(ch.randint(0, 3)))
                               % b.const(self.block))
            acc = ch.pick(self.mut_f)
            b.assign(acc, acc * corr + pv)
            if ch.chance(0.5):
                other = ch.pick(self.mut_f)
                b.assign(other, other + b.scan_add(p))
            b.barrier("attn-c")
        self._pop_scope(mark)

    # -- statements --------------------------------------------------------
    def gen_stmts(self, n: int, depth: int, top: bool) -> None:
        for _ in range(n):
            if self.ops_budget <= 0:
                return
            self.gen_stmt(depth, top)

    def gen_stmt(self, depth: int, top: bool) -> None:
        b, ch = self.b, self.ch
        kinds = ["assign", "assign", "store", "pred"]
        if self.mem:
            kinds += ["memrw", "memrw"]
        if depth == 0:
            kinds += ["loop", "collective"]
            if not self.attn:
                # the attention corpus is compared *across* backends,
                # and an atomic racing a later plain store to the same
                # slot has no defined winner between block-serial
                # (interp) and lockstep (vectorized) execution — a real
                # GPU gives it no defined order either
                kinds += ["atomic"]
            if self.mem:
                kinds += ["memloop"]
            if self.attn:
                kinds += ["attn_tile", "attn_tile", "fcollective"]
        kind = ch.pick(kinds)
        if kind == "assign":
            if ch.chance(0.5):
                b.assign(ch.pick(self.mut_f), self.float_expr())
            else:
                b.assign(ch.pick(self.mut_i), self.int_expr())
        elif kind == "store":
            if ch.chance(0.5):
                b.store("OutF", self.gid, self.float_expr())
            else:
                b.store("OutI", self.gid, self.int_expr())
        elif kind == "pred":
            cond = self.bool_expr()
            mark = self._push_scope()
            with b.when(cond):
                self.gen_stmts(ch.randint(1, 2), depth + 1, top=False)
            self._pop_scope(mark)
        elif kind == "memrw":
            self.gen_memrw()
        elif kind == "memloop":
            self.gen_memloop(depth)
        elif kind == "attn_tile":
            self.gen_attn_tile()
        elif kind == "fcollective":
            # float collectives (the zoo's softmax/normalizer primitives)
            w = ch.pick(["reduce_add", "reduce_max", "scan_add"])
            v = self.float_expr()
            self.floats.append({"reduce_add": b.reduce_add,
                                "reduce_max": b.reduce_max,
                                "scan_add": b.scan_add}[w](v))
        elif kind == "loop":
            self.gen_loop(depth, top)
        elif kind == "atomic":
            b.atomic_add("OutI", self._wrap_idx(self.int_expr()),
                         self.int_expr())
        else:  # collective
            w = ch.pick(["reduce", "ballot", "vote"])
            if w == "reduce":
                v = b.reduce_add(self.int_expr())
            elif w == "ballot":
                v = b.ballot(self.bool_expr())
            else:
                v = b.vote_any(self.bool_expr()).astype(ir.I32)
            self.ints.append(v)

    def gen_loop(self, depth: int, top: bool) -> None:
        b, ch = self.b, self.ch
        kind = ch.pick(["const", "const", "dyn"] + (["barrier"] if top
                                                    else []))
        count = ch.randint(1, 10) if kind != "dyn" else "t"
        mark = self._push_scope()
        with b.loop(count, hint="L") as j:
            if kind != "dyn":
                self.ints.append(j)  # defined through and after the loop
            if ch.chance(0.4):
                # predicated loop-carry: the write is guaranteed in
                # iteration 0 (j == 0) and may be skipped later, so reads
                # after the @PRED observe the carried previous-iteration
                # value — the pattern a buggy unroll renames apart
                # (review-found miscompile, now a generator staple)
                cond = j.eq(b.const(0)) | self.bool_expr()
                pmark = self._push_scope()
                with b.when(cond):
                    carried = self.float_expr()
                self._pop_scope(pmark)
                b.assign(ch.pick(self.mut_f),
                         ch.pick(self.mut_f) + carried)
            self.gen_stmts(ch.randint(1, 3), depth + 1, top=False)
            if kind == "barrier":
                b.store("OutF", self.gid,
                        ch.pick(self.mut_f) + j.astype(ir.F32))
                b.barrier("iter")
        self._pop_scope(mark)
        if kind == "const":
            self.ints.append(j)  # post-loop read sees the final value

    # -- whole program -----------------------------------------------------
    def build(self):
        ch = self.ch
        grid = ch.pick((1, 2))
        block = ch.pick((4, 8, 16))
        self.N = grid * block
        self.block = block
        # the attention profile is *about* shared-tile traffic: always on
        use_shared = self.use_shared = True if self.attn else ch.chance(0.3)
        b = Builder(f"fuzz_{self.tag}",
                    [Ptr("F"), Ptr("G"), Ptr("I", ir.I32), Ptr("OutF"),
                     Ptr("OutI", ir.I32), Scalar("s"), Scalar("t"),
                     Scalar("fs", ir.F32)],
                    shared_size=block if use_shared else 0)
        self.b = b
        self.gid = b.global_id(0)
        self.ints = [self.gid, b.thread_id(), b.block_id(), b.param("s"),
                     b.block_dim()]
        self.floats = [b.param("fs"),
                       b.load("F", self._wrap_idx(self.gid))]
        self.bools = []
        # pre-declared accumulators: the only values divergent writes may
        # mutate, so every read is defined on every path
        self.mut_f = [b.var(self.floats[ch.randint(0, 1)], hint="mf"),
                      b.var(b.const(0.0, ir.F32), hint="mf")]
        self.mut_i = [b.var(b.const(ch.pick(_INT_CONSTS)), hint="mi"),
                      b.var(self.gid, hint="mi")]
        phases = ch.randint(1, 3)
        for p in range(phases):
            self.gen_stmts(ch.randint(2, 5), depth=0, top=True)
            if use_shared and ch.chance(0.6):
                tid = b.thread_id()
                b.store_shared(tid, self.float_expr())
                b.barrier(f"sh{p}")
                self.floats.append(b.load_shared(
                    (tid + b.const(ch.randint(0, 3))) % b.const(block)))
            elif p < phases - 1:
                b.barrier(f"ph{p}")  # cross-segment value reuse
        b.store("OutF", self.gid, ch.pick(self.mut_f) + self.float_expr())
        b.store("OutI", self.gid, ch.pick(self.mut_i) ^ self.int_expr())
        prog = b.done()

        args_seed = ch.randint(0, 2 ** 31 - 1)
        rng = np.random.default_rng(args_seed)
        args = {
            "F": rng.normal(size=self.N).astype(np.float32),
            "G": rng.normal(size=self.N).astype(np.float32),
            "I": rng.integers(-100, 100, size=self.N).astype(np.int32),
            "OutF": np.zeros(self.N, np.float32),
            "OutI": np.zeros(self.N, np.int32),
            "s": ch.randint(1, 5),
            "t": ch.randint(0, 4),   # dynamic trip counts include zero
            "fs": np.float32(rng.normal()),
        }
        # the memory profile stores into F / I too: compare them as well,
        # so a misplaced (hoisted/reordered) store is caught even when no
        # later load happens to observe it
        outs = ("OutF", "OutI", "F", "I") if self.mem \
            else ("OutF", "OutI")
        return prog, args, grid, block, outs


# ---------------------------------------------------------------------------
# the differential property
# ---------------------------------------------------------------------------


def _check_differential(prog, args, grid, block, outs, cache,
                        backends=BACKENDS, note="", cross=False):
    """O0 vs OPT_MAX must be bit-identical per backend (NaNs compare
    positionally equal).  With ``cross=True``, O0 results must also be
    bit-identical *across* backends — the portable-exp / lane-order-fold
    contract the zoo's bit-exact oracles rely on."""
    per_backend = {}
    for backend in backends:
        results = []
        for level in (0, OPT_MAX):
            eng = Engine(prog, get_backend(backend, cache=cache),
                         grid, block, dict(args), opt_level=level)
            assert eng.run(), f"{note}: {backend} O{level} did not finish"
            results.append([np.asarray(eng.result(o)) for o in outs])
        for o, r0, r1 in zip(outs, results[0], results[1]):
            np.testing.assert_array_equal(
                r0, r1,
                err_msg=(f"{note}: {backend} O0 vs O{OPT_MAX} differ in "
                         f"{o}\n{prog.to_text()}"))
        per_backend[backend] = results[0]
    if cross and len(backends) > 1:
        base = backends[0]
        for backend in backends[1:]:
            for o, r0, r1 in zip(outs, per_backend[base],
                                 per_backend[backend]):
                np.testing.assert_array_equal(
                    r0, r1,
                    err_msg=(f"{note}: {base} vs {backend} differ in "
                             f"{o}\n{prog.to_text()}"))


def _corpus_case(seed: int):
    gen = _ProgramGen(_RngChooser(np.random.default_rng(seed)), str(seed))
    return gen.build()


def _mem_corpus_case(seed: int):
    gen = _ProgramGen(_RngChooser(np.random.default_rng(seed)),
                      f"m{seed}", mem=True)
    return gen.build()


def _attn_corpus_case(seed: int):
    gen = _ProgramGen(_RngChooser(np.random.default_rng(seed)),
                      f"a{seed}", attn=True)
    return gen.build()


# fixed-seed deterministic profile (the CI profile): N_EXAMPLES programs,
# split into chunks so progress and failures localize
@pytest.mark.parametrize("chunk", range(CHUNKS))
def test_fuzz_differential_corpus(chunk):
    per = (N_EXAMPLES + CHUNKS - 1) // CHUNKS
    cache = TranslationCache(capacity=4 * per)
    for i in range(per):
        seed = SEED0 + chunk * per + i
        prog, args, grid, block, outs = _corpus_case(seed)
        _check_differential(prog, args, grid, block, outs, cache,
                            note=f"seed {seed}")


# memory-op corpus: LOAD/STORE programs with overlapping and disjoint
# buffer access patterns (incl. same-buffer read-after-write in loops) —
# the shapes that make alias-aware memory motion dangerous.  Same fixed-
# seed determinism contract as the main corpus.
@pytest.mark.parametrize("chunk", range(CHUNKS))
def test_fuzz_memory_op_corpus(chunk):
    per = (MEM_EXAMPLES + CHUNKS - 1) // CHUNKS
    cache = TranslationCache(capacity=4 * per)
    for i in range(per):
        seed = MEM_SEED0 + chunk * per + i
        prog, args, grid, block, outs = _mem_corpus_case(seed)
        _check_differential(prog, args, grid, block, outs, cache,
                            note=f"mem seed {seed}")


# attention-shaped corpus: EXP/REDUCE_MAX/SCAN_ADD over shared score
# tiles with barriers inside the loop — the collective composition the
# model zoo depends on.  This corpus is additionally checked *across*
# backends: interp and vectorized must produce the same bits, which is
# exactly the property the portable software EXP exists to provide
# (np.exp vs XLA's exp diverge on ~40% of float32 inputs).
@pytest.mark.parametrize("chunk", range(CHUNKS))
def test_fuzz_attention_corpus(chunk):
    per = (ATTN_EXAMPLES + CHUNKS - 1) // CHUNKS
    cache = TranslationCache(capacity=4 * per)
    for i in range(per):
        seed = ATTN_SEED0 + chunk * per + i
        prog, args, grid, block, outs = _attn_corpus_case(seed)
        _check_differential(prog, args, grid, block, outs, cache,
                            note=f"attn seed {seed}", cross=True)


def test_fuzz_attention_corpus_actually_emits_softmax_shapes():
    """Structural guarantee for the attention profile: across a sample,
    programs contain EXP, the float collectives (REDUCE_MAX/REDUCE_ADD/
    SCAN_ADD), shared-memory traffic and barriers *inside* loops."""
    import repro.core.hetir as hir

    opcodes = set()
    barrier_in_loop = 0

    def walk(body, in_loop):
        nonlocal barrier_in_loop
        for s in body:
            if isinstance(s, hir.Op):
                opcodes.add(s.opcode)
            elif isinstance(s, hir.Barrier):
                barrier_in_loop += bool(in_loop)
            elif isinstance(s, hir.Loop):
                walk(s.body, True)
            elif isinstance(s, hir.Pred):
                walk(s.body, in_loop)

    for i in range(30):
        prog, _, _, _, _ = _attn_corpus_case(ATTN_SEED0 + i)
        walk(prog.body, False)
    assert {ir.EXP, ir.REDUCE_MAX, ir.SCAN_ADD,
            ir.ST_SHARED, ir.LD_SHARED} <= opcodes, opcodes
    assert barrier_in_loop >= 10, "no barriered shared-tile loops emitted"


def test_fuzz_memory_corpus_meets_acceptance_size():
    if "HETGPU_FUZZ_MEM_EXAMPLES" in os.environ and MEM_EXAMPLES < 200:
        pytest.skip("memory corpus size deliberately overridden below "
                    "the acceptance bar (local iteration)")
    assert MEM_EXAMPLES >= 200, \
        "acceptance: >= 200 memory-op programs through the differential"


def test_fuzz_memory_corpus_actually_emits_memory_patterns():
    """Structural guarantee that the profile does what it claims: across
    a sample of the corpus there are loops whose body stores AND loads
    the same buffer (the read-after-write-in-loop pattern), and both F/I
    (input) and OutF/OutI (output) buffers get written."""
    import repro.core.hetir as hir

    raw_loops = 0
    written = set()
    for i in range(40):
        prog, _, _, _, _ = _mem_corpus_case(MEM_SEED0 + i)

        def loop_bodies(body):
            for s in body:
                if isinstance(s, hir.Loop):
                    yield s.body
                    yield from loop_bodies(s.body)
                elif isinstance(s, hir.Pred):
                    yield from loop_bodies(s.body)

        for body in loop_bodies(prog.body):
            reads, writes = hir.body_global_accesses(body)
            written |= writes
            if reads & writes:
                raw_loops += 1
    assert raw_loops >= 5, "no same-buffer read-after-write loops emitted"
    assert {"F", "I"} & written and {"OutF", "OutI"} & written


@pytest.mark.fast
def test_fuzz_differential_smoke():
    """Ten seeds, interp only — the seconds-fast marker subset."""
    cache = TranslationCache()
    for i in range(10):
        seed = SEED0 + 10_000 + i
        prog, args, grid, block, outs = _corpus_case(seed)
        _check_differential(prog, args, grid, block, outs, cache,
                            backends=("interp",), note=f"seed {seed}")


def test_fuzz_generator_is_deterministic():
    """Same seed → same program (the corpus is a *fixed* corpus: a CI
    failure reproduces locally from the seed in the message alone)."""
    a = _corpus_case(SEED0)[0]
    b = _corpus_case(SEED0)[0]
    assert ir.program_fingerprint(a) == ir.program_fingerprint(b)


def test_fuzz_corpus_meets_acceptance_size():
    if "HETGPU_FUZZ_EXAMPLES" in os.environ and N_EXAMPLES < 200:
        pytest.skip("corpus size deliberately overridden below the "
                    "acceptance bar (local iteration)")
    assert N_EXAMPLES >= 200, \
        "acceptance: >= 200 fuzzed programs through the differential check"


# ---------------------------------------------------------------------------
# hypothesis strategy over the same generator (shrinks; CI installs it)
# ---------------------------------------------------------------------------

if hypothesis is not None:

    @st.composite
    def hetir_programs(draw):
        """Strategy producing (program, args, grid, block, outs)."""
        return _ProgramGen(_DrawChooser(draw), "hyp").build()

    @hypothesis.settings(max_examples=25, deadline=None,
                         derandomize=True, database=None)
    @hypothesis.given(case=hetir_programs())
    def test_fuzz_differential_hypothesis(case):
        prog, args, grid, block, outs = case
        _check_differential(prog, args, grid, block, outs,
                            TranslationCache(), backends=("interp",),
                            note="hypothesis")
