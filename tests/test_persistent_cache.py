"""Persistent translation-cache tests (paper §4.2, cluster-lifetime JIT).

The on-disk tier must make translations survive the in-memory cache:
rebuilding a session against the same store turns every relaunch into a
disk restore (never a re-translation) with bit-identical results; corrupt
or version-skewed entry files degrade to misses, never exceptions;
concurrent writers are safe; eviction is cost-aware (GDSF), so expensive
translations outlive cheap ones; `HetSession.warmup` ahead-of-time
translates a kernel set; and `migrate` preloads the destination's cache.
"""
import pickle
import threading

import numpy as np
import pytest

from repro.core import (DiskStore, Engine, HetSession, OPT_MAX,
                        TranslationCache, get_backend, migrate)
from repro.core import kernels_suite as suite
from repro.core import passes

RNG = np.random.default_rng(7)


def _vadd_session(backend, store):
    s = HetSession(backend, cache=TranslationCache(store=store))
    prog, _ = suite.vadd()
    s.load_kernel(prog)
    return s


def _vadd_args(n=128):
    return {"A": RNG.normal(size=n).astype(np.float32),
            "B": RNG.normal(size=n).astype(np.float32),
            "C": np.zeros(n, np.float32), "n": n}


# ---------------------------------------------------------------------------
# cross-instance reuse (the acceptance scenario)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["interp", "vectorized"])
def test_cross_instance_restore_is_not_a_retranslation(backend, tmp_path):
    """Translate a suite kernel, drop the in-memory cache entirely (new
    TranslationCache instance), rebuild the session against the same
    on-disk store: the relaunch must be served by disk restores — zero
    fresh translations — and produce bit-identical output."""
    args = _vadd_args()
    s1 = _vadd_session(backend, DiskStore(tmp_path))
    s1.launch("vadd", grid=4, block=32, args=dict(args))
    out1 = s1._streams[0][-1].engine.result("C")
    st1 = s1.cache_stats()
    assert st1["translated"] >= 1 and st1["restored"] == 0

    # fresh memory tier, same persistent store — a "process restart"
    s2 = _vadd_session(backend, DiskStore(tmp_path))
    s2.launch("vadd", grid=4, block=32, args=dict(args))
    out2 = s2._streams[0][-1].engine.result("C")
    st2 = s2.cache_stats()
    assert st2["translated"] == 0, "relaunch must not re-translate"
    assert st2["restored"] == st1["translated"]
    assert np.array_equal(np.asarray(out1), np.asarray(out2)), \
        "disk-restored translation changed semantics"


def test_restore_across_cache_instances_direct(tmp_path):
    """DiskStore round-trip at the TranslationCache level."""
    store = DiskStore(tmp_path)
    c1 = TranslationCache(store=store)
    c1.get_or_translate(("interp", "fp", 0, 0),
                        lambda: ([1, 2, 3], ("interp-plan", [1, 2, 3])))
    c2 = TranslationCache(store=DiskStore(tmp_path))
    calls = []
    val = c2.get_or_translate(("interp", "fp", 0, 0),
                              lambda: (calls.append(1) or [], None))
    assert val == [1, 2, 3] and not calls
    assert c2.stats()["restored"] == 1 and c2.stats()["translated"] == 0


# ---------------------------------------------------------------------------
# corruption tolerance / invalidation
# ---------------------------------------------------------------------------


def test_truncated_and_corrupt_entries_load_as_misses(tmp_path):
    store = DiskStore(tmp_path)
    s1 = _vadd_session("interp", store)
    s1.launch("vadd", grid=4, block=32, args=_vadd_args())
    files = list(store.dir.glob("*.tce"))
    assert files
    files[0].write_bytes(files[0].read_bytes()[: len(files[0]
                                                    .read_bytes()) // 2])
    for f in files[1:]:
        f.write_bytes(b"\x00garbage\xff")

    s2 = _vadd_session("interp", DiskStore(tmp_path))
    s2.launch("vadd", grid=4, block=32, args=_vadd_args())  # must not raise
    st = s2.cache_stats()
    assert st["translated"] >= 1  # re-translated past the bad entries
    assert st["store"]["corrupt"] >= 1
    # corrupt files were quarantined and fresh entries re-persisted
    s3 = _vadd_session("interp", DiskStore(tmp_path))
    s3.launch("vadd", grid=4, block=32, args=_vadd_args())
    assert s3.cache_stats()["translated"] == 0


def test_version_mismatch_invalidates_entry(tmp_path):
    store = DiskStore(tmp_path)
    key = ("interp", "fp", 0, 0)
    store.save(key, "interp-plan", [1, 2, 3])
    path = store._path(key)
    env = pickle.loads(path.read_bytes())
    env["version"] = 999  # a future format
    path.write_bytes(pickle.dumps(env))
    assert store.load(key) is None
    assert store.stats()["corrupt"] >= 1


def test_runtime_tag_isolates_stores(tmp_path):
    """Entries written under one runtime tag are invisible to another
    (jax upgrade / platform change invalidation)."""
    old = DiskStore(tmp_path, tag="v0-jax0.0.0-cpu")
    old.save(("interp", "fp", 0, 0), "interp-plan", [1])
    new = DiskStore(tmp_path)  # current runtime tag
    assert new.load(("interp", "fp", 0, 0)) is None
    assert new.entry_count() == 0 and old.entry_count() == 1


def test_key_collision_guard(tmp_path):
    """An envelope whose stored key differs from the requested key (hash
    collision, or a tampered file) is a miss."""
    store = DiskStore(tmp_path)
    key_a, key_b = ("interp", "a", 0, 0), ("interp", "b", 0, 0)
    store.save(key_a, "interp-plan", [1])
    # graft A's envelope onto B's path
    store._path(key_b).write_bytes(store._path(key_a).read_bytes())
    assert store.load(key_b) is None


@pytest.mark.fast
def test_session_accepts_same_path_store(tmp_path):
    """cache already bound to a store + store= at the same path is fine;
    a genuinely different path is refused loudly."""
    cache = TranslationCache(store=DiskStore(tmp_path / "a"))
    HetSession("interp", cache=cache, store=DiskStore(tmp_path / "a"))
    with pytest.raises(ValueError):
        HetSession("interp", cache=cache, store=DiskStore(tmp_path / "b"))


@pytest.mark.fast
def test_failed_store_write_degrades_to_memory_only(tmp_path, monkeypatch):
    """A full/read-only disk must not fail the launch: the translation
    stays usable in memory and persist_errors counts the loss."""
    store = DiskStore(tmp_path)

    def boom(*a, **k):
        raise OSError(28, "No space left on device")

    monkeypatch.setattr(store, "save", boom)
    cache = TranslationCache(store=store)
    val = cache.get_or_translate(
        ("interp", "fp", 0, 0), lambda: ("LIVE", ("interp-plan", "LIVE")))
    assert val == "LIVE"
    assert cache.get(("interp", "fp", 0, 0)) == "LIVE"
    assert cache.stats()["persist_errors"] == 1


# ---------------------------------------------------------------------------
# pass-pipeline fingerprint invalidation
# ---------------------------------------------------------------------------


def test_store_tag_carries_pipeline_fingerprint(tmp_path):
    store = DiskStore(tmp_path)
    assert f"-p{passes.pipeline_fingerprint()}-" in store.tag


def test_pass_set_change_invalidates_persisted_entries(tmp_path,
                                                       monkeypatch):
    """A store populated under one pass pipeline must be invisible to a
    runtime with a different pass set — otherwise a stale artifact,
    optimized by passes that no longer exist (or have been fixed), would
    be restored silently."""
    old_fp = passes.pipeline_fingerprint()
    s1 = _vadd_session("interp", DiskStore(tmp_path))
    s1.launch("vadd", grid=4, block=32, args=_vadd_args())
    assert DiskStore(tmp_path).entry_count() >= 1

    # simulate a pass-semantics change (any pipeline edit has this effect)
    monkeypatch.setattr(passes, "_PASS_SCHEMA_VERSION", 10 ** 6)
    assert passes.pipeline_fingerprint() != old_fp
    fresh = DiskStore(tmp_path)
    assert fresh.tag != s1.cache.store.tag
    assert fresh.entry_count() == 0, \
        "entries persisted by the old pipeline leaked into the new tag"
    s2 = _vadd_session("interp", fresh)
    s2.launch("vadd", grid=4, block=32, args=_vadd_args())
    st = s2.cache_stats()
    assert st["translated"] >= 1 and st["restored"] == 0, \
        "stale optimized artifact restored across a pass-set change"


# ---------------------------------------------------------------------------
# cooperative checkpoint + migrate() on OPT_MAX-unrolled programs
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("src,dst", [("vectorized", "interp"),
                                     ("interp", "vectorized")])
def test_migrate_unrolled_omax_bit_identical(src, dst, tmp_path):
    """Mid-kernel checkpoint of an OPT_MAX program whose inner tile loop
    was *unrolled*: pause at a barrier inside the k-loop, migrate to the
    other backend (store-warmed destination), and finish **bit-identical**
    to an uninterrupted run on the destination backend.  This is the
    paper's migration story composed with the phase-2 optimizer: node
    indices are positions in the *optimized* segmented program, so the
    snapshot only restores correctly if the destination re-derives the
    exact same unrolled body."""
    M, K, N, TK = 4, 32, 16, 8
    args = {"A": RNG.normal(size=M * K).astype(np.float32),
            "B": RNG.normal(size=K * N).astype(np.float32),
            "C": np.zeros(M * N, np.float32),
            "K": K, "N": N, "ktiles": K // TK}
    prog, _ = suite.matmul_tiled(TK)

    ref = Engine(prog, get_backend(dst, cache=TranslationCache()),
                 M, N, dict(args), opt_level=OPT_MAX)
    assert ref.run()

    s_src = HetSession(src, opt_level=OPT_MAX,
                       cache=TranslationCache(store=DiskStore(tmp_path)))
    s_dst = HetSession(dst, opt_level=OPT_MAX,
                       cache=TranslationCache(store=DiskStore(tmp_path)))
    s_src.load_kernel(prog)
    s_dst.load_kernel(prog)
    rec = s_src.launch("matmul_tiled", grid=M, block=N, args=dict(args),
                       blocking=False)
    # the inner loop really did unroll, and we really do pause mid-kernel
    assert rec.engine.opt_stats.per_pass.get("unroll_loops", 0) >= 1
    assert not rec.engine.run(max_segments=5)
    new = migrate(rec, s_src, s_dst, "matmul_tiled")
    s_dst.run_to_completion(new)
    assert new.finished
    np.testing.assert_array_equal(
        np.asarray(new.engine.result("C")), np.asarray(ref.result("C")),
        err_msg=f"{src}->{dst} migrated OPT_MAX run diverged")


# ---------------------------------------------------------------------------
# concurrency
# ---------------------------------------------------------------------------


def test_concurrent_writers_threads(tmp_path):
    """Many threads translating into one store: atomic temp-file+rename
    writes mean no torn entries and no exceptions; a fresh cache can
    restore everything afterwards."""
    store = DiskStore(tmp_path)
    cache = TranslationCache(store=store)
    errors = []

    def worker(i):
        try:
            be = get_backend("interp", cache=cache)
            prog, _ = suite.vadd()
            eng = Engine(prog, be, 2 + (i % 3), 32, _vadd_args(
                (2 + (i % 3)) * 32))
            eng.run()
        except Exception as exc:  # pragma: no cover - the assertion target
            errors.append(exc)

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    assert not list(store.dir.glob("*.tmp")), "leaked temp files"
    fresh = TranslationCache(store=DiskStore(tmp_path))
    assert fresh.preload(backend="interp") >= 1


# ---------------------------------------------------------------------------
# cost-aware (GDSF) eviction
# ---------------------------------------------------------------------------


@pytest.mark.fast
def test_eviction_is_cost_aware_not_lru():
    """A capacity-2 cache holding one expensive and one cheap entry must
    evict the *cheap* one when a third arrives — plain LRU would have
    evicted the oldest (expensive) entry."""
    c = TranslationCache(capacity=2)
    c.put("expensive", "E", cost_ms=500.0, size_bytes=10)
    c.put("cheap-1", "c1", cost_ms=0.01, size_bytes=10)
    c.put("cheap-2", "c2", cost_ms=0.01, size_bytes=10)
    assert c.stats()["evictions"] == 1
    assert c.get("expensive") == "E"
    assert c.get("cheap-1") is None


@pytest.mark.fast
def test_eviction_clock_ages_out_stale_entries():
    """The GDSF clock advances on eviction, so an expensive-but-idle entry
    is eventually displaced by repeatedly-touched cheap ones."""
    c = TranslationCache(capacity=2)
    c.put("old", "O", cost_ms=1.0, size_bytes=1000)  # score ~ 0.001
    for i in range(50):
        c.put(f"k{i}", i, cost_ms=50.0, size_bytes=10)  # scores >= 5
    assert c.get("old") is None


@pytest.mark.fast
def test_ties_fall_back_to_lru():
    c = TranslationCache(capacity=2)
    c.put("a", 1)
    c.put("b", 2)
    assert c.get("a") == 1  # refresh recency of a
    c.put("c", 3)  # equal scores: evict least-recently-used => b
    assert c.get("b") is None and c.get("a") == 1 and c.get("c") == 3


# ---------------------------------------------------------------------------
# warm-up API
# ---------------------------------------------------------------------------


def test_warmup_translates_then_restores(tmp_path):
    progs = [suite.vadd()[0], (suite.saxpy()[0],
                               {"X": np.ones(64, np.float32),
                                "Y": np.ones(64, np.float32),
                                "n": 64, "a": 2.0})]
    s1 = HetSession("interp", cache=TranslationCache(store=DiskStore(
        tmp_path)))
    rep1 = s1.warmup(progs, grids=((2, 32),))
    assert rep1["errors"] == 0
    assert rep1["translated"] >= 2 and rep1["restored"] == 0

    # a warm node: same store, cold memory — everything restores from disk
    s2 = HetSession("interp", cache=TranslationCache(store=DiskStore(
        tmp_path)))
    rep2 = s2.warmup(progs, grids=((2, 32),))
    assert rep2["errors"] == 0
    assert rep2["translated"] == 0
    assert rep2["restored"] == rep1["translated"]
    # and a post-warmup launch is all memory hits
    prog, _ = suite.vadd()
    s2.load_kernel(prog)
    s2.launch("vadd", grid=2, block=32, args=_vadd_args(64))
    st = s2.cache_stats()
    assert st["translated"] == 0 and st["restored"] == rep1["translated"]


def test_warmup_reports_unlaunchable_kernels(tmp_path):
    """Synthesized args cannot drive every kernel — warm-up reports the
    failure instead of raising."""
    s = HetSession("interp", cache=TranslationCache(store=DiskStore(
        tmp_path)))
    rep = s.warmup([suite.matmul_tiled()[0]], grids=((2, 8),))
    assert len(rep["kernels"]) == 1
    assert rep["errors"] in (0, 1)  # best-effort either way
    statuses = {e["status"].split(":")[0] for e in rep["kernels"]}
    assert statuses <= {"ok", "error"}


# ---------------------------------------------------------------------------
# migration preloads the destination cache
# ---------------------------------------------------------------------------


def test_migrate_warms_destination_from_store(tmp_path):
    """A destination node whose runtime has previously translated this
    program (cluster lifetime) pays zero translation on migration."""
    args = _vadd_args()
    # cluster history: some interp session once ran vadd against the store
    hist = _vadd_session("interp", DiskStore(tmp_path))
    hist.launch("vadd", grid=4, block=32, args=dict(args))

    src = _vadd_session("vectorized", None)
    dst = _vadd_session("interp", DiskStore(tmp_path))
    rec = src.launch("vadd", grid=4, block=32, args=dict(args),
                     blocking=False)
    new = migrate(rec, src, dst, "vadd")
    assert dst.stats["last_migration"]["cache_restored"] >= 1
    dst.run_to_completion(new)
    assert dst.cache_stats()["translated"] == 0, \
        "migration destination re-translated despite a warm store"
    ref = _vadd_session("interp", None)
    ref.launch("vadd", grid=4, block=32, args=dict(args))
    np.testing.assert_array_equal(
        np.asarray(new.engine.result("C")),
        np.asarray(ref._streams[0][-1].engine.result("C")))


# ---------------------------------------------------------------------------
# on-disk size bound + garbage collection (GDSF score)
# ---------------------------------------------------------------------------


def _blob(i, n=1024):
    return bytes([i % 256]) * n


@pytest.mark.fast
def test_diskstore_gc_bounds_the_store(tmp_path):
    """With a byte bound, the store must stop growing: after every save
    the on-disk total stays within max_bytes."""
    store = DiskStore(tmp_path, max_bytes=16_000)
    for i in range(40):
        store.save(("interp", f"fp{i}", 0, 0), "interp-plan", _blob(i),
                   cost_ms=float(i))
        assert store.total_bytes() <= 16_000, f"store grew past bound at {i}"
    assert store.entry_count() < 40
    st = store.stats()
    assert st["gc_evictions"] > 0 and st["gc_runs"] > 0
    assert st["max_bytes"] == 16_000


@pytest.mark.fast
def test_diskstore_gc_evicts_by_gdsf_score(tmp_path):
    """The cheap-to-rebuild entries go first: an expensive translation
    survives a GC that evicts many cheap ones."""
    store = DiskStore(tmp_path, max_bytes=8_000)
    exp_key = ("pallas", "exp", 0, 0)
    store.save(exp_key, "interp-plan", _blob(0), cost_ms=5000.0)
    for i in range(30):
        store.save(("interp", f"cheap{i}", 0, 0), "interp-plan",
                   _blob(i), cost_ms=0.01)
    assert store.load(exp_key) is not None, \
        "GC evicted the expensive entry while cheap ones existed"
    assert store.total_bytes() <= 8_000


@pytest.mark.fast
def test_diskstore_bound_from_env(tmp_path, monkeypatch):
    monkeypatch.setenv("HETGPU_CACHE_MAX_BYTES", "12345")
    assert DiskStore(tmp_path).max_bytes == 12345
    monkeypatch.delenv("HETGPU_CACHE_MAX_BYTES")
    assert DiskStore(tmp_path).max_bytes == 0  # unbounded default


@pytest.mark.fast
def test_diskstore_gc_explicit_and_unbounded_default(tmp_path):
    store = DiskStore(tmp_path)  # unbounded: saves never trigger gc
    for i in range(10):
        store.save(("interp", f"fp{i}", 0, 0), "interp-plan", _blob(i))
    assert store.entry_count() == 10
    assert store.gc() == 0  # no bound, explicit gc is a no-op
    assert store.gc(limit=4_000) > 0  # explicit limit evicts
    assert store.total_bytes() <= 4_000


def test_bounded_store_still_serves_the_working_set(tmp_path):
    """A launch against a tightly bounded store stays correct (worst case
    it re-translates what GC evicted), and the store honours the bound."""
    args = _vadd_args()
    store = DiskStore(tmp_path, max_bytes=4096)
    s = _vadd_session("interp", store)
    s.launch("vadd", grid=4, block=32, args=dict(args))
    assert store.total_bytes() <= 4096
    out = s._streams[0][-1].engine.result("C")
    ref = _vadd_session("interp", None)
    ref.launch("vadd", grid=4, block=32, args=dict(args))
    np.testing.assert_array_equal(
        np.asarray(out),
        np.asarray(ref._streams[0][-1].engine.result("C")))


# ---------------------------------------------------------------------------
# acceptance: cold vs warm benchmark ratio
# ---------------------------------------------------------------------------


def test_bench_cold_warm_speedup_at_least_5x(tmp_path):
    from benchmarks.bench_translation import run_cold_warm

    rows = run_cold_warm(store_dir=str(tmp_path))
    per_backend = [r for r in rows if r["backend"] != "ALL"]
    assert all(r["warm_translated"] == 0 for r in per_backend), \
        "warm start re-translated instead of restoring"
    assert all(r["warm_restored"] == r["cold_translated"]
               for r in per_backend)
    agg = next(r for r in rows if r["backend"] == "ALL")
    assert agg["speedup"] >= 5.0, rows
