"""Stream semantics: segment-granularity interleaving across streams,
event ordering, cooperative pause-checkpoint of one stream while another
keeps running, and migration of in-flight async launches (both backend
directions, bit-identical)."""
import numpy as np
import pytest

from repro.core import Event, HetSession, TranslationCache, migrate
from repro.core import kernels_suite as suite

RNG = np.random.default_rng(11)


def _counter_session(backend="vectorized"):
    s = HetSession(backend, cache=TranslationCache())
    fn = s.load(suite.persistent_counter()[0]).function()
    return s, fn


def _mk_state(s, value=None):
    init = RNG.normal(size=64).astype(np.float32) if value is None \
        else np.full(64, value, np.float32)
    return s.alloc(64).copy_from_host(init), init


# ---------------------------------------------------------------------------
# Interleaving
# ---------------------------------------------------------------------------

def test_two_streams_interleave_at_segment_granularity():
    """The acceptance criterion: two async launches on different streams
    demonstrably alternate segment-by-segment — not serial completion."""
    s, fn = _counter_session()
    st1, st2 = s.stream(), s.stream()
    b1, i1 = _mk_state(s)
    b2, i2 = _mk_state(s)
    r1 = fn.launch_async(2, 32, {"State": b1, "iters": 6}, stream=st1)
    r2 = fn.launch_async(2, 32, {"State": b2, "iters": 6}, stream=st2)
    s.sched_trace.clear()
    assert s.synchronize()
    ids = [t["stream"] for t in s.sched_trace]
    assert set(ids) == {st1.sid, st2.sid}
    # round-robin: while both are in flight the trace alternates strictly
    n_overlap = 2 * min(ids.count(st1.sid), ids.count(st2.sid))
    overlap = ids[:n_overlap]
    assert all(a != b for a, b in zip(overlap, overlap[1:])), \
        f"streams did not alternate at segment granularity: {ids}"
    assert n_overlap >= 8, f"too little overlap to call it async: {ids}"
    # both finished with correct, independent results
    oracle = suite.persistent_counter()[1]
    for buf, init in ((b1, i1), (b2, i2)):
        np.testing.assert_allclose(
            buf.copy_to_host(),
            oracle({"State": init.copy(), "iters": 6})["State"],
            atol=1e-4, rtol=1e-4)
    assert r1.finished and r2.finished


def test_interleaving_visible_in_executed_ops():
    """Both engines accumulate executed ops concurrently — neither ran to
    completion before the other started."""
    s, fn = _counter_session()
    st1, st2 = s.stream(), s.stream()
    b1, _ = _mk_state(s)
    b2, _ = _mk_state(s)
    r1 = fn.launch_async(2, 32, {"State": b1, "iters": 6}, stream=st1)
    r2 = fn.launch_async(2, 32, {"State": b2, "iters": 6}, stream=st2)
    assert s.step(2)                      # two round-robin passes
    assert r1.started and r2.started
    assert 0 < r1.engine.executed_ops
    assert 0 < r2.engine.executed_ops
    assert not r1.finished and not r2.finished
    assert s.synchronize()


# ---------------------------------------------------------------------------
# Events
# ---------------------------------------------------------------------------

def test_event_wait_orders_across_streams():
    """Stream 2's dependent launch must not execute a single segment
    before stream 1 reaches the recorded event."""
    s = HetSession("vectorized", cache=TranslationCache())
    pc = s.load(suite.persistent_counter()[0]).function()
    va = s.load(suite.vadd()[0]).function()
    st1, st2 = s.stream(), s.stream()
    state, init = _mk_state(s)
    c = s.alloc(64)
    r1 = pc.launch_async(2, 32, {"State": state, "iters": 5}, stream=st1)
    ev = st1.record_event()
    st2.wait_event(ev)
    # reads the counter's output — legal only after the event
    r2 = va.launch_async(2, 32, {"A": state, "B": state, "C": c, "n": 64},
                         stream=st2)
    s.sched_trace.clear()
    assert s.synchronize()
    seqs = [t["seq"] for t in s.sched_trace]
    assert seqs.index(r2.seq) > max(i for i, q in enumerate(seqs)
                                    if q == r1.seq), \
        f"dependent launch ran before the event: {s.sched_trace}"
    expect = suite.persistent_counter()[1](
        {"State": init.copy(), "iters": 5})["State"]
    np.testing.assert_allclose(c.copy_to_host(), 2 * expect,
                               atol=1e-4, rtol=1e-4)
    assert ev.query()


def test_event_query_record_semantics():
    s, fn = _counter_session()
    ev = Event()
    assert not ev.query()                 # never recorded
    st = s.stream()
    st.wait_event(ev)                     # CUDA: no-op, must not block
    buf, _ = _mk_state(s)
    fn.launch_async(2, 32, {"State": buf, "iters": 3}, stream=st)
    assert s.synchronize()

    ev2 = st.record_event()               # empty stream: completes now
    assert ev2.query()
    buf2, _ = _mk_state(s)
    fn.launch_async(2, 32, {"State": buf2, "iters": 3}, stream=st)
    ev3 = st.record_event()
    assert not ev3.query()                # pending behind the launch
    assert ev3.synchronize()
    assert ev3.query()


def test_event_rerecord_invalidates_old_marker():
    """CUDA re-record semantics: moving an event's record point to a new
    stream must invalidate the old marker — reaching the *old* point no
    longer completes the event."""
    s, fn = _counter_session()
    st1, st2 = s.stream(), s.stream()
    b1, _ = _mk_state(s)
    b2, _ = _mk_state(s)
    fn.launch_async(2, 32, {"State": b1, "iters": 3}, stream=st1)
    ev = st1.record_event()
    fn.launch_async(2, 32, {"State": b2, "iters": 6}, stream=st2)
    st2.record_event(ev)                  # re-record behind st2's work
    st2.pause()
    assert st1.synchronize()              # old marker point reached...
    assert not ev.query(), \
        "stale marker completed a re-recorded event"
    st2.resume()
    assert s.synchronize()
    assert ev.query()


def test_event_wait_pins_record_point_at_wait_time():
    """CUDA: a wait refers to the record point current when the wait was
    issued; a later re-record must not move it (else two streams that
    cross-record can deadlock)."""
    s, fn = _counter_session()
    a, b = s.stream(), s.stream()
    b1, _ = _mk_state(s)
    b2, _ = _mk_state(s)
    fn.launch_async(2, 32, {"State": b1, "iters": 3}, stream=a)
    ev = a.record_event()
    b.wait_event(ev)                      # pinned to the record above
    fn.launch_async(2, 32, {"State": b2, "iters": 3}, stream=b)
    f = b.record_event()
    a.wait_event(f)
    a.record_event(ev)                    # re-record AFTER b's wait
    assert s.synchronize(), \
        "cross-recorded streams deadlocked: b's wait moved to the new " \
        "record point instead of staying pinned"
    assert ev.query() and f.query()


# ---------------------------------------------------------------------------
# Cooperative pause / checkpoint of one stream while another runs
# ---------------------------------------------------------------------------

def test_pause_one_stream_checkpoint_while_other_runs():
    s, fn = _counter_session()
    st1, st2 = s.stream(), s.stream()
    b1, i1 = _mk_state(s)
    b2, i2 = _mk_state(s)
    r1 = fn.launch_async(2, 32, {"State": b1, "iters": 8}, stream=st1)
    r2 = fn.launch_async(2, 32, {"State": b2, "iters": 8}, stream=st2)
    s.step(2)                              # both in flight
    st1.pause()
    assert s.synchronize() is False, "paused work must remain"
    assert r2.finished, "the unpaused stream must have kept running"
    assert not r1.finished
    eng = r1.engine
    assert 0 < eng.node_idx < len(eng.nodes), "paused mid-kernel"

    # checkpoint the paused in-flight launch, restore it, finish both
    blob = s.checkpoint(r1)
    r1.cancel()
    st1.resume()
    restored = s.restore("persistent_counter", blob, stream=st1)
    assert s.synchronize()
    oracle = suite.persistent_counter()[1]
    # identity: restore re-bound b1, so results land in the original
    np.testing.assert_allclose(
        b1.copy_to_host(),
        oracle({"State": i1.copy(), "iters": 8})["State"],
        atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(
        b2.copy_to_host(),
        oracle({"State": i2.copy(), "iters": 8})["State"],
        atol=1e-4, rtol=1e-4)
    assert restored.finished


def test_session_pause_flag_holds_all_streams():
    s, fn = _counter_session()
    buf, _ = _mk_state(s)
    fn.launch_async(2, 32, {"State": buf, "iters": 6})
    s.step(1)
    s.pause_flag = True
    assert s.synchronize() is False
    s.pause_flag = False
    assert s.synchronize() is True


# ---------------------------------------------------------------------------
# Migration of in-flight async launches — both directions, bit-identical
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("src,dst", [("vectorized", "pallas"),
                                     ("pallas", "vectorized")])
def test_migrate_async_launch_mid_kernel_bit_identical(src, dst):
    """Acceptance criterion: an in-flight async launch survives
    checkpoint → migrate → resume *bit-identically* on the destination
    backend (both jit backends share pinned fp semantics)."""
    prog, _ = suite.persistent_counter()
    init = RNG.normal(size=64).astype(np.float32)

    # reference: the whole kernel on the destination backend
    s_ref = HetSession(dst, cache=TranslationCache())
    ref_buf = s_ref.alloc(64).copy_from_host(init)
    s_ref.load(prog).launch(2, 32, {"State": ref_buf, "iters": 6})

    s_src = HetSession(src, cache=TranslationCache())
    s_dst = HetSession(dst, cache=TranslationCache())
    fn = s_src.load(prog).function()
    s_dst.load(prog)
    buf = s_src.alloc(64).copy_from_host(init)
    rec = fn.launch_async(2, 32, {"State": buf, "iters": 6})
    assert s_src.step(3)                   # genuinely mid-kernel
    assert rec.started and not rec.finished
    assert 0 < rec.engine.node_idx < len(rec.engine.nodes)

    new = migrate(rec, s_src, s_dst, "persistent_counter")
    assert rec.cancelled, "migrated-away launch must not finish on src"
    assert s_dst.synchronize() and new.finished
    np.testing.assert_array_equal(
        np.asarray(new.buffer("State").copy_to_host()),
        np.asarray(ref_buf.copy_to_host()),
        err_msg=f"{src}->{dst} async migration not bit-identical")
    # the migrated buffer adopted the source uid: identity is stable
    # across hops
    assert new.buffer("State").uid == buf.uid


def test_migrate_onto_chosen_destination_stream():
    prog, oracle = suite.persistent_counter()
    init = RNG.normal(size=64).astype(np.float32)
    s_src = HetSession("vectorized", cache=TranslationCache())
    s_dst = HetSession("interp", cache=TranslationCache())
    fn = s_src.load(prog).function()
    s_dst.load(prog)
    buf = s_src.alloc(64).copy_from_host(init)
    rec = fn.launch_async(2, 32, {"State": buf, "iters": 6})
    s_src.step(2)
    target = s_dst.stream()
    new = migrate(rec, s_src, s_dst, "persistent_counter", stream=target)
    assert new.stream is target
    assert s_dst.synchronize() and new.finished
    np.testing.assert_allclose(
        new.buffer("State").copy_to_host(),
        oracle({"State": init.copy(), "iters": 6})["State"],
        atol=1e-4, rtol=1e-4)
    assert s_dst.stats["last_migration"]["payload_bytes"] > 0
