"""Pipeline-parallelism correctness: GPipe schedule over a mesh axis must
match sequential layer application (subprocess with 4 host devices)."""
import json
import os
import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json
import jax
import jax.numpy as jnp
import numpy as np
from repro.parallel.pipeline import pipeline_apply

mesh = jax.make_mesh((4,), ("stage",))
S, M, D = 4, 6, 16
rng = np.random.default_rng(0)
Ws = jnp.asarray(rng.normal(size=(S, D, D)) * 0.3, jnp.float32)
bs = jnp.asarray(rng.normal(size=(S, D)) * 0.1, jnp.float32)
mbs = jnp.asarray(rng.normal(size=(M, 8, D)), jnp.float32)

def stage_fn(params, x):
    W, b = params
    return jnp.tanh(x @ W + b)

out = pipeline_apply(stage_fn, mesh, "stage", (Ws, bs), mbs)

# sequential reference
ref = mbs
for i in range(S):
    ref = jnp.tanh(ref @ Ws[i] + bs[i])

ok = bool(np.allclose(np.asarray(out), np.asarray(ref), atol=1e-5))
print(json.dumps({"match": ok,
                  "max_err": float(np.abs(np.asarray(out) - np.asarray(ref)).max())}))
"""


def test_gpipe_matches_sequential():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath("src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", SCRIPT],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["match"], res
