"""Driver-style object API (paper §4.3 as a driver API): Module/Function
typed metadata, DeviceBuffer in-place semantics, the stats split, the
async-writeback regression, and restore-to-a-chosen-stream with buffer
identity."""
import numpy as np
import pytest

from repro.core import (DeviceBuffer, Function, HetSession, Module,
                        TranslationCache)
from repro.core import kernels_suite as suite

RNG = np.random.default_rng(7)


def _vadd_session(backend="vectorized"):
    s = HetSession(backend, cache=TranslationCache())
    fn = s.load(suite.vadd()[0]).function()
    return s, fn


# ---------------------------------------------------------------------------
# Module / Function object model
# ---------------------------------------------------------------------------

def test_module_function_typed_metadata():
    s = HetSession("interp")
    mod = s.load(suite.vadd()[0])
    assert isinstance(mod, Module)
    assert mod.functions() == ("vadd",)
    fn = mod.function("vadd")
    assert isinstance(fn, Function)
    assert fn is mod.function()          # single entry: name optional
    kinds = {p.name: (p.kind, p.dtype) for p in fn.params}
    assert kinds == {"A": ("buffer", "f32"), "B": ("buffer", "f32"),
                     "C": ("buffer", "f32"), "n": ("scalar", "i32")}
    assert fn.param("n").kind == "scalar"
    with pytest.raises(KeyError):
        fn.param("nope")
    with pytest.raises(KeyError):
        mod.function("nope")


def test_multi_entry_module_requires_name():
    s = HetSession("interp")
    mod = s.load([suite.vadd()[0], suite.saxpy()[0]])
    assert set(mod.functions()) == {"vadd", "saxpy"}
    with pytest.raises(ValueError, match="multiple entry points"):
        mod.function()
    assert mod.function("saxpy").name == "saxpy"


def test_single_entry_module_acts_as_function():
    s = HetSession("vectorized", cache=TranslationCache())
    mod = s.load(suite.vadd()[0])
    a = s.alloc(64).copy_from_host(RNG.normal(size=64).astype(np.float32))
    b = s.alloc(64).copy_from_host(RNG.normal(size=64).astype(np.float32))
    c = s.alloc(64)
    mod.launch(2, 32, {"A": a, "B": b, "C": c, "n": 64})
    np.testing.assert_allclose(c.copy_to_host(),
                               a.copy_to_host() + b.copy_to_host(),
                               atol=1e-6)
    assert [p.name for p in mod.params] == ["A", "B", "C", "n"]


# ---------------------------------------------------------------------------
# DeviceBuffer: typed handles, explicit transfers, in-place mutation
# ---------------------------------------------------------------------------

def test_alloc_and_transfers():
    s = HetSession("interp")
    buf = s.alloc(16, "f32")
    assert isinstance(buf, DeviceBuffer)
    assert buf.size == 16 and buf.dtype == "f32"
    assert buf.np_dtype == np.float32
    host2d = np.arange(16, dtype=np.float32).reshape(4, 4)
    buf.copy_from_host(host2d)           # multi-dim host flattens
    np.testing.assert_array_equal(buf.copy_to_host(),
                                  host2d.reshape(-1))
    out = buf.copy_to_host()
    out[:] = 0                           # defensive copy: no aliasing
    assert buf.copy_to_host()[1] == 1.0
    # multi-dim alloc shapes flatten (device memory is linear)
    assert s.alloc((4, 8), np.int32).size == 32
    with pytest.raises(ValueError, match="elements"):
        buf.copy_from_host(np.zeros(5, np.float32))
    buf.fill(3.0)
    assert (buf.copy_to_host() == 3.0).all()
    buf.free()
    with pytest.raises(ValueError, match="freed"):
        buf.copy_to_host()


def test_launch_mutates_buffer_in_place():
    s, fn = _vadd_session()
    A = RNG.normal(size=64).astype(np.float32)
    B = RNG.normal(size=64).astype(np.float32)
    a, b = s.alloc(64).copy_from_host(A), s.alloc(64).copy_from_host(B)
    c = s.alloc(64)
    backing = c.data
    fn.launch(2, 32, {"A": a, "B": b, "C": c, "n": 64})
    assert c.data is backing, "in-place: same backing array, no rebind"
    np.testing.assert_allclose(c.copy_to_host(), A + B, atol=1e-6)
    # inputs untouched
    np.testing.assert_array_equal(a.copy_to_host(), A)


def test_typed_binding_errors():
    s, fn = _vadd_session()
    a = s.alloc(64)
    b = s.alloc(64)
    c = s.alloc(64)
    ok = {"A": a, "B": b, "C": c, "n": 64}
    with pytest.raises(TypeError, match="DeviceBuffer"):
        fn.launch_async(2, 32, {**ok, "A": np.zeros(64, np.float32)})
    with pytest.raises(TypeError, match="scalar"):
        fn.launch_async(2, 32, {**ok, "n": s.alloc(1)})
    with pytest.raises(TypeError, match="dtype"):
        fn.launch_async(2, 32, {**ok, "C": s.alloc(64, np.int32)})
    with pytest.raises(ValueError, match="missing argument"):
        fn.launch_async(2, 32, {"A": a, "B": b, "C": c})
    with pytest.raises(ValueError, match="unknown argument"):
        fn.launch_async(2, 32, {**ok, "typo": 1})
    other = HetSession("vectorized", cache=TranslationCache())
    with pytest.raises(ValueError, match="different session"):
        fn.launch_async(2, 32, {**ok, "A": other.alloc(64)})
    freed = s.alloc(64)
    freed.free()
    with pytest.raises(ValueError, match="freed"):
        fn.launch_async(2, 32, {**ok, "A": freed})


def test_same_stream_dataflow_cuda_semantics():
    """A launch only binds its buffers when prior same-stream work is
    done — so back-to-back async launches chain through a DeviceBuffer
    exactly like CUDA stream ordering."""
    s, fn = _vadd_session()
    A = RNG.normal(size=64).astype(np.float32)
    B = RNG.normal(size=64).astype(np.float32)
    a, b = s.alloc(64).copy_from_host(A), s.alloc(64).copy_from_host(B)
    c, e = s.alloc(64), s.alloc(64)
    fn.launch_async(2, 32, {"A": a, "B": b, "C": c, "n": 64})
    # second launch reads C — enqueued before the first ran a segment
    fn.launch_async(2, 32, {"A": c, "B": c, "C": e, "n": 64})
    assert s.synchronize()
    np.testing.assert_allclose(e.copy_to_host(), 2 * (A + B), atol=1e-5)


# ---------------------------------------------------------------------------
# Satellite: async-writeback regression (the old surface was lossy)
# ---------------------------------------------------------------------------

def test_async_writeback_no_longer_lossy():
    """Old bug: ``device_synchronize()`` completed engines but never wrote
    results back, so a non-blocking launch's output silently vanished.
    Under DeviceBuffer in-place semantics the writeback is part of launch
    completion — the shim's sync must surface the results."""
    s = HetSession("vectorized", cache=TranslationCache())
    prog, _ = suite.vadd()
    A = RNG.normal(size=64).astype(np.float32)
    B = RNG.normal(size=64).astype(np.float32)
    with pytest.warns(DeprecationWarning):
        s.load_kernel(prog)
        s.gpu_malloc("A", 64)
        s.gpu_malloc("B", 64)
        s.gpu_malloc("C", 64)
        s.memcpy_h2d("A", A)
        s.memcpy_h2d("B", B)
        rec = s.launch("vadd", grid=2, block=32, args={"n": 64},
                       blocking=False)
        assert not rec.finished
        s.device_synchronize()
        assert rec.finished
        np.testing.assert_allclose(s.memcpy_d2h("C"), A + B, atol=1e-6)


def test_explicitly_passed_session_buffer_gets_writeback():
    """Old bug #2: ``_writeback`` skipped any buffer passed explicitly in
    ``args`` even when it *was* the session buffer."""
    s = HetSession("vectorized", cache=TranslationCache())
    prog, _ = suite.vadd()
    A = RNG.normal(size=64).astype(np.float32)
    B = RNG.normal(size=64).astype(np.float32)
    with pytest.warns(DeprecationWarning):
        s.load_kernel(prog)
        s.gpu_malloc("A", 64)
        s.gpu_malloc("B", 64)
        cbuf = s.gpu_malloc("C", 64)
        s.memcpy_h2d("A", A)
        s.memcpy_h2d("B", B)
        # pass the session's own C buffer explicitly — previously lossy
        s.launch("vadd", grid=2, block=32, args={"n": 64, "C": cbuf})
        np.testing.assert_allclose(s.memcpy_d2h("C"), A + B, atol=1e-6)


def test_explicit_foreign_array_is_never_mutated():
    """A raw host array passed explicitly keeps copy-in semantics: the
    caller's array must not be mutated behind their back (oracles are
    routinely fed the same args dict)."""
    s = HetSession("vectorized", cache=TranslationCache())
    prog, _ = suite.vadd()
    A = RNG.normal(size=64).astype(np.float32)
    B = RNG.normal(size=64).astype(np.float32)
    mine = np.zeros(64, np.float32)
    with pytest.warns(DeprecationWarning):
        s.load_kernel(prog)
        s.gpu_malloc("C", 64)       # session buffer with the same name
        rec = s.launch("vadd", grid=2, block=32,
                       args={"A": A, "B": B, "C": mine, "n": 64})
    np.testing.assert_array_equal(mine, np.zeros(64, np.float32))
    # session buffer untouched too (the kernel wrote to its own copy)
    with pytest.warns(DeprecationWarning):
        np.testing.assert_array_equal(s.memcpy_d2h("C"),
                                      np.zeros(64, np.float32))
    # results remain readable through the record
    np.testing.assert_allclose(np.asarray(rec.engine.result("C")),
                               A + B, atol=1e-6)


# ---------------------------------------------------------------------------
# Satellite: stats split (translate_ms vs launch_ms)
# ---------------------------------------------------------------------------

def test_stats_split_translate_vs_launch():
    s, fn = _vadd_session()
    a = s.alloc(64).copy_from_host(RNG.normal(size=64).astype(np.float32))
    b = s.alloc(64).copy_from_host(RNG.normal(size=64).astype(np.float32))
    c = s.alloc(64)
    fn.launch(2, 32, {"A": a, "B": b, "C": c, "n": 64})
    assert s.stats["launches"] == 1
    assert s.stats["launch_ms"] > 0.0
    assert s.stats["translate_ms"] > 0.0, "cold launch must translate"
    # deprecated alias mirrors the *translation* number now, not the old
    # launch-inclusive mistiming
    assert s.stats["translation_ms"] == s.stats["translate_ms"]
    cold_translate = s.stats["translate_ms"]
    fn.launch(2, 32, {"A": a, "B": b, "C": c, "n": 64})
    assert s.stats["launches"] == 2
    # warm launch: no new translation, but launch work still accrues
    assert s.stats["translate_ms"] == pytest.approx(cold_translate)
    assert s.stats["segments_executed"] >= 2


# ---------------------------------------------------------------------------
# Satellite: restore lands on a caller-chosen stream; buffer identity
# ---------------------------------------------------------------------------

def test_restore_on_chosen_stream_and_buffer_identity():
    s = HetSession("vectorized", cache=TranslationCache())
    prog, oracle = suite.persistent_counter()
    fn = s.load(prog).function()
    init = RNG.normal(size=64).astype(np.float32)
    state = s.alloc(64).copy_from_host(init)
    rec = fn.launch_async(2, 32, {"State": state, "iters": 6})
    s.step(3)                                   # in flight, at a barrier
    assert rec.started and not rec.finished
    blob = s.checkpoint(rec)
    rec.cancel()

    other = s.stream()
    restored = s.restore("persistent_counter", blob, stream=other)
    assert restored.stream is other, "restore must honour the stream"
    # buffer identity: the restored launch re-bound the *same* handle
    assert restored.buffer("State") is state
    # synchronize() sweeps all streams, not just stream 0
    assert s.synchronize()
    assert restored.finished
    expect = oracle({"State": init.copy(), "iters": 6})["State"]
    np.testing.assert_allclose(state.copy_to_host(), expect,
                               atol=1e-4, rtol=1e-4)


def test_restore_rejects_foreign_stream():
    s = HetSession("vectorized", cache=TranslationCache())
    other = HetSession("vectorized", cache=TranslationCache())
    fn = s.load(suite.persistent_counter()[0]).function()
    state = s.alloc(64).copy_from_host(np.ones(64, np.float32))
    rec = fn.launch_async(2, 32, {"State": state, "iters": 4})
    s.step(2)
    blob = s.checkpoint(rec)
    rec.cancel()
    with pytest.raises(ValueError, match="different session"):
        s.restore(fn, blob, stream=other.stream())


def test_nonhetir_dtype_buffer_rejected_by_typed_binding():
    """alloc() tolerates non-hetIR dtypes for host staging (the legacy
    memcpy surface needs them), but the typed Function binding rejects
    them."""
    s, fn = _vadd_session()
    staging = s.alloc(64, np.float64)
    assert staging.dtype is None and staging.np_dtype == np.float64
    ok = {"A": s.alloc(64), "B": s.alloc(64), "C": s.alloc(64), "n": 64}
    with pytest.raises(TypeError, match="dtype"):
        fn.launch_async(2, 32, {**ok, "A": staging})


def test_restore_default_and_legacy_int_stream():
    s = HetSession("vectorized", cache=TranslationCache())
    prog, _ = suite.persistent_counter()
    fn = s.load(prog).function()
    state = s.alloc(64).copy_from_host(np.ones(64, np.float32))
    rec = fn.launch_async(2, 32, {"State": state, "iters": 4})
    s.step(2)
    blob = s.checkpoint(rec)
    rec.cancel()
    r_def = s.restore(fn, blob)                  # Function + default stream
    assert r_def.stream is s.default_stream
    r_def.cancel()
    r_int = s.restore("persistent_counter", blob, stream=0)  # legacy int
    assert r_int.stream is s.default_stream
    assert s.synchronize() and r_int.finished


def test_checkpoint_of_queued_launch_refuses_stale_binding():
    """A launch queued behind other same-stream work has no state yet —
    materializing it early (e.g. via checkpoint/migrate) would snapshot
    its buffers *before* the predecessor's writes.  It must refuse."""
    s = HetSession("vectorized", cache=TranslationCache())
    fn = s.load(suite.persistent_counter()[0]).function()
    buf = s.alloc(64).copy_from_host(np.ones(64, np.float32))
    fn.launch_async(2, 32, {"State": buf, "iters": 4})
    rec2 = fn.launch_async(2, 32, {"State": buf, "iters": 4})
    with pytest.raises(RuntimeError, match="queued behind"):
        s.checkpoint(rec2)
    assert not rec2.started
    # once the predecessor finishes, chained results stay correct
    assert s.synchronize()
    oracle = suite.persistent_counter()[1]
    once = oracle({"State": np.ones(64, np.float32), "iters": 4})["State"]
    twice = oracle({"State": once.copy(), "iters": 4})["State"]
    np.testing.assert_allclose(buf.copy_to_host(), twice,
                               atol=1e-4, rtol=1e-4)


def test_restore_legacy_int_stream_history_key():
    """restore(stream=<int>) must file the record in the legacy
    ``_streams`` history under the caller's int id, matching launch()."""
    s = HetSession("vectorized", cache=TranslationCache())
    prog, _ = suite.persistent_counter()
    with pytest.warns(DeprecationWarning):
        s.load_kernel(prog)
        rec = s.launch("persistent_counter", grid=2, block=32,
                       args={"State": np.ones(64, np.float32),
                             "iters": 4},
                       stream=3, blocking=False)
    rec.engine.run(max_segments=2)
    blob = s.checkpoint(rec)
    rec.cancel()
    restored = s.restore("persistent_counter", blob, stream=3)
    assert s._streams[3][-1] is restored
    assert s.synchronize() and restored.finished


def test_launch_record_future_surface():
    s, fn = _vadd_session()
    a = s.alloc(64).copy_from_host(RNG.normal(size=64).astype(np.float32))
    b = s.alloc(64).copy_from_host(RNG.normal(size=64).astype(np.float32))
    c = s.alloc(64)
    rec = fn.launch_async(2, 32, {"A": a, "B": b, "C": c, "n": 64})
    assert not rec.done() and not rec.started
    assert rec.wait() is True
    assert rec.done() and rec.finished
    assert rec.buffer("C") is c
    with pytest.raises(KeyError):
        rec.buffer("n")
