"""hetIR-generated kernels (kernels/hetir_gen) + HetSession API coverage."""
import numpy as np
import pytest

from repro.core import HetSession
from repro.core import kernels_suite as suite
from repro.kernels.hetir_gen import het_kernel
from repro.kernels.hetir_gen.ref import het_kernel_ref


def test_hetir_generated_pallas_kernel_matches_interp_oracle():
    prog, _ = suite.saxpy()
    prog_ref, _ = suite.saxpy()
    rng = np.random.default_rng(0)
    args = {"X": rng.normal(size=128).astype(np.float32),
            "Y": rng.normal(size=128).astype(np.float32),
            "n": 128, "a": 0.7}
    out = het_kernel(prog, grid=4, block=32)(**args)
    ref = het_kernel_ref(prog_ref, grid=4, block=32)(**args)
    np.testing.assert_allclose(out["Y"], ref["Y"], atol=1e-5, rtol=1e-5)


def test_session_memory_api():
    s = HetSession("vectorized")
    prog, _ = suite.vadd()
    s.load_kernel(prog)
    rng = np.random.default_rng(2)
    A = rng.normal(size=64).astype(np.float32)
    B = rng.normal(size=64).astype(np.float32)
    s.gpu_malloc("A", 64)
    s.gpu_malloc("B", 64)
    s.gpu_malloc("C", 64)
    s.memcpy_h2d("A", A)
    s.memcpy_h2d("B", B)
    s.launch("vadd", grid=2, block=32, args={"n": 64})
    np.testing.assert_allclose(s.memcpy_d2h("C"), A + B, atol=1e-6)
    assert s.stats["launches"] == 1


def test_engine_rejects_missing_args():
    from repro.core import Engine, get_backend
    prog, _ = suite.vadd()
    with pytest.raises(ValueError, match="missing"):
        Engine(prog, get_backend("vectorized"), 2, 32,
               {"A": np.zeros(64, np.float32)})


def test_zero_trip_loop():
    from repro.core import Engine, get_backend
    prog, _ = suite.persistent_counter()
    args = {"State": np.ones(64, np.float32), "iters": 0}
    eng = Engine(prog, get_backend("vectorized"), 2, 32, dict(args))
    assert eng.run()
    np.testing.assert_array_equal(eng.result("State"), np.ones(64))
