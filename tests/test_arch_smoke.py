"""Per-architecture smoke tests: reduced same-family configs, one
forward/train step + one prefill/decode step on CPU, asserting shapes and
finiteness (no NaNs)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import (decode_step, forward_train, init_caches,
                          init_params, prefill)
from repro.models.registry import param_count

ARCHS = ["llama3-405b", "llama3.2-3b", "h2o-danube-3-4b", "glm4-9b",
         "internvl2-2b", "recurrentgemma-2b", "mixtral-8x22b",
         "granite-moe-3b-a800m", "xlstm-125m", "whisper-large-v3"]

B, S = 2, 32


def make_batch(cfg, rng):
    if cfg.encoder_decoder:
        return {"enc_embeds": jnp.asarray(
                    rng.normal(size=(B, S, cfg.d_model)) * 0.02,
                    jnp.dtype(cfg.compute_dtype)),
                "tokens": jnp.asarray(
                    rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)}
    if cfg.frontend == "patch":
        F = cfg.frontend_tokens
        return {"embeds": jnp.asarray(
                    rng.normal(size=(B, F, cfg.d_model)) * 0.02,
                    jnp.dtype(cfg.compute_dtype)),
                "tokens": jnp.asarray(
                    rng.integers(0, cfg.vocab_size, (B, S - F)),
                    jnp.int32)}
    return {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)}


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch):
    cfg = configs.get_smoke_config(arch)
    rng = np.random.default_rng(0)
    params = init_params(jax.random.key(0), cfg)
    batch = make_batch(cfg, rng)

    loss, grads = jax.jit(jax.value_and_grad(
        lambda p, b: forward_train(p, b, cfg)))(params, batch)
    assert loss.shape == ()
    assert jnp.isfinite(loss), f"{arch}: loss not finite"
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    assert jnp.isfinite(gnorm), f"{arch}: grad not finite"
    assert float(gnorm) > 0.0, f"{arch}: zero gradient"


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_smoke(arch):
    cfg = configs.get_smoke_config(arch)
    rng = np.random.default_rng(1)
    params = init_params(jax.random.key(1), cfg)
    batch = make_batch(cfg, rng)

    logits, caches = jax.jit(
        lambda p, b: prefill(p, b, cfg, cache_len=S + 8))(params, batch)
    assert logits.shape == (B, 1, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: prefill logits NaN"

    tok = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, 1)), jnp.int32)
    pos = jnp.asarray(S if cfg.frontend != "patch" else S, jnp.int32)
    logits2, caches2 = jax.jit(
        lambda p, t, c, q: decode_step(p, t, c, q, cfg))(
        params, tok, caches, pos)
    assert logits2.shape == (B, 1, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits2).all()), f"{arch}: decode logits NaN"
    # cache structure preserved
    assert jax.tree.structure(caches) == jax.tree.structure(caches2)


@pytest.mark.parametrize("arch", ["llama3.2-3b", "recurrentgemma-2b",
                                  "xlstm-125m", "h2o-danube-3-4b"])
def test_decode_matches_prefill(arch):
    """KV-cache / recurrent-state correctness: decoding token S given a
    prefill of S tokens must equal prefilling S+1 tokens."""
    cfg = configs.get_smoke_config(arch)
    rng = np.random.default_rng(2)
    toks = rng.integers(0, cfg.vocab_size, (B, S + 1))
    b_short = {"tokens": jnp.asarray(toks[:, :S], jnp.int32)}
    b_full = {"tokens": jnp.asarray(toks, jnp.int32)}

    _, caches = prefill(params := init_params(jax.random.key(3), cfg),
                        b_short, cfg, cache_len=S + 1)
    logits_dec, _ = decode_step(params, jnp.asarray(toks[:, S:], jnp.int32),
                                caches, jnp.asarray(S, jnp.int32), cfg)
    logits_ref, _ = prefill(params, b_full, cfg, cache_len=S + 1)
    np.testing.assert_allclose(np.asarray(logits_dec),
                               np.asarray(logits_ref), rtol=2e-3, atol=2e-3)


def test_param_counts_match_published_scale():
    """Full configs should land near their nameplate parameter counts."""
    expect = {
        "llama3-405b": (380e9, 430e9),
        "llama3.2-3b": (3.0e9, 3.9e9),
        "h2o-danube-3-4b": (3.2e9, 4.5e9),
        "glm4-9b": (8.5e9, 10.5e9),
        "internvl2-2b": (1.7e9, 2.4e9),   # LM backbone (ViT is stubbed)
        "recurrentgemma-2b": (2.0e9, 3.4e9),
        "mixtral-8x22b": (130e9, 148e9),
        "granite-moe-3b-a800m": (2.6e9, 3.9e9),
        "xlstm-125m": (0.08e9, 0.22e9),
        "whisper-large-v3": (1.4e9, 1.75e9),
    }
    for arch, (lo, hi) in expect.items():
        n = param_count(configs.get_config(arch))
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B params not in " \
                              f"[{lo/1e9:.1f}B, {hi/1e9:.1f}B]"
