"""Cross-backend portability tests — the paper's §6.1 table as a test suite.

Every kernel in the suite runs on all three backends from the same hetIR
"binary" and must match the independent numpy oracle.
"""
import numpy as np
import pytest

from repro.core import Engine, get_backend
from repro.core import kernels_suite as suite

RNG = np.random.default_rng(0)
BACKENDS = ["interp", "vectorized", "pallas"]


def run(prog, backend, grid, block, args):
    eng = Engine(prog, get_backend(backend), grid, block, dict(args))
    assert eng.run()
    return eng


def check(name, backend, grid, block, args, outs, atol=1e-5, rtol=1e-5):
    prog, oracle = suite.SUITE[name]()
    eng = run(prog, backend, grid, block, args)
    oracle_args = dict(args)
    oracle_args["_num_blocks"] = grid
    oracle_args["_block_size"] = block
    expect = oracle(oracle_args)
    for o in outs:
        np.testing.assert_allclose(eng.result(o), expect[o],
                                   atol=atol, rtol=rtol,
                                   err_msg=f"{name} on {backend}: {o}")


@pytest.mark.parametrize("backend", BACKENDS)
def test_vadd(backend):
    n = 100  # deliberately not a multiple of block size -> predication
    grid, block = 4, 32
    args = {"A": RNG.normal(size=128).astype(np.float32),
            "B": RNG.normal(size=128).astype(np.float32),
            "C": np.zeros(128, np.float32), "n": n}
    check("vadd", backend, grid, block, args, ["C"])


@pytest.mark.parametrize("backend", BACKENDS)
def test_saxpy(backend):
    args = {"X": RNG.normal(size=96).astype(np.float32),
            "Y": RNG.normal(size=96).astype(np.float32),
            "n": 80, "a": 2.5}
    check("saxpy", backend, 3, 32, args, ["Y"])


@pytest.mark.parametrize("backend", BACKENDS)
def test_matmul_tiled(backend):
    M, K, N, TK = 6, 16, 16, 8
    A = RNG.normal(size=(M, K)).astype(np.float32)
    B = RNG.normal(size=(K, N)).astype(np.float32)
    args = {"A": A.reshape(-1), "B": B.reshape(-1),
            "C": np.zeros(M * N, np.float32),
            "K": K, "N": N, "ktiles": K // TK}
    check("matmul_tiled", backend, M, N, args, ["C"], atol=1e-4)


@pytest.mark.parametrize("backend", BACKENDS)
def test_reduction(backend):
    n, grid, block = 100, 4, 32  # block must be power of two
    args = {"A": RNG.normal(size=128).astype(np.float32),
            "Out": np.zeros(1, np.float32), "n": n,
            "log2t": 5}
    check("reduction", backend, grid, block, args, ["Out"], atol=1e-4)


@pytest.mark.parametrize("backend", BACKENDS)
def test_inclusive_scan(backend):
    n, grid, block = 70, 3, 32
    args = {"A": RNG.normal(size=96).astype(np.float32),
            "Out": np.zeros(96, np.float32),
            "BlockSums": np.zeros(3, np.float32), "n": n}
    check("inclusive_scan", backend, grid, block, args,
          ["Out", "BlockSums"], atol=1e-4)


@pytest.mark.parametrize("backend", BACKENDS)
def test_bitcount_vote(backend):
    args = {"A": RNG.normal(size=128).astype(np.float32),
            "Out": np.zeros(4, np.float32), "n": 120, "thresh": 0.3}
    check("bitcount_vote", backend, 4, 32, args, ["Out"])


@pytest.mark.parametrize("backend", BACKENDS)
def test_montecarlo_pi(backend):
    args = {"Count": np.zeros(1, np.float32)}
    check("montecarlo_pi", backend, 2, 32, args, ["Count"])
    # sanity: the estimate should be near pi
    prog, _ = suite.SUITE["montecarlo_pi"]()
    eng = run(prog, backend, 2, 32, {"Count": np.zeros(1, np.float32)})
    est = 4.0 * eng.result("Count")[0] / (2 * 32 * 16)
    assert abs(est - np.pi) < 0.4


@pytest.mark.parametrize("backend", BACKENDS)
def test_nn_layer(backend):
    M, K, block = 4, 48, 16
    args = {"W": RNG.normal(size=(M, K)).astype(np.float32).reshape(-1),
            "X": RNG.normal(size=K).astype(np.float32),
            "Bias": RNG.normal(size=M).astype(np.float32),
            "Out": np.zeros(M, np.float32),
            "K": K, "kchunks": K // block}
    check("nn_layer", backend, M, block, args, ["Out"], atol=1e-4)


@pytest.mark.parametrize("backend", BACKENDS)
def test_stencil(backend):
    args = {"A": RNG.normal(size=64).astype(np.float32),
            "Out": np.zeros(64, np.float32), "n": 50}
    check("stencil_1d", backend, 2, 32, args, ["Out"])


@pytest.mark.parametrize("backend", BACKENDS)
def test_persistent_counter(backend):
    args = {"State": RNG.normal(size=64).astype(np.float32), "iters": 5}
    check("persistent_counter", backend, 2, 32, args, ["State"], atol=1e-4)


@pytest.mark.parametrize("backend", BACKENDS)
def test_dot_product(backend):
    args = {"A": RNG.normal(size=64).astype(np.float32),
            "B": RNG.normal(size=64).astype(np.float32),
            "Out": np.zeros(1, np.float32), "n": 60}
    check("dot_product", backend, 2, 32, args, ["Out"], atol=1e-4)


@pytest.mark.parametrize("name", sorted(suite.EXAMPLES))
def test_backends_agree_bitwise_full_suite(name):
    """All three backends implement one rounding contract — strict
    IEEE-sequential, one rounding per op, collectives folded in lane
    order (`semantics._pin` pins every inexact float op against XLA's
    graph-shape-dependent rewrites).  Every suite kernel must therefore
    be *bit-identical* across interp, vectorized, and pallas — no
    exemptions."""
    results = {}
    for backend in BACKENDS:
        prog, _oracle, grid, block, args, outs = suite.example_launch(
            name, rng=np.random.default_rng(0))
        eng = run(prog, backend, grid, block,
                  {k: np.array(v, copy=True) for k, v in args.items()})
        results[backend] = {o: np.asarray(eng.result(o)) for o in outs}
    ref = results["interp"]
    for backend in BACKENDS[1:]:
        for o, expect in ref.items():
            np.testing.assert_array_equal(
                results[backend][o], expect,
                err_msg=f"{name}.{o}: {backend} not bit-identical to interp")
