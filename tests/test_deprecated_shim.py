"""The deprecated string-keyed shim: every old-surface call raises
DeprecationWarning but stays functionally correct on top of the
driver-style object model (old→new table in docs/API.md)."""
import warnings

import numpy as np
import pytest

from repro.core import HetSession, TranslationCache
from repro.core import kernels_suite as suite

RNG = np.random.default_rng(3)


def _fresh():
    return HetSession("vectorized", cache=TranslationCache())


def test_every_legacy_method_warns():
    prog, _ = suite.vadd()
    s = _fresh()
    with pytest.warns(DeprecationWarning, match="load_kernel"):
        s.load_kernel(prog)
    with pytest.warns(DeprecationWarning, match="gpu_malloc"):
        s.gpu_malloc("A", 64)
    with pytest.warns(DeprecationWarning, match="memcpy_h2d"):
        s.memcpy_h2d("A", np.ones(64, np.float32))
    with pytest.warns(DeprecationWarning, match="memcpy_d2h"):
        s.memcpy_d2h("A")
    with pytest.warns(DeprecationWarning, match=r"launch\(kernel"):
        s.gpu_malloc("B", 64)
        s.gpu_malloc("C", 64)
        s.launch("vadd", grid=2, block=32, args={"n": 64})
    with pytest.warns(DeprecationWarning, match="device_synchronize"):
        s.device_synchronize()


def test_new_surface_does_not_warn():
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        s = _fresh()
        fn = s.load(suite.vadd()[0]).function()
        a = s.alloc(64).copy_from_host(np.ones(64, np.float32))
        b = s.alloc(64).copy_from_host(np.ones(64, np.float32))
        c = s.alloc(64)
        rec = fn.launch_async(2, 32, {"A": a, "B": b, "C": c, "n": 64},
                              stream=s.stream())
        s.synchronize()
        s.restore(fn, s.checkpoint(rec))
        s.synchronize()
    assert (c.copy_to_host() == 2.0).all()


def test_legacy_end_to_end_matches_new_api():
    """The 16-kernel-era flow through the shim produces results identical
    to the same launch through the object API."""
    prog, _ = suite.saxpy()
    X = RNG.normal(size=128).astype(np.float32)
    Y = RNG.normal(size=128).astype(np.float32)

    old = _fresh()
    with pytest.warns(DeprecationWarning):
        old.load_kernel(prog)
        old.gpu_malloc("X", 128)
        old.gpu_malloc("Y", 128)
        old.memcpy_h2d("X", X)
        old.memcpy_h2d("Y", Y)
        old.launch("saxpy", grid=4, block=32, args={"n": 128, "a": 0.7})
        got_old = old.memcpy_d2h("Y")

    new = _fresh()
    fn = new.load(suite.saxpy()[0]).function()
    x = new.alloc(128).copy_from_host(X)
    y = new.alloc(128).copy_from_host(Y)
    fn.launch(4, 32, {"X": x, "Y": y, "n": 128, "a": 0.7})
    np.testing.assert_array_equal(got_old, y.copy_to_host())
    assert old.stats["launches"] == new.stats["launches"] == 1


def test_legacy_stream_history_view_preserved():
    """Pre-redesign callers poke ``session._streams[sid][-1].engine`` —
    the per-stream launch-history view must survive the redesign."""
    s = _fresh()
    prog, _ = suite.vadd()
    A = RNG.normal(size=64).astype(np.float32)
    with pytest.warns(DeprecationWarning):
        s.load_kernel(prog)
        s.gpu_malloc("A", 64)
        s.gpu_malloc("B", 64)
        s.gpu_malloc("C", 64)
        s.memcpy_h2d("A", A)
        s.launch("vadd", grid=2, block=32, args={"n": 64})
    assert len(s._streams[0]) == 1
    rec = s._streams[0][-1]
    assert rec.finished
    np.testing.assert_allclose(np.asarray(rec.engine.result("C")), A,
                               atol=1e-6)


def test_legacy_nonblocking_launch_engine_is_eager():
    """Old callers drive ``rec.engine.run(max_segments=...)`` right after
    a non-blocking launch — the shim must bind eagerly (the lazy binding
    is a new-surface behavior)."""
    s = _fresh()
    prog, _ = suite.persistent_counter()
    with pytest.warns(DeprecationWarning):
        s.load_kernel(prog)
        rec = s.launch("persistent_counter", grid=2, block=32,
                       args={"State": RNG.normal(size=64).astype(
                           np.float32), "iters": 6},
                       blocking=False)
    assert rec.started
    assert not rec.engine.run(max_segments=3)
    with pytest.warns(DeprecationWarning):
        s.device_synchronize()
    assert rec.finished


def test_legacy_any_dtype_and_shape_preserved():
    """The old memory surface accepted any numpy dtype and preserved
    multi-dim shapes; the shim must too (the typed restrictions belong to
    the new DeviceBuffer surface only)."""
    s = _fresh()
    with pytest.warns(DeprecationWarning):
        buf = s.gpu_malloc("A", (8, 16), dtype=np.float64)
        assert buf.shape == (8, 16) and buf.dtype == np.float64
        buf[2, 3] = 7.5                   # shape-intact view, writable
        assert s.memcpy_d2h("A")[2, 3] == 7.5
        s.memcpy_h2d("B", np.ones((4, 4), np.float32))
        assert s.memcpy_d2h("B").shape == (4, 4)


def test_legacy_dtype_mismatch_writeback_rebinds():
    """Old semantics: _writeback rebound the session buffer to the
    kernel's result array even when the gpu_malloc dtype differed — the
    shim must not crash on the cast, it must rebind."""
    s = _fresh()
    prog, _ = suite.vadd()
    A = RNG.normal(size=64).astype(np.float32)
    with pytest.warns(DeprecationWarning):
        s.load_kernel(prog)
        s.gpu_malloc("A", 64)
        s.gpu_malloc("B", 64)
        s.gpu_malloc("C", 64, dtype=np.int32)   # mismatched vs f32 param
        s.memcpy_h2d("A", A)
        s.launch("vadd", grid=2, block=32, args={"n": 64})
        out = s.memcpy_d2h("C")
    assert out.dtype == np.float32              # rebound, old behavior
    np.testing.assert_allclose(out, A, atol=1e-6)


def test_legacy_unknown_kernel_and_missing_arg_errors():
    s = _fresh()
    with pytest.warns(DeprecationWarning):
        with pytest.raises(KeyError):
            s.launch("nope", grid=1, block=1, args={})
        s.load_kernel(suite.vadd()[0])
        with pytest.raises(ValueError, match="missing argument"):
            s.launch("vadd", grid=2, block=32, args={"n": 64})
