"""Parallelism-feature tests: sharding-rule structure, divisibility
fallbacks, and true expert-parallelism on a divisible mesh (subprocess)."""
import json
import os
import subprocess
import sys

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.configs.base import SHAPES, ParallelCfg, default_parallel
from repro.models import registry as R
from repro.parallel import MeshRules


def test_param_specs_match_param_tree_structure():
    for arch in configs.list_archs():
        cfg = configs.get_smoke_config(arch)
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        rules = MeshRules(cfg, ParallelCfg(), mesh)
        params = R.abstract_params(cfg)
        specs = rules.param_specs()
        ps = jax.tree.structure(params)
        ss = jax.tree.structure(specs,
                                is_leaf=lambda x: isinstance(x, P))
        assert ps == ss, f"{arch}: spec tree != param tree"


def test_divisibility_fallback_never_invalid():
    """Every spec entry must divide its dim by the mesh axis product."""
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    # simulate production sizes through the axis-size table
    for arch in configs.list_archs():
        cfg = configs.get_config(arch)
        rules = MeshRules(cfg, default_parallel(cfg, SHAPES["train_4k"]),
                          mesh)
        rules.axis_size = {"data": 16, "model": 16}
        rules.fsdp = ("data",)
        rules.tp = "model"
        params = R.abstract_params(cfg)
        specs = rules.param_specs()
        flat_p = jax.tree.leaves(params)
        flat_s = jax.tree.leaves(specs,
                                 is_leaf=lambda x: isinstance(x, P))
        for leaf, spec in zip(flat_p, flat_s):
            for dim, entry in zip(leaf.shape, spec):
                if entry is None:
                    continue
                n = 1
                for a in (entry if isinstance(entry, tuple)
                          else (entry,)):
                    n *= rules.axis_size[a]
                assert dim % n == 0, \
                    f"{arch}: dim {dim} not divisible by {entry} ({n})"


EP_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses, json
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro import configs
from repro.configs.base import ParallelCfg
from repro.models import layers as L
from repro.parallel import MeshRules

mesh = jax.make_mesh((2, 4), ("data", "model"))
cfg = configs.get_smoke_config("mixtral-8x22b")
# 4 experts over the 4-wide model axis -> TRUE expert parallelism
cfg = dataclasses.replace(cfg, moe_impl="grouped")
assert cfg.moe.n_experts == 4
rules = MeshRules(cfg, ParallelCfg(fsdp_axes=("data",)), mesh)
assert rules._ep_axis(cfg.moe.n_experts) == "model", "EP axis not chosen"

rng = np.random.default_rng(0)
B, S, D = 4, 16, cfg.d_model
x = jnp.asarray(rng.normal(size=(B, S, D)) * 0.3, jnp.float32)
p = L.init_moe(jax.random.key(0), cfg, jnp.float32)

# unsharded reference
ref = L.moe_ffn_grouped(x, p, cfg)

# sharded: params EP-sharded on the expert dim, batch over data
pspecs = {k: P("model", None, None) if v.ndim == 3 else P(None)
          for k, v in p.items()}
ns = lambda s: NamedSharding(mesh, s)
p_sh = {k: jax.device_put(v, ns(pspecs[k])) for k, v in p.items()}
x_sh = jax.device_put(x, ns(P("data", None, None)))

fn = jax.jit(lambda xx, pp: L.moe_ffn_grouped(xx, pp, cfg, ac=rules.ac))
with mesh:
    out = fn(x_sh, p_sh)
ok = bool(np.allclose(np.asarray(out), np.asarray(ref), atol=1e-4,
                      rtol=1e-4))
# check an all-to-all or expert-routing collective exists in the HLO
with mesh:
    hlo = jax.jit(lambda xx, pp: L.moe_ffn_grouped(xx, pp, cfg,
                                                   ac=rules.ac)) \
        .lower(x_sh, p_sh).compile().as_text()
has_coll = ("all-to-all" in hlo) or ("all-gather" in hlo) or \
    ("collective-permute" in hlo) or ("all-reduce" in hlo)
print(json.dumps({"match": ok, "has_collective": has_coll}))
"""


def test_true_expert_parallelism_on_divisible_mesh():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath("src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", EP_SCRIPT],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["match"], res
