"""Validate the loop-weighted HLO analyzer against programs with known
analytic FLOP/collective counts (multi-device via subprocess-free host
platform override is NOT possible here since jax is already initialized, so
single-device checks cover flops/loop weighting and a scripted HLO covers
collectives)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_analysis import analyze_hlo, _shape_bytes


def _compile(fn, *specs):
    return jax.jit(fn).lower(*specs).compile()


def test_matmul_flops_exact():
    M, K, N = 64, 128, 32
    c = _compile(lambda a, b: a @ b,
                 jax.ShapeDtypeStruct((M, K), jnp.float32),
                 jax.ShapeDtypeStruct((K, N), jnp.float32))
    res = analyze_hlo(c.as_text(), 1)
    want = 2 * M * K * N
    assert want <= res["flops"] <= want * 1.1, res["flops"]


def test_scan_multiplies_body_flops():
    M, K = 32, 32
    L = 17

    def f(x, ws):
        def body(c, w):
            return jnp.tanh(c @ w), ()
        y, _ = jax.lax.scan(body, x, ws)
        return y

    c = _compile(f, jax.ShapeDtypeStruct((M, K), jnp.float32),
                 jax.ShapeDtypeStruct((L, K, K), jnp.float32))
    res = analyze_hlo(c.as_text(), 1)
    want = 2 * M * K * K * L
    assert want <= res["flops"] <= want * 1.5, \
        f"{res['flops']} vs {want} (loop weighting broken?)"


def test_nested_scan_weighting():
    M = 16
    L1, L2 = 5, 7

    def f(x, ws):
        def outer(c, w2):
            def inner(ci, w):
                return jnp.tanh(ci @ w), ()
            co, _ = jax.lax.scan(inner, c, w2)
            return co, ()
        y, _ = jax.lax.scan(outer, x, ws)
        return y

    c = _compile(f, jax.ShapeDtypeStruct((M, M), jnp.float32),
                 jax.ShapeDtypeStruct((L1, L2, M, M), jnp.float32))
    res = analyze_hlo(c.as_text(), 1)
    want = 2 * M * M * M * L1 * L2
    assert want <= res["flops"] <= want * 1.5, res["flops"]


def test_collective_ring_model_from_synthetic_hlo():
    hlo = """
HloModule test

ENTRY %main (p0: f32[16,16]) -> f32[16,16] {
  %p0 = f32[16,16]{1,0} parameter(0)
  ROOT %all-reduce.1 = f32[16,16]{1,0} all-reduce(%p0), replica_groups={{0,1,2,3}}, to_apply=%add
}
"""
    res = analyze_hlo(hlo, 4)
    want = 2 * 16 * 16 * 4 * (4 - 1) / 4
    assert res["collectives"]["all-reduce"] == pytest.approx(want)


def test_collective_inside_loop_is_weighted():
    hlo = """
HloModule test

%body (arg: (s32[], f32[8])) -> (s32[], f32[8]) {
  %arg = (s32[], f32[8]) parameter(0)
  %i = s32[] get-tuple-element(%arg), index=0
  %x = f32[8]{0} get-tuple-element(%arg), index=1
  %ag = f32[8]{0} all-gather(%x), replica_groups=[2,8]<=[16], dimensions={0}
  ROOT %t = (s32[], f32[8]) tuple(%i, %ag)
}

%cond (arg: (s32[], f32[8])) -> pred[] {
  %arg = (s32[], f32[8]) parameter(0)
  ROOT %lt = pred[] constant(true)
}

ENTRY %main (p0: (s32[], f32[8])) -> (s32[], f32[8]) {
  %p0 = (s32[], f32[8]) parameter(0)
  ROOT %w = (s32[], f32[8]) while(%p0), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"10"}}
}
"""
    res = analyze_hlo(hlo, 8)
    want = 8 * 4 * (8 - 1) / 8 * 10  # bytes(out) * (n-1)/n * trips
    assert res["collectives"]["all-gather"] == pytest.approx(want)


def test_shape_bytes_tuple():
    assert _shape_bytes("(bf16[4,4], f32[2])") == 4 * 4 * 2 + 2 * 4
