"""Chaos suite for the self-healing fleet (ISSUE 7 acceptance bar).

Every test here spawns real worker *processes* and most of them kill one
with SIGKILL at a named fault point — ``pre-launch``, ``mid-kernel`` (at
a segment boundary), ``post-checkpoint-pre-ack`` — via the in-worker
:class:`~repro.core.fleet.FaultInjector`.  The property under test is the
paper's live-migration claim under failure: the launch must complete on a
surviving worker **bit-identical** to a single-process oracle run, with
zero lost and zero double-acked launches, and the fleet's ``retried`` /
``evacuated`` counters must match the injected schedule exactly.

Marked ``fleet`` and deselected from the tier-1 default run (see
pytest.ini); CI's chaos job runs them with a fixed ``HETGPU_FAULT_SEED``
and a job-level timeout so a wedged fleet fails loudly.
"""
import numpy as np
import pytest

from repro.core.fleet import (FAULT_POINTS, MID_KERNEL,
                              POST_CHECKPOINT_PRE_ACK, PRE_LAUNCH,
                              FleetCoordinator)
from repro.core.kernels_suite import example_launch
from repro.core.runtime import HetSession
from repro.core.serving import ServingFrontEnd

pytestmark = pytest.mark.fleet

KERNELS = ("dyn_matmul", "decode_gemv")
_WAIT = 180.0   # generous per-test fleet deadline; CI adds a job timeout

_oracle_cache = {}


def _example(kernel):
    prog, _oracle, grid, block, args, outs = example_launch(kernel)
    return prog, grid, block, args, outs


def oracle_outputs(kernel):
    """Single-process interp run of the canonical example launch — the
    bit-identity reference every fleet result is compared against."""
    if kernel not in _oracle_cache:
        prog, grid, block, args, outs = _example(kernel)
        sess = HetSession("interp")
        sess.load(prog)
        fn = sess.function(prog.name)
        eng_args = {}
        for p in fn.params:
            v = args[p.name]
            if p.kind == "buffer":
                arr = np.asarray(v)
                db = sess.alloc(arr.size, arr.dtype)
                db.copy_from_host(arr)
                eng_args[p.name] = db
            else:
                eng_args[p.name] = v
        rec = fn.launch_async(grid, block, eng_args)
        assert sess.synchronize()
        _oracle_cache[kernel] = {
            n: rec.buffer(n).copy_to_host() for n in outs}
    return _oracle_cache[kernel]


def assert_bit_identical(ticket, kernel):
    for name, expect in oracle_outputs(kernel).items():
        got = ticket.result(name)
        assert got.dtype == expect.dtype
        assert np.array_equal(got, expect), \
            f"{kernel}.{name} diverged from the single-process oracle"


# ---------------------------------------------------------------------------
# baseline: no faults
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kernel", KERNELS)
def test_happy_path_bit_identical(tmp_path, kernel):
    prog, grid, block, args, _outs = _example(kernel)
    with FleetCoordinator(backends=("interp", "interp"),
                          queue_dir=tmp_path / "q",
                          fault_plan=[]) as fleet:
        fleet.register(prog)
        tickets = [fleet.submit(kernel, grid, block, args)
                   for _ in range(3)]
        fleet.wait_all(timeout=_WAIT)
        for t in tickets:
            assert_bit_identical(t, kernel)
        st = fleet.fleet_stats()
        assert st["completed"] == 3
        assert st["retried"] == st["evacuated"] == st["workers_lost"] == 0
        assert st["duplicate_acks"] == 0
        assert st["queue"]["acked"] == 3 and not fleet.queue.unacked()


# ---------------------------------------------------------------------------
# the acceptance matrix: every named fault point x both kernels
# ---------------------------------------------------------------------------

def _plan_for(point, kernel):
    spec = {"point": point, "worker": 0, "kernel": kernel, "nth": 1}
    if point == MID_KERNEL:
        spec["after_segments"] = 2   # kill two segment boundaries in
    return [spec]


@pytest.mark.parametrize("kernel", KERNELS)
@pytest.mark.parametrize("point", FAULT_POINTS)
def test_kill_point_replays_bit_identical(tmp_path, point, kernel):
    """SIGKILL worker 0 at ``point``; the launch must complete on the
    surviving worker bit-identical to the oracle, exactly once."""
    prog, grid, block, args, _outs = _example(kernel)
    with FleetCoordinator(backends=("interp", "interp"),
                          queue_dir=tmp_path / "q",
                          fault_plan=_plan_for(point, kernel),
                          fault_seed=42) as fleet:
        fleet.register(prog)
        ticket = fleet.submit(kernel, grid, block, args)
        fleet.wait_all(timeout=_WAIT)

        assert ticket.finished
        assert_bit_identical(ticket, kernel)
        st = fleet.fleet_stats()
        # counters must match the injected schedule exactly: one kill ->
        # one lost worker, one evacuation, one retry, no duplicates
        assert st["workers_lost"] == 1
        assert st["evacuated"] == 1
        assert st["retried"] == 1
        assert st["duplicate_acks"] == 0
        assert st["completed"] == 1
        assert ticket.attempts == 2 and ticket.worker == 1
        # nothing lost: the queue holds exactly one record, acked
        assert st["queue"] == {"pending": 0, "inflight": 0, "acked": 1,
                               "total": 1, "durable": True}
        # the recovery log recorded detect -> replay -> complete
        assert len(fleet.failures) == 1
        assert ticket.launch_id in fleet.failures[0]["recovered"]
        assert fleet.failures[0]["recovered"][ticket.launch_id] > 0


def test_mid_kernel_seed_resolved(tmp_path):
    """A mid-kernel spec without ``after_segments`` resolves it from the
    seed — the unpinned plan is still deterministic and still heals."""
    kernel = "dyn_matmul"
    prog, grid, block, args, _outs = _example(kernel)
    plan = [{"point": MID_KERNEL, "worker": 0, "kernel": kernel}]
    with FleetCoordinator(backends=("interp", "interp"),
                          queue_dir=tmp_path / "q",
                          fault_plan=plan, fault_seed=7) as fleet:
        fleet.register(prog)
        ticket = fleet.submit(kernel, grid, block, args)
        fleet.wait_all(timeout=_WAIT)
        assert_bit_identical(ticket, kernel)
        assert fleet.fleet_stats()["workers_lost"] == 1


def test_multi_kill_schedule(tmp_path):
    """Three kills (one per fault point, on three different workers)
    across a batch of launches: everything still completes exactly once,
    and the loss/evacuation counters match the schedule."""
    kernel = "dyn_matmul"
    prog, grid, block, args, _outs = _example(kernel)
    plan = [
        {"point": PRE_LAUNCH, "worker": 0, "kernel": kernel, "nth": 1},
        {"point": MID_KERNEL, "worker": 1, "kernel": kernel, "nth": 1,
         "after_segments": 1},
        {"point": POST_CHECKPOINT_PRE_ACK, "worker": 2, "kernel": kernel,
         "nth": 1},
    ]
    with FleetCoordinator(backends=("interp",) * 4,
                          queue_dir=tmp_path / "q",
                          fault_plan=plan, fault_seed=42) as fleet:
        fleet.register(prog)
        tickets = [fleet.submit(kernel, grid, block, args)
                   for _ in range(6)]
        fleet.wait_all(timeout=_WAIT)
        for t in tickets:
            assert_bit_identical(t, kernel)
        st = fleet.fleet_stats()
        assert st["workers_lost"] == 3
        assert st["completed"] == 6
        assert st["duplicate_acks"] == 0
        assert st["evacuated"] >= 3 and st["retried"] >= 3
        assert st["queue"]["acked"] == 6 and not fleet.queue.unacked()


# ---------------------------------------------------------------------------
# cross-backend healing (the paper's point: snapshots are device-neutral)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kernel", KERNELS)
def test_evacuation_lands_on_other_backend(tmp_path, kernel):
    """Kill the interp worker mid-kernel; the replay lands on the
    vectorized worker and must still be bit-identical (backends are
    bit-exact per PR 4's FP pinning)."""
    prog, grid, block, args, _outs = _example(kernel)
    with FleetCoordinator(backends=("interp", "vectorized"),
                          queue_dir=tmp_path / "q",
                          fault_plan=_plan_for(MID_KERNEL, kernel),
                          fault_seed=42) as fleet:
        fleet.register(prog)
        ticket = fleet.submit(kernel, grid, block, args)
        fleet.wait_all(timeout=_WAIT)
        assert ticket.worker == 1   # healed onto the vectorized worker
        assert_bit_identical(ticket, kernel)


def test_graceful_drain_migrates_live_state(tmp_path):
    """drain() moves in-flight launches via checkpoint/restore across
    backends — a migration, not a replay: attempts stay at 1."""
    kernel = "dyn_matmul"
    prog, grid, block, args, _outs = _example(kernel)
    with FleetCoordinator(backends=("interp", "vectorized"),
                          queue_dir=tmp_path / "q", slice_segments=1,
                          fault_plan=[]) as fleet:
        fleet.register(prog)
        tickets = [fleet.submit(kernel, grid, block, args)
                   for _ in range(4)]
        fleet.pump()                 # dispatch + first slices
        victim_launches = len(fleet.workers[0].launches)
        assert victim_launches > 0
        moved = fleet.drain(0)       # checkpoint/restore onto worker 1
        assert moved == victim_launches
        fleet.wait_all(timeout=_WAIT)
        for t in tickets:
            assert_bit_identical(t, kernel)
            assert t.attempts == 1   # moved live, never replayed
        st = fleet.fleet_stats()
        assert st["migrated"] == moved
        assert st["retried"] == st["evacuated"] == 0


def test_rebalance_moves_load(tmp_path):
    kernel = "dyn_matmul"
    prog, grid, block, args, _outs = _example(kernel)
    with FleetCoordinator(backends=("interp", "interp"),
                          queue_dir=tmp_path / "q", slice_segments=1,
                          fault_plan=[]) as fleet:
        fleet.register(prog)
        tickets = [fleet.submit(kernel, grid, block, args)
                   for _ in range(4)]
        fleet.pump()
        # pile everything on worker 1 (graceful), then rebalance back
        fleet.drain(0, shutdown=False)
        fleet.workers[0].draining = False
        assert len(fleet.workers[1].launches) >= 2
        moves = fleet.rebalance()
        assert moves >= 1
        loads = [len(w.launches) for w in fleet.workers.values()]
        assert max(loads) - min(loads) <= 1
        fleet.wait_all(timeout=_WAIT)
        for t in tickets:
            assert_bit_identical(t, kernel)


# ---------------------------------------------------------------------------
# durability: the coordinator itself dies
# ---------------------------------------------------------------------------

def test_coordinator_restart_replays_unacked(tmp_path):
    """Kill the whole control plane mid-flight; a fresh coordinator over
    the same queue_dir recovers the launch and completes it
    bit-identically (attempts == 2: one stale dispatch, one replay)."""
    kernel = "decode_gemv"
    prog, grid, block, args, _outs = _example(kernel)
    qdir = tmp_path / "q"
    fleet = FleetCoordinator(backends=("interp",), queue_dir=qdir,
                             slice_segments=1, fault_plan=[])
    try:
        fleet.register(prog)
        lid = fleet.submit(kernel, grid, block, args).launch_id
        fleet.pump()                 # inflight, not finished
        assert fleet.queue.get(lid)["state"] == "inflight"
    finally:
        fleet.shutdown()             # queue dir survives

    with FleetCoordinator(backends=("interp",), queue_dir=qdir,
                          fault_plan=[]) as fleet2:
        recovered = fleet2.recover()
        assert [t.launch_id for t in recovered] == [lid]
        fleet2.register(prog)        # programs must be re-registered
        fleet2.wait_all(timeout=_WAIT)
        assert recovered[0].finished and recovered[0].attempts == 2
        assert_bit_identical(recovered[0], kernel)


def test_respawn_replaces_dead_worker(tmp_path):
    kernel = "dyn_matmul"
    prog, grid, block, args, _outs = _example(kernel)
    with FleetCoordinator(backends=("interp", "interp"),
                          queue_dir=tmp_path / "q",
                          fault_plan=_plan_for(PRE_LAUNCH, kernel),
                          fault_seed=42, respawn=True) as fleet:
        fleet.register(prog)
        ticket = fleet.submit(kernel, grid, block, args)
        fleet.wait_all(timeout=_WAIT)
        assert_bit_identical(ticket, kernel)
        st = fleet.fleet_stats()
        assert st["workers_lost"] == 1
        assert st["workers_spawned"] == 3   # 2 initial + 1 replacement
        assert st["alive_workers"] == 2


def test_evacuate_on_failure_policy(tmp_path):
    """The evacuation policy entry point, driven directly: kill=True is a
    real SIGKILL and the launches replay elsewhere."""
    kernel = "dyn_matmul"
    prog, grid, block, args, _outs = _example(kernel)
    with FleetCoordinator(backends=("interp", "interp"),
                          queue_dir=tmp_path / "q", slice_segments=1,
                          fault_plan=[]) as fleet:
        fleet.register(prog)
        tickets = [fleet.submit(kernel, grid, block, args)
                   for _ in range(2)]
        fleet.pump()
        victim = fleet.workers[0]
        owned = list(victim.launches)
        assert owned
        requeued = fleet.evacuate_on_failure(0, kill=True)
        assert sorted(requeued) == sorted(owned)
        assert not victim.alive
        fleet.wait_all(timeout=_WAIT)
        for t in tickets:
            assert_bit_identical(t, kernel)
        assert fleet.fleet_stats()["evacuated"] == len(owned)


# ---------------------------------------------------------------------------
# serving tier riding the fleet
# ---------------------------------------------------------------------------

def test_serving_front_end_over_fleet(tmp_path):
    """ServingFrontEnd fronting a FleetCoordinator: tenant quotas and
    latency accounting on top, self-healing dispatch underneath — a
    mid-kernel kill is invisible to the serving API."""
    kernel = "dyn_matmul"
    prog, grid, block, args, _outs = _example(kernel)
    with FleetCoordinator(backends=("interp", "interp"),
                          queue_dir=tmp_path / "q",
                          fault_plan=_plan_for(MID_KERNEL, kernel),
                          fault_seed=42) as fleet:
        fleet.register(prog)
        front = ServingFrontEnd(fleet, default_quota=8)
        front.tenant("alpha", weight=2.0)
        front.tenant("beta")
        tickets = [front.submit("alpha", kernel, grid, block, args)
                   for _ in range(3)]
        tickets += [front.submit("beta", kernel, grid, block, args)
                    for _ in range(2)]
        assert front.drain(timeout=_WAIT)
        st = front.stats()
        assert st["admitted"] == st["completed"] == 5
        assert st["fleet"]["workers_lost"] == 1
        assert st["fleet"]["duplicate_acks"] == 0
        for t in tickets:
            assert t.done() and t.latency_ms is not None
            assert_bit_identical(t.record, kernel)
