"""Per-kernel allclose tests: sweep shapes/dtypes in interpret mode against
the pure-jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.kernel import flash_attention_fwd
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.mlstm_chunk.kernel import mlstm_chunk_fwd
from repro.kernels.mlstm_chunk.ref import mlstm_chunk_ref
from repro.kernels.moe_gmm.kernel import moe_gmm_fwd
from repro.kernels.moe_gmm.ref import moe_gmm_ref
from repro.kernels.rglru_scan.kernel import rglru_scan_fwd
from repro.kernels.rglru_scan.ref import rglru_scan_ref

RNG = np.random.default_rng(7)


def _rand(shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(RNG.normal(size=shape) * scale, dtype)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,H,S,d", [(1, 1, 128, 64), (2, 2, 256, 64),
                                     (1, 2, 384, 128)])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_shapes(B, H, S, d, causal, dtype):
    q, k, v = (_rand((B, H, S, d), dtype) for _ in range(3))
    out = flash_attention_fwd(q, k, v, causal=causal, bq=128, bk=128)
    ref = attention_ref(q, k, v, causal=causal)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("window", [64, 128, 999])
def test_flash_attention_sliding_window(window):
    B, H, S, d = 1, 2, 256, 64
    q, k, v = (_rand((B, H, S, d)) for _ in range(3))
    out = flash_attention_fwd(q, k, v, causal=True, window=window)
    ref = attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_flash_attention_uneven_tiles():
    # S not a multiple of the block: masked tail keys must not contribute
    B, H, S, d = 1, 1, 192, 64
    q, k, v = (_rand((B, H, S, d)) for _ in range(3))
    out = flash_attention_fwd(q, k, v, causal=True, bq=128, bk=128)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_flash_attention_grad_matches_ref():
    B, H, S, d = 1, 1, 128, 64
    q, k, v = (_rand((B, H, S, d)) for _ in range(3))

    def loss_kernel(q, k, v):
        return (flash_attention(q, k, v, True, None) ** 2).sum()

    def loss_ref(q, k, v):
        return (attention_ref(q, k, v, causal=True) ** 2).sum()

    gk = jax.grad(loss_kernel, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-3, rtol=1e-3)


# ---------------------------------------------------------------------------
# rglru scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,D", [(1, 128, 128), (2, 512, 256),
                                   (1, 384, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rglru_scan(B, S, D, dtype):
    a = jnp.asarray(RNG.uniform(0.7, 0.999, (B, S, D)), dtype)
    x = _rand((B, S, D), dtype, scale=0.1)
    h0 = _rand((B, D), jnp.float32, scale=0.1)
    h, hT = rglru_scan_fwd(a, x, h0)
    h_ref, hT_ref = rglru_scan_ref(a, x, h0)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(h, np.float32),
                               np.asarray(h_ref, np.float32),
                               atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(hT), np.asarray(hT_ref),
                               atol=tol, rtol=tol)


def test_rglru_scan_time_tiling_invariance():
    B, S, D = 1, 512, 128
    a = jnp.asarray(RNG.uniform(0.8, 0.99, (B, S, D)), jnp.float32)
    x = _rand((B, S, D), scale=0.1)
    h0 = jnp.zeros((B, D), jnp.float32)
    h1, _ = rglru_scan_fwd(a, x, h0, bs=64)
    h2, _ = rglru_scan_fwd(a, x, h0, bs=256)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                               atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# mlstm chunk
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("BH,S,dk,dv", [(1, 128, 64, 64), (2, 256, 64, 128)])
def test_mlstm_chunk(BH, S, dk, dv):
    q = _rand((BH, S, dk), scale=0.5)
    k = _rand((BH, S, dk), scale=0.5)
    v = _rand((BH, S, dv), scale=0.5)
    lf = jnp.asarray(np.log(RNG.uniform(0.9, 0.999, (BH, S, 1))),
                     jnp.float32)
    gi = jnp.asarray(RNG.uniform(0.1, 1.0, (BH, S, 1)), jnp.float32)
    y, CT = mlstm_chunk_fwd(q, k, v, lf, gi, bt=64)
    y_ref, CT_ref = mlstm_chunk_ref(q, k, v, lf, gi)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=2e-3, rtol=2e-3)
    np.testing.assert_allclose(np.asarray(CT), np.asarray(CT_ref),
                               atol=2e-3, rtol=2e-3)


def test_mlstm_chunk_tiling_invariance():
    BH, S, dk, dv = 1, 256, 64, 64
    q, k, v = (_rand((BH, S, d_), scale=0.5) for d_ in (dk, dk, dv))
    lf = jnp.asarray(np.log(RNG.uniform(0.9, 0.999, (BH, S, 1))),
                     jnp.float32)
    gi = jnp.ones((BH, S, 1), jnp.float32)
    y1, C1 = mlstm_chunk_fwd(q, k, v, lf, gi, bt=32)
    y2, C2 = mlstm_chunk_fwd(q, k, v, lf, gi, bt=128)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=1e-3, rtol=1e-3)


# ---------------------------------------------------------------------------
# moe gmm
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("E,C,D,F", [(4, 128, 128, 256), (8, 256, 64, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_moe_gmm(E, C, D, F, dtype):
    counts = jnp.asarray(RNG.integers(0, C + 1, size=E), jnp.int32)
    x = _rand((E, C, D), dtype)
    # contract: rows past counts[e] are zero
    rows = jnp.arange(C)[None, :, None]
    x = jnp.where(rows < counts[:, None, None], x, jnp.zeros_like(x))
    w = _rand((E, D, F), dtype)
    out = moe_gmm_fwd(x, w, counts)
    ref = moe_gmm_ref(x, w, counts)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol, rtol=tol)


def test_moe_gmm_empty_experts_are_zero():
    E, C, D, F = 4, 128, 64, 64
    counts = jnp.asarray([0, 64, 0, 128], jnp.int32)
    x = _rand((E, C, D))
    rows = jnp.arange(C)[None, :, None]
    x = jnp.where(rows < counts[:, None, None], x, jnp.zeros_like(x))
    w = _rand((E, D, F))
    out = np.asarray(moe_gmm_fwd(x, w, counts))
    assert np.all(out[0] == 0)
    assert np.all(out[2] == 0)
