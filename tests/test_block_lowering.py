"""Block-tiled codegen tests: the lane-independence proof, the tiled pallas
fast path's conformance against the interpreter, the zero-default register
contract the differential sweep pinned down, and snapshot/restore through a
block-lowered backend."""
import numpy as np
import pytest

from repro.core import Engine, Snapshot, get_backend
from repro.core import hetir as ir
from repro.core import kernels_suite as suite
from repro.core.backends.pallas_backend import PallasBackend
from repro.core.cache import TranslationCache
from repro.core.hetir import Builder, Ptr, Scalar
from repro.core.passes import block_lower, choose_block

RNG = np.random.default_rng(11)

TILED_KERNELS = ["vadd", "saxpy", "stencil_1d", "poly_eval",
                 "swizzle_copy", "dyn_fir"]


# ---------------------------------------------------------------------------
# choose_block / block_lower legality units
# ---------------------------------------------------------------------------

def test_choose_block():
    assert choose_block(128) == 128
    assert choose_block(16384) == 1024          # HETGPU_BLOCK_MAX cap
    assert choose_block(96) == 32               # largest pow2 divisor
    assert choose_block(0) is None
    assert choose_block(128, cap=64) == 64


def _vadd_prog():
    b = Builder("vadd", [Ptr("A"), Ptr("B"), Ptr("C"), Scalar("n")])
    i = b.global_id(0)
    with b.when(i < b.param("n")):
        b.store("C", i, b.load("A", i) + b.load("B", i))
    return b.done()


def test_block_lower_vadd_fully_tiled():
    prog = _vadd_prog()
    lens = {"A": 128, "B": 128, "C": 128}
    plan, reason = block_lower(prog.body, 4, 32, 128, buffer_lens=lens)
    assert reason is None and plan is not None
    assert plan.block == 128 and plan.grid == 1
    assert set(plan.tiled) == {"A", "B", "C"}
    ops = {op.opcode for op in ir.walk_ops(plan.stmts)}
    assert ir.BLOCK_LD in ops and ir.BLOCK_ST in ops
    assert ir.LD_GLOBAL not in ops and ir.ST_GLOBAL not in ops
    for op in ir.walk_ops(plan.stmts):
        if op.opcode in (ir.BLOCK_LD, ir.BLOCK_ST):
            assert op.attrs["block"] == 128
            assert op.attrs["mode"] == "tiled"


def test_block_lower_refuses_bad_block():
    prog = _vadd_prog()
    assert block_lower(prog.body, 4, 32, 0)[1] == "bad-block"
    assert block_lower(prog.body, 4, 32, 48)[1] == "bad-block"  # 128 % 48


def test_block_lower_refuses_shared_memory():
    b = Builder("sh", [Ptr("A"), Ptr("Out")], shared_size=32)
    t = b.thread_id()
    b.store_shared(t, b.load("A", b.global_id(0)))
    b.store("Out", b.global_id(0), b.load_shared(t))
    _, reason = block_lower(b.done().body, 4, 32, 128)
    assert reason == "shared-memory"


def test_block_lower_refuses_collective():
    b = Builder("cv", [Ptr("A"), Ptr("Out")])
    i = b.global_id(0)
    s = b.reduce_add(b.load("A", i))
    b.store("Out", i, s)
    _, reason = block_lower(b.done().body, 4, 32, 128)
    assert reason == f"collective:{ir.REDUCE_ADD}"


def test_block_lower_refuses_atomic():
    b = Builder("at", [Ptr("A"), Ptr("Out")])
    i = b.global_id(0)
    b.atomic_add("Out", b.const(0), b.load("A", i))
    _, reason = block_lower(b.done().body, 4, 32, 128)
    assert reason == "atomic"


def test_block_lower_refuses_loop_var_store_index():
    b = Builder("lv", [Ptr("Out")])
    with b.loop(4, hint="j") as j:
        b.store("Out", j, b.const(1.0, ir.F32))
    _, reason = block_lower(b.done().body, 4, 32, 128)
    assert reason == "unprovable-base:Out"


def test_block_lower_refuses_non_injective_store():
    b = Builder("ni", [Ptr("Out")])
    b.store("Out", b.const(0), b.const(1.0, ir.F32))  # every thread, slot 0
    _, reason = block_lower(b.done().body, 4, 32, 128)
    assert reason == "store-not-injective:Out"


def test_block_lower_gathers_oversized_buffer():
    """A written buffer whose length is not exactly grid*block stays in
    gather mode (whole-buffer staging) but the segment still lowers."""
    b = Builder("gt", [Ptr("A"), Ptr("Out")])
    i = b.global_id(0)
    b.store("Out", i, b.load("A", i))
    plan, reason = block_lower(b.done().body, 4, 32, 128,
                               buffer_lens={"A": 128, "Out": 256})
    assert reason is None and plan is not None
    assert "A" in plan.tiled
    assert "Out" not in plan.tiled


def test_block_lower_refuses_bid_store_under_divergent_predicate():
    """A bid-indexed store is not thread-injective (the whole block hits
    one slot) even when a predicate would mask it — the proof is
    predicate-blind and must refuse."""
    b = Builder("bp", [Ptr("A"), Ptr("Out")])
    bid = b.block_id()
    t = b.thread_id()
    with b.when(t.eq(b.const(0))):
        b.store("Out", bid, b.load("A", bid))
    _, reason = block_lower(b.done().body, 4, 32, 128,
                            buffer_lens={"A": 128, "Out": 4})
    assert reason == "store-not-injective:Out"


# ---------------------------------------------------------------------------
# tiled fast path conformance: bit-identical to the interpreter
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", TILED_KERNELS)
def test_tiled_path_bit_identical_to_interp(name):
    prog, _oracle, grid, block, args, outs = suite.example_launch(
        name, rng=np.random.default_rng(5))
    ref = Engine(prog, get_backend("interp"), grid, block, dict(args))
    assert ref.run()

    backend = PallasBackend(cache=TranslationCache())
    eng = Engine(prog, backend, grid, block, dict(args))
    assert eng.run()
    assert backend.block_stats["tiled"] >= 1, \
        f"{name} did not take the tiled path: {backend.block_stats}"
    for o in outs:
        assert np.array_equal(np.asarray(eng.result(o)),
                              np.asarray(ref.result(o))), \
            f"{name}: tiled pallas diverges from interp on {o}"


def test_block_flag_flip_does_not_poison_cache(monkeypatch):
    """HETGPU_BLOCK_LOWER is part of the translation-cache key: flipping it
    between launches on the same backend must re-translate, not reuse the
    other mode's kernel."""
    prog, _oracle, grid, block, args, outs = suite.example_launch(
        "vadd", rng=np.random.default_rng(6))
    backend = PallasBackend(cache=TranslationCache())

    monkeypatch.setenv("HETGPU_BLOCK_LOWER", "1")
    e1 = Engine(prog, backend, grid, block, dict(args))
    assert e1.run()
    assert backend.block_stats["tiled"] >= 1

    monkeypatch.setenv("HETGPU_BLOCK_LOWER", "0")
    e2 = Engine(prog, backend, grid, block, dict(args))
    assert e2.run()
    assert backend.block_stats["scalar"] >= 1, \
        "flag flip reused the tiled translation: cache key misses the flag"
    for o in outs:
        assert np.array_equal(np.asarray(e1.result(o)),
                              np.asarray(e2.result(o)))


# ---------------------------------------------------------------------------
# differential sweep: zero-default register contract (all backends, O0 and
# OPT_MAX must agree bit-for-bit)
# ---------------------------------------------------------------------------

def _zero_trip_prog():
    """A register defined *only* inside an engine-level loop, read after it:
    with a zero trip count the loop never runs and every backend must see
    the typed zero default."""
    b = Builder("zt", [Ptr("A"), Ptr("Out"), Scalar("k")])
    i = b.global_id(0)
    t = b.var(b.const(0.0, ir.F32), hint="zv")
    with b.loop("k", hint="zk") as _:
        b.assign(t, b.load("A", i) + b.const(1.0, ir.F32))
        b.barrier("zl")
    b.store("Out", i, t * b.const(2.0, ir.F32))
    return b.done()


def _passthrough_prog():
    """A register conditionally written in segment 1 and read in segment 2:
    untouched lanes must carry their pre-segment value across the barrier
    (and lanes never written read as zero)."""
    b = Builder("pt", [Ptr("A"), Ptr("Out"), Scalar("n")])
    i = b.global_id(0)
    v = b.var(b.const(0.0, ir.F32), hint="pv")
    with b.when(i < b.param("n")):
        b.assign(v, b.load("A", i))
    b.barrier("mid")
    b.store("Out", i, v)
    return b.done()


def _revisit_prog():
    """A non-coalesced output buffer written in two segments: the second
    segment's read must observe the first segment's store (the pallas
    revisited-output staging path)."""
    b = Builder("rv", [Ptr("Out")])
    bid = b.block_id()
    t = b.thread_id()
    with b.when(t.eq(b.const(0))):
        b.store("Out", bid, b.const(1.0, ir.F32))
    b.barrier("m")
    with b.when(t.eq(b.const(0))):
        b.store("Out", bid, b.load("Out", bid) + b.const(2.0, ir.F32))
    return b.done()


_DIFF_CASES = {
    "zero_trip": (_zero_trip_prog, 2, 32, lambda: {
        "A": RNG.normal(size=64).astype(np.float32),
        "Out": np.full(64, -7.0, np.float32), "k": 0}, "Out",
        lambda args: np.zeros(64, np.float32)),
    "passthrough": (_passthrough_prog, 2, 32, lambda: {
        "A": RNG.normal(size=64).astype(np.float32),
        "Out": np.zeros(64, np.float32), "n": 40}, "Out",
        lambda args: np.where(np.arange(64) < 40,
                              np.asarray(args["A"]),
                              np.float32(0.0)).astype(np.float32)),
    "revisit": (_revisit_prog, 4, 32, lambda: {
        "Out": np.zeros(4, np.float32)}, "Out",
        lambda args: np.full(4, 3.0, np.float32)),
}


@pytest.mark.parametrize("case", sorted(_DIFF_CASES))
@pytest.mark.parametrize("backend", ["interp", "vectorized", "pallas"])
@pytest.mark.parametrize("opt", [0, None])  # None = OPT_MAX default
def test_differential_zero_default_contract(case, backend, opt):
    mk_prog, grid, block, mk_args, out, expect = _DIFF_CASES[case]
    args = mk_args()
    kw = {} if opt is None else {"opt_level": opt}
    eng = Engine(mk_prog(), get_backend(backend), grid, block,
                 dict(args), **kw)
    assert eng.run()
    np.testing.assert_array_equal(
        np.asarray(eng.result(out)), expect(args),
        err_msg=f"{case} on {backend} (opt={opt})")


# ---------------------------------------------------------------------------
# snapshot / restore through the block-lowered backend
# ---------------------------------------------------------------------------

def test_snapshot_restore_block_lowered_bit_identical():
    """Pause a multi-segment kernel on the block-lowering pallas backend,
    serialize, resume on a fresh pallas backend: bit-identical to the
    unpaused run, and the restored launch record keeps the buffer shapes
    (so specialization and tiled legality re-key correctly)."""
    prog, _oracle = suite.persistent_counter()
    args = {"State": RNG.normal(size=64).astype(np.float32), "iters": 6}

    ref = Engine(prog, PallasBackend(cache=TranslationCache()),
                 2, 32, dict(args))
    assert ref.run()

    eng = Engine(prog, PallasBackend(cache=TranslationCache()),
                 2, 32, dict(args))
    assert not eng.run(max_segments=3)
    blob = eng.snapshot().to_bytes()
    eng2 = Engine.resume(prog, PallasBackend(cache=TranslationCache()),
                         Snapshot.from_bytes(blob))
    assert eng2.launch.buffer_shapes.get("State") == (64,)
    assert eng2.run()
    np.testing.assert_array_equal(eng2.result("State"), ref.result("State"))


def test_snapshot_restore_block_lowered_to_interp():
    prog, oracle = suite.persistent_counter()
    args = {"State": RNG.normal(size=64).astype(np.float32), "iters": 6}
    eng = Engine(prog, PallasBackend(cache=TranslationCache()),
                 2, 32, dict(args))
    assert not eng.run(max_segments=3)
    blob = eng.snapshot().to_bytes()
    eng2 = Engine.resume(prog, get_backend("interp"),
                         Snapshot.from_bytes(blob))
    assert eng2.run()
    expect = oracle(dict(args, _num_blocks=2, _block_size=32))
    np.testing.assert_allclose(eng2.result("State"), expect["State"],
                               rtol=1e-4, atol=1e-4)
