"""Baseline-vs-optimized layer-variant equivalence (§Perf switches).

Every hillclimb switch must be semantics-preserving:
  * mlstm chunked == mlstm scan (and decode continues from its state)
  * flash-attention custom VJP == full autodiff gradients
  * grouped MoE == global MoE at ample capacity
"""
import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import layers as L
from repro.models.layers import _mha_chunked

RNG = np.random.default_rng(11)


def test_mlstm_chunked_matches_scan():
    cfg = configs.get_smoke_config("xlstm-125m")
    B, S, D = 2, 64, cfg.d_model
    x = jnp.asarray(RNG.normal(size=(B, S, D)) * 0.3, jnp.float32)
    p = L.init_mlstm(jax.random.key(0), cfg, jnp.float32)
    y1, st1 = L._mlstm_scan(x, p, cfg)
    y2, st2 = L.mlstm_chunked(x, p, cfg, chunk=16)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=1e-5, rtol=1e-5)
    for k_ in ("C", "n", "m"):
        np.testing.assert_allclose(np.asarray(st1[k_]),
                                   np.asarray(st2[k_]),
                                   atol=1e-4, rtol=1e-4)
    # decode continuation from the chunked state matches
    x1 = jnp.asarray(RNG.normal(size=(B, 1, D)) * 0.3, jnp.float32)
    yd1, _ = L._mlstm_scan(x1, p, cfg, st1)
    yd2, _ = L._mlstm_scan(x1, p, cfg, st2)
    np.testing.assert_allclose(np.asarray(yd1), np.asarray(yd2),
                               atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("window", [None, 48])
def test_flash_vjp_matches_autodiff(window):
    B, S, H, d = 2, 128, 2, 32
    q, k, v = (jnp.asarray(RNG.normal(size=(B, S, H, d)), jnp.float32)
               for _ in range(3))

    def ref(q, k, v):
        s = jnp.einsum("bchd,bshd->bhcs", q, k) / math.sqrt(d)
        qp = jnp.arange(S)[:, None]
        kp = jnp.arange(S)[None, :]
        mask = kp <= qp
        if window is not None:
            mask = mask & (kp > qp - window)
        s = jnp.where(mask[None, None], s, -jnp.inf)
        return jnp.einsum("bhcs,bshd->bchd", jax.nn.softmax(s, axis=-1),
                          v)

    gk = jax.grad(lambda *a: (_mha_chunked(*a, True, window, 0, 32) ** 2)
                  .sum(), argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(lambda *a: (ref(*a) ** 2).sum(),
                  argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-4, rtol=2e-4)


def test_grouped_moe_matches_global_at_ample_capacity():
    cfg = configs.get_smoke_config("mixtral-8x22b")
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    B, S, D = 2, 16, cfg.d_model
    x = jnp.asarray(RNG.normal(size=(B, S, D)) * 0.3, jnp.float32)
    p = L.init_moe(jax.random.key(0), cfg, jnp.float32)
    y1 = L._moe_ffn_global(x, p, cfg)
    y2 = L.moe_ffn_grouped(x, p, cfg)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=1e-4, rtol=1e-4)


def test_train_loss_invariant_under_switches():
    """End-to-end: the optimized switches don't change the loss."""
    from repro.models import forward_train
    from repro.models import init_params
    base = configs.get_smoke_config("mixtral-8x22b")
    opt = dataclasses.replace(base, moe_impl="grouped", attn_vjp="flash",
                              moe=dataclasses.replace(
                                  base.moe, capacity_factor=8.0))
    base = dataclasses.replace(base, moe=dataclasses.replace(
        base.moe, capacity_factor=8.0))
    params = init_params(jax.random.key(0), base)
    batch = {"tokens": jnp.asarray(
        RNG.integers(0, base.vocab_size, (2, 32)), jnp.int32)}
    l1 = forward_train(params, batch, base)
    l2 = forward_train(params, batch, opt)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)
    g1 = jax.grad(lambda p: forward_train(p, batch, base))(params)
    g2 = jax.grad(lambda p: forward_train(p, batch, opt))(params)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-4, rtol=5e-3)
