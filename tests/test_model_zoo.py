"""Model-zoo conformance, migration and fabric tests (ISSUE 10).

The zoo kernels (`repro.zoo`) are faithful hetIR reductions of the
repo's real workloads, each with a *bit-exact* NumPy oracle — so unlike
the reference-model tests, everything here asserts
``np.testing.assert_array_equal``: same bits on interp, vectorized and
pallas, at O0 and OPT_MAX, before and after a mid-kernel migration.
"""
import itertools

import numpy as np
import pytest

import repro.zoo as zoo  # noqa: F401  (import registers the zoo kernels)
from repro.core import Engine, HetSession, Snapshot, get_backend
from repro.core import kernels_suite as ks
from repro.core.backends.pallas_backend import PallasBackend
from repro.core.backends.portable_math import (EXP_MAX_INPUT, EXP_MIN_INPUT,
                                               exp_jnp, exp_np)
from repro.core.cache import TranslationCache
from repro.core.passes import OPT_MAX, REFUSAL_REASONS, refusal_category

ZOO_NAMES = sorted(zoo.ZOO)
BACKENDS = ["interp", "vectorized", "pallas"]


def _launch(name, seed=0):
    return ks.example_launch(name, rng=np.random.default_rng(seed))


# ---------------------------------------------------------------------------
# conformance sweep: 4 kernels x 3 backends x {O0, OPT_MAX}, bit-identical
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name,backend,opt",
                         [(n, b, o) for n in ZOO_NAMES for b in BACKENDS
                          for o in (0, OPT_MAX)])
def test_zoo_conformance(name, backend, opt):
    prog, oracle, grid, block, args, outs = _launch(name)
    expect = oracle({k: (np.array(v, copy=True)
                         if isinstance(v, np.ndarray) else v)
                     for k, v in args.items()})
    eng = Engine(prog, get_backend(backend), grid, block, dict(args),
                 opt_level=opt)
    assert eng.run()
    for o in outs:
        np.testing.assert_array_equal(
            np.asarray(eng.result(o)), np.asarray(expect[o]),
            err_msg=f"{name} on {backend} at O{opt}: {o} not bit-identical")


# ---------------------------------------------------------------------------
# mid-kernel checkpoint / migrate of attn_decode, both directions
# ---------------------------------------------------------------------------

def _attn_expect(args, oracle):
    return oracle({k: (np.array(v, copy=True)
                       if isinstance(v, np.ndarray) else v)
                   for k, v in args.items()})["O"]


@pytest.mark.parametrize("src,dst",
                         list(itertools.permutations(BACKENDS, 2)))
def test_attn_decode_migrates_mid_softmax(src, dst):
    """Pause attn_decode inside the online-softmax tile loop (its m/l/acc
    state lives in the regfile, the probability tile in shared memory),
    snapshot, resume on the other backend — output stays bit-identical
    to the oracle."""
    prog, oracle, grid, block, args, _outs = _launch("attn_decode")
    expect = _attn_expect(args, oracle)

    eng = Engine(prog, get_backend(src), grid, block, dict(args))
    assert not eng.run(max_segments=3), "should pause mid-decode"
    blob = eng.snapshot().to_bytes()
    eng2 = Engine.resume(prog, get_backend(dst), Snapshot.from_bytes(blob))
    assert eng2.run()
    np.testing.assert_array_equal(np.asarray(eng2.result("O")), expect)


def test_attn_decode_double_migration_chain():
    """interp -> vectorized -> pallas across two tile boundaries (the
    serve_decode --zoo demo's exact path), still bit-identical."""
    prog, oracle, grid, block, args, _outs = _launch("attn_decode")
    expect = _attn_expect(args, oracle)
    e1 = Engine(prog, get_backend("interp"), grid, block, dict(args))
    assert not e1.run(max_segments=2)
    e2 = Engine.resume(prog, get_backend("vectorized"), e1.snapshot())
    assert not e2.run(max_segments=2)
    e3 = Engine.resume(prog, get_backend("pallas"), e2.snapshot())
    assert e3.run()
    np.testing.assert_array_equal(np.asarray(e3.result("O")), expect)


# ---------------------------------------------------------------------------
# SharedStore fabric: a fresh node warm-starts the whole zoo
# ---------------------------------------------------------------------------

def test_zoo_sharedstore_warm_start(tmp_path):
    fabric = str(tmp_path / "fabric")
    hot = HetSession("vectorized", shared=fabric)
    for name in ZOO_NAMES:
        prog, _oracle, grid, block, args, _outs = _launch(name)
        rep = hot.warmup([(prog, args)], grids=((grid, block),))
        assert rep["errors"] == 0, rep
        assert rep["translated"] > 0, f"{name}: nothing translated to publish"

    cold = HetSession("vectorized", shared=fabric)
    for name in ZOO_NAMES:
        prog, _oracle, grid, block, args, _outs = _launch(name)
        rep = cold.warmup([(prog, args)], grids=((grid, block),))
        assert rep["errors"] == 0, rep
        assert rep["translated"] == 0, \
            f"{name}: warm node re-translated instead of fetching"
        assert rep["restored"] > 0 and rep["fetched"] == rep["restored"], rep


# ---------------------------------------------------------------------------
# block_lower refusal reasons: stable names, every zoo kernel accounted for
# ---------------------------------------------------------------------------

def test_zoo_block_stats_refusals_are_named():
    """Each zoo kernel either block-tiles or refuses for a *documented*
    reason — block_stats histogram keys must come from the stable
    REFUSAL_REASONS vocabulary (satellite: no more free-form strings)."""
    for name in ZOO_NAMES:
        prog, _oracle, grid, block, args, _outs = _launch(name)
        backend = PallasBackend(cache=TranslationCache())
        eng = Engine(prog, backend, grid, block, dict(args))
        assert eng.run()
        stats = backend.block_stats
        assert stats["tiled"] or stats["reasons"], \
            f"{name}: scalar fallback with no recorded reason"
        unknown = set(stats["reasons"]) - set(REFUSAL_REASONS)
        assert not unknown, f"{name}: undocumented refusal names {unknown}"


def test_refusal_category_contract():
    assert refusal_category("collective:REDUCE_ADD") == "collective"
    assert refusal_category("shared-memory") == "shared-memory"
    for r in REFUSAL_REASONS:
        assert refusal_category(r) == r  # canonical names are categories


# ---------------------------------------------------------------------------
# the portable EXP that makes the above possible
# ---------------------------------------------------------------------------

def test_portable_exp_bit_identity():
    """exp_np (interp) and exp_jnp (vectorized/pallas trace) agree bit
    for bit across the full float32 input range, including the overflow
    and flush-to-zero thresholds and non-finite inputs."""
    import jax
    import jax.numpy as jnp

    rng = np.random.default_rng(7)
    xs = np.concatenate([
        rng.uniform(-110.0, 95.0, size=50_000).astype(np.float32),
        rng.standard_normal(20_000).astype(np.float32) * 10,
        np.array([0.0, -0.0, np.inf, -np.inf, np.nan,
                  float(EXP_MAX_INPUT), float(EXP_MIN_INPUT),
                  np.nextafter(np.float32(EXP_MAX_INPUT), np.float32(200)),
                  np.nextafter(np.float32(EXP_MIN_INPUT), np.float32(-200)),
                  -87.33655, -87.4, -103.9, 88.72, 1.0, -1.0], np.float32),
    ])
    got_np = exp_np(xs)
    got_jit = np.asarray(jax.jit(exp_jnp)(jnp.asarray(xs)))
    np.testing.assert_array_equal(got_np.view(np.uint32),
                                  got_jit.view(np.uint32))
    # sanity: accurate, not just self-consistent
    finite = np.isfinite(xs) & (xs > -80) & (xs < 80)
    ref = np.exp(xs[finite].astype(np.float64))
    rel = np.abs(got_np[finite].astype(np.float64) - ref) / ref
    assert float(rel.max()) < 1e-6


# ---------------------------------------------------------------------------
# registry hygiene: the zoo never leaks into the closed suite
# ---------------------------------------------------------------------------

def test_zoo_registry_is_namespaced():
    assert set(ks.registered_examples("zoo")) == set(ZOO_NAMES)
    assert not set(ZOO_NAMES) & set(ks.SUITE)
    assert not set(ZOO_NAMES) & set(ks.EXAMPLES)
    for name in ZOO_NAMES:
        assert ks.lookup(name) is zoo.ZOO[name]
    with pytest.raises(ValueError):
        ks.register_kernel("rogue", zoo.attn_decode, registry="suite")
