"""Property-based tests (hypothesis) for hetIR system invariants.

Invariants under test:

1. **Backend equivalence** — randomly generated hetIR programs (arith,
   divergence, shared memory, collectives) produce identical results on the
   scalar-interpreter oracle and the vectorized/pallas backends.
2. **Migration transparency** — pausing at *any* barrier and resuming on
   *any* backend never changes the final result.
3. **Snapshot serialization** — to_bytes/from_bytes is lossless.
"""
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need the optional hypothesis dep")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import Engine, Snapshot, get_backend
from repro.core import hetir as ir
from repro.core import kernels_suite as suite
from repro.core.hetir import Builder, Ptr, Scalar
from repro.core.segments import SegNode, segment_program

# ---------------------------------------------------------------------------
# random program generator
# ---------------------------------------------------------------------------

_BINARY = [ir.ADD, ir.SUB, ir.MUL, ir.MIN, ir.MAX]


def build_random_program(draw_ops, n_stmts: int, use_barrier: bool):
    """Construct a random but well-formed hetIR program from a draw list."""
    b = Builder("rand", [Ptr("In"), Ptr("Out"), Scalar("n")],
                shared_size=32)
    i = b.global_id(0)
    vals = [b.load("In", i), i.astype(ir.F32),
            b.const(1.5, ir.F32)]
    k = 0
    for spec in draw_ops[:n_stmts]:
        kind = spec[0]
        if kind == "bin":
            _, opi, a_i, b_i = spec
            a, c = vals[a_i % len(vals)], vals[b_i % len(vals)]
            op = _BINARY[opi % len(_BINARY)]
            vals.append(Builder._emit(b, op, ir.F32, a, c))
        elif kind == "pred":
            # values escaping a @PRED region must be pre-initialized (reading
            # a register defined only under a predicate is UB in hetIR)
            _, thr, a_i = spec
            cond = vals[a_i % len(vals)] > b.const(float(thr), ir.F32)
            v = b.var(b.const(0.0, ir.F32))
            with b.when(cond):
                b.assign(v, b.load("In", i) + b.const(float(thr), ir.F32))
            vals.append(v)
        elif kind == "shared":
            _, a_i = spec
            t = b.thread_id()
            b.store_shared(t, vals[a_i % len(vals)])
            if use_barrier:
                b.barrier(f"s{k}")
                k += 1
            other = (b.block_dim() - b.const(1)) - t
            vals.append(b.load_shared(other))
        elif kind == "coll":
            _, which, a_i = spec
            v = vals[a_i % len(vals)]
            if which % 3 == 0:
                vals.append(b.reduce_add(v))
            elif which % 3 == 1:
                vals.append(b.reduce_max(v))
            else:
                vals.append(b.scan_add(v))
    b.store("Out", i, vals[-1])
    return b.done()


op_spec = st.one_of(
    st.tuples(st.just("bin"), st.integers(0, 4), st.integers(0, 7),
              st.integers(0, 7)),
    st.tuples(st.just("pred"), st.integers(-2, 2), st.integers(0, 7)),
    st.tuples(st.just("shared"), st.integers(0, 7)),
    st.tuples(st.just("coll"), st.integers(0, 2), st.integers(0, 7)),
)


@settings(max_examples=25, deadline=None)
@given(ops=st.lists(op_spec, min_size=1, max_size=6),
       use_barrier=st.booleans(),
       seed=st.integers(0, 2**31 - 1))
def test_random_programs_backend_equivalence(ops, use_barrier, seed):
    rng = np.random.default_rng(seed)
    prog = build_random_program(ops, len(ops), use_barrier)
    grid, block = 2, 8
    args = {"In": rng.uniform(-2, 2, size=grid * block).astype(np.float32),
            "Out": np.zeros(grid * block, np.float32), "n": grid * block}

    results = {}
    for backend in ("interp", "vectorized", "pallas"):
        prog_b = build_random_program(ops, len(ops), use_barrier)
        eng = Engine(prog_b, get_backend(backend), grid, block, dict(args))
        assert eng.run()
        results[backend] = eng.result("Out")

    np.testing.assert_allclose(results["vectorized"], results["interp"],
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(results["pallas"], results["interp"],
                               rtol=1e-5, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(pause_at=st.integers(1, 12),
       src=st.sampled_from(["vectorized", "pallas", "interp"]),
       dst=st.sampled_from(["vectorized", "pallas", "interp"]),
       seed=st.integers(0, 2**31 - 1))
def test_migration_at_any_barrier_is_transparent(pause_at, src, dst, seed):
    rng = np.random.default_rng(seed)
    prog, _ = suite.persistent_counter()
    args = {"State": rng.normal(size=32).astype(np.float32), "iters": 5}

    ref = Engine(prog, get_backend("interp"), 2, 16, dict(args))
    assert ref.run()

    eng = Engine(prog, get_backend(src), 2, 16, dict(args))
    finished = eng.run(max_segments=pause_at)
    if not finished:
        eng = Engine.resume(prog, get_backend(dst),
                            Snapshot.from_bytes(eng.snapshot().to_bytes()))
        assert eng.run()
    np.testing.assert_allclose(eng.result("State"), ref.result("State"),
                               rtol=1e-5, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(n_barriers=st.integers(0, 5))
def test_segmentation_structure(n_barriers):
    """Segments = barrier-separated regions; all ops preserved in order."""
    b = Builder("seg", [Ptr("A")])
    i = b.global_id(0)
    total_ops = 0
    for k in range(n_barriers + 1):
        v = b.load("A", i) + b.const(float(k), ir.F32)
        b.store("A", i, v)
        total_ops += 1
        if k < n_barriers:
            b.barrier(f"b{k}")
    prog = b.done()
    nodes = segment_program(prog)
    segs = [n for n in nodes if isinstance(n, SegNode)]
    assert len(segs) == n_barriers + 1
    n_stores = sum(
        1 for s in segs for stmt in s.stmts
        if isinstance(stmt, ir.Op) and stmt.opcode == ir.ST_GLOBAL)
    assert n_stores == total_ops


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), nregs=st.integers(1, 5))
def test_snapshot_bytes_roundtrip(seed, nregs):
    rng = np.random.default_rng(seed)
    snap = Snapshot(
        program_name="p", num_blocks=2, block_size=4, node_idx=3,
        loop_counters={1: 7},
        regs={f"r{i}": rng.normal(size=(2, 4)).astype(np.float32)
              for i in range(nregs)},
        shared=rng.normal(size=(2, 8)).astype(np.float32),
        globals_={"G": rng.normal(size=16).astype(np.float32)},
        scalars={"n": 5},
    )
    back = Snapshot.from_bytes(snap.to_bytes())
    assert back.node_idx == snap.node_idx
    assert back.loop_counters == snap.loop_counters
    for k in snap.regs:
        np.testing.assert_array_equal(back.regs[k], snap.regs[k])
    np.testing.assert_array_equal(back.shared, snap.shared)
    np.testing.assert_array_equal(back.globals_["G"], snap.globals_["G"])
    assert back.scalars["n"] == 5
