"""Gradient compression: error feedback keeps long-run updates unbiased."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim.compression import (compress, compressed_bytes,
                                     decompress, ef_init)


def test_roundtrip_error_bounded():
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.normal(size=(64, 64)), jnp.float32)}
    ef = ef_init(g)
    q, ef2 = compress(g, ef)
    back = decompress(q)
    err = np.abs(np.asarray(back["w"]) - np.asarray(g["w"])).max()
    scale = np.abs(np.asarray(g["w"])).max() / 127
    assert err <= scale * 0.51 + 1e-6


def test_error_feedback_cancels_bias():
    """Sum of decompressed grads over many steps ≈ sum of true grads
    (error feedback carries the residual forward)."""
    rng = np.random.default_rng(1)
    true_sum = np.zeros((32,), np.float32)
    dec_sum = np.zeros((32,), np.float32)
    ef = ef_init({"w": jnp.zeros(32)})
    for _ in range(50):
        g = {"w": jnp.asarray(rng.normal(size=32) * 0.01, jnp.float32)}
        q, ef = compress(g, ef)
        dec_sum += np.asarray(decompress(q)["w"])
        true_sum += np.asarray(g["w"])
    resid = np.abs(ef["w"]).max()
    np.testing.assert_allclose(dec_sum, true_sum, atol=2 * resid + 1e-5)


def test_compression_ratio():
    g = {"w": jnp.ones((1024, 1024), jnp.float32)}
    q, _ = compress(g, ef_init(g))
    assert compressed_bytes(q) < g["w"].size * 4 / 3.9
