"""Checkpoint + fault-tolerance + elastic-restore tests.

Single-device tests run in-process; cross-mesh tests spawn a subprocess
with XLA_FLAGS=--xla_force_host_platform_device_count=8 (jax device count
is locked at first init in this process).
"""
import json
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro import configs
from repro.checkpoint import AsyncCheckpointer, latest_step, restore, save
from repro.configs.base import ParallelCfg, ShapeCfg
from repro.runtime.train_loop import SimulatedFailure, Trainer

SMOKE_SHAPE = ShapeCfg("tiny", 32, 4, "train")


def _mesh1():
    return jax.make_mesh((1, 1), ("data", "model"))


def test_checkpoint_roundtrip(tmp_path):
    state = {"a": jax.numpy.arange(12.0).reshape(3, 4),
             "b": [jax.numpy.ones(5), jax.numpy.zeros(2)]}
    save(tmp_path, 7, state, extra={"next_step": 7})
    assert latest_step(tmp_path) == 7
    got, extra = restore(tmp_path, 7, state)
    assert extra["next_step"] == 7
    np.testing.assert_array_equal(np.asarray(got["a"]),
                                  np.asarray(state["a"]))
    np.testing.assert_array_equal(np.asarray(got["b"][0]), np.ones(5))


def test_async_checkpointer(tmp_path):
    ck = AsyncCheckpointer(tmp_path)
    state = {"x": jax.numpy.ones((4, 4))}
    ck.save(3, state, extra={"next_step": 3})
    ck.wait()
    assert latest_step(tmp_path) == 3
    got, _ = restore(tmp_path, 3, state)
    np.testing.assert_array_equal(np.asarray(got["x"]), np.ones((4, 4)))


def test_train_restart_after_failure_is_bit_exact(tmp_path):
    """Crash mid-run, restart from checkpoint: the loss trajectory must
    match an uninterrupted run exactly (deterministic data + optimizer)."""
    cfg = configs.get_smoke_config("llama3.2-3b")
    mesh = _mesh1()

    # uninterrupted reference
    t_ref = Trainer(cfg, SMOKE_SHAPE, mesh, seed=3)
    ref = t_ref.run(6)

    # crash at step 4, restart from the step-2 checkpoint
    t1 = Trainer(cfg, SMOKE_SHAPE, mesh, ckpt_dir=tmp_path / "ck", seed=3)
    with pytest.raises(SimulatedFailure):
        t1.run(6, checkpoint_every=2, failure_at=4)
    t2 = Trainer(cfg, SMOKE_SHAPE, mesh, ckpt_dir=tmp_path / "ck", seed=3)
    assert t2.maybe_restore()
    assert t2.step == 4
    rep2 = t2.run(2)

    np.testing.assert_allclose(rep2.losses, ref.losses[4:6],
                               rtol=1e-5, atol=1e-6)


def test_preempt_flag_checkpoints_and_stops(tmp_path):
    cfg = configs.get_smoke_config("xlstm-125m")
    t = Trainer(cfg, SMOKE_SHAPE, _mesh1(), ckpt_dir=tmp_path / "ck",
                seed=1)
    calls = {"n": 0}

    def flag():
        calls["n"] += 1
        return calls["n"] >= 2

    rep = t.run(10, preempt_flag=flag)
    assert rep.preempted
    assert rep.steps_run < 10
    assert latest_step(tmp_path / "ck") == t.step


MULTIDEV_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, sys
import jax
import numpy as np
from repro import configs
from repro.configs.base import ParallelCfg, ShapeCfg
from repro.runtime.train_loop import Trainer

cfg = configs.get_smoke_config("llama3.2-3b")
shape = ShapeCfg("tiny", 32, 8, "train")
ckpt = sys.argv[1]

mesh_a = jax.make_mesh((2, 4), ("data", "model"))
mesh_b = jax.make_mesh((4, 2), ("data", "model"))

# reference: 4 steps on mesh A only
t_ref = Trainer(cfg, shape, mesh_a, seed=11)
ref = t_ref.run(4)

# elastic: 2 steps on mesh A -> checkpoint -> restore on mesh B (different
# DP/TP split) -> 2 more steps
t1 = Trainer(cfg, shape, mesh_a, ckpt_dir=ckpt, seed=11)
t1.run(2)
t1.save_checkpoint()

t2 = Trainer(cfg, shape, mesh_b, ckpt_dir=ckpt, seed=11)
assert t2.maybe_restore(), "restore failed"
assert t2.step == 2
rep = t2.run(2)

# in-process live resize as well: mesh B -> mesh A
t2.resize(mesh_a)
rep2 = t2.run(1)
ok = bool(np.allclose(rep.losses, ref.losses[2:4], rtol=5e-4, atol=5e-5))
print(json.dumps({
    "elastic_losses": rep.losses, "ref_losses": ref.losses[2:4],
    "resize_loss_finite": bool(np.isfinite(rep2.losses[0])),
    "match": ok,
}))
"""


def test_cross_mesh_elastic_restore(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath("src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", MULTIDEV_SCRIPT,
                          str(tmp_path / "ck")],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["match"], res
    assert res["resize_loss_finite"]
