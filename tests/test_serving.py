"""Serving tier: weighted-fair-share scheduling (shares, starvation
guard, priority preemption), buffer pooling, async copies as scheduled
stream work, the serving-scale runtime fixes (sched_trace ring cap,
use-after-free detection on queued launches, stream retirement), and the
ServingFrontEnd coordinator's quota-based admission control."""
import numpy as np
import pytest

from repro.core import (BufferPool, HetSession, QuotaExceeded,
                        ServingFrontEnd, TranslationCache)
from repro.core import kernels_suite as suite
from repro.core.pool import size_class

RNG = np.random.default_rng(23)


def _counter_session(**kw):
    s = HetSession("vectorized", cache=TranslationCache(), **kw)
    fn = s.load(suite.persistent_counter()[0]).function()
    return s, fn


def _state(s, value=0.0):
    return s.alloc(64).copy_from_host(np.full(64, value, np.float32))


def _backlog(s, fn, streams, iters=8, launches=3):
    """Keep every stream backlogged with multi-segment launches."""
    recs = []
    for st in streams:
        for _ in range(launches):
            recs.append(fn.launch_async(
                2, 32, {"State": _state(s), "iters": iters}, stream=st))
    return recs


def _shares(s, sids):
    counts = {sid: 0 for sid in sids}
    for t in s.sched_trace:
        if t["stream"] in counts:
            counts[t["stream"]] += 1
    total = sum(counts.values()) or 1
    return {sid: c / total for sid, c in counts.items()}


# ---------------------------------------------------------------------------
# Weighted fair share
# ---------------------------------------------------------------------------

def test_weighted_shares_match_weights():
    """Over a window where all streams stay backlogged, segment service
    splits proportionally to weight (guard off: pure WFQ)."""
    s, fn = _counter_session(starvation_guard=0)
    w = {1.0: None, 2.0: None, 4.0: None}
    streams = [s.stream(weight=wt) for wt in w]
    _backlog(s, fn, streams, iters=30, launches=4)
    s.sched_trace.clear()
    s.step(140)                       # all streams still backlogged after
    shares = _shares(s, [st.sid for st in streams])
    total_w = sum(st.weight for st in streams)
    for st in streams:
        want = st.weight / total_w
        got = shares[st.sid]
        assert abs(got - want) <= 0.15 * want + 0.02, \
            f"stream {st.sid} w={st.weight}: share {got:.3f} vs {want:.3f}"
    assert s.synchronize()


def test_equal_weights_degenerate_to_round_robin():
    s, fn = _counter_session(starvation_guard=0)
    st1, st2 = s.stream(), s.stream()
    _backlog(s, fn, [st1, st2], iters=6, launches=1)
    s.sched_trace.clear()
    assert s.synchronize()
    ids = [t["stream"] for t in s.sched_trace]
    assert all(a != b for a, b in zip(ids, ids[1:])), ids


def test_late_waker_does_not_monopolize():
    """A stream that sat idle while another accumulated virtual time must
    not get a catch-up burst when it wakes (vclock sync on enqueue)."""
    s, fn = _counter_session(starvation_guard=0)
    st1, st2 = s.stream(), s.stream()
    fn.launch_async(2, 32, {"State": _state(s), "iters": 20}, stream=st1)
    s.step(10)                        # st1 runs alone for a while
    fn.launch_async(2, 32, {"State": _state(s), "iters": 20}, stream=st2)
    s.sched_trace.clear()
    s.step(10)
    ids = [t["stream"] for t in s.sched_trace]
    # st2 must not take more than ~half the window + slack
    assert ids.count(st2.sid) <= 6, ids
    assert s.synchronize()


# ---------------------------------------------------------------------------
# Starvation guard + priority
# ---------------------------------------------------------------------------

def test_zero_weight_stream_progresses_via_guard():
    s, fn = _counter_session(starvation_guard=8)
    fast = s.stream(weight=1.0)
    starved = s.stream(weight=0.0)
    _backlog(s, fn, [fast], iters=40, launches=4)
    rec = fn.launch_async(2, 32, {"State": _state(s), "iters": 4},
                          stream=starved)
    s.sched_trace.clear()
    s.step(100)
    served = [t for t in s.sched_trace if t["stream"] == starved.sid]
    assert served, "guard never served the zero-weight stream"
    assert s.synchronize()
    assert rec.finished


def test_zero_weight_stream_starves_without_guard():
    """Control: with the guard off, a zero-weight stream gets nothing
    while a weighted stream stays backlogged."""
    s, fn = _counter_session(starvation_guard=0)
    fast = s.stream(weight=1.0)
    starved = s.stream(weight=0.0)
    _backlog(s, fn, [fast], iters=40, launches=4)
    fn.launch_async(2, 32, {"State": _state(s), "iters": 4},
                    stream=starved)
    s.sched_trace.clear()
    s.step(60)
    assert not [t for t in s.sched_trace if t["stream"] == starved.sid]
    assert s.synchronize()            # ...but it drains once fast is done


def test_priority_tier_served_first_and_preempts_quantum():
    """A higher-priority stream is served ahead of lower tiers, and a
    lower-priority stream's multi-segment quantum yields at the next
    segment boundary when high-priority work arrives."""
    s, fn = _counter_session(starvation_guard=0)
    low = s.stream(priority=0, quantum=100)   # would hog without preemption
    high = s.stream(priority=5)
    fn.launch_async(2, 32, {"State": _state(s), "iters": 30}, stream=low)
    s.step(1)                         # low's quantum starts...
    fn.launch_async(2, 32, {"State": _state(s), "iters": 3}, stream=high)
    s.sched_trace.clear()
    s.step(8)
    ids = [t["stream"] for t in s.sched_trace]
    assert high.sid in ids
    first_high = ids.index(high.sid)
    # preempted promptly: high ran within the first couple of decisions,
    # and once runnable it finished before low got service again
    assert first_high <= 2, ids
    high_slots = [i for i, x in enumerate(ids) if x == high.sid]
    assert high_slots == list(range(first_high,
                                    first_high + len(high_slots))), ids
    assert s.synchronize()


# ---------------------------------------------------------------------------
# sched_trace ring (fix #1)
# ---------------------------------------------------------------------------

def test_sched_trace_is_capped_with_dropped_counter():
    s, fn = _counter_session(trace_cap=16)
    recs = _backlog(s, fn, [s.stream()], iters=20, launches=2)
    assert s.synchronize()
    assert all(r.finished for r in recs)
    assert len(s.sched_trace) <= 16
    assert s.sched_trace.cap == 16
    assert s.sched_trace.dropped > 0
    assert s.stats["sched_trace_dropped"] == s.sched_trace.dropped
    # ring keeps the *latest* entries and stays list-like
    assert s.sched_trace[-1]["node_idx"] >= 0
    assert len(s.sched_trace[:4]) == 4
    # clear() empties the window but the drop counter is cumulative
    before = s.sched_trace.dropped
    s.sched_trace.clear()
    assert len(s.sched_trace) == 0
    assert s.sched_trace.dropped == before


def test_trace_cap_env_override(monkeypatch):
    monkeypatch.setenv("HETGPU_SCHED_TRACE_CAP", "7")
    s = HetSession("vectorized", cache=TranslationCache())
    assert s.sched_trace.cap == 7


# ---------------------------------------------------------------------------
# Use-after-free on queued launches (fix #2)
# ---------------------------------------------------------------------------

def test_freed_buffer_behind_queued_launch_raises_cleanly():
    """enqueue -> free -> drain must fail loudly at materialization, not
    silently compute on freed memory — and must not wedge the stream."""
    s, fn = _counter_session()
    st = s.stream()
    keep = _state(s)
    doomed = _state(s)
    fn.launch_async(2, 32, {"State": keep, "iters": 4}, stream=st)
    bad = fn.launch_async(2, 32, {"State": doomed, "iters": 4}, stream=st)
    after = fn.launch_async(2, 32, {"State": keep, "iters": 4}, stream=st)
    doomed.free()                     # while the launch is still queued
    with pytest.raises(RuntimeError, match="freed before the launch"):
        s.synchronize()
    assert bad.cancelled and not bad.finished
    # the stream is not wedged: the rest of the queue drains
    assert s.synchronize()
    assert after.finished


def test_freed_buffer_behind_queued_copy_raises_cleanly():
    s = HetSession("vectorized", cache=TranslationCache())
    st = s.stream()
    db = s.alloc(32)
    rec = db.copy_to_host_async(stream=st)
    db.free()
    with pytest.raises(RuntimeError, match="freed before the copy"):
        s.synchronize()
    assert not rec.finished
    assert s.synchronize()            # stream drained, not wedged


# ---------------------------------------------------------------------------
# Stream retirement (fix #3)
# ---------------------------------------------------------------------------

def test_destroyed_streams_leave_the_scan_set():
    """1k drained-and-destroyed streams: the session scan set stays
    O(active), retirement is counted, and scheduling still works."""
    s, fn = _counter_session()
    for _ in range(1000):
        st = s.stream()
        st.destroy()
    assert len(s.streams) == 1        # just the default stream
    assert s.stats["streams_retired"] == 1000
    rec = fn.launch_async(2, 32, {"State": _state(s), "iters": 2})
    assert s.synchronize() and rec.finished


def test_destroy_refuses_with_pending_work():
    s, fn = _counter_session()
    st = s.stream()
    fn.launch_async(2, 32, {"State": _state(s), "iters": 4}, stream=st)
    with pytest.raises(RuntimeError, match="still pending"):
        st.destroy()
    assert st.synchronize()
    st.destroy()                      # idempotent once drained
    st.destroy()


def test_destroy_default_stream_refused():
    s = HetSession("vectorized", cache=TranslationCache())
    with pytest.raises(ValueError):
        s.default_stream.destroy()


def test_destroyed_stream_rejects_new_work():
    s, fn = _counter_session()
    st = s.stream()
    st.destroy()
    with pytest.raises(RuntimeError, match="destroyed"):
        fn.launch_async(2, 32, {"State": _state(s), "iters": 2}, stream=st)
    with pytest.raises(RuntimeError, match="destroyed"):
        st.record_event()


# ---------------------------------------------------------------------------
# Buffer pool
# ---------------------------------------------------------------------------

def test_pool_reuses_and_zeroes_backings():
    s = HetSession("vectorized", cache=TranslationCache())
    a = s.alloc(100)
    backing_id = id(a._backing)
    a.data[:] = 7.0
    a.free()
    b = s.alloc(90)                   # same size class (128)
    assert id(b._backing) == backing_id
    assert not b.data.any(), "pooled reuse must present zeroed memory"
    st = s.pool_stats()
    assert st["hits"] >= 1
    assert 0.0 <= st["reuse_rate"] <= 1.0


def test_pool_reuse_rate_converges_under_churn():
    s = HetSession("vectorized", cache=TranslationCache())
    for _ in range(300):
        s.alloc(64).free()
        s.alloc(200).free()
    assert s.pool_stats()["reuse_rate"] >= 0.90


def test_pool_respects_byte_bound():
    pool = BufferPool(max_bytes=size_class(64) * 4)   # one f32 backing
    s = HetSession("vectorized", cache=TranslationCache(), pool=pool)
    bufs = [s.alloc(64) for _ in range(4)]
    for b in bufs:
        b.free()
    st = s.pool_stats()
    assert st["pooled_bytes"] <= st["max_bytes"]
    assert st["dropped"] >= 3         # only one backing fit


def test_pool_opt_out():
    s = HetSession("vectorized", cache=TranslationCache(), pool=False)
    db = s.alloc(64)
    assert db._backing is None or not s.pool.enabled
    db.free()
    assert s.pool_stats()["hits"] == 0


def test_double_free_is_idempotent():
    s = HetSession("vectorized", cache=TranslationCache())
    db = s.alloc(64)
    db.free()
    db.free()
    assert s.pool_stats()["released"] == 1


# ---------------------------------------------------------------------------
# Async copies as scheduled stream work
# ---------------------------------------------------------------------------

def test_async_copies_run_in_stream_order():
    """A d2h enqueued before a launch observes pre-launch contents; one
    enqueued after observes the kernel's writes (CUDA stream semantics)."""
    s, fn = _counter_session()
    st = s.stream()
    db = s.alloc(64)
    init = np.full(64, 2.0, np.float32)
    up = db.copy_from_host_async(init, stream=st)
    pre = db.copy_to_host_async(stream=st)
    rec = fn.launch_async(2, 32, {"State": db, "iters": 3}, stream=st)
    post = db.copy_to_host_async(stream=st)
    assert not up.done() and not pre.done()
    assert s.synchronize()
    np.testing.assert_allclose(pre.result(), init)
    oracle = suite.persistent_counter()[1]
    np.testing.assert_allclose(
        post.result(), oracle({"State": init.copy(), "iters": 3})["State"],
        atol=1e-4, rtol=1e-4)
    assert rec.finished
    assert s.stats["async_copies"] == 3
    kinds = [t["kernel"] for t in s.sched_trace
             if t["kernel"] in ("<h2d>", "<d2h>")]
    assert kinds == ["<h2d>", "<d2h>", "<d2h>"]


def test_async_copy_competes_across_streams():
    """Copies are scheduling units: a copy on one stream interleaves with
    segments on another rather than jumping the fair-share queue."""
    s, fn = _counter_session(starvation_guard=0)
    st1, st2 = s.stream(), s.stream()
    fn.launch_async(2, 32, {"State": _state(s), "iters": 6}, stream=st1)
    db = s.alloc(64)
    recs = [db.copy_from_host_async(np.full(64, i, np.float32), stream=st2)
            for i in range(4)]
    s.sched_trace.clear()
    assert s.synchronize()
    ids = [t["stream"] for t in s.sched_trace]
    n_overlap = 2 * min(ids.count(st1.sid), ids.count(st2.sid))
    assert n_overlap >= 6, ids
    assert all(r.finished for r in recs)
    np.testing.assert_allclose(db.copy_to_host(), np.full(64, 3.0))


# ---------------------------------------------------------------------------
# ServingFrontEnd: admission control + end-to-end
# ---------------------------------------------------------------------------

def test_quota_shedding_rejects_before_enqueue():
    s, fn = _counter_session()
    front = ServingFrontEnd(s, default_quota=2)
    front.tenant("a")
    args = lambda: {"State": _state(s), "iters": 2}   # noqa: E731
    front.submit("a", fn, 2, 32, args())
    front.submit("a", fn, 2, 32, args())
    with pytest.raises(QuotaExceeded) as ei:
        front.submit("a", fn, 2, 32, args())
    assert ei.value.tenant == "a"
    t = front.tenants["a"]
    assert t.rejected == 1 and t.admitted == 2
    assert len(t.stream._q) == 2      # nothing extra was enqueued
    assert front.drain()
    assert t.completed == 2           # shedding never cancels in-flight
    front.submit("a", fn, 2, 32, args())   # admits again after drain


def test_global_cap_sheds_across_tenants():
    s, fn = _counter_session()
    front = ServingFrontEnd(s, max_inflight=3, default_quota=10)
    front.tenant("a")
    front.tenant("b")
    args = lambda: {"State": _state(s), "iters": 2}   # noqa: E731
    for name in ("a", "b", "a"):
        front.submit(name, fn, 2, 32, args())
    with pytest.raises(QuotaExceeded):
        front.submit("b", fn, 2, 32, args())
    assert front.drain()
    assert front.stats()["completed"] == 3
    assert front.stats()["rejected"] == 1


def test_serving_end_to_end_weighted_tenants():
    """Two tenants with 1:3 weights, sticky streams, correct results and
    latency accounting."""
    s, fn = _counter_session(starvation_guard=0)
    front = ServingFrontEnd(s, slo_ms=60_000)
    front.tenant("small", weight=1.0)
    front.tenant("big", weight=3.0)
    assert front.tenant("big") is front.tenants["big"]   # idempotent
    tickets = []
    for _ in range(4):
        for name in ("small", "big"):
            tickets.append(front.submit(
                name, fn, 2, 32, {"State": _state(s, 1.0), "iters": 10}))
    # measure shares over a window where both tenants stay backlogged
    s.sched_trace.clear()
    front.pump(24)
    shares = _shares(s, [front.tenants["small"].stream.sid,
                         front.tenants["big"].stream.sid])
    big = shares[front.tenants["big"].stream.sid]
    assert 0.60 <= big <= 0.90, shares
    while front.pump(16):
        pass
    assert all(t.done() for t in tickets)
    agg = front.stats()
    assert agg["completed"] == 8 and agg["inflight"] == 0
    assert agg["slo_violations"] == 0
    assert agg["p99_ms"] >= agg["p50_ms"] >= 0


def test_retire_tenant_frees_its_stream():
    s, fn = _counter_session()
    front = ServingFrontEnd(s)
    front.tenant("x")
    tk = front.submit("x", fn, 2, 32, {"State": _state(s), "iters": 2})
    with pytest.raises(RuntimeError, match="in-flight"):
        front.retire_tenant("x")
    front.drain()
    assert tk.done()
    n = len(s.streams)
    front.retire_tenant("x")
    assert len(s.streams) == n - 1
    assert "x" not in front.tenants
    front.retire_tenant("x")          # unknown tenant is a no-op


def test_submit_unknown_tenant_is_an_error():
    s, fn = _counter_session()
    front = ServingFrontEnd(s)
    with pytest.raises(KeyError):
        front.submit("ghost", fn, 2, 32, {})
