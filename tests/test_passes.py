"""Differential conformance harness for the hetIR pass pipeline.

Every kernel in the suite runs at opt level 0 and at OPT_MAX on the interp
and vectorized backends; outputs must be **bit-identical** per backend —
the pipeline may only remove/rearrange work, never change a computed bit
(passes exclude anything with backend-dependent rounding, e.g. folding
transcendentals).  Plus unit tests that each pass actually fires and
reports statistics.
"""
import numpy as np
import pytest

from repro.core import Engine, OPT_MAX, get_backend, optimize
from repro.core import hetir as ir
from repro.core import kernels_suite as suite
from repro.core.hetir import Builder, Ptr, Scalar
from repro.core.passes import (UNROLL_MAX_TRIPS, eliminate_dead_code,
                               fold_constants, fuse_fma, hoist_invariants,
                               merge_duplicates, simplify_predicates,
                               strength_reduce, unroll_loops,
                               value_number_cross_segment)
from repro.core.segments import dynamic_op_count

RNG = np.random.default_rng(7)
BACKENDS = ["interp", "vectorized"]


def _suite_cases():
    """(kernel name, grid, block, args, output buffers) for every suite
    kernel, with sizes that exercise predication (n < grid*block)."""
    M, K, N, TK = 6, 16, 16, 8
    return [
        ("vadd", 4, 32,
         {"A": RNG.normal(size=128).astype(np.float32),
          "B": RNG.normal(size=128).astype(np.float32),
          "C": np.zeros(128, np.float32), "n": 100}, ["C"]),
        ("saxpy", 3, 32,
         {"X": RNG.normal(size=96).astype(np.float32),
          "Y": RNG.normal(size=96).astype(np.float32),
          "n": 80, "a": 2.5}, ["Y"]),
        ("matmul_tiled", M, N,
         {"A": RNG.normal(size=M * K).astype(np.float32),
          "B": RNG.normal(size=K * N).astype(np.float32),
          "C": np.zeros(M * N, np.float32),
          "K": K, "N": N, "ktiles": K // TK}, ["C"]),
        ("reduction", 4, 32,
         {"A": RNG.normal(size=128).astype(np.float32),
          "Out": np.zeros(1, np.float32), "n": 100, "log2t": 5}, ["Out"]),
        ("inclusive_scan", 4, 32,
         {"A": RNG.normal(size=128).astype(np.float32),
          "Out": np.zeros(128, np.float32),
          "BlockSums": np.zeros(4, np.float32), "n": 100},
         ["Out", "BlockSums"]),
        ("bitcount_vote", 4, 32,
         {"A": RNG.normal(size=128).astype(np.float32),
          "Out": np.zeros(4, np.float32), "n": 100, "thresh": 0.1},
         ["Out"]),
        ("montecarlo_pi", 2, 32, {"Count": np.zeros(1, np.float32)},
         ["Count"]),
        ("nn_layer", 4, 8,
         {"W": RNG.normal(size=4 * 16).astype(np.float32),
          "X": RNG.normal(size=16).astype(np.float32),
          "Bias": RNG.normal(size=4).astype(np.float32),
          "Out": np.zeros(4, np.float32), "K": 16, "kchunks": 2}, ["Out"]),
        ("stencil_1d", 4, 32,
         {"A": RNG.normal(size=128).astype(np.float32),
          "Out": np.zeros(128, np.float32), "n": 120}, ["Out"]),
        ("persistent_counter", 2, 32,
         {"State": RNG.normal(size=64).astype(np.float32), "iters": 5},
         ["State"]),
        ("dot_product", 3, 32,
         {"A": RNG.normal(size=96).astype(np.float32),
          "B": RNG.normal(size=96).astype(np.float32),
          "Out": np.zeros(1, np.float32), "n": 90}, ["Out"]),
        ("poly_eval", 4, 32,
         {"X": RNG.normal(size=128).astype(np.float32),
          "Coef": RNG.normal(size=7).astype(np.float32),
          "Out": np.zeros(128, np.float32), "n": 100}, ["Out"]),
        ("swizzle_copy", 4, 32,
         {"A": RNG.normal(size=128).astype(np.float32),
          "Out": np.zeros(128, np.float32)}, ["Out"]),
        ("tap_filter", 2, 32,
         {"A": RNG.normal(size=64).astype(np.float32),
          "W": RNG.normal(size=4).astype(np.float32),
          "Tmp": np.zeros(64, np.float32),
          "Out": np.zeros(64, np.float32)}, ["Tmp", "Out"]),
        # dynamic-trip kernels: at OPT_MAX the auto specialization policy
        # binds the launch scalars, so O0 (always generic) vs OPT_MAX here
        # is also the generic-vs-specialized differential
        ("dyn_matmul", M, N,
         {"A": RNG.normal(size=M * K).astype(np.float32),
          "B": RNG.normal(size=K * N).astype(np.float32),
          "C": np.zeros(M * N, np.float32),
          "K": K, "N": N, "ktiles": K // TK, "tk": TK}, ["C"]),
        ("dyn_fir", 2, 32,
         {"A": RNG.normal(size=64).astype(np.float32),
          "W": RNG.normal(size=5).astype(np.float32),
          "Out": np.zeros(64, np.float32), "taps": 5}, ["Out"]),
        ("decode_gemv", 4, 16,
         {"W": RNG.normal(size=64 * 32).astype(np.float32),
          "X": RNG.normal(size=32).astype(np.float32),
          "R": RNG.normal(size=64).astype(np.float32),
          "Out": np.zeros(64, np.float32), "K": 32, "ktiles": 3},
         ["Out"]),
    ]


_CASES = _suite_cases()
assert {c[0] for c in _CASES} == set(suite.SUITE), \
    "conformance harness must cover every suite kernel"


def _run(name, backend, grid, block, args, level):
    prog, _ = suite.SUITE[name]()
    eng = Engine(prog, get_backend(backend), grid, block, dict(args),
                 opt_level=level)
    assert eng.run()
    return eng


# ---------------------------------------------------------------------------
# differential conformance sweep: opt 0 vs OPT_MAX must be bit-identical
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("case", _CASES, ids=[c[0] for c in _CASES])
def test_opt_levels_bit_identical(case, backend):
    name, grid, block, args, outs = case
    base = _run(name, backend, grid, block, args, level=0)
    opt = _run(name, backend, grid, block, args, level=OPT_MAX)
    for o in outs:
        np.testing.assert_array_equal(
            base.result(o), opt.result(o),
            err_msg=f"{name} on {backend}: O0 vs O{OPT_MAX} differ in {o}")


@pytest.mark.fast
def test_opt_strictly_reduces_executed_schedule_on_suite():
    """Acceptance: OPT_MAX strictly reduces the *executed-op schedule*
    (static count × trip counts — what a launch actually issues per
    thread) on >= 3 suite kernels, and never increases it.  Static op
    count is the wrong metric since phase 2: unrolling deliberately grows
    the body to shrink the schedule."""
    reduced = []
    for name, fn in suite.SUITE.items():
        prog, _ = fn()
        opt, stats = optimize(prog, OPT_MAX)
        before = dynamic_op_count(prog.body)
        after = dynamic_op_count(opt.body)
        assert after <= before, f"{name}: schedule grew {before}->{after}"
        if after < before:
            reduced.append(name)
    assert len(reduced) >= 3, f"only {reduced} shrank"


# ---------------------------------------------------------------------------
# per-pass unit tests (statistics + structural effect)
# ---------------------------------------------------------------------------


@pytest.mark.fast
def test_constant_folding_folds_and_reports():
    b = Builder("fold", [Ptr("Out"), Scalar("n")])
    i = b.global_id(0)
    c = (b.const(2.0, ir.F32) + b.const(3.0, ir.F32)) * b.const(4.0, ir.F32)
    with b.when(i < b.param("n")):
        b.store("Out", i, c)
    prog = b.done()
    opt, stats = optimize(prog, OPT_MAX)
    assert stats.per_pass["fold_constants"] >= 2  # ADD then MUL
    consts = [op for op in ir.walk_ops(opt.body) if op.opcode == ir.CONST]
    assert any(op.args[0] == 20.0 for op in consts)
    # no arithmetic left — everything folded into the stored constant
    assert not any(op.opcode in (ir.ADD, ir.MUL)
                   for op in ir.walk_ops(opt.body))
    assert stats.ops_after < stats.ops_before


@pytest.mark.fast
def test_dce_removes_unused_ops_and_reports():
    b = Builder("dead", [Ptr("A"), Ptr("Out"), Scalar("n")])
    i = b.global_id(0)
    live = b.load("A", i)
    dead = live * b.const(3.0, ir.F32)   # never stored
    dead2 = dead + live                  # transitively dead
    assert dead2 is not None
    b.store("Out", i, live)
    prog = b.done()
    opt, stats = optimize(prog, 1)       # level 1 = fold + dce only
    assert stats.per_pass["eliminate_dead_code"] >= 3
    assert ir.count_ops(opt.body) < ir.count_ops(prog.body)
    assert not any(op.opcode in (ir.MUL, ir.ADD)
                   for op in ir.walk_ops(opt.body))


@pytest.mark.fast
def test_dce_keeps_side_effects():
    b = Builder("atomic", [Ptr("Out")])
    i = b.global_id(0)
    old = b.atomic_add("Out", i, b.const(1.0, ir.F32))
    assert old is not None               # dest unused, op must survive
    prog = b.done()
    opt, _ = optimize(prog, OPT_MAX)
    assert any(op.opcode == ir.ATOMIC_ADD for op in ir.walk_ops(opt.body))


@pytest.mark.fast
def test_predicate_simplification_splices_constant_true():
    b = Builder("pred", [Ptr("A"), Ptr("Out")])
    i = b.global_id(0)
    with b.when(b.const(1.0, ir.F32) < b.const(2.0, ir.F32)):  # always true
        b.store("Out", i, b.load("A", i))
    prog = b.done()
    assert any(isinstance(s, ir.Pred) for s in prog.body)
    opt, stats = optimize(prog, OPT_MAX)
    assert stats.per_pass["simplify_predicates"] >= 1
    assert not any(isinstance(s, ir.Pred) for s in opt.body)


@pytest.mark.fast
def test_predicate_simplification_drops_constant_false():
    b = Builder("pred0", [Ptr("A"), Ptr("Out")])
    i = b.global_id(0)
    b.store("Out", i, b.load("A", i))
    with b.when(b.const(2.0, ir.F32) < b.const(1.0, ir.F32)):  # never taken
        b.store("Out", i, b.const(99.0, ir.F32))
    prog = b.done()
    opt, _ = optimize(prog, OPT_MAX)
    stores = [op for op in ir.walk_ops(opt.body)
              if op.opcode == ir.ST_GLOBAL]
    assert len(stores) == 1              # dead branch store eliminated


@pytest.mark.fast
def test_hoisting_moves_invariant_out_of_loop():
    b = Builder("hoist", [Ptr("A"), Ptr("Out"), Scalar("n"),
                          Scalar("iters")])
    i = b.global_id(0)
    acc = b.var(b.const(0.0, ir.F32), hint="acc")
    with b.loop("iters"):
        inv = b.param("n").astype(ir.F32) * b.const(2.0, ir.F32)  # invariant
        b.assign(acc, acc + inv)
        b.barrier("step")
    b.store("Out", i, acc)
    prog = b.done()
    opt, stats = optimize(prog, OPT_MAX)
    assert stats.per_pass["hoist_invariants"] >= 1
    loop = next(s for s in opt.body if isinstance(s, ir.Loop))

    def ops_in(body):
        return [op.opcode for op in ir.walk_ops(body)]

    assert ir.MUL not in ops_in(loop.body)       # moved out...
    pre = []
    for s in opt.body:
        if s is loop:
            break
        if isinstance(s, ir.Op):
            pre.append(s.opcode)
    assert ir.MUL in pre                          # ...to before the loop


@pytest.mark.fast
def test_merge_duplicates_unifies_repeated_constants():
    b = Builder("dups", [Ptr("Out")])
    i = b.global_id(0)
    b.store("Out", i, b.const(5.0, ir.F32) + b.const(5.0, ir.F32))
    prog = b.done()
    opt, stats = optimize(prog, 2)   # level 2 runs the region-scoped CSE
    assert stats.per_pass["merge_duplicates"] >= 1
    # at OPT_MAX the cross-segment value-numbering pass subsumes it
    _, stats3 = optimize(prog, OPT_MAX)
    assert stats3.per_pass["value_number_cross_segment"] >= 1


@pytest.mark.fast
def test_fma_fusion():
    b = Builder("fma", [Ptr("A"), Ptr("B"), Ptr("C"), Ptr("Out")])
    i = b.global_id(0)
    b.store("Out", i, b.load("C", i) + b.load("A", i) * b.load("B", i))
    prog = b.done()
    assert any(op.opcode == ir.MUL for op in ir.walk_ops(prog.body))
    opt, stats = optimize(prog, OPT_MAX)
    assert stats.per_pass["fuse_fma"] == 1
    ops = [op.opcode for op in ir.walk_ops(opt.body)]
    assert ir.FMA in ops and ir.MUL not in ops and ir.ADD not in ops


# ---------------------------------------------------------------------------
# phase-2 passes: unrolling, strength reduction, cross-segment VN
# ---------------------------------------------------------------------------


@pytest.mark.fast
def test_unroll_flattens_const_trip_loop_and_shrinks_schedule():
    b = Builder("unroll", [Ptr("A"), Ptr("Out")])
    i = b.global_id(0)
    acc = b.var(b.const(0.0, ir.F32), hint="acc")
    with b.loop(4, hint="u") as j:
        idx = j * b.const(3) + b.const(1)        # folds per unrolled copy
        b.assign(acc, acc + b.load("A", idx) * j.astype(ir.F32))
    # post-loop read of the loop var must see its final value (3)
    b.store("Out", i, acc + j.astype(ir.F32))
    prog = b.done()
    opt, stats = optimize(prog, OPT_MAX)
    assert stats.per_pass["unroll_loops"] >= 1
    assert not any(isinstance(s, ir.Loop) for s in opt.body)
    assert dynamic_op_count(opt.body) < dynamic_op_count(prog.body)
    # semantics: O0 vs OPT_MAX bit-identical on both quick backends
    A = np.arange(16, dtype=np.float32)
    for backend in ("interp", "vectorized"):
        res = []
        for level in (0, OPT_MAX):
            eng = Engine(prog, get_backend(backend), 1, 4,
                         {"A": A, "Out": np.zeros(4, np.float32)},
                         opt_level=level)
            assert eng.run()
            res.append(eng.result("Out"))
        np.testing.assert_array_equal(res[0], res[1])


@pytest.mark.fast
def test_unroll_skips_dynamic_big_and_barrier_loops():
    # dynamic trip count: must stay a loop
    b = Builder("dyn", [Ptr("Out"), Scalar("m")])
    i = b.global_id(0)
    acc = b.var(b.const(0.0, ir.F32), hint="acc")
    with b.loop("m"):
        b.assign(acc, acc + b.const(1.0, ir.F32))
    b.store("Out", i, acc)
    opt, _ = optimize(b.done(), OPT_MAX)
    assert any(isinstance(s, ir.Loop) for s in opt.body)

    # above the trip threshold: must stay a loop
    b = Builder("big", [Ptr("Out")])
    i = b.global_id(0)
    acc = b.var(b.const(0.0, ir.F32), hint="acc")
    with b.loop(UNROLL_MAX_TRIPS + 1):
        b.assign(acc, acc + b.const(1.0, ir.F32))
    b.store("Out", i, acc)
    opt, _ = optimize(b.done(), OPT_MAX)
    assert any(isinstance(s, ir.Loop) for s in opt.body)

    # barrier-carrying loop: its iterations are engine segments — never
    # unrolled, or checkpoints inside it would lose their anchor
    b = Builder("barloop", [Ptr("Out")])
    i = b.global_id(0)
    with b.loop(3) as j:
        b.store("Out", i, j.astype(ir.F32))
        b.barrier("step")
    opt, _ = optimize(b.done(), OPT_MAX)
    assert any(isinstance(s, ir.Loop) for s in opt.body)


@pytest.mark.fast
def test_unroll_preserves_predicated_loop_carry():
    """A register defined under a @PRED inside the loop body legally
    *carries* its value into iterations where the predicate is false.
    Unrolling must not rename it per copy — a renamed later copy would
    read a never-written register (review-found miscompile)."""
    b = Builder("carry", [Ptr("A"), Ptr("Out")])
    i = b.global_id(0)
    acc = b.var(b.const(0.0, ir.F32), hint="acc")
    t = None
    with b.loop(4, hint="c") as j:
        with b.when(j < b.const(2)):       # writes only in iterations 0-1
            t = b.load("A", i)
        b.assign(acc, acc + t)             # iterations 2-3 read the carry
    b.store("Out", i, acc)
    prog = b.done()
    A = np.arange(1, 5, dtype=np.float32)
    expect = A * 4                         # t carried through trips 2-3
    for backend in BACKENDS:
        for level in (0, OPT_MAX):
            eng = Engine(prog, get_backend(backend), 1, 4,
                         {"A": A, "Out": np.zeros(4, np.float32)},
                         opt_level=level)
            assert eng.run()
            np.testing.assert_array_equal(
                eng.result("Out"), expect,
                err_msg=f"{backend} O{level} lost the predicated carry")
    # the loop still unrolled — the carried register just kept its name
    opt, stats = optimize(prog, OPT_MAX)
    assert stats.per_pass["unroll_loops"] >= 1
    assert not any(isinstance(s, ir.Loop) for s in opt.body)


@pytest.mark.fast
def test_strength_reduction_rewrites_pow2_and_keeps_odd():
    b = Builder("sr", [Ptr("A"), Ptr("Out")])
    i = b.global_id(0)
    v = b.load("A", i % b.const(16))          # -> AND
    a = i * b.const(8)                        # -> SHL
    q = i / b.const(4)                        # -> SHR
    m = i % b.const(2)                        # -> AND
    f = v / b.const(2.0, ir.F32)              # -> MUL by 0.5
    g = v / b.const(3.0, ir.F32)              # stays: 1/3 is inexact
    h = i / b.const(6)                        # stays: not a power of two
    b.store("Out", i, (a + q + m + h).astype(ir.F32) + f + g)
    prog = b.done()
    opt, stats = optimize(prog, OPT_MAX)
    assert stats.per_pass["strength_reduce"] >= 5
    ops = [op.opcode for op in ir.walk_ops(opt.body)]
    assert ir.SHL in ops and ir.SHR in ops and ir.AND in ops
    assert ops.count(ir.DIV) == 2           # the two irreducible divides
    assert ir.MOD not in ops
    # semantics: levels agree bit-exactly on both quick backends
    A = RNG.normal(size=16).astype(np.float32)
    for backend in BACKENDS:
        res = []
        for level in (0, OPT_MAX):
            eng = Engine(prog, get_backend(backend), 1, 8,
                         {"A": A, "Out": np.zeros(8, np.float32)},
                         opt_level=level)
            assert eng.run()
            res.append(eng.result("Out"))
        np.testing.assert_array_equal(res[0], res[1])


@pytest.mark.fast
def test_int_div_or_mod_by_zero_never_folds():
    """numpy folds int x/0 to 0 but XLA computes a platform value — the
    fold guard must leave it for the backend so O0 and OPT_MAX agree."""
    for opcode in ("div", "mod"):
        b = Builder(f"z{opcode}", [Ptr("Out")])
        tid = b.thread_id()
        c5, c0 = b.const(5), b.const(0)
        q = c5 / c0 if opcode == "div" else c5 % c0
        b.store("Out", tid, q.astype(ir.F32))
        prog = b.done()
        opt, _ = optimize(prog, OPT_MAX)
        assert any(op.opcode in (ir.DIV, ir.MOD)
                   for op in ir.walk_ops(opt.body))
        for backend in BACKENDS:
            res = []
            for level in (0, OPT_MAX):
                eng = Engine(prog, get_backend(backend), 1, 4,
                             {"Out": np.zeros(4, np.float32)},
                             opt_level=level)
                assert eng.run()
                res.append(eng.result("Out"))
            np.testing.assert_array_equal(res[0], res[1])


@pytest.mark.fast
def test_cross_segment_vn_merges_across_guaranteed_loop():
    def build(count):
        b = Builder("vnx", [Ptr("Out"), Scalar("m")])
        tid = b.thread_id()
        with b.loop(count, hint="L"):
            q = tid / b.const(3)            # DIV: hoisting refuses it
            b.store("Out", tid, q.astype(ir.F32))
        q2 = tid / b.const(3)               # only cross-loop VN merges this
        b.store("Out", tid, q2.astype(ir.F32))
        return b.done()

    def divs(body):
        return sum(1 for op in ir.walk_ops(body) if op.opcode == ir.DIV)

    static = build(3)
    body, n = value_number_cross_segment(list(static.body), static)
    assert n >= 2 and divs(body) == 1       # CONST + DIV (+CVT) merged
    # region-scoped CSE must NOT merge it (documents the new capability)
    body, _ = merge_duplicates(list(static.body), static)
    assert divs(body) == 2
    # dynamic trip count: possibly zero-trip, must stay conservative
    dyn = build("m")
    body, _ = value_number_cross_segment(list(dyn.body), dyn)
    assert divs(body) == 2


@pytest.mark.fast
def test_loop_heavy_kernels_shrink_executed_schedule():
    """The phase-2 acceptance numbers: unrolling + folding measurably
    shrink the executed schedule of the loop-heavy suite kernels."""
    for name in ("poly_eval", "tap_filter", "matmul_tiled"):
        prog, _ = suite.SUITE[name]()
        opt, _ = optimize(prog, OPT_MAX)
        before = dynamic_op_count(prog.body)
        after = dynamic_op_count(opt.body)
        assert after < before, f"{name}: {before} -> {after}"


@pytest.mark.fast
def test_optimize_is_deterministic_and_validates():
    for name, fn in suite.SUITE.items():
        prog_a, _ = fn()
        prog_b, _ = fn()
        opt_a, _ = optimize(prog_a, OPT_MAX)
        opt_b, _ = optimize(prog_b, OPT_MAX)
        # deterministic pipeline -> identical fingerprints (this is what
        # makes cross-backend snapshot restore at the same level sound)
        assert ir.program_fingerprint(opt_a) == ir.program_fingerprint(opt_b)
        opt_a.validate()


@pytest.mark.fast
def test_level0_is_identity():
    prog, _ = suite.vadd()
    opt, stats = optimize(prog, 0)
    assert ir.program_fingerprint(opt) == ir.program_fingerprint(prog)
    assert stats.ops_removed == 0


# ---------------------------------------------------------------------------
# divergence-masking regressions: values written under a @PRED are only
# defined for active threads at level 0 (interp masks register writes), so
# no pass may unconditionalize such a write or rename an escaping read
# ---------------------------------------------------------------------------


def _run_interp_levels(prog, out="Out", n=4):
    res = []
    for level in (0, OPT_MAX):
        eng = Engine(prog, get_backend("interp"), 1, n,
                     {out: np.zeros(n, np.float32)}, opt_level=level)
        assert eng.run()
        res.append(eng.result(out))
    return res


@pytest.mark.fast
def test_pred_constant_does_not_escape_its_region():
    b = Builder("escape1", [Ptr("Out")])
    tid = b.thread_id()
    with b.when(tid < b.const(1)):
        c = b.const(True, ir.BOOL)
    with b.when(c):  # cond only written for thread 0 at level 0
        b.store("Out", tid, b.const(1.0, ir.F32))
    base, opt = _run_interp_levels(b.done())
    np.testing.assert_array_equal(base, opt)
    np.testing.assert_array_equal(base, [1, 0, 0, 0])


@pytest.mark.fast
def test_hoisting_never_lifts_out_of_predicates():
    b = Builder("escape2", [Ptr("Out")])
    tid = b.thread_id()
    cond = tid < b.const(1)
    av = b.const(2.0, ir.F32)
    x = None
    with b.loop(1):
        with b.when(cond):
            x = av + av  # loop-invariant but divergence-masked
    b.store("Out", tid, x)
    base, opt = _run_interp_levels(b.done())
    np.testing.assert_array_equal(base, opt)
    np.testing.assert_array_equal(base, [4, 0, 0, 0])


@pytest.mark.fast
def test_cse_keeps_pred_nested_dup_whose_dest_escapes():
    b = Builder("escape3", [Ptr("Out")])
    tid = b.thread_id()
    b.store("Out", tid, b.const(5.0, ir.F32))
    with b.when(tid < b.const(1)):
        c1 = b.const(5.0, ir.F32)  # duplicate, but c1 is read outside
    b.store("Out", tid, c1)
    base, opt = _run_interp_levels(b.done())
    np.testing.assert_array_equal(base, opt)
    np.testing.assert_array_equal(base, [5, 0, 0, 0])


@pytest.mark.fast
def test_nan_minmax_never_folds():
    b = Builder("nanmin", [Ptr("Out")])
    tid = b.thread_id()
    b.store("Out", tid,
            b.minimum(b.const(1.0, ir.F32), b.const(float("nan"), ir.F32)))
    prog = b.done()
    for backend in ("interp", "vectorized"):
        res = []
        for level in (0, OPT_MAX):
            eng = Engine(prog, get_backend(backend), 1, 4,
                         {"Out": np.zeros(4, np.float32)}, opt_level=level)
            assert eng.run()
            res.append(eng.result("Out"))
        # per-backend NaN behaviour differs, but levels must agree
        np.testing.assert_array_equal(res[0], res[1])


def _direct_pass_smoke():
    # each pass callable runs standalone on a raw body (API stability)
    prog, _ = suite.matmul_tiled()
    body = list(prog.body)
    for p in (unroll_loops, fold_constants, simplify_predicates,
              hoist_invariants, value_number_cross_segment, strength_reduce,
              merge_duplicates, fuse_fma, eliminate_dead_code):
        body, n = p(body, prog)
        assert n >= 0
    return body


@pytest.mark.fast
def test_passes_compose_directly():
    body = _direct_pass_smoke()
    # unrolling may grow the static body; the executed schedule never grows
    assert dynamic_op_count(body) <= \
        dynamic_op_count(suite.matmul_tiled()[0].body)
