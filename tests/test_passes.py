"""Differential conformance harness for the hetIR pass pipeline.

Every kernel in the suite runs at opt level 0 and at OPT_MAX on the interp
and vectorized backends; outputs must be **bit-identical** per backend —
the pipeline may only remove/rearrange work, never change a computed bit
(passes exclude anything with backend-dependent rounding, e.g. folding
transcendentals).  Plus unit tests that each pass actually fires and
reports statistics.
"""
import numpy as np
import pytest

from repro.core import Engine, OPT_MAX, get_backend, optimize
from repro.core import hetir as ir
from repro.core import kernels_suite as suite
from repro.core.hetir import Builder, Ptr, Scalar
from repro.core.passes import (eliminate_dead_code, fold_constants,
                               fuse_fma, hoist_invariants,
                               merge_duplicates, simplify_predicates)

RNG = np.random.default_rng(7)
BACKENDS = ["interp", "vectorized"]


def _suite_cases():
    """(kernel name, grid, block, args, output buffers) for every suite
    kernel, with sizes that exercise predication (n < grid*block)."""
    M, K, N, TK = 6, 16, 16, 8
    return [
        ("vadd", 4, 32,
         {"A": RNG.normal(size=128).astype(np.float32),
          "B": RNG.normal(size=128).astype(np.float32),
          "C": np.zeros(128, np.float32), "n": 100}, ["C"]),
        ("saxpy", 3, 32,
         {"X": RNG.normal(size=96).astype(np.float32),
          "Y": RNG.normal(size=96).astype(np.float32),
          "n": 80, "a": 2.5}, ["Y"]),
        ("matmul_tiled", M, N,
         {"A": RNG.normal(size=M * K).astype(np.float32),
          "B": RNG.normal(size=K * N).astype(np.float32),
          "C": np.zeros(M * N, np.float32),
          "K": K, "N": N, "ktiles": K // TK}, ["C"]),
        ("reduction", 4, 32,
         {"A": RNG.normal(size=128).astype(np.float32),
          "Out": np.zeros(1, np.float32), "n": 100, "log2t": 5}, ["Out"]),
        ("inclusive_scan", 4, 32,
         {"A": RNG.normal(size=128).astype(np.float32),
          "Out": np.zeros(128, np.float32),
          "BlockSums": np.zeros(4, np.float32), "n": 100},
         ["Out", "BlockSums"]),
        ("bitcount_vote", 4, 32,
         {"A": RNG.normal(size=128).astype(np.float32),
          "Out": np.zeros(4, np.float32), "n": 100, "thresh": 0.1},
         ["Out"]),
        ("montecarlo_pi", 2, 32, {"Count": np.zeros(1, np.float32)},
         ["Count"]),
        ("nn_layer", 4, 8,
         {"W": RNG.normal(size=4 * 16).astype(np.float32),
          "X": RNG.normal(size=16).astype(np.float32),
          "Bias": RNG.normal(size=4).astype(np.float32),
          "Out": np.zeros(4, np.float32), "K": 16, "kchunks": 2}, ["Out"]),
        ("stencil_1d", 4, 32,
         {"A": RNG.normal(size=128).astype(np.float32),
          "Out": np.zeros(128, np.float32), "n": 120}, ["Out"]),
        ("persistent_counter", 2, 32,
         {"State": RNG.normal(size=64).astype(np.float32), "iters": 5},
         ["State"]),
        ("dot_product", 3, 32,
         {"A": RNG.normal(size=96).astype(np.float32),
          "B": RNG.normal(size=96).astype(np.float32),
          "Out": np.zeros(1, np.float32), "n": 90}, ["Out"]),
    ]


_CASES = _suite_cases()
assert {c[0] for c in _CASES} == set(suite.SUITE), \
    "conformance harness must cover every suite kernel"


def _run(name, backend, grid, block, args, level):
    prog, _ = suite.SUITE[name]()
    eng = Engine(prog, get_backend(backend), grid, block, dict(args),
                 opt_level=level)
    assert eng.run()
    return eng


# ---------------------------------------------------------------------------
# differential conformance sweep: opt 0 vs OPT_MAX must be bit-identical
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("case", _CASES, ids=[c[0] for c in _CASES])
def test_opt_levels_bit_identical(case, backend):
    name, grid, block, args, outs = case
    base = _run(name, backend, grid, block, args, level=0)
    opt = _run(name, backend, grid, block, args, level=OPT_MAX)
    for o in outs:
        np.testing.assert_array_equal(
            base.result(o), opt.result(o),
            err_msg=f"{name} on {backend}: O0 vs O{OPT_MAX} differ in {o}")


@pytest.mark.fast
def test_opt_strictly_reduces_op_count_on_suite():
    """Acceptance: OPT_MAX strictly reduces static op count on >= 3 suite
    kernels (it currently does on most of them)."""
    reduced = []
    for name, fn in suite.SUITE.items():
        prog, _ = fn()
        _, stats = optimize(prog, OPT_MAX)
        assert stats.ops_after <= stats.ops_before
        if stats.ops_after < stats.ops_before:
            reduced.append(name)
    assert len(reduced) >= 3, f"only {reduced} shrank"


# ---------------------------------------------------------------------------
# per-pass unit tests (statistics + structural effect)
# ---------------------------------------------------------------------------


@pytest.mark.fast
def test_constant_folding_folds_and_reports():
    b = Builder("fold", [Ptr("Out"), Scalar("n")])
    i = b.global_id(0)
    c = (b.const(2.0, ir.F32) + b.const(3.0, ir.F32)) * b.const(4.0, ir.F32)
    with b.when(i < b.param("n")):
        b.store("Out", i, c)
    prog = b.done()
    opt, stats = optimize(prog, OPT_MAX)
    assert stats.per_pass["fold_constants"] >= 2  # ADD then MUL
    consts = [op for op in ir.walk_ops(opt.body) if op.opcode == ir.CONST]
    assert any(op.args[0] == 20.0 for op in consts)
    # no arithmetic left — everything folded into the stored constant
    assert not any(op.opcode in (ir.ADD, ir.MUL)
                   for op in ir.walk_ops(opt.body))
    assert stats.ops_after < stats.ops_before


@pytest.mark.fast
def test_dce_removes_unused_ops_and_reports():
    b = Builder("dead", [Ptr("A"), Ptr("Out"), Scalar("n")])
    i = b.global_id(0)
    live = b.load("A", i)
    dead = live * b.const(3.0, ir.F32)   # never stored
    dead2 = dead + live                  # transitively dead
    assert dead2 is not None
    b.store("Out", i, live)
    prog = b.done()
    opt, stats = optimize(prog, 1)       # level 1 = fold + dce only
    assert stats.per_pass["eliminate_dead_code"] >= 3
    assert ir.count_ops(opt.body) < ir.count_ops(prog.body)
    assert not any(op.opcode in (ir.MUL, ir.ADD)
                   for op in ir.walk_ops(opt.body))


@pytest.mark.fast
def test_dce_keeps_side_effects():
    b = Builder("atomic", [Ptr("Out")])
    i = b.global_id(0)
    old = b.atomic_add("Out", i, b.const(1.0, ir.F32))
    assert old is not None               # dest unused, op must survive
    prog = b.done()
    opt, _ = optimize(prog, OPT_MAX)
    assert any(op.opcode == ir.ATOMIC_ADD for op in ir.walk_ops(opt.body))


@pytest.mark.fast
def test_predicate_simplification_splices_constant_true():
    b = Builder("pred", [Ptr("A"), Ptr("Out")])
    i = b.global_id(0)
    with b.when(b.const(1.0, ir.F32) < b.const(2.0, ir.F32)):  # always true
        b.store("Out", i, b.load("A", i))
    prog = b.done()
    assert any(isinstance(s, ir.Pred) for s in prog.body)
    opt, stats = optimize(prog, OPT_MAX)
    assert stats.per_pass["simplify_predicates"] >= 1
    assert not any(isinstance(s, ir.Pred) for s in opt.body)


@pytest.mark.fast
def test_predicate_simplification_drops_constant_false():
    b = Builder("pred0", [Ptr("A"), Ptr("Out")])
    i = b.global_id(0)
    b.store("Out", i, b.load("A", i))
    with b.when(b.const(2.0, ir.F32) < b.const(1.0, ir.F32)):  # never taken
        b.store("Out", i, b.const(99.0, ir.F32))
    prog = b.done()
    opt, _ = optimize(prog, OPT_MAX)
    stores = [op for op in ir.walk_ops(opt.body)
              if op.opcode == ir.ST_GLOBAL]
    assert len(stores) == 1              # dead branch store eliminated


@pytest.mark.fast
def test_hoisting_moves_invariant_out_of_loop():
    b = Builder("hoist", [Ptr("A"), Ptr("Out"), Scalar("n"),
                          Scalar("iters")])
    i = b.global_id(0)
    acc = b.var(b.const(0.0, ir.F32), hint="acc")
    with b.loop("iters"):
        inv = b.param("n").astype(ir.F32) * b.const(2.0, ir.F32)  # invariant
        b.assign(acc, acc + inv)
        b.barrier("step")
    b.store("Out", i, acc)
    prog = b.done()
    opt, stats = optimize(prog, OPT_MAX)
    assert stats.per_pass["hoist_invariants"] >= 1
    loop = next(s for s in opt.body if isinstance(s, ir.Loop))

    def ops_in(body):
        return [op.opcode for op in ir.walk_ops(body)]

    assert ir.MUL not in ops_in(loop.body)       # moved out...
    pre = []
    for s in opt.body:
        if s is loop:
            break
        if isinstance(s, ir.Op):
            pre.append(s.opcode)
    assert ir.MUL in pre                          # ...to before the loop


@pytest.mark.fast
def test_merge_duplicates_unifies_repeated_constants():
    b = Builder("dups", [Ptr("Out")])
    i = b.global_id(0)
    b.store("Out", i, b.const(5.0, ir.F32) + b.const(5.0, ir.F32))
    prog = b.done()
    opt, stats = optimize(prog, OPT_MAX)
    assert stats.per_pass["merge_duplicates"] >= 1


@pytest.mark.fast
def test_fma_fusion():
    b = Builder("fma", [Ptr("A"), Ptr("B"), Ptr("C"), Ptr("Out")])
    i = b.global_id(0)
    b.store("Out", i, b.load("C", i) + b.load("A", i) * b.load("B", i))
    prog = b.done()
    assert any(op.opcode == ir.MUL for op in ir.walk_ops(prog.body))
    opt, stats = optimize(prog, OPT_MAX)
    assert stats.per_pass["fuse_fma"] == 1
    ops = [op.opcode for op in ir.walk_ops(opt.body)]
    assert ir.FMA in ops and ir.MUL not in ops and ir.ADD not in ops


@pytest.mark.fast
def test_optimize_is_deterministic_and_validates():
    for name, fn in suite.SUITE.items():
        prog_a, _ = fn()
        prog_b, _ = fn()
        opt_a, _ = optimize(prog_a, OPT_MAX)
        opt_b, _ = optimize(prog_b, OPT_MAX)
        # deterministic pipeline -> identical fingerprints (this is what
        # makes cross-backend snapshot restore at the same level sound)
        assert ir.program_fingerprint(opt_a) == ir.program_fingerprint(opt_b)
        opt_a.validate()


@pytest.mark.fast
def test_level0_is_identity():
    prog, _ = suite.vadd()
    opt, stats = optimize(prog, 0)
    assert ir.program_fingerprint(opt) == ir.program_fingerprint(prog)
    assert stats.ops_removed == 0


# ---------------------------------------------------------------------------
# divergence-masking regressions: values written under a @PRED are only
# defined for active threads at level 0 (interp masks register writes), so
# no pass may unconditionalize such a write or rename an escaping read
# ---------------------------------------------------------------------------


def _run_interp_levels(prog, out="Out", n=4):
    res = []
    for level in (0, OPT_MAX):
        eng = Engine(prog, get_backend("interp"), 1, n,
                     {out: np.zeros(n, np.float32)}, opt_level=level)
        assert eng.run()
        res.append(eng.result(out))
    return res


@pytest.mark.fast
def test_pred_constant_does_not_escape_its_region():
    b = Builder("escape1", [Ptr("Out")])
    tid = b.thread_id()
    with b.when(tid < b.const(1)):
        c = b.const(True, ir.BOOL)
    with b.when(c):  # cond only written for thread 0 at level 0
        b.store("Out", tid, b.const(1.0, ir.F32))
    base, opt = _run_interp_levels(b.done())
    np.testing.assert_array_equal(base, opt)
    np.testing.assert_array_equal(base, [1, 0, 0, 0])


@pytest.mark.fast
def test_hoisting_never_lifts_out_of_predicates():
    b = Builder("escape2", [Ptr("Out")])
    tid = b.thread_id()
    cond = tid < b.const(1)
    av = b.const(2.0, ir.F32)
    x = None
    with b.loop(1):
        with b.when(cond):
            x = av + av  # loop-invariant but divergence-masked
    b.store("Out", tid, x)
    base, opt = _run_interp_levels(b.done())
    np.testing.assert_array_equal(base, opt)
    np.testing.assert_array_equal(base, [4, 0, 0, 0])


@pytest.mark.fast
def test_cse_keeps_pred_nested_dup_whose_dest_escapes():
    b = Builder("escape3", [Ptr("Out")])
    tid = b.thread_id()
    b.store("Out", tid, b.const(5.0, ir.F32))
    with b.when(tid < b.const(1)):
        c1 = b.const(5.0, ir.F32)  # duplicate, but c1 is read outside
    b.store("Out", tid, c1)
    base, opt = _run_interp_levels(b.done())
    np.testing.assert_array_equal(base, opt)
    np.testing.assert_array_equal(base, [5, 0, 0, 0])


@pytest.mark.fast
def test_nan_minmax_never_folds():
    b = Builder("nanmin", [Ptr("Out")])
    tid = b.thread_id()
    b.store("Out", tid,
            b.minimum(b.const(1.0, ir.F32), b.const(float("nan"), ir.F32)))
    prog = b.done()
    for backend in ("interp", "vectorized"):
        res = []
        for level in (0, OPT_MAX):
            eng = Engine(prog, get_backend(backend), 1, 4,
                         {"Out": np.zeros(4, np.float32)}, opt_level=level)
            assert eng.run()
            res.append(eng.result("Out"))
        # per-backend NaN behaviour differs, but levels must agree
        np.testing.assert_array_equal(res[0], res[1])


def _direct_pass_smoke():
    # each pass callable runs standalone on a raw body (API stability)
    prog, _ = suite.matmul_tiled()
    body = list(prog.body)
    for p in (fold_constants, simplify_predicates, hoist_invariants,
              merge_duplicates, fuse_fma, eliminate_dead_code):
        body, n = p(body, prog)
        assert n >= 0
    return body


@pytest.mark.fast
def test_passes_compose_directly():
    body = _direct_pass_smoke()
    assert ir.count_ops(body) <= ir.count_ops(suite.matmul_tiled()[0].body)
