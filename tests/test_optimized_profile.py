"""Integration: every architecture trains and decodes under the optimized
§Perf profile (chunked mLSTM + grouped MoE + flash attention VJP)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import decode_step, forward_train, init_params, prefill

ARCHS = configs.list_archs()
B, S = 2, 32


def _batch(cfg, rng):
    if cfg.encoder_decoder:
        return {"enc_embeds": jnp.asarray(
                    rng.normal(size=(B, S, cfg.d_model)) * 0.02,
                    jnp.dtype(cfg.compute_dtype)),
                "tokens": jnp.asarray(
                    rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)}
    if cfg.frontend == "patch":
        F = cfg.frontend_tokens
        return {"embeds": jnp.asarray(
                    rng.normal(size=(B, F, cfg.d_model)) * 0.02,
                    jnp.dtype(cfg.compute_dtype)),
                "tokens": jnp.asarray(
                    rng.integers(0, cfg.vocab_size, (B, S - F)),
                    jnp.int32)}
    return {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)}


@pytest.mark.parametrize("arch", ARCHS)
def test_optimized_profile_trains_and_decodes(arch):
    cfg = configs.get_optimized_smoke_config(arch)
    rng = np.random.default_rng(5)
    params = init_params(jax.random.key(5), cfg)
    batch = _batch(cfg, rng)

    loss, grads = jax.jit(jax.value_and_grad(
        lambda p, b: forward_train(p, b, cfg)))(params, batch)
    assert jnp.isfinite(loss), f"{arch}: optimized-profile loss not finite"

    logits, caches = prefill(params, batch, cfg, cache_len=S + 2)
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    logits2, _ = decode_step(params, tok, caches,
                             jnp.asarray(S, jnp.int32), cfg)
    assert bool(jnp.isfinite(logits2).all()), f"{arch}: decode NaN"
