"""Launch-time specialization + alias-aware memory motion tests.

Covers the specialization subsystem end to end: policy decisions
(``HETGPU_SPECIALIZE`` modes, per-program budget with generic fallback),
cache-key properties (same scalars → warm hit; different scalars →
distinct entries; specialized and generic entries coexist), persistence
(cross-instance DiskStore restore of a specialized artifact), snapshots
(the spec key rides ``to_bytes``/``from_bytes`` and a mid-kernel
checkpoint+migrate of a specialized program restores bit-identical), and
the acceptance bar on the dynamic-trip suite kernels (executed-op
reduction > 0, ≥ 15 % interp step cut, bit-identical outputs).  Plus unit
tests for the affine may-alias analysis and the
``hoist_invariant_loads`` pass it gates.
"""
import numpy as np
import pytest

from repro.core import (DiskStore, Engine, HetSession, OPT_MAX, Snapshot,
                        TranslationCache, get_backend, get_specialized,
                        migrate, optimize)
from repro.core import hetir as ir
from repro.core import kernels_suite as suite
from repro.core.alias import AffineIndex, affine_env, may_alias
from repro.core.hetir import Builder, Ptr, Scalar
from repro.core.passes import hoist_invariant_loads

RNG = np.random.default_rng(11)


def _fir_args(taps=4, n=64):
    return {"A": RNG.normal(size=n).astype(np.float32),
            "W": RNG.normal(size=max(taps, 1)).astype(np.float32),
            "Out": np.zeros(n, np.float32), "taps": taps}


def _matmul_args(M=4, K=32, N=16, TK=8):
    return {"A": RNG.normal(size=M * K).astype(np.float32),
            "B": RNG.normal(size=K * N).astype(np.float32),
            "C": np.zeros(M * N, np.float32),
            "K": K, "N": N, "ktiles": K // TK, "tk": TK}


def _run(prog, backend, grid, block, args, specialize=None, cache=None):
    eng = Engine(prog, get_backend(backend, cache=cache), grid, block,
                 dict(args), opt_level=OPT_MAX, specialize=specialize)
    assert eng.run()
    return eng


# ---------------------------------------------------------------------------
# policy
# ---------------------------------------------------------------------------


def test_auto_policy_specializes_dynamic_trip_programs():
    prog, _ = suite.dyn_fir()
    eng = _run(prog, "interp", 2, 32, _fir_args())
    # shape-aware key (PR 8): buffer shapes ride as inert "#shape" entries
    # alongside the bound scalars
    assert ("taps", 4) in eng.spec_key
    assert ("A#shape", 64) in eng.spec_key
    assert ("W#shape", 4) in eng.spec_key
    assert eng.opt_stats.per_pass.get("bind_launch_scalars", 0) >= 1
    assert eng.opt_stats.spec_key == eng.spec_key


def test_auto_policy_leaves_static_programs_generic():
    """vadd has no dynamic-trip loop: auto must not mint a variant (its
    generic translations stay shared across all scalar values)."""
    prog, _ = suite.vadd()
    eng = _run(prog, "interp", 2, 32,
               {"A": np.zeros(64, np.float32), "B": np.zeros(64, np.float32),
                "C": np.zeros(64, np.float32), "n": 64})
    assert eng.spec_key == ()


def test_policy_off_falls_back_to_generic(monkeypatch):
    monkeypatch.setenv("HETGPU_SPECIALIZE", "off")
    prog, _ = suite.dyn_fir()
    eng = _run(prog, "interp", 2, 32, _fir_args())
    assert eng.spec_key == ()
    assert eng.opt_stats.per_pass.get("bind_launch_scalars", 0) == 0
    # the generic variant is shared: a second launch reuses the memoized
    # optimized program object (and hence its cache keys)
    eng2 = _run(prog, "interp", 2, 32, _fir_args(taps=3))
    assert eng2.program is eng.program


def test_explicit_override_beats_env(monkeypatch):
    monkeypatch.setenv("HETGPU_SPECIALIZE", "off")
    prog, _ = suite.dyn_fir()
    eng = _run(prog, "interp", 2, 32, _fir_args(), specialize=True)
    assert eng.spec_key != ()
    monkeypatch.setenv("HETGPU_SPECIALIZE", "all")
    prog2, _ = suite.dyn_fir()
    eng2 = _run(prog2, "interp", 2, 32, _fir_args(), specialize=False)
    assert eng2.spec_key == ()


def test_budget_exhaustion_falls_back_to_generic(monkeypatch):
    monkeypatch.setenv("HETGPU_SPECIALIZE_BUDGET", "2")
    prog, _ = suite.dyn_fir()
    keys = []
    for taps in (1, 2, 3, 4):
        eng = _run(prog, "interp", 2, 32, _fir_args(taps=taps))
        keys.append(eng.spec_key)
    assert keys[0] != () and keys[1] != ()
    assert keys[2] == () and keys[3] == (), \
        "budget exceeded: launches must fall back to the generic variant"
    # an admitted binding keeps specializing (warm variants stay warm)
    eng = _run(prog, "interp", 2, 32, _fir_args(taps=1))
    assert eng.spec_key == keys[0]
    # an explicit per-launch demand bypasses the budget (the budget
    # polices the ambient policy, not deliberate requests)
    eng = _run(prog, "interp", 2, 32, _fir_args(taps=9), specialize=True)
    assert ("taps", 9) in eng.spec_key


def test_warmup_with_synthesized_args_stays_generic(tmp_path):
    """warmup() without example args must not specialize on its made-up
    unit scalars: that would warm a variant no real launch asks for and
    burn a budget slot.  The generic entries it warms instead are shared;
    a real specialized launch afterwards translates only its own body."""
    prog, _ = suite.dyn_fir()
    s = HetSession("interp", specialize=True,
                   cache=TranslationCache(store=DiskStore(tmp_path)))
    rep = s.warmup([prog], grids=((2, 32),))
    assert rep["errors"] == 0
    assert prog.__dict__.get("_spec_variants", {}) == {}, \
        "synthesized warmup consumed a specialization budget slot"
    assert s.stats.get("specialized_launches", 0) == 0


# ---------------------------------------------------------------------------
# cache-key properties
# ---------------------------------------------------------------------------


def test_same_scalars_hit_the_specialized_entries():
    prog, _ = suite.dyn_fir()
    cache = TranslationCache()
    args = _fir_args()
    _run(prog, "interp", 2, 32, args, cache=cache)
    translated = cache.stats()["translated"]
    assert translated >= 1
    _run(prog, "interp", 2, 32, args, cache=cache)
    st = cache.stats()
    assert st["translated"] == translated, "relaunch re-translated"
    assert st["hits"] >= 1


def test_different_scalars_get_distinct_entries():
    prog, _ = suite.dyn_fir()
    cache = TranslationCache()
    e3 = _run(prog, "interp", 2, 32, _fir_args(taps=3), cache=cache)
    n3 = cache.size("interp")
    e4 = _run(prog, "interp", 2, 32, _fir_args(taps=4), cache=cache)
    assert e3.spec_key != e4.spec_key
    assert cache.size("interp") > n3, \
        "a different binding must translate its own entries"
    assert cache.stats()["translated"] >= 2


def test_generic_and_specialized_entries_coexist():
    prog, _ = suite.dyn_fir()
    cache = TranslationCache()
    args = _fir_args()
    eg = _run(prog, "interp", 2, 32, args, specialize=False, cache=cache)
    n_generic = cache.size("interp")
    es = _run(prog, "interp", 2, 32, args, specialize=True, cache=cache)
    assert eg.spec_key == () and es.spec_key != ()
    assert cache.size("interp") > n_generic
    np.testing.assert_array_equal(eg.result("Out"), es.result("Out"))


def test_cross_instance_diskstore_restore_of_specialized_artifact(
        tmp_path):
    """A specialized translation persists and revives across 'process'
    boundaries exactly like a generic one (same scalars → warm disk hit,
    zero fresh translations, bit-identical output)."""
    prog, _ = suite.dyn_fir()
    args = _fir_args()
    c1 = TranslationCache(store=DiskStore(tmp_path))
    e1 = _run(prog, "interp", 2, 32, args, specialize=True, cache=c1)
    assert e1.spec_key != ()
    assert c1.stats()["translated"] >= 1

    prog2, _ = suite.dyn_fir()  # rebuilt program: content-addressed keys
    c2 = TranslationCache(store=DiskStore(tmp_path))
    e2 = _run(prog2, "interp", 2, 32, args, specialize=True, cache=c2)
    st = c2.stats()
    assert st["translated"] == 0, \
        "specialized relaunch must restore from disk, not re-translate"
    assert st["restored"] >= 1
    np.testing.assert_array_equal(e1.result("Out"), e2.result("Out"))


# ---------------------------------------------------------------------------
# snapshots and migration
# ---------------------------------------------------------------------------


def test_snapshot_carries_spec_key_through_wire_format():
    prog, _ = suite.dyn_fir()
    eng = _run(prog, "interp", 2, 32, _fir_args())
    snap = eng.snapshot()
    assert snap.spec_key == eng.spec_key
    back = Snapshot.from_bytes(snap.to_bytes())
    assert back.spec_key == snap.spec_key


@pytest.mark.parametrize("src,dst", [("vectorized", "interp"),
                                     ("interp", "vectorized")])
def test_specialized_checkpoint_migrate_bit_identical(src, dst, tmp_path):
    """Acceptance: a mid-kernel checkpoint of a *specialized* dyn_matmul
    (inner dynamic-trip loop unrolled under the bound scalars) migrates to
    the other backend and finishes bit-identical to an uninterrupted
    specialized run AND to the unspecialized run."""
    args = _matmul_args()
    prog, _ = suite.dyn_matmul()

    ref_gen = _run(prog, dst, 4, 16, args, specialize=False,
                   cache=TranslationCache())
    ref_spec = _run(prog, dst, 4, 16, args, specialize=True,
                    cache=TranslationCache())
    np.testing.assert_array_equal(ref_gen.result("C"), ref_spec.result("C"))

    s_src = HetSession(src, opt_level=OPT_MAX, specialize=True,
                       cache=TranslationCache(store=DiskStore(tmp_path)))
    s_dst = HetSession(dst, opt_level=OPT_MAX, specialize=True,
                       cache=TranslationCache(store=DiskStore(tmp_path)))
    s_src.load_kernel(prog)
    s_dst.load_kernel(prog)
    rec = s_src.launch("dyn_matmul", grid=4, block=16, args=dict(args),
                       blocking=False)
    assert rec.engine.spec_key != ()
    assert rec.engine.opt_stats.per_pass.get("unroll_loops", 0) >= 1, \
        "the dynamic-trip inner loop must unroll under specialization"
    assert not rec.engine.run(max_segments=3)  # pause mid-kernel
    new = migrate(rec, s_src, s_dst, "dyn_matmul")
    assert new.engine.spec_key == rec.engine.spec_key
    s_dst.run_to_completion(new)
    assert new.finished
    np.testing.assert_array_equal(
        np.asarray(new.engine.result("C")),
        np.asarray(ref_spec.result("C")),
        err_msg=f"{src}->{dst} migrated specialized run diverged")


# ---------------------------------------------------------------------------
# acceptance: executed-work reduction on the dynamic-trip kernels
# ---------------------------------------------------------------------------


def test_acceptance_specialization_cuts_executed_work():
    """≥ 15 % interp step cut and dynamic ops_removed > 0 on both
    dynamic-trip suite kernels, outputs bit-identical to generic."""
    from benchmarks.bench_translation import run_specialization

    rows = run_specialization()
    assert len(rows) >= 2
    for r in rows:
        assert r["bit_identical"], r
        assert r["ops_removed"] > 0, r
        assert r["interp_step_cut"] >= 0.15, r
        assert r["scalars_bound"] >= 1, r


# ---------------------------------------------------------------------------
# may-alias analysis
# ---------------------------------------------------------------------------


def _aff(terms, const):
    return AffineIndex(tuple(sorted(terms.items())), const)


@pytest.mark.fast
def test_may_alias_rules():
    gid2 = _aff({"gid": 2}, 0)
    gid2p1 = _aff({"gid": 2}, 1)
    gid2p2 = _aff({"gid": 2}, 2)
    assert not may_alias(gid2, gid2p1)     # odd delta, stride 2: disjoint
    assert may_alias(gid2, gid2p2)         # delta 2 divisible: thread t+1
    assert may_alias(gid2, gid2)           # identical address
    assert may_alias(_aff({"gid": 3}, 0), _aff({"gid": 3}, 1)), \
        "odd coefficients give no pow2-gcd disjointness (wrap-safety)"
    assert may_alias(gid2, _aff({"tid": 2}, 1))   # different base sets
    assert may_alias(gid2, None) and may_alias(None, gid2)
    # pure constants: absolute addresses
    assert not may_alias(_aff({}, 3), _aff({}, 4))
    assert may_alias(_aff({}, 3), _aff({}, 3))
    # unstable base defeats cancellation
    assert may_alias(gid2, gid2p1, stable=lambda n: n != "gid")


@pytest.mark.fast
def test_affine_env_builds_index_forms():
    b = Builder("aff", [Ptr("A"), Ptr("Out")])
    i = b.global_id(0)
    j = i * b.const(4) + b.const(3)      # 4*gid + 3
    k = (j - i) << b.const(1)            # (3*gid + 3) * 2
    b.store("Out", k, b.load("A", j))
    prog = b.done()
    env = affine_env(prog.body)
    gid = prog.body[0].dest.name
    assert env[j.reg.name] == _aff({gid: 4}, 3)
    assert env[k.reg.name] == _aff({gid: 6}, 6)


# ---------------------------------------------------------------------------
# hoist_invariant_loads unit tests (pass invoked directly)
# ---------------------------------------------------------------------------


def _first_loop(body):
    return next(s for s in body if isinstance(s, ir.Loop))


def _loop_opcodes(body):
    return [s.opcode for s in _first_loop(body).body
            if isinstance(s, ir.Op)]


@pytest.mark.fast
def test_hoists_invariant_load_with_distinct_buffer_store():
    b = Builder("h1", [Ptr("A"), Ptr("Out")])
    i = b.global_id(0)
    c = b.const(0)
    with b.loop(20) as j:                 # trip > unroll budget
        v = b.load("A", c)                # invariant; stores go to Out
        b.store("Out", (i + j) % b.const(64), v)
    prog = b.done()
    body, n = hoist_invariant_loads(list(prog.body), prog)
    assert n == 1
    assert ir.LD_GLOBAL not in _loop_opcodes(body)
    # the load now sits before the loop
    pre = [s.opcode for s in body if isinstance(s, ir.Op)]
    assert ir.LD_GLOBAL in pre


@pytest.mark.fast
def test_hoist_allowed_when_same_buffer_store_provably_disjoint():
    """Load A[2*gid] vs store A[2*gid+1]: same base terms, odd delta under
    stride 2 — disjoint for every thread pair, so the hoist is legal."""
    b = Builder("h2", [Ptr("A"), Ptr("Out")])
    i = b.global_id(0)
    even = i * b.const(2)
    odd = i * b.const(2) + b.const(1)
    with b.loop(20):
        v = b.load("A", even)
        b.store("A", odd, v + b.const(1.0, ir.F32))
    prog = b.done()
    _, n = hoist_invariant_loads(list(prog.body), prog)
    assert n == 1


@pytest.mark.fast
def test_hoist_blocked_by_may_aliasing_store():
    """persistent_counter's shape: LD State[i] … ST State[i] in the loop —
    must-alias, the load must stay put."""
    b = Builder("h3", [Ptr("S")])
    i = b.global_id(0)
    with b.loop(20):
        v = b.load("S", i)
        b.store("S", i, v + b.const(1.0, ir.F32))
    prog = b.done()
    _, n = hoist_invariant_loads(list(prog.body), prog)
    assert n == 0


@pytest.mark.fast
def test_hoist_blocked_by_loop_varying_store_index():
    """Store index involves the loop variable: its base is unstable across
    iterations, so no disjointness argument exists — blocked."""
    b = Builder("h4", [Ptr("A")])
    i = b.global_id(0)
    c = b.const(0)
    with b.loop(20) as j:
        v = b.load("A", c)
        b.store("A", (i + j) % b.const(64), v)
    prog = b.done()
    _, n = hoist_invariant_loads(list(prog.body), prog)
    assert n == 0


@pytest.mark.fast
def test_hoist_requires_static_positive_trip():
    for count in ("n", 0):
        b = Builder("h5", [Ptr("A"), Ptr("Out"), Scalar("n")])
        c = b.const(0)
        with b.loop(count) as j:
            v = b.load("A", c)
            b.store("Out", j % b.const(64), v)
        prog = b.done()
        _, n = hoist_invariant_loads(list(prog.body), prog)
        assert n == 0, f"hoisted out of a trip={count!r} loop"


@pytest.mark.fast
def test_hoist_skips_predicated_loads():
    b = Builder("h6", [Ptr("A"), Ptr("Out")])
    i = b.global_id(0)
    c = b.const(0)
    with b.loop(20) as j:
        with b.when(i < b.const(4)):
            v = b.load("A", c)
            b.store("Out", (i * b.const(2) + j * b.const(8))
                    % b.const(64), v)
    prog = b.done()
    _, n = hoist_invariant_loads(list(prog.body), prog)
    assert n == 0


@pytest.mark.fast
def test_shared_store_blocks_shared_load_hoist():
    b = Builder("h7", [Ptr("A"), Ptr("Out")], shared_size=8)
    t = b.thread_id()
    c = b.const(0)
    with b.loop(20):
        v = b.load_shared(c)
        b.store_shared(t % b.const(8), v + b.const(1.0, ir.F32))
        b.store("Out", t % b.const(8), v)
    prog = b.done()
    _, n = hoist_invariant_loads(list(prog.body), prog)
    assert n == 0


@pytest.mark.fast
def test_global_store_does_not_block_shared_load_hoist():
    """Different memory spaces never alias."""
    b = Builder("h8", [Ptr("A"), Ptr("Out")], shared_size=8)
    i = b.global_id(0)
    c = b.const(0)
    with b.loop(20) as j:
        v = b.load_shared(c)
        b.store("Out", (i + j * b.const(2)) % b.const(64), v)
    prog = b.done()
    _, n = hoist_invariant_loads(list(prog.body), prog)
    assert n == 1


def test_dyn_fir_hoists_gain_load_only_under_specialization():
    """End to end: generic dyn_fir (dynamic trip) never hoists the W[0]
    load; binding taps makes the trip static and the alias analysis clears
    the hoist (stores go to Out, a distinct buffer)."""
    prog, _ = suite.dyn_fir()
    _, gstats = optimize(prog, OPT_MAX)
    assert gstats.per_pass.get("hoist_invariant_loads", 0) == 0
    # taps=12 > unroll budget: the loop survives, minus the gain load
    _, sstats = get_specialized(prog, OPT_MAX, (("taps", 12),))
    assert sstats.per_pass.get("hoist_invariant_loads", 0) >= 1
