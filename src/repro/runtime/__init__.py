from .train_loop import Trainer

__all__ = ["Trainer"]
