"""Fault-tolerant, elastic training runtime.

The hetGPU ideas at job scale:

* **cooperative checkpointing** — a ``preempt_flag`` callable is checked at
  every step boundary (the training "barrier"); when raised, the loop
  snapshots and exits cleanly (the paper's pause-flag protocol);
* **checkpoint/restart** — topology-neutral checkpoints (see
  repro.checkpoint) + seekable data mean a restart resumes bit-exact;
* **elastic resize / live migration** — ``Trainer.resize(new_mesh)``
  re-fits the sharding rules to a different mesh and reshards the live
  state through the neutral format (mesh A -> mesh B without a restart);
* **failure injection** — ``failure_at`` simulates a node loss mid-run for
  the fault-tolerance tests;
* **straggler monitoring** — per-step wall-time EMA; steps slower than
  ``straggler_factor``× the EMA are logged and counted (the signal a real
  cluster uses to trigger re-layout or backup workers).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint import AsyncCheckpointer, latest_step, restore
from repro.configs.base import ModelConfig, ParallelCfg, ShapeCfg
from repro.data import SyntheticLMData
from repro.models import init_params
from repro.models import registry as R
from repro.optim import adamw_init
from repro.parallel import MeshRules, make_train_step


class SimulatedFailure(RuntimeError):
    pass


@dataclass
class TrainReport:
    steps_run: int = 0
    losses: List[float] = field(default_factory=list)
    straggler_events: int = 0
    checkpoints: List[int] = field(default_factory=list)
    preempted: bool = False


class Trainer:
    def __init__(self, cfg: ModelConfig, shape: ShapeCfg, mesh,
                 pcfg: Optional[ParallelCfg] = None, ckpt_dir=None,
                 seed: int = 0, peak_lr: float = 1e-3):
        self.cfg = cfg
        self.shape = shape
        self.pcfg = pcfg or ParallelCfg(grad_accum=1, remat=True,
                                        seq_shard=False)
        self.seed = seed
        self.peak_lr = peak_lr
        self.ckpt = AsyncCheckpointer(ckpt_dir) if ckpt_dir else None
        self.ckpt_dir = ckpt_dir
        self._bind_mesh(mesh)
        self.step = 0
        self.state = None  # {"params":..., "opt":...}

    # -- mesh binding (initial and elastic) ------------------------------
    def _bind_mesh(self, mesh) -> None:
        self.mesh = mesh
        self.rules = MeshRules(self.cfg, self.pcfg, mesh)
        self.pspecs = self.rules.param_specs()
        self.ospecs = self.rules.opt_specs(self.pspecs)
        abstract_batch = {
            k: jax.ShapeDtypeStruct(v.shape, v.dtype)
            for k, v in SyntheticLMData(self.cfg, self.shape,
                                        self.seed).batch_at(0).items()}
        self.bspecs = self.rules.batch_specs(abstract_batch)
        self.data = SyntheticLMData(self.cfg, self.shape, self.seed,
                                    mesh=mesh, specs=self.bspecs)
        step_fn = make_train_step(self.cfg, self.pcfg, self.rules,
                                  peak_lr=self.peak_lr)
        ns = lambda t: jax.tree.map(  # noqa: E731
            lambda s: NamedSharding(mesh, s), t,
            is_leaf=lambda x: isinstance(x, P))
        self._jitted = jax.jit(
            step_fn,
            in_shardings=(ns(self.pspecs), ns(self.ospecs),
                          ns(self.bspecs), NamedSharding(mesh, P())),
        )

    # -- state ------------------------------------------------------------
    def init_state(self) -> None:
        with self.mesh:
            params = jax.jit(
                lambda k: init_params(k, self.cfg),
                out_shardings=jax.tree.map(
                    lambda s: NamedSharding(self.mesh, s), self.pspecs,
                    is_leaf=lambda x: isinstance(x, P)),
            )(jax.random.key(self.seed))
            opt = jax.jit(
                lambda p: adamw_init(p, self.cfg.opt_state_dtype),
                out_shardings=jax.tree.map(
                    lambda s: NamedSharding(self.mesh, s), self.ospecs,
                    is_leaf=lambda x: isinstance(x, P)),
            )(params)
        self.state = {"params": params, "opt": opt}
        self.step = 0

    def maybe_restore(self) -> bool:
        if self.ckpt_dir is None:
            return False
        last = latest_step(self.ckpt_dir)
        if last is None:
            return False
        template = {"params": R.abstract_params(self.cfg),
                    "opt": jax.eval_shape(
                        lambda p: adamw_init(p, self.cfg.opt_state_dtype),
                        R.abstract_params(self.cfg))}
        state, extra = restore(self.ckpt_dir, last, template,
                               mesh=self.mesh)
        self.state = state
        self.step = int(extra["next_step"])
        return True

    def save_checkpoint(self) -> None:
        if self.ckpt is None:
            return
        self.ckpt.save(self.step, self.state,
                       specs={"params": self.pspecs, "opt": self.ospecs},
                       extra={"next_step": self.step})
        self.ckpt.wait()

    # -- elastic resize / live migration ----------------------------------
    def resize(self, new_mesh) -> None:
        """Live-migrate the job onto a different mesh (the cluster-scale
        analogue of the paper's cross-GPU kernel migration)."""
        host_state = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                  self.state)
        old_step = self.step
        self._bind_mesh(new_mesh)
        # reshard through the neutral format
        ns = lambda t: jax.tree.map(  # noqa: E731
            lambda s: NamedSharding(new_mesh, s), t,
            is_leaf=lambda x: isinstance(x, P))
        self.state = {
            "params": jax.tree.map(jax.device_put, host_state["params"],
                                   ns(self.pspecs)),
            "opt": jax.tree.map(jax.device_put, host_state["opt"],
                                ns(self.ospecs)),
        }
        self.step = old_step

    # -- the loop -----------------------------------------------------------
    def run(self, num_steps: int, *, checkpoint_every: int = 0,
            preempt_flag: Optional[Callable[[], bool]] = None,
            failure_at: Optional[int] = None,
            straggler_factor: float = 3.0) -> TrainReport:
        if self.state is None:
            if not self.maybe_restore():
                self.init_state()
        report = TrainReport()
        ema = None
        target = self.step + num_steps
        while self.step < target:
            if failure_at is not None and self.step == failure_at:
                raise SimulatedFailure(f"node lost at step {self.step}")
            t0 = time.perf_counter()
            batch = self.data.batch_at(self.step)
            with self.mesh:
                params, opt, metrics = self._jitted(
                    self.state["params"], self.state["opt"], batch,
                    jax.numpy.asarray(self.step, jax.numpy.int32))
            loss = float(metrics["loss"])
            self.state = {"params": params, "opt": opt}
            self.step += 1
            report.steps_run += 1
            report.losses.append(loss)

            dt = time.perf_counter() - t0
            if ema is None:
                ema = dt
            elif dt > straggler_factor * ema:
                report.straggler_events += 1
            ema = 0.9 * ema + 0.1 * dt if ema else dt

            if checkpoint_every and self.step % checkpoint_every == 0:
                self.save_checkpoint()
                report.checkpoints.append(self.step)
            if preempt_flag is not None and preempt_flag():
                # cooperative checkpoint at the step barrier, then stop
                self.save_checkpoint()
                report.preempted = True
                break
        return report
