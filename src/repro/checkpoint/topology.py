"""Topology-neutral checkpointing — hetGPU state capture at cluster scale.

The paper snapshots kernels in a device-independent format (hetIR virtual
registers, not machine registers) so they restore on *different* hardware.
The training-system analogue: checkpoints store **logical arrays** plus
their *logical* partition specs — never per-device shards — so a job
checkpointed on mesh A (say 16×16) restores onto mesh B (2×16×16, 8×32, a
degraded 15×16 slice...) by re-fitting specs to the new mesh and
resharding on device_put.  This is what makes elastic restart and
cross-topology migration first-class.

Layout:  <dir>/step_<N>/manifest.json + one ``.npy`` per leaf.
Async: ``AsyncCheckpointer`` device_gets synchronously (the snapshot
barrier — cheap) and writes to disk on a background thread so the train
loop resumes immediately (cooperative checkpointing, paper §4.2).
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

MANIFEST = "manifest.json"


# -- pytree <-> flat path helpers -------------------------------------------


def _flatten_with_paths(tree) -> Dict[str, Any]:
    out = {}

    def walk(node, path):
        # PartitionSpec subclasses tuple: it must stay a *leaf* here, or a
        # specs tree flattens into per-axis fragments whose keys never match
        # the state's keys — every leaf would then be saved spec-less and
        # restore fully replicated (breaking cross-mesh elastic restore).
        if isinstance(node, dict):
            for k in sorted(node):
                walk(node[k], path + (str(k),))
        elif isinstance(node, (list, tuple)) and not isinstance(node, P):
            for i, v in enumerate(node):
                walk(v, path + (str(i),))
        else:
            out["/".join(path)] = node

    walk(tree, ())
    return out


def _unflatten_like(template, flat: Dict[str, Any]):
    def walk(node, path):
        if isinstance(node, dict):
            return {k: walk(node[k], path + (str(k),))
                    for k in node}
        if isinstance(node, (list, tuple)):
            t = [walk(v, path + (str(i),)) for i, v in enumerate(node)]
            return type(node)(t) if isinstance(node, list) else tuple(t)
        return flat["/".join(path)]

    return walk(template, ())


def _spec_to_json(spec: P) -> list:
    out = []
    for entry in spec:
        if entry is None:
            out.append(None)
        elif isinstance(entry, tuple):
            out.append(list(entry))
        else:
            out.append(entry)
    return out


def _spec_from_json(obj) -> P:
    return P(*[tuple(e) if isinstance(e, list) else e for e in obj])


# -- save / restore -----------------------------------------------------------


def save(path, step: int, state, specs=None, extra: Optional[dict] = None
         ) -> None:
    """Write a topology-neutral checkpoint.  ``specs``: matching pytree of
    PartitionSpecs (logical shardings recorded for restore-time re-fit)."""
    path = Path(path)
    tmp = path / f".tmp_step_{step}"
    final = path / f"step_{step}"
    tmp.mkdir(parents=True, exist_ok=True)

    flat = _flatten_with_paths(state)
    flat_specs = _flatten_with_paths(specs) if specs is not None else {}
    manifest = {"step": step, "leaves": {}, "extra": extra or {}}
    for key, leaf in flat.items():
        arr = np.asarray(jax.device_get(leaf))
        fname = key.replace("/", "__") + ".npy"
        np.save(tmp / fname, arr)
        manifest["leaves"][key] = {
            "file": fname,
            "shape": list(arr.shape),
            "dtype": arr.dtype.str,
            "spec": _spec_to_json(flat_specs[key])
            if key in flat_specs else None,
        }
    (tmp / MANIFEST).write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    os.replace(tmp, final)  # atomic publish


def latest_step(path) -> Optional[int]:
    path = Path(path)
    if not path.exists():
        return None
    steps = [int(m.group(1)) for p in path.iterdir()
             if (m := re.match(r"step_(\d+)$", p.name))]
    return max(steps) if steps else None


def restore(path, step: int, template, mesh=None, respec=None):
    """Load a checkpoint onto ``mesh``.

    ``template``: pytree with the target structure (leaves ignored).
    ``respec``: optional fn(key, stored_spec, shape) -> PartitionSpec to
    re-fit specs onto a *different* mesh (defaults to the stored spec with
    axes missing from the mesh dropped).  Returns (state, extra).
    """
    path = Path(path) / f"step_{step}"
    manifest = json.loads((path / MANIFEST).read_text())
    axis_names = set(mesh.axis_names) if mesh is not None else set()

    def default_respec(key, spec, shape):
        if spec is None:
            return P()
        fitted = []
        axis_size = dict(zip(mesh.axis_names, mesh.devices.shape))
        for dim, entry in zip(shape, spec):
            if entry is None:
                fitted.append(None)
                continue
            axes = [a for a in (entry if isinstance(entry, tuple)
                                else (entry,)) if a in axis_names]
            n = 1
            for a in axes:
                n *= axis_size[a]
            fitted.append(tuple(axes) if len(axes) > 1 else
                          (axes[0] if axes else None)
                          if dim % max(n, 1) == 0 else None)
        return P(*fitted)

    respec = respec or default_respec
    flat = {}
    for key, info in manifest["leaves"].items():
        arr = np.load(path / info["file"])
        if mesh is not None:
            spec = _spec_from_json(info["spec"]) if info["spec"] else None
            fitted = respec(key, spec, arr.shape)
            flat[key] = jax.device_put(arr, NamedSharding(mesh, fitted))
        else:
            flat[key] = jax.numpy.asarray(arr)
    state = _unflatten_like(template, flat)
    return state, manifest["extra"]


class AsyncCheckpointer:
    """Snapshot synchronously (device_get), persist asynchronously."""

    def __init__(self, path):
        self.path = Path(path)
        self._thread: Optional[threading.Thread] = None
        self.saved_steps: list = []

    def save(self, step: int, state, specs=None, extra=None) -> None:
        self.wait()
        host_flat = {k: np.asarray(jax.device_get(v))
                     for k, v in _flatten_with_paths(state).items()}
        host_state = _unflatten_like(state, host_flat)

        def work():
            save(self.path, step, host_state, specs=specs, extra=extra)
            self.saved_steps.append(step)

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
