"""GPipe-style pipeline parallelism over a mesh axis (opt-in).

The layer stack is split into ``n_stages`` contiguous stages, one per
device along ``axis``; microbatches stream through with activations
forwarded stage-to-stage via ``ppermute`` (the TPU ICI-neighbor
collective).  Schedule: plain GPipe — ``M + S - 1`` ticks for M
microbatches over S stages, bubble fraction ``(S-1)/(M+S-1)``.

This is the production ``pod``-axis option noted in DESIGN.md §5; the
default plan maps ``pod`` to FSDP/DP (better roofline for the assigned
shapes), so pipeline() is exercised at small scale in
tests/test_pipeline.py and available as a hillclimb lever for
inter-pod-bandwidth-starved deployments.
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def pipeline_apply(stage_fn: Callable, mesh: Mesh, axis: str,
                   params_stacked, microbatches):
    """Run ``y_mb = stage_S(...stage_1(x_mb))`` for every microbatch.

    stage_fn(stage_params, x) -> y : one stage's computation.
    params_stacked: pytree with leading dim n_stages (stage i's params).
    microbatches:   [M, ...] stacked microbatch inputs.
    Returns [M, ...] outputs (from the last stage).
    """
    S = mesh.devices.shape[list(mesh.axis_names).index(axis)]
    M = microbatches.shape[0]
    T = M + S - 1
    perm = [(i, (i + 1) % S) for i in range(S)]

    @partial(shard_map, mesh=mesh,
             in_specs=(P(axis), P()),   # params sharded by stage; mbs repl.
             out_specs=P(),
             check_rep=False)
    def run(params_stage, mbs):
        params_stage = jax.tree.map(lambda t: t[0], params_stage)
        sid = jax.lax.axis_index(axis)
        h0 = jnp.zeros_like(mbs[0])
        outs0 = jnp.zeros((M,) + mbs.shape[1:], mbs.dtype)

        def tick(carry, t):
            h, outs = carry
            # stage 0 ingests microbatch t (when in range)
            mb_idx = jnp.clip(t, 0, M - 1)
            inp = jnp.where(sid == 0,
                            jax.lax.dynamic_index_in_dim(
                                mbs, mb_idx, keepdims=False), h)
            out = stage_fn(params_stage, inp)
            # last stage emits microbatch (t - S + 1)
            emit_idx = t - (S - 1)
            valid = jnp.logical_and(sid == S - 1,
                                    jnp.logical_and(emit_idx >= 0,
                                                    emit_idx < M))
            outs = jax.lax.cond(
                valid,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, out, jnp.clip(emit_idx, 0, M - 1), axis=0),
                lambda o: o, outs)
            # rotate activations to the next stage
            h_next = jax.lax.ppermute(out, axis, perm)
            return (h_next, outs), None

        (_, outs), _ = jax.lax.scan(tick, (h0, outs0), jnp.arange(T))
        # only the last stage holds real outputs; psum broadcasts them
        # (everyone else contributes zeros)
        mask = (sid == S - 1).astype(outs.dtype)
        return jax.lax.psum(outs * mask, axis)

    return run(params_stacked, microbatches)
