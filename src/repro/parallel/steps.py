"""Distributed train/serve steps: grad accumulation, remat, AdamW, decode.

``make_train_step`` returns (step_fn, in_shardings, out_shardings) ready for
``jax.jit(...).lower(...)`` with abstract inputs — the dry-run path — or for
real execution on a live mesh.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ParallelCfg, ShapeCfg
from repro.models import decode_step, forward_train
from repro.optim import adamw_update, warmup_cosine
from .sharding import MeshRules


def make_train_step(cfg: ModelConfig, pcfg: ParallelCfg, rules: MeshRules,
                    *, peak_lr: float = 3e-4, warmup: int = 100,
                    total_steps: int = 10000):
    """Returns train_step(params, opt_state, batch, step) -> (params,
    opt_state, metrics).  Gradient accumulation slices the global batch
    into ``pcfg.grad_accum`` microbatches via lax.scan (activations for one
    microbatch at a time)."""
    A = pcfg.grad_accum
    ac = rules.ac if rules is not None else (lambda x, k: x)
    constrain = rules.constrain_batch if rules is not None else (
        lambda b: b)
    if rules is not None:
        pspecs = rules.param_specs()

        def pin_grads(grads):
            # gradients land sharded exactly like their parameters: the
            # per-microbatch batch reduction becomes a reduce-scatter over
            # the FSDP axes instead of a full f32 all-reduce per layer.
            return jax.tree.map(
                lambda g, s: jax.lax.with_sharding_constraint(
                    g, rules.shd(s)), grads, pspecs)
    else:
        pin_grads = lambda g: g  # noqa: E731

    def loss_fn(params, mb):
        mb = constrain(mb)
        return forward_train(params, mb, cfg, ac=ac, remat=pcfg.remat)

    gdt = jnp.dtype(pcfg.grad_dtype)

    def train_step(params, opt_state, batch, step):
        if A == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            grads = pin_grads(grads)
        else:
            def split(x):
                return x.reshape((A, x.shape[0] // A) + x.shape[1:])

            mbs = jax.tree.map(split, batch)

            def acc_fn(carry, mb):
                loss_acc, grads_acc = carry
                loss, grads = jax.value_and_grad(loss_fn)(params, mb)
                grads = pin_grads(grads)
                grads = jax.tree.map(
                    lambda a, g: a + g.astype(gdt) / A,
                    grads_acc, grads)
                return (loss_acc + loss / A, pin_grads(grads)), None

            zeros = pin_grads(jax.tree.map(
                lambda p: jnp.zeros(p.shape, gdt), params))
            (loss, grads), _ = jax.lax.scan(
                acc_fn, (jnp.zeros((), jnp.float32), zeros), mbs)

        lr = warmup_cosine(step, peak_lr=peak_lr, warmup_steps=warmup,
                           total_steps=total_steps)
        params, opt_state, metrics = adamw_update(
            grads, opt_state, params, lr=lr)
        metrics["loss"] = loss
        metrics["lr"] = lr
        return params, opt_state, metrics

    return train_step


def make_serve_step(cfg: ModelConfig, rules: Optional[MeshRules] = None):
    """One batched decode step: (params, tokens, caches, pos) ->
    (logits, new_caches)."""
    ac = rules.ac if rules is not None else (lambda x, k: x)

    def serve_step(params, tokens, caches, pos):
        return decode_step(params, tokens, caches, pos, cfg, ac=ac)

    return serve_step


def make_prefill_step(cfg: ModelConfig, rules: Optional[MeshRules] = None,
                      cache_len: Optional[int] = None):
    from repro.models import prefill
    ac = rules.ac if rules is not None else (lambda x, k: x)

    def prefill_step(params, batch):
        return prefill(params, batch, cfg, cache_len=cache_len, ac=ac)

    return prefill_step
