"""Sharding rules: logical parallelism axes -> physical mesh axes.

This is the cluster-scale analogue of hetGPU's backend modules: the model
definition is mesh-agnostic (the "portable binary"); MeshRules lowers it
onto whatever mesh exists — single-pod (16,16)=("data","model"),
multi-pod (2,16,16)=("pod","data","model"), or any test mesh — the way
hetIR lowers onto PTX/SPIR-V/Metalium.

Logical axes:
  fsdp  -> ("pod","data")∩mesh : ZeRO-3 parameter/optimizer sharding + DP
  tp    -> "model"             : Megatron column/row sharding
  sp    -> "model"             : sequence-parallel activations / KV caches
  ep    -> "model" when n_experts divides it, else expert-TP fallback

Every rule is divisibility-guarded: a dim that doesn't divide its axis is
left unsharded (GSPMD would otherwise reject the in_sharding), which is how
odd vocab (pre-padding), 24-head, or 40-expert shapes stay lowerable.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import (ATTN, DENSE_FFN, MLSTM, MOE_FFN, RGLRU,
                                SLSTM, SWA, BlockSpec, ModelConfig,
                                ParallelCfg, ShapeCfg)
from repro.models import registry as R


class MeshRules:
    def __init__(self, cfg: ModelConfig, pcfg: ParallelCfg, mesh: Mesh):
        self.cfg = cfg
        self.pcfg = pcfg
        self.mesh = mesh
        self.axis_size = dict(zip(mesh.axis_names, mesh.devices.shape))
        self.fsdp: Tuple[str, ...] = tuple(
            a for a in pcfg.fsdp_axes if a in self.axis_size)
        self.tp = pcfg.tp_axis if pcfg.tp_axis in self.axis_size else None

    # -- helpers ---------------------------------------------------------
    def _size(self, axes) -> int:
        if axes is None:
            return 1
        if isinstance(axes, str):
            axes = (axes,)
        n = 1
        for a in axes:
            n *= self.axis_size[a]
        return n

    def _fit(self, dim: int, axes) -> Optional[Any]:
        """axes if dim divides their product, else None (replicate)."""
        if axes is None:
            return None
        if dim % self._size(axes) == 0:
            return axes
        # try a single-axis subset (e.g. drop "pod" from ("pod","data"))
        if isinstance(axes, tuple) and len(axes) > 1:
            for a in axes[::-1]:
                if dim % self._size(a) == 0:
                    return a
        return None

    def spec(self, shape: Tuple[int, ...], *axes) -> P:
        assert len(axes) == len(shape), (shape, axes)
        return P(*[self._fit(d, a) for d, a in zip(shape, axes)])

    def shd(self, spec: P) -> NamedSharding:
        return NamedSharding(self.mesh, spec)

    # -- parameter specs ---------------------------------------------------
    def param_specs(self):
        """PartitionSpec pytree matching models.init_params structure."""
        cfg = self.cfg
        a_params = R.abstract_params(cfg)

        def leaf_rule(path: Tuple[str, ...], leaf) -> P:
            shape = leaf.shape
            name = path[-1]
            stacked = "groups" in path  # scan-stacked leaves: [repeat, ...]
            core = shape[1:] if stacked else shape
            s = self._core_rule(name, core, path)
            return P(*((None,) + tuple(s))) if stacked else P(*s)

        return _tree_map_with_path(leaf_rule, a_params)

    def _core_rule(self, name: str, shape, path) -> Tuple:
        fsdp, tp = self.fsdp, self.tp
        f = lambda d, a: self._fit(d, a)  # noqa: E731
        if name in ("embed",):
            return (f(shape[0], tp), f(shape[1], fsdp))
        if name == "lm_head":
            return (f(shape[0], fsdp), f(shape[1], tp))
        if name in ("wq", "wk", "wv", "wg", "wu", "w1", "w_in_rec",
                    "w_in_gate", "w_qkv", "w_skip", "w_x", "w_r", "w_i",
                    "proj"):
            if len(shape) == 3:  # stacked MoE experts [E, D, F]
                ep = self._ep_axis(shape[0])
                return (ep, f(shape[1], fsdp if ep is None else None),
                        None if ep == tp else f(shape[2], tp))
            return (f(shape[0], fsdp), f(shape[1], tp))
        if name in ("wo", "wd", "w2", "w_out", "w_o"):
            if len(shape) == 3:  # [E, F, D]
                ep = self._ep_axis(shape[0])
                return (ep, None if ep == tp else f(shape[1], tp),
                        f(shape[2], fsdp if ep is None else None))
            return (f(shape[0], tp), f(shape[1], fsdp))
        if name == "router":
            return (f(shape[0], fsdp), None)
        if name == "w_if":
            return (f(shape[0], fsdp), None)
        if name == "conv_w":
            return (None, f(shape[1], tp))
        if name == "lam":
            return (f(shape[0], tp),)
        if name == "r":  # sLSTM block-diag recurrence [H, dh, 4dh]
            return (f(shape[0], tp), None, None)
        if name in ("scale", "bias"):
            return (None,) * len(shape)
        # default: replicate
        return (None,) * len(shape)

    def _ep_axis(self, n_experts: int) -> Optional[str]:
        """True expert-parallel axis when expert count divides `model`;
        otherwise None -> expert-TP fallback shards d_ff instead."""
        if self.tp and n_experts % self.axis_size[self.tp] == 0:
            return self.tp
        return None

    def opt_specs(self, param_specs):
        return {"m": param_specs, "v": param_specs, "count": P()}

    # -- batch / cache specs -------------------------------------------------
    def batch_specs(self, batch_tree):
        def rule(path, leaf):
            if leaf.ndim == 0:
                return P()
            b_axes = self._fit(leaf.shape[0], self.fsdp)
            if leaf.ndim >= 2 and self.pcfg.seq_shard:
                s_axes = self._fit(leaf.shape[1], self.tp)
                rest = (None,) * (leaf.ndim - 2)
                return P(b_axes, s_axes, *rest)
            return P(b_axes, *((None,) * (leaf.ndim - 1)))

        return _tree_map_with_path(rule, batch_tree)

    def cache_specs(self, cache_tree):
        """KV caches [B,S,Hkv,hd] / recurrent states [B,...]."""
        def rule(path, leaf):
            # stacked over layers: [L, B, ...]
            shape = leaf.shape[1:]
            b_axes = self._fit(shape[0], self.fsdp)
            if len(shape) == 4:  # [B, S, Hkv, hd]
                if self.pcfg.kv_shard == "seq":
                    return P(None, b_axes, self._fit(shape[1], self.tp),
                             None, None)
                return P(None, b_axes, None,
                         self._fit(shape[2], self.tp), None)
            if len(shape) >= 2:
                return P(None, b_axes, self._fit(shape[1], self.tp),
                         *((None,) * (len(shape) - 2)))
            return P(None, b_axes)

        return _tree_map_with_path(rule, cache_tree)

    def constrain_batch(self, batch_tree):
        """Pin batch sharding on (micro)batch arrays.  Crucial inside the
        grad-accum scan: slicing microbatches out of [A, B/A, ...] would
        otherwise let GSPMD shard the accumulation dim and replicate the
        microbatch."""
        specs = self.batch_specs(batch_tree)
        return jax.tree.map(
            lambda x, s: jax.lax.with_sharding_constraint(x, self.shd(s)),
            batch_tree, specs)

    # -- activation constraint hook (threaded into the model as `ac`) --------
    def ac(self, x, kind: str):
        if kind == "residual" and x.ndim == 3:
            b = self._fit(x.shape[0], self.fsdp)
            s = self._fit(x.shape[1], self.tp) if self.pcfg.seq_shard \
                else None
            return jax.lax.with_sharding_constraint(
                x, self.shd(P(b, s, None)))
        if kind == "logits" and x.ndim == 3:
            b = self._fit(x.shape[0], self.fsdp)
            v = self._fit(x.shape[-1], self.tp) \
                if self.pcfg.shard_logits else None
            return jax.lax.with_sharding_constraint(
                x, self.shd(P(b, None, v)))
        if kind == "lm_head_weight" and x.ndim == 2:
            return jax.lax.with_sharding_constraint(
                x, self.shd(P(None, self._fit(x.shape[1], self.tp))))
        if kind == "heads4" and x.ndim == 4:
            # [B,S,H,hd]: Megatron head sharding (replicate when H doesn't
            # divide tp — small models)
            b = self._fit(x.shape[0], self.fsdp)
            return jax.lax.with_sharding_constraint(
                x, self.shd(P(b, None, self._fit(x.shape[2], self.tp),
                              None)))
        if kind in ("attn_mix", "ffn_hidden") and x.ndim == 3:
            b = self._fit(x.shape[0], self.fsdp)
            return jax.lax.with_sharding_constraint(
                x, self.shd(P(b, None, self._fit(x.shape[2], self.tp))))
        if kind == "mm_input" and x.ndim == 3:
            # Megatron-SP boundary: gather the (possibly seq-sharded)
            # activation BEFORE a TP matmul — otherwise the GSPMD solver
            # may resolve the conflict by fully gathering the weights
            # (3.3 GB/layer on 405B) instead of the activation.
            b = self._fit(x.shape[0], self.fsdp)
            return jax.lax.with_sharding_constraint(
                x, self.shd(P(b, None, None)))
        if kind == "moe_buf" and x.ndim == 3:
            # [E,C,D]: experts over EP when divisible; capacity over fsdp
            ep = self._ep_axis(x.shape[0])
            c = self._fit(x.shape[1], self.fsdp) if ep is None else None
            return jax.lax.with_sharding_constraint(
                x, self.shd(P(ep, c, None)))
        if kind == "moe_hidden" and x.ndim == 3:
            ep = self._ep_axis(x.shape[0])
            c = self._fit(x.shape[1], self.fsdp) if ep is None else None
            f = None if ep == self.tp else self._fit(x.shape[2], self.tp)
            return jax.lax.with_sharding_constraint(
                x, self.shd(P(ep, c, f)))
        if kind == "moe_buf4" and x.ndim == 4:   # [B,E,C,D] grouped MoE
            b = self._fit(x.shape[0], self.fsdp)
            return jax.lax.with_sharding_constraint(
                x, self.shd(P(b, self._ep_axis(x.shape[1]), None, None)))
        if kind == "moe_hidden4" and x.ndim == 4:  # [B,E,C,F]
            b = self._fit(x.shape[0], self.fsdp)
            ep = self._ep_axis(x.shape[1])
            f = None if ep == self.tp else self._fit(x.shape[3], self.tp)
            return jax.lax.with_sharding_constraint(
                x, self.shd(P(b, ep, None, f)))
        return x


def _tree_map_with_path(fn, tree):
    """tree_map passing a tuple of dict-keys/list-indices as path."""
    def walk(node, path):
        if isinstance(node, dict):
            return {k: walk(v, path + (k,)) for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            t = [walk(v, path + (str(i),)) for i, v in enumerate(node)]
            return type(node)(t) if not isinstance(node, tuple) \
                else tuple(t)
        return fn(path, node)

    return walk(tree, ())
