from .sharding import MeshRules
from .steps import make_serve_step, make_train_step

__all__ = ["MeshRules", "make_train_step", "make_serve_step"]
