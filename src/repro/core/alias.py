"""May-alias analysis over hetIR memory operations.

The pass pipeline needs an alias story before it may move *memory* ops:
``hoist_invariant_loads`` (:mod:`~repro.core.passes`) moves provably
loop-invariant ``LD_GLOBAL``/``LD_SHARED`` ops out of loops, which is only
sound when no store inside the loop may write the loaded address.  This
module answers that question with two rules, mirroring the memory model
hetIR inherits from the paper's abstract device:

* **Distinct buffers never alias.**  A hetIR pointer parameter names a
  whole allocation; two different buffer names (or the global space versus
  the per-block shared scratchpad) are disjoint by construction.

* **Same-buffer accesses are compared via affine index forms.**  Every
  i32/u32 register is (best-effort) summarized as an affine expression
  ``Σ coeff_i · base_i + const`` over *opaque base* registers — the same
  index terms the value-numbering pass keys on, here made explicit.  Two
  accesses whose forms share an identical base/coefficient multiset differ
  only by a constant ``delta``; the addresses of any two threads then
  differ by ``Σ coeff_i · (base_i(t) − base_i(s))``, a multiple of
  ``g = 2^(min trailing zeros of the coefficients)``.  If
  ``delta mod g ≠ 0`` the accesses can never collide — **for any pair of
  threads**, which is what makes the rule sound under SPMD execution
  (per-thread disjointness alone would miss thread ``t`` hitting thread
  ``s``'s slot).  Restricting ``g`` to the power-of-two part of the gcd
  keeps the argument valid under i32/u32 wraparound: ``g`` divides
  ``2^32``, so congruence mod ``g`` survives any number of wraps.

Everything else is a conservative *may alias*: forms with different base
sets, non-affine indices (``MOD``/``SHR``/loads/selects become opaque
bases), multi-def registers, or bases the caller marks unstable (defined
inside the loop under analysis, e.g. the loop variable — their values
differ between the iteration that stores and the iteration that loads,
so base cancellation would be wrong).

hetIR programs are required to keep indices in bounds (out-of-range
access is undefined behaviour), so "different index value" is the same
statement as "different address" — the analysis never needs buffer
extents.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from . import hetir as ir

#: memory spaces an access can live in (shared is one pseudo-buffer per
#: block; the per-block separation only makes the verdicts conservative)
GLOBAL_SPACE = "global"
SHARED_SPACE = "shared"

#: the single pseudo-buffer name of the shared scratchpad
SHARED_BUF = "__shared__"


@dataclass(frozen=True)
class AffineIndex:
    """``Σ coeff·base + const`` with sorted, coeff≠0 terms."""

    terms: Tuple[Tuple[str, int], ...]
    const: int

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        parts = [f"{c}*%{b}" for b, c in self.terms]
        return " + ".join(parts + [str(self.const)]) or "0"


def _make(terms: Dict[str, int], const: int) -> AffineIndex:
    return AffineIndex(tuple(sorted((b, c) for b, c in terms.items()
                                    if c != 0)), int(const))


def _combine(a: AffineIndex, b: AffineIndex, sign: int) -> AffineIndex:
    terms = dict(a.terms)
    for base, coeff in b.terms:
        terms[base] = terms.get(base, 0) + sign * coeff
    return _make(terms, a.const + sign * b.const)


def _scale(a: AffineIndex, k: int) -> Optional[AffineIndex]:
    if k == 0:
        return AffineIndex((), 0)
    return _make({b: c * k for b, c in a.terms}, a.const * k)


def affine_env(body: Sequence[ir.Stmt]) -> Dict[str, AffineIndex]:
    """Affine form per *single-def* integer register in ``body``.

    Opaque ops (memory loads, identity ops, divisions, …) contribute their
    dest as a fresh base term; multi-def registers (``Builder.assign``
    targets) are excluded entirely — their value depends on the program
    point, so they can never participate in base cancellation.  The walk is
    program-order, which SSA-style construction makes sufficient: an arg
    defined later (impossible for a value actually read) simply falls back
    to an opaque base.
    """
    defs = ir.reg_def_counts(body)
    env: Dict[str, AffineIndex] = {}

    def base_of(reg: ir.Reg) -> Optional[AffineIndex]:
        f = env.get(reg.name)
        if f is not None:
            return f
        if defs.get(reg.name, 0) != 1:
            return None
        return AffineIndex(((reg.name, 1),), 0)

    def const_val(a) -> Optional[int]:
        if isinstance(a, ir.Reg):
            f = env.get(a.name)
            if f is not None and not f.terms:
                return f.const
            return None
        try:
            v = int(a)
        except (TypeError, ValueError, OverflowError):
            return None
        return v if v == a else None

    for op in ir.walk_ops(body):
        d = op.dest
        if d is None or d.dtype not in (ir.I32, ir.U32) \
                or defs.get(d.name, 0) != 1:
            continue
        form: Optional[AffineIndex] = None
        if op.opcode == ir.CONST:
            c = const_val(op.args[0])
            if c is not None:
                form = AffineIndex((), c)
        elif op.opcode in (ir.ADD, ir.SUB):
            a = _arg_form(op.args[0], base_of, const_val)
            b = _arg_form(op.args[1], base_of, const_val)
            if a is not None and b is not None:
                form = _combine(a, b, 1 if op.opcode == ir.ADD else -1)
        elif op.opcode == ir.MUL:
            for x, c in ((op.args[0], const_val(op.args[1])),
                         (op.args[1], const_val(op.args[0]))):
                if c is None:
                    continue
                xf = _arg_form(x, base_of, const_val)
                if xf is not None:
                    form = _scale(xf, c)
                    break
        elif op.opcode == ir.SHL:
            k = const_val(op.args[1])
            if k is not None and 0 <= k < 32:
                xf = _arg_form(op.args[0], base_of, const_val)
                if xf is not None:
                    form = _scale(xf, 1 << k)
        elif op.opcode == ir.MOV:
            form = _arg_form(op.args[0], base_of, const_val)
        if form is not None:
            env[d.name] = form
        # anything else: d stays out of env and becomes an opaque base at
        # its uses (base_of), which is exactly the conservative choice
    return env


def _arg_form(a, base_of, const_val) -> Optional[AffineIndex]:
    if isinstance(a, ir.Reg):
        return base_of(a)
    c = const_val(a)
    return None if c is None else AffineIndex((), c)


def index_form(idx, env: Dict[str, AffineIndex],
               defs: Dict[str, int]) -> Optional[AffineIndex]:
    """Affine form of a memory op's index operand (Reg or immediate), or
    ``None`` when nothing sound can be said."""
    if isinstance(idx, ir.Reg):
        f = env.get(idx.name)
        if f is not None:
            return f
        if defs.get(idx.name, 0) == 1:
            return AffineIndex(((idx.name, 1),), 0)
        return None
    try:
        return AffineIndex((), int(idx))
    except (TypeError, ValueError, OverflowError):
        return None


def _pow2_gcd(coeffs) -> int:
    """Largest power of two dividing every coefficient (capped at 2^31) —
    the wrap-safe part of the gcd (see module docstring)."""
    shift = 32
    for c in coeffs:
        c = abs(int(c))
        if c == 0:
            continue
        shift = min(shift, (c & -c).bit_length() - 1)
    return 1 << min(shift, 31)


def injective_step(coeff: int, span: int) -> bool:
    """Is ``i -> coeff * i`` injective over ``i in [0, span)`` under i32/u32
    wraparound?  ``coeff * (i - j) ≡ 0 (mod 2^32)`` requires ``i - j`` to be
    a multiple of ``2^(32 - tz(coeff))`` (``tz`` = trailing zero count), so
    injectivity holds exactly when ``span`` does not reach that multiple.
    This is the wrap-safe leg of the lane-independence proof
    (:func:`repro.core.passes.block_lower`): a store whose index is
    ``coeff · global_id + uniform`` hits a distinct element per thread —
    for *any* two threads — whenever this returns True."""
    coeff = abs(int(coeff))
    if coeff == 0:
        return span <= 1
    tz = (coeff & -coeff).bit_length() - 1
    if tz >= 32:
        return span <= 1
    return span <= (1 << (32 - tz))


def may_alias(a: Optional[AffineIndex], b: Optional[AffineIndex],
              stable: Callable[[str], bool] = lambda name: True) -> bool:
    """May two same-buffer accesses with index forms ``a`` and ``b``
    touch the same element — for *any* pair of executing threads?

    ``stable(base)`` must return True only when the base register holds a
    single value for the whole window under analysis (e.g. it is defined
    outside the loop a hoist is considered for).  Unstable bases defeat
    cancellation and force a conservative True.
    """
    if a is None or b is None:
        return True
    if any(not stable(base) for base, _ in a.terms + b.terms):
        return True
    if dict(a.terms) != dict(b.terms):
        return True  # different base sets: no disjointness argument
    delta = b.const - a.const
    if not a.terms:
        return delta % (1 << 32) == 0  # two absolute (wrapped) addresses
    if delta == 0:
        return True  # identical per-thread address (and t==s collides)
    return delta % _pow2_gcd(c for _, c in a.terms) == 0


def body_mem_accesses(body: Sequence[ir.Stmt]
                      ) -> Tuple[List[Tuple[str, str, object]],
                                 List[Tuple[str, str, object]]]:
    """All (space, buffer, index operand) memory reads and writes in
    ``body``, recursively (``ATOMIC_ADD`` is both)."""
    reads: List[Tuple[str, str, object]] = []
    writes: List[Tuple[str, str, object]] = []

    def walk(stmts: Sequence[ir.Stmt]) -> None:
        for s in stmts:
            if isinstance(s, ir.Op):
                if s.opcode == ir.LD_GLOBAL:
                    reads.append((GLOBAL_SPACE, s.args[0], s.args[1]))
                elif s.opcode == ir.ST_GLOBAL:
                    writes.append((GLOBAL_SPACE, s.args[0], s.args[1]))
                elif s.opcode == ir.ATOMIC_ADD:
                    reads.append((GLOBAL_SPACE, s.args[0], s.args[1]))
                    writes.append((GLOBAL_SPACE, s.args[0], s.args[1]))
                elif s.opcode == ir.LD_SHARED:
                    reads.append((SHARED_SPACE, SHARED_BUF, s.args[0]))
                elif s.opcode == ir.ST_SHARED:
                    writes.append((SHARED_SPACE, SHARED_BUF, s.args[0]))
            elif isinstance(s, (ir.Pred, ir.Loop)):
                walk(s.body)

    walk(body)
    return reads, writes
