"""The hetGPU execution engine — segment walker + snapshot machinery
(paper §4.2 Dynamic Translation, §4.3 State Capture).

The engine is the piece of the paper's runtime that walks the *segmented*
program: it snapshots the launch's uniform scalar arguments and consults
the :class:`~repro.core.passes.SpecializationPolicy` (launch-time
specialization — the paper's runtime translates at launch, when every
scalar is known), runs the :mod:`~repro.core.passes` pipeline at the
launch's ``opt_level`` (with the scalars bound as constants when the
policy grants a specialized variant), asks :mod:`~repro.core.segments` to
split the optimized body at barriers ("each segment is a separate
kernel"), then executes the node list one entry at a time, delegating
each straight-line :class:`~repro.core.segments.SegNode` to the bound
backend — whose translation of it lands in the shared
:class:`~repro.core.cache.TranslationCache` under a key carrying the
specialization's bound-scalar vector.

The engine owns the *control* state the paper puts in its snapshots
(§4.3 "State Representation"): the position in the segmented program
(node index — the device-neutral stand-in for a machine PC), loop
iteration counters, the per-thread virtual register file, shared memory,
and global buffers.  Backends only ever execute one straight-line segment;
everything between segments (barrier semantics, loop back-edges,
cooperative pause flags, snapshot / resume) lives here and is therefore
**identical across backends** — which is precisely what makes
cross-backend migration (§6.3) sound.  Between segments the engine also
prunes registers no later segment reads, the paper's §8 "only saving live
registers" snapshot-size optimization.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional

import numpy as np

from . import hetir as ir
from .backends.base import Backend, HostState, Launch
from .passes import (DEFAULT_OPT_LEVEL, OPT_MAX, SPECIALIZATION_POLICY,
                     get_optimized, get_specialized)
from .segments import (LoopEnd, LoopStart, Node, SegNode, dynamic_op_count,
                       resolve_trip_count, segment_program)
from .state import Snapshot


class Engine:
    def __init__(self, program: ir.Program, backend: Backend,
                 num_blocks: int, block_size: int,
                 args: Dict[str, object], opt_level: int = None,
                 specialize: Optional[bool] = None,
                 _from_snapshot: bool = False,
                 _spec_key: Optional[tuple] = None):
        program.validate()
        self.opt_level = DEFAULT_OPT_LEVEL if opt_level is None \
            else max(0, min(int(opt_level), OPT_MAX))
        self.source_program = program
        # snapshot the uniform scalar arguments up front: launch-time
        # specialization (paper §4.2 — translation happens at launch, when
        # every scalar is known) may bind them into the optimized body
        scalars: Dict[str, object] = {}
        shapes: Dict[str, tuple] = {}
        if not _from_snapshot:
            for p in program.scalars():
                if p.name not in args:
                    raise ValueError(f"missing scalar argument {p.name}")
                scalars[p.name] = ir.np_dtype(p.dtype).type(args[p.name])
            # buffer shapes join the launch record up front: the
            # specialization policy keys on them (two launches differing
            # only in buffer length are distinct variants) and the pallas
            # block lowering proves tiled-buffer legality against them
            for p in program.buffers():
                if p.name in args:
                    val = args[p.name]
                    if hasattr(val, "uid") and hasattr(val, "data"):
                        val = val.data
                    shapes[p.name] = tuple(np.shape(val))
        # run the pass pipeline before translation (paper §4.2: the runtime
        # "dynamically translates this IR to the target GPU's native code" —
        # every backend then consumes the same optimized body).  Memoized per
        # (program, level[, spec key]) so segmentation and fingerprints stay
        # stable.  A resume reapplies the snapshot's spec key verbatim —
        # never the policy — so the destination reconstructs the exact node
        # list the node_idx addresses.
        if _spec_key is not None:
            self.spec_key = tuple(tuple(e) for e in _spec_key)
        else:
            self.spec_key = SPECIALIZATION_POLICY.consider(
                program, self.opt_level, scalars, override=specialize,
                shapes=shapes)
        if self.spec_key:
            opt_prog, self.opt_stats = get_specialized(
                program, self.opt_level, self.spec_key)
        else:
            opt_prog, self.opt_stats = get_optimized(program, self.opt_level)
        self.program = opt_prog
        self.backend = backend
        # segmentation is memoized on the (optimized) Program so SegNode
        # identities are stable across launches — the shared translation
        # cache keys on the program fingerprint + segment index
        # (paper §4.2: "the runtime caches these translated kernels")
        nodes = getattr(opt_prog, "_nodes_cache", None)
        if nodes is None:
            nodes = segment_program(opt_prog)
            opt_prog._nodes_cache = nodes
        self.nodes = nodes
        self.launch = Launch(opt_prog, num_blocks, block_size,
                             scalars=scalars, opt_level=self.opt_level,
                             spec_key=self.spec_key, buffer_shapes=shapes)
        self.node_idx = 0
        self.loop_counters: Dict[int, int] = {}
        self.finished = False
        # per-thread executed-op schedule size, accumulated per executed
        # segment (segments.dynamic_op_count) — the benchmark metric that
        # makes unrolling + post-unroll folding visible as one number.
        # Counts are memoized per node: stmts and launch scalars are fixed
        # for an engine, and segment-level loops re-execute their nodes.
        self.executed_ops = 0
        self._node_sched: Dict[int, int] = {}
        # DeviceBuffer identity (runtime.py object model): param name ->
        # the uid of the buffer handle bound at launch (None for raw host
        # arrays).  Rides in every snapshot so restore/migration can
        # re-bind the same live buffer — identity survives checkpoints.
        self.buffer_uids: Dict[str, Optional[str]] = {}

        # registers that any segment reads — everything else is dead between
        # segments and gets pruned from state (the paper's "only saving live
        # registers" snapshot-size optimization, §8 Scalability)
        self._live: set = set()
        for n in self.nodes:
            if isinstance(n, SegNode):
                self._live.update(r.name for r in n.uses)
            elif isinstance(n, LoopStart):
                self._live.add(n.var.name)

        if _from_snapshot:
            return

        globals_: Dict[str, np.ndarray] = {}
        for p in program.buffers():
            if p.name not in args:
                raise ValueError(f"missing buffer argument {p.name}")
            val = args[p.name]
            # a runtime.DeviceBuffer handle (duck-typed — runtime imports
            # this module, not the reverse): unwrap and record its uid
            if hasattr(val, "uid") and hasattr(val, "data"):
                self.buffer_uids[p.name] = val.uid
                val = val.data
            buf = np.asarray(val, dtype=ir.np_dtype(p.dtype))
            if buf.ndim != 1:
                raise ValueError(f"buffer {p.name} must be 1-D")
            globals_[p.name] = buf.copy()

        shared = None
        if program.shared_size:
            shared = np.zeros((num_blocks, program.shared_size),
                              dtype=ir.np_dtype(program.shared_dtype))
        self.state = HostState(regs={}, shared=shared, globals_=globals_)

    # ------------------------------------------------------------------
    def run(self, max_segments: Optional[int] = None,
            pause_flag: Optional[Callable[[], bool]] = None,
            on_segment: Optional[Callable[["Engine"], bool]] = None
            ) -> bool:
        """Execute until completion, ``max_segments`` executed segments, or
        ``pause_flag()`` turning true at a barrier.  Returns True iff the
        program ran to completion.

        ``on_segment`` is the segment-boundary *yield hook*: it is invoked
        after **every** executed segment (including the last one, so
        callers can account/trace each segment exactly once), and a truthy
        return requests a cooperative yield at this barrier — the serving
        scheduler uses it to preempt a stream mid-quantum when a
        higher-priority stream becomes runnable."""
        executed = 0
        while self.node_idx < len(self.nodes):
            if max_segments is not None and executed >= max_segments:
                return False
            node = self.nodes[self.node_idx]
            if isinstance(node, SegNode):
                self.backend.run_segment(node, self.state, self.launch)
                sched = self._node_sched.get(self.node_idx)
                if sched is None:
                    sched = dynamic_op_count(node.stmts,
                                             self.launch.scalars)
                    self._node_sched[self.node_idx] = sched
                self.executed_ops += sched
                self._prune_dead_regs()
                executed += 1
                self.node_idx += 1
                # a barrier boundary — the paper's cooperative pause point
                yield_req = (on_segment is not None and on_segment(self))
                if self.node_idx < len(self.nodes):
                    if yield_req:
                        return False
                    if pause_flag is not None and pause_flag():
                        return False
            elif isinstance(node, LoopStart):
                if self._trip_count(node) <= 0:
                    # zero-trip loop: jump past the matching LoopEnd.  The
                    # skipped segments never execute, so registers they
                    # would define are materialized as zeros (hetIR
                    # registers read as zero until first written) — later
                    # segments and snapshots then see identical state on
                    # every backend.
                    end = next(n.index for n in self.nodes
                               if isinstance(n, LoopEnd)
                               and n.loop_id == node.loop_id)
                    self._zero_fill_skipped_defs(self.node_idx, end)
                    self.node_idx = end + 1
                    continue
                self.loop_counters[node.loop_id] = 0
                self._set_loop_var(node, 0)
                self.node_idx += 1
            elif isinstance(node, LoopEnd):
                start = self.nodes[node.start_index]
                cnt = self.loop_counters[node.loop_id] + 1
                trip = self._trip_count(start)
                if cnt < trip:
                    self.loop_counters[node.loop_id] = cnt
                    self._set_loop_var(start, cnt)
                    self.node_idx = node.start_index + 1
                else:
                    del self.loop_counters[node.loop_id]
                    self.node_idx += 1
        self.finished = True
        return True

    def _trip_count(self, start: LoopStart) -> int:
        trips = resolve_trip_count(start.count, self.launch.scalars)
        if trips is None:
            raise KeyError(f"loop count scalar {start.count!r} is unbound")
        return trips

    def _set_loop_var(self, start: LoopStart, value: int) -> None:
        self.state.regs[start.var.name] = np.full(
            (self.launch.num_blocks, self.launch.block_size), value,
            dtype=ir.np_dtype(start.var.dtype))

    def _zero_fill_skipped_defs(self, lo: int, hi: int) -> None:
        shape = (self.launch.num_blocks, self.launch.block_size)
        for n in self.nodes[lo:hi]:
            if isinstance(n, SegNode):
                for r in n.defs:
                    if r.name in self._live and r.name not in self.state.regs:
                        self.state.regs[r.name] = np.zeros(
                            shape, dtype=ir.np_dtype(r.dtype))

    def _prune_dead_regs(self) -> None:
        self.state.regs = {k: v for k, v in self.state.regs.items()
                           if k in self._live}

    # ------------------------------------------------------------------
    def snapshot(self) -> Snapshot:
        """Capture device-neutral state (only legal between segments —
        which is the only place this can be called, by construction)."""
        return Snapshot(
            program_name=self.program.name,
            num_blocks=self.launch.num_blocks,
            block_size=self.launch.block_size,
            node_idx=self.node_idx,
            opt_level=self.opt_level,
            loop_counters=dict(self.loop_counters),
            regs={k: np.asarray(v).copy()
                  for k, v in self.state.regs.items()},
            shared=None if self.state.shared is None
            else np.asarray(self.state.shared).copy(),
            globals_={k: np.asarray(v).copy()
                      for k, v in self.state.globals_.items()},
            scalars=dict(self.launch.scalars),
            spec_key=self.spec_key,
            buffer_uids=dict(self.buffer_uids),
        )

    @classmethod
    def resume(cls, program: ir.Program, backend: Backend,
               snap: Snapshot) -> "Engine":
        """Re-instantiate a snapshot on (possibly) a different backend —
        the paper's cross-architecture restore."""
        if snap.program_name != program.name:
            raise ValueError(
                f"snapshot is for {snap.program_name!r}, not {program.name!r}")
        # re-optimize at the snapshot's level — and with the snapshot's
        # specialization key: node indices are positions in the *optimized*
        # (possibly specialized) segmented program, and the pipeline is
        # deterministic, so the destination sees the same node list
        eng = cls(program, backend, snap.num_blocks, snap.block_size,
                  args={}, opt_level=snap.opt_level, _from_snapshot=True,
                  _spec_key=tuple(snap.spec_key))
        eng.launch.scalars = dict(snap.scalars)
        eng.launch.buffer_shapes = {k: tuple(np.shape(v))
                                    for k, v in snap.globals_.items()}
        eng.buffer_uids = dict(snap.buffer_uids)
        eng.node_idx = snap.node_idx
        eng.loop_counters = dict(snap.loop_counters)
        eng.state = HostState(
            regs={k: v.copy() for k, v in snap.regs.items()},
            shared=None if snap.shared is None else snap.shared.copy(),
            globals_={k: v.copy() for k, v in snap.globals_.items()},
        )
        eng.finished = eng.node_idx >= len(eng.nodes)
        return eng

    # ------------------------------------------------------------------
    def result(self, buf: str) -> np.ndarray:
        return np.asarray(self.state.globals_[buf])
