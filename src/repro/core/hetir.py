"""hetIR — the architecture-neutral SPMD kernel IR from the HetGPU paper.

A hetIR :class:`Program` describes a kernel in the paper's SPMD model:

* a grid of *blocks*, each of *block_size* threads — no warp size is baked in
  (the paper's key IR property);
* explicit predication (``@PRED`` regions) instead of implicit SIMT masks;
* explicit ``BARRIER`` synchronization points — these are the only places the
  runtime may capture state (the paper's "safe suspension points");
* abstract memory spaces: ``LD/ST_GLOBAL`` (device DRAM) and ``LD/ST_SHARED``
  (per-block scratchpad);
* virtualized collective intrinsics (``VOTE_*``, ``SHUFFLE``, ``REDUCE_ADD``,
  ``ATOMIC_ADD``) defined over the *currently active* threads of a block.

Programs are SSA: every register is assigned exactly once per dynamic
execution of its defining op.  Loops carry values through registers that are
re-assigned each iteration at the engine level (the register *file* is the
unit of state capture, exactly as in the paper's snapshot design).

The IR is deliberately small but complete enough to express the paper's
evaluation suite (vector add, SAXPY, tiled matmul with shared memory,
reduction, inclusive scan, ballot/bitcount, Monte-Carlo pi with divergence
and atomics, persistent iterative kernels).
"""
from __future__ import annotations

import dataclasses
import hashlib
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

# --------------------------------------------------------------------------
# Types
# --------------------------------------------------------------------------

F32 = "f32"
I32 = "i32"
U32 = "u32"
BOOL = "bool"

_NP_DTYPES = {
    F32: np.float32,
    I32: np.int32,
    U32: np.uint32,
    BOOL: np.bool_,
}


def np_dtype(t: str) -> np.dtype:
    return np.dtype(_NP_DTYPES[t])


def ir_dtype(dt) -> str:
    """Normalize a dtype spec (hetIR code like ``"f32"``, numpy dtype, or
    anything ``np.dtype`` accepts) to the hetIR dtype code."""
    if isinstance(dt, str) and dt in _NP_DTYPES:
        return dt
    npdt = np.dtype(dt)
    for code, npt in _NP_DTYPES.items():
        if np.dtype(npt) == npdt:
            return code
    raise TypeError(f"no hetIR dtype for {dt!r} "
                    f"(supported: {sorted(_NP_DTYPES)})")


# --------------------------------------------------------------------------
# Parameters (kernel arguments)
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class Ptr:
    """A pointer kernel argument — a named global-memory buffer."""

    name: str
    dtype: str = F32


@dataclass(frozen=True)
class Scalar:
    """A scalar kernel argument (uniform across all threads)."""

    name: str
    dtype: str = I32


Param = Union[Ptr, Scalar]


# --------------------------------------------------------------------------
# Registers and ops
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class Reg:
    """An SSA virtual register.  Per-thread unless ``uniform`` is True."""

    name: str
    dtype: str
    uniform: bool = False

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"%{self.name}:{self.dtype}"


# Opcodes ------------------------------------------------------------------
# thread identity
GET_GLOBAL_ID = "GET_GLOBAL_ID"
GET_BLOCK_ID = "GET_BLOCK_ID"
GET_THREAD_ID = "GET_THREAD_ID"
GET_BLOCK_DIM = "GET_BLOCK_DIM"
GET_NUM_BLOCKS = "GET_NUM_BLOCKS"
# constants / moves
CONST = "CONST"
MOV = "MOV"
CVT = "CVT"
# arithmetic (dtype of dest decides int/float semantics)
ADD = "ADD"
SUB = "SUB"
MUL = "MUL"
DIV = "DIV"
MOD = "MOD"
FMA = "FMA"
MIN = "MIN"
MAX = "MAX"
NEG = "NEG"
ABS = "ABS"
SQRT = "SQRT"
EXP = "EXP"
# bitwise / logical
AND = "AND"
OR = "OR"
XOR = "XOR"
NOT = "NOT"
SHL = "SHL"
SHR = "SHR"
# comparisons -> bool
LT = "LT"
LE = "LE"
GT = "GT"
GE = "GE"
EQ = "EQ"
NE = "NE"
SELECT = "SELECT"
# memory
LD_GLOBAL = "LD_GLOBAL"
ST_GLOBAL = "ST_GLOBAL"
LD_SHARED = "LD_SHARED"
ST_SHARED = "ST_SHARED"
LD_PARAM = "LD_PARAM"
# collectives (over active threads of the block)
VOTE_ANY = "VOTE_ANY"
VOTE_ALL = "VOTE_ALL"
VOTE_BALLOT = "VOTE_BALLOT"  # popcount of active threads with pred true
SHUFFLE = "SHUFFLE"  # read val from lane index (block-relative)
REDUCE_ADD = "REDUCE_ADD"  # block-wide sum broadcast to all active threads
REDUCE_MAX = "REDUCE_MAX"
SCAN_ADD = "SCAN_ADD"  # inclusive prefix-sum over lanes of the block
ATOMIC_ADD = "ATOMIC_ADD"  # global-memory atomic add, returns old value
# block primitives (Triton-style): the form lane-independent segments are
# rewritten into by passes.block_lower before the pallas fast path consumes
# them.  Same (buf, idx[, val]) operands as LD_GLOBAL/ST_GLOBAL; attrs carry
# the constexpr tile geometry chosen at translate time:
#   attrs["block"] — constexpr BLOCK size (elements per grid step),
#   attrs["mode"]  — "tiled" (index is exactly the flat global id; the
#                    buffer is BlockSpec-tiled and the index rebased to the
#                    tile) or "gather" (arbitrary proven-disjoint affine
#                    index; the buffer is staged whole and masked-gathered).
# Stores are always masked (predication masks + mode="drop" writes), so a
# partially-active tile never writes out of its proven footprint.
BLOCK_LD = "BLOCK_LD"
BLOCK_ST = "BLOCK_ST"

ALU_UNARY = {NEG, ABS, SQRT, EXP, NOT, MOV}
ALU_BINARY = {ADD, SUB, MUL, DIV, MOD, MIN, MAX, AND, OR, XOR, SHL, SHR}
CMP_OPS = {LT, LE, GT, GE, EQ, NE}
COLLECTIVE_OPS = {VOTE_ANY, VOTE_ALL, VOTE_BALLOT, SHUFFLE, REDUCE_ADD,
                  REDUCE_MAX, SCAN_ADD}
BLOCK_OPS = {BLOCK_LD, BLOCK_ST}


@dataclass(frozen=True)
class Op:
    """A single hetIR instruction."""

    opcode: str
    dest: Optional[Reg]
    args: Tuple[Any, ...] = ()  # Regs, immediates, or param/buffer names
    attrs: Dict[str, Any] = field(default_factory=dict)

    def arg_regs(self) -> List[Reg]:
        return [a for a in self.args if isinstance(a, Reg)]


# --------------------------------------------------------------------------
# Structured statements
# --------------------------------------------------------------------------


@dataclass
class Pred:
    """``@PRED(cond) { body }`` — the paper's explicit predication region.

    On SIMT backends this becomes a hardware exec-mask; on MIMD backends a
    per-thread branch.  Barriers are NOT allowed inside (CUDA-like rule, and
    required for the paper's barrier-anchored state capture to be sound).
    """

    cond: Reg
    body: List["Stmt"]


@dataclass
class Loop:
    """A counted loop.  ``count`` is a uniform scalar (param name or int).

    ``var`` is re-assigned with the iteration index at the top of every
    iteration.  Barriers ARE allowed at the loop body's top level — the
    engine segments through them, which is how the paper migrates
    long-running iterative kernels ("insert a global barrier every X
    iterations of a loop to create segments").
    """

    var: Reg
    count: Union[str, int]
    body: List["Stmt"]


@dataclass
class Barrier:
    """Block-wide barrier and safe suspension point."""

    label: str = ""


Stmt = Union[Op, Pred, Loop, Barrier]


# --------------------------------------------------------------------------
# Program
# --------------------------------------------------------------------------


@dataclass
class Program:
    name: str
    params: List[Param]
    body: List[Stmt]
    shared_size: int = 0  # elements of shared memory per block
    shared_dtype: str = F32

    def param(self, name: str) -> Param:
        for p in self.params:
            if p.name == name:
                return p
        raise KeyError(name)

    def buffers(self) -> List[Ptr]:
        return [p for p in self.params if isinstance(p, Ptr)]

    def scalars(self) -> List[Scalar]:
        return [p for p in self.params if isinstance(p, Scalar)]

    def validate(self) -> None:
        """Check structural invariants (SSA-ish, barrier placement)."""
        _validate_body(self.body, in_pred=False)

    # -- pretty printing (the paper shows textual hetIR assembly) ----------
    def to_text(self) -> str:
        lines = [f".func {self.name}(" + ", ".join(
            (f"%rd<1> %{p.name}" if isinstance(p, Ptr) else f"%{p.dtype} %{p.name}")
            for p in self.params) + ")", "{"]
        if self.shared_size:
            lines.append(f"  .shared .{self.shared_dtype} [{self.shared_size}];")
        _fmt_body(self.body, lines, indent=1)
        lines.append("}")
        return "\n".join(lines)


def _fmt_body(body: Sequence[Stmt], lines: List[str], indent: int) -> None:
    pad = "  " * indent
    for s in body:
        if isinstance(s, Op):
            dest = f"%{s.dest.name} = " if s.dest is not None else ""
            args = ", ".join(
                f"%{a.name}" if isinstance(a, Reg) else str(a) for a in s.args)
            attrs = f" {s.attrs}" if s.attrs else ""
            lines.append(f"{pad}{dest}{s.opcode} {args}{attrs}")
        elif isinstance(s, Pred):
            lines.append(f"{pad}@PRED(%{s.cond.name}) {{")
            _fmt_body(s.body, lines, indent + 1)
            lines.append(f"{pad}}}")
        elif isinstance(s, Loop):
            lines.append(f"{pad}LOOP %{s.var.name} < {s.count} {{")
            _fmt_body(s.body, lines, indent + 1)
            lines.append(f"{pad}}}")
        elif isinstance(s, Barrier):
            lines.append(f"{pad}BAR.SHARED  // {s.label}")


def _validate_body(body: Sequence[Stmt], in_pred: bool) -> None:
    for s in body:
        if isinstance(s, Barrier) and in_pred:
            raise ValueError("BARRIER inside @PRED region is illegal in hetIR")
        if isinstance(s, Pred):
            _validate_body(s.body, in_pred=True)
        if isinstance(s, Loop):
            if in_pred and _contains_barrier(s.body):
                raise ValueError("Loop with barrier inside @PRED is illegal")
            _validate_body(s.body, in_pred=in_pred)


def _contains_barrier(body: Sequence[Stmt]) -> bool:
    for s in body:
        if isinstance(s, Barrier):
            return True
        if isinstance(s, (Pred, Loop)) and _contains_barrier(s.body):
            return True
    return False


# --------------------------------------------------------------------------
# Builder — the "compiler frontend" convenience layer
# --------------------------------------------------------------------------


class _Ctx:
    def __init__(self, builder: "Builder", stmts: List[Stmt]):
        self.builder = builder
        self.stmts = stmts

    def __enter__(self):
        self.builder._stack.append(self.stmts)
        return self

    def __exit__(self, *exc):
        self.builder._stack.pop()
        return False


class Value:
    """Builder-level handle around a :class:`Reg` with operator sugar."""

    __slots__ = ("reg", "b")
    __array_priority__ = 1000  # beat numpy scalars in mixed expressions

    def __init__(self, reg: Reg, b: "Builder"):
        self.reg = reg
        self.b = b

    # arithmetic sugar ------------------------------------------------------
    def _bin(self, opcode: str, other, rdtype: Optional[str] = None,
             swap: bool = False) -> "Value":
        o = self.b._coerce(other, self.reg.dtype)
        a, c = (o, self) if swap else (self, o)
        dt = rdtype or self.reg.dtype
        return self.b._emit(opcode, dt, a.reg, c.reg)

    def __add__(self, o):
        return self._bin(ADD, o)

    __radd__ = __add__

    def __sub__(self, o):
        return self._bin(SUB, o)

    def __rsub__(self, o):
        return self._bin(SUB, o, swap=True)

    def __mul__(self, o):
        return self._bin(MUL, o)

    __rmul__ = __mul__

    def __truediv__(self, o):
        return self._bin(DIV, o)

    def __mod__(self, o):
        return self._bin(MOD, o)

    def __and__(self, o):
        return self._bin(AND, o)

    def __or__(self, o):
        return self._bin(OR, o)

    def __xor__(self, o):
        return self._bin(XOR, o)

    def __lshift__(self, o):
        return self._bin(SHL, o)

    def __rshift__(self, o):
        return self._bin(SHR, o)

    def __neg__(self):
        return self.b._emit(NEG, self.reg.dtype, self.reg)

    # comparisons -> bool values
    def __lt__(self, o):
        return self._bin(LT, o, rdtype=BOOL)

    def __le__(self, o):
        return self._bin(LE, o, rdtype=BOOL)

    def __gt__(self, o):
        return self._bin(GT, o, rdtype=BOOL)

    def __ge__(self, o):
        return self._bin(GE, o, rdtype=BOOL)

    def eq(self, o):
        return self._bin(EQ, o, rdtype=BOOL)

    def ne(self, o):
        return self._bin(NE, o, rdtype=BOOL)

    def astype(self, dtype: str) -> "Value":
        return self.b._emit(CVT, dtype, self.reg)


class Builder:
    """Builds a hetIR :class:`Program` (plays the role of the paper's
    Clang→hetIR frontend for hand-written kernels)."""

    def __init__(self, name: str, params: Sequence[Param],
                 shared_size: int = 0, shared_dtype: str = F32):
        self.program = Program(name=name, params=list(params), body=[],
                               shared_size=shared_size,
                               shared_dtype=shared_dtype)
        self._stack: List[List[Stmt]] = [self.program.body]
        self._counter = 0
        # scalar params become uniform registers on first use
        self._param_vals: Dict[str, Value] = {}

    # -- plumbing -----------------------------------------------------------
    def _fresh(self, dtype: str, hint: str = "t", uniform: bool = False) -> Reg:
        self._counter += 1
        return Reg(f"{hint}{self._counter}", dtype, uniform)

    def _push(self, stmt: Stmt) -> None:
        self._stack[-1].append(stmt)

    def _emit(self, opcode: str, dtype: Optional[str], *args,
              uniform: bool = False, **attrs) -> Optional[Value]:
        dest = self._fresh(dtype, hint=opcode.lower()[:3]) if dtype else None
        self._push(Op(opcode, dest, tuple(
            a.reg if isinstance(a, Value) else a for a in args), dict(attrs)))
        return Value(dest, self) if dest is not None else None

    def _coerce(self, v, dtype: str) -> Value:
        if isinstance(v, Value):
            return v
        return self.const(v, dtype)

    # -- public op API ------------------------------------------------------
    def const(self, v, dtype: str = None) -> Value:
        if dtype is None:
            dtype = F32 if isinstance(v, float) else I32
        return self._emit(CONST, dtype, v)

    def param(self, name: str) -> Value:
        """Load a uniform scalar parameter into a register."""
        if name not in self._param_vals:
            p = self.program.param(name)
            assert isinstance(p, Scalar), f"{name} is not a scalar param"
            val = self._emit(LD_PARAM, p.dtype, name)
            self._param_vals[name] = val
        return self._param_vals[name]

    def global_id(self, dim: int = 0) -> Value:
        return self._emit(GET_GLOBAL_ID, I32, dim)

    def block_id(self) -> Value:
        return self._emit(GET_BLOCK_ID, I32)

    def thread_id(self) -> Value:
        return self._emit(GET_THREAD_ID, I32)

    def block_dim(self) -> Value:
        return self._emit(GET_BLOCK_DIM, I32)

    def num_blocks(self) -> Value:
        return self._emit(GET_NUM_BLOCKS, I32)

    def load(self, buf: str, idx: Value) -> Value:
        p = self.program.param(buf)
        assert isinstance(p, Ptr)
        return self._emit(LD_GLOBAL, p.dtype, buf, idx)

    def store(self, buf: str, idx: Value, val: Value) -> None:
        self._emit(ST_GLOBAL, None, buf, idx, val)

    def load_shared(self, idx: Value) -> Value:
        return self._emit(LD_SHARED, self.program.shared_dtype, idx)

    def store_shared(self, idx: Value, val: Value) -> None:
        self._emit(ST_SHARED, None, idx, val)

    def select(self, cond: Value, a: Value, b: Value) -> Value:
        b_ = self._coerce(b, a.reg.dtype)
        return self._emit(SELECT, a.reg.dtype, cond, a, b_)

    def minimum(self, a: Value, b) -> Value:
        return a._bin(MIN, b)

    def maximum(self, a: Value, b) -> Value:
        return a._bin(MAX, b)

    def sqrt(self, a: Value) -> Value:
        return self._emit(SQRT, a.reg.dtype, a)

    def exp(self, a: Value) -> Value:
        return self._emit(EXP, a.reg.dtype, a)

    def abs(self, a: Value) -> Value:
        return self._emit(ABS, a.reg.dtype, a)

    def fma(self, a: Value, bv: Value, c: Value) -> Value:
        return self._emit(FMA, a.reg.dtype, a, bv, c)

    # collectives
    def vote_any(self, pred: Value) -> Value:
        return self._emit(VOTE_ANY, BOOL, pred)

    def vote_all(self, pred: Value) -> Value:
        return self._emit(VOTE_ALL, BOOL, pred)

    def ballot(self, pred: Value) -> Value:
        return self._emit(VOTE_BALLOT, I32, pred)

    def shuffle(self, val: Value, src_lane: Value) -> Value:
        return self._emit(SHUFFLE, val.reg.dtype, val, src_lane)

    def reduce_add(self, val: Value) -> Value:
        return self._emit(REDUCE_ADD, val.reg.dtype, val)

    def reduce_max(self, val: Value) -> Value:
        return self._emit(REDUCE_MAX, val.reg.dtype, val)

    def scan_add(self, val: Value) -> Value:
        return self._emit(SCAN_ADD, val.reg.dtype, val)

    def atomic_add(self, buf: str, idx: Value, val: Value) -> Value:
        p = self.program.param(buf)
        return self._emit(ATOMIC_ADD, p.dtype, buf, idx, val)

    # control structure
    def when(self, cond: Value) -> _Ctx:
        blk = Pred(cond.reg, [])
        self._push(blk)
        return _Ctx(self, blk.body)

    def loop(self, count: Union[str, int, Value], hint: str = "i"
             ) -> "_LoopCtx":
        if isinstance(count, Value):
            raise TypeError("loop count must be a scalar param name or int "
                            "(uniform), got a Value")
        var = self._fresh(I32, hint=hint, uniform=True)
        blk = Loop(var, count, [])
        self._push(blk)
        return _LoopCtx(self, blk)

    def barrier(self, label: str = "") -> None:
        self._push(Barrier(label))

    # mutable "accumulator" helper: hetIR is SSA, so loop-carried values are
    # modeled via shared or global memory, or via the engine's regfile when
    # re-assigned with .assign() below
    def assign(self, dst: Value, src: Value) -> None:
        """Overwrite dst's register with src (MOV).  Used for loop carries —
        the engine's regfile is mutable between segments, as in the paper."""
        self._push(Op(MOV, dst.reg, (src.reg,)))

    def var(self, init: Value, hint: str = "v") -> Value:
        """Declare a mutable loop-carried variable initialized to ``init``."""
        reg = self._fresh(init.reg.dtype, hint=hint)
        self._push(Op(MOV, reg, (init.reg,)))
        return Value(reg, self)

    def done(self) -> Program:
        self.program.validate()
        return self.program


class _LoopCtx(_Ctx):
    def __init__(self, builder: Builder, loop: Loop):
        super().__init__(builder, loop.body)
        self.loop = loop

    def __enter__(self):
        super().__enter__()
        return Value(self.loop.var, self.builder)


# --------------------------------------------------------------------------
# Liveness / def-use analysis (used by backends to build segment signatures)
# --------------------------------------------------------------------------


def body_defs_uses(body: Sequence[Stmt]) -> Tuple[List[Reg], List[Reg]]:
    """Registers defined in ``body`` and registers used before definition.

    Only *unconditional* defs (not nested under a @PRED or inside a
    possibly-zero-trip loop) shadow later uses: a predicated write leaves
    inactive threads reading the register's pre-segment value, so the
    register is genuinely live-in.  Treating conditional defs as sure defs
    made the engine prune such registers between segments — a divergent
    block then crashed (or, worse, silently merged against zeros) when a
    later segment read them.  Found by the differential fuzz harness."""
    defs: Dict[str, Reg] = {}
    sure: set = set()
    uses: Dict[str, Reg] = {}

    def walk(stmts: Sequence[Stmt], conditional: bool):
        for s in stmts:
            if isinstance(s, Op):
                for r in s.arg_regs():
                    if r.name not in sure and r.name not in uses:
                        uses[r.name] = r
                if s.dest is not None:
                    defs.setdefault(s.dest.name, s.dest)
                    if not conditional:
                        sure.add(s.dest.name)
            elif isinstance(s, Pred):
                if s.cond.name not in sure and s.cond.name not in uses:
                    uses[s.cond.name] = s.cond
                walk(s.body, True)
            elif isinstance(s, Loop):
                defs.setdefault(s.var.name, s.var)
                # a zero-trip loop defines nothing: body defs (and the
                # loop var itself) stay conditional
                walk(s.body, True)
            elif isinstance(s, Barrier):
                pass

    walk(body, False)
    return list(defs.values()), list(uses.values())


def body_global_accesses(body: Sequence[Stmt]) -> Tuple[set, set]:
    """Names of global buffers read / written in ``body``."""
    reads, writes = set(), set()

    def walk(stmts: Sequence[Stmt]):
        for s in stmts:
            if isinstance(s, Op):
                if s.opcode in (LD_GLOBAL, BLOCK_LD):
                    reads.add(s.args[0])
                elif s.opcode in (ST_GLOBAL, BLOCK_ST):
                    writes.add(s.args[0])
                elif s.opcode == ATOMIC_ADD:
                    reads.add(s.args[0])
                    writes.add(s.args[0])
            elif isinstance(s, (Pred, Loop)):
                walk(s.body)

    walk(body)
    return reads, writes


def body_uses_shared(body: Sequence[Stmt]) -> bool:
    def walk(stmts) -> bool:
        for s in stmts:
            if isinstance(s, Op) and s.opcode in (LD_SHARED, ST_SHARED):
                return True
            if isinstance(s, (Pred, Loop)) and walk(s.body):
                return True
        return False

    return walk(body)


# --------------------------------------------------------------------------
# Body rewriting helpers (used by the optimization passes in passes.py)
# --------------------------------------------------------------------------


def walk_ops(body: Sequence[Stmt]):
    """Yield every :class:`Op` in ``body`` in program order, recursively."""
    for s in body:
        if isinstance(s, Op):
            yield s
        elif isinstance(s, (Pred, Loop)):
            yield from walk_ops(s.body)


def count_ops(body: Sequence[Stmt]) -> int:
    """Static op count (Pred/Loop/Barrier structure nodes not counted)."""
    return sum(1 for _ in walk_ops(body))


def rewrite_body(body: Sequence[Stmt],
                 fn: Callable[[Op], Union[Op, List[Stmt], None]]
                 ) -> List[Stmt]:
    """Structure-preserving rewrite: ``fn`` maps each op to a replacement op,
    a list of statements, or ``None`` (delete).  Pred/Loop/Barrier nodes are
    rebuilt around the rewritten bodies."""
    out: List[Stmt] = []
    for s in body:
        if isinstance(s, Op):
            r = fn(s)
            if r is None:
                continue
            out.extend(r if isinstance(r, list) else [r])
        elif isinstance(s, Pred):
            out.append(Pred(s.cond, rewrite_body(s.body, fn)))
        elif isinstance(s, Loop):
            out.append(Loop(s.var, s.count, rewrite_body(s.body, fn)))
        else:
            out.append(s)
    return out


def reg_def_counts(body: Sequence[Stmt]) -> Dict[str, int]:
    """How many ops (or loop headers) define each register name.  A count of
    one means true SSA; ``Builder.assign`` re-targets give counts > 1."""
    counts: Dict[str, int] = {}

    def walk(stmts):
        for s in stmts:
            if isinstance(s, Op):
                if s.dest is not None:
                    counts[s.dest.name] = counts.get(s.dest.name, 0) + 1
            elif isinstance(s, Pred):
                walk(s.body)
            elif isinstance(s, Loop):
                counts[s.var.name] = counts.get(s.var.name, 0) + 1
                walk(s.body)

    walk(body)
    return counts


def reg_use_counts(body: Sequence[Stmt]) -> Dict[str, int]:
    """How many times each register name is read (op args + @PRED conds)."""
    counts: Dict[str, int] = {}

    def walk(stmts):
        for s in stmts:
            if isinstance(s, Op):
                for r in s.arg_regs():
                    counts[r.name] = counts.get(r.name, 0) + 1
            elif isinstance(s, Pred):
                counts[s.cond.name] = counts.get(s.cond.name, 0) + 1
                walk(s.body)
            elif isinstance(s, Loop):
                walk(s.body)

    walk(body)
    return counts


# --------------------------------------------------------------------------
# Stable program fingerprinting (translation-cache keys, paper §4.2)
# --------------------------------------------------------------------------


def _fingerprint_tokens(body: Sequence[Stmt], emit) -> None:
    for s in body:
        if isinstance(s, Op):
            emit("op"); emit(s.opcode)
            if s.dest is None:
                emit("-")
            else:
                emit(f"%{s.dest.name}:{s.dest.dtype}:{int(s.dest.uniform)}")
            for a in s.args:
                if isinstance(a, Reg):
                    emit(f"r%{a.name}:{a.dtype}:{int(a.uniform)}")
                else:
                    emit(f"i{type(a).__name__}:{a!r}")
            for k in sorted(s.attrs):
                emit(f"a{k}={s.attrs[k]!r}")
        elif isinstance(s, Pred):
            emit(f"pred %{s.cond.name}:{s.cond.dtype}")
            _fingerprint_tokens(s.body, emit)
            emit("endpred")
        elif isinstance(s, Loop):
            emit(f"loop %{s.var.name}:{s.var.dtype} {s.count!r}")
            _fingerprint_tokens(s.body, emit)
            emit("endloop")
        elif isinstance(s, Barrier):
            emit(f"bar {s.label}")


def program_fingerprint(prog: Program) -> str:
    """Stable content hash of a program — the translation-cache key
    component (paper §4.2: translated kernels are cached and "reused on
    subsequent launches").  Two independently built but structurally
    identical programs fingerprint equal; any change to params, shared
    memory, or the body changes the digest."""
    cached = prog.__dict__.get("_fingerprint")
    if cached is not None:
        return cached
    h = hashlib.sha256()

    def emit(tok: str) -> None:
        h.update(tok.encode())
        h.update(b"\x00")

    emit(prog.name)
    for p in prog.params:
        kind = "ptr" if isinstance(p, Ptr) else "scalar"
        emit(f"{kind}:{p.name}:{p.dtype}")
    emit(f"shared:{prog.shared_size}:{prog.shared_dtype}")
    _fingerprint_tokens(prog.body, emit)
    fp = h.hexdigest()
    prog.__dict__["_fingerprint"] = fp
    return fp
