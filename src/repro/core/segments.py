"""Barrier segmentation of hetIR programs (paper §4.3, State Capture).

The paper's state-capture design hinges on splitting a kernel into
*segments* separated by barriers: "we break the kernel into segments
separated by global barriers ... Each segment is a separate kernel."
A snapshot is only taken between segments, where every thread of a block is
at a known, aligned point — so the snapshot is just (segment index, register
file, shared memory, global memory), with no machine PC involved.

Segmentation runs *after* the :mod:`~repro.core.passes` pipeline and is
memoized on the optimized :class:`~repro.core.hetir.Program`, so a
``SegNode``'s index is stable across launches — that index is a component
of every translation-cache key (paper §4.2), and is the ``node_idx`` a
:class:`~repro.core.state.Snapshot` records.  The per-segment def/use and
global-access analyses computed here feed both the engine's live-register
pruning (§8) and the pallas backend's coalesced-buffer tiling.

We flatten a structured :class:`~repro.core.hetir.Program` into a linear
list of *nodes*:

* ``SegNode``   — a straight-line chunk of statements with no top-level
  barrier (it may contain @PRED regions and barrier-free loops);
* ``LoopStart`` / ``LoopEnd`` — control nodes for loops whose body contains
  barriers (the engine maintains an iteration counter per loop — part of the
  device-neutral snapshot, like the paper's loop-counter registers).

Execution then proceeds node by node; between any two nodes the engine may
pause, snapshot, and resume on a different backend.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Sequence, Union

from . import hetir as ir


@dataclass
class SegNode:
    index: int
    stmts: List[ir.Stmt]
    label: str = ""
    # analysis results filled by ``segment_program``
    defs: List[ir.Reg] = field(default_factory=list)
    uses: List[ir.Reg] = field(default_factory=list)
    greads: set = field(default_factory=set)
    gwrites: set = field(default_factory=set)
    uses_shared: bool = False


@dataclass
class LoopStart:
    index: int
    loop_id: int
    var: ir.Reg
    count: Union[str, int]  # scalar param name or literal trip count


@dataclass
class LoopEnd:
    index: int
    loop_id: int
    start_index: int


Node = Union[SegNode, LoopStart, LoopEnd]


def segment_program(prog: ir.Program) -> List[Node]:
    """Flatten ``prog.body`` into engine nodes, splitting at barriers."""
    nodes: List[Node] = []
    loop_counter = [0]

    def emit_seg(stmts: List[ir.Stmt], label: str) -> None:
        if not stmts:
            return
        seg = SegNode(index=len(nodes), stmts=stmts, label=label)
        seg.defs, seg.uses = ir.body_defs_uses(stmts)
        seg.greads, seg.gwrites = ir.body_global_accesses(stmts)
        seg.uses_shared = ir.body_uses_shared(stmts)
        nodes.append(seg)

    def walk(body: Sequence[ir.Stmt]) -> None:
        pending: List[ir.Stmt] = []
        for s in body:
            if isinstance(s, ir.Barrier):
                emit_seg(pending, label=s.label)
                pending = []
            elif isinstance(s, ir.Loop) and ir._contains_barrier(s.body):
                # flush statements before the loop, then expand the loop
                emit_seg(pending, label="pre-loop")
                pending = []
                loop_counter[0] += 1
                lid = loop_counter[0]
                start = LoopStart(index=len(nodes), loop_id=lid, var=s.var,
                                  count=s.count)
                nodes.append(start)
                walk(s.body)
                # implicit barrier at loop back-edge: segments inside ended
                nodes.append(LoopEnd(index=len(nodes), loop_id=lid,
                                     start_index=start.index))
            else:
                pending.append(s)
        emit_seg(pending, label="tail")

    walk(prog.body)
    # fix node indices after construction order
    for i, n in enumerate(nodes):
        if isinstance(n, SegNode):
            n.index = i
        elif isinstance(n, LoopStart):
            n.index = i
        else:
            n.index = i
    # re-resolve start_index (indices may have shifted): map loop_id -> start
    starts = {n.loop_id: n.index for n in nodes if isinstance(n, LoopStart)}
    for n in nodes:
        if isinstance(n, LoopEnd):
            n.start_index = starts[n.loop_id]
    return nodes


def seg_nodes(nodes: Sequence[Node]) -> List[SegNode]:
    return [n for n in nodes if isinstance(n, SegNode)]
