"""Barrier segmentation of hetIR programs (paper §4.3, State Capture).

The paper's state-capture design hinges on splitting a kernel into
*segments* separated by barriers: "we break the kernel into segments
separated by global barriers ... Each segment is a separate kernel."
A snapshot is only taken between segments, where every thread of a block is
at a known, aligned point — so the snapshot is just (segment index, register
file, shared memory, global memory), with no machine PC involved.

Segmentation runs *after* the :mod:`~repro.core.passes` pipeline and is
memoized on the optimized :class:`~repro.core.hetir.Program`, so a
``SegNode``'s index is stable across launches — that index is a component
of every translation-cache key (paper §4.2), and is the ``node_idx`` a
:class:`~repro.core.state.Snapshot` records.  The per-segment def/use and
global-access analyses computed here feed both the engine's live-register
pruning (§8) and the pallas backend's coalesced-buffer tiling.

We flatten a structured :class:`~repro.core.hetir.Program` into a linear
list of *nodes*:

* ``SegNode``   — a straight-line chunk of statements with no top-level
  barrier (it may contain @PRED regions and barrier-free loops);
* ``LoopStart`` / ``LoopEnd`` — control nodes for loops whose body contains
  barriers (the engine maintains an iteration counter per loop — part of the
  device-neutral snapshot, like the paper's loop-counter registers).

Execution then proceeds node by node; between any two nodes the engine may
pause, snapshot, and resume on a different backend.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Union

from . import hetir as ir


@dataclass
class SegNode:
    index: int
    stmts: List[ir.Stmt]
    label: str = ""
    # analysis results filled by ``segment_program``
    defs: List[ir.Reg] = field(default_factory=list)
    uses: List[ir.Reg] = field(default_factory=list)
    greads: set = field(default_factory=set)
    gwrites: set = field(default_factory=set)
    uses_shared: bool = False


@dataclass
class LoopStart:
    index: int
    loop_id: int
    var: ir.Reg
    count: Union[str, int]  # scalar param name or literal trip count


@dataclass
class LoopEnd:
    index: int
    loop_id: int
    start_index: int


Node = Union[SegNode, LoopStart, LoopEnd]


def static_trip_count(count: Union[str, int]) -> Optional[int]:
    """Trip count of a loop when it is knowable without a launch: an ``int``
    literal.  A scalar-param name returns ``None`` — its value only exists
    at launch time.  This is the legality gate shared by the optimizer
    (:mod:`~repro.core.passes` may unroll, or let value numbers survive a
    loop, only when the trip count is statically positive) and the engine's
    node walker."""
    return int(count) if isinstance(count, int) else None


def resolve_trip_count(count: Union[str, int],
                       scalars: Optional[Dict[str, object]] = None
                       ) -> Optional[int]:
    """Trip count given a launch's uniform scalars; ``None`` if unknowable
    (dynamic count and no/missing scalars)."""
    static = static_trip_count(count)
    if static is not None:
        return static
    if scalars is not None and count in scalars:
        return int(scalars[count])
    return None


def dynamic_op_count(body: Sequence[ir.Stmt],
                     scalars: Optional[Dict[str, object]] = None) -> int:
    """Per-thread *executed-op schedule* size of ``body``: every op counts
    once per time the walker reaches it, with loop bodies multiplied by
    their (resolved) trip counts.  ``@PRED`` bodies count in full — the
    schedule models issued instructions, and every backend walks both sides
    of a predicated region (SIMT masking).  Unresolvable trip counts fall
    back to 1 so the metric stays a lower bound rather than guessing.

    This is the number the translation benchmarks report per opt level:
    loop unrolling plus post-unroll folding/CSE shrink it, which is exactly
    the paper's "optimize once, every target benefits" claim in one
    integer."""
    total = 0
    for s in body:
        if isinstance(s, ir.Op):
            total += 1
        elif isinstance(s, ir.Pred):
            total += dynamic_op_count(s.body, scalars)
        elif isinstance(s, ir.Loop):
            trips = resolve_trip_count(s.count, scalars)
            total += max(0, 1 if trips is None else trips) \
                * dynamic_op_count(s.body, scalars)
    return total


def dynamic_op_histogram(body: Sequence[ir.Stmt],
                         scalars: Optional[Dict[str, object]] = None
                         ) -> Dict[str, int]:
    """Per-thread executed-op schedule of ``body`` broken down *by opcode*
    — the same walk as :func:`dynamic_op_count` (loop bodies multiplied by
    resolved trip counts, ``@PRED`` bodies in full, unresolved trips
    counted once) but keeping each opcode's tally.  This is what the
    measured roofline mode feeds on: memory opcodes (``LD_GLOBAL`` /
    ``ST_GLOBAL`` / ``ATOMIC_ADD`` / block forms) give the bytes term,
    ALU/FMA opcodes give the FLOPs term."""
    hist: Dict[str, int] = {}

    def walk(stmts: Sequence[ir.Stmt], mult: int) -> None:
        for s in stmts:
            if isinstance(s, ir.Op):
                hist[s.opcode] = hist.get(s.opcode, 0) + mult
            elif isinstance(s, ir.Pred):
                walk(s.body, mult)
            elif isinstance(s, ir.Loop):
                trips = resolve_trip_count(s.count, scalars)
                walk(s.body, mult * max(0, 1 if trips is None else trips))

    walk(body, 1)
    return hist


def specializable_counts(body: Sequence[ir.Stmt]) -> set:
    """Scalar-param names used as trip counts of *barrier-free* loops —
    the profitability signal for launch-time specialization: binding one
    of these turns a dynamic trip count static, which is what lets
    :func:`~repro.core.passes.unroll_loops` (and the static-trip gates of
    hoisting / cross-segment value numbering) fire at launch time.
    Barrier-carrying loops are excluded: they are the engine's
    segment/migration structure and are never unrolled, so binding their
    counts alone is not worth a specialized variant."""
    names: set = set()

    def walk(stmts: Sequence[ir.Stmt]) -> None:
        for s in stmts:
            if isinstance(s, ir.Loop):
                if isinstance(s.count, str) \
                        and not ir._contains_barrier(s.body):
                    names.add(s.count)
                walk(s.body)
            elif isinstance(s, ir.Pred):
                walk(s.body)

    walk(body)
    return names


def segment_program(prog: ir.Program) -> List[Node]:
    """Flatten ``prog.body`` into engine nodes, splitting at barriers."""
    nodes: List[Node] = []
    loop_counter = [0]

    def emit_seg(stmts: List[ir.Stmt], label: str) -> None:
        if not stmts:
            return
        seg = SegNode(index=len(nodes), stmts=stmts, label=label)
        seg.defs, seg.uses = ir.body_defs_uses(stmts)
        seg.greads, seg.gwrites = ir.body_global_accesses(stmts)
        seg.uses_shared = ir.body_uses_shared(stmts)
        nodes.append(seg)

    def walk(body: Sequence[ir.Stmt]) -> None:
        pending: List[ir.Stmt] = []
        for s in body:
            if isinstance(s, ir.Barrier):
                emit_seg(pending, label=s.label)
                pending = []
            elif isinstance(s, ir.Loop) and ir._contains_barrier(s.body):
                # flush statements before the loop, then expand the loop
                emit_seg(pending, label="pre-loop")
                pending = []
                loop_counter[0] += 1
                lid = loop_counter[0]
                start = LoopStart(index=len(nodes), loop_id=lid, var=s.var,
                                  count=s.count)
                nodes.append(start)
                walk(s.body)
                # implicit barrier at loop back-edge: segments inside ended
                nodes.append(LoopEnd(index=len(nodes), loop_id=lid,
                                     start_index=start.index))
            else:
                pending.append(s)
        emit_seg(pending, label="tail")

    walk(prog.body)
    # fix node indices after construction order
    for i, n in enumerate(nodes):
        if isinstance(n, SegNode):
            n.index = i
        elif isinstance(n, LoopStart):
            n.index = i
        else:
            n.index = i
    # re-resolve start_index (indices may have shifted): map loop_id -> start
    starts = {n.loop_id: n.index for n in nodes if isinstance(n, LoopStart)}
    for n in nodes:
        if isinstance(n, LoopEnd):
            n.start_index = starts[n.loop_id]
    return nodes


def seg_nodes(nodes: Sequence[Node]) -> List[SegNode]:
    return [n for n in nodes if isinstance(n, SegNode)]
