"""Multi-tenant serving tier on the driver API (paper §4.3 under load).

The paper's runtime promises a uniform abstraction of threads, memory,
and synchronization that holds up under real fleets, not just
single happy-path launches.  :class:`ServingFrontEnd` is the coordinator
that puts the :class:`~repro.core.runtime.HetSession` scheduler under
that load: tenants register with a fair-share **weight**, a **priority**
tier, and an in-flight **quota**; each tenant gets a *sticky* stream
(all of a tenant's launches ride its own in-order queue, so per-tenant
dataflow keeps CUDA stream semantics while the session's
weighted-fair-share scheduler arbitrates *between* tenants at segment
granularity).

Admission control is quota-based load shedding: a ``submit`` that would
exceed the tenant's in-flight quota (or the coordinator's global cap) is
**rejected with an error** (:class:`QuotaExceeded`) before anything is
enqueued — in-flight work is never cancelled or lost to shedding, the
overload is pushed back to the caller, who retries or sheds upstream.

The coordinator/worker-queue shape (a dispatcher in front of sticky
per-worker queues, with per-worker state and counters) follows the
GPU-miner coordinator idiom referenced in the roadmap; here the
"workers" are scheduler streams and the dispatch currency is segments.

The front end can alternatively sit on a **fleet**
(:class:`~repro.core.fleet.FleetCoordinator`) instead of an in-process
session: pass a coordinator as the first constructor argument and
tenants keep their quotas, admission control, and latency accounting,
but launches dispatch to worker *processes* (kernels named by string,
args as host arrays) and survive worker deaths via the fleet's retry
queue — the serving tier inherits self-healing without changing its
API surface.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Union

from .runtime import Function, HetSession, LaunchRecord, Stream


class QuotaExceeded(RuntimeError):
    """Admission rejected: the tenant (or the coordinator) is at its
    in-flight quota.  Nothing was enqueued — retry after completions
    drain, or shed the request upstream."""

    def __init__(self, message: str, tenant: str):
        super().__init__(message)
        self.tenant = tenant


class ServeTicket:
    """One admitted request: the launch future plus serving metadata
    (tenant, submit/completion timestamps, measured latency)."""

    __slots__ = ("tenant", "record", "submitted_at", "completed_at")

    def __init__(self, tenant: str, record: LaunchRecord):
        self.tenant = tenant
        self.record = record
        self.submitted_at = time.perf_counter()
        self.completed_at: Optional[float] = None

    def done(self) -> bool:
        return self.completed_at is not None

    @property
    def latency_ms(self) -> Optional[float]:
        if self.completed_at is None:
            return None
        return (self.completed_at - self.submitted_at) * 1e3

    def __repr__(self) -> str:
        state = f"{self.latency_ms:.2f}ms" if self.done() else "in-flight"
        return f"<ServeTicket {self.tenant} #{self.record.seq} {state}>"


@dataclass
class TenantState:
    """Per-tenant serving state: the sticky stream (``None`` in fleet
    mode, where dispatch is the fleet's), the quota, and the counters
    the front end reports."""
    name: str
    stream: Optional[Stream]
    max_inflight: int
    weight: float = 1.0
    priority: int = 0
    inflight: List[ServeTicket] = field(default_factory=list)
    admitted: int = 0
    rejected: int = 0
    completed: int = 0
    latencies_ms: List[float] = field(default_factory=list)

    def stats(self) -> Dict[str, object]:
        out = {"tenant": self.name,
               "stream": self.stream.sid if self.stream else None,
               "weight": self.weight, "priority": self.priority,
               "max_inflight": self.max_inflight,
               "inflight": len(self.inflight),
               "admitted": self.admitted, "rejected": self.rejected,
               "completed": self.completed}
        if self.latencies_ms:
            out["p50_ms"] = round(_pct(self.latencies_ms, 50), 3)
            out["p99_ms"] = round(_pct(self.latencies_ms, 99), 3)
        return out


def _pct(samples: List[float], q: float) -> float:
    ordered = sorted(samples)
    idx = min(len(ordered) - 1, int(round((q / 100.0)
                                          * (len(ordered) - 1))))
    return ordered[idx]


class ServingFrontEnd:
    """Coordinator for multi-tenant serving on one session.

    * ``tenant(name, weight=, priority=, max_inflight=)`` registers a
      tenant (idempotent) and pins its sticky stream.
    * ``submit(name, fn, grid, block, args)`` applies admission control,
      then enqueues on the tenant's stream and returns a
      :class:`ServeTicket`.
    * ``pump(decisions)`` drives the scheduler and reaps completions
      (recording per-request latency); ``drain()`` runs everything dry.

    ``slo_ms`` is advisory: completions above it are counted in
    ``slo_violations`` per tenant aggregate — admission itself sheds on
    *quotas*, so an overload rejects new work instead of silently
    blowing the deadline of admitted work.
    """

    def __init__(self, session, max_inflight: int = 256,
                 default_quota: int = 32, slo_ms: Optional[float] = None,
                 quantum: int = 1):
        # ``session`` is either an in-process HetSession or a
        # FleetCoordinator (duck-typed on its fleet_stats surface) —
        # fleet mode routes launches to worker processes instead of
        # in-process streams, with identical admission semantics.
        self.fleet = session if hasattr(session, "fleet_stats") else None
        self.session: Optional[HetSession] = \
            None if self.fleet is not None else session
        self.max_inflight = int(max_inflight)
        self.default_quota = int(default_quota)
        self.slo_ms = slo_ms
        self.quantum = max(1, int(quantum))
        self.tenants: Dict[str, TenantState] = {}
        self.slo_violations = 0
        self.total_inflight = 0

    # -- tenant registry (sticky stream assignment) ------------------------
    def tenant(self, name: str, weight: float = 1.0, priority: int = 0,
               max_inflight: Optional[int] = None) -> TenantState:
        """Register ``name`` (or return its existing state).  The tenant's
        stream is created once and stays sticky — scheduling policy
        changes require a new tenant, matching driver streams whose
        priority is fixed at creation."""
        t = self.tenants.get(name)
        if t is None:
            quota = self.default_quota if max_inflight is None \
                else int(max_inflight)
            if self.fleet is not None:
                # fleet mode: no sticky stream — the fleet's dispatcher
                # owns placement; weight/priority kept for reporting
                t = TenantState(name, None, quota,
                                weight=weight, priority=priority)
            else:
                st = self.session.stream(weight=weight, priority=priority,
                                         quantum=self.quantum)
                t = TenantState(name, st, quota,
                                weight=st.weight, priority=st.priority)
            self.tenants[name] = t
        return t

    def retire_tenant(self, name: str) -> None:
        """Drop a tenant and destroy its stream (refuses while the tenant
        still has in-flight work, like :meth:`Stream.destroy`)."""
        t = self.tenants.get(name)
        if t is None:
            return
        if t.inflight:
            raise RuntimeError(
                f"tenant {name!r} has {len(t.inflight)} in-flight "
                "request(s) — drain before retiring")
        if t.stream is not None:
            t.stream.destroy()
        del self.tenants[name]

    # -- admission + dispatch ----------------------------------------------
    def submit(self, name: str, fn: Union[Function, str], grid: int,
               block: int, args: Dict[str, object]) -> ServeTicket:
        """Admit and enqueue one request for tenant ``name`` (which must
        be registered).  Raises :class:`QuotaExceeded` — *before* anything
        is enqueued — when the tenant or the coordinator is at its
        in-flight cap.  In fleet mode ``fn`` may be the kernel name (the
        fleet registry resolves it in each worker) and ``args`` are host
        values (scalars / numpy arrays), not device buffers."""
        t = self.tenants.get(name)
        if t is None:
            raise KeyError(f"unknown tenant {name!r} — register with "
                           "front.tenant(name, ...) first")
        self._reap(t)
        if len(t.inflight) >= t.max_inflight:
            t.rejected += 1
            raise QuotaExceeded(
                f"tenant {name!r} is at its in-flight quota "
                f"({t.max_inflight}) — shed or retry after completions",
                tenant=name)
        if self.total_inflight >= self.max_inflight:
            t.rejected += 1
            raise QuotaExceeded(
                f"serving front end is at its global in-flight cap "
                f"({self.max_inflight}) — shed or retry after completions",
                tenant=name)
        if self.fleet is not None:
            kernel = fn if isinstance(fn, str) else fn.name
            rec = self.fleet.submit(kernel, grid, block, args)
        else:
            rec = fn.launch_async(grid, block, args, stream=t.stream)
        ticket = ServeTicket(name, rec)
        t.inflight.append(ticket)
        t.admitted += 1
        self.total_inflight += 1
        return ticket

    # -- driving the scheduler ---------------------------------------------
    def pump(self, decisions: int = 64) -> bool:
        """Make up to ``decisions`` scheduling decisions and reap
        completions.  Returns True iff any progress was made.  In fleet
        mode a "decision" is one fleet pump round (dispatch sweep + one
        segment slice per busy worker)."""
        if self.fleet is not None:
            progressed = self.fleet.pump()
        else:
            progressed = self.session.step(decisions)
        for t in self.tenants.values():
            self._reap(t)
        return progressed

    def drain(self, timeout: Optional[float] = None) -> bool:
        """Drive everything to completion (False if paused work remains),
        then reap.  In fleet mode this waits until every accepted launch
        is acked — surviving worker deaths along the way."""
        if self.fleet is not None:
            self.fleet.wait_all(timeout=timeout)
            ok = True
        else:
            ok = self.session.synchronize()
        for t in self.tenants.values():
            self._reap(t)
        return ok

    def _reap(self, t: TenantState) -> None:
        still: List[ServeTicket] = []
        now = time.perf_counter()
        for ticket in t.inflight:
            rec = ticket.record
            if rec.finished or rec.cancelled:
                ticket.completed_at = now
                t.completed += 1
                self.total_inflight -= 1
                lat = ticket.latency_ms
                t.latencies_ms.append(lat)
                if self.slo_ms is not None and lat > self.slo_ms:
                    self.slo_violations += 1
            else:
                still.append(ticket)
        t.inflight = still

    # -- reporting ---------------------------------------------------------
    def stats(self) -> Dict[str, object]:
        per = [t.stats() for t in self.tenants.values()]
        lats = [x for t in self.tenants.values() for x in t.latencies_ms]
        agg: Dict[str, object] = {
            "tenants": per,
            "admitted": sum(t.admitted for t in self.tenants.values()),
            "rejected": sum(t.rejected for t in self.tenants.values()),
            "completed": sum(t.completed for t in self.tenants.values()),
            "inflight": self.total_inflight,
            "slo_ms": self.slo_ms,
            "slo_violations": self.slo_violations,
        }
        if lats:
            agg["p50_ms"] = round(_pct(lats, 50), 3)
            agg["p99_ms"] = round(_pct(lats, 99), 3)
        if self.fleet is not None:
            agg["fleet"] = self.fleet.fleet_stats()
        return agg

    def __repr__(self) -> str:
        return (f"<ServingFrontEnd tenants={len(self.tenants)} "
                f"inflight={self.total_inflight}/{self.max_inflight}>")
