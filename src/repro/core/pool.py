"""Device-memory pooling for the serving tier (paper §4.3 memory
abstraction under load).

The driver API hands out :class:`~repro.core.runtime.DeviceBuffer`
handles; a serving workload allocates and frees thousands of short-lived
buffers per second, and backing each one with a fresh ``np.zeros`` turns
the allocator into the hot path.  :class:`BufferPool` is a size-class
sub-allocator: backings are carved in power-of-two element classes, a
freed buffer's backing returns to the class free list, and the next
``alloc`` of a compatible (dtype, class) reuses it — zeroed, so the
"fresh allocation is zero-initialized" contract holds either way.

The pool is bounded (``max_bytes``, env ``HETGPU_POOL_MAX_BYTES``):
backings past the bound are dropped to the host allocator instead of
accumulating.  ``stats()`` exposes hit/miss/reuse-rate counters — the
serving benchmark's steady-state acceptance bar is a ≥ 90% reuse rate.
"""
from __future__ import annotations

import os
from typing import Dict, List, Optional, Tuple

import numpy as np

#: floor for the smallest size class, in elements — tiny buffers all land
#: in one class so a mixed small-allocation workload still pools well
_MIN_CLASS = 64

#: default pool bound: 64 MiB of retained free backings
_DEFAULT_MAX_BYTES = 64 << 20


def size_class(size: int) -> int:
    """The pooled capacity (in elements) that backs a ``size``-element
    request: the next power of two, floored at ``_MIN_CLASS``."""
    size = max(int(size), 1)
    cls = _MIN_CLASS
    while cls < size:
        cls <<= 1
    return cls


class BufferPool:
    """Size-class free lists of ndarray backings, keyed by (dtype, class).

    ``take(size, np_dtype)`` returns a zeroed backing of
    ``size_class(size)`` elements (the caller views the first ``size``);
    ``release(backing)`` returns it for reuse.  Both are O(1).  A
    ``max_bytes=0`` (or ``enabled=False``) pool degenerates to plain
    allocation — every take is a miss, every release a drop."""

    def __init__(self, max_bytes: Optional[int] = None,
                 enabled: bool = True):
        if max_bytes is None:
            max_bytes = int(os.environ.get("HETGPU_POOL_MAX_BYTES",
                                           _DEFAULT_MAX_BYTES))
        self.max_bytes = int(max_bytes)
        self.enabled = bool(enabled) and self.max_bytes > 0
        self._free: Dict[Tuple[str, int], List[np.ndarray]] = {}
        self.pooled_bytes = 0
        self.hits = 0          # takes served from a free list
        self.misses = 0        # takes that hit the host allocator
        self.released = 0      # backings accepted back into the pool
        self.dropped = 0       # releases past the bound (or disabled)

    # ------------------------------------------------------------------
    def take(self, size: int, np_dtype: np.dtype) -> np.ndarray:
        """A zeroed backing array of ``size_class(size)`` elements."""
        np_dtype = np.dtype(np_dtype)
        cls = size_class(size)
        lst = self._free.get((np_dtype.str, cls))
        if lst:
            backing = lst.pop()
            self.pooled_bytes -= backing.nbytes
            self.hits += 1
            backing[:size] = 0          # the visible span must read as fresh
            return backing
        self.misses += 1
        return np.zeros(cls, dtype=np_dtype)

    def release(self, backing: np.ndarray) -> bool:
        """Return a backing to its class free list.  Returns False when the
        pool is full (or disabled) and the backing was dropped instead."""
        if not self.enabled \
                or self.pooled_bytes + backing.nbytes > self.max_bytes:
            self.dropped += 1
            return False
        key = (backing.dtype.str, backing.size)
        self._free.setdefault(key, []).append(backing)
        self.pooled_bytes += backing.nbytes
        self.released += 1
        return True

    def trim(self) -> int:
        """Drop every retained backing (e.g. before handing memory back to
        the host).  Returns the number of bytes released."""
        freed = self.pooled_bytes
        self._free.clear()
        self.pooled_bytes = 0
        return freed

    # ------------------------------------------------------------------
    def reuse_rate(self) -> float:
        """Fraction of takes served from the pool."""
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def stats(self) -> Dict[str, object]:
        return {
            "hits": self.hits, "misses": self.misses,
            "released": self.released, "dropped": self.dropped,
            "pooled_bytes": self.pooled_bytes,
            "max_bytes": self.max_bytes,
            "free_lists": len(self._free),
            "reuse_rate": round(self.reuse_rate(), 4),
        }

    def __repr__(self) -> str:
        return (f"<BufferPool {self.pooled_bytes}/{self.max_bytes}B "
                f"reuse={self.reuse_rate():.2%}>")
