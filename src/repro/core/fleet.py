"""Self-healing worker fleet — the paper's live-migration claim at
production scale (§6.3 migration, §4.3 state capture, under failure).

PR 6/7 gave the runtime a driver-style API with in-process streams and a
happy-path :func:`~repro.core.runtime.migrate`.  This module makes the
multi-process fleet the ROADMAP asks for, and makes *failure* a
first-class input instead of an untested branch:

* **Workers** (:func:`_worker_main`) are separate OS processes, each
  owning a full :class:`~repro.core.runtime.HetSession` bound to its own
  backend (interp / vectorized / pallas — a fleet can be heterogeneous,
  which is the paper's whole point).  The coordinator talks to each
  worker over a ``multiprocessing`` pipe with a strict request/reply
  protocol; kernels execute in bounded *segment slices*
  (:meth:`~repro.core.runtime.LaunchRecord.advance`), so between slices
  every launch rests at a barrier — exactly where the paper's snapshot
  is legal — and the control plane can interpose.

* The **control plane** (:class:`FleetCoordinator`) dispatches accepted
  launches to the least-loaded alive worker and pumps slices round-robin.
  Migration is *policy-driven* rather than caller-driven:
  :meth:`~FleetCoordinator.drain` moves a worker's in-flight launches
  elsewhere via checkpoint/restore (graceful — live state rides along),
  :meth:`~FleetCoordinator.rebalance` evens out load the same way, and
  :meth:`~FleetCoordinator.evacuate_on_failure` handles the ungraceful
  case: a dead worker's state is gone, so its launches *replay* from the
  retry queue on a surviving worker — bit-identically, because execution
  is deterministic per backend and snapshots are device-neutral.

* The **two-tier retry queue** (:class:`RetryQueue`) is what makes every
  accepted launch durable until acked: an in-memory tier for dispatch
  bookkeeping plus a JSON-on-disk persistent tier (atomic
  temp-file + ``os.replace`` writes, ndarray args base64-encoded
  bit-exactly), so a coordinator restart recovers unacked work and a
  double ack is structurally impossible (``ack`` consumes exactly once).

* The **fault-injection harness** (:class:`FaultInjector`) is the proof.
  It is env-gated (``HETGPU_FAULT_PLAN`` — a JSON plan, or ``@path`` to
  one; ``HETGPU_FAULT_SEED`` resolves any unpinned choices
  deterministically) and runs *inside* the worker: at a named fault
  point — ``pre-launch``, ``mid-kernel`` (at a segment boundary), or
  ``post-checkpoint-pre-ack`` (work complete, ack never sent) — it
  SIGKILLs the worker process, kill ``-9``, no cleanup.  The coordinator
  must detect the death, requeue, replay, and still produce bit-identical
  results with zero lost and zero double-acked launches; the chaos suite
  (``tests/test_chaos_fleet.py``) asserts exactly that at every point.

Workers attached to the same ``store_dir`` share one persistent
:class:`~repro.core.cache.DiskStore`, so a kernel is translated once per
fleet (single-flight cross-process locking lives in
:mod:`~repro.core.cache`) — the paper's cluster-lifetime JIT
amortization, now actually cross-process.
"""
from __future__ import annotations

import base64
import itertools
import json
import os
import pickle
import signal
import tempfile
import time
import traceback
import uuid
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

#: the named fault points a :class:`FaultInjector` can kill a worker at
PRE_LAUNCH = "pre-launch"
MID_KERNEL = "mid-kernel"
POST_CHECKPOINT_PRE_ACK = "post-checkpoint-pre-ack"
FAULT_POINTS = (PRE_LAUNCH, MID_KERNEL, POST_CHECKPOINT_PRE_ACK)

#: default per-RPC timeout: a wedged worker fails loudly, never hangs CI
_DEFAULT_RPC_TIMEOUT = float(os.environ.get("HETGPU_FLEET_TIMEOUT", "60"))


class FleetError(RuntimeError):
    """Base class for fleet control-plane failures."""


class FleetTimeout(FleetError):
    """A worker did not reply within the RPC timeout — it is treated as
    wedged and the operation fails loudly instead of hanging."""


class WorkerLost(FleetError):
    """The worker died mid-conversation (its launches have already been
    requeued by the time this is raised)."""


class FleetWorkerError(FleetError):
    """The worker survived but the command raised; carries the remote
    traceback."""


# ---------------------------------------------------------------------------
# Fault injection (runs inside the worker process)
# ---------------------------------------------------------------------------

class FaultInjector:
    """Deterministic kill-switch for chaos testing.  Each *spec* names a
    fault point and what must match for it to arm:

    ``{"point": "mid-kernel", "worker": 1, "kernel": "dyn_matmul",
       "nth": 1, "after_segments": 3}``

    * ``point`` — one of :data:`FAULT_POINTS`;
    * ``worker`` — worker id the spec applies to (``None`` = any; specs
      for other workers are dropped at construction);
    * ``kernel`` — kernel name filter (``None`` = any);
    * ``nth`` — fire on the n-th matching occurrence (1-based, default 1);
    * ``after_segments`` — for ``mid-kernel``: kill once this many
      segments of the matched launch have executed.  When omitted it is
      resolved from the seed (``HETGPU_FAULT_SEED``), deterministically
      per worker and spec index, so an unpinned plan is still exactly
      reproducible.

    Firing is ``os.kill(os.getpid(), SIGKILL)`` — the hard death the
    self-healing machinery must survive.  The injector is inert with an
    empty plan (the production default: no env var, no faults).
    """

    def __init__(self, specs: Optional[Sequence[Dict]] = None,
                 worker_id: Optional[int] = None, seed: int = 0):
        self.worker_id = worker_id
        rng = np.random.default_rng(
            abs(int(seed)) + 7919 * (worker_id if worker_id else 0))
        self._specs: List[Dict] = []
        for idx, raw in enumerate(specs or []):
            spec = dict(raw)
            if spec.get("point") not in FAULT_POINTS:
                raise ValueError(
                    f"fault spec {idx}: unknown point {spec.get('point')!r} "
                    f"(valid: {FAULT_POINTS})")
            w = spec.get("worker")
            if worker_id is not None and w is not None \
                    and int(w) != worker_id:
                continue
            spec["nth"] = int(spec.get("nth", 1))
            if spec["point"] == MID_KERNEL \
                    and not spec.get("after_segments"):
                spec["after_segments"] = int(rng.integers(1, 6))
            spec["_matched"] = 0
            spec["_armed"] = None   # the launch_id the spec armed on
            self._specs.append(spec)

    @classmethod
    def from_env(cls, worker_id: Optional[int] = None) -> "FaultInjector":
        """Env-gated construction: no ``HETGPU_FAULT_PLAN`` → inert."""
        return cls(load_fault_plan(), worker_id,
                   int(os.environ.get("HETGPU_FAULT_SEED", "0") or 0))

    def _match(self, spec: Dict, kernel: str) -> bool:
        return spec.get("kernel") in (None, kernel)

    def _kill(self) -> None:  # pragma: no cover - the process dies here
        os.kill(os.getpid(), signal.SIGKILL)

    # -- hooks the worker calls at the named points ------------------------
    def begin_launch(self, kernel: str, launch_id: str = "") -> None:
        """Called when a launch starts (or a migrated one restores):
        counts occurrences and arms matching ``mid-kernel`` specs *on
        that launch* — arming is per-launch, so a worker juggling
        several launches (starts arrive before any of them advances)
        keeps the armed spec pointed at the n-th match, not at whichever
        launch happened to start last."""
        for s in self._specs:
            if s["point"] == MID_KERNEL and s["_armed"] is None \
                    and self._match(s, kernel):
                s["_matched"] += 1
                if s["_matched"] == s["nth"]:
                    s["_armed"] = launch_id

    def on_segment(self, kernel: str, segments_done: int,
                   launch_id: str = "") -> None:
        """Called at every segment boundary of the running launch."""
        for s in self._specs:
            if s["point"] == MID_KERNEL and s["_armed"] == launch_id \
                    and s["_armed"] is not None \
                    and segments_done >= s["after_segments"]:
                self._kill()

    def at_point(self, point: str, kernel: str) -> None:
        """Called at ``pre-launch`` / ``post-checkpoint-pre-ack``."""
        for s in self._specs:
            if s["point"] == point and self._match(s, kernel):
                s["_matched"] += 1
                if s["_matched"] == s["nth"]:
                    self._kill()


def load_fault_plan() -> List[Dict]:
    """Parse ``HETGPU_FAULT_PLAN`` (inline JSON, or ``@/path/to.json``).
    Absent/empty → no faults."""
    raw = os.environ.get("HETGPU_FAULT_PLAN", "").strip()
    if not raw:
        return []
    if raw.startswith("@"):
        raw = Path(raw[1:]).read_text()
    plan = json.loads(raw)
    if not isinstance(plan, list):
        raise ValueError("HETGPU_FAULT_PLAN must be a JSON list of specs")
    return plan


# ---------------------------------------------------------------------------
# Durable launch payloads (JSON-safe, bit-exact)
# ---------------------------------------------------------------------------

def _encode_value(v) -> object:
    if isinstance(v, np.ndarray):
        return {"__ndarray__": {
            "dtype": v.dtype.str, "shape": list(v.shape),
            "data": base64.b64encode(np.ascontiguousarray(v).tobytes())
            .decode("ascii")}}
    if isinstance(v, np.generic):
        return {"__npscalar__": {"dtype": v.dtype.str,
                                 "value": v.item()}}
    if isinstance(v, (bool, int, float, str)) or v is None:
        return v
    raise TypeError(f"launch argument of type {type(v).__name__} is not "
                    "durable (pass scalars or numpy arrays)")


def _decode_value(v):
    if isinstance(v, dict) and "__ndarray__" in v:
        d = v["__ndarray__"]
        return np.frombuffer(
            base64.b64decode(d["data"]),
            dtype=np.dtype(d["dtype"])).reshape(d["shape"]).copy()
    if isinstance(v, dict) and "__npscalar__" in v:
        d = v["__npscalar__"]
        return np.dtype(d["dtype"]).type(d["value"])
    return v


# ---------------------------------------------------------------------------
# Two-tier retry queue: in-memory dispatch state + JSON-on-disk durability
# ---------------------------------------------------------------------------

class RetryQueue:
    """Every accepted launch lives here until acked — the self-healing
    invariant's source of truth.

    States: ``pending`` (awaiting dispatch) → ``inflight`` (on a worker;
    ``attempts`` counts dispatches) → ``acked`` (result delivered,
    terminal).  A worker death moves its inflight records back to
    ``pending`` via :meth:`requeue` — nothing is lost; :meth:`ack`
    consumes exactly once and reports whether *this* call was the first —
    nothing is delivered twice.

    With a ``queue_dir`` every record is mirrored to
    ``<dir>/<launch_id>.json`` with atomic writes (temp file +
    ``os.replace``), numpy args encoded base64 bit-exactly.  A fresh
    :class:`RetryQueue` over the same directory reloads every record;
    :meth:`recover` then demotes stale ``inflight`` records (their
    workers died with the old coordinator) back to ``pending``.
    Memory-only operation (``queue_dir=None``) keeps the same semantics
    minus restart durability.
    """

    def __init__(self, queue_dir: Optional[Union[str, Path]] = None):
        self.dir = Path(queue_dir) if queue_dir is not None else None
        self._records: Dict[str, Dict] = {}
        self._seq = itertools.count()
        if self.dir is not None:
            self.dir.mkdir(parents=True, exist_ok=True)
            self._load()

    # -- persistence -----------------------------------------------------
    def _path(self, launch_id: str) -> Path:
        return self.dir / f"{launch_id}.json"

    def _persist(self, rec: Dict) -> None:
        if self.dir is None:
            return
        blob = json.dumps(rec).encode()
        fd, tmp = tempfile.mkstemp(dir=str(self.dir), suffix=".tmp")
        try:
            with os.fdopen(fd, "wb") as f:
                f.write(blob)
            os.replace(tmp, self._path(rec["launch_id"]))
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise

    def _load(self) -> None:
        loaded = []
        for path in self.dir.glob("*.json"):
            try:
                rec = json.loads(path.read_text())
                if not isinstance(rec, dict) or "launch_id" not in rec \
                        or rec.get("state") not in ("pending", "inflight",
                                                    "acked"):
                    raise ValueError("bad record")
            except Exception:
                continue  # torn/foreign file: skip, never raise
            loaded.append(rec)
        # preserve enqueue order across restarts
        loaded.sort(key=lambda r: r.get("seq", 0))
        for rec in loaded:
            self._records[rec["launch_id"]] = rec
        if loaded:
            self._seq = itertools.count(
                max(r.get("seq", 0) for r in loaded) + 1)

    # -- lifecycle -------------------------------------------------------
    def enqueue(self, launch_id: str, kernel: str, grid: int, block: int,
                args: Dict[str, object],
                outputs: Sequence[str]) -> Dict:
        if launch_id in self._records:
            raise ValueError(f"launch {launch_id!r} already enqueued")
        rec = {
            "launch_id": launch_id, "kernel": kernel,
            "grid": int(grid), "block": int(block),
            "args": {k: _encode_value(v) for k, v in args.items()},
            "outputs": list(outputs),
            "state": "pending", "attempts": 0, "worker": None,
            "seq": next(self._seq), "enqueued_at": time.time(),
        }
        self._records[launch_id] = rec
        self._persist(rec)
        return rec

    def get(self, launch_id: str) -> Dict:
        return self._records[launch_id]

    def decode_args(self, launch_id: str) -> Dict[str, object]:
        rec = self._records[launch_id]
        return {k: _decode_value(v) for k, v in rec["args"].items()}

    def pending(self) -> List[str]:
        """Launch ids awaiting dispatch, in enqueue order."""
        return [r["launch_id"]
                for r in sorted(self._records.values(),
                                key=lambda r: r["seq"])
                if r["state"] == "pending"]

    def inflight(self, worker: Optional[int] = None) -> List[str]:
        return [r["launch_id"] for r in self._records.values()
                if r["state"] == "inflight"
                and (worker is None or r["worker"] == worker)]

    def unacked(self) -> List[str]:
        return [r["launch_id"] for r in self._records.values()
                if r["state"] != "acked"]

    def mark_inflight(self, launch_id: str, worker: int) -> int:
        """Record a dispatch; returns the attempt number (1 = first)."""
        rec = self._records[launch_id]
        if rec["state"] == "acked":
            raise ValueError(f"launch {launch_id!r} is already acked")
        rec["state"] = "inflight"
        rec["worker"] = int(worker)
        rec["attempts"] += 1
        self._persist(rec)
        return rec["attempts"]

    def reassign(self, launch_id: str, worker: int) -> None:
        """Graceful migration bookkeeping: the launch moved workers with
        its live state — same attempt, new owner."""
        rec = self._records[launch_id]
        rec["worker"] = int(worker)
        self._persist(rec)

    def requeue(self, launch_id: str) -> bool:
        """Worker died (or dispatch failed): back to ``pending`` so it
        replays.  No-op on acked records; returns True if requeued."""
        rec = self._records[launch_id]
        if rec["state"] == "acked":
            return False
        rec["state"] = "pending"
        rec["worker"] = None
        self._persist(rec)
        return True

    def ack(self, launch_id: str) -> bool:
        """Consume exactly once: True iff *this* call transitioned the
        record to ``acked`` — callers must deliver results only then."""
        rec = self._records[launch_id]
        if rec["state"] == "acked":
            return False
        rec["state"] = "acked"
        rec["acked_at"] = time.time()
        self._persist(rec)
        return True

    def is_acked(self, launch_id: str) -> bool:
        return self._records[launch_id]["state"] == "acked"

    def recover(self) -> List[str]:
        """After a coordinator restart: demote stale inflight records
        (their workers belonged to the dead coordinator) to pending.
        Returns the demoted launch ids."""
        demoted = []
        for rec in self._records.values():
            if rec["state"] == "inflight":
                rec["state"] = "pending"
                rec["worker"] = None
                self._persist(rec)
                demoted.append(rec["launch_id"])
        return demoted

    def stats(self) -> Dict[str, int]:
        by_state = {"pending": 0, "inflight": 0, "acked": 0}
        for rec in self._records.values():
            by_state[rec["state"]] += 1
        by_state["total"] = len(self._records)
        by_state["durable"] = self.dir is not None
        return by_state

    def __len__(self) -> int:
        return len(self._records)


# ---------------------------------------------------------------------------
# Worker process
# ---------------------------------------------------------------------------

def _worker_main(worker_id: int, conn, config: Dict) -> None:
    """Worker entry point: one :class:`HetSession` on one backend, a
    strict request/reply loop over the pipe, fault hooks at the named
    points.  Runs until ``shutdown``, EOF (coordinator gone), or the
    injector kills the process."""
    # import here: under the spawn start method this runs in a fresh
    # interpreter, and the parent's module state does not exist yet
    from .runtime import HetSession

    inj = FaultInjector(config.get("fault_specs"), worker_id,
                        int(config.get("fault_seed", 0)))
    session = HetSession(config.get("backend", "interp"),
                         opt_level=config.get("opt_level"),
                         store=config.get("store_dir"),
                         shared=config.get("shared_dir"))
    # launch_id -> {"rec", "stream", "kernel", "outputs", "segments"}
    launches: Dict[str, Dict] = {}

    def _outputs(entry) -> Dict[str, np.ndarray]:
        return {name: entry["rec"].buffer(name).copy_to_host()
                for name in entry["outputs"]}

    conn.send(("ready", {"pid": os.getpid(),
                         "backend": config.get("backend", "interp")}))
    while True:
        try:
            cmd, payload = conn.recv()
        except (EOFError, OSError):  # coordinator went away
            return
        try:
            if cmd == "load":
                for prog in pickle.loads(payload["blob"]):
                    session.load(prog)
                conn.send(("ok", {}))
            elif cmd == "start":
                lid, kernel = payload["launch_id"], payload["kernel"]
                inj.begin_launch(kernel, lid)
                inj.at_point(PRE_LAUNCH, kernel)
                fn = session.function(kernel)
                eng_args: Dict[str, object] = {}
                for p in fn.params:
                    v = payload["args"][p.name]
                    if p.kind == "buffer":
                        arr = np.asarray(v)
                        db = session.alloc(arr.size, arr.dtype)
                        db.copy_from_host(arr)
                        eng_args[p.name] = db
                    else:
                        eng_args[p.name] = v
                st = session.stream()
                rec = fn.launch_async(payload["grid"], payload["block"],
                                      eng_args, stream=st)
                launches[lid] = {"rec": rec, "stream": st,
                                 "kernel": kernel, "segments": 0,
                                 "outputs": list(payload["outputs"])}
                conn.send(("ok", {}))
            elif cmd == "advance":
                lid = payload["launch_id"]
                entry = launches[lid]
                rec, kernel = entry["rec"], entry["kernel"]

                def _hook(eng, _e=entry, _k=kernel, _lid=lid):
                    _e["segments"] += 1
                    inj.on_segment(_k, _e["segments"], _lid)
                    return False

                finished = rec.advance(
                    max_segments=payload.get("max_segments"),
                    on_segment=_hook)
                if finished:
                    outs = _outputs(entry)
                    # the work is done and (for a restored launch) its
                    # checkpoint state consumed — but the coordinator has
                    # not heard: the ungraceful-death window the retry
                    # queue must cover
                    inj.at_point(POST_CHECKPOINT_PRE_ACK, kernel)
                    del launches[lid]
                    for db in rec.bindings.values():
                        db.free()
                    entry["stream"].destroy()
                    conn.send(("done", {"outputs": outs,
                                        "segments": entry["segments"]}))
                else:
                    conn.send(("paused",
                               {"segments": entry["segments"]}))
            elif cmd == "checkpoint":
                lid = payload["launch_id"]
                entry = launches.pop(lid)
                blob = session.checkpoint(entry["rec"])
                entry["rec"].cancel()
                for db in entry["rec"].bindings.values():
                    db.free()
                entry["stream"].destroy()
                conn.send(("ok", {"blob": blob,
                                  "kernel": entry["kernel"],
                                  "segments": entry["segments"],
                                  "outputs": entry["outputs"]}))
            elif cmd == "restore":
                lid, kernel = payload["launch_id"], payload["kernel"]
                inj.begin_launch(kernel, lid)
                st = session.stream()
                rec = session.restore(kernel, payload["blob"], stream=st)
                launches[lid] = {"rec": rec, "stream": st,
                                 "kernel": kernel,
                                 "segments": int(payload.get("segments",
                                                             0)),
                                 "outputs": list(payload["outputs"])}
                conn.send(("ok", {"finished": rec.finished}))
            elif cmd == "ping":
                conn.send(("ok", {"pid": os.getpid(),
                                  "inflight": len(launches)}))
            elif cmd == "stats":
                conn.send(("ok", {
                    "inflight": len(launches),
                    "segments_executed":
                        session.stats["segments_executed"],
                    "launches": session.stats["launches"],
                    "cache": session.cache_stats()}))
            elif cmd == "shutdown":
                conn.send(("ok", {}))
                return
            else:
                conn.send(("error",
                           {"error": f"unknown command {cmd!r}"}))
        except Exception as exc:
            conn.send(("error", {
                "error": f"{type(exc).__name__}: {exc}",
                "trace": traceback.format_exc()}))


# ---------------------------------------------------------------------------
# Coordinator-side worker handle and tickets
# ---------------------------------------------------------------------------

class _Worker:
    __slots__ = ("wid", "proc", "conn", "backend", "alive", "draining",
                 "launches", "_rr")

    def __init__(self, wid: int, proc, conn, backend: str):
        self.wid = wid
        self.proc = proc
        self.conn = conn
        self.backend = backend
        self.alive = True
        self.draining = False
        self.launches: List[str] = []   # dispatch order
        self._rr = 0                    # round-robin cursor

    def next_launch(self) -> Optional[str]:
        if not self.launches:
            return None
        self._rr = (self._rr + 1) % len(self.launches)
        return self.launches[self._rr]

    def __repr__(self) -> str:
        state = "alive" if self.alive else "dead"
        state += " draining" if self.draining else ""
        return (f"<Worker {self.wid} {self.backend} {state} "
                f"inflight={len(self.launches)}>")


class FleetTicket:
    """Future for a fleet launch: resolves when the coordinator receives
    (and acks) the result — possibly from a different worker, a
    different backend, or a later attempt than the first dispatch."""

    __slots__ = ("launch_id", "kernel", "fleet", "finished", "results",
                 "attempts", "worker", "cancelled")

    def __init__(self, fleet: "FleetCoordinator", launch_id: str,
                 kernel: str):
        self.fleet = fleet
        self.launch_id = launch_id
        self.kernel = kernel
        self.finished = False
        self.cancelled = False          # serving-front duck type
        self.results: Optional[Dict[str, np.ndarray]] = None
        self.attempts = 0
        self.worker: Optional[int] = None

    def done(self) -> bool:
        return self.finished

    def wait(self, timeout: Optional[float] = None) -> bool:
        """Pump the fleet until this launch completes."""
        self.fleet.wait_all(timeout=timeout,
                            until=lambda: self.finished)
        return self.finished

    def result(self, name: str) -> np.ndarray:
        if not self.finished:
            raise RuntimeError(
                f"launch {self.launch_id} has not completed — pump the "
                "fleet (wait()/wait_all()) first")
        return self.results[name]

    @property
    def seq(self) -> str:               # serving-front duck type
        return self.launch_id

    def __repr__(self) -> str:
        state = "finished" if self.finished else "in-flight"
        return (f"<FleetTicket {self.launch_id} {self.kernel} {state} "
                f"attempts={self.attempts}>")


# ---------------------------------------------------------------------------
# The control plane
# ---------------------------------------------------------------------------

class FleetCoordinator:
    """Dispatches launches over IPC to a fleet of worker processes and
    heals around their deaths.

    * ``backends`` — one worker per entry (heterogeneous fleets mix
      interp / vectorized / pallas; snapshots are device-neutral, so any
      launch can land anywhere).
    * ``queue_dir`` — directory for the retry queue's persistent tier
      (``None`` = in-memory only).
    * ``store_dir`` — shared :class:`~repro.core.cache.DiskStore` root
      every worker session attaches to (translate once per fleet).
    * ``shared_dir`` — cluster cache fabric root
      (:class:`~repro.core.cache.SharedStore`): workers consult the
      fabric before translating, publish what they translate, and take
      their single-flight locks there, so exactly one translation happens
      fleet-wide even across *independent* fleets sharing the directory.
      Defaults to ``HETGPU_CACHE_SHARED_DIR``.  :meth:`prewarm` lets the
      coordinator pre-publish kernels before any worker runs them.
    * ``slice_segments`` — segments granted per pump slice; smaller
      slices mean finer-grained preemption/migration points.
    * ``fault_plan`` / ``fault_seed`` — explicit chaos schedule; both
      default to the env gate (``HETGPU_FAULT_PLAN`` /
      ``HETGPU_FAULT_SEED``), so production fleets are fault-free unless
      deliberately armed.
    * ``respawn`` — spawn a replacement worker (same backend) whenever a
      death is detected.

    Use as a context manager; :meth:`shutdown` is idempotent.
    """

    def __init__(self, backends: Sequence[str] = ("interp",) * 3,
                 queue_dir: Optional[Union[str, Path]] = None,
                 store_dir: Optional[Union[str, Path]] = None,
                 shared_dir: Optional[Union[str, Path]] = None,
                 slice_segments: int = 4,
                 opt_level: Optional[int] = None,
                 fault_plan: Optional[List[Dict]] = None,
                 fault_seed: Optional[int] = None,
                 rpc_timeout: float = _DEFAULT_RPC_TIMEOUT,
                 respawn: bool = False,
                 start_method: str = "spawn"):
        import multiprocessing as mp
        self._ctx = mp.get_context(start_method)
        self.queue = RetryQueue(queue_dir)
        self.store_dir = str(store_dir) if store_dir is not None else None
        if shared_dir is None:
            shared_dir = os.environ.get("HETGPU_CACHE_SHARED_DIR") or None
        self.shared_dir = str(shared_dir) if shared_dir is not None else None
        self.slice_segments = max(1, int(slice_segments))
        self.opt_level = opt_level
        self.rpc_timeout = float(rpc_timeout)
        self.respawn = bool(respawn)
        self.fault_plan = load_fault_plan() if fault_plan is None \
            else list(fault_plan)
        self.fault_seed = int(os.environ.get("HETGPU_FAULT_SEED", "0") or 0) \
            if fault_seed is None else int(fault_seed)
        self._wid = itertools.count()
        self.workers: Dict[int, _Worker] = {}
        self._programs: Dict[str, bytes] = {}       # kernel -> pickled [prog]
        self._buffer_params: Dict[str, Tuple[str, ...]] = {}
        self.tickets: Dict[str, FleetTicket] = {}
        self.counters = {"submitted": 0, "completed": 0, "retried": 0,
                         "evacuated": 0, "migrated": 0, "workers_lost": 0,
                         "workers_spawned": 0, "duplicate_acks": 0}
        #: per-failure recovery log: detection timestamp + the requeued
        #: launches; completions stamp recovery_ms (detect→replay→done)
        self.failures: List[Dict] = []
        for backend in backends:
            self.add_worker(backend)

    # -- lifecycle -------------------------------------------------------
    def __enter__(self) -> "FleetCoordinator":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()

    def add_worker(self, backend: str = "interp") -> int:
        """Spawn one worker process and wait for its handshake."""
        wid = next(self._wid)
        parent, child = self._ctx.Pipe()
        cfg = {"backend": backend, "opt_level": self.opt_level,
               "store_dir": self.store_dir,
               "shared_dir": self.shared_dir,
               "fault_specs": [s for s in self.fault_plan
                               if s.get("worker") in (None, wid)],
               "fault_seed": self.fault_seed}
        proc = self._ctx.Process(target=_worker_main,
                                 args=(wid, child, cfg), daemon=True)
        proc.start()
        child.close()
        w = _Worker(wid, proc, parent, backend)
        self.workers[wid] = w
        self.counters["workers_spawned"] += 1
        status, _ = self._recv(w)       # "ready" handshake
        if status != "ready":
            raise FleetError(f"worker {wid} failed its handshake: {status}")
        for blob in dict.fromkeys(self._programs.values()):
            self._rpc(w, "load", {"blob": blob})
        return wid

    def shutdown(self) -> None:
        """Stop every worker (graceful first, SIGKILL stragglers).  The
        retry queue's persistent tier is left intact for recovery."""
        for w in list(self.workers.values()):
            if not w.alive:
                continue
            try:
                w.conn.send(("shutdown", {}))
                if w.conn.poll(2.0):
                    w.conn.recv()
            except (OSError, EOFError):
                pass
            w.alive = False
        for w in self.workers.values():
            w.proc.join(timeout=5.0)
            if w.proc.is_alive():  # pragma: no cover - stuck worker
                w.proc.kill()
                w.proc.join(timeout=5.0)
            try:
                w.conn.close()
            except OSError:
                pass

    # -- program registry ------------------------------------------------
    def register(self, program) -> None:
        """Register a hetIR program (or a list) with the fleet: pickled
        once here, broadcast to every alive worker, and re-sent to any
        worker spawned later.  Must be re-done after a coordinator
        restart before recovered launches can dispatch."""
        programs = program if isinstance(program, (list, tuple)) \
            else [program]
        from . import hetir as ir
        blob = pickle.dumps(list(programs),
                            protocol=pickle.HIGHEST_PROTOCOL)
        for prog in programs:
            self._programs[prog.name] = blob
            self._buffer_params[prog.name] = tuple(
                p.name for p in prog.params if isinstance(p, ir.Ptr))
        for w in self._alive():
            self._rpc(w, "load", {"blob": blob})

    def prewarm(self, grids: Sequence[Tuple[int, int]] = ((2, 32),),
                backends: Optional[Sequence[str]] = None) -> Dict[str, object]:
        """Pre-publish translations for every registered kernel into the
        cluster fabric, in-process, before any worker touches them — the
        coordinator pays the one fleet-wide translation up front, and
        every worker (current and future, here and on other hosts sharing
        the fabric) warm-starts from the published AOT executables.

        ``backends`` defaults to the distinct backends of the current
        workers.  Requires a ``shared_dir`` (without a fabric there is
        nowhere to publish — raises ``FleetError``).  Returns a per-backend
        report of :meth:`HetSession.warmup` results."""
        if self.shared_dir is None:
            raise FleetError("prewarm needs a cluster fabric: construct the "
                             "coordinator with shared_dir= (or set "
                             "HETGPU_CACHE_SHARED_DIR)")
        from .runtime import HetSession
        if backends is None:
            backends = sorted({w.backend for w in self.workers.values()})
        programs = [pickle.loads(blob)
                    for blob in dict.fromkeys(self._programs.values())]
        flat = [p for group in programs for p in group]
        report: Dict[str, object] = {}
        for backend in backends:
            session = HetSession(backend, opt_level=self.opt_level,
                                 shared=self.shared_dir)
            report[backend] = session.warmup(flat, grids=grids)
        return report

    # -- submission ------------------------------------------------------
    def submit(self, kernel: str, grid: int, block: int,
               args: Dict[str, object],
               outputs: Optional[Sequence[str]] = None) -> FleetTicket:
        """Accept one launch: durably enqueued (the accept *is* the
        durability point), dispatched by the pump.  ``outputs`` defaults
        to every buffer parameter."""
        if kernel not in self._programs:
            raise KeyError(f"kernel {kernel!r} is not registered — call "
                           "fleet.register(program) first")
        if outputs is None:
            outputs = self._buffer_params[kernel]
        lid = f"L{uuid.uuid4().hex[:12]}"
        self.queue.enqueue(lid, kernel, grid, block, args, outputs)
        ticket = FleetTicket(self, lid, kernel)
        self.tickets[lid] = ticket
        self.counters["submitted"] += 1
        return ticket

    def recover(self) -> List[FleetTicket]:
        """After a coordinator restart over the same ``queue_dir``:
        demote stale inflight records and mint tickets for every unacked
        launch.  Their programs must be :meth:`register`-ed before the
        pump can dispatch them."""
        self.queue.recover()
        out = []
        for lid in self.queue.unacked():
            if lid not in self.tickets:
                rec = self.queue.get(lid)
                ticket = FleetTicket(self, lid, rec["kernel"])
                ticket.attempts = rec["attempts"]
                self.tickets[lid] = ticket
            out.append(self.tickets[lid])
        return out

    # -- RPC plumbing ----------------------------------------------------
    def _alive(self) -> List[_Worker]:
        return [w for w in self.workers.values() if w.alive]

    def _recv(self, w: _Worker, timeout: Optional[float] = None):
        """One reply from ``w`` — raises :class:`WorkerLost` after
        handling the death, :class:`FleetTimeout` on a wedged worker."""
        timeout = self.rpc_timeout if timeout is None else timeout
        try:
            if not w.conn.poll(timeout):
                raise FleetTimeout(
                    f"worker {w.wid} sent no reply within {timeout}s — "
                    "treating it as wedged")
            return w.conn.recv()
        except (EOFError, ConnectionResetError, BrokenPipeError, OSError):
            self._on_worker_death(w)
            raise WorkerLost(f"worker {w.wid} died") from None

    def _rpc(self, w: _Worker, cmd: str, payload: Dict,
             timeout: Optional[float] = None):
        try:
            w.conn.send((cmd, payload))
        except (BrokenPipeError, ConnectionResetError, OSError):
            self._on_worker_death(w)
            raise WorkerLost(f"worker {w.wid} died") from None
        status, reply = self._recv(w, timeout)
        if status == "error":
            raise FleetWorkerError(
                f"worker {w.wid} failed {cmd}: {reply.get('error')}\n"
                f"{reply.get('trace', '')}")
        return status, reply

    # -- failure handling ------------------------------------------------
    def _on_worker_death(self, w: _Worker) -> None:
        """Detection → evacuation: mark dead, requeue every launch it
        owned (nothing acked is touched), optionally respawn."""
        if not w.alive:
            return
        w.alive = False
        w.proc.join(timeout=2.0)
        self.counters["workers_lost"] += 1
        requeued = []
        for lid in list(w.launches):
            if self.queue.requeue(lid):
                requeued.append(lid)
        self.counters["evacuated"] += len(requeued)
        w.launches.clear()
        self.failures.append({"worker": w.wid, "backend": w.backend,
                              "detected_at": time.perf_counter(),
                              "requeued": requeued,
                              "recovered": {}})
        if self.respawn:
            self.add_worker(w.backend)

    def evacuate_on_failure(self, worker_id: int,
                            kill: bool = False) -> List[str]:
        """Failure-evacuation policy, callable directly: with
        ``kill=True`` SIGKILLs the worker first (simulated hard failure),
        then runs the same detect/requeue path the pump takes when it
        notices a death on its own.  Returns the requeued launch ids."""
        w = self.workers[worker_id]
        if kill and w.alive:  # a real kill -9, same as the injector's
            try:
                os.kill(w.proc.pid, signal.SIGKILL)
            except (OSError, ProcessLookupError):
                pass
            w.proc.join(timeout=5.0)
        before = len(self.failures)
        self._on_worker_death(w)
        return self.failures[-1]["requeued"] \
            if len(self.failures) > before else []

    # -- dispatch + pump -------------------------------------------------
    def _pick_worker(self) -> Optional[_Worker]:
        candidates = [w for w in self._alive() if not w.draining]
        if not candidates:
            return None
        return min(candidates, key=lambda w: (len(w.launches), w.wid))

    def _dispatch_pending(self) -> int:
        sent = 0
        for lid in self.queue.pending():
            rec = self.queue.get(lid)
            if rec["kernel"] not in self._programs:
                continue        # recovered launch awaiting register()
            w = self._pick_worker()
            if w is None:
                if not self._alive():
                    raise FleetError(
                        "no alive workers — cannot dispatch "
                        f"{len(self.queue.pending()) + 1} pending "
                        "launch(es); add_worker() or enable respawn")
                break
            attempts = self.queue.mark_inflight(lid, w.wid)
            if attempts > 1:
                self.counters["retried"] += 1
            ticket = self.tickets.get(lid)
            if ticket is not None:
                ticket.attempts = attempts
                ticket.worker = w.wid
            try:
                self._rpc(w, "start", {
                    "launch_id": lid, "kernel": rec["kernel"],
                    "grid": rec["grid"], "block": rec["block"],
                    "args": self.queue.decode_args(lid),
                    "outputs": rec["outputs"]})
            except WorkerLost:
                # the worker died holding this very dispatch (e.g. a
                # pre-launch fault) — it was not in w.launches yet, so
                # the death handler could not requeue it; do it here
                if self.queue.requeue(lid):
                    self.counters["evacuated"] += 1
                    if self.failures:
                        self.failures[-1]["requeued"].append(lid)
                continue
            w.launches.append(lid)
            sent += 1
        return sent

    def _handle_done(self, w: _Worker, lid: str, reply: Dict) -> None:
        if lid in w.launches:
            w.launches.remove(lid)
        if not self.queue.ack(lid):
            # already acked (can only happen if a result raced a
            # migration) — never deliver twice
            self.counters["duplicate_acks"] += 1
            return
        self.counters["completed"] += 1
        ticket = self.tickets.get(lid)
        if ticket is not None:
            ticket.results = reply["outputs"]
            ticket.finished = True
            ticket.worker = w.wid
        now = time.perf_counter()
        for failure in self.failures:
            if lid in failure["requeued"]:
                failure["recovered"][lid] = \
                    (now - failure["detected_at"]) * 1e3

    def pump(self, rounds: int = 1) -> bool:
        """One scheduling sweep per round: dispatch pending launches,
        then grant every busy worker one ``slice_segments`` slice of one
        of its launches (round-robin within the worker).  Worker deaths
        surface here as evacuation + replay, not exceptions.  Returns
        True iff any work was dispatched or advanced."""
        progressed = False
        for _ in range(max(1, int(rounds))):
            # a death detected anywhere in this round *is* progress (its
            # launches were requeued and will re-dispatch next round) —
            # without this, a kill during dispatch reads as a stall
            lost_before = self.counters["workers_lost"]
            if self._dispatch_pending():
                progressed = True
            busy = [(w, w.next_launch()) for w in self._alive()
                    if w.launches]
            busy = [(w, lid) for w, lid in busy if lid is not None]
            if not busy:
                if self.counters["workers_lost"] > lost_before:
                    progressed = True
                if not self.queue.pending():
                    break
                continue
            # phase 1: send every slice (workers run genuinely in
            # parallel); phase 2: collect replies
            issued = []
            for w, lid in busy:
                try:
                    w.conn.send(("advance", {
                        "launch_id": lid,
                        "max_segments": self.slice_segments}))
                    issued.append((w, lid))
                except (BrokenPipeError, ConnectionResetError, OSError):
                    self._on_worker_death(w)
            for w, lid in issued:
                try:
                    status, reply = self._recv(w)
                except WorkerLost:
                    continue    # evacuation already done
                if status == "error":
                    raise FleetWorkerError(
                        f"worker {w.wid} failed advancing {lid}: "
                        f"{reply.get('error')}\n{reply.get('trace', '')}")
                if status == "done":
                    self._handle_done(w, lid, reply)
                progressed = True
            if self.counters["workers_lost"] > lost_before:
                progressed = True
        return progressed

    def wait_all(self, timeout: Optional[float] = None,
                 until=None) -> None:
        """Pump until every accepted launch is acked (or ``until()``
        holds).  Raises :class:`FleetTimeout` on the deadline and
        :class:`FleetError` if work remains but no worker is alive."""
        deadline = None if timeout is None \
            else time.perf_counter() + timeout
        while True:
            if until is not None and until():
                return
            if until is None and not self.queue.unacked():
                return
            if deadline is not None and time.perf_counter() > deadline:
                raise FleetTimeout(
                    f"fleet did not settle within {timeout}s "
                    f"(queue: {self.queue.stats()})")
            if not self.pump():
                # nothing dispatched, nothing advanced — fail loudly
                # instead of spinning (wedged fleets must not hang CI)
                if until is not None and until():
                    return
                missing = sorted({
                    self.queue.get(lid)["kernel"]
                    for lid in self.queue.pending()
                    if self.queue.get(lid)["kernel"]
                    not in self._programs})
                raise FleetError(
                    "fleet stalled with "
                    f"{len(self.queue.unacked())} unacked launch(es)"
                    + (f" — kernels not registered: {missing}"
                       if missing else ""))

    # -- migration policies ---------------------------------------------
    def _move_launch(self, src: _Worker, dst: _Worker, lid: str) -> bool:
        """checkpoint on ``src`` → restore on ``dst``: the live-state
        migration primitive every policy reuses.  Returns False when the
        source died mid-move (the launch is then requeued — replay covers
        what migration could not save)."""
        try:
            _, pl = self._rpc(src, "checkpoint", {"launch_id": lid})
        except WorkerLost:
            return False
        src.launches.remove(lid)
        try:
            self._rpc(dst, "restore", {
                "launch_id": lid, "kernel": pl["kernel"],
                "blob": pl["blob"], "segments": pl["segments"],
                "outputs": pl["outputs"]})
        except WorkerLost:
            # destination died holding the only copy of the live state:
            # its evacuation requeued everything it owned, but this
            # launch was not registered there yet — requeue explicitly
            self.queue.requeue(lid)
            self.counters["evacuated"] += 1
            return False
        dst.launches.append(lid)
        self.queue.reassign(lid, dst.wid)
        self.counters["migrated"] += 1
        ticket = self.tickets.get(lid)
        if ticket is not None:
            ticket.worker = dst.wid
        return True

    def drain(self, worker_id: int, shutdown: bool = True) -> int:
        """Graceful drain policy: move every in-flight launch off the
        worker via checkpoint/restore (live state preserved — not a
        replay), stop dispatching to it, and by default shut it down.
        Returns the number of launches migrated."""
        w = self.workers[worker_id]
        w.draining = True
        moved = 0
        for lid in list(w.launches):
            dst = min((o for o in self._alive()
                       if o is not w and not o.draining),
                      key=lambda o: (len(o.launches), o.wid),
                      default=None)
            if dst is None:
                raise FleetError(
                    f"cannot drain worker {worker_id}: no other alive "
                    "worker to receive its launches")
            if self._move_launch(w, dst, lid):
                moved += 1
            if not w.alive:
                break
        if shutdown and w.alive:
            try:
                self._rpc(w, "shutdown", {}, timeout=5.0)
            except (WorkerLost, FleetTimeout):
                pass
            w.alive = False
            w.proc.join(timeout=5.0)
        return moved

    def rebalance(self) -> int:
        """Load-balance policy: while the most- and least-loaded alive
        workers differ by ≥ 2 launches, migrate one (checkpoint/restore,
        live state preserved).  Returns the number of moves."""
        moves = 0
        while True:
            ws = [w for w in self._alive() if not w.draining]
            if len(ws) < 2:
                return moves
            src = max(ws, key=lambda w: (len(w.launches), -w.wid))
            dst = min(ws, key=lambda w: (len(w.launches), w.wid))
            if len(src.launches) - len(dst.launches) < 2:
                return moves
            lid = src.launches[0]
            if not self._move_launch(src, dst, lid):
                return moves
            moves += 1

    # -- reporting -------------------------------------------------------
    def fleet_stats(self) -> Dict[str, object]:
        recoveries = [ms for f in self.failures
                      for ms in f["recovered"].values()]
        out: Dict[str, object] = dict(self.counters)
        out["queue"] = self.queue.stats()
        out["workers"] = [{"id": w.wid, "backend": w.backend,
                           "alive": w.alive, "draining": w.draining,
                           "inflight": len(w.launches)}
                          for w in self.workers.values()]
        out["alive_workers"] = len(self._alive())
        if recoveries:
            out["recovery_ms_max"] = max(recoveries)
            out["recovery_ms_mean"] = sum(recoveries) / len(recoveries)
        return out

    def __repr__(self) -> str:
        return (f"<FleetCoordinator workers={len(self._alive())}/"
                f"{len(self.workers)} queue={self.queue.stats()}>")
