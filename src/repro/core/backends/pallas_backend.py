"""Pallas backend — hetIR segments lowered to TPU kernels.

This is the "SIMT hardware" target: one ``pl.pallas_call`` per segment
(the paper: *"each segment is a separate kernel"*), with

* grid ``(num_blocks,)`` — one grid step per hetIR thread block;
* per-thread registers as ``[num_blocks, block_size]`` arrays, BlockSpec'd
  ``(1, block_size)`` so each grid step sees its own block's register file in
  VMEM — the register-file-in-memory handoff the paper uses between segment
  kernels;
* hetIR shared memory as a ``(1, shared_size)`` VMEM-resident block;
* global buffers staged into VMEM.  Buffers whose every access is *coalesced*
  (indexed exactly by ``GET_GLOBAL_ID``) are tiled ``(block_size,)`` per grid
  step — the fast path; all other buffers are staged whole per grid step (the
  gather/DMA path, mirroring the paper's Tenstorrent fallback).  Written
  non-coalesced buffers use the revisited-output accumulator pattern: the
  output block is initialized from the input at grid step 0 and all later
  reads/writes go through the output ref (constant ``index_map`` keeps the
  block resident in VMEM across the sequential TPU grid).

**Block-tiled fast path** (the scalar-per-thread speed-ceiling fix): when
:func:`~repro.core.passes.block_lower` proves a segment *lane-independent*,
the segment is emitted as one ``pl.pallas_call`` whose grid tiles the flat
*element* domain ``N = num_blocks * block_size`` into constexpr ``BLOCK``
chunks — the Triton vector-addition idiom — instead of one grid step per
hetIR block.  Registers travel as ``[1, N]`` flat arrays BlockSpec'd
``(1, BLOCK)``; buffers whose every access is exactly the flat global id
are BlockSpec-tiled ``(BLOCK,)`` and everything else takes the staged
gather path.  Segments the proof rejects (shared memory, collectives,
atomics, unprovable store indices) fall back to the scalar-per-thread path
below; ``PallasBackend.block_stats`` counts both and records the refusal
reasons.  ``HETGPU_BLOCK_LOWER=0`` disables the fast path;
``HETGPU_BLOCK_MAX`` caps the tile size (default 1024).

On this CPU container kernels execute with ``interpret=True``; the emitted
BlockSpecs are the TPU contract.  Lane width: ``block_size`` should be a
multiple of 128 for peak TPU efficiency (any size is functionally correct).
"""
from __future__ import annotations

import os
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from .. import hetir as ir
from ..cache import TranslationCache
from ..passes import (BlockPlan, block_lower, choose_block,
                      refusal_category)
from ..segments import SegNode
from .base import (Backend, HostState, Launch, export_translation,
                   scalar_signature, state_signature)
from .semantics import Env, eval_stmts


def _coalesced_buffers(seg: SegNode) -> set:
    """Buffers where every LD/ST index is exactly a GET_GLOBAL_ID register."""
    gid_regs: set = set()
    access: Dict[str, List[str]] = {}

    def walk(stmts):
        for s in stmts:
            if isinstance(s, ir.Op):
                if s.opcode == ir.GET_GLOBAL_ID:
                    gid_regs.add(s.dest.name)
                elif s.opcode in (ir.LD_GLOBAL, ir.ST_GLOBAL, ir.ATOMIC_ADD):
                    idx = s.args[1]
                    access.setdefault(s.args[0], []).append(
                        idx.name if isinstance(idx, ir.Reg) else "#imm")
            elif isinstance(s, (ir.Pred, ir.Loop)):
                walk(s.body)

    walk(seg.stmts)
    return {buf for buf, idxs in access.items()
            if all(i in gid_regs for i in idxs)}


class PallasBackend(Backend):
    name = "pallas"

    def __init__(self, interpret: bool = True,
                 cache: "TranslationCache" = None):
        super().__init__(cache)
        self.interpret = interpret
        # fast-path observability: how many segment executions took the
        # block-tiled vs scalar-per-thread path, and why refusals happened
        self.block_stats: Dict[str, object] = \
            {"tiled": 0, "scalar": 0, "reasons": {}}

    @staticmethod
    def _block_enabled() -> bool:
        return os.environ.get("HETGPU_BLOCK_LOWER", "1").lower() \
            not in ("0", "off", "false")

    # ------------------------------------------------------------------
    def _translate(self, seg: SegNode, launch: Launch, reg_sig: Tuple,
                   glb_sig: Tuple, shared_sig):
        # geometry, scalars, and the register/buffer shape+dtype signatures
        # all specialize the emitted kernel, so they join the shared key
        # (on top of the base key's launch-time specialization vector —
        # a scalar-specialized segment emits from a different body).  The
        # candidate tile size joins too: it folds in HETGPU_BLOCK_LOWER /
        # HETGPU_BLOCK_MAX, so flipping either can never revive a
        # translation emitted under the other setting.
        cand = choose_block(launch.num_blocks * launch.block_size) \
            if self._block_enabled() else None
        key = self._cache_key(seg, launch, launch.num_blocks,
                              launch.block_size, scalar_signature(launch),
                              reg_sig, glb_sig, shared_sig, ("block", cand))

        def translate():
            return self._build(seg, launch, reg_sig, glb_sig, shared_sig,
                               cand)

        return self.cache.get_or_translate(key, translate)

    def _build(self, seg: SegNode, launch: Launch, reg_sig: Tuple,
               glb_sig: Tuple, shared_sig, block_cand: Optional[int] = None):
        """Emit, trace, and export the segment's ``pl.pallas_call`` kernel.
        Returns ``((jitted fn, meta), persist)`` for the translation cache;
        the persisted payload is the serialized ``jax.export`` artifact plus
        ``meta``, so a warm process skips re-emitting and re-tracing.

        Tries the block-tiled fast path first (``block_cand`` is the
        candidate tile size, None when disabled); the scalar-per-thread
        lowering below is the fallback, with the refusal reason recorded in
        ``meta["block_reason"]``."""
        B, T = launch.num_blocks, launch.block_size
        block_reason = "disabled"
        if block_cand is not None:
            plan, block_reason = block_lower(
                seg.stmts, B, T, block_cand,
                buffer_lens={n: shape[0] for n, shape, _ in glb_sig
                             if len(shape) == 1})
            if plan is not None:
                return self._build_block(plan, seg, launch, reg_sig, glb_sig)
        scalars = dict(launch.scalars)
        reg_names = tuple(n for n, _, _ in reg_sig)
        reg_dtypes = {n: dt for n, _, dt in reg_sig}
        glb_names = tuple(n for n, _, _ in glb_sig)
        glb_shapes = {n: (shape, dt) for n, shape, dt in glb_sig}
        coalesced = {b for b in _coalesced_buffers(seg)
                     if b in glb_shapes and glb_shapes[b][0] == (B * T,)}
        written_order = tuple(sorted(seg.gwrites))
        has_shared = shared_sig is not None
        S = shared_sig[0][1] if has_shared else 0
        new_regs = tuple(sorted(r.name for r in seg.defs
                                if r.name not in reg_names))
        new_dt = {r.name: ir.np_dtype(r.dtype) for r in seg.defs
                  if r.name in new_regs}

        row_spec = pl.BlockSpec((1, T), lambda b: (b, 0))

        in_specs: List[pl.BlockSpec] = [row_spec] * len(reg_names)
        if has_shared:
            in_specs.append(pl.BlockSpec((1, S), lambda b: (b, 0)))
        for n in glb_names:
            if n in coalesced:
                in_specs.append(pl.BlockSpec((T,), lambda b: (b,)))
            else:
                in_specs.append(pl.BlockSpec(glb_shapes[n][0],
                                             lambda b: (0,)))

        out_specs: List[pl.BlockSpec] = []
        out_shapes: List[jax.ShapeDtypeStruct] = []
        for n in reg_names:
            out_specs.append(row_spec)
            out_shapes.append(jax.ShapeDtypeStruct((B, T), reg_dtypes[n]))
        for n in new_regs:
            out_specs.append(row_spec)
            out_shapes.append(jax.ShapeDtypeStruct((B, T), new_dt[n]))
        if has_shared:
            out_specs.append(pl.BlockSpec((1, S), lambda b: (b, 0)))
            out_shapes.append(jax.ShapeDtypeStruct((B, S), shared_sig[1]))
        for n in written_order:
            shape, dt = glb_shapes[n]
            if n in coalesced:
                out_specs.append(pl.BlockSpec((T,), lambda b: (b,)))
            else:
                out_specs.append(pl.BlockSpec(shape, lambda b: (0,)))
            out_shapes.append(jax.ShapeDtypeStruct(shape, dt))

        n_in = len(reg_names) + int(has_shared) + len(glb_names)

        def kernel(*refs):
            in_refs, out_refs = refs[:n_in], refs[n_in:]
            b = pl.program_id(0)

            reg_in = dict(zip(reg_names, in_refs[:len(reg_names)]))
            sh_ref = in_refs[len(reg_names)] if has_shared else None
            glb_in = dict(zip(glb_names,
                              in_refs[len(reg_names) + int(has_shared):]))
            out_reg_refs = dict(zip(reg_names + new_regs, out_refs))
            o = len(reg_names) + len(new_regs)
            out_sh_ref = out_refs[o] if has_shared else None
            out_glb_refs = dict(zip(written_order,
                                    out_refs[o + int(has_shared):]))

            # revisited-output init for written, non-coalesced buffers
            for n in written_order:
                if n not in coalesced:
                    @pl.when(b == 0)
                    def _init(n=n):
                        out_glb_refs[n][...] = glb_in[n][...]

            glbs = {}
            for n in glb_names:
                if n in written_order and n not in coalesced:
                    glbs[n] = out_glb_refs[n][...]
                else:
                    glbs[n] = glb_in[n][...]

            env = Env(regs={k: v[...] for k, v in reg_in.items()},
                      shared=sh_ref[...] if has_shared else None,
                      globals_=glbs, scalars=scalars,
                      num_blocks=B, block_size=T, block_offset=b)
            env.lane_shape = (1, T)
            env.coalesced = coalesced
            env.tile_base = b * T
            eval_stmts(seg.stmts, env, mask=None)

            for k, ref in out_reg_refs.items():
                if k in env.regs:
                    ref[...] = jnp.broadcast_to(
                        env.regs[k], (1, T)).astype(ref.dtype)
                elif k in reg_in:  # untouched register: pass through
                    ref[...] = reg_in[k][...]
                else:  # defined only in a zero-trip loop: zeros
                    ref[...] = jnp.zeros((1, T), ref.dtype)
            if has_shared:
                out_sh_ref[...] = env.shared.reshape(1, S)
            for n in written_order:
                out_glb_refs[n][...] = env.globals[n]

        call = pl.pallas_call(
            kernel,
            grid=(B,),
            in_specs=in_specs,
            out_specs=out_specs,
            out_shape=out_shapes,
            interpret=self.interpret,
        )
        meta = dict(reg_names=reg_names, new_regs=new_regs,
                    glb_names=glb_names, written=written_order,
                    has_shared=has_shared, coalesced=coalesced,
                    block=None, block_reason=block_reason)
        example = tuple(
            [jax.ShapeDtypeStruct(shape, np.dtype(dt))
             for _, shape, dt in reg_sig]
            + ([jax.ShapeDtypeStruct(shared_sig[0], np.dtype(shared_sig[1]))]
               if has_shared else [])
            + [jax.ShapeDtypeStruct(shape, np.dtype(dt))
               for _, shape, dt in glb_sig])
        fn, payload = export_translation(jax.jit(call), example,
                                         cache=self.cache)
        persist = None if payload is None \
            else ("jax-aot-meta", (payload, meta))
        return (fn, meta), persist

    def _build_block(self, plan: BlockPlan, seg: SegNode, launch: Launch,
                     reg_sig: Tuple, glb_sig: Tuple):
        """Block-tiled lowering of a proven lane-independent segment: one
        ``pl.pallas_call`` whose grid walks ``N // BLOCK`` flat element
        tiles.  Registers are ``[1, N]`` flat arrays (``run_segment``
        reshapes the host-state ``[B, T]`` view; row-major flatten makes
        lane ``gid = b * T + t`` land at flat position ``gid``); tiled
        buffers get one ``(BLOCK,)`` tile per grid step, gather buffers are
        staged whole with the revisited-output accumulator when written.
        The segment is proven free of shared memory, so hetIR shared state
        (if any) bypasses the kernel untouched."""
        B, T = launch.num_blocks, launch.block_size
        N = B * T
        BLOCK, grid = plan.block, plan.grid
        scalars = dict(launch.scalars)
        reg_names = tuple(n for n, _, _ in reg_sig)
        reg_dtypes = {n: dt for n, _, dt in reg_sig}
        glb_names = tuple(n for n, _, _ in glb_sig)
        glb_shapes = {n: (shape, dt) for n, shape, dt in glb_sig}
        tiled = set(plan.tiled)
        written_order = tuple(sorted(seg.gwrites))
        new_regs = tuple(sorted(r.name for r in seg.defs
                                if r.name not in reg_names))
        new_dt = {r.name: ir.np_dtype(r.dtype) for r in seg.defs
                  if r.name in new_regs}

        row_spec = pl.BlockSpec((1, BLOCK), lambda i: (0, i))

        in_specs: List[pl.BlockSpec] = [row_spec] * len(reg_names)
        for n in glb_names:
            if n in tiled:
                in_specs.append(pl.BlockSpec((BLOCK,), lambda i: (i,)))
            else:
                in_specs.append(pl.BlockSpec(glb_shapes[n][0],
                                             lambda i: (0,)))

        out_specs: List[pl.BlockSpec] = []
        out_shapes: List[jax.ShapeDtypeStruct] = []
        for n in reg_names:
            out_specs.append(row_spec)
            out_shapes.append(jax.ShapeDtypeStruct((1, N), reg_dtypes[n]))
        for n in new_regs:
            out_specs.append(row_spec)
            out_shapes.append(jax.ShapeDtypeStruct((1, N), new_dt[n]))
        for n in written_order:
            shape, dt = glb_shapes[n]
            if n in tiled:
                out_specs.append(pl.BlockSpec((BLOCK,), lambda i: (i,)))
            else:
                out_specs.append(pl.BlockSpec(shape, lambda i: (0,)))
            out_shapes.append(jax.ShapeDtypeStruct(shape, dt))

        n_in = len(reg_names) + len(glb_names)

        def kernel(*refs):
            in_refs, out_refs = refs[:n_in], refs[n_in:]
            i = pl.program_id(0)

            reg_in = dict(zip(reg_names, in_refs[:len(reg_names)]))
            glb_in = dict(zip(glb_names, in_refs[len(reg_names):]))
            out_reg_refs = dict(zip(reg_names + new_regs, out_refs))
            o = len(reg_names) + len(new_regs)
            out_glb_refs = dict(zip(written_order, out_refs[o:]))

            # revisited-output init for written gather buffers
            for n in written_order:
                if n not in tiled:
                    @pl.when(i == 0)
                    def _init(n=n):
                        out_glb_refs[n][...] = glb_in[n][...]

            glbs = {}
            for n in glb_names:
                if n in written_order and n not in tiled:
                    glbs[n] = out_glb_refs[n][...]
                else:
                    glbs[n] = glb_in[n][...]

            env = Env(regs={k: v[...] for k, v in reg_in.items()},
                      shared=None, globals_=glbs, scalars=scalars,
                      num_blocks=B, block_size=T)
            env.lane_shape = (1, BLOCK)
            env.flat_base = i * BLOCK   # lanes are flat global-id tiles
            env.coalesced = tiled       # tiled indices rebase to the tile
            env.tile_base = i * BLOCK
            eval_stmts(plan.stmts, env, mask=None)

            for k, ref in out_reg_refs.items():
                if k in env.regs:
                    ref[...] = jnp.broadcast_to(
                        env.regs[k], (1, BLOCK)).astype(ref.dtype)
                elif k in reg_in:  # untouched register: pass through
                    ref[...] = reg_in[k][...]
                else:  # defined only in a zero-trip loop: zeros
                    ref[...] = jnp.zeros((1, BLOCK), ref.dtype)
            for n in written_order:
                out_glb_refs[n][...] = env.globals[n]

        call = pl.pallas_call(
            kernel,
            grid=(grid,),
            in_specs=in_specs,
            out_specs=out_specs,
            out_shape=out_shapes,
            interpret=self.interpret,
        )
        meta = dict(reg_names=reg_names, new_regs=new_regs,
                    glb_names=glb_names, written=written_order,
                    has_shared=False, coalesced=tiled,
                    block=dict(block=BLOCK, grid=grid,
                               tiled=tuple(sorted(tiled))),
                    block_reason=None)
        example = tuple(
            [jax.ShapeDtypeStruct((1, N), np.dtype(dt))
             for _, _, dt in reg_sig]
            + [jax.ShapeDtypeStruct(shape, np.dtype(dt))
               for _, shape, dt in glb_sig])
        fn, payload = export_translation(jax.jit(call), example,
                                         cache=self.cache)
        persist = None if payload is None \
            else ("jax-aot-meta", (payload, meta))
        return (fn, meta), persist

    # ------------------------------------------------------------------
    def run_segment(self, seg: SegNode, state: HostState,
                    launch: Launch) -> None:
        reg_sig, glb_sig, shared_sig = state_signature(state)
        reg_names = tuple(n for n, _, _ in reg_sig)
        glb_names = tuple(n for n, _, _ in glb_sig)

        call, meta = self._translate(seg, launch, reg_sig, glb_sig,
                                     shared_sig)

        blk = meta.get("block")
        if blk is not None:
            self.block_stats["tiled"] += 1
            B, T = launch.num_blocks, launch.block_size
            N = B * T
            # registers travel flat: [B, T] row-major == lane gid order
            args = [jnp.asarray(state.regs[n]).reshape(1, N)
                    for n in reg_names]
            args += [jnp.asarray(state.globals_[n]) for n in glb_names]
            outs = call(*args)
            i = 0
            regs = {}
            for n in meta["reg_names"] + meta["new_regs"]:
                regs[n] = outs[i].reshape(B, T)
                i += 1
            state.regs = regs
            for n in meta["written"]:
                state.globals_[n] = outs[i]
                i += 1
            # shared memory provably untouched by a block-lowered segment
            return
        self.block_stats["scalar"] += 1
        reason = meta.get("block_reason")
        if reason:
            # histogram on the *stable category* only (passes.
            # REFUSAL_REASONS); the free-form detail suffix (buffer name,
            # opcode) stays in meta["block_reason"] for diagnostics but
            # must not leak into the stats surface gates key on
            rs = self.block_stats["reasons"]
            cat = refusal_category(reason)
            rs[cat] = rs.get(cat, 0) + 1

        args = [jnp.asarray(state.regs[n]) for n in reg_names]
        if meta["has_shared"]:
            args.append(jnp.asarray(state.shared))
        args += [jnp.asarray(state.globals_[n]) for n in glb_names]

        outs = call(*args)
        i = 0
        regs = {}
        for n in meta["reg_names"] + meta["new_regs"]:
            regs[n] = outs[i]  # stays on device between segments
            i += 1
        state.regs = regs
        if meta["has_shared"]:
            state.shared = outs[i]
            i += 1
        for n in meta["written"]:
            state.globals_[n] = outs[i]
            i += 1
