"""Shared lane-vector semantics for hetIR ops.

Both the vectorized backend (arrays shaped ``[num_blocks, block_size]``) and
the Pallas backend (arrays shaped ``[1, block_size]`` inside one grid step)
evaluate segments through this module.  All per-thread values carry the lane
axis last; collectives reduce over the lane axis only (i.e. within a block),
matching hetIR's definition of collectives over the *active threads of the
block*.

Predication (`@PRED`) is realized as an explicit active-mask stack — the
paper's "software-managed predication" (§4.4): both branch outcomes share a
single instruction stream and inactive lanes are masked at register writes,
memory stores, and collective participation.

Every inexact floating-point result (``ADD``/``SUB``/``MUL``/``DIV`` and
both roundings of ``FMA``) is pinned to its own IEEE rounding (see
:func:`_pin`): XLA otherwise applies graph-shape-dependent inexact
rewrites — FMA contraction, constant reassociation across adds — which
make results differ between two semantically identical programs (e.g. a
rolled loop vs its IR-unrolled form — found by the differential fuzz
harness).  hetIR's contract is one-op-one-rounding on every backend.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .. import hetir as ir
from .portable_math import exp_jnp


class Env:
    """Mutable evaluation environment for one segment."""

    def __init__(self, regs: Dict[str, Any], shared, globals_: Dict[str, Any],
                 scalars: Dict[str, Any], num_blocks: int, block_size: int,
                 block_offset: Any = 0):
        self.regs = regs
        self.shared = shared
        self.globals = globals_
        self.scalars = scalars
        self.num_blocks = num_blocks      # total blocks in the launch
        self.block_size = block_size
        self.block_offset = block_offset  # first block id of the lane arrays
        self.lane_shape: Optional[Tuple[int, ...]] = None  # set on first use
        # Pallas fast path: buffers tiled per block (indices are global ids
        # and must be rebased to tile-local offsets).  Empty for other
        # backends.
        self.coalesced: set = set()
        self.tile_base = 0
        # Block-tiled fast path: when set, lane arrays are flat element
        # tiles ``[1, BLOCK]`` starting at this flat global id instead of
        # ``[rows, block_size]`` — thread identity is reconstructed from
        # ``gid = flat_base + iota`` (sound only for segments proven
        # lane-independent by ``passes.block_lower``).
        self.flat_base: Optional[Any] = None

    def write_reg(self, reg: ir.Reg, value, mask):
        value = jnp.asarray(value, dtype=ir.np_dtype(reg.dtype))
        value = jnp.broadcast_to(value, self.lane_shape)
        if mask is not None:
            old = self.regs.get(reg.name)
            if old is None:
                # hetIR registers read as zero until first written; a
                # masked first write must leave inactive lanes at zero
                # (matches the interpreter's per-lane zero-fill).
                old = jnp.zeros(self.lane_shape, ir.np_dtype(reg.dtype))
            else:
                old = jnp.broadcast_to(
                    jnp.asarray(old, dtype=ir.np_dtype(reg.dtype)),
                    self.lane_shape)
            value = jnp.where(mask, value, old)
        self.regs[reg.name] = value

    def read_reg(self, reg: ir.Reg):
        v = self.regs.get(reg.name)
        if v is None:  # never-written register: reads as zero
            return jnp.zeros(self.lane_shape, ir.np_dtype(reg.dtype))
        return jnp.broadcast_to(jnp.asarray(v, ir.np_dtype(reg.dtype)),
                                self.lane_shape)


def _lane_ids(env: Env):
    """[rows, block_size] thread / block index arrays (or, in flat block
    mode, identities reconstructed from the flat global id)."""
    if env.flat_base is not None:
        gid = jax.lax.broadcasted_iota(jnp.int32, env.lane_shape, 1) \
            + jnp.asarray(env.flat_base, jnp.int32)
        t = jnp.int32(env.block_size)
        return gid // t, gid % t
    tid = jax.lax.broadcasted_iota(jnp.int32, env.lane_shape, 1)
    bid = jax.lax.broadcasted_iota(jnp.int32, env.lane_shape, 0)
    bid = bid + jnp.asarray(env.block_offset, jnp.int32)
    return bid, tid


def _arg(env: Env, a, dtype=None):
    if isinstance(a, ir.Reg):
        return env.read_reg(a)
    return jnp.asarray(a, dtype)


def eval_stmts(stmts: Sequence[ir.Stmt], env: Env, mask) -> None:
    for s in stmts:
        if isinstance(s, ir.Op):
            eval_op(s, env, mask)
        elif isinstance(s, ir.Pred):
            cond = env.read_reg(s.cond)
            inner = cond if mask is None else jnp.logical_and(mask, cond)
            eval_stmts(s.body, env, inner)
        elif isinstance(s, ir.Loop):
            count = s.count if isinstance(s.count, int) \
                else int(env.scalars[s.count])
            for it in range(count):  # trace-time unroll (uniform count)
                env.regs[s.var.name] = jnp.full(
                    env.lane_shape, it, dtype=jnp.int32)
                eval_stmts(s.body, env, mask)
        elif isinstance(s, ir.Barrier):
            raise AssertionError(
                "barrier inside a segment — segmentation bug")
        else:  # pragma: no cover
            raise TypeError(type(s))


def eval_op(op: ir.Op, env: Env, mask) -> None:
    oc = op.opcode
    d = op.dest

    # ---- identity ---------------------------------------------------------
    if oc == ir.GET_GLOBAL_ID:
        bid, tid = _lane_ids(env)
        env.write_reg(d, bid * env.block_size + tid, mask)
    elif oc == ir.GET_BLOCK_ID:
        bid, _ = _lane_ids(env)
        env.write_reg(d, bid, mask)
    elif oc == ir.GET_THREAD_ID:
        _, tid = _lane_ids(env)
        env.write_reg(d, tid, mask)
    elif oc == ir.GET_BLOCK_DIM:
        env.write_reg(d, jnp.full(env.lane_shape, env.block_size,
                                  jnp.int32), mask)
    elif oc == ir.GET_NUM_BLOCKS:
        env.write_reg(d, jnp.full(env.lane_shape, env.num_blocks,
                                  jnp.int32), mask)

    # ---- constants / moves ------------------------------------------------
    elif oc == ir.CONST:
        env.write_reg(d, jnp.full(env.lane_shape, op.args[0],
                                  ir.np_dtype(d.dtype)), mask)
    elif oc == ir.LD_PARAM:
        env.write_reg(d, jnp.full(env.lane_shape, env.scalars[op.args[0]],
                                  ir.np_dtype(d.dtype)), mask)
    elif oc == ir.MOV:
        env.write_reg(d, _arg(env, op.args[0]), mask)
    elif oc == ir.CVT:
        env.write_reg(d, _arg(env, op.args[0]).astype(
            ir.np_dtype(d.dtype)), mask)

    # ---- ALU ---------------------------------------------------------------
    elif oc in _BINOPS:
        a = _arg(env, op.args[0])
        b = _arg(env, op.args[1])
        env.write_reg(d, _BINOPS[oc](a, b), mask)
    elif oc in _UNOPS:
        env.write_reg(d, _UNOPS[oc](_arg(env, op.args[0])), mask)
    elif oc == ir.FMA:
        a, b, c = (_arg(env, x) for x in op.args)
        env.write_reg(d, _pin(_mul_exact(a, b) + c), mask)
    elif oc == ir.SELECT:
        c, a, b = (_arg(env, x) for x in op.args)
        env.write_reg(d, jnp.where(c, a, b), mask)

    # ---- global memory -----------------------------------------------------
    # BLOCK_LD/BLOCK_ST evaluate exactly like their scalar forms — the tile
    # geometry in their attrs steers BlockSpec construction in the pallas
    # backend, not the per-lane value semantics ("tiled" buffers are rebased
    # via env.coalesced/tile_base like any coalesced buffer).
    elif oc in (ir.LD_GLOBAL, ir.BLOCK_LD):
        buf = env.globals[op.args[0]]
        idx = _global_idx(env, op.args[0], op.args[1])
        safe = idx if mask is None else jnp.where(mask, idx, 0)
        env.write_reg(d, jnp.take(buf, safe.reshape(-1), axis=0)
                      .reshape(env.lane_shape), mask)
    elif oc in (ir.ST_GLOBAL, ir.BLOCK_ST):
        buf = env.globals[op.args[0]]
        idx = _global_idx(env, op.args[0], op.args[1])
        val = _arg(env, op.args[2]).astype(buf.dtype)
        val = jnp.broadcast_to(val, env.lane_shape)
        oob = jnp.int32(buf.shape[0])
        safe = idx if mask is None else jnp.where(mask, idx, oob)
        env.globals[op.args[0]] = buf.at[safe.reshape(-1)].set(
            val.reshape(-1), mode="drop")
    elif oc == ir.ATOMIC_ADD:
        buf = env.globals[op.args[0]]
        idx = _global_idx(env, op.args[0], op.args[1])
        val = _arg(env, op.args[2]).astype(buf.dtype)
        val = jnp.broadcast_to(val, env.lane_shape)
        oob = jnp.int32(buf.shape[0])
        safe = idx if mask is None else jnp.where(mask, idx, oob)
        old = jnp.take(buf, jnp.where(safe >= oob, 0, safe).reshape(-1),
                       axis=0).reshape(env.lane_shape)
        env.globals[op.args[0]] = buf.at[safe.reshape(-1)].add(
            val.reshape(-1), mode="drop")
        if d is not None:
            env.write_reg(d, old, mask)

    # ---- shared memory -----------------------------------------------------
    elif oc == ir.LD_SHARED:
        idx = _arg(env, op.args[0]).astype(jnp.int32)
        safe = idx if mask is None else jnp.where(mask, idx, 0)
        env.write_reg(d, jnp.take_along_axis(env.shared, safe, axis=1), mask)
    elif oc == ir.ST_SHARED:
        idx = _arg(env, op.args[0]).astype(jnp.int32)
        val = _arg(env, op.args[1]).astype(env.shared.dtype)
        val = jnp.broadcast_to(val, env.lane_shape)
        oob = jnp.int32(env.shared.shape[1])
        safe = idx if mask is None else jnp.where(mask, idx, oob)
        rows = jax.lax.broadcasted_iota(jnp.int32, env.lane_shape, 0)
        env.shared = env.shared.at[rows.reshape(-1), safe.reshape(-1)].set(
            val.reshape(-1), mode="drop")

    # ---- collectives (within block, over active lanes) ----------------------
    elif oc == ir.VOTE_ANY:
        p = _active(_arg(env, op.args[0]), mask)
        env.write_reg(d, jnp.any(p, axis=-1, keepdims=True), mask)
    elif oc == ir.VOTE_ALL:
        p = _arg(env, op.args[0])
        p = p if mask is None else jnp.logical_or(p, jnp.logical_not(mask))
        env.write_reg(d, jnp.all(p, axis=-1, keepdims=True), mask)
    elif oc == ir.VOTE_BALLOT:
        p = _active(_arg(env, op.args[0]), mask)
        env.write_reg(d, jnp.sum(p.astype(jnp.int32), axis=-1,
                                 keepdims=True), mask)
    elif oc == ir.REDUCE_ADD:
        v = _arg(env, op.args[0])
        v = v if mask is None else jnp.where(mask, v, jnp.zeros_like(v))
        env.write_reg(d, _seq_reduce_add(v), mask)
    elif oc == ir.REDUCE_MAX:
        v = _arg(env, op.args[0])
        neg = jnp.full_like(v, _min_value(v.dtype))
        v = v if mask is None else jnp.where(mask, v, neg)
        env.write_reg(d, jnp.max(v, axis=-1, keepdims=True), mask)
    elif oc == ir.SCAN_ADD:
        v = _arg(env, op.args[0])
        v = v if mask is None else jnp.where(mask, v, jnp.zeros_like(v))
        env.write_reg(d, _seq_scan_add(v), mask)
    elif oc == ir.SHUFFLE:
        v = _arg(env, op.args[0])
        src = _arg(env, op.args[1]).astype(jnp.int32)
        src = jnp.clip(src, 0, env.block_size - 1)
        env.write_reg(d, jnp.take_along_axis(v, src, axis=-1), mask)

    else:  # pragma: no cover
        raise NotImplementedError(oc)


def _global_idx(env: Env, buf_name: str, idx_arg):
    """Index into a global buffer, rebased for per-block tiles (Pallas)."""
    idx = _arg(env, idx_arg).astype(jnp.int32)
    if buf_name in env.coalesced:
        idx = idx - jnp.asarray(env.tile_base, jnp.int32)
    return idx


def _seq_reduce_add(v):
    """Lane-order sequential sum, one pinned rounding per add.

    ``jnp.sum`` lets XLA pick the reduction tree (and numpy's ``sum`` on
    the interp side used pairwise summation), so float REDUCE_ADD results
    disagreed across backends in the low bits — the documented
    inclusive_scan/nn_layer ULP divergence.  The portable contract is the
    same one every scalar op follows: strict IEEE-sequential over lane
    order, one rounding per ADD (:func:`_pin`).  Masked-off lanes were
    already zeroed by the caller, and ``x + 0.0`` is exact, so inactive
    lanes never perturb the fold.  Integers are exact under any
    association and keep the vectorized path."""
    if not jnp.issubdtype(v.dtype, jnp.floating):
        return jnp.sum(v, axis=-1, keepdims=True)
    acc = v[..., 0:1]
    for t in range(1, v.shape[-1]):
        acc = _pin(acc + v[..., t:t + 1])
    return acc


def _seq_scan_add(v):
    """Lane-order sequential inclusive prefix sum (see _seq_reduce_add).

    Unrolled at trace time: lane t's prefix is the pinned fold of lanes
    0..t, so every partial matches the interpreter's sequential
    accumulator bit for bit."""
    if not jnp.issubdtype(v.dtype, jnp.floating):
        return jnp.cumsum(v, axis=-1)
    cols = [v[..., 0:1]]
    for t in range(1, v.shape[-1]):
        cols.append(_pin(cols[-1] + v[..., t:t + 1]))
    return jnp.concatenate(cols, axis=-1)


def _active(pred, mask):
    return pred if mask is None else jnp.logical_and(pred, mask)


def _min_value(dtype):
    if jnp.issubdtype(dtype, jnp.floating):
        return -jnp.inf
    return jnp.iinfo(dtype).min


def _int_or_float(a, b, fi, ff):
    return ff(a, b) if jnp.issubdtype(a.dtype, jnp.floating) else fi(a, b)


def _pin(v):
    """Pin a floating intermediate to its own IEEE rounding.

    XLA CPU applies inexact algebraic rewrites whose firing depends on the
    *surrounding graph shape*: mul+add contracts into a hardware FMA
    inside fused loops, and constant operands reassociate across adds
    (``(x + c1) + c2 → x + (c1 + c2)``) — so two semantically identical
    programs can differ in their low bits, which breaks the pass
    pipeline's bit-identical O0-vs-OPT_MAX contract (both found by the
    differential fuzz harness).  ``lax.optimization_barrier`` and
    ``reduce_precision`` are erased before fusion (verified on jax 0.4.x);
    ``nextafter(v, v)`` is a *bitwise identity* for every input (equal
    arguments return ``y``; NaN/±inf/±0 round-trip exactly) that lowers
    to bit manipulation the compiler cannot rewrite through.  Every
    inexact float op (ADD/SUB/MUL/DIV and both halves of FMA) pins its
    result, making the jit backends exactly IEEE-sequential — the same
    one-op-one-rounding semantics the interpreter defines.  Integer
    values pass through untouched."""
    if jnp.issubdtype(jnp.result_type(v), jnp.floating):
        return jnp.nextafter(v, v)
    return v


def _mul_exact(a, b):
    return _pin(a * b)


_BINOPS = {
    ir.ADD: lambda a, b: _pin(a + b),
    ir.SUB: lambda a, b: _pin(a - b),
    ir.MUL: _mul_exact,
    # the float divisor hides behind an optimization_barrier: XLA
    # strength-reduces division by a *constant* into multiply-by-
    # reciprocal (~15% of inputs off by 1 ULP vs a true IEEE divide;
    # _pin can't help — the rewrite happens at the div, not after it).
    # The barrier makes the divisor opaque to the algebraic simplifier,
    # so the true division survives.  Found by the attention-profile
    # cross-backend fuzz corpus (seed 20260860: x / 3.1415927).
    ir.DIV: lambda a, b: _int_or_float(
        a, b, lambda x, y: x // y,
        lambda x, y: _pin(x / jax.lax.optimization_barrier(y))),
    ir.MOD: lambda a, b: a % b,
    ir.MIN: jnp.minimum,
    ir.MAX: jnp.maximum,
    ir.AND: lambda a, b: (jnp.logical_and(a, b) if a.dtype == jnp.bool_
                          else a & b),
    ir.OR: lambda a, b: (jnp.logical_or(a, b) if a.dtype == jnp.bool_
                         else a | b),
    ir.XOR: lambda a, b: (jnp.logical_xor(a, b) if a.dtype == jnp.bool_
                          else a ^ b),
    ir.SHL: lambda a, b: a << b,
    ir.SHR: lambda a, b: a >> b,
    ir.LT: lambda a, b: a < b,
    ir.LE: lambda a, b: a <= b,
    ir.GT: lambda a, b: a > b,
    ir.GE: lambda a, b: a >= b,
    ir.EQ: lambda a, b: a == b,
    ir.NE: lambda a, b: a != b,
}

_UNOPS = {
    ir.NEG: lambda a: -a,
    ir.ABS: jnp.abs,
    ir.SQRT: jnp.sqrt,
    # EXP is the portable software exponential (one pinned rounding per
    # primitive op), bit-identical to the interpreter's exp_np — jnp.exp
    # would diverge from np.exp in the low bits (portable_math.py)
    ir.EXP: exp_jnp,
    ir.NOT: lambda a: (jnp.logical_not(a) if a.dtype == jnp.bool_ else ~a),
    ir.MOV: lambda a: a,
}
