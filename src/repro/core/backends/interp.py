"""Scalar per-thread interpreter — MIMD "independent-thread mode".

Each thread is interpreted with its own control flow (a thread set shrinks at
divergent @PRED regions and reconverges after them); collectives synchronize
whichever threads are active at that point.  This is the semantics oracle
against which the vectorized and Pallas backends are validated, written
independently of :mod:`semantics` (numpy scalars, explicit thread loops) so
the implementations cross-check each other.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Sequence

import numpy as np

from .. import hetir as ir
from ..cache import register_reviver
from ..segments import SegNode
from .base import Backend, HostState, Launch
from .portable_math import exp_np


class InterpBackend(Backend):
    name = "interp"

    def __init__(self, cache=None):
        super().__init__(cache)
        # true dynamic work counter: per-thread op executions, divergence-
        # aware (a step over k active threads counts k).  This is the
        # "interp step count" the translation benchmark reports at O0 vs
        # OPT_MAX — unrolling + post-unroll folding shrink it directly.
        self.steps_executed = 0

    def _translate(self, seg: SegNode, launch: Launch):
        """"Translation" for the interpreter: stage the segment into a tree
        of dispatch-step objects once, instead of re-walking the statement
        structure on every block of every launch.  Geometry-independent, so
        the key is just (backend, fingerprint, opt level, segment, spec
        key) — a *specialized* launch stages its own plan (the bound body
        differs), while every generic launch of the program shares one.
        The staged plan is plain picklable objects over IR dataclasses, so
        it persists to the disk tier verbatim — a warm process unpickles
        the plan and skips staging entirely."""
        key = self._cache_key(seg, launch)

        def translate():
            plan = _compile_stmts(seg.stmts)
            return plan, ("interp-plan", plan)

        return self.cache.get_or_translate(key, translate)

    def run_segment(self, seg: SegNode, state: HostState,
                    launch: Launch) -> None:
        T = launch.block_size
        plan = self._translate(seg, launch)
        # normalize to host numpy (previous segments may have run on a
        # jax-array backend — cross-backend migration mid-kernel)
        state.regs = {k: np.asarray(v) for k, v in state.regs.items()}
        if state.shared is not None:
            state.shared = np.asarray(state.shared)
        state.globals_ = {k: np.asarray(v).copy()
                          for k, v in state.globals_.items()}
        with np.errstate(all="ignore"):
            for b in range(launch.num_blocks):
                regs = {k: v[b].copy() for k, v in state.regs.items()}
                shared = state.shared[b] if state.shared is not None else None
                ctx = _BlockCtx(b, T, launch, regs, shared, state.globals_)
                plan(ctx, list(range(T)))
                self.steps_executed += ctx.steps
                for k, v in ctx.regs.items():
                    if k not in state.regs:
                        state.regs[k] = np.zeros(
                            (launch.num_blocks, T), dtype=v.dtype)
                    state.regs[k][b] = v
                if shared is not None:
                    state.shared[b] = ctx.shared


class _BlockCtx:
    def __init__(self, block_id, block_size, launch, regs, shared, globals_):
        self.block_id = block_id
        self.block_size = block_size
        self.launch = launch
        self.regs: Dict[str, np.ndarray] = regs
        self.shared = shared
        self.globals_ = globals_
        self.steps = 0  # per-thread op executions within this block

    def reg_write(self, reg: ir.Reg, t: int, value) -> None:
        if reg.name not in self.regs:
            self.regs[reg.name] = np.zeros(self.block_size,
                                           dtype=ir.np_dtype(reg.dtype))
        self.regs[reg.name][t] = value

    def reg_read(self, reg: ir.Reg, t: int):
        row = self.regs.get(reg.name)
        if row is None:  # never-written register: reads as zero
            return ir.np_dtype(reg.dtype).type(0)
        return row[t]


class _Plan:
    """Staged segment body: a list of step objects.  Built once per cache
    entry; plain data over IR dataclasses, so the whole tree pickles —
    which is what makes interp translations directly persistable."""

    def __init__(self, steps: List["_Step"]):
        self.steps = steps

    def __call__(self, ctx: "_BlockCtx", threads: List[int]) -> None:
        if not threads:
            return
        for step in self.steps:
            step(ctx, threads)


class _Step:
    pass


class _OpStep(_Step):
    def __init__(self, op: ir.Op):
        self.op = op

    def __call__(self, ctx, threads):
        ctx.steps += len(threads)
        _exec_op(self.op, ctx, threads)


class _CollectiveStep(_Step):
    def __init__(self, op: ir.Op):
        self.op = op

    def __call__(self, ctx, threads):
        ctx.steps += len(threads)
        _exec_collective(self.op, ctx, threads)


class _PredStep(_Step):
    def __init__(self, cond: ir.Reg, inner: _Plan):
        self.cond = cond
        self.inner = inner

    def __call__(self, ctx, threads):
        taken = [t for t in threads if bool(ctx.reg_read(self.cond, t))]
        if taken:  # divergence; implicit reconverge
            self.inner(ctx, taken)


class _LoopStep(_Step):
    def __init__(self, loop: ir.Loop, inner: _Plan):
        self.loop = loop
        self.inner = inner

    def __call__(self, ctx, threads):
        loop = self.loop
        count = loop.count if isinstance(loop.count, int) \
            else int(ctx.launch.scalars[loop.count])
        for it in range(count):
            for t in threads:
                ctx.reg_write(loop.var, t, it)
            self.inner(ctx, threads)


def _compile_stmts(stmts: Sequence[ir.Stmt]) -> _Plan:
    """Stage a segment body into a step tree: structural dispatch and
    collective/scalar classification happen once at translation time."""
    steps: List[_Step] = []
    for s in stmts:
        if isinstance(s, ir.Op):
            if s.opcode in ir.COLLECTIVE_OPS:
                steps.append(_CollectiveStep(s))
            else:
                steps.append(_OpStep(s))
        elif isinstance(s, ir.Pred):
            steps.append(_PredStep(s.cond, _compile_stmts(s.body)))
        elif isinstance(s, ir.Loop):
            steps.append(_LoopStep(s, _compile_stmts(s.body)))
        elif isinstance(s, ir.Barrier):
            raise AssertionError("barrier inside segment")
    return _Plan(steps)


# a persisted interp plan is the live value itself
register_reviver("interp-plan", lambda payload: payload)


def _exec_stmts(stmts: Sequence[ir.Stmt], ctx: _BlockCtx,
                threads: List[int]) -> None:
    """Uncached single-shot execution (kept for direct use in tests)."""
    _compile_stmts(stmts)(ctx, threads)


def _val(ctx: _BlockCtx, a, t: int):
    if isinstance(a, ir.Reg):
        return ctx.reg_read(a, t)
    return a


def _exec_op(op: ir.Op, ctx: _BlockCtx, threads: List[int]) -> None:
    oc, d = op.opcode, op.dest

    if oc in ir.COLLECTIVE_OPS:
        _exec_collective(op, ctx, threads)
        return

    for t in threads:
        if oc == ir.GET_GLOBAL_ID:
            v = ctx.block_id * ctx.block_size + t
        elif oc == ir.GET_BLOCK_ID:
            v = ctx.block_id
        elif oc == ir.GET_THREAD_ID:
            v = t
        elif oc == ir.GET_BLOCK_DIM:
            v = ctx.block_size
        elif oc == ir.GET_NUM_BLOCKS:
            v = ctx.launch.num_blocks
        elif oc == ir.CONST:
            v = ir.np_dtype(d.dtype).type(op.args[0])
        elif oc == ir.LD_PARAM:
            v = ir.np_dtype(d.dtype).type(ctx.launch.scalars[op.args[0]])
        elif oc == ir.MOV:
            v = _val(ctx, op.args[0], t)
        elif oc == ir.CVT:
            v = ir.np_dtype(d.dtype).type(_val(ctx, op.args[0], t))
        elif oc == ir.SELECT:
            c, a, bb = (_val(ctx, x, t) for x in op.args)
            v = a if bool(c) else bb
        elif oc == ir.FMA:
            a, bb, c = (_val(ctx, x, t) for x in op.args)
            v = a * bb + c
        elif oc == ir.LD_GLOBAL:
            buf = ctx.globals_[op.args[0]]
            v = buf[int(_val(ctx, op.args[1], t))]
        elif oc == ir.ST_GLOBAL:
            buf = ctx.globals_[op.args[0]]
            buf[int(_val(ctx, op.args[1], t))] = _val(ctx, op.args[2], t)
            continue
        elif oc == ir.ATOMIC_ADD:
            buf = ctx.globals_[op.args[0]]
            i = int(_val(ctx, op.args[1], t))
            old = buf[i]
            buf[i] = old + _val(ctx, op.args[2], t)
            if d is None:
                continue
            v = old
        elif oc == ir.LD_SHARED:
            v = ctx.shared[int(_val(ctx, op.args[0], t))]
        elif oc == ir.ST_SHARED:
            ctx.shared[int(_val(ctx, op.args[0], t))] = \
                _val(ctx, op.args[1], t)
            continue
        elif oc in _SCALAR_BIN:
            a = _val(ctx, op.args[0], t)
            b = _val(ctx, op.args[1], t)
            v = _SCALAR_BIN[oc](a, b)
        elif oc in _SCALAR_UN:
            v = _SCALAR_UN[oc](_val(ctx, op.args[0], t))
        else:  # pragma: no cover
            raise NotImplementedError(oc)
        if d is not None:
            ctx.reg_write(d, t, v)


def _exec_collective(op: ir.Op, ctx: _BlockCtx, threads: List[int]) -> None:
    oc, d = op.opcode, op.dest
    if oc == ir.VOTE_ANY:
        r = any(bool(_val(ctx, op.args[0], t)) for t in threads)
        for t in threads:
            ctx.reg_write(d, t, r)
    elif oc == ir.VOTE_ALL:
        r = all(bool(_val(ctx, op.args[0], t)) for t in threads)
        for t in threads:
            ctx.reg_write(d, t, r)
    elif oc == ir.VOTE_BALLOT:
        r = sum(1 for t in threads if bool(_val(ctx, op.args[0], t)))
        for t in threads:
            ctx.reg_write(d, t, r)
    elif oc == ir.REDUCE_ADD:
        vals = [_val(ctx, op.args[0], t) for t in threads]
        # accumulate in the dest dtype (numpy's sum silently promotes
        # int32 to the platform int — fuzz-harness find) and strictly
        # sequentially in lane order: np.sum uses pairwise summation,
        # whose float rounding diverged from the jnp backends' lane-order
        # fold (the documented nn_layer ULP divergence)
        dt = np.dtype(ir.np_dtype(d.dtype))
        acc = np.zeros((), dtype=dt)  # 0-d: int overflow wraps, no warning
        for val in vals:
            acc = np.add(acc, val, dtype=dt)
        for t in threads:
            ctx.reg_write(d, t, dt.type(acc))
    elif oc == ir.REDUCE_MAX:
        vals = [_val(ctx, op.args[0], t) for t in threads]
        r = np.max(np.array(vals))
        for t in threads:
            ctx.reg_write(d, t, r)
    elif oc == ir.SCAN_ADD:
        # inclusive prefix over *lane order* with inactive lanes contributing 0
        acc = 0
        vals = {}
        active = set(threads)
        for t in range(ctx.block_size):
            if t in active:
                acc = acc + _val(ctx, op.args[0], t)
                vals[t] = acc
        for t in threads:
            ctx.reg_write(d, t, vals[t])
    elif oc == ir.SHUFFLE:
        # read source lane's value regardless of its activity (hardware-like)
        full = ctx.regs[op.args[0].name]
        for t in threads:
            src = int(np.clip(_val(ctx, op.args[1], t), 0,
                              ctx.block_size - 1))
            ctx.reg_write(d, t, full[src])
    else:  # pragma: no cover
        raise NotImplementedError(oc)


def _py_div(a, b):
    if isinstance(a, (np.floating, float)):
        return a / b
    return a // b


_SCALAR_BIN = {
    ir.ADD: lambda a, b: a + b,
    ir.SUB: lambda a, b: a - b,
    ir.MUL: lambda a, b: a * b,
    ir.DIV: _py_div,
    ir.MOD: lambda a, b: a % b,
    # NOT Python min/max: those return whichever operand survives a
    # False comparison, so NaN propagation depends on argument order
    # (max(0.0, nan) == 0.0 but max(nan, 0.0) == nan) while the jnp
    # backends' minimum/maximum always propagate NaN — caught by the
    # attention-profile cross-backend fuzz corpus
    ir.MIN: np.minimum,
    ir.MAX: np.maximum,
    ir.AND: lambda a, b: (a and b) if isinstance(a, (bool, np.bool_))
        else a & b,
    ir.OR: lambda a, b: (a or b) if isinstance(a, (bool, np.bool_))
        else a | b,
    ir.XOR: lambda a, b: (bool(a) != bool(b))
        if isinstance(a, (bool, np.bool_)) else a ^ b,
    ir.SHL: lambda a, b: a << b,
    ir.SHR: lambda a, b: a >> b,
    ir.LT: lambda a, b: a < b,
    ir.LE: lambda a, b: a <= b,
    ir.GT: lambda a, b: a > b,
    ir.GE: lambda a, b: a >= b,
    ir.EQ: lambda a, b: a == b,
    ir.NE: lambda a, b: a != b,
}

_SCALAR_UN = {
    ir.NEG: lambda a: -a,
    ir.ABS: abs,
    ir.SQRT: np.sqrt,
    # EXP is the portable software exponential, not libm: np.exp and
    # jnp.exp disagree in the low bits, which would break the cross-
    # backend bit-identity contract (see backends/portable_math.py)
    ir.EXP: exp_np,
    ir.NOT: lambda a: (not a) if isinstance(a, (bool, np.bool_)) else ~a,
    ir.MOV: lambda a: a,
}
