"""Backend-exact software transcendentals (the portable libm story).

hetIR's conformance contract is one-op-one-rounding bit-identity across
interp / vectorized / pallas.  Basic IEEE ops (``+ - * / sqrt``) are
correctly rounded on every backend, so numpy and XLA agree bit for bit —
but ``EXP`` is a *libm* call, and libms disagree: ``np.exp`` (the
interpreter) and ``jnp.exp`` (the jit backends) differ by 1 ULP on ~40%
of float32 inputs (and by millions of ULP near overflow, where XLA's
range reduction saturates differently).  The model-zoo kernels lean on
``EXP`` for softmax and log-space gating, so the divergence graduated
from a latent suite gap (no suite kernel used EXP cross-backend) to a
conformance break — caught by the attention-shaped fuzz profile.

The fix is the classic one: stop trusting libm and evaluate ``exp`` from
*correctly rounded primitives only*, with an identical operation sequence
on both array substrates:

* range reduction ``x = k·ln2 + r`` with a two-term ``ln2`` split
  (Cody–Waite; the high part has enough trailing zero bits that
  ``k · LN2_HI`` is exact for every |k| ≤ 150),
* a degree-6 minimax polynomial on ``|r| ≤ ln2/2`` (the Cephes ``expf``
  coefficients: ``exp(r) ≈ 1 + r + r²·P(r)``), evaluated by Horner with
  one rounding per multiply/add,
* reconstruction by two exact powers of two built with integer bit
  manipulation (``(e+127) << 23`` bitcast to f32), split ``k = k₁ + k₂``
  so subnormal/overflow outputs round exactly once, in the final multiply.

Every inexact step on the jax side is pinned to its own IEEE rounding
(``nextafter(v, v)`` — see :func:`semantics._pin`) so XLA cannot contract
or reassociate it; the numpy side performs the same roundings natively.
Result: ``exp_np`` and ``exp_jnp`` are **bit-identical for every float32
input** (asserted by ``tests/test_model_zoo.py``), and both stay within
2 ULP of the correctly rounded exponential on the primary range.

NumPy-side oracles (the zoo's reference implementations) must call
:func:`exp_np` wherever their kernel uses ``EXP`` — that shared rounding
sequence *is* the oracle contract for transcendentals.
"""
from __future__ import annotations

import numpy as np

__all__ = ["exp_np", "exp_jnp", "EXP_MAX_INPUT", "EXP_MIN_INPUT"]

#: inputs above this produce +inf, below :data:`EXP_MIN_INPUT` produce +0
#: (with flush-to-zero, underflow effectively begins near ln(2^-126))
EXP_MAX_INPUT = np.float32(88.72283)
EXP_MIN_INPUT = np.float32(-103.972084)

_FLT_MIN_NORMAL = np.float32(1.1754943508222875e-38)  # 2^-126

_LOG2E = np.float32(1.44269504088896341)
_LN2_HI = np.float32(0.693359375)        # 0x1.63p-1: 11 trailing zero bits
_LN2_LO = np.float32(-2.12194440e-4)
#: Cephes expf minimax: exp(r) = 1 + r + r^2 * P(r) on |r| <= ln2/2
_POLY = (np.float32(1.9875691500e-4), np.float32(1.3981999507e-3),
         np.float32(8.3334519073e-3), np.float32(4.1665795894e-2),
         np.float32(1.6666665459e-1), np.float32(5.0000001201e-1))


def _exp_core(x, o):
    """One shared op sequence; ``o`` supplies the array substrate.  Every
    ``o.add/sub/mul`` is exactly one IEEE float32 rounding."""
    one = o.f32(1.0)
    # sanitize so the int cast below never sees NaN; the final selects
    # restore NaN / overflow / underflow from the *original* x
    xs = o.where(o.isnan(x), o.f32(0.0), x)
    xs = o.minimum(o.maximum(xs, o.f32(-104.0)), o.f32(89.0))
    k = o.rint(o.mul(xs, o.f32(_LOG2E)))       # exact: rint of one product
    r = o.sub(xs, o.mul(k, o.f32(_LN2_HI)))    # k*LN2_HI exact, sub exact
    r = o.sub(r, o.mul(k, o.f32(_LN2_LO)))
    p = o.f32(_POLY[0])
    for c in _POLY[1:]:
        p = o.add(o.mul(p, r), o.f32(c))
    rr = o.mul(r, r)
    y = o.add(o.add(o.mul(rr, p), r), one)
    # 2^k as two exact scale factors: k in [-151, 129] after the clamp,
    # so both halves stay in the normal exponent range [-76, 65]
    ki = o.to_i32(k)
    k1 = o.shr1(ki)
    y = o.mul(o.mul(y, o.pow2(k1)), o.pow2(o.isub(ki, k1)))
    # flush-to-zero on subnormal outputs: XLA CPU kernels run FTZ, so a
    # subnormal result of the final multiply is already 0 on the jit
    # backends — the portable contract adopts FTZ, and this select makes
    # the numpy substrate match (subnormal y < FLT_MIN selects 0 on both)
    y = o.where(y < o.f32(_FLT_MIN_NORMAL), o.f32(0.0), y)
    y = o.where(x > o.f32(EXP_MAX_INPUT), o.f32(np.inf), y)
    y = o.where(x < o.f32(EXP_MIN_INPUT), o.f32(0.0), y)
    return o.where(o.isnan(x), x, y)


class _NpOps:
    """NumPy substrate: one rounding per op natively — no pinning needed."""
    f32 = staticmethod(np.float32)
    add = staticmethod(np.add)
    sub = staticmethod(np.subtract)
    mul = staticmethod(np.multiply)
    rint = staticmethod(np.rint)
    where = staticmethod(np.where)
    minimum = staticmethod(np.minimum)
    maximum = staticmethod(np.maximum)
    isnan = staticmethod(np.isnan)

    @staticmethod
    def to_i32(v):
        return np.asarray(v).astype(np.int32)

    @staticmethod
    def shr1(v):
        return v >> 1                       # arithmetic: floor halving

    @staticmethod
    def isub(a, b):
        return a - b

    @staticmethod
    def pow2(e):
        return ((e + np.int32(127)) << np.int32(23)).view(np.float32)


def exp_np(x):
    """float32 exp on the numpy substrate (scalars or arrays).  Returns
    the same shape; scalar in, numpy scalar out."""
    arr = np.asarray(x, dtype=np.float32)
    with np.errstate(over="ignore", invalid="ignore"):
        out = np.asarray(_exp_core(arr, _NpOps), dtype=np.float32)
    return out if out.ndim else np.float32(out)


class _JnpOps:
    """JAX substrate: every inexact op pinned so XLA cannot fuse/contract
    it away from the one-rounding sequence (see semantics._pin)."""

    def __init__(self):
        import jax
        import jax.numpy as jnp
        self._jax, self._jnp = jax, jnp

    def f32(self, v):
        return self._jnp.float32(v)

    def _pin(self, v):
        return self._jnp.nextafter(v, v)

    def add(self, a, b):
        return self._pin(self._jnp.add(a, b))

    def sub(self, a, b):
        return self._pin(self._jnp.subtract(a, b))

    def mul(self, a, b):
        return self._pin(self._jnp.multiply(a, b))

    def rint(self, v):
        return self._jnp.rint(v)

    def where(self, c, a, b):
        return self._jnp.where(c, a, b)

    def minimum(self, a, b):
        return self._jnp.minimum(a, b)

    def maximum(self, a, b):
        return self._jnp.maximum(a, b)

    def isnan(self, v):
        return self._jnp.isnan(v)

    def to_i32(self, v):
        return v.astype(self._jnp.int32)

    def shr1(self, v):
        return v >> 1

    def isub(self, a, b):
        return a - b

    def pow2(self, e):
        bits = (e + self._jnp.int32(127)) << self._jnp.int32(23)
        return self._jax.lax.bitcast_convert_type(bits, self._jnp.float32)


_JNP_OPS = None


def exp_jnp(x):
    """float32 exp on the jax substrate — bit-identical to :func:`exp_np`
    for every input, inside or outside jit (and under pallas interpret)."""
    global _JNP_OPS
    if _JNP_OPS is None:
        _JNP_OPS = _JnpOps()
    o = _JNP_OPS
    return _exp_core(o._jnp.asarray(x, o._jnp.float32), o)
