"""Vectorized-warp backend — software SIMT under ``jax.jit``.

The Tenstorrent "vectorized warp on a core" strategy (paper §4.4): every
block's threads become lanes of dense arrays ``[num_blocks, block_size]``;
divergence is an explicit active-mask; one traced instruction stream serves
all threads.  Each segment is staged and jitted once per
(segment, launch-geometry, uniform-scalars) key — the runtime's translation
cache (paper §4.2 "the runtime caches these translated kernels").
"""
from __future__ import annotations

import jax

from ..segments import SegNode
from .base import Backend, HostState, Launch, scalar_signature
from .semantics import Env, eval_stmts


class VectorizedBackend(Backend):
    name = "vectorized"

    def _translate(self, seg: SegNode, launch: Launch):
        # content-addressed (fingerprint, not object identity): rebuilding
        # an identical program still hits the shared cache
        key = self._cache_key(seg, launch, launch.num_blocks,
                              launch.block_size, scalar_signature(launch))
        fn = self.cache.get(key)
        if fn is not None:
            return fn

        scalars = dict(launch.scalars)
        B, T = launch.num_blocks, launch.block_size

        @jax.jit
        def run(regs: dict, shared, glbs: dict):
            env = Env(dict(regs), shared, dict(glbs), scalars, B, T)
            env.lane_shape = (B, T)
            eval_stmts(seg.stmts, env, mask=None)
            return env.regs, env.shared, env.globals

        return self.cache.put(key, run)

    def run_segment(self, seg: SegNode, state: HostState,
                    launch: Launch) -> None:
        run = self._translate(seg, launch)
        regs, shared, glbs = run(state.regs, state.shared, state.globals_)
        # keep state on-device between segments (registers are only pulled
        # to host numpy at snapshot time — Engine.snapshot)
        state.regs = dict(regs)
        state.shared = shared
        state.globals_ = dict(glbs)
