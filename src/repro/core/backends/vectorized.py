"""Vectorized-warp backend — software SIMT under ``jax.jit``.

The Tenstorrent "vectorized warp on a core" strategy (paper §4.4): every
block's threads become lanes of dense arrays ``[num_blocks, block_size]``;
divergence is an explicit active-mask; one traced instruction stream serves
all threads.  Each segment is staged and traced once per
(segment, launch-geometry, uniform-scalars, state-signature) key — the
runtime's translation cache (paper §4.2 "the runtime caches these
translated kernels").  Translation goes through ``jax.export``: the trace
is recorded as a StableHLO artifact whose serialized bytes ride into the
cache's disk tier, so a warm process re-compiles the recorded program
instead of re-tracing the Python IR evaluator (the dominant cost).

Register contract (shared with interp and pallas via
:mod:`~repro.core.backends.semantics`): hetIR registers read as **zero**
until first written — a register defined only inside a zero-trip loop, or
first written under a mask, yields zeros for the lanes never reached.
Registers in the incoming state that the segment does not touch pass
through unchanged (``env.regs`` starts as the live register dict).
"""
from __future__ import annotations

import jax

from ..segments import SegNode
from .base import (Backend, HostState, Launch, export_translation,
                   scalar_signature, state_signature)
from .semantics import Env, eval_stmts


class VectorizedBackend(Backend):
    name = "vectorized"

    def _translate(self, seg: SegNode, launch: Launch, state: HostState):
        # content-addressed (fingerprint, not object identity): rebuilding
        # an identical program still hits the shared cache.  The incoming
        # state signature joins the key because the exported artifact is
        # shape/dtype-exact.  For a specialized launch the base key already
        # carries the bound-scalar vector; the scalar signature below stays
        # in the key regardless — generic launches still bake scalars into
        # the trace as constants.
        reg_sig, glb_sig, shared_sig = state_signature(state)
        key = self._cache_key(seg, launch, launch.num_blocks,
                              launch.block_size, scalar_signature(launch),
                              reg_sig, glb_sig, shared_sig)

        scalars = dict(launch.scalars)
        B, T = launch.num_blocks, launch.block_size

        def translate():
            @jax.jit
            def run(regs: dict, shared, glbs: dict):
                env = Env(dict(regs), shared, dict(glbs), scalars, B, T)
                env.lane_shape = (B, T)
                eval_stmts(seg.stmts, env, mask=None)
                return env.regs, env.shared, env.globals

            fn, payload = export_translation(
                run, (dict(state.regs), state.shared, dict(state.globals_)),
                cache=self.cache)
            return fn, (None if payload is None else ("jax-aot", payload))

        return self.cache.get_or_translate(key, translate)

    def run_segment(self, seg: SegNode, state: HostState,
                    launch: Launch) -> None:
        run = self._translate(seg, launch, state)
        regs, shared, glbs = run(state.regs, state.shared, state.globals_)
        # keep state on-device between segments (registers are only pulled
        # to host numpy at snapshot time — Engine.snapshot)
        state.regs = dict(regs)
        state.shared = shared
        state.globals_ = dict(glbs)
