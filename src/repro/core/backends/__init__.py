"""hetGPU backends — the runtime's per-target code-generation modules.

Three targets, mirroring the paper's backend taxonomy:

* :mod:`interp`      — scalar per-thread interpreter (MIMD independent-thread
  mode; the correctness oracle);
* :mod:`vectorized`  — masked lane-vector execution under ``jax.jit``
  (the Tenstorrent "vectorized warp on a core" strategy);
* :mod:`pallas_backend` — lowers each segment to a ``pl.pallas_call`` TPU
  kernel (the SIMT-hardware target; "each segment is a separate kernel").
"""
from ..cache import TranslationCache
from .interp import InterpBackend
from .vectorized import VectorizedBackend
from .pallas_backend import PallasBackend

BACKENDS = {
    "interp": InterpBackend,
    "vectorized": VectorizedBackend,
    "pallas": PallasBackend,
}


def get_backend(name: str, cache: TranslationCache = None):
    """Instantiate a backend; ``cache`` overrides the process-wide shared
    translation cache (tests pass a fresh one for counter isolation)."""
    return BACKENDS[name](cache=cache)
