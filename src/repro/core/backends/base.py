"""Backend interface: a backend executes one *segment* at a time.

Between segments all state lives in host numpy arrays (:class:`HostState`) —
this is the paper's design where segment kernels communicate registers and
shared memory "via memory", and it is what makes snapshots backend-neutral
for free.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

import numpy as np

from .. import hetir as ir
from ..cache import TranslationCache, global_cache
from ..segments import SegNode


@dataclass
class Launch:
    program: ir.Program
    num_blocks: int
    block_size: int
    scalars: Dict[str, object] = field(default_factory=dict)
    opt_level: int = 0  # pass-pipeline level the body was optimized at


@dataclass
class HostState:
    regs: Dict[str, np.ndarray]            # [num_blocks, block_size]
    shared: Optional[np.ndarray]           # [num_blocks, shared_size]
    globals_: Dict[str, np.ndarray]        # 1-D buffers


class Backend:
    name = "abstract"

    def __init__(self, cache: Optional[TranslationCache] = None):
        # all backends share one translation cache (paper §4.2: "the runtime
        # caches these translated kernels") unless handed a private one
        self.cache = cache if cache is not None else global_cache()

    def run_segment(self, seg: SegNode, state: HostState,
                    launch: Launch) -> None:
        raise NotImplementedError

    def _cache_key(self, seg: SegNode, launch: Launch,
                   *extra) -> Tuple:
        """Content-addressed translation key: backend, program fingerprint,
        opt level, segment index, plus backend-specific specialization."""
        return (self.name, ir.program_fingerprint(launch.program),
                launch.opt_level, seg.index) + tuple(extra)

    # Cached per-segment compiled artifacts; exposed for the
    # translation-cost benchmark (the paper's JIT-cost table).
    def translation_cache_size(self) -> int:
        return self.cache.size(self.name)

    def cache_stats(self) -> Dict[str, object]:
        return self.cache.stats()


def scalar_signature(launch: Launch) -> Tuple:
    """Uniform scalars as a hashable, dtype-insensitive key component
    (scalars are baked into traced code as constants)."""
    return tuple(sorted((k, float(v)) for k, v in launch.scalars.items()))
