"""Backend interface: a backend executes one *segment* at a time.

Between segments all state lives in host numpy arrays (:class:`HostState`) —
this is the paper's design where segment kernels communicate registers and
shared memory "via memory", and it is what makes snapshots backend-neutral
for free.

This module also owns the *persistence contract* for jitted translations
(paper §4.2's cluster-lifetime JIT amortization): the vectorized and pallas
backends trace their segments through ``jax.export`` at translate time, so
the translation cache can write the serialized StableHLO artifact to its
:class:`~repro.core.cache.DiskStore`.  A warm process revives the artifact
with :func:`jax.export.deserialize` and pays only the (cheap) XLA compile —
the expensive Python re-trace of the IR evaluator is skipped entirely.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

import numpy as np

from .. import hetir as ir
from ..cache import TranslationCache, global_cache, register_reviver
from ..segments import SegNode


@dataclass
class Launch:
    program: ir.Program
    num_blocks: int
    block_size: int
    scalars: Dict[str, object] = field(default_factory=dict)
    opt_level: int = 0  # pass-pipeline level the body was optimized at
    # launch-time specialization key: the (name, value) uniform scalars
    # bound into the optimized body, () for the generic variant.  Part of
    # every translation-cache key (a specialized segment must never be
    # served a generic translation or vice versa, even though their
    # program fingerprints already differ — the key stays self-describing
    # for store scans and debugging)
    spec_key: Tuple = ()
    # buffer name -> shape tuple for every global buffer bound at launch
    # (the PR 5 remainder: the policy and cache keys were shape-blind).
    # Feeds SpecializationPolicy.consider (two launches differing only in
    # buffer length are distinct specialization candidates) and the block
    # lowering's tiled-buffer legality check (a buffer may only be
    # BlockSpec-tiled when its length is exactly num_blocks * block_size)
    buffer_shapes: Dict[str, Tuple[int, ...]] = field(default_factory=dict)
    # stream-scheduler metadata, set by the session when the launch is
    # enqueued/materialized.  Diagnostic only — NEVER part of a
    # translation-cache key: a translated segment is stream-agnostic, and
    # keying on these would shatter the shared cache per launch
    stream_id: Optional[int] = None
    launch_seq: Optional[int] = None


@dataclass
class HostState:
    regs: Dict[str, np.ndarray]            # [num_blocks, block_size]
    shared: Optional[np.ndarray]           # [num_blocks, shared_size]
    globals_: Dict[str, np.ndarray]        # 1-D buffers


class Backend:
    name = "abstract"

    def __init__(self, cache: Optional[TranslationCache] = None):
        # all backends share one translation cache (paper §4.2: "the runtime
        # caches these translated kernels") unless handed a private one
        self.cache = cache if cache is not None else global_cache()

    def run_segment(self, seg: SegNode, state: HostState,
                    launch: Launch) -> None:
        raise NotImplementedError

    def _cache_key(self, seg: SegNode, launch: Launch,
                   *extra) -> Tuple:
        """Content-addressed translation key: backend, program fingerprint,
        opt level, segment index, the launch-time specialization's
        bound-scalar vector (() = generic), plus backend-specific
        specialization.  ``preload`` filters on the first two components,
        so warm-up and migration revive specialized entries exactly like
        generic ones."""
        return (self.name, ir.program_fingerprint(launch.program),
                launch.opt_level, seg.index,
                tuple(launch.spec_key)) + tuple(extra)

    # Cached per-segment compiled artifacts; exposed for the
    # translation-cost benchmark (the paper's JIT-cost table).
    def translation_cache_size(self) -> int:
        return self.cache.size(self.name)

    def cache_stats(self) -> Dict[str, object]:
        return self.cache.stats()


def scalar_signature(launch: Launch) -> Tuple:
    """Uniform scalars as a hashable, dtype-insensitive key component
    (scalars are baked into traced code as constants)."""
    return tuple(sorted((k, float(v)) for k, v in launch.scalars.items()))


def state_signature(state: HostState) -> Tuple[Tuple, Tuple, Optional[Tuple]]:
    """(reg, global, shared) shape+dtype signatures of the incoming state.
    Jit-compiling backends fold these into the cache key: the exported
    artifact is shape-exact, so the key must be too."""
    reg_sig = tuple((n, tuple(np.shape(state.regs[n])),
                     np.dtype(state.regs[n].dtype).str)
                    for n in sorted(state.regs))
    glb_sig = tuple((n, tuple(np.shape(state.globals_[n])),
                     np.dtype(state.globals_[n].dtype).str)
                    for n in sorted(state.globals_))
    shared_sig = None if state.shared is None else \
        (tuple(np.shape(state.shared)), np.dtype(state.shared.dtype).str)
    return reg_sig, glb_sig, shared_sig


# ---------------------------------------------------------------------------
# jax.export persistence: serialize traced+lowered segments so a warm
# process skips Python re-tracing (the dominant translation cost).
# ---------------------------------------------------------------------------

def export_translation(
        jitted, example_args: Tuple,
        cache: Optional[TranslationCache] = None) -> Tuple[Any,
                                                           Optional[bytes]]:
    """Trace ``jitted`` over ``example_args`` (arrays or ShapeDtypeStructs,
    any pytree) with ``jax.export`` and return ``(live fn, payload bytes)``.
    The live fn is the re-jitted exported call — same semantics, compiled
    from the recorded StableHLO.  If export is unsupported for this
    computation, fall back to the plain jitted fn with no payload (the
    entry then lives in memory only) and record the failure on ``cache``
    (``stats()['export_fallbacks']`` / ``['last_export_error']``) so the
    lost persistence is diagnosable."""
    import jax

    try:
        from jax import export as jexport
        structs = jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(np.shape(a), np.dtype(a.dtype)),
            example_args)
        exported = jexport.export(jitted)(*structs)
        return jax.jit(exported.call), exported.serialize()
    except Exception as exc:
        if cache is not None:
            cache.note_export_fallback(f"{type(exc).__name__}: {exc}")
        return jitted, None


def _revive_exported(blob: bytes):
    import jax
    from jax import export as jexport

    return jax.jit(jexport.deserialize(blob).call)


def _revive_exported_with_meta(payload: Tuple[bytes, Dict]):
    blob, meta = payload
    return _revive_exported(blob), meta


register_reviver("jax-export", _revive_exported)
register_reviver("jax-export-meta", _revive_exported_with_meta)
