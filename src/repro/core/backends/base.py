"""Backend interface: a backend executes one *segment* at a time.

Between segments all state lives in host numpy arrays (:class:`HostState`) —
this is the paper's design where segment kernels communicate registers and
shared memory "via memory", and it is what makes snapshots backend-neutral
for free.

This module also owns the *persistence contract* for jitted translations
(paper §4.2's cluster-lifetime JIT amortization): the vectorized and pallas
backends trace their segments through ``jax.export`` at translate time and
AOT-compile them, so the translation cache persists both the portable
StableHLO artifact *and* the serialized XLA executable
(``jax.experimental.serialize_executable``) to its
:class:`~repro.core.cache.DiskStore` / :class:`~repro.core.cache.SharedStore`
tiers.  A warm process deserializes the executable directly — skipping the
expensive Python re-trace *and* the XLA compile — and falls back to
recompiling the StableHLO only when executable revival is impossible.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

import numpy as np

from .. import hetir as ir
from ..cache import TranslationCache, global_cache, register_reviver
from ..segments import SegNode


@dataclass
class Launch:
    program: ir.Program
    num_blocks: int
    block_size: int
    scalars: Dict[str, object] = field(default_factory=dict)
    opt_level: int = 0  # pass-pipeline level the body was optimized at
    # launch-time specialization key: the (name, value) uniform scalars
    # bound into the optimized body, () for the generic variant.  Part of
    # every translation-cache key (a specialized segment must never be
    # served a generic translation or vice versa, even though their
    # program fingerprints already differ — the key stays self-describing
    # for store scans and debugging)
    spec_key: Tuple = ()
    # buffer name -> shape tuple for every global buffer bound at launch
    # (the PR 5 remainder: the policy and cache keys were shape-blind).
    # Feeds SpecializationPolicy.consider (two launches differing only in
    # buffer length are distinct specialization candidates) and the block
    # lowering's tiled-buffer legality check (a buffer may only be
    # BlockSpec-tiled when its length is exactly num_blocks * block_size)
    buffer_shapes: Dict[str, Tuple[int, ...]] = field(default_factory=dict)
    # stream-scheduler metadata, set by the session when the launch is
    # enqueued/materialized.  Diagnostic only — NEVER part of a
    # translation-cache key: a translated segment is stream-agnostic, and
    # keying on these would shatter the shared cache per launch
    stream_id: Optional[int] = None
    launch_seq: Optional[int] = None


@dataclass
class HostState:
    regs: Dict[str, np.ndarray]            # [num_blocks, block_size]
    shared: Optional[np.ndarray]           # [num_blocks, shared_size]
    globals_: Dict[str, np.ndarray]        # 1-D buffers


class Backend:
    name = "abstract"

    def __init__(self, cache: Optional[TranslationCache] = None):
        # all backends share one translation cache (paper §4.2: "the runtime
        # caches these translated kernels") unless handed a private one
        self.cache = cache if cache is not None else global_cache()

    def run_segment(self, seg: SegNode, state: HostState,
                    launch: Launch) -> None:
        raise NotImplementedError

    def _cache_key(self, seg: SegNode, launch: Launch,
                   *extra) -> Tuple:
        """Content-addressed translation key: backend, program fingerprint,
        opt level, segment index, the launch-time specialization's
        bound-scalar vector (() = generic), plus backend-specific
        specialization.  ``preload`` filters on the first two components,
        so warm-up and migration revive specialized entries exactly like
        generic ones."""
        return (self.name, ir.program_fingerprint(launch.program),
                launch.opt_level, seg.index,
                tuple(launch.spec_key)) + tuple(extra)

    # Cached per-segment compiled artifacts; exposed for the
    # translation-cost benchmark (the paper's JIT-cost table).
    def translation_cache_size(self) -> int:
        return self.cache.size(self.name)

    def cache_stats(self) -> Dict[str, object]:
        return self.cache.stats()


def scalar_signature(launch: Launch) -> Tuple:
    """Uniform scalars as a hashable, dtype-insensitive key component
    (scalars are baked into traced code as constants)."""
    return tuple(sorted((k, float(v)) for k, v in launch.scalars.items()))


def state_signature(state: HostState) -> Tuple[Tuple, Tuple, Optional[Tuple]]:
    """(reg, global, shared) shape+dtype signatures of the incoming state.
    Jit-compiling backends fold these into the cache key: the exported
    artifact is shape-exact, so the key must be too."""
    reg_sig = tuple((n, tuple(np.shape(state.regs[n])),
                     np.dtype(state.regs[n].dtype).str)
                    for n in sorted(state.regs))
    glb_sig = tuple((n, tuple(np.shape(state.globals_[n])),
                     np.dtype(state.globals_[n].dtype).str)
                    for n in sorted(state.globals_))
    shared_sig = None if state.shared is None else \
        (tuple(np.shape(state.shared)), np.dtype(state.shared.dtype).str)
    return reg_sig, glb_sig, shared_sig


# ---------------------------------------------------------------------------
# jax.export + AOT persistence: serialize the traced StableHLO *and* the
# XLA-compiled executable, so a warm process skips both Python re-tracing
# and the XLA compile (store format v2, the cluster-fabric contract).
# ---------------------------------------------------------------------------

def export_translation(
        jitted, example_args: Tuple,
        cache: Optional[TranslationCache] = None) -> Tuple[Any,
                                                           Optional[Tuple]]:
    """Trace ``jitted`` over ``example_args`` (arrays or ShapeDtypeStructs,
    any pytree) with ``jax.export``, AOT-compile it, and return
    ``(live fn, payload)`` where the live fn is the *compiled* executable
    (ready to call, no deferred first-launch compile) and the payload is
    the ``jax-aot`` triple ``(hlo_blob, exe, argspec)``:

    * ``hlo_blob`` — the portable serialized StableHLO (always present in
      a payload; survives jaxlib upgrades since the runtime tag retires
      version-skewed stores anyway),
    * ``exe`` — ``jax.experimental.serialize_executable.serialize`` output
      for the compiled executable, or ``None`` when executable
      serialization failed (counted via ``cache.note_aot_fallback``; warm
      starts then recompile from the HLO),
    * ``argspec`` — ``(treedef, [(shape, dtype_str), ...])`` of the
      example args, so the HLO-fallback reviver can eagerly AOT-compile
      and the compile cost lands in ``restore_compile_ms`` instead of
      hiding in the first launch.

    Translate-side wall time is split into trace/export vs XLA-compile on
    ``cache`` (``stats()['trace_ms']`` / ``['compile_ms']``).  If export
    itself is unsupported for this computation, fall back to the plain
    jitted fn with no payload (the entry then lives in memory only) and
    record the failure (``stats()['export_fallbacks']`` /
    ``['last_export_error']``) so the lost persistence is diagnosable."""
    import jax

    try:
        from jax import export as jexport
        t0 = time.perf_counter()
        structs = jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(np.shape(a), np.dtype(a.dtype)),
            example_args)
        exported = jexport.export(jitted)(*structs)
        hlo_blob = exported.serialize()
        t1 = time.perf_counter()
    except Exception as exc:
        if cache is not None:
            cache.note_export_fallback(f"{type(exc).__name__}: {exc}")
        return jitted, None

    flat, treedef = jax.tree.flatten(structs)
    argspec = (treedef, [(tuple(s.shape), np.dtype(s.dtype).str)
                         for s in flat])
    fn, exe = jitted, None
    try:
        compiled = jitted.lower(*structs).compile()
        t2 = time.perf_counter()
        fn = compiled
        try:
            from jax.experimental import serialize_executable
            exe = serialize_executable.serialize(compiled)
        except Exception as exc:
            if cache is not None:
                cache.note_aot_fallback(f"{type(exc).__name__}: {exc}")
    except Exception as exc:
        # AOT lowering failed outright: stay on the lazily-compiling
        # jitted fn; the persisted HLO still spares warm re-traces
        t2 = time.perf_counter()
        if cache is not None:
            cache.note_aot_fallback(f"{type(exc).__name__}: {exc}")
    if cache is not None:
        cache.note_translate_detail(trace_ms=(t1 - t0) * 1e3,
                                    compile_ms=(t2 - t1) * 1e3)
    return fn, (hlo_blob, exe, argspec)


def _revive_exported(blob: bytes):
    import jax
    from jax import export as jexport

    return jax.jit(jexport.deserialize(blob).call)


def _revive_exported_with_meta(payload: Tuple[bytes, Dict]):
    blob, meta = payload
    return _revive_exported(blob), meta


def _revive_aot(payload: Tuple):
    """Revive a ``jax-aot`` payload: deserialize the pickled executable
    (no XLA compile — the fabric's warm-start guarantee) or, when that
    fails (absent / host-topology skew), eagerly recompile from the
    portable StableHLO so the compile cost is attributed to the restore
    (``restore_compile_ms``), not smeared into the first launch."""
    import jax
    from jax import export as jexport
    from ..cache import note_restore_detail

    hlo_blob, exe, argspec = payload
    if exe is not None:
        try:
            from jax.experimental import serialize_executable
            fn = serialize_executable.deserialize_and_load(*exe)
            note_restore_detail(aot=True)
            return fn
        except Exception:
            pass  # fall through to the HLO recompile below
    exported = jexport.deserialize(hlo_blob)
    jitted = jax.jit(exported.call)
    t0 = time.perf_counter()
    try:
        treedef, flat_spec = argspec
        structs = jax.tree.unflatten(
            treedef, [jax.ShapeDtypeStruct(shape, np.dtype(dt))
                      for shape, dt in flat_spec])
        fn = jitted.lower(*structs).compile()
    except Exception:
        fn = jitted  # compile lazily on first launch
    note_restore_detail(aot=False,
                        compile_ms=(time.perf_counter() - t0) * 1e3)
    return fn


def _revive_aot_with_meta(payload: Tuple):
    inner, meta = payload
    return _revive_aot(inner), meta


register_reviver("jax-export", _revive_exported)
register_reviver("jax-export-meta", _revive_exported_with_meta)
register_reviver("jax-aot", _revive_aot)
register_reviver("jax-aot-meta", _revive_aot_with_meta)
