"""Backend interface: a backend executes one *segment* at a time.

Between segments all state lives in host numpy arrays (:class:`HostState`) —
this is the paper's design where segment kernels communicate registers and
shared memory "via memory", and it is what makes snapshots backend-neutral
for free.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional

import numpy as np

from .. import hetir as ir
from ..segments import SegNode


@dataclass
class Launch:
    program: ir.Program
    num_blocks: int
    block_size: int
    scalars: Dict[str, object] = field(default_factory=dict)


@dataclass
class HostState:
    regs: Dict[str, np.ndarray]            # [num_blocks, block_size]
    shared: Optional[np.ndarray]           # [num_blocks, shared_size]
    globals_: Dict[str, np.ndarray]        # 1-D buffers


class Backend:
    name = "abstract"

    def run_segment(self, seg: SegNode, state: HostState,
                    launch: Launch) -> None:
        raise NotImplementedError

    # Backends may cache per-segment compiled artifacts; exposed for the
    # translation-cost benchmark (the paper's JIT-cost table).
    def translation_cache_size(self) -> int:
        return 0
