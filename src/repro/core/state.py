"""Device-neutral execution state — the paper's snapshot format.

A :class:`Snapshot` captures exactly what the paper's state-capture design
prescribes (§4.2 "State Representation"):

* an array of **per-thread virtual register files** — here, a dict mapping
  hetIR register name → ndarray of shape ``[num_blocks, block_size]``;
* the **program position** — not a machine PC but the *node index* in the
  segmented program (all threads of all blocks are aligned at a barrier);
* **loop counters** for barrier-containing loops (uniform scalars);
* **shared memory** contents per block (``[num_blocks, shared_size]``);
* **global memory** buffers.

Everything is stored as host numpy arrays, so a snapshot taken from any
backend (scalar interpreter, vectorized jnp, Pallas) can be re-instantiated
on any other — the cross-architecture migration property (§6.3).  The wire
format is a self-describing, versioned npz blob (``to_bytes`` /
``from_bytes``): the migration payload :func:`~repro.core.runtime.migrate`
ships between sessions.  The snapshot records the ``opt_level`` its
``node_idx`` was taken at, because node indices address the *optimized*
segmented program (see :mod:`~repro.core.segments`) and the destination
must re-run the deterministic :mod:`~repro.core.passes` pipeline at the
same level to reconstruct an identical node list.  What a snapshot does
*not* carry is translated code: the destination's translations come from
its own :class:`~repro.core.cache.TranslationCache` — warmed from a
persistent store when one is available (§4.2 cluster-lifetime JIT).
"""
from __future__ import annotations

import io
import json
from dataclasses import dataclass, field
from typing import Dict, Optional

import numpy as np

FORMAT_VERSION = 1


@dataclass
class Snapshot:
    program_name: str
    num_blocks: int
    block_size: int
    node_idx: int
    loop_counters: Dict[int, int]
    regs: Dict[str, np.ndarray]        # [num_blocks, block_size] each
    shared: Optional[np.ndarray]       # [num_blocks, shared_size] or None
    globals_: Dict[str, np.ndarray]    # buffer name -> host array
    scalars: Dict[str, object] = field(default_factory=dict)
    # pass-pipeline level the program was optimized at when the snapshot was
    # taken: node_idx indexes the *optimized* segmented program, so restore
    # must re-optimize at the same level (the pipeline is deterministic)
    opt_level: int = 0
    # launch-time specialization key — the (name, value) scalar bindings the
    # source engine optimized under; () = generic.  Restore re-binds them
    # verbatim (never re-consults the policy), so a mid-kernel checkpoint of
    # a specialized program reconstructs the identical node list on the
    # destination backend
    spec_key: tuple = ()
    # DeviceBuffer identity: buffer param name -> uid of the handle bound
    # at launch (None for raw host arrays).  Restore re-binds the live
    # buffer with a matching uid when one exists, so checkpoint/restore in
    # one session lands results in the *same* DeviceBuffer objects, and a
    # migration chain keeps stable buffer identity across hops
    buffer_uids: Dict[str, Optional[str]] = field(default_factory=dict)

    # -- serialization ------------------------------------------------------
    def to_bytes(self) -> bytes:
        """Serialize to a self-describing npz blob (the migration payload)."""
        meta = {
            "version": FORMAT_VERSION,
            "program_name": self.program_name,
            "num_blocks": self.num_blocks,
            "block_size": self.block_size,
            "node_idx": self.node_idx,
            "opt_level": int(self.opt_level),
            "loop_counters": {str(k): int(v)
                              for k, v in self.loop_counters.items()},
            "scalars": {k: (float(v)
                            if isinstance(v, (float, np.floating))
                            else int(v))
                        for k, v in self.scalars.items()},
            "spec_key": [[str(k), (float(v)
                                   if isinstance(v, (float, np.floating))
                                   else int(v))]
                         for k, v in self.spec_key],
            "buffer_uids": {k: v for k, v in self.buffer_uids.items()},
            "reg_names": sorted(self.regs),
            "global_names": sorted(self.globals_),
            "has_shared": self.shared is not None,
        }
        arrays = {f"reg_{k}": np.asarray(v) for k, v in self.regs.items()}
        arrays.update({f"glb_{k}": np.asarray(v)
                       for k, v in self.globals_.items()})
        if self.shared is not None:
            arrays["shared"] = np.asarray(self.shared)
        buf = io.BytesIO()
        np.savez(buf, __meta__=np.frombuffer(
            json.dumps(meta).encode(), dtype=np.uint8), **arrays)
        return buf.getvalue()

    @classmethod
    def from_bytes(cls, blob: bytes) -> "Snapshot":
        with np.load(io.BytesIO(blob)) as z:
            meta = json.loads(bytes(z["__meta__"].tobytes()).decode())
            if meta["version"] != FORMAT_VERSION:
                raise ValueError(f"snapshot version {meta['version']} "
                                 f"!= {FORMAT_VERSION}")
            regs = {k: z[f"reg_{k}"] for k in meta["reg_names"]}
            globals_ = {k: z[f"glb_{k}"] for k in meta["global_names"]}
            shared = z["shared"] if meta["has_shared"] else None
        return cls(
            program_name=meta["program_name"],
            num_blocks=meta["num_blocks"],
            block_size=meta["block_size"],
            node_idx=meta["node_idx"],
            opt_level=int(meta.get("opt_level", 0)),
            spec_key=tuple((k, v) for k, v in meta.get("spec_key", [])),
            buffer_uids=dict(meta.get("buffer_uids", {})),
            loop_counters={int(k): v
                           for k, v in meta["loop_counters"].items()},
            regs=regs,
            shared=shared,
            globals_=globals_,
            scalars=meta["scalars"],
        )

    def nbytes(self) -> int:
        n = sum(v.nbytes for v in self.regs.values())
        n += sum(v.nbytes for v in self.globals_.values())
        if self.shared is not None:
            n += self.shared.nbytes
        return n
