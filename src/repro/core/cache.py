"""Shared translation cache (paper §4.2, Module Loading and JIT).

The paper's runtime JIT-translates each hetIR segment to the target's
native code and "caches these translated kernels, so subsequent launches of
the same kernel do not pay the translation cost again."  The seed runtime
gave every backend its own ad-hoc ``_cache`` dict keyed on segment object
identity, so translations were lost whenever a program was rebuilt and
could never be observed or bounded.  :class:`TranslationCache` replaces
those: one process-wide cache, shared by every backend, keyed on

    ``(backend name, program fingerprint, opt level, segment index, ...)``

where the fingerprint is :func:`repro.core.hetir.program_fingerprint` — a
content hash, so structurally identical programs built independently share
translations.  Backends append whatever else their codegen specializes on
(launch geometry, uniform scalars, register/buffer signatures), which is
exactly what makes a relaunch hit and a geometry or dtype change miss.

Three layers extend the paper's per-process cache to its *cluster
lifetime* amortization model (§4.2 notes JIT cost is paid once per kernel,
not per process — and the fabric makes that once per *fleet*):

* **Persistence** — an optional :class:`DiskStore` gives the cache a
  content-addressed on-disk tier.  Entries are written atomically
  (temp-file + ``os.replace``) into a runtime-version-tagged directory, and
  loads are corruption-tolerant: a truncated, garbled, or version-skewed
  entry file is a *miss*, never an exception.  What goes to disk is decided
  by the backend that translated the value: picklable plans (interp) go
  verbatim; jitted XLA code (vectorized / pallas) goes as serialized
  ``jax.export`` artifacts, so a warm start skips Python re-tracing — the
  dominant translation cost — and only replays the cheap StableHLO compile.
  Revival is dispatched through a ``kind`` → reviver registry
  (:func:`register_reviver`) so the cache core stays backend-agnostic.
  Since store format v2 the jit backends persist the **AOT-compiled
  executable** (``jax.experimental.serialize_executable``) next to the
  portable StableHLO, so a warm start skips the XLA compile too —
  ``stats()`` splits ``trace_ms`` / ``compile_ms`` / ``restore_compile_ms``
  to keep that honest.

* **Cluster fabric** — an optional :class:`SharedStore` (shared
  filesystem, ``HETGPU_CACHE_SHARED_DIR``) layered *under* the local
  store: fetch-on-miss with local replication, publish-on-translate, and
  fleet-wide single-flight locking, so N fresh processes pay exactly one
  translation cluster-wide.

* **Cost-aware eviction** — every entry carries its measured translation
  wall-time and serialized size; in-memory eviction uses a GDSF-style
  score ``clock + cost_ms / size`` (Greedy-Dual-Size-Frequency) instead of
  plain LRU, so a 5-second pallas trace is not evicted to make room for a
  microsecond interp plan.  The ``clock`` advances to each victim's score,
  which ages out stale expensive entries over time.  The on-disk tier is
  bounded the same way: past ``HETGPU_CACHE_MAX_BYTES`` (or the
  ``max_bytes`` constructor argument) :meth:`DiskStore.gc` evicts entries
  by the same ``cost_ms / size`` score until the store fits, so a
  long-lived store stops growing instead of filling the disk.

Hit/miss/restore/eviction counters are surfaced through
``HetSession.cache_stats()`` and ``benchmarks/bench_translation.py``.
Set ``HETGPU_CACHE_DIR`` to attach a :class:`DiskStore` to the process-wide
default cache.  See ``docs/CACHING.md`` for the full key anatomy, on-disk
layout, and invalidation rules.
"""
from __future__ import annotations

import contextlib
import hashlib
import os
import pickle
import tempfile
import threading
import time
from pathlib import Path
from typing import Any, Callable, Dict, Hashable, Iterator, Optional, Tuple

try:
    import fcntl
except ImportError:  # pragma: no cover - non-POSIX platform
    fcntl = None

#: bump when the envelope layout or any persisted payload format changes —
#: old store directories are simply never looked at again (tag mismatch)
#: v2: jitted translations persist the AOT-compiled executable alongside
#: the StableHLO (``jax-aot`` / ``jax-aot-meta`` kinds), so warm starts
#: skip XLA compile, not just Python re-trace
STORE_FORMAT_VERSION = 2

_ENVELOPE_MAGIC = "hetgpu-tcache"

# ---------------------------------------------------------------------------
# reviver registry: disk payload ``kind`` -> live-value constructor.
# Backends register their kinds at import time (see backends/interp.py and
# backends/base.py); an entry whose kind has no reviver is a disk miss.
# ---------------------------------------------------------------------------
_REVIVERS: Dict[str, Callable[[Any], Any]] = {}


def register_reviver(kind: str, fn: Callable[[Any], Any]) -> None:
    """Register ``fn`` to turn a persisted payload of ``kind`` back into a
    live cache value.  Last registration wins (idempotent re-imports)."""
    _REVIVERS[kind] = fn


# Side-channel from revivers back to the cache doing the restore: revivers
# are plain ``payload -> value`` callables with no cache handle, but the
# AOT reviver needs to report *how* it revived (deserialized executable vs
# recompiled from StableHLO, and the compile wall-time it paid).  They call
# :func:`note_restore_detail`; the cache pops the fields right after the
# reviver returns, on the same thread.
_RESTORE_DETAIL = threading.local()


def note_restore_detail(**fields) -> None:
    """Called by revivers to annotate the in-progress restore (thread-local;
    consumed by the cache that invoked the reviver)."""
    current = getattr(_RESTORE_DETAIL, "fields", None) or {}
    current.update(fields)
    _RESTORE_DETAIL.fields = current


def _pop_restore_detail() -> Dict[str, Any]:
    fields = getattr(_RESTORE_DETAIL, "fields", None) or {}
    _RESTORE_DETAIL.fields = {}
    return fields


def _runtime_tag() -> str:
    """Version tag for the store directory: entries are only shared between
    processes with an identical serialization contract (store format,
    jax version, accelerator platform) *and* an identical pass pipeline.
    The pipeline fingerprint (:func:`repro.core.passes.
    pipeline_fingerprint`) covers pass names/order per level and the
    unrolling thresholds — a pass-set change retires the whole directory,
    so a stale artifact optimized by an older pipeline is never restored
    against a program the current pipeline would optimize differently."""
    try:
        import jax
        jv, plat = jax.__version__, jax.default_backend()
    except Exception:  # pragma: no cover - jax is a baked-in dependency
        jv, plat = "nojax", "cpu"
    from .passes import pipeline_fingerprint
    return f"v{STORE_FORMAT_VERSION}-p{pipeline_fingerprint()}-jax{jv}-{plat}"


class DiskStore:
    """Content-addressed on-disk tier for :class:`TranslationCache`.

    Layout: ``<root>/<runtime tag>/<sha256(key)[:40]>.tce`` — one pickled
    *envelope* per entry, carrying the full key (collision + integrity
    guard), the payload ``kind``, the payload itself, and the measured
    translation cost.  Writes are atomic (same-directory temp file +
    ``os.replace``), so concurrent writers race benignly (last identical
    write wins) and a crash can never leave a half-written entry visible.
    Loads never raise on bad data: any unpickling error, magic/version
    skew, or key mismatch counts as a miss and quarantines the file —
    but only when the file is provably still the bytes that failed to
    parse (same inode size/mtime): another process may have atomically
    republished a healthy entry at that path between our read and the
    unlink, and quarantining *that* would delete good data.

    The store is **process-safe**, not just thread-safe: a fleet of
    worker processes (:mod:`~repro.core.fleet`) shares one directory.
    :meth:`lock` hands out a per-key advisory ``flock`` (a sidecar
    ``.lock`` file, never unlinked — removing a lock file another
    process is blocked on would silently split the lock), which
    :meth:`TranslationCache.get_or_translate` uses for cross-process
    *single-flight* translation: N processes missing on the same key
    produce one translation and N−1 disk restores, not N translations.
    Set ``HETGPU_CACHE_SINGLE_FLIGHT=0`` to opt out (translations then
    race benignly, last write wins, work is duplicated).
    """

    def __init__(self, root, tag: Optional[str] = None,
                 max_bytes: Optional[int] = None):
        self.root = Path(root)
        self.tag = tag if tag is not None else _runtime_tag()
        self.dir = self.root / self.tag
        self.dir.mkdir(parents=True, exist_ok=True)
        # sweep temp files orphaned by writers killed mid-save (atomic
        # rename means they were never visible as entries).  Age-gated so
        # we never race a live writer in another process.
        cutoff = time.time() - 3600
        for stale in self.dir.glob("*.tmp"):
            try:
                if stale.stat().st_mtime < cutoff:
                    os.unlink(stale)
            except OSError:
                pass
        # on-disk size bound: past it, gc() evicts lowest-GDSF-score
        # entries (HETGPU_CACHE_MAX_BYTES; 0/unset = unbounded)
        if max_bytes is None:
            max_bytes = int(os.environ.get("HETGPU_CACHE_MAX_BYTES",
                                           "0") or 0)
        self.max_bytes = max(0, int(max_bytes))
        self._lock = threading.Lock()
        self.saves = 0
        self.loads = 0
        self.load_misses = 0
        self.corrupt = 0
        self.gc_evictions = 0
        self.gc_runs = 0
        self.lock_sweeps = 0
        # running estimate of the directory's entry bytes; seeded by a
        # scan here, incremented per save, corrected exactly by each gc()
        self._approx_bytes = self.total_bytes()

    # -- key addressing -------------------------------------------------
    def _path(self, key: Hashable) -> Path:
        digest = hashlib.sha256(repr(key).encode()).hexdigest()[:40]
        return self.dir / f"{digest}.tce"

    # -- cross-process locking ------------------------------------------
    @contextlib.contextmanager
    def lock(self, key: Hashable) -> Iterator[bool]:
        """Advisory per-key cross-process lock (``flock`` on a sidecar
        ``<digest>.lock`` file).  Yields ``True`` while holding the lock,
        or ``False`` when locking is unavailable (no ``fcntl``, unwritable
        directory) — callers must treat ``False`` as "proceed unlocked",
        which is always safe because entry publishes are atomic; the lock
        only de-duplicates work.  Lock files are deliberately never
        unlinked: removing one while another process is blocked on it
        would hand out two "exclusive" locks on fresh inodes."""
        if fcntl is None:
            yield False
            return
        lock_path = self._path(key).with_suffix(".lock")
        try:
            fd = os.open(str(lock_path), os.O_RDWR | os.O_CREAT, 0o644)
        except OSError:
            yield False
            return
        try:
            fcntl.flock(fd, fcntl.LOCK_EX)
            yield True
        finally:
            try:
                fcntl.flock(fd, fcntl.LOCK_UN)
            finally:
                os.close(fd)

    # -- write ----------------------------------------------------------
    def save(self, key: Hashable, kind: str, payload: Any,
             cost_ms: float = 0.0) -> int:
        """Atomically persist one translation.  Returns the entry's size in
        bytes (also recorded in the envelope for cost-aware eviction)."""
        envelope = {
            "magic": _ENVELOPE_MAGIC,
            "version": STORE_FORMAT_VERSION,
            "tag": self.tag,
            "key": key,
            "kind": kind,
            "payload": payload,
            "cost_ms": float(cost_ms),
            "created": time.time(),
        }
        # the entry's size (for cost-aware eviction) is the file size,
        # recomputed at load time — no need to serialize twice to embed it
        blob = pickle.dumps(envelope, protocol=pickle.HIGHEST_PROTOCOL)
        path = self._path(key)
        try:
            replaced = path.stat().st_size  # re-save: count the delta
        except OSError:
            replaced = 0
        fd, tmp = tempfile.mkstemp(dir=str(self.dir), suffix=".tmp")
        try:
            with os.fdopen(fd, "wb") as f:
                f.write(blob)
            os.replace(tmp, path)  # atomic publish
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise
        with self._lock:
            self.saves += 1
            self._approx_bytes += len(blob) - replaced
            over = self.max_bytes and self._approx_bytes > self.max_bytes
        if over:
            self.gc()
        return len(blob)

    # -- read -----------------------------------------------------------
    def load(self, key: Hashable) -> Optional[Dict[str, Any]]:
        """Load an envelope, or ``None`` (miss) for absent / truncated /
        corrupt / version-mismatched / colliding entries.  Never raises."""
        with self._lock:
            self.loads += 1
        path = self._path(key)
        env = self._read_envelope(path)
        if env is None or env["key"] != key:
            with self._lock:
                self.load_misses += 1
            return None
        return env

    def _read_envelope(self, path: Path) -> Optional[Dict[str, Any]]:
        try:
            stat_before = path.stat()
            blob = path.read_bytes()
        except OSError:
            return None
        try:
            env = pickle.loads(blob)
            if (not isinstance(env, dict)
                    or env.get("magic") != _ENVELOPE_MAGIC
                    or env.get("version") != STORE_FORMAT_VERSION
                    or "key" not in env or "kind" not in env
                    or "payload" not in env):
                raise ValueError("bad envelope")
            env["size_bytes"] = len(blob)
        except Exception:
            # corruption tolerance: quarantine and report a miss — but only
            # if the file is still the bytes that failed to parse.  Another
            # process may have atomically republished a healthy entry here
            # between our read and this unlink; deleting that would throw
            # away good data, so re-stat and skip the unlink on any change.
            with self._lock:
                self.corrupt += 1
            try:
                st = path.stat()
                if (st.st_mtime_ns == stat_before.st_mtime_ns
                        and st.st_size == stat_before.st_size):
                    os.unlink(path)
            except OSError:
                pass
            return None
        return env

    # -- scan (warm-up / migration preload) -----------------------------
    def iter_entries(self) -> Iterator[Tuple[Hashable, Dict[str, Any]]]:
        """Yield ``(key, envelope)`` for every readable entry; unreadable
        files are skipped (and quarantined), never raised."""
        for path in sorted(self.dir.glob("*.tce")):
            env = self._read_envelope(path)
            if env is not None:
                yield env["key"], env

    def entry_count(self) -> int:
        return sum(1 for _ in self.dir.glob("*.tce"))

    def total_bytes(self) -> int:
        """Exact on-disk entry bytes (directory scan)."""
        total = 0
        for path in self.dir.glob("*.tce"):
            try:
                total += path.stat().st_size
            except OSError:
                pass
        return total

    # -- garbage collection (store size bound) ---------------------------
    #: GC evicts down to this fraction of the bound, not just under it —
    #: the scan reads every envelope (cost_ms lives inside), so draining
    #: some slack per run keeps a store sitting at its bound from paying
    #: a full-directory scan on every subsequent save
    GC_WATERMARK = 0.85

    def gc(self, limit: Optional[int] = None) -> int:
        """Evict entries until the store fits within ``limit`` bytes
        (default: ``max_bytes``), lowest GDSF score first —
        ``cost_ms / size``, the same cost/size trade the in-memory tier
        uses, with age (envelope ``created``) breaking ties — so a
        bounded store sheds its cheapest-to-rebuild translations and
        keeps the expensive traces.  Runs automatically after any save
        that pushes the store past ``max_bytes``, draining to
        ``GC_WATERMARK × limit`` so steady-state inserts amortize the
        scan.  Unreadable entries are quarantined as usual (they count
        as ``corrupt``, not evictions).  Returns the number of entries
        evicted; concurrent GCs race benignly (unlink of a missing file
        is ignored)."""
        limit = self.max_bytes if limit is None else max(0, int(limit))
        scored = []
        total = 0
        for path in sorted(self.dir.glob("*.tce")):
            env = self._read_envelope(path)
            if env is None:
                continue
            size = env["size_bytes"]
            total += size
            scored.append((env.get("cost_ms", 0.0) / max(1, size),
                           env.get("created", 0.0), str(path), size))
        evicted = 0
        if limit:
            target = int(limit * self.GC_WATERMARK)
            for _score, _created, path, size in sorted(scored):
                if total <= target:
                    break
                try:
                    os.unlink(path)
                except OSError:
                    continue
                total -= size
                evicted += 1
        swept = self._sweep_orphan_locks()
        with self._lock:
            self._approx_bytes = total
            self.gc_evictions += evicted
            self.gc_runs += 1
            self.lock_sweeps += swept
        return evicted

    def _sweep_orphan_locks(self) -> int:
        """Unlink ``.lock`` sidecars whose entry is gone (evicted,
        quarantined, or cleared) — without the sweep a long-lived store
        accumulates one inode per key it has *ever* translated.  A sidecar
        is only removed while we hold its ``flock`` non-blocking, so a
        lock someone currently holds (e.g. an in-flight first translation,
        which takes the lock before any entry exists) is never touched.
        A process that opened the file but has not flocked yet can still
        end up on the doomed inode — the documented benign degradation:
        a split lock only means duplicated translation work, since entry
        publishes stay atomic either way."""
        if fcntl is None:
            return 0
        swept = 0
        for lock_path in self.dir.glob("*.lock"):
            if lock_path.with_suffix(".tce").exists():
                continue
            try:
                fd = os.open(str(lock_path), os.O_RDWR)
            except OSError:
                continue
            try:
                try:
                    fcntl.flock(fd, fcntl.LOCK_EX | fcntl.LOCK_NB)
                except OSError:
                    continue  # held right now: an in-flight translation
                try:
                    os.unlink(lock_path)
                    swept += 1
                except OSError:
                    pass
            finally:
                os.close(fd)
        return swept

    def stats(self) -> Dict[str, object]:
        """Cheap counters only — no directory scan, this runs on the
        launch hot path via ``HetSession._sync_cache_stats``.  Use
        :meth:`entry_count` when the on-disk entry total is wanted."""
        with self._lock:
            return {
                "path": str(self.dir),
                "tag": self.tag,
                "saves": self.saves,
                "loads": self.loads,
                "load_misses": self.load_misses,
                "corrupt": self.corrupt,
                "max_bytes": self.max_bytes,
                "approx_bytes": self._approx_bytes,
                "gc_evictions": self.gc_evictions,
                "gc_runs": self.gc_runs,
                "lock_sweeps": self.lock_sweeps,
            }

    def clear(self) -> None:
        for pattern in ("*.tce", "*.tmp"):
            for path in self.dir.glob(pattern):
                try:
                    os.unlink(path)
                except OSError:
                    pass
        with self._lock:
            self._approx_bytes = 0


class SharedStore(DiskStore):
    """Cluster-wide fetch-on-miss tier: one :class:`DiskStore` directory on
    a shared filesystem, layered *under* each node's local store.

    This is the paper's cluster-lifetime amortization made literal: a
    translation is published once (atomic temp-file + ``os.replace``, same
    envelope format and corruption tolerance as the local tier) and every
    other process in the fleet *fetches* it instead of translating.  The
    flock sidecar protocol works unchanged on the shared directory, which
    is what turns per-process single-flight into **fleet-wide**
    single-flight: when a cache has a shared tier attached, it takes the
    translation lock on the shared store, so N fresh processes missing on
    the same key produce exactly one translation cluster-wide.

    Fetched entries are *replicated* into the fetching process's local
    store (when it has one), so subsequent cold starts on that node never
    touch the shared filesystem again — the fabric is a fill path, not a
    dependency.

    Attach via ``HETGPU_CACHE_SHARED_DIR`` (process-wide default cache),
    ``HetSession(shared=...)``, or ``FleetCoordinator(shared_dir=...)``.
    Size-bound with ``HETGPU_CACHE_SHARED_MAX_BYTES`` (same GDSF gc as the
    local tier; unset = unbounded — a fleet's shared tier usually *wants*
    to keep everything).
    """

    def __init__(self, root, tag: Optional[str] = None,
                 max_bytes: Optional[int] = None):
        if max_bytes is None:
            max_bytes = int(os.environ.get("HETGPU_CACHE_SHARED_MAX_BYTES",
                                           "0") or 0)
        super().__init__(root, tag=tag, max_bytes=max_bytes)
        self.publishes = 0
        self.fetches = 0
        self.fetch_misses = 0

    def publish(self, key: Hashable, kind: str, payload: Any,
                cost_ms: float = 0.0) -> int:
        """Atomically publish one translation to the fleet."""
        nbytes = self.save(key, kind, payload, cost_ms=cost_ms)
        with self._lock:
            self.publishes += 1
        return nbytes

    def fetch(self, key: Hashable) -> Optional[Dict[str, Any]]:
        """Load an envelope published by any fleet member (``None`` = clean
        miss, same corruption tolerance as :meth:`DiskStore.load`)."""
        env = self.load(key)
        with self._lock:
            if env is None:
                self.fetch_misses += 1
            else:
                self.fetches += 1
        return env

    def stats(self) -> Dict[str, object]:
        st = super().stats()
        with self._lock:
            st.update(publishes=self.publishes, fetches=self.fetches,
                      fetch_misses=self.fetch_misses)
        return st


class _Entry:
    """One cached translation plus its cost accounting."""

    __slots__ = ("value", "cost_ms", "size_bytes", "score", "seq")

    def __init__(self, value, cost_ms: float, size_bytes: int,
                 score: float, seq: int):
        self.value = value
        self.cost_ms = cost_ms
        self.size_bytes = size_bytes
        self.score = score
        self.seq = seq


class TranslationCache:
    """Thread-safe, cost-aware cache for per-segment translated kernels,
    with an optional persistent :class:`DiskStore` tier and an optional
    cluster-wide :class:`SharedStore` tier underneath it.

    Lookup order: memory → local disk → shared fabric → translate.  A
    shared-tier hit is *replicated* into the local store on the way up;
    a translation is saved locally *and published* to the fabric.  When
    a shared tier is attached, single-flight translation locks live on
    the shared directory, making them fleet-wide."""

    def __init__(self, capacity: int = 1024,
                 store: Optional["DiskStore"] = None,
                 shared: Optional["SharedStore"] = None):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = capacity
        self.store = DiskStore(store) if isinstance(store, (str, Path)) \
            else store
        self.shared = SharedStore(shared) if isinstance(shared, (str, Path)) \
            else shared
        self._entries: Dict[Hashable, _Entry] = {}
        self._lock = threading.RLock()
        self._clock = 0.0   # GDSF aging clock: advances to each victim's score
        self._seq = 0       # recency tie-break among equal scores
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.translated = 0      # fresh translations (factory ran)
        self.restored = 0        # served from the disk/shared tiers
        self.disk_misses = 0     # memory miss that no store could serve
        self.translate_ms = 0.0  # total wall-time spent translating
        self.restore_ms = 0.0    # total wall-time spent reviving from disk
        # translate-side split (reported by export_translation): Python
        # trace + export vs XLA compile.  restore_compile_ms is compile
        # time paid *during restores* — ≈ 0 whenever the persisted AOT
        # executable deserializes, which is the whole point of the fabric.
        self.trace_ms = 0.0
        self.compile_ms = 0.0
        self.restore_compile_ms = 0.0
        self.aot_restored = 0          # restores via deserialized executable
        self.aot_fallback_restores = 0  # restores that recompiled from HLO
        self.shared_fetches = 0        # restores served by the shared tier
        self.shared_publishes = 0      # translations published to the fabric
        self.replicated = 0            # shared-tier hits copied to local disk
        self.export_fallbacks = 0      # translations that could not persist
        self.last_export_error = None  # why (first line of the exception)
        self.aot_export_fallbacks = 0  # persisted without an executable
        self.last_aot_error = None     # why (first line of the exception)
        self.persist_errors = 0        # store writes that failed (disk full…)

    def note_export_fallback(self, error: Optional[str] = None) -> None:
        """Record that a backend produced a memory-only translation because
        serialization (jax.export) failed — otherwise a persistence
        regression is invisible until a warm start mysteriously re-traces."""
        with self._lock:
            self.export_fallbacks += 1
            if error:
                self.last_export_error = str(error).splitlines()[0][:200]

    def note_aot_fallback(self, error: Optional[str] = None) -> None:
        """Record that a translation persisted its StableHLO but not its
        compiled executable (``jax.experimental.serialize_executable``
        failed) — warm starts of this entry will pay the XLA compile."""
        with self._lock:
            self.aot_export_fallbacks += 1
            if error:
                self.last_aot_error = str(error).splitlines()[0][:200]

    def note_translate_detail(self, trace_ms: float = 0.0,
                              compile_ms: float = 0.0) -> None:
        """Called by export_translation to split translation wall-time into
        Python-trace/export vs XLA-compile (bench_translation columns)."""
        with self._lock:
            self.trace_ms += trace_ms
            self.compile_ms += compile_ms

    # -- GDSF internals --------------------------------------------------
    def _score(self, cost_ms: float, size_bytes: int) -> float:
        return self._clock + cost_ms / max(1.0, float(size_bytes))

    def _insert(self, key: Hashable, value: Any, cost_ms: float,
                size_bytes: int) -> None:
        """Insert under the lock, evicting lowest-score entries past
        capacity (cost-aware: cheap-to-rebuild entries go first)."""
        self._seq += 1
        self._entries[key] = _Entry(value, cost_ms, max(1, int(size_bytes)),
                                    self._score(cost_ms, size_bytes),
                                    self._seq)
        while len(self._entries) > self.capacity:
            victim = min(self._entries,
                         key=lambda k: (self._entries[k].score,
                                        self._entries[k].seq))
            self._clock = max(self._clock, self._entries[victim].score)
            del self._entries[victim]
            self.evictions += 1

    # -- memory tier (back-compat surface) -------------------------------
    def get(self, key: Hashable) -> Optional[Any]:
        with self._lock:
            e = self._entries.get(key)
            if e is not None:
                self.hits += 1
                self._seq += 1
                e.seq = self._seq
                e.score = self._score(e.cost_ms, e.size_bytes)  # refresh
                return e.value
            self.misses += 1
            return None

    def put(self, key: Hashable, value: Any, cost_ms: float = 0.0,
            size_bytes: int = 1,
            persist: Optional[Tuple[str, Any]] = None) -> Any:
        with self._lock:
            self._insert(key, value, cost_ms, size_bytes)
        if persist is not None and self.store is not None:
            kind, payload = persist
            nbytes = self._safe_save(key, kind, payload, cost_ms)
            if nbytes:
                with self._lock:
                    e = self._entries.get(key)
                    if e is not None:
                        e.size_bytes = max(1, nbytes)
                        e.score = self._score(e.cost_ms, e.size_bytes)
        return value

    def _safe_save(self, key: Hashable, kind: str, payload: Any,
                   cost_ms: float) -> int:
        """Persist without ever failing the launch: a full/read-only disk
        degrades the entry to memory-only (counted in ``persist_errors``).
        Returns the written size, or 0 when the save did not happen."""
        try:
            return self.store.save(key, kind, payload, cost_ms=cost_ms)
        except Exception:
            with self._lock:
                self.persist_errors += 1
            return 0

    def get_or_create(self, key: Hashable, factory: Callable[[], Any]) -> Any:
        """Lookup; on miss, run ``factory`` (the translation) and cache."""
        return self.get_or_translate(key, lambda: (factory(), None))

    def _try_restore(self, key: Hashable) -> Optional[Any]:
        """Store-tier lookup: local disk first, then the shared fabric.
        Revives the envelope into the memory tier; a fabric hit is also
        replicated into the local store so this node never refetches it.
        Returns the live value, or ``None`` on any miss (absent entry,
        unknown kind, revival failure)."""
        env, from_shared = None, False
        if self.store is not None:
            env = self.store.load(key)
        if env is None and self.shared is not None:
            env = self.shared.fetch(key)
            from_shared = env is not None
        if env is None or env["kind"] not in _REVIVERS:
            return None
        value = self._revive(key, env)
        if value is not None and from_shared:
            with self._lock:
                self.shared_fetches += 1
            if self.store is not None:
                if self._safe_save(key, env["kind"], env["payload"],
                                   env.get("cost_ms", 0.0)):
                    with self._lock:
                        self.replicated += 1
        return value

    def _revive(self, key: Hashable, env: Dict[str, Any]) -> Optional[Any]:
        """Run the reviver for one loaded envelope, account the restore,
        and insert the live value into the memory tier."""
        t0 = time.perf_counter()
        _pop_restore_detail()  # drop any stale fields from a failed revive
        try:
            value = _REVIVERS[env["kind"]](env["payload"])
        except Exception:
            return None  # revival failure degrades to a miss
        dt = (time.perf_counter() - t0) * 1e3
        detail = _pop_restore_detail()
        if value is not None:
            with self._lock:
                self.restored += 1
                self.restore_ms += dt
                if detail.get("aot") is True:
                    self.aot_restored += 1
                elif detail.get("aot") is False:
                    self.aot_fallback_restores += 1
                self.restore_compile_ms += detail.get("compile_ms", 0.0)
                self._insert(key, value, env.get("cost_ms", 0.0),
                             env.get("size_bytes", 1))
        return value

    # -- full lookup path: memory -> disk -> translate --------------------
    def get_or_translate(
            self, key: Hashable,
            translate: Callable[[], Tuple[Any, Optional[Tuple[str, Any]]]]
    ) -> Any:
        """Three-tier lookup.  ``translate`` runs only when neither the
        memory tier nor the disk tier can serve ``key``; it returns
        ``(live value, persist)`` where ``persist`` is ``(kind, payload)``
        for the disk tier or ``None`` for memory-only values.  Translation
        wall-time is measured here and drives both the eviction score and
        ``stats()['translate_ms']``.

        When a disk tier is attached, translation runs under the store's
        per-key cross-process lock (*single-flight*): of N processes
        missing on the same key, one translates while the rest block on
        the lock, then find the published entry on their re-check and
        restore it.  With a shared fabric attached, the lock is taken on
        the *shared* directory instead, so single-flight holds across
        the whole fleet, not just one node.
        ``HETGPU_CACHE_SINGLE_FLIGHT=0`` disables the lock (translations
        then race benignly — atomic publishes mean the last identical
        write wins, work is merely duplicated)."""
        value = self.get(key)
        if value is not None:
            return value
        if self.store is not None or self.shared is not None:
            value = self._try_restore(key)
            if value is not None:
                return value
            with self._lock:
                self.disk_misses += 1
            lock_store = self.shared if self.shared is not None else self.store
            if os.environ.get("HETGPU_CACHE_SINGLE_FLIGHT", "1") != "0":
                with lock_store.lock(key) as locked:
                    if locked:
                        # a lock-holder may have published while we waited
                        value = self._try_restore(key)
                        if value is not None:
                            return value
                    return self._translate_and_insert(key, translate)
        return self._translate_and_insert(key, translate)

    def _translate_and_insert(
            self, key: Hashable,
            translate: Callable[[], Tuple[Any, Optional[Tuple[str, Any]]]]
    ) -> Any:
        t0 = time.perf_counter()
        value, persist = translate()
        dt = (time.perf_counter() - t0) * 1e3
        with self._lock:
            self.translated += 1
            self.translate_ms += dt
        size = 1
        if persist is not None:
            kind, payload = persist
            if self.store is not None:
                size = self._safe_save(key, kind, payload, dt) or 1
            if self.shared is not None:
                try:
                    nbytes = self.shared.publish(key, kind, payload,
                                                 cost_ms=dt)
                    with self._lock:
                        self.shared_publishes += 1
                    if size == 1:
                        size = nbytes or 1
                except Exception:
                    with self._lock:
                        self.persist_errors += 1
        with self._lock:
            self._insert(key, value, dt, size)
        return value

    def preload(self, backend: Optional[str] = None,
                fingerprint: Optional[str] = None,
                store: Optional["DiskStore"] = None) -> int:
        """Revive matching disk entries into the memory tier ahead of use
        (warm-up / migration).  ``backend`` / ``fingerprint`` filter on the
        leading key components; ``store`` overrides the default scan order
        — local store, then the shared fabric (a migration source may hand
        over its own store).  Fabric entries revived here are replicated
        into the local store, exactly like a fetch-on-miss.  Returns the
        number of entries restored; unrevivable entries are skipped
        silently."""
        if store is not None:
            sources = [store]
        else:
            sources = [s for s in (self.store, self.shared) if s is not None]
        count = 0
        for src in sources:
            for key, env in src.iter_entries():
                if not isinstance(key, tuple) or len(key) < 2:
                    continue
                if backend is not None and key[0] != backend:
                    continue
                if fingerprint is not None and key[1] != fingerprint:
                    continue
                with self._lock:
                    if key in self._entries:
                        continue
                if env["kind"] not in _REVIVERS:
                    continue
                if self._revive(key, env) is None:
                    continue
                if src is self.shared:
                    with self._lock:
                        self.shared_fetches += 1
                    if self.store is not None:
                        if self._safe_save(key, env["kind"], env["payload"],
                                           env.get("cost_ms", 0.0)):
                            with self._lock:
                                self.replicated += 1
                count += 1
        return count

    # ------------------------------------------------------------------
    def size(self, backend: Optional[str] = None) -> int:
        """Entry count, optionally restricted to one backend's keys (every
        backend key leads with the backend name)."""
        with self._lock:
            if backend is None:
                return len(self._entries)
            return sum(1 for k in self._entries
                       if isinstance(k, tuple) and k and k[0] == backend)

    def stats(self) -> Dict[str, object]:
        with self._lock:
            lookups = self.hits + self.misses
            st: Dict[str, object] = {
                "entries": len(self._entries),
                "capacity": self.capacity,
                "hits": self.hits,
                "misses": self.misses,
                "evictions": self.evictions,
                "hit_rate": (self.hits / lookups) if lookups else 0.0,
                "translated": self.translated,
                "restored": self.restored,
                "disk_misses": self.disk_misses,
                "translate_ms": self.translate_ms,
                "restore_ms": self.restore_ms,
                "trace_ms": self.trace_ms,
                "compile_ms": self.compile_ms,
                "restore_compile_ms": self.restore_compile_ms,
                "aot_restored": self.aot_restored,
                "aot_fallback_restores": self.aot_fallback_restores,
                "shared_fetches": self.shared_fetches,
                "shared_publishes": self.shared_publishes,
                "replicated": self.replicated,
                "export_fallbacks": self.export_fallbacks,
                "last_export_error": self.last_export_error,
                "aot_export_fallbacks": self.aot_export_fallbacks,
                "last_aot_error": self.last_aot_error,
                "persist_errors": self.persist_errors,
            }
        if self.store is not None:
            st["store"] = self.store.stats()
        if self.shared is not None:
            st["shared"] = self.shared.stats()
        return st

    def clear(self) -> None:
        """Drop the memory tier and reset counters (the disk tier, if any,
        is deliberately left intact — use ``store.clear()`` for that)."""
        with self._lock:
            self._entries.clear()
            self.hits = self.misses = self.evictions = 0
            self.translated = self.restored = self.disk_misses = 0
            self.translate_ms = self.restore_ms = 0.0
            self.trace_ms = self.compile_ms = self.restore_compile_ms = 0.0
            self.aot_restored = self.aot_fallback_restores = 0
            self.shared_fetches = self.shared_publishes = self.replicated = 0
            self.export_fallbacks = 0
            self.last_export_error = None
            self.aot_export_fallbacks = 0
            self.last_aot_error = None
            self.persist_errors = 0
            self._clock = 0.0


# process-wide default: sessions and backends share translations unless
# handed an explicit cache (tests inject fresh instances for isolation).
# HETGPU_CACHE_DIR attaches a persistent tier; HETGPU_CACHE_SHARED_DIR
# attaches the cluster fabric.
_GLOBAL_CACHE = TranslationCache()


def global_cache() -> TranslationCache:
    # re-checked on every call (not latched): an application may set the
    # env vars after some backend has already touched the global cache
    if _GLOBAL_CACHE.store is None:
        cache_dir = os.environ.get("HETGPU_CACHE_DIR")
        if cache_dir:
            _GLOBAL_CACHE.store = DiskStore(cache_dir)
    if _GLOBAL_CACHE.shared is None:
        shared_dir = os.environ.get("HETGPU_CACHE_SHARED_DIR")
        if shared_dir:
            _GLOBAL_CACHE.shared = SharedStore(shared_dir)
    return _GLOBAL_CACHE
