"""Shared translation cache (paper §4.2, Module Loading and JIT).

The paper's runtime JIT-translates each hetIR segment to the target's
native code and "caches these translated kernels, so subsequent launches of
the same kernel do not pay the translation cost again."  The seed runtime
gave every backend its own ad-hoc ``_cache`` dict keyed on segment object
identity, so translations were lost whenever a program was rebuilt and
could never be observed or bounded.  :class:`TranslationCache` replaces
those: one process-wide LRU, shared by every backend, keyed on

    ``(backend name, program fingerprint, opt level, segment index, ...)``

where the fingerprint is :func:`repro.core.hetir.program_fingerprint` — a
content hash, so structurally identical programs built independently share
translations.  Backends append whatever else their codegen specializes on
(launch geometry, uniform scalars, register/buffer signatures), which is
exactly what makes a relaunch hit and a geometry or dtype change miss.

Hit/miss/eviction counters are surfaced through
``HetSession.cache_stats()`` and ``benchmarks/bench_translation.py``.
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from typing import Any, Callable, Dict, Hashable, Optional


class TranslationCache:
    """Thread-safe LRU cache for per-segment translated kernels."""

    def __init__(self, capacity: int = 1024):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = capacity
        self._entries: "OrderedDict[Hashable, Any]" = OrderedDict()
        self._lock = threading.RLock()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    # ------------------------------------------------------------------
    def get(self, key: Hashable) -> Optional[Any]:
        with self._lock:
            if key in self._entries:
                self._entries.move_to_end(key)
                self.hits += 1
                return self._entries[key]
            self.misses += 1
            return None

    def put(self, key: Hashable, value: Any) -> Any:
        with self._lock:
            self._entries[key] = value
            self._entries.move_to_end(key)
            while len(self._entries) > self.capacity:
                self._entries.popitem(last=False)
                self.evictions += 1
        return value

    def get_or_create(self, key: Hashable, factory: Callable[[], Any]) -> Any:
        """Lookup; on miss, run ``factory`` (the translation) and cache."""
        value = self.get(key)
        if value is None:
            value = self.put(key, factory())
        return value

    # ------------------------------------------------------------------
    def size(self, backend: Optional[str] = None) -> int:
        """Entry count, optionally restricted to one backend's keys (every
        backend key leads with the backend name)."""
        with self._lock:
            if backend is None:
                return len(self._entries)
            return sum(1 for k in self._entries
                       if isinstance(k, tuple) and k and k[0] == backend)

    def stats(self) -> Dict[str, object]:
        with self._lock:
            lookups = self.hits + self.misses
            return {
                "entries": len(self._entries),
                "capacity": self.capacity,
                "hits": self.hits,
                "misses": self.misses,
                "evictions": self.evictions,
                "hit_rate": (self.hits / lookups) if lookups else 0.0,
            }

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()
            self.hits = self.misses = self.evictions = 0


# process-wide default: sessions and backends share translations unless
# handed an explicit cache (tests inject fresh instances for isolation)
_GLOBAL_CACHE = TranslationCache()


def global_cache() -> TranslationCache:
    return _GLOBAL_CACHE
