"""The paper's microbenchmark/validation kernel suite, in hetIR (§5.3/§6.1).

Ten kernels mirroring the paper's portability evaluation: vector add, SAXPY,
tiled matrix multiply (shared memory + barriers), reduction (shared-memory
tree + atomics), inclusive scan, bitcount via ballot vote, Monte-Carlo pi
(divergence + RNG + atomics), a small neural-net layer (matvec + ReLU),
a divergent 1-D stencil, and a persistent iterative kernel (the migration
test target).  Three loop-heavy kernels target the phase-2 optimizer
(see ``docs/PASSES.md``): ``poly_eval`` (constant-trip Horner loop —
unrolling + folding), ``swizzle_copy`` (power-of-two index arithmetic —
strength reduction), and ``tap_filter`` (a recomputed quotient spanning a
barrier — cross-segment value numbering).  Two *dynamic-trip* kernels
target launch-time specialization: ``dyn_matmul`` (the tile loop's trip
count is a launch scalar, unrollable only once bound) and ``dyn_fir``
(dynamic taps plus a loop-invariant load that the alias-aware hoist moves
once the trip count is known positive).  ``decode_gemv`` is the
serving-tier workload: one decode step's residual matvec, barriered per
tile so the fair-share scheduler preempts between tiles.

Each returns a :class:`~repro.core.hetir.Program` plus a pure-numpy oracle.
"""
from __future__ import annotations

import math
from typing import Callable, Dict, Tuple

import numpy as np

from . import hetir as ir
from .hetir import Builder, Ptr, Scalar


# ---------------------------------------------------------------------------
def vadd() -> Tuple[ir.Program, Callable]:
    b = Builder("vadd", [Ptr("A"), Ptr("B"), Ptr("C"), Scalar("n")])
    i = b.global_id(0)
    with b.when(i < b.param("n")):
        b.store("C", i, b.load("A", i) + b.load("B", i))
    prog = b.done()

    def oracle(args):
        n = int(args["n"])
        out = np.array(args["C"], dtype=np.float32)
        out[:n] = np.asarray(args["A"])[:n] + np.asarray(args["B"])[:n]
        return {"C": out}

    return prog, oracle


def saxpy() -> Tuple[ir.Program, Callable]:
    b = Builder("saxpy", [Ptr("X"), Ptr("Y"), Scalar("n"),
                          Scalar("a", ir.F32)])
    i = b.global_id(0)
    with b.when(i < b.param("n")):
        y = b.load("Y", i) + b.param("a") * b.load("X", i)
        b.store("Y", i, y)
    prog = b.done()

    def oracle(args):
        n, a = int(args["n"]), np.float32(args["a"])
        y = np.array(args["Y"], dtype=np.float32)
        y[:n] = y[:n] + a * np.asarray(args["X"])[:n]
        return {"Y": y}

    return prog, oracle


# ---------------------------------------------------------------------------
def matmul_tiled(tile_k: int = 8) -> Tuple[ir.Program, Callable]:
    """C[M,N] = A[M,K] @ B[K,N].  One block per row of C; ``block_size`` = N.
    K is consumed in ``tile_k`` chunks staged through shared memory with a
    barrier per tile — the paper's shared-memory matmul, and the canonical
    barrier-segmented kernel for migration tests."""
    b = Builder("matmul_tiled",
                [Ptr("A"), Ptr("B"), Ptr("C"), Scalar("K"), Scalar("N"),
                 Scalar("ktiles")],
                shared_size=tile_k)
    row = b.block_id()
    col = b.thread_id()
    n = b.param("N")
    k = b.param("K")
    acc = b.var(b.const(0.0, ir.F32), hint="acc")
    with b.loop("ktiles", hint="kt") as kt:
        # threads t < tile_k cooperatively stage A[row, kt*tile_k + t]
        t = b.thread_id()
        with b.when(t < b.const(tile_k)):
            a_idx = row * k + kt * b.const(tile_k) + t
            b.store_shared(t, b.load("A", a_idx))
        b.barrier("tile-staged")
        with b.loop(tile_k, hint="kk") as kk:
            a_val = b.load_shared(kk)
            b_idx = (kt * b.const(tile_k) + kk) * n + col
            b.assign(acc, b.fma(a_val, b.load("B", b_idx), acc))
        b.barrier("tile-consumed")
    b.store("C", row * n + col, acc)
    prog = b.done()

    def oracle(args):
        K, N = int(args["K"]), int(args["N"])
        A = np.asarray(args["A"], np.float32)
        B = np.asarray(args["B"], np.float32)
        M = A.size // K
        C = (A.reshape(M, K) @ B.reshape(K, N)).reshape(-1)
        return {"C": C.astype(np.float32)}

    return prog, oracle


# ---------------------------------------------------------------------------
def reduction() -> Tuple[ir.Program, Callable]:
    """Block-level shared-memory tree reduction + one atomic per block."""
    b = Builder("reduction", [Ptr("A"), Ptr("Out"), Scalar("n"),
                              Scalar("log2t")],
                shared_size=1024)
    i = b.global_id(0)
    t = b.thread_id()
    x = b.var(b.const(0.0, ir.F32), hint="x")
    with b.when(i < b.param("n")):
        b.assign(x, b.load("A", i))
    b.store_shared(t, x)
    b.barrier("loaded")
    dim = b.block_dim()
    with b.loop("log2t", hint="lv") as lv:
        # offset = block_dim >> (lv+1)
        off = dim >> (lv + b.const(1))
        with b.when(t < off):
            s = b.load_shared(t) + b.load_shared(t + off)
            b.store_shared(t, s)
        b.barrier("tree-step")
    with b.when(t.eq(b.const(0))):
        b.atomic_add("Out", b.const(0), b.load_shared(b.const(0)))
    prog = b.done()

    def oracle(args):
        n = int(args["n"])
        s = np.asarray(args["A"], np.float32)[:n].sum()
        out = np.array(args["Out"], np.float32)
        out[0] += s
        return {"Out": out}

    return prog, oracle


# ---------------------------------------------------------------------------
def inclusive_scan() -> Tuple[ir.Program, Callable]:
    """Per-block inclusive prefix sum (the paper rewrote warp-shuffle scan
    with portable collectives — ours uses the SCAN_ADD intrinsic)."""
    b = Builder("inclusive_scan", [Ptr("A"), Ptr("Out"), Ptr("BlockSums"),
                                   Scalar("n")])
    i = b.global_id(0)
    x = b.var(b.const(0.0, ir.F32), hint="x")
    with b.when(i < b.param("n")):
        b.assign(x, b.load("A", i))
    s = b.scan_add(x)
    with b.when(i < b.param("n")):
        b.store("Out", i, s)
    t = b.thread_id()
    last = b.block_dim() - b.const(1)
    with b.when(t.eq(last)):
        b.store("BlockSums", b.block_id(), s)
    prog = b.done()

    def oracle(args):
        n = int(args["n"])
        A = np.asarray(args["A"], np.float32)
        T = args["_block_size"]
        out = np.array(args["Out"], np.float32)
        bs = np.array(args["BlockSums"], np.float32)
        x = A.copy()
        x[n:] = 0
        blocks = x.reshape(-1, T)
        scans = np.cumsum(blocks, axis=1, dtype=np.float32)
        flat = scans.reshape(-1)
        out[:n] = flat[:n]
        bs[:scans.shape[0]] = scans[:, -1]
        return {"Out": out, "BlockSums": bs}

    return prog, oracle


# ---------------------------------------------------------------------------
def bitcount_vote() -> Tuple[ir.Program, Callable]:
    """Count of threads per block with A[i] > thresh, via VOTE_BALLOT."""
    b = Builder("bitcount_vote", [Ptr("A"), Ptr("Out"), Scalar("n"),
                                  Scalar("thresh", ir.F32)])
    i = b.global_id(0)
    inb = i < b.param("n")
    val = b.var(b.const(0.0, ir.F32), hint="val")
    with b.when(inb):
        b.assign(val, b.load("A", i))
    hit = (val > b.param("thresh")) & inb
    cnt = b.ballot(hit)
    with b.when(b.thread_id().eq(b.const(0))):
        b.store("Out", b.block_id(), cnt.astype(ir.F32))
    prog = b.done()

    def oracle(args):
        n, th = int(args["n"]), np.float32(args["thresh"])
        T = args["_block_size"]
        A = np.asarray(args["A"], np.float32).copy()
        mask = np.zeros(A.size, bool)
        mask[:n] = A[:n] > th
        counts = mask.reshape(-1, T).sum(axis=1)
        out = np.array(args["Out"], np.float32)
        out[:counts.size] = counts
        return {"Out": out}

    return prog, oracle


# ---------------------------------------------------------------------------
def montecarlo_pi(iters: int = 16) -> Tuple[ir.Program, Callable]:
    """Monte-Carlo pi with per-thread xorshift RNG — the paper's divergent
    kernel (divergence + atomics)."""
    b = Builder("montecarlo_pi", [Ptr("Count", ir.F32)])
    i = b.global_id(0)
    seed = (i + b.const(1)).astype(ir.U32)
    x = b.var(seed * b.const(2654435761, ir.U32), hint="rng")
    hits = b.var(b.const(0.0, ir.F32), hint="hits")
    inv = b.const(float(1.0 / (1 << 24)), ir.F32)
    with b.loop(iters, hint="mc"):
        # xorshift32 twice -> u, v
        def step(v):
            v1 = v ^ (v << b.const(13, ir.U32))
            v2 = v1 ^ (v1 >> b.const(17, ir.U32))
            return v2 ^ (v2 << b.const(5, ir.U32))

        r1 = step(x)
        r2 = step(r1)
        b.assign(x, r2)
        u = (r1 >> b.const(8, ir.U32)).astype(ir.F32) * inv
        v = (r2 >> b.const(8, ir.U32)).astype(ir.F32) * inv
        d = u * u + v * v
        with b.when(d < b.const(1.0, ir.F32)):
            b.assign(hits, hits + b.const(1.0, ir.F32))
    total = b.reduce_add(hits)
    with b.when(b.thread_id().eq(b.const(0))):
        b.atomic_add("Count", b.const(0), total)
    prog = b.done()

    def oracle(args):
        # RNG-exact oracle computed in numpy
        B, T = args["_num_blocks"], args["_block_size"]
        n = B * T
        gid = np.arange(n, dtype=np.uint32)
        x = (gid + 1) * np.uint32(2654435761)
        hits = np.zeros(n, np.float32)
        with np.errstate(over="ignore"):
            for _ in range(iters):
                def step(v):
                    v = v ^ (v << np.uint32(13))
                    v = v ^ (v >> np.uint32(17))
                    return v ^ (v << np.uint32(5))

                r1 = step(x)
                r2 = step(r1)
                x = r2
                u = (r1 >> np.uint32(8)).astype(np.float32) / (1 << 24)
                v = (r2 >> np.uint32(8)).astype(np.float32) / (1 << 24)
                hits += (u * u + v * v < 1.0).astype(np.float32)
        out = np.array(args["Count"], np.float32)
        out[0] += hits.sum()
        return {"Count": out}

    return prog, oracle


# ---------------------------------------------------------------------------
def nn_layer() -> Tuple[ir.Program, Callable]:
    """Small neural-net layer: out = relu(W @ x + bias); one block per
    output row, K-loop per thread (the paper's matvec+ReLU kernel)."""
    b = Builder("nn_layer", [Ptr("W"), Ptr("X"), Ptr("Bias"), Ptr("Out"),
                             Scalar("K"), Scalar("kchunks")])
    row = b.block_id()
    t = b.thread_id()
    k = b.param("K")
    dim = b.block_dim()
    acc = b.var(b.const(0.0, ir.F32), hint="acc")
    # threads stride over K; per-thread partials reduced block-wide
    with b.loop("kchunks", hint="kc") as kc:
        idx = kc * dim + t
        with b.when(idx < k):
            b.assign(acc, b.fma(b.load("W", row * k + idx),
                                b.load("X", idx), acc))
    total = b.reduce_add(acc)
    with b.when(t.eq(b.const(0))):
        val = total + b.load("Bias", row)
        b.store("Out", row, b.maximum(val, b.const(0.0, ir.F32)))
    prog = b.done()

    def oracle(args):
        K = int(args["K"])
        W = np.asarray(args["W"], np.float32)
        Xv = np.asarray(args["X"], np.float32)[:K]
        Bv = np.asarray(args["Bias"], np.float32)
        M = W.size // K
        out = np.maximum(W.reshape(M, K) @ Xv + Bv[:M], 0)
        res = np.array(args["Out"], np.float32)
        res[:M] = out
        return {"Out": res}

    return prog, oracle


# ---------------------------------------------------------------------------
def stencil_1d() -> Tuple[ir.Program, Callable]:
    """Divergent boundary-handling stencil."""
    b = Builder("stencil_1d", [Ptr("A"), Ptr("Out"), Scalar("n")])
    i = b.global_id(0)
    n = b.param("n")
    with b.when(i < n):
        c = b.load("A", i)
        left = b.var(c, hint="left")
        right = b.var(c, hint="right")
        with b.when(i > b.const(0)):
            b.assign(left, b.load("A", i - b.const(1)))
        with b.when(i < n - b.const(1)):
            b.assign(right, b.load("A", i + b.const(1)))
        b.store("Out", i, (left + c + right) * b.const(1.0 / 3.0, ir.F32))
    prog = b.done()

    def oracle(args):
        n = int(args["n"])
        A = np.asarray(args["A"], np.float32)[:n]
        out = np.array(args["Out"], np.float32)
        acc = A.copy()
        acc[1:] += A[:-1]
        acc[:-1] += A[1:]
        acc[0] += A[0]
        acc[-1] += A[-1]
        out[:n] = acc / 3.0
        return {"Out": out}

    return prog, oracle


# ---------------------------------------------------------------------------
def persistent_counter(outer: str = "iters") -> Tuple[ir.Program, Callable]:
    """The paper's migration-validation kernel: a persistent loop with
    internal per-thread state, a barrier per iteration, and a running
    global array update.  Loop counters + registers must survive
    migration for the final state to match a non-migrated run."""
    b = Builder("persistent_counter", [Ptr("State"), Scalar(outer)])
    i = b.global_id(0)
    carry = b.var(b.const(0.0, ir.F32), hint="carry")
    with b.loop(outer, hint="it") as it:
        prev = b.load("State", i)
        b.assign(carry, carry + prev * b.const(0.5, ir.F32)
                 + it.astype(ir.F32))
        b.store("State", i, prev + carry)
        b.barrier("iteration")
    prog = b.done()

    def oracle(args):
        iters = int(args[outer])
        st = np.asarray(args["State"], np.float32).copy()
        carry = np.zeros_like(st)
        for it in range(iters):
            prev = st.copy()
            carry = carry + prev * np.float32(0.5) + np.float32(it)
            st = prev + carry
        return {"State": st}

    return prog, oracle


# ---------------------------------------------------------------------------
def poly_eval(degree: int = 6) -> Tuple[ir.Program, Callable]:
    """Horner polynomial evaluation — the unrolling showcase: a constant
    trip-count loop whose per-iteration coefficient index (``degree - j``)
    is pure arithmetic on the loop variable.  Rolled, every trip pays
    CONST/SUB/loads; unrolled, the index folds to a constant per copy and
    DCE deletes the arithmetic, so the executed schedule shrinks on interp
    and pallas exactly as trace-unrolling always did on vectorized."""
    b = Builder("poly_eval", [Ptr("X"), Ptr("Coef"), Ptr("Out"),
                              Scalar("n")])
    i = b.global_id(0)
    x = b.var(b.const(0.0, ir.F32), hint="x")
    with b.when(i < b.param("n")):
        b.assign(x, b.load("X", i))
    acc = b.var(b.const(0.0, ir.F32), hint="pacc")
    with b.loop(degree + 1, hint="pj") as j:
        cidx = b.const(degree) - j       # folds once unrolled
        c = b.load("Coef", cidx)
        b.assign(acc, acc * x + c)       # fuses to FMA
    with b.when(i < b.param("n")):
        b.store("Out", i, acc)
    prog = b.done()

    def oracle(args):
        n = int(args["n"])
        X = np.asarray(args["X"], np.float32)
        C = np.asarray(args["Coef"], np.float32)
        out = np.array(args["Out"], np.float32)
        acc = np.zeros_like(X)
        for j in range(degree + 1):
            acc = acc * X + C[degree - j]
        out[:n] = acc[:n]
        return {"Out": out}

    return prog, oracle


# ---------------------------------------------------------------------------
def swizzle_copy(size: int = 128) -> Tuple[ir.Program, Callable]:
    """Power-of-two index swizzle — the strength-reduction showcase: the
    gather index is built from ``*8``, ``/4``, ``%16``, ``%size`` and a
    parity test, all by power-of-two constants, so at OPT_MAX every
    multiplicative op becomes a shift or mask.  Launch with
    ``grid * block == size`` (``size`` is baked in at build time so the
    wrap is a foldable constant, like a template parameter)."""
    assert size & (size - 1) == 0, "size must be a power of two"
    b = Builder("swizzle_copy", [Ptr("A"), Ptr("Out")])
    i = b.global_id(0)
    j = (i * b.const(8) + i / b.const(4) + i % b.const(16)) \
        % b.const(size)
    v = b.var(b.load("A", j), hint="sv")
    even = (i % b.const(2)).eq(b.const(0))
    with b.when(even):
        b.assign(v, v + b.load("A", i))
    b.store("Out", i, v)
    prog = b.done()

    def oracle(args):
        A = np.asarray(args["A"], np.float32)
        i = np.arange(size, dtype=np.int64)
        j = (i * 8 + i // 4 + i % 16) % size
        out = A[j].copy()
        out[i % 2 == 0] += A[i % 2 == 0]
        return {"Out": out.astype(np.float32)}

    return prog, oracle


# ---------------------------------------------------------------------------
def tap_filter(taps: int = 4, size: int = 64) -> Tuple[ir.Program, Callable]:
    """Two-phase tap filter across a barrier — the cross-segment
    value-numbering showcase.  Phase 1 recomputes ``i / 3`` (a DIV that
    :func:`~repro.core.passes.hoist_invariants` refuses to move) inside a
    constant-trip tap loop; phase 2, a separate engine segment after the
    barrier, derives the same quotient again.  At OPT_MAX the loop unrolls,
    the per-iteration ``j * 5`` offsets fold, and value numbering keeps one
    ``i / 3`` alive across the segment boundary instead of three
    re-executions.  Launch with ``grid * block == size``."""
    b = Builder("tap_filter", [Ptr("A"), Ptr("W"), Ptr("Tmp"), Ptr("Out")])
    i = b.global_id(0)
    acc = b.var(b.const(0.0, ir.F32), hint="tacc")
    with b.loop(taps, hint="tp") as j:
        base = i / b.const(3)            # non-hoistable duplicate, per trip
        idx = (base + j * b.const(5)) % b.const(size)
        b.assign(acc, acc + b.load("A", idx) * b.load("W", j))
    b.store("Tmp", i, acc)
    b.barrier("phase")
    base2 = i / b.const(3)               # merges with the in-loop quotient
    nb = (base2 + i) % b.const(size)
    b.store("Out", i, b.load("Tmp", nb) + acc)
    prog = b.done()

    def oracle(args):
        A = np.asarray(args["A"], np.float32)
        W = np.asarray(args["W"], np.float32)
        i = np.arange(size, dtype=np.int64)
        base = i // 3
        acc = np.zeros(size, np.float32)
        for j in range(taps):
            acc = acc + A[(base + j * 5) % size] * W[j]
        out = acc[(base + i) % size] + acc
        return {"Tmp": acc, "Out": out.astype(np.float32)}

    return prog, oracle


# ---------------------------------------------------------------------------
def dyn_matmul(tile_k: int = 8) -> Tuple[ir.Program, Callable]:
    """:func:`matmul_tiled` with the inner K-tile loop's trip count a
    *launch scalar* (``tk``) — the launch-time-specialization showcase.
    Statically the inner loop is dynamic-trip, so the generic pipeline can
    never unroll it; binding ``tk`` at launch makes it static and the
    whole phase-2 cascade (unroll → fold → strength-reduce → CSE) fires on
    the per-tile index math.  Launch with ``tk == tile_k`` (the shared
    staging buffer is sized at build time, like a template parameter)."""
    b = Builder("dyn_matmul",
                [Ptr("A"), Ptr("B"), Ptr("C"), Scalar("K"), Scalar("N"),
                 Scalar("ktiles"), Scalar("tk")],
                shared_size=tile_k)
    row = b.block_id()
    col = b.thread_id()
    n = b.param("N")
    k = b.param("K")
    acc = b.var(b.const(0.0, ir.F32), hint="acc")
    with b.loop("ktiles", hint="kt") as kt:
        t = b.thread_id()
        with b.when(t < b.const(tile_k)):
            a_idx = row * k + kt * b.const(tile_k) + t
            b.store_shared(t, b.load("A", a_idx))
        b.barrier("tile-staged")
        with b.loop("tk", hint="kk") as kk:  # dynamic trip: scalar param
            # swizzled tile order (odd-stride permutation of 0..tile_k-1,
            # the classic bank-conflict dodge): uniform-on-kk index math
            # that the rolled loop pays every trip and an unrolled copy
            # folds to one constant
            kidx = (kk * b.const(5) + b.const(2)) % b.const(tile_k)
            a_val = b.load_shared(kidx)
            b_idx = (kt * b.const(tile_k) + kidx) * n + col
            b.assign(acc, b.fma(a_val, b.load("B", b_idx), acc))
        b.barrier("tile-consumed")
    b.store("C", row * n + col, acc)
    prog = b.done()

    def oracle(args):
        K, N = int(args["K"]), int(args["N"])
        A = np.asarray(args["A"], np.float32)
        B = np.asarray(args["B"], np.float32)
        M = A.size // K
        C = (A.reshape(M, K) @ B.reshape(K, N)).reshape(-1)
        return {"C": C.astype(np.float32)}

    return prog, oracle


# ---------------------------------------------------------------------------
def dyn_fir(size: int = 64) -> Tuple[ir.Program, Callable]:
    """FIR filter with a *dynamic* tap count (``taps``) and a
    loop-invariant gain load — the second specialization showcase, and the
    alias-aware load-hoist showcase in one kernel.  ``W[0]`` is re-loaded
    every trip; the only stores go to ``Out`` (a distinct buffer, so the
    alias analysis clears the hoist) — but hoisting needs a static trip
    count ≥ 1, which only launch-time specialization can provide here.
    Small bound tap counts additionally unroll, folding the per-tap
    ``j*3`` offsets.  Launch with ``grid * block == size``."""
    assert size & (size - 1) == 0, "size must be a power of two"
    b = Builder("dyn_fir", [Ptr("A"), Ptr("W"), Ptr("Out"), Scalar("taps")])
    i = b.global_id(0)
    acc = b.var(b.const(0.0, ir.F32), hint="facc")
    with b.loop("taps", hint="fj") as j:
        g = b.load("W", b.const(0))          # invariant: hoists once static
        # swizzled tap offset: a chain of uniform-on-j arithmetic that the
        # rolled loop re-executes every trip but collapses to one constant
        # per unrolled copy once the trip count is bound
        off = ((j * b.const(5) + b.const(2)) % b.const(8)) * b.const(4) \
            + j % b.const(4)
        idx = (i + off) % b.const(size)
        b.assign(acc, acc + b.load("A", idx) * (b.load("W", j) + g))
    b.store("Out", i, acc)
    prog = b.done()

    def oracle(args):
        taps = int(args["taps"])
        A = np.asarray(args["A"], np.float32)
        W = np.asarray(args["W"], np.float32)
        i = np.arange(size, dtype=np.int64)
        acc = np.zeros(size, np.float32)
        for j in range(taps):
            off = ((j * 5 + 2) % 8) * 4 + (j % 4)
            acc = acc + A[(i + off) % size] * (W[j] + W[0])
        out = np.array(args["Out"], np.float32)
        out[:size] = acc
        return {"Out": out}

    return prog, oracle


# ---------------------------------------------------------------------------
def decode_gemv(tile_k: int = 8) -> Tuple[ir.Program, Callable]:
    """The serving-tier workload: one decode step's matvec,
    ``Out = relu(W @ X + R)`` (``R`` the residual), with ``X`` staged
    through shared memory in ``tile_k`` chunks and a barrier per chunk.
    One output row per *thread* (``grid*block`` rows), dynamic ``ktiles``
    trip — so a single token's worth of work is many short segments, the
    shape the fair-share scheduler preempts between, and the
    specialization policy can bind the tile count at launch."""
    b = Builder("decode_gemv",
                [Ptr("W"), Ptr("X"), Ptr("R"), Ptr("Out"), Scalar("K"),
                 Scalar("ktiles")], shared_size=tile_k)
    row = b.global_id(0)
    t = b.thread_id()
    k = b.param("K")
    acc = b.var(b.const(0.0, ir.F32), hint="acc")
    with b.loop("ktiles", hint="kt") as kt:
        with b.when(t < b.const(tile_k)):
            b.store_shared(t, b.load("X", kt * b.const(tile_k) + t))
        b.barrier("x-staged")
        with b.loop(tile_k, hint="kk") as kk:
            idx = kt * b.const(tile_k) + kk
            b.assign(acc, b.fma(b.load("W", row * k + idx),
                                b.load_shared(kk), acc))
        b.barrier("x-consumed")
    val = acc + b.load("R", row)
    b.store("Out", row, b.maximum(val, b.const(0.0, ir.F32)))
    prog = b.done()

    def oracle(args):
        K = int(args["K"])
        used = int(args["ktiles"]) * tile_k
        W = np.asarray(args["W"], np.float32)
        X = np.asarray(args["X"], np.float32)
        R = np.asarray(args["R"], np.float32)
        M = W.size // K
        Wm = W.reshape(M, K)[:, :used]
        out = np.maximum(Wm @ X[:used] + R[:M], 0)
        res = np.array(args["Out"], np.float32)
        res[:M] = out
        return {"Out": res}

    return prog, oracle


# ---------------------------------------------------------------------------
def dot_product() -> Tuple[ir.Program, Callable]:
    b = Builder("dot_product", [Ptr("A"), Ptr("B"), Ptr("Out"), Scalar("n")])
    i = b.global_id(0)
    x = b.var(b.const(0.0, ir.F32), hint="x")
    with b.when(i < b.param("n")):
        b.assign(x, b.load("A", i) * b.load("B", i))
    s = b.reduce_add(x)
    with b.when(b.thread_id().eq(b.const(0))):
        b.atomic_add("Out", b.const(0), s)
    prog = b.done()

    def oracle(args):
        n = int(args["n"])
        r = (np.asarray(args["A"], np.float32)[:n]
             * np.asarray(args["B"], np.float32)[:n]).sum()
        out = np.array(args["Out"], np.float32)
        out[0] += r
        return {"Out": out}

    return prog, oracle


# ---------------------------------------------------------------------------
# Canonical example launches — one validated (grid, block, args, outputs)
# geometry per suite kernel, shared by the portability benchmark, the
# driver-API demo/tests, and anything else that wants to run the whole
# suite without re-deriving per-kernel argument shapes.
# ---------------------------------------------------------------------------

#: name -> (grid, block, make_args(rng) -> host args dict, output buffers)
EXAMPLES: Dict[str, Tuple[int, int, Callable, Tuple[str, ...]]] = {
    "vadd": (4, 32, lambda rng: {
        "A": rng.normal(size=128).astype(np.float32),
        "B": rng.normal(size=128).astype(np.float32),
        "C": np.zeros(128, np.float32), "n": 128}, ("C",)),
    "saxpy": (4, 32, lambda rng: {
        "X": rng.normal(size=128).astype(np.float32),
        "Y": rng.normal(size=128).astype(np.float32),
        "n": 128, "a": 1.5}, ("Y",)),
    "matmul_tiled": (8, 16, lambda rng: {
        "A": rng.normal(size=(8, 16)).astype(np.float32).reshape(-1),
        "B": rng.normal(size=(16, 16)).astype(np.float32).reshape(-1),
        "C": np.zeros(128, np.float32), "K": 16, "N": 16, "ktiles": 2},
        ("C",)),
    "reduction": (4, 32, lambda rng: {
        "A": rng.normal(size=128).astype(np.float32),
        "Out": np.zeros(1, np.float32), "n": 128, "log2t": 5}, ("Out",)),
    "inclusive_scan": (4, 32, lambda rng: {
        "A": rng.normal(size=128).astype(np.float32),
        "Out": np.zeros(128, np.float32),
        "BlockSums": np.zeros(4, np.float32), "n": 128},
        ("Out", "BlockSums")),
    "bitcount_vote": (4, 32, lambda rng: {
        "A": rng.normal(size=128).astype(np.float32),
        "Out": np.zeros(4, np.float32), "n": 128, "thresh": 0.0}, ("Out",)),
    "montecarlo_pi": (2, 32, lambda rng: {
        "Count": np.zeros(1, np.float32)}, ("Count",)),
    "nn_layer": (4, 16, lambda rng: {
        "W": rng.normal(size=(4, 32)).astype(np.float32).reshape(-1),
        "X": rng.normal(size=32).astype(np.float32),
        "Bias": rng.normal(size=4).astype(np.float32),
        "Out": np.zeros(4, np.float32), "K": 32, "kchunks": 2}, ("Out",)),
    "stencil_1d": (2, 32, lambda rng: {
        "A": rng.normal(size=64).astype(np.float32),
        "Out": np.zeros(64, np.float32), "n": 64}, ("Out",)),
    "persistent_counter": (2, 32, lambda rng: {
        "State": rng.normal(size=64).astype(np.float32), "iters": 4},
        ("State",)),
    "dot_product": (4, 32, lambda rng: {
        "A": rng.normal(size=128).astype(np.float32),
        "B": rng.normal(size=128).astype(np.float32),
        "Out": np.zeros(1, np.float32), "n": 128}, ("Out",)),
    "poly_eval": (4, 32, lambda rng: {
        "X": rng.normal(size=128).astype(np.float32),
        "Coef": rng.normal(size=7).astype(np.float32),
        "Out": np.zeros(128, np.float32), "n": 128}, ("Out",)),
    "swizzle_copy": (4, 32, lambda rng: {
        "A": rng.normal(size=128).astype(np.float32),
        "Out": np.zeros(128, np.float32)}, ("Out",)),
    "tap_filter": (2, 32, lambda rng: {
        "A": rng.normal(size=64).astype(np.float32),
        "W": rng.normal(size=4).astype(np.float32),
        "Tmp": np.zeros(64, np.float32),
        "Out": np.zeros(64, np.float32)}, ("Out",)),
    "dyn_matmul": (4, 16, lambda rng: {
        "A": rng.normal(size=(4, 32)).astype(np.float32).reshape(-1),
        "B": rng.normal(size=(32, 16)).astype(np.float32).reshape(-1),
        "C": np.zeros(64, np.float32), "K": 32, "N": 16, "ktiles": 4,
        "tk": 8}, ("C",)),
    "dyn_fir": (2, 32, lambda rng: {
        "A": rng.normal(size=64).astype(np.float32),
        "W": rng.normal(size=8).astype(np.float32),
        "Out": np.zeros(64, np.float32), "taps": 4}, ("Out",)),
    "decode_gemv": (4, 16, lambda rng: {
        "W": rng.normal(size=(64, 32)).astype(np.float32).reshape(-1),
        "X": rng.normal(size=32).astype(np.float32),
        "R": rng.normal(size=64).astype(np.float32),
        "Out": np.zeros(64, np.float32), "K": 32, "ktiles": 4}, ("Out",)),
}


def example_launch(name: str, rng=None
                   ) -> Tuple["ir.Program", Callable, int, int,
                              Dict[str, object], Tuple[str, ...]]:
    """Build the canonical example launch for kernel ``name``:
    ``(program, oracle, grid, block, host_args, output_buffer_names)``.

    Looks through the suite first, then every registered namespace (the
    model zoo registers under ``"zoo"``) — so roofline/benchmark/driver
    tooling runs zoo kernels with the same one-liner it uses for the
    suite."""
    if rng is None:
        rng = np.random.default_rng(42)
    kernels, examples = _registry_for(name)
    grid, block, mk, outs = examples[name]
    prog, oracle = kernels[name]()
    return prog, oracle, grid, block, mk(rng), outs


# ---------------------------------------------------------------------------
# Namespaced kernel registries.  The conformance harnesses pin their
# parametrization to ``SUITE``/``EXAMPLES`` at collection time (and
# test_passes asserts exact coverage of SUITE), so external workload
# packages must NOT mutate those dicts — they register under their own
# namespace here and the generic lookups below search all of them.
# ---------------------------------------------------------------------------

#: namespace -> (kernels dict, examples dict); "suite" is the built-in tier
REGISTRIES: Dict[str, Tuple[Dict[str, Callable], Dict[str, tuple]]] = {}


def register_kernel(name: str, builder: Callable, example=None,
                    registry: str = "zoo") -> None:
    """Register kernel ``builder`` (``() -> (Program, oracle)``) under a
    namespace, with an optional EXAMPLES-style canonical launch
    ``(grid, block, make_args(rng), output_names)``.  Idempotent per
    (registry, name); re-registering replaces the entry."""
    if registry == "suite":
        raise ValueError("the built-in suite is closed — register under "
                         "a new namespace (e.g. 'zoo')")
    kernels, examples = REGISTRIES.setdefault(registry, ({}, {}))
    kernels[name] = builder
    if example is not None:
        examples[name] = tuple(example)


def _registry_for(name: str) -> Tuple[Dict[str, Callable], Dict[str, tuple]]:
    if name in SUITE:
        return SUITE, EXAMPLES
    for kernels, examples in REGISTRIES.values():
        if name in kernels:
            return kernels, examples
    raise KeyError(f"unknown kernel {name!r} (suite: {sorted(SUITE)}; "
                   f"registries: {sorted(REGISTRIES)})")


def lookup(name: str) -> Callable:
    """The builder for ``name``, searching the suite then all registries."""
    return _registry_for(name)[0][name]


def registered_examples(registry: str) -> Dict[str, tuple]:
    """The canonical-launch table of one namespace (``"suite"`` included)."""
    if registry == "suite":
        return EXAMPLES
    return REGISTRIES[registry][1]


SUITE: Dict[str, Callable] = {
    "vadd": vadd,
    "saxpy": saxpy,
    "matmul_tiled": matmul_tiled,
    "reduction": reduction,
    "inclusive_scan": inclusive_scan,
    "bitcount_vote": bitcount_vote,
    "montecarlo_pi": montecarlo_pi,
    "nn_layer": nn_layer,
    "stencil_1d": stencil_1d,
    "persistent_counter": persistent_counter,
    "dot_product": dot_product,
    "poly_eval": poly_eval,
    "swizzle_copy": swizzle_copy,
    "tap_filter": tap_filter,
    "dyn_matmul": dyn_matmul,
    "dyn_fir": dyn_fir,
    "decode_gemv": decode_gemv,
}
