# The paper's primary contribution: hetIR (portable GPU kernel IR), the
# multi-backend runtime (interp / vectorized / pallas), barrier-anchored
# segmentation, device-neutral snapshots, and cross-backend live migration.
from . import hetir
from .backends import BACKENDS, get_backend
from .engine import Engine
from .runtime import HetSession, migrate
from .state import Snapshot

__all__ = ["hetir", "BACKENDS", "get_backend", "Engine", "HetSession",
           "migrate", "Snapshot"]
