"""The paper's primary contribution: hetIR (portable GPU kernel IR), the
multi-backend runtime (interp / vectorized / pallas), barrier-anchored
segmentation, device-neutral snapshots, cross-backend live migration, and
the persistent cost-aware translation cache (see docs/ARCHITECTURE.md for
the paper-section → module map)."""
from . import alias, hetir
from .backends import BACKENDS, get_backend
from .cache import (DiskStore, SharedStore, TranslationCache, global_cache,
                    register_reviver)
from .engine import Engine
from .fleet import (FAULT_POINTS, FaultInjector, FleetCoordinator,
                    FleetError, FleetTicket, FleetTimeout,
                    FleetWorkerError, RetryQueue, WorkerLost)
from .passes import (DEFAULT_OPT_LEVEL, OPT_MAX, PipelineStats,
                     SpecializationPolicy, get_optimized, get_specialized,
                     optimize)
from .pool import BufferPool
from .runtime import (CopyRecord, DeviceBuffer, Event, Function,
                      HetSession, LaunchRecord, Module, ParamInfo, Stream,
                      TraceRing, migrate)
from .serving import QuotaExceeded, ServeTicket, ServingFrontEnd
from .state import Snapshot

__all__ = ["alias", "hetir", "BACKENDS", "get_backend", "Engine",
           "HetSession", "migrate", "Snapshot", "TranslationCache",
           "Module", "Function", "DeviceBuffer", "Stream", "Event",
           "LaunchRecord", "ParamInfo", "CopyRecord", "TraceRing",
           "BufferPool", "ServingFrontEnd", "ServeTicket", "QuotaExceeded",
           "FleetCoordinator", "FleetTicket", "RetryQueue", "FaultInjector",
           "FAULT_POINTS", "FleetError", "FleetTimeout", "FleetWorkerError",
           "WorkerLost",
           "DiskStore", "SharedStore", "global_cache", "register_reviver",
           "optimize",
           "get_optimized", "get_specialized", "SpecializationPolicy",
           "PipelineStats", "OPT_MAX", "DEFAULT_OPT_LEVEL"]
