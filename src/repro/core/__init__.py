# The paper's primary contribution: hetIR (portable GPU kernel IR), the
# multi-backend runtime (interp / vectorized / pallas), barrier-anchored
# segmentation, device-neutral snapshots, and cross-backend live migration.
from . import hetir
from .backends import BACKENDS, get_backend
from .cache import TranslationCache, global_cache
from .engine import Engine
from .passes import (DEFAULT_OPT_LEVEL, OPT_MAX, PipelineStats,
                     get_optimized, optimize)
from .runtime import HetSession, migrate
from .state import Snapshot

__all__ = ["hetir", "BACKENDS", "get_backend", "Engine", "HetSession",
           "migrate", "Snapshot", "TranslationCache", "global_cache",
           "optimize", "get_optimized", "PipelineStats", "OPT_MAX",
           "DEFAULT_OPT_LEVEL"]
