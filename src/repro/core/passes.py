"""hetIR optimization-pass pipeline (paper §4.2, Dynamic Translation).

The paper's runtime "dynamically translates this IR to the target GPU's
native code" and "caches these translated kernels" — before translation it
is free to canonicalize the IR, and because every backend consumes the same
optimized body, one mid-level pipeline pays off on *all* targets at once
(and shrinks the migration payload: dead registers never enter the
snapshot's live set, the paper's §8 "only saving live registers"
optimization).

Passes (all semantics-preserving, verified bit-identical at every opt level
by ``tests/test_passes.py``):

* **constant folding** — ALU/compare/select ops over known constants become
  ``CONST``; evaluation uses the exact numpy dtype semantics of the
  interpreter backend so folded values are bit-identical to runtime values.
  Transcendentals (``SQRT``/``EXP``) are never folded: their libm results
  may differ between numpy (fold time) and XLA (run time) by an ULP.
* **predicate simplification** — ``@PRED(const true)`` regions are spliced
  inline, ``@PRED(const false)`` regions are dropped, empty regions and
  redundant same-condition nests are removed.
* **barrier-aware invariant hoisting** — pure register ops whose inputs are
  loop-invariant move out of ``LOOP`` bodies (across BARRIERs, which only
  order *memory*; register ops may legally cross them — memory ops and
  collectives never move).
* **uniform duplicate merging** — dominator-scoped value numbering merges
  re-emitted constants / param loads / identity reads (the Builder emits a
  fresh ``CONST`` per mention).
* **FMA fusion** — single-use ``MUL`` feeding an ``ADD`` in the same region
  fuses to ``FMA``.  All backends evaluate ``FMA`` as unfused ``a*b + c``,
  so fusion is bit-exact.
* **dead-code elimination** — pure ops whose dests are never read, and the
  empty control regions they leave behind, are deleted.

Phase-2 passes (level 3, the default ``OPT_MAX``; see ``docs/PASSES.md``
for legality conditions and worked examples):

* **loop unrolling** — barrier-free loops with a *static* trip count ≤
  ``HETGPU_UNROLL_MAX`` are flattened; each iteration's copy binds the loop
  variable to a fresh single-def constant, so folding/CSE collapse the
  per-iteration index arithmetic that a counted loop re-executes every
  trip.  The vectorized backend already got this for free by tracing;
  doing it in the IR hands the same win to interp and pallas.
* **strength reduction** — integer ``MUL``/``DIV``/``MOD`` by power-of-two
  constants become ``SHL``/``SHR``/``AND`` (exact: hetIR integer division
  is floor division, so an arithmetic shift right *is* the division), and
  f32 ``DIV`` by a power of two becomes ``MUL`` by its exactly
  representable reciprocal.  The rewritten forms are also hoistable, which
  plain ``DIV``/``MOD`` never are.
* **cross-segment value numbering** — extends duplicate merging across the
  boundaries that create engine segments: a value computed inside a loop
  whose static trip count is ≥ 1 stays available *after* the loop (its
  register provably holds the last-iteration value, which equals what the
  duplicate would recompute), so re-derived quantities are not re-executed
  in later segments.

Phase-3 additions (this level-3 pipeline; see ``docs/PASSES.md``):

* **alias-aware invariant load motion** (:func:`hoist_invariant_loads`) —
  loads whose index is loop-invariant move out of loops with static trip
  count ≥ 1, unless a store in the loop *may alias* them under the affine
  may-alias analysis of :mod:`~repro.core.alias` (distinct buffers never
  alias; same-buffer accesses compare their affine index forms).

* **launch-time specialization** — the paper's runtime translates IR at
  *launch*, when every uniform scalar argument is known, so the engine may
  re-run this pipeline with those scalars bound as constants
  (:func:`bind_launch_scalars` / :func:`get_specialized`): dynamic trip
  counts become static (``unroll_loops`` and the static-trip legality
  gates fire), and size-dependent index math folds away.  A
  :class:`SpecializationPolicy` (``HETGPU_SPECIALIZE``, budgeted by
  ``HETGPU_SPECIALIZE_BUDGET``) gates which launches get a variant;
  everything else falls back to the shared generic translation.  The
  bound-scalar vector (``SpecKey``) joins every translation-cache key and
  rides in snapshots, so a migrated specialized kernel restores against
  the identical specialized body on the destination backend.

Entry point: :func:`optimize`, wired into :class:`~repro.core.engine.Engine`
so every backend translates the optimized body; per-pass statistics are
returned in :class:`PipelineStats` and surfaced through
``HetSession.stats`` and ``benchmarks/bench_translation.py``.  The pass
set itself is fingerprinted (:func:`pipeline_fingerprint`) into the
persistent cache's runtime tag, so changing or re-ordering passes
invalidates previously persisted translations instead of silently
restoring artifacts optimized by an older pipeline.
"""
from __future__ import annotations

import hashlib
import os
import re
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from . import hetir as ir
from .alias import (GLOBAL_SPACE, SHARED_BUF, SHARED_SPACE, AffineIndex,
                    affine_env, body_mem_accesses, index_form,
                    injective_step, may_alias)
from .segments import specializable_counts, static_trip_count

# --------------------------------------------------------------------------
# Opcode classification
# --------------------------------------------------------------------------

# ops with observable effects beyond their dest register — never removed,
# never moved
SIDE_EFFECT_OPS = {ir.ST_GLOBAL, ir.ST_SHARED, ir.ATOMIC_ADD}

_IDENTITY_OPS = {ir.GET_GLOBAL_ID, ir.GET_BLOCK_ID, ir.GET_THREAD_ID,
                 ir.GET_BLOCK_DIM, ir.GET_NUM_BLOCKS}

# pure register ops that may be hoisted across barriers (barriers order
# memory, not registers) and out of predicate regions (writing a dead lane's
# register early is unobservable — only stores are masked).  DIV/MOD are
# excluded so hoisting can never introduce a divide-by-zero that the source
# program guarded with a predicate or zero-trip loop.
HOISTABLE_OPS = (_IDENTITY_OPS
                 | {ir.CONST, ir.LD_PARAM, ir.MOV, ir.CVT, ir.SELECT, ir.FMA}
                 | ir.ALU_UNARY
                 | (ir.ALU_BINARY - {ir.DIV, ir.MOD})
                 | ir.CMP_OPS)

# value-numberable ops for duplicate merging: pure, thread-deterministic,
# no memory or active-mask dependence
_CSE_OPS = (_IDENTITY_OPS
            | {ir.CONST, ir.LD_PARAM, ir.CVT, ir.SELECT, ir.FMA}
            | ir.ALU_UNARY | ir.ALU_BINARY | ir.CMP_OPS) - {ir.MOV}


def _is_pure(opcode: str) -> bool:
    return opcode not in SIDE_EFFECT_OPS


# --------------------------------------------------------------------------
# Statistics
# --------------------------------------------------------------------------


@dataclass
class PipelineStats:
    """Per-pass change counters (and wall time) for one :func:`optimize`
    run."""

    level: int = 0
    ops_before: int = 0
    ops_after: int = 0
    iterations: int = 0
    per_pass: Dict[str, int] = field(default_factory=dict)
    per_pass_ms: Dict[str, float] = field(default_factory=dict)
    #: bound uniform scalars for a specialized variant; () = generic
    spec_key: Tuple = ()

    def record(self, pass_name: str, n: int, ms: float = 0.0) -> None:
        self.per_pass[pass_name] = self.per_pass.get(pass_name, 0) + n
        self.per_pass_ms[pass_name] = \
            self.per_pass_ms.get(pass_name, 0.0) + ms

    @property
    def ops_removed(self) -> int:
        return self.ops_before - self.ops_after

    def as_dict(self) -> Dict[str, object]:
        return {"level": self.level, "ops_before": self.ops_before,
                "ops_after": self.ops_after, "ops_removed": self.ops_removed,
                "iterations": self.iterations, "per_pass": dict(self.per_pass),
                "per_pass_ms": {k: round(v, 3)
                                for k, v in self.per_pass_ms.items()},
                "spec_key": list(self.spec_key)}


# --------------------------------------------------------------------------
# Constant folding
# --------------------------------------------------------------------------

def _fold_div(a, b):
    if isinstance(a, (np.floating, float)):
        return a / b
    return a // b


def _fold_bitop(fi, fb):
    def f(a, b):
        if isinstance(a, (bool, np.bool_)):
            return fb(a, b)
        return fi(a, b)
    return f


# exact-arithmetic folding tables, matching the interpreter backend's scalar
# semantics op for op (and jnp's IEEE semantics for +,-,*,/ on f32)
_FOLD_BIN = {
    ir.ADD: lambda a, b: a + b,
    ir.SUB: lambda a, b: a - b,
    ir.MUL: lambda a, b: a * b,
    ir.DIV: _fold_div,
    ir.MOD: lambda a, b: a % b,
    ir.MIN: min,
    ir.MAX: max,
    ir.AND: _fold_bitop(lambda a, b: a & b, lambda a, b: a and b),
    ir.OR: _fold_bitop(lambda a, b: a | b, lambda a, b: a or b),
    ir.XOR: _fold_bitop(lambda a, b: a ^ b,
                        lambda a, b: bool(a) != bool(b)),
    ir.SHL: lambda a, b: a << b,
    ir.SHR: lambda a, b: a >> b,
    ir.LT: lambda a, b: a < b,
    ir.LE: lambda a, b: a <= b,
    ir.GT: lambda a, b: a > b,
    ir.GE: lambda a, b: a >= b,
    ir.EQ: lambda a, b: a == b,
    ir.NE: lambda a, b: a != b,
}

_FOLD_UN = {
    ir.NEG: lambda a: -a,
    ir.ABS: abs,
    ir.NOT: lambda a: (not a) if isinstance(a, (bool, np.bool_)) else ~a,
    ir.MOV: lambda a: a,
}


def fold_constants(body: List[ir.Stmt], prog: ir.Program
                   ) -> Tuple[List[ir.Stmt], int]:
    """Replace ops whose inputs are all known constants with ``CONST``.
    Constant visibility is scoped to the structured region tree, so a value
    defined under a predicate or loop never folds into code outside it."""
    defs = ir.reg_def_counts(body)
    consts: Dict[str, object] = {}
    n = [0]

    def try_fold(op: ir.Op) -> ir.Op:
        d = op.dest
        if d is None or defs.get(d.name, 0) != 1:
            return op
        if op.opcode == ir.CONST:
            consts[d.name] = ir.np_dtype(d.dtype).type(op.args[0])
            return op
        foldable = (op.opcode in _FOLD_BIN or op.opcode in _FOLD_UN
                    or op.opcode in (ir.CVT, ir.SELECT, ir.FMA))
        if not foldable:
            return op
        vals = []
        for a in op.args:
            if isinstance(a, ir.Reg):
                if a.name not in consts:
                    return op
                vals.append(consts[a.name])
            else:
                vals.append(a)
        try:
            with np.errstate(all="ignore"):
                if op.opcode in (ir.SHL, ir.SHR) and not (
                        0 <= int(vals[1]) < 32):
                    return op  # out-of-width shifts differ numpy vs XLA
                if (op.opcode in (ir.DIV, ir.MOD)
                        and d.dtype in (ir.I32, ir.U32)
                        and int(vals[1]) == 0):
                    # integer x/0 is 0 under numpy but platform-defined
                    # under XLA: folding would change the vectorized
                    # backend's O0 result
                    return op
                if op.opcode in (ir.MIN, ir.MAX) and any(
                        isinstance(v, (np.floating, float))
                        and np.isnan(v) for v in vals):
                    return op  # NaN min/max differs across backends
                if op.opcode in _FOLD_BIN:
                    v = _FOLD_BIN[op.opcode](*vals)
                elif op.opcode in _FOLD_UN:
                    v = _FOLD_UN[op.opcode](*vals)
                elif op.opcode == ir.CVT:
                    v = vals[0]
                    # float -> int of an out-of-range/NaN value is
                    # backend-dependent (numpy vs XLA): never fold it
                    if isinstance(v, (np.floating, float)) \
                            and d.dtype in (ir.I32, ir.U32):
                        info = np.iinfo(ir.np_dtype(d.dtype))
                        if not (np.isfinite(v)
                                and info.min <= v <= info.max):
                            return op
                elif op.opcode == ir.SELECT:
                    v = vals[1] if bool(vals[0]) else vals[2]
                else:  # FMA — two exact f32 ops, same as every backend
                    v = vals[0] * vals[1] + vals[2]
            v = ir.np_dtype(d.dtype).type(v)
        except (ZeroDivisionError, OverflowError, TypeError, ValueError):
            return op
        consts[d.name] = v
        n[0] += 1
        return ir.Op(ir.CONST, d, (v.item(),))

    def walk(stmts: Sequence[ir.Stmt]) -> List[ir.Stmt]:
        out: List[ir.Stmt] = []
        for s in stmts:
            if isinstance(s, ir.Op):
                out.append(try_fold(s))
            elif isinstance(s, ir.Pred):
                out.append(ir.Pred(s.cond, scoped(s.body)))
            elif isinstance(s, ir.Loop):
                out.append(ir.Loop(s.var, s.count, scoped(s.body)))
            else:
                out.append(s)
        return out

    def scoped(stmts: Sequence[ir.Stmt]) -> List[ir.Stmt]:
        outer = set(consts)
        out = walk(stmts)
        for k in list(consts):
            if k not in outer:
                del consts[k]
        return out

    return walk(body), n[0]


# --------------------------------------------------------------------------
# Predicate simplification
# --------------------------------------------------------------------------


def simplify_predicates(body: List[ir.Stmt], prog: ir.Program
                        ) -> Tuple[List[ir.Stmt], int]:
    """Splice always-true @PRED regions, drop always-false and empty ones,
    flatten redundant same-condition nests, and remove dead zero-trip
    constant loops.  Constant-condition visibility is scoped to the region
    tree: a CONST defined under some other predicate is only conditionally
    written at level 0 (the interp backend masks register writes), so it
    must never simplify a predicate outside its region."""
    defs = ir.reg_def_counts(body)
    uses = ir.reg_use_counts(body)
    const_bools: Dict[str, bool] = {}
    n = [0]

    def walk(stmts: Sequence[ir.Stmt]) -> List[ir.Stmt]:
        out: List[ir.Stmt] = []
        for s in stmts:
            if isinstance(s, ir.Op):
                if (s.opcode == ir.CONST and s.dest is not None
                        and s.dest.dtype == ir.BOOL
                        and defs.get(s.dest.name, 0) == 1):
                    const_bools[s.dest.name] = bool(s.args[0])
                out.append(s)
                continue
            if isinstance(s, ir.Pred):
                inner = scoped(s.body)
                if not inner:
                    n[0] += 1
                elif s.cond.name in const_bools:
                    n[0] += 1
                    if const_bools[s.cond.name]:
                        # uniform-true predicate: the active set inside
                        # equals the enclosing one, so splicing is exact
                        out.extend(inner)
                elif (len(inner) == 1 and isinstance(inner[0], ir.Pred)
                        and inner[0].cond.name == s.cond.name):
                    n[0] += 1
                    out.append(ir.Pred(s.cond, inner[0].body))
                else:
                    out.append(ir.Pred(s.cond, inner))
            elif isinstance(s, ir.Loop):
                inner = scoped(s.body)
                dead = (isinstance(s.count, int) and s.count <= 0) \
                    or not inner
                if dead and uses.get(s.var.name, 0) == 0:
                    n[0] += 1
                else:
                    out.append(ir.Loop(s.var, s.count, inner))
            else:
                out.append(s)
        return out

    def scoped(stmts: Sequence[ir.Stmt]) -> List[ir.Stmt]:
        outer = set(const_bools)
        out = walk(stmts)
        for k in list(const_bools):
            if k not in outer:
                del const_bools[k]
        return out

    return walk(body), n[0]


# --------------------------------------------------------------------------
# Barrier-aware loop-invariant hoisting
# --------------------------------------------------------------------------


def _defined_names(stmts: Sequence[ir.Stmt]) -> set:
    """Register names defined anywhere in ``stmts`` (op dests and loop
    vars, recursive) — the "inside the loop" set both hoisting passes
    test invariance against."""
    return set(ir.reg_def_counts(stmts))


def hoist_invariants(body: List[ir.Stmt], prog: ir.Program
                     ) -> Tuple[List[ir.Stmt], int]:
    """Move pure ops whose inputs are defined entirely outside a loop to
    just before that loop.  Hoisting crosses barrier segment boundaries —
    sound for register-only ops, since barriers synchronize memory, not
    registers; the op computes the same value every iteration either way.
    Ops under a @PRED never move: the interp backend masks register writes
    per-thread, so unconditionalizing a write is observable there."""
    defs = ir.reg_def_counts(body)
    n = [0]

    def extract(stmts: Sequence[ir.Stmt], inside: set,
                hoisted: List[ir.Stmt]) -> List[ir.Stmt]:
        out: List[ir.Stmt] = []
        for s in stmts:
            if isinstance(s, ir.Op):
                if (s.opcode in HOISTABLE_OPS and s.dest is not None
                        and defs.get(s.dest.name, 0) == 1
                        and all(r.name not in inside
                                for r in s.arg_regs())):
                    hoisted.append(s)
                    inside.discard(s.dest.name)
                    n[0] += 1
                else:
                    out.append(s)
            elif isinstance(s, ir.Loop):
                # hoisting through a nested loop is fine (its body runs
                # unconditionally for all threads); through a @PRED is not
                out.append(ir.Loop(s.var, s.count,
                                   extract(s.body, inside, hoisted)))
            else:
                out.append(s)
        return out

    def process(stmts: Sequence[ir.Stmt]) -> List[ir.Stmt]:
        out: List[ir.Stmt] = []
        for s in stmts:
            if isinstance(s, ir.Loop):
                inner = process(s.body)
                inside = _defined_names(inner) | {s.var.name}
                while True:
                    hoisted: List[ir.Stmt] = []
                    inner = extract(inner, inside, hoisted)
                    if not hoisted:
                        break
                    out.extend(hoisted)
                out.append(ir.Loop(s.var, s.count, inner))
            elif isinstance(s, ir.Pred):
                out.append(ir.Pred(s.cond, process(s.body)))
            else:
                out.append(s)
        return out

    return process(body), n[0]


# --------------------------------------------------------------------------
# Alias-aware loop-invariant load motion
# --------------------------------------------------------------------------


def hoist_invariant_loads(body: List[ir.Stmt], prog: ir.Program
                          ) -> Tuple[List[ir.Stmt], int]:
    """Move provably loop-invariant ``LD_GLOBAL``/``LD_SHARED`` ops out of
    loops — the loop-invariant *memory* motion :func:`hoist_invariants`
    cannot do, because loads observe stores.

    Legality (all must hold, per load):

    * the loop's **static trip count is ≥ 1** — hoisting out of a
      possibly-zero-trip loop would execute a load (and its index) that
      never ran, exactly the hazard that keeps ``DIV``/``MOD`` out of
      :data:`HOISTABLE_OPS`.  Launch-time specialization is what makes
      dynamic-trip loops eligible: binding the count makes it static;
    * the load sits at the **loop body's top level** (not under a
      ``@PRED`` — a masked register write must stay masked), its dest is
      single-def, and its index registers are defined outside the loop;
    * **no store in the loop may alias it** (:mod:`~repro.core.alias`):
      stores to *other* buffers never block; a same-buffer
      ``ST_GLOBAL``/``ST_SHARED``/``ATOMIC_ADD`` blocks unless the affine
      index forms are provably disjoint across *all* thread pairs
      (identical base/coefficient terms, constant delta indivisible by
      the coefficients' power-of-two gcd).  Bases defined inside the loop
      (including the loop variable) are unstable and force a conservative
      block.

    Hoisting then crosses any barrier inside the loop soundly: with no
    may-aliasing store in the body, no thread of any block writes the
    loaded address between iterations, so the value the pre-loop load
    reads is the value every iteration would have read."""
    defs = ir.reg_def_counts(body)
    aff = affine_env(body)
    n = [0]

    def load_site(op: ir.Op):
        if op.opcode == ir.LD_GLOBAL:
            return GLOBAL_SPACE, op.args[0], op.args[1]
        if op.opcode == ir.LD_SHARED:
            return SHARED_SPACE, SHARED_BUF, op.args[0]
        return None

    def process(stmts: Sequence[ir.Stmt]) -> List[ir.Stmt]:
        out: List[ir.Stmt] = []
        for s in stmts:
            if isinstance(s, ir.Loop):
                inner = process(s.body)
                trip = static_trip_count(s.count)
                if trip is not None and trip >= 1:
                    inside = _defined_names(inner) | {s.var.name}
                    _, writes = body_mem_accesses(inner)
                    while True:
                        hoisted: List[ir.Stmt] = []
                        kept: List[ir.Stmt] = []

                        def stable(name: str) -> bool:
                            return name not in inside \
                                and defs.get(name, 0) == 1

                        for t in inner:
                            site = load_site(t) if isinstance(t, ir.Op) \
                                else None
                            if (site is not None and t.dest is not None
                                    and defs.get(t.dest.name, 0) == 1
                                    and all(r.name not in inside
                                            for r in t.arg_regs())):
                                space, buf, idx = site
                                lform = index_form(idx, aff, defs)
                                blocked = any(
                                    wspace == space and wbuf == buf
                                    and may_alias(
                                        lform,
                                        index_form(widx, aff, defs),
                                        stable)
                                    for wspace, wbuf, widx in writes)
                                if not blocked:
                                    hoisted.append(t)
                                    inside.discard(t.dest.name)
                                    n[0] += 1
                                    continue
                            kept.append(t)
                        inner = kept
                        out.extend(hoisted)
                        if not hoisted:
                            break
                out.append(ir.Loop(s.var, s.count, inner))
            elif isinstance(s, ir.Pred):
                out.append(ir.Pred(s.cond, process(s.body)))
            else:
                out.append(s)
        return out

    return process(body), n[0]


# --------------------------------------------------------------------------
# Uniform duplicate merging (dominator-scoped value numbering)
# --------------------------------------------------------------------------


def _value_number(body: List[ir.Stmt], prog: ir.Program,
                  cross_loops: bool) -> Tuple[List[ir.Stmt], int]:
    """Value-numbering core shared by :func:`merge_duplicates` (region
    scope) and :func:`value_number_cross_segment` (``cross_loops=True``).

    A duplicate nested under a @PRED is only merged when every use of its
    dest lies inside that same predicate region: at level 0 the interp
    backend writes the dup's register only for active threads, so a read
    outside the region would observe the rename.

    With ``cross_loops``, values defined at the top level of a loop whose
    static trip count is ≥ 1 stay available after the loop: the defining op
    provably executed, its non-loop-var inputs are single-def (unchanged),
    and the loop variable's final value is exactly what a post-loop
    duplicate would read — so the register already holds the duplicate's
    value across the LoopEnd segment boundary.  Dynamic or possibly
    zero-trip loops keep the conservative region scope."""
    defs = ir.reg_def_counts(body)
    rename: Dict[str, ir.Reg] = {}
    table: Dict[Tuple, ir.Reg] = {}
    n = [0]

    # pred-ancestor chains (tuples of Pred object ids) for every reg use
    use_chains: Dict[str, List[Tuple[int, ...]]] = {}

    def collect_uses(stmts: Sequence[ir.Stmt],
                     chain: Tuple[int, ...]) -> None:
        for s in stmts:
            if isinstance(s, ir.Op):
                for r in s.arg_regs():
                    use_chains.setdefault(r.name, []).append(chain)
            elif isinstance(s, ir.Pred):
                use_chains.setdefault(s.cond.name, []).append(chain)
                collect_uses(s.body, chain + (id(s),))
            elif isinstance(s, ir.Loop):
                collect_uses(s.body, chain)

    collect_uses(body, ())

    def uses_confined(name: str, chain: Tuple[int, ...]) -> bool:
        return all(uc[:len(chain)] == chain
                   for uc in use_chains.get(name, []))

    def key_of(op: ir.Op) -> Optional[Tuple]:
        if (op.opcode not in _CSE_OPS or op.dest is None
                or defs.get(op.dest.name, 0) != 1):
            return None
        parts: List[object] = [op.opcode, op.dest.dtype, op.dest.uniform]
        for a in op.args:
            if isinstance(a, ir.Reg):
                if defs.get(a.name, 0) != 1:
                    return None  # value varies across redefinitions
                parts.append(("r", a.name, a.dtype))
            else:
                parts.append(("i", type(a).__name__, repr(a)))
        for k in sorted(op.attrs):
            parts.append(("a", k, repr(op.attrs[k])))
        return tuple(parts)

    def sub(a):
        return rename.get(a.name, a) if isinstance(a, ir.Reg) else a

    def walk(stmts: Sequence[ir.Stmt], chain: Tuple[int, ...],
             marks: Optional[List[Tuple]] = None) -> List[ir.Stmt]:
        own_marks = marks is None
        if own_marks:
            marks = []
        out: List[ir.Stmt] = []
        for s in stmts:
            if isinstance(s, ir.Op):
                args = tuple(sub(a) for a in s.args)
                op = s if args == s.args else \
                    ir.Op(s.opcode, s.dest, args, dict(s.attrs))
                k = key_of(op)
                if k is not None:
                    prior = table.get(k)
                    if prior is not None and (
                            not chain
                            or uses_confined(op.dest.name, chain)):
                        rename[op.dest.name] = prior
                        n[0] += 1
                        continue
                    if prior is None:
                        table[k] = op.dest
                        marks.append(k)
                out.append(op)
            elif isinstance(s, ir.Pred):
                out.append(ir.Pred(sub(s.cond),
                                   walk(s.body, chain + (id(s),))))
            elif isinstance(s, ir.Loop):
                trip = static_trip_count(s.count)
                if cross_loops and trip is not None and trip >= 1:
                    # guaranteed execution: the body's value numbers stay
                    # available in the enclosing scope (parent's marks)
                    inner = walk(s.body, chain, marks)
                else:
                    inner = walk(s.body, chain)
                out.append(ir.Loop(s.var, s.count, inner))
            else:
                out.append(s)
        if own_marks:
            for k in marks:
                del table[k]
        return out

    return walk(body, ()), n[0]


def merge_duplicates(body: List[ir.Stmt], prog: ir.Program
                     ) -> Tuple[List[ir.Stmt], int]:
    """Merge re-emitted identical pure ops (the Builder emits a fresh CONST
    per mention) via value numbering scoped to the structured-region tree,
    so a merge target always dominates the duplicate it replaces."""
    return _value_number(body, prog, cross_loops=False)


def value_number_cross_segment(body: List[ir.Stmt], prog: ir.Program
                               ) -> Tuple[List[ir.Stmt], int]:
    """:func:`merge_duplicates` extended across segment-creating loop
    boundaries (see :func:`_value_number`): values computed inside a
    statically-guaranteed loop serve later duplicates — including ones in
    segments after the loop's barriers — without re-execution.  This is
    where non-hoistable duplicates (``DIV``/``MOD``, which
    :func:`hoist_invariants` refuses to move) finally merge."""
    return _value_number(body, prog, cross_loops=True)


# --------------------------------------------------------------------------
# FMA fusion
# --------------------------------------------------------------------------


def fuse_fma(body: List[ir.Stmt], prog: ir.Program
             ) -> Tuple[List[ir.Stmt], int]:
    """Fuse a single-use f32 ``MUL`` feeding an ``ADD`` in the same region
    into ``FMA``.  Every backend evaluates FMA as the unfused ``a*b + c``,
    so the fusion is bit-exact; DCE then deletes the orphaned MUL."""
    defs = ir.reg_def_counts(body)
    uses = ir.reg_use_counts(body)

    # map mul-dest name -> (mul op, region path); paths gate fusion to the
    # same structured region so activity masks line up exactly
    muls: Dict[str, Tuple[ir.Op, Tuple[int, ...]]] = {}

    def collect(stmts: Sequence[ir.Stmt], path: Tuple[int, ...]) -> None:
        for i, s in enumerate(stmts):
            if isinstance(s, ir.Op):
                if (s.opcode == ir.MUL and s.dest is not None
                        and s.dest.dtype == ir.F32
                        and defs.get(s.dest.name, 0) == 1
                        and uses.get(s.dest.name, 0) == 1
                        and all(defs.get(r.name, 0) == 1
                                for r in s.arg_regs())):
                    muls[s.dest.name] = (s, path)
            elif isinstance(s, (ir.Pred, ir.Loop)):
                collect(s.body, path + (i,))

    collect(body, ())
    n = [0]

    def walk(stmts: Sequence[ir.Stmt], path: Tuple[int, ...]
             ) -> List[ir.Stmt]:
        out: List[ir.Stmt] = []
        for i, s in enumerate(stmts):
            if isinstance(s, ir.Op):
                if (s.opcode == ir.ADD and s.dest is not None
                        and s.dest.dtype == ir.F32):
                    for ai, other in ((0, 1), (1, 0)):
                        a = s.args[ai]
                        if (isinstance(a, ir.Reg) and a.name in muls
                                and muls[a.name][1] == path):
                            mul = muls[a.name][0]
                            out.append(ir.Op(
                                ir.FMA, s.dest,
                                (mul.args[0], mul.args[1], s.args[other])))
                            n[0] += 1
                            break
                    else:
                        out.append(s)
                else:
                    out.append(s)
            elif isinstance(s, ir.Pred):
                out.append(ir.Pred(s.cond, walk(s.body, path + (i,))))
            elif isinstance(s, ir.Loop):
                out.append(ir.Loop(s.var, s.count, walk(s.body, path + (i,))))
            else:
                out.append(s)
        return out

    return walk(body, ()), n[0]


# --------------------------------------------------------------------------
# Dead-code elimination
# --------------------------------------------------------------------------


def eliminate_dead_code(body: List[ir.Stmt], prog: ir.Program
                        ) -> Tuple[List[ir.Stmt], int]:
    """Delete pure ops whose dests are never read, then the empty @PRED
    regions and dead loops left behind; iterate to a fixpoint."""
    total = 0
    while True:
        uses = ir.reg_use_counts(body)
        removed = [0]

        def walk(stmts: Sequence[ir.Stmt]) -> List[ir.Stmt]:
            out: List[ir.Stmt] = []
            for s in stmts:
                if isinstance(s, ir.Op):
                    if (s.dest is not None and _is_pure(s.opcode)
                            and uses.get(s.dest.name, 0) == 0):
                        removed[0] += 1
                    else:
                        out.append(s)
                elif isinstance(s, ir.Pred):
                    inner = walk(s.body)
                    if inner:
                        out.append(ir.Pred(s.cond, inner))
                    else:
                        removed[0] += 1
                elif isinstance(s, ir.Loop):
                    inner = walk(s.body)
                    if inner or uses.get(s.var.name, 0) > 0:
                        out.append(ir.Loop(s.var, s.count, inner))
                    else:
                        removed[0] += 1
                else:
                    out.append(s)
            return out

        body = walk(body)
        if removed[0] == 0:
            return body, total
        total += removed[0]


# --------------------------------------------------------------------------
# Loop unrolling (phase 2)
# --------------------------------------------------------------------------

#: largest static trip count that is unrolled (HETGPU_UNROLL_MAX overrides)
UNROLL_MAX_TRIPS = max(0, int(os.environ.get("HETGPU_UNROLL_MAX", "8")))
#: code-growth budget: trips × body ops must stay under this
UNROLL_MAX_BODY_OPS = 256


def _subst_copy(stmts: Sequence[ir.Stmt],
                ren: Dict[str, ir.Reg]) -> List[ir.Stmt]:
    """Fresh structural copy of ``stmts`` with registers renamed per
    ``ren``.  Every Pred/Loop node is rebuilt (passes key on node identity,
    so copies must never alias the original tree)."""
    out: List[ir.Stmt] = []
    for s in stmts:
        if isinstance(s, ir.Op):
            dest = s.dest
            if dest is not None and dest.name in ren:
                dest = ren[dest.name]
            args = tuple(ren.get(a.name, a) if isinstance(a, ir.Reg) else a
                         for a in s.args)
            out.append(ir.Op(s.opcode, dest, args, dict(s.attrs)))
        elif isinstance(s, ir.Pred):
            out.append(ir.Pred(ren.get(s.cond.name, s.cond),
                               _subst_copy(s.body, ren)))
        elif isinstance(s, ir.Loop):
            out.append(ir.Loop(s.var, s.count, _subst_copy(s.body, ren)))
        else:
            out.append(ir.Barrier(s.label))
    return out


def _collect_op_defs(stmts: Sequence[ir.Stmt]) -> Dict[str, ir.Reg]:
    """First Reg object per name defined by an Op in ``stmts`` (recursive;
    loop-header vars excluded — they are never renamed)."""
    found: Dict[str, ir.Reg] = {}

    def walk(ss):
        for s in ss:
            if isinstance(s, ir.Op):
                if s.dest is not None:
                    found.setdefault(s.dest.name, s.dest)
            elif isinstance(s, (ir.Pred, ir.Loop)):
                walk(s.body)

    walk(stmts)
    return found


def _conditional_def_names(stmts: Sequence[ir.Stmt]) -> set:
    """Names whose def sits under a @PRED (or nested loop) in ``stmts``.
    Such a write may not happen in a given iteration for a given thread,
    so the register legally *carries* its previous-iteration value — it
    must never be renamed per unrolled copy."""
    names: set = set()

    def walk(ss, under: bool):
        for s in ss:
            if isinstance(s, ir.Op):
                if under and s.dest is not None:
                    names.add(s.dest.name)
            elif isinstance(s, (ir.Pred, ir.Loop)):
                walk(s.body, True)

    walk(stmts, False)
    return names


#: names minted by passes: ``srN.c`` (strength-reduce constants) and the
#: ``.itN`` / ``.uN`` suffixes of unrolled copies.  Each pass seeds its
#: counter past the largest tag already present in the body, so a second
#: pipeline iteration (or a pass re-run on already-optimized IR) can never
#: re-mint a name that an earlier invocation defined with another value —
#: while staying deterministic (the seed is a pure function of the body).
_SR_NAME = re.compile(r"^sr(\d+)\.c$")
_UNROLL_TAG = re.compile(r"\.(?:it|u)(\d+)$")


def _fresh_base(body: Sequence[ir.Stmt], pattern: re.Pattern) -> int:
    base = 0
    for op in ir.walk_ops(body):
        if op.dest is not None:
            m = pattern.search(op.dest.name)
            if m:
                base = max(base, int(m.group(1)))
    return base


def unroll_loops(body: List[ir.Stmt], prog: ir.Program
                 ) -> Tuple[List[ir.Stmt], int]:
    """Flatten barrier-free loops with static trip count in
    ``[1, UNROLL_MAX_TRIPS]`` (and ``trips × body ops ≤
    UNROLL_MAX_BODY_OPS``) into straight-line copies of the body.

    Each iteration binds the loop variable to a fresh single-def ``CONST``
    and renames the body's *local* registers (defined only inside the body
    and never read outside it), so every copy is single-def — which is what
    lets the downstream folding/CSE/DCE passes collapse the per-iteration
    index arithmetic.  Registers that escape the loop keep their names:
    the last copy's write is the value a post-loop reader must see, exactly
    as the rolled loop behaves.  After the copies the loop variable itself
    is materialized to its final value (``trips - 1``) for any post-loop
    reads; DCE deletes it when unused.  Innermost loops unroll first
    (sweep to fixpoint), so tight nests flatten fully within budget.
    Barrier-carrying loops are never unrolled — their iteration structure
    *is* the engine's segment/migration boundary."""
    uid = [_fresh_base(body, _UNROLL_TAG)]
    total = 0
    while True:
        body, changed = _unroll_sweep(body, uid)
        total += changed
        if not changed:
            return body, total


def _unroll_sweep(body: List[ir.Stmt], uid: List[int]
                  ) -> Tuple[List[ir.Stmt], int]:
    defs = ir.reg_def_counts(body)
    uses = ir.reg_use_counts(body)
    n = [0]

    def eligible(s: ir.Loop) -> Optional[int]:
        trip = static_trip_count(s.count)
        if trip is None or not 1 <= trip <= UNROLL_MAX_TRIPS:
            return None
        if any(isinstance(x, ir.Loop) for x in ir_walk_stmts(s.body)):
            return None  # innermost first; outer unrolls next sweep
        if ir._contains_barrier(s.body):
            return None
        if trip * ir.count_ops(s.body) > UNROLL_MAX_BODY_OPS:
            return None
        body_defs = ir.reg_def_counts(s.body)
        if s.var.name in body_defs:
            return None  # body writes the loop var: not a counted loop
        return trip

    def expand(s: ir.Loop, trip: int) -> List[ir.Stmt]:
        body_defs = ir.reg_def_counts(s.body)
        body_uses = ir.reg_use_counts(s.body)
        reg_objs = _collect_op_defs(s.body)
        # renameable = defined only inside this body, read only inside it,
        # and written *unconditionally* each iteration.  A def under a
        # @PRED carries its previous-iteration value whenever the
        # predicate is false — renaming it per copy would make later
        # copies read a never-written register (miscompile found by
        # review; regression in tests/test_passes.py).
        conditional = _conditional_def_names(s.body)
        local = {r for r, c in body_defs.items()
                 if c == defs.get(r, 0)
                 and body_uses.get(r, 0) == uses.get(r, 0)
                 and r in reg_objs
                 and r not in conditional}
        out: List[ir.Stmt] = []
        for it in range(trip):
            uid[0] += 1
            tag = uid[0]
            iv = ir.Reg(f"{s.var.name}.it{tag}", s.var.dtype, s.var.uniform)
            ren = {s.var.name: iv}
            for r in local:
                old = reg_objs[r]
                ren[r] = ir.Reg(f"{r}.u{tag}", old.dtype, old.uniform)
            out.append(ir.Op(ir.CONST, iv, (it,)))
            out.extend(_subst_copy(s.body, ren))
        # post-loop reads of the loop var see its final iteration value
        out.append(ir.Op(ir.CONST, s.var, (trip - 1,)))
        return out

    def walk(stmts: Sequence[ir.Stmt]) -> List[ir.Stmt]:
        out: List[ir.Stmt] = []
        for s in stmts:
            if isinstance(s, ir.Loop):
                trip = eligible(s)
                if trip is not None:
                    n[0] += 1
                    out.extend(expand(s, trip))
                else:
                    out.append(ir.Loop(s.var, s.count, walk(s.body)))
            elif isinstance(s, ir.Pred):
                out.append(ir.Pred(s.cond, walk(s.body)))
            else:
                out.append(s)
        return out

    return walk(body), n[0]


def ir_walk_stmts(body: Sequence[ir.Stmt]):
    """Yield every statement in ``body`` recursively (structure included)."""
    for s in body:
        yield s
        if isinstance(s, (ir.Pred, ir.Loop)):
            yield from ir_walk_stmts(s.body)


# --------------------------------------------------------------------------
# Strength reduction (phase 2)
# --------------------------------------------------------------------------


def _pow2_exponent(v) -> Optional[int]:
    """k if ``v`` is exactly 2**k for an integer value, else None."""
    try:
        iv = int(v)
    except (TypeError, ValueError, OverflowError):
        return None
    if iv != v or iv <= 0 or iv & (iv - 1):
        return None
    k = iv.bit_length() - 1
    return k if 0 <= k < 32 else None


def _exact_recip(v) -> Optional[float]:
    """1/v when that reciprocal is exactly representable in f32 and
    round-trips (``v`` a power of two, sign allowed) — the condition under
    which ``x / v`` and ``x * (1/v)`` are bit-identical IEEE results for
    every x, including denormals, infinities and NaN."""
    fv = float(v)
    if fv == 0.0 or not np.isfinite(fv):
        return None
    recip = np.float32(1.0) / np.float32(fv)
    if not np.isfinite(recip) or float(recip) * fv != 1.0:
        return None
    return float(recip)


def strength_reduce(body: List[ir.Stmt], prog: ir.Program
                    ) -> Tuple[List[ir.Stmt], int]:
    """Rewrite multiplicative ops with power-of-two constant operands into
    cheaper exact equivalents:

    * int ``MUL x, 2**k``  → ``SHL x, k``   (two's-complement wrap matches)
    * int ``DIV x, 2**k``  → ``SHR x, k``   (hetIR integer division is
      *floor* division, so the arithmetic shift is exactly it — this
      rewrite would be wrong for C-style truncating division)
    * int ``MOD x, 2**k``  → ``AND x, 2**k - 1``  (floor-mod of a positive
      modulus is non-negative, which is exactly the mask)
    * f32 ``DIV x, c``     → ``MUL x, 1/c``  when ``1/c`` is an exact
      power-of-two reciprocal (division and multiplication then round the
      same infinitely-precise value — bit-identical)
    * ``MUL/DIV x, 1`` → ``MOV x``; int ``MOD x, 1`` → ``CONST 0``

    Beyond the latency win, ``SHL``/``SHR``/``AND`` are *hoistable* ops
    while ``DIV``/``MOD`` are not (divide-by-zero introduction), so reduced
    forms escape loops.  Constant visibility is region-scoped exactly like
    :func:`fold_constants`."""
    defs = ir.reg_def_counts(body)
    consts: Dict[str, object] = {}
    n = [0]
    fresh = [_fresh_base(body, _SR_NAME)]

    def const_reg(dtype: str, value, out: List[ir.Stmt]) -> ir.Reg:
        fresh[0] += 1
        r = ir.Reg(f"sr{fresh[0]}.c", dtype, True)
        out.append(ir.Op(ir.CONST, r, (value,)))
        return r

    def known(a) -> Optional[object]:
        if isinstance(a, ir.Reg):
            return consts.get(a.name)
        return a  # immediate operand

    def rewrite(op: ir.Op, out: List[ir.Stmt]) -> bool:
        d = op.dest
        if d is None or op.opcode not in (ir.MUL, ir.DIV, ir.MOD):
            return False
        is_int = d.dtype in (ir.I32, ir.U32)
        a, b = op.args
        if op.opcode == ir.MUL and is_int:
            for x, c in ((a, known(b)), (b, known(a))):
                if c is None or not isinstance(x, ir.Reg):
                    continue
                k = _pow2_exponent(c)
                if k is None:
                    continue
                if k == 0:
                    out.append(ir.Op(ir.MOV, d, (x,)))
                else:
                    kreg = const_reg(d.dtype, k, out)
                    out.append(ir.Op(ir.SHL, d, (x, kreg)))
                n[0] += 1
                return True
            return False
        c = known(b)
        if c is None or not isinstance(a, ir.Reg):
            return False
        if is_int:
            k = _pow2_exponent(c)
            if k is None:
                return False
            if op.opcode == ir.DIV:
                if k == 0:
                    out.append(ir.Op(ir.MOV, d, (a,)))
                else:
                    kreg = const_reg(d.dtype, k, out)
                    out.append(ir.Op(ir.SHR, d, (a, kreg)))
            else:  # MOD
                if k == 0:
                    out.append(ir.Op(ir.CONST, d, (0,)))
                else:
                    mreg = const_reg(d.dtype, (1 << k) - 1, out)
                    out.append(ir.Op(ir.AND, d, (a, mreg)))
            n[0] += 1
            return True
        if d.dtype == ir.F32 and op.opcode == ir.DIV:
            recip = _exact_recip(c)
            if recip is None:
                return False
            if recip == 1.0:
                out.append(ir.Op(ir.MOV, d, (a,)))
            else:
                rreg = const_reg(ir.F32, recip, out)
                out.append(ir.Op(ir.MUL, d, (a, rreg)))
            n[0] += 1
            return True
        return False

    def walk(stmts: Sequence[ir.Stmt]) -> List[ir.Stmt]:
        out: List[ir.Stmt] = []
        for s in stmts:
            if isinstance(s, ir.Op):
                if (s.opcode == ir.CONST and s.dest is not None
                        and defs.get(s.dest.name, 0) == 1):
                    consts[s.dest.name] = \
                        ir.np_dtype(s.dest.dtype).type(s.args[0])
                if not rewrite(s, out):
                    out.append(s)
            elif isinstance(s, ir.Pred):
                out.append(ir.Pred(s.cond, scoped(s.body)))
            elif isinstance(s, ir.Loop):
                out.append(ir.Loop(s.var, s.count, scoped(s.body)))
            else:
                out.append(s)
        return out

    def scoped(stmts: Sequence[ir.Stmt]) -> List[ir.Stmt]:
        outer = set(consts)
        out = walk(stmts)
        for k in list(consts):
            if k not in outer:
                del consts[k]
        return out

    return walk(body), n[0]


# --------------------------------------------------------------------------
# Pipeline driver
# --------------------------------------------------------------------------

PassFn = Callable[[List[ir.Stmt], ir.Program], Tuple[List[ir.Stmt], int]]

_PIPELINES: Dict[int, List[PassFn]] = {
    0: [],
    1: [fold_constants, eliminate_dead_code],
    2: [fold_constants, simplify_predicates, hoist_invariants,
        merge_duplicates, fuse_fma, fold_constants, eliminate_dead_code],
    # phase 2/3.  A fold/pred/hoist prefix runs *before* unrolling so
    # loop-invariant scalars (and the constants feeding invariant-load
    # indices) leave loop bodies first — then hoist_invariant_loads can
    # lift an alias-free load once instead of unrolling N copies of it.
    # Unrolling next, so the second folding/CSE sweep sees per-iteration
    # constants; value numbering (cross-segment) before strength
    # reduction so duplicate DIV/MODs merge before being rewritten; the
    # final fold sweep cleans up what unrolling and strength reduction
    # exposed
    3: [fold_constants, simplify_predicates, hoist_invariants,
        hoist_invariant_loads, unroll_loops, fold_constants,
        simplify_predicates, hoist_invariants, value_number_cross_segment,
        strength_reduce, fuse_fma, fold_constants, eliminate_dead_code],
}

OPT_MAX = max(_PIPELINES)
_MAX_PIPELINE_ITERS = 4

#: bump when any pass's *output semantics* change without a rename — part
#: of :func:`pipeline_fingerprint`, hence of the persistent store's tag
#: (v3: launch-time specialization + alias-aware load hoisting; the
#: translation-cache key layout also gained the bound-scalar vector.
#: v4: block-tiled pallas lowering — translation keys gained the block
#: plan component, spec keys gained inert ``name#shape`` buffer-extent
#: entries, and uninitialized-register reads are defined as zero on every
#: backend — stale DiskStore entries from v3 must not be revived)
_PASS_SCHEMA_VERSION = 4

DEFAULT_OPT_LEVEL = max(0, min(
    int(os.environ.get("HETGPU_OPT_LEVEL", str(OPT_MAX))), OPT_MAX))


def pipeline_fingerprint() -> str:
    """Stable digest of the pass pipeline configuration: pass names per
    level (order included), the unrolling limits, and the schema version.
    :func:`repro.core.cache._runtime_tag` folds this into the persistent
    store's directory tag, so *any* pass-set change — added, removed,
    reordered passes, changed thresholds, or a bumped schema — invalidates
    every persisted translation.  Without it, a store populated by an older
    pipeline would silently serve artifacts the current optimizer would
    never produce."""
    h = hashlib.sha256()
    h.update(f"schema{_PASS_SCHEMA_VERSION}".encode())
    for level in sorted(_PIPELINES):
        names = ",".join(fn.__name__ for fn in _PIPELINES[level])
        h.update(f"|{level}:{names}".encode())
    h.update(f"|unroll{UNROLL_MAX_TRIPS}x{UNROLL_MAX_BODY_OPS}".encode())
    return h.hexdigest()[:12]


def optimize(program: ir.Program, level: int = OPT_MAX
             ) -> Tuple[ir.Program, PipelineStats]:
    """Run the pass pipeline for ``level`` and return a new, semantically
    identical :class:`~repro.core.hetir.Program` plus per-pass statistics.
    ``level`` clamps into ``[0, OPT_MAX]``; level 0 is the identity."""
    level = max(0, min(int(level), OPT_MAX))
    stats = PipelineStats(level=level, ops_before=ir.count_ops(program.body))
    body = list(program.body)
    pipeline = _PIPELINES[level]
    if pipeline:
        for _ in range(_MAX_PIPELINE_ITERS):
            stats.iterations += 1
            changed = 0
            for pass_fn in pipeline:
                t0 = time.perf_counter()
                body, n = pass_fn(body, program)
                stats.record(pass_fn.__name__, n,
                             (time.perf_counter() - t0) * 1e3)
                changed += n
            if changed == 0:
                break
    out = ir.Program(name=program.name, params=list(program.params),
                     body=body, shared_size=program.shared_size,
                     shared_dtype=program.shared_dtype)
    out.validate()
    stats.ops_after = ir.count_ops(body)
    return out, stats


def get_optimized(program: ir.Program, level: int
                  ) -> Tuple[ir.Program, PipelineStats]:
    """Memoized :func:`optimize` — one optimized body per (program, level),
    so repeated launches (and the segmentation/node cache riding on the
    optimized program) reuse identical objects."""
    level = max(0, min(int(level), OPT_MAX))
    memo = program.__dict__.setdefault("_opt_cache", {})
    hit = memo.get(level)
    if hit is None:
        if level == 0:
            stats = PipelineStats(level=0,
                                  ops_before=ir.count_ops(program.body),
                                  ops_after=ir.count_ops(program.body))
            hit = (program, stats)
        else:
            hit = optimize(program, level)
        memo[level] = hit
    return hit


# --------------------------------------------------------------------------
# Launch-time specialization (paper §4.2: translation happens at launch,
# when every uniform scalar argument is known)
# --------------------------------------------------------------------------

#: (name, value) pairs of bound uniform scalars, sorted by name; () means
#: the generic (unspecialized) program.  This tuple is the *specialization
#: key*: it joins every translation-cache key, rides in snapshots, and
#: selects the memoized specialized variant.
SpecKey = Tuple[Tuple[str, object], ...]


def bind_launch_scalars(body: List[ir.Stmt], prog: ir.Program,
                        values: Dict[str, object]
                        ) -> Tuple[List[ir.Stmt], int]:
    """Rewrite ``LD_PARAM`` of a bound scalar into ``CONST`` of its
    launch value, and dynamic loop counts naming a bound scalar into
    ``int`` literals — after which the ordinary pipeline folds the
    size-dependent index math and :func:`unroll_loops` /
    :func:`hoist_invariant_loads` see static trip counts.  Values are
    typed through the dest register's dtype, so a folded constant is
    bit-identical to what ``LD_PARAM`` would have produced at run time."""
    n = [0]

    def walk(stmts: Sequence[ir.Stmt]) -> List[ir.Stmt]:
        out: List[ir.Stmt] = []
        for s in stmts:
            if isinstance(s, ir.Op):
                if (s.opcode == ir.LD_PARAM and s.dest is not None
                        and s.args[0] in values):
                    v = ir.np_dtype(s.dest.dtype).type(values[s.args[0]])
                    out.append(ir.Op(ir.CONST, s.dest, (v.item(),)))
                    n[0] += 1
                else:
                    out.append(s)
            elif isinstance(s, ir.Pred):
                out.append(ir.Pred(s.cond, walk(s.body)))
            elif isinstance(s, ir.Loop):
                count = s.count
                if isinstance(count, str) and count in values:
                    count = int(values[count])
                    n[0] += 1
                out.append(ir.Loop(s.var, count, walk(s.body)))
            else:
                out.append(s)
        return out

    return walk(body), n[0]


def get_specialized(program: ir.Program, level: int, spec_key: SpecKey
                    ) -> Tuple[ir.Program, PipelineStats]:
    """Memoized specialized variant: bind the scalars in ``spec_key`` as
    constants, then run the ordinary pipeline at ``level``.  Deterministic
    in (program, level, spec_key) — a migration destination re-deriving a
    variant from a snapshot's key reconstructs the *identical* optimized
    body, node list, and program fingerprint."""
    level = max(0, min(int(level), OPT_MAX))
    spec_key = tuple((str(k), v) for k, v in spec_key)
    memo = program.__dict__.setdefault("_spec_cache", {})
    hit = memo.get((level, spec_key))
    if hit is None:
        values = dict(spec_key)
        body, bound = bind_launch_scalars(list(program.body), program,
                                          values)
        seed = ir.Program(name=program.name, params=list(program.params),
                          body=body, shared_size=program.shared_size,
                          shared_dtype=program.shared_dtype)
        out, stats = optimize(seed, level)
        stats.record("bind_launch_scalars", bound)
        stats.spec_key = spec_key
        hit = (out, stats)
        memo[(level, spec_key)] = hit
    return hit


def shape_spec_entries(shapes: Optional[Dict[str, Tuple]]) -> list:
    """Buffer extents as *inert* spec-key entries.

    Names carry a ``#shape`` suffix no hetIR parameter name can have, so
    :func:`bind_launch_scalars` (which matches ``LD_PARAM`` argument names
    and loop-count scalar names) never binds them — they change no op in
    the specialized body.  They exist purely to make the specialization
    key, the memoized variant, every translation-cache key, and the
    snapshot's ``spec_key`` distinguish launches per buffer shape: the
    block-tiled pallas path specializes tile geometry on exactly these
    extents (the PR 5 remainder the roadmap calls "shapes in the launch
    record")."""
    if not shapes:
        return []
    return [(f"{name}#shape", int(np.prod(shape, dtype=np.int64)))
            for name, shape in shapes.items()]


class SpecializationPolicy:
    """Decides whether a launch gets a specialized variant.

    Modes (``HETGPU_SPECIALIZE``, read at decision time so tests can
    flip it):

    * ``off``/``0``/``false``/``no`` — never specialize;
    * ``auto`` (default) — specialize only programs with at least one
      *barrier-free dynamic-trip* loop
      (:func:`~repro.core.segments.specializable_counts`), where binding
      the count unlocks unrolling / static-trip load hoisting;
    * ``all`` — specialize every launch with uniform scalars.

    The per-program **budget** (``HETGPU_SPECIALIZE_BUDGET``, default 8)
    caps how many *distinct* scalar bindings a program may accumulate at
    one opt level; past it, new bindings fall back to the generic variant
    (whose translations every launch shares), so an adversarial scalar
    stream cannot grow code and cache without bound.  Already-admitted
    bindings keep specializing — a warm variant stays warm.  An explicit
    ``override=True`` is a per-launch *demand* (e.g. a caller that needs
    the unrolled body before checkpointing) and bypasses the budget —
    the budget polices the ambient policy, not deliberate requests."""

    def consider(self, program: ir.Program, level: int,
                 scalars: Dict[str, object],
                 override: Optional[bool] = None,
                 shapes: Optional[Dict[str, Tuple]] = None) -> SpecKey:
        if override is False:
            return ()
        mode = "all" if override else \
            os.environ.get("HETGPU_SPECIALIZE", "auto").strip().lower()
        if mode in ("off", "0", "false", "no"):
            return ()
        if level < 1 or (not scalars and not shapes):
            return ()  # O0 is the differential baseline: always generic
        if mode != "all" and not specializable_counts(program.body):
            return ()
        # buffer extents join the key (inert ``name#shape`` entries): two
        # launches differing only in buffer length are *different*
        # specialization variants — the policy used to be shape-blind
        key: SpecKey = tuple(sorted(
            [(name, np.asarray(v).item()) for name, v in scalars.items()]
            + shape_spec_entries(shapes)))
        budget = max(0, int(os.environ.get("HETGPU_SPECIALIZE_BUDGET",
                                           "8")))
        seen = program.__dict__.setdefault("_spec_variants", {}) \
            .setdefault(level, set())
        if key not in seen:
            if override is not True and len(seen) >= budget:
                return ()  # budget exhausted: generic fallback
            seen.add(key)
        return key


#: process-wide policy instance (stateless beyond env/program lookups)
SPECIALIZATION_POLICY = SpecializationPolicy()


# --------------------------------------------------------------------------
# Block lowering — the lane-independence proof behind the pallas tiled
# fast path (see docs/PASSES.md, "Block lowering")
# --------------------------------------------------------------------------

#: thread-identity base kinds an affine index term may stand on
_THREAD_BASES = {ir.GET_GLOBAL_ID: "gid", ir.GET_BLOCK_ID: "bid",
                 ir.GET_THREAD_ID: "tid"}

#: ops whose dest is launch-uniform when every Reg argument is
_UNIFORM_SEED_OPS = {ir.CONST, ir.LD_PARAM, ir.GET_BLOCK_DIM,
                     ir.GET_NUM_BLOCKS}
_UNIFORM_PURE_OPS = (ir.ALU_UNARY | ir.ALU_BINARY | ir.CMP_OPS
                     | {ir.MOV, ir.CVT, ir.SELECT, ir.FMA})


@dataclass(frozen=True)
class BlockPlan:
    """A proven-legal block-tiled lowering of one barrier-free segment.

    ``stmts`` is the segment body with every global access rewritten into
    the block-primitive form (:data:`~repro.core.hetir.BLOCK_LD` /
    :data:`~repro.core.hetir.BLOCK_ST`, constexpr ``block`` size and
    tiling ``mode`` in the op attrs).  ``tiled`` names the buffers whose
    every access index is exactly the flat global id (BlockSpec-tiled one
    tile per grid step); every other accessed buffer is staged whole and
    masked-gathered.  ``block``/``grid`` tile the flat element domain
    ``N = num_blocks * block_size`` into ``grid = N // block`` steps."""

    stmts: Tuple[ir.Stmt, ...]
    tiled: frozenset
    block: int
    grid: int


def choose_block(n_elems: int, cap: Optional[int] = None) -> Optional[int]:
    """Constexpr tile size for a flat element domain of ``n_elems``: the
    largest power of two dividing ``n_elems`` (tiles are always full — the
    semantic mask is the program's own predication), capped by
    ``HETGPU_BLOCK_MAX`` (default 1024, the Triton-style constexpr BLOCK
    ceiling).  ``None`` when no tile exists (``n_elems <= 0``)."""
    if cap is None:
        cap = int(os.environ.get("HETGPU_BLOCK_MAX", "1024"))
    if n_elems <= 0 or cap <= 0:
        return None
    pow2 = n_elems & -n_elems
    cap2 = 1 << (cap.bit_length() - 1)
    return min(pow2, cap2)


def _uniform_regs(stmts: Sequence[ir.Stmt]) -> set:
    """Single-def registers provably launch-uniform (equal across every
    thread of every block): transitive closure of pure ops over uniform
    inputs, seeded by CONST / LD_PARAM / GET_BLOCK_DIM / GET_NUM_BLOCKS.
    Loop variables are *excluded*: they are uniform across threads at any
    instant but vary across iterations, and the block-lowering proof needs
    values stable over the whole segment."""
    defs = ir.reg_def_counts(stmts)
    uni: set = set()

    def uniform_arg(a) -> bool:
        return not isinstance(a, ir.Reg) or a.name in uni

    def walk(body):
        for s in body:
            if isinstance(s, ir.Op):
                d = s.dest
                if d is None or defs.get(d.name, 0) != 1:
                    continue
                if s.opcode in _UNIFORM_SEED_OPS:
                    uni.add(d.name)
                elif s.opcode in _UNIFORM_PURE_OPS \
                        and all(uniform_arg(a) for a in s.args):
                    uni.add(d.name)
            elif isinstance(s, (ir.Pred, ir.Loop)):
                walk(s.body)

    walk(stmts)
    return uni


def _decompose(form: AffineIndex, kinds: Dict[str, str], uniform: set,
               block_size: int) -> Optional[Tuple[int, int, bool]]:
    """Split an affine index form's thread dependence into per-thread
    coefficients.  With ``gid = bid * T + tid``, the address difference of
    two threads ``(bid1, tid1)`` vs ``(bid2, tid2)`` is
    ``cb * (bid1 - bid2) + ct * (tid1 - tid2)`` where ``ct`` / ``cb`` are
    the effective tid / bid coefficients returned here.  Returns ``(ct,
    cb, has_uniform_terms)``, or ``None`` when any base is neither thread
    identity nor launch-uniform (loop variables, loaded values, multi-def
    registers — nothing sound can be said)."""
    c_gid = c_bid = c_tid = 0
    has_uniform = False
    for base, coeff in form.terms:
        k = kinds.get(base)
        if k == "gid":
            c_gid += coeff
        elif k == "bid":
            c_bid += coeff
        elif k == "tid":
            c_tid += coeff
        elif base in uniform:
            has_uniform = True
        else:
            return None
    return (c_gid + c_tid, c_gid * block_size + c_bid, has_uniform)


def _store_injective(ct: int, cb: int, num_blocks: int,
                     block_size: int) -> bool:
    """Does the store form hit a distinct element for every thread of the
    launch, wrap-safely under i32?  Degenerate grids only need one axis;
    the general case requires the (bid, tid) dependence to collapse onto
    the flat global id (``cb == ct * T``) with an injective step."""
    B, T = num_blocks, block_size
    if B <= 1:
        return injective_step(ct, T)
    if T <= 1:
        return injective_step(cb, B)
    return cb == ct * T and injective_step(ct, B * T)


#: The stable refusal-reason vocabulary of :func:`block_lower` (plus
#: ``disabled``, emitted by the pallas backend when the env switch is
#: off).  Every reason string is ``<category>`` or ``<category>:<detail>``
#: where the category is drawn from this tuple — histograms, gates
#: (``scripts/check_zoo.py``) and docs (``docs/ZOO.md``) key on the
#: category; the detail (offending buffer / opcode) is diagnostic only
#: and carries no stability promise.  Adding a category is an API change:
#: document it and extend the regression test in tests/test_model_zoo.py.
REFUSAL_REASONS = ("bad-block", "shared-memory", "collective", "atomic",
                   "opaque-index", "unprovable-base", "store-not-injective",
                   "may-alias", "disabled")


def refusal_category(reason: str) -> str:
    """Stable category of a :func:`block_lower` refusal reason: the part
    before the first ``:`` (reasons are ``category[:detail]``).  Always a
    member of :data:`REFUSAL_REASONS` for reasons this module emits."""
    return reason.split(":", 1)[0]


def block_lower(stmts: Sequence[ir.Stmt], num_blocks: int, block_size: int,
                block: int,
                buffer_lens: Optional[Dict[str, int]] = None
                ) -> Tuple[Optional[BlockPlan], Optional[str]]:
    """Prove a barrier-free segment *lane-independent* and rewrite it into
    block-primitive form; returns ``(plan, None)`` on success or
    ``(None, reason)`` when the proof fails (the pallas backend then keeps
    the scalar-per-thread path and surfaces ``reason`` in its stats).

    A segment is lane-independent when reordering its threads into
    arbitrary flat tiles of ``block`` elements cannot change any result
    bit.  The proof obligations, checked in order:

    * **no cross-thread traffic by construction** — no shared-memory ops,
      no collectives, no ``ATOMIC_ADD`` (its returned old value is
      execution-order-dependent).  Loop trip counts are uniform by hetIR
      construction (an int literal or a uniform scalar param), so there
      are no divergent loop trips to consider.
    * **global stores are thread-injective** — every store index must have
      an affine form (:mod:`~repro.core.alias`) over thread-identity bases
      (``GET_GLOBAL_ID``/``GET_BLOCK_ID``/``GET_THREAD_ID``) and
      launch-uniform values only, whose effective per-thread step is
      injective over the launch under i32 wraparound
      (:func:`~repro.core.alias.injective_step`).  Loop-variable bases
      fail the proof: a store whose address varies per iteration could
      collide with another thread's address from a *different* iteration,
      which tile reordering would then order differently.
    * **same-buffer accesses are per-lane or disjoint** — for every buffer
      the segment writes, each (load, store) and (store, store) pair must
      either share an *identical* index form (the per-lane slot: program
      order within a lane is preserved by any tiling, and store
      injectivity rules out cross-lane hits) or be provably disjoint under
      :func:`~repro.core.alias.may_alias`.  Buffers only read are
      unconstrained (gather tiles never conflict).

    Buffers whose every access is exactly the flat global id *and* whose
    length (from ``buffer_lens``, the launch record's buffer shapes) is
    exactly ``num_blocks * block_size`` become BlockSpec-tiled
    (``mode="tiled"``); all other accesses gather from the whole staged
    buffer (``mode="gather"``)."""
    B, T = int(num_blocks), int(block_size)
    N = B * T
    if block <= 0 or N % block:
        return None, "bad-block"

    for op in ir.walk_ops(stmts):
        if op.opcode in (ir.LD_SHARED, ir.ST_SHARED):
            return None, "shared-memory"
        if op.opcode in ir.COLLECTIVE_OPS:
            return None, f"collective:{op.opcode}"
        if op.opcode == ir.ATOMIC_ADD:
            return None, "atomic"

    env = affine_env(stmts)
    defs = ir.reg_def_counts(stmts)
    uniform = _uniform_regs(stmts)
    kinds: Dict[str, str] = {}
    for op in ir.walk_ops(stmts):
        if op.dest is not None and defs.get(op.dest.name, 0) == 1 \
                and op.opcode in _THREAD_BASES:
            kinds[op.dest.name] = _THREAD_BASES[op.opcode]

    reads, writes = body_mem_accesses(stmts)
    per_buf: Dict[str, list] = {}
    for is_store, accs in ((False, reads), (True, writes)):
        for space, buf, idx in accs:
            assert space == GLOBAL_SPACE  # shared ops rejected above
            per_buf.setdefault(buf, []).append(
                (is_store, index_form(idx, env, defs)))

    written = {buf for _, buf, _ in writes}
    for buf in sorted(written):
        forms = per_buf[buf]
        for is_store, f in forms:
            if f is None:
                return None, f"opaque-index:{buf}"
            if _decompose(f, kinds, uniform, T) is None:
                return None, f"unprovable-base:{buf}"
        store_forms = [f for is_store, f in forms if is_store]
        for fs in store_forms:
            ct, cb, _ = _decompose(fs, kinds, uniform, T)
            if not _store_injective(ct, cb, B, T):
                return None, f"store-not-injective:{buf}"
        for fs in store_forms:
            for _, f in forms:
                if f == fs:
                    continue  # identical form: the per-lane slot
                if may_alias(f, fs):
                    return None, f"may-alias:{buf}"

    tiled = set()
    for buf, forms in per_buf.items():
        if buffer_lens is None or buffer_lens.get(buf) != N:
            continue
        ok = True
        for _, f in forms:
            dec = None if f is None else _decompose(f, kinds, uniform, T)
            if dec != (1, T, False) or f.const != 0:
                ok = False
                break
        if ok:
            tiled.add(buf)

    def rw(op: ir.Op):
        if op.opcode in (ir.LD_GLOBAL, ir.ST_GLOBAL):
            mode = "tiled" if op.args[0] in tiled else "gather"
            oc = ir.BLOCK_LD if op.opcode == ir.LD_GLOBAL else ir.BLOCK_ST
            return ir.Op(oc, op.dest, op.args,
                         {"block": int(block), "mode": mode})
        return op

    plan = BlockPlan(stmts=tuple(ir.rewrite_body(list(stmts), rw)),
                     tiled=frozenset(tiled), block=int(block),
                     grid=N // int(block))
    return plan, None
