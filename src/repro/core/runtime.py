"""HetSession — the hetGPU abstraction layer (paper §4.3).

Presents the uniform device API the paper describes: buffer allocation,
kernel launch with CUDA-like ``<<<grid, block>>>`` geometry, streams with
in-order semantics, cooperative checkpoint (pause flag honoured at
barriers), restore, and live migration between backends.  The "JIT
modules" are entries in the shared :class:`~repro.core.cache.
TranslationCache` (paper §4.2), whose hit/miss/restore/eviction counters
this session surfaces via :meth:`HetSession.cache_stats` and ``stats``;
kernels launch through the :mod:`~repro.core.passes` pipeline at the
session's ``opt_level``.

Two cluster-lifetime amortization hooks sit here (paper §4.2 pays JIT cost
once per kernel, not once per process): a session may be bound to a
persistent :class:`~repro.core.cache.DiskStore` (``store=``) so its
translations outlive the process, and :meth:`HetSession.warmup` ahead-of-
time translates a kernel set, reporting what was restored from disk versus
freshly translated.  :func:`migrate` preloads the destination session's
cache from the source's store, so a live migration lands on a node whose
runtime already holds the translated segments it is about to execute.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence, Tuple, Union

import numpy as np

from . import hetir as ir
from .backends import get_backend
from .backends.base import Backend
from .cache import DiskStore, TranslationCache
from .engine import Engine
from .passes import DEFAULT_OPT_LEVEL, OPT_MAX
from .state import Snapshot


@dataclass
class _KernelHandle:
    program: ir.Program


@dataclass
class LaunchRecord:
    engine: Engine
    finished: bool = False


class HetSession:
    """One "device context" bound to a backend, with migration support."""

    def __init__(self, backend: str = "vectorized",
                 opt_level: Optional[int] = None,
                 cache: Optional[TranslationCache] = None,
                 store: Optional[Union[str, DiskStore]] = None,
                 specialize: Optional[bool] = None):
        # specialize: None = policy default (HETGPU_SPECIALIZE / auto),
        # True = force launch-time specialization, False = always generic
        self.specialize = specialize
        self.backend_name = backend
        if store is not None and not isinstance(store, DiskStore):
            store = DiskStore(store)
        if cache is None and store is not None:
            # a session opened "against a store": private memory tier,
            # persistent disk tier — translations survive this process
            cache = TranslationCache(store=store)
        elif cache is not None and store is not None:
            if cache.store is None:
                cache.store = store
            elif cache.store.dir.resolve() != store.dir.resolve():
                raise ValueError(
                    "cache is already bound to a different store "
                    f"({cache.store.dir}); refusing to silently ignore "
                    f"store={store.dir}")
        self.backend: Backend = get_backend(backend, cache=cache)
        self.cache: TranslationCache = self.backend.cache
        self.opt_level = DEFAULT_OPT_LEVEL if opt_level is None \
            else max(0, min(int(opt_level), OPT_MAX))
        self._kernels: Dict[str, _KernelHandle] = {}
        self._buffers: Dict[str, np.ndarray] = {}
        self._streams: Dict[int, List[LaunchRecord]] = {0: []}
        self.pause_flag = False  # the paper's cooperative pause flag
        self.stats = {"launches": 0, "translation_ms": 0.0,
                      "migrations": 0, "cache_hits": 0, "cache_misses": 0,
                      "cache_evictions": 0, "cache_restored": 0,
                      "cache_translated": 0}

    def cache_stats(self) -> Dict[str, object]:
        """Shared translation-cache counters (paper §4.2 JIT cache)."""
        return self.cache.stats()

    def _sync_cache_stats(self) -> None:
        st = self.cache.stats()
        self.stats["cache_hits"] = st["hits"]
        self.stats["cache_misses"] = st["misses"]
        self.stats["cache_evictions"] = st["evictions"]
        self.stats["cache_restored"] = st["restored"]
        self.stats["cache_translated"] = st["translated"]

    # -- module loading ------------------------------------------------
    def load_kernel(self, program: ir.Program) -> str:
        """Register a hetIR "binary".  Translation happens lazily at first
        launch (paper §4.2 Module Loading and JIT)."""
        program.validate()
        self._kernels[program.name] = _KernelHandle(program)
        return program.name

    # -- cache warm-up ---------------------------------------------------
    def warmup(self, programs: Iterable, grids: Sequence[Tuple[int, int]]
               = ((2, 32),)) -> Dict[str, object]:
        """Ahead-of-time translate a kernel set (paper §4.2: JIT cost is
        paid per *cluster lifetime* — a node expecting migrated work can
        translate it before the work arrives).

        ``programs`` is an iterable of ``ir.Program`` or ``(ir.Program,
        example_args)`` pairs; ``grids`` is a sequence of ``(grid, block)``
        geometries to specialize for.  When no example args are given they
        are synthesized (unit scalars, zero buffers sized ``grid*block``)
        and any kernel the synthetic args cannot drive is reported —
        warm-up is best-effort by design.  Each warm-up launch runs on
        scratch copies; session buffers are untouched.

        Returns a report: per-kernel status plus how many segments were
        ``restored`` from the disk store versus freshly ``translated``
        (warm restarts should see ``translated == 0``).
        """
        report: Dict[str, object] = {"kernels": [], "translated": 0,
                                     "restored": 0, "cache_hits": 0,
                                     "errors": 0}
        for item in programs:
            prog, args = item if isinstance(item, tuple) else (item, None)
            for grid, block in grids:
                before = self.cache.stats()
                entry = {"kernel": prog.name, "grid": grid, "block": block}
                t0 = time.perf_counter()
                try:
                    use_args = dict(args) if args is not None else \
                        _synthesize_args(prog, grid, block)
                    # synthesized args carry made-up unit scalars: never
                    # specialize on them — it would warm (and persist) a
                    # variant no real launch will ask for, and burn one of
                    # the program's specialization-budget slots.  The
                    # generic entries warmed instead are the ones budget
                    # fallbacks and policy-off launches share.  Explicit
                    # example args warm whatever a real launch would run.
                    eng = Engine(prog, self.backend, grid, block, use_args,
                                 opt_level=self.opt_level,
                                 specialize=(False if args is None
                                             else self.specialize))
                    eng.run()
                    entry["status"] = "ok"
                except Exception as exc:  # best-effort: report, don't raise
                    entry["status"] = f"error: {type(exc).__name__}: {exc}"
                    report["errors"] += 1
                after = self.cache.stats()
                for field_ in ("translated", "restored"):
                    delta = after[field_] - before[field_]
                    entry[field_] = delta
                    report[field_] += delta
                entry["cache_hits"] = after["hits"] - before["hits"]
                report["cache_hits"] += entry["cache_hits"]
                entry["ms"] = round((time.perf_counter() - t0) * 1e3, 2)
                report["kernels"].append(entry)
        self._sync_cache_stats()
        return report

    # -- memory management ----------------------------------------------
    def gpu_malloc(self, name: str, shape, dtype=np.float32) -> np.ndarray:
        buf = np.zeros(shape, dtype=dtype)
        self._buffers[name] = buf
        return buf

    def memcpy_h2d(self, name: str, host: np.ndarray) -> None:
        self._buffers[name] = np.array(host, copy=True)

    def memcpy_d2h(self, name: str) -> np.ndarray:
        return self._buffers[name].copy()

    # -- kernel launch ----------------------------------------------------
    def launch(self, kernel: str, grid: int, block: int,
               args: Dict[str, object], stream: int = 0,
               blocking: bool = True) -> LaunchRecord:
        handle = self._kernels[kernel]
        merged = {}
        for p in handle.program.params:
            if p.name in args:
                merged[p.name] = args[p.name]
            elif isinstance(p, ir.Ptr) and p.name in self._buffers:
                merged[p.name] = self._buffers[p.name]
            else:
                raise ValueError(f"missing argument {p.name}")
        t0 = time.perf_counter()
        eng = Engine(handle.program, self.backend, grid, block, merged,
                     opt_level=self.opt_level, specialize=self.specialize)
        rec = LaunchRecord(engine=eng)
        self._streams.setdefault(stream, []).append(rec)
        self.stats["launches"] += 1
        self.stats["last_opt"] = eng.opt_stats.as_dict()
        self.stats["last_spec_key"] = eng.spec_key
        if eng.spec_key:
            self.stats["specialized_launches"] = \
                self.stats.get("specialized_launches", 0) + 1
        if blocking:
            rec.finished = eng.run(pause_flag=lambda: self.pause_flag)
            self._writeback(handle.program, eng, args)
        self.stats["translation_ms"] += (time.perf_counter() - t0) * 1e3
        self._sync_cache_stats()
        return rec

    def _writeback(self, program: ir.Program, eng: Engine,
                   args: Dict[str, object]) -> None:
        """Propagate kernel writes back into session buffers."""
        for p in program.buffers():
            if p.name in self._buffers and p.name not in args:
                self._buffers[p.name] = eng.result(p.name)

    def device_synchronize(self, stream: int = 0) -> None:
        for rec in self._streams.get(stream, []):
            if not rec.finished:
                rec.finished = rec.engine.run(
                    pause_flag=lambda: self.pause_flag)

    # -- checkpoint / restore / migration ---------------------------------
    def checkpoint(self, rec: LaunchRecord) -> bytes:
        """Serialize a paused (or finished) launch — the migration payload."""
        return rec.engine.snapshot().to_bytes()

    def restore(self, kernel: str, blob: bytes) -> LaunchRecord:
        snap = Snapshot.from_bytes(blob)
        eng = Engine.resume(self._kernels[kernel].program, self.backend,
                            snap)
        rec = LaunchRecord(engine=eng, finished=eng.finished)
        self._streams[0].append(rec)
        return rec

    def run_to_completion(self, rec: LaunchRecord) -> None:
        rec.finished = rec.engine.run(pause_flag=lambda: self.pause_flag)
        self._sync_cache_stats()


def _synthesize_args(prog: ir.Program, grid: int,
                     block: int) -> Dict[str, object]:
    """Best-effort example arguments for warm-up launches: unit scalars
    and ``grid*block``-sized zero buffers (covers gid-indexed kernels; a
    kernel needing real geometry scalars should be warmed with explicit
    example args)."""
    args: Dict[str, object] = {}
    for p in prog.params:
        if isinstance(p, ir.Ptr):
            args[p.name] = np.zeros(grid * block, dtype=ir.np_dtype(p.dtype))
        else:
            args[p.name] = ir.np_dtype(p.dtype).type(1)
    return args


def migrate(rec: LaunchRecord, src: HetSession, dst: HetSession,
            kernel: str) -> LaunchRecord:
    """Live-migrate a launch from one session/backend to another
    (paper §6.3). Returns the resumed launch on ``dst``; timing stats are
    recorded on both sessions.

    Before resuming, the destination's translation cache is preloaded from
    whichever persistent store is reachable (its own, else the source's):
    if this program has ever been translated for the destination backend
    within the store's lifetime, the migrated launch pays near-zero
    translation cost — the paper's cluster-lifetime JIT amortization.

    Specialization keys ride along: the snapshot records the source
    engine's bound-scalar vector, ``Engine.resume`` re-derives the
    identical specialized body from it (never re-consulting the policy),
    and the fingerprint used for the preload below is the *specialized*
    program's — so a mid-kernel checkpoint of a specialized kernel
    restores bit-identical, against warm specialized translations."""
    t0 = time.perf_counter()
    blob = src.checkpoint(rec)  # capture at barrier
    t1 = time.perf_counter()
    # warm the destination from the persistent tier: the engine's program
    # is the *optimized* body, whose fingerprint is what cache keys carry
    fp = ir.program_fingerprint(rec.engine.program)
    store = dst.cache.store if dst.cache.store is not None \
        else src.cache.store
    restored = 0
    if store is not None:
        restored = dst.cache.preload(backend=dst.backend_name,
                                     fingerprint=fp, store=store)
    t2 = time.perf_counter()
    new = dst.restore(kernel, blob)  # reload + reshard onto new device
    t3 = time.perf_counter()
    src.stats["migrations"] += 1
    dst.stats["migrations"] += 1
    dst.stats.setdefault("last_migration", {})
    dst.stats["last_migration"] = {
        "checkpoint_ms": (t1 - t0) * 1e3,
        "warmup_ms": (t2 - t1) * 1e3,
        "restore_ms": (t3 - t2) * 1e3,
        "payload_bytes": len(blob),
        "cache_restored": restored,
    }
    return new
