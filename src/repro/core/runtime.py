"""HetSession — the hetGPU abstraction layer as a driver-style API
(paper §4.3).

The paper promises "a uniform abstraction of threads, memory, and
synchronization"; this module presents it the way the CUDA Driver / HIP
APIs present theirs — as an *object model*, not a string-keyed grab-bag:

* :meth:`HetSession.load` turns a hetIR "binary" into a :class:`Module`;
  :meth:`Module.function` returns a :class:`Function` carrying typed
  parameter metadata (buffer vs scalar, dtype).
* :meth:`HetSession.alloc` returns a first-class :class:`DeviceBuffer`
  handle.  Kernels mutate buffers **in place** — results land in the very
  buffer object that was passed, with explicit
  :meth:`DeviceBuffer.copy_to_host` / :meth:`~DeviceBuffer.copy_from_host`
  transfers and no name-matching writeback.
* :meth:`Function.launch_async` enqueues onto a real :class:`Stream` and
  returns a :class:`LaunchRecord` future.  A cooperative priority /
  weighted-fair-share scheduler interleaves *segments* (the unit between
  barriers — see :mod:`~repro.core.engine`) from concurrent streams —
  equal weights degenerate to exact round-robin — so two async launches
  genuinely overlap at segment granularity, observable in
  ``HetSession.sched_trace`` (a capped ring).
* :class:`Event` objects give cross-stream ordering
  (:meth:`Stream.record_event` / :meth:`Stream.wait_event` / ``query`` /
  ``synchronize``), with CUDA's semantics (waiting on a never-recorded
  event is a no-op).
* The scheduler is a **priority / weighted-fair-share** segment scheduler
  (the serving tier): each :class:`Stream` carries a ``weight`` and a
  ``priority``, the session picks the runnable stream with the highest
  priority and least weighted virtual time for each segment, preemption
  happens at segment boundaries (via the engine's segment-boundary yield
  hook), and a starvation guard periodically serves the longest-waiting
  stream so zero-weight / low-priority work still progresses.
* :meth:`DeviceBuffer.copy_from_host_async` /
  :meth:`~DeviceBuffer.copy_to_host_async` enqueue data movement as
  stream work items, so copies participate in stream ordering *and* in
  scheduling; :meth:`HetSession.alloc` sub-allocates from a bounded
  :class:`~repro.core.pool.BufferPool` so short-lived serving buffers
  reuse backings instead of thrashing the host allocator.
* ``checkpoint`` / :func:`migrate` work on in-flight async launches at
  their next barrier; :class:`DeviceBuffer` identity survives restore
  within a session (a restored launch re-binds the live buffer by uid)
  and migration carries uids so a chain of hops stays identity-stable.

The "JIT modules" are entries in the shared :class:`~repro.core.cache.
TranslationCache` (paper §4.2), surfaced via :meth:`HetSession.
cache_stats` and ``stats`` (``translate_ms`` from cache counters,
``launch_ms`` for end-to-end launch work — ``translation_ms`` is a
deprecated alias of ``translate_ms``).  The cluster-lifetime amortization
hooks: a session bound to a persistent :class:`~repro.core.cache.
DiskStore` (``store=``) and/or the cluster fabric's
:class:`~repro.core.cache.SharedStore` (``shared=``), plus
:meth:`HetSession.warmup` ahead-of-time translation; :func:`migrate`
preloads the destination cache from the fabric.

The old string-keyed surface (``load_kernel`` / ``gpu_malloc`` /
``memcpy_h2d`` / ``memcpy_d2h`` / ``launch`` / ``device_synchronize``)
survives as a thin deprecated shim on top of the object model — each call
raises :class:`DeprecationWarning` and is mapped in docs/API.md's
old→new table.
"""
from __future__ import annotations

import itertools
import os
import time
import uuid
import warnings
from collections import deque
from dataclasses import dataclass
from typing import (Callable, Dict, Iterable, List, Optional, Sequence,
                    Tuple, Union)

import numpy as np

from . import hetir as ir
from .backends import get_backend
from .backends.base import Backend
from .cache import DiskStore, SharedStore, TranslationCache
from .engine import Engine
from .passes import DEFAULT_OPT_LEVEL, OPT_MAX
from .pool import BufferPool
from .state import Snapshot

#: default ``sched_trace`` ring capacity — generous enough that every
#: existing test window fits whole, small enough that a serving session
#: driving millions of segments stays bounded
_DEFAULT_TRACE_CAP = 100_000

#: a zero-weight stream's virtual time advances this fast per segment —
#: effectively "never pick me on weight grounds"; only the starvation
#: guard (or an otherwise-idle session) serves it
_ZERO_WEIGHT_RATE = 1e9


class TraceRing:
    """The scheduler trace as a capped ring buffer: list-like for readers
    (iteration, indexing, ``len``, ``clear``), but appends past ``cap``
    evict the oldest entry and bump ``dropped`` — a serving session that
    executes millions of segments keeps a bounded window of the most
    recent ones instead of leaking one dict per segment."""

    __slots__ = ("_d", "dropped")

    def __init__(self, cap: int = _DEFAULT_TRACE_CAP):
        if cap <= 0:
            raise ValueError(f"trace cap must be positive, got {cap}")
        self._d: deque = deque(maxlen=int(cap))
        self.dropped = 0

    @property
    def cap(self) -> int:
        return self._d.maxlen

    def append(self, item: Dict[str, object]) -> None:
        if len(self._d) == self._d.maxlen:
            self.dropped += 1
        self._d.append(item)

    def clear(self) -> None:
        """Empty the window (``dropped`` stays cumulative)."""
        self._d.clear()

    def __len__(self) -> int:
        return len(self._d)

    def __iter__(self):
        return iter(self._d)

    def __getitem__(self, idx):
        if isinstance(idx, slice):
            return list(self._d)[idx]
        return self._d[idx]

    def __bool__(self) -> bool:
        return bool(self._d)

    def __repr__(self) -> str:
        return (f"<TraceRing {len(self._d)}/{self.cap} "
                f"dropped={self.dropped}>")

# Buffer uids must stay unique across sessions *and* across processes
# (snapshots carry them; restore re-binds by uid, and a false match would
# silently alias two unrelated buffers), so they carry a per-process salt.
_UID_SALT = uuid.uuid4().hex[:8]
_UID_COUNTER = itertools.count()


def _next_uid() -> str:
    return f"b{_UID_SALT}-{next(_UID_COUNTER)}"


def _deprecated(old: str, new: str) -> None:
    warnings.warn(
        f"HetSession.{old} is deprecated; use {new} instead "
        "(driver-style API — see docs/API.md for the old→new table)",
        DeprecationWarning, stacklevel=3)


# ---------------------------------------------------------------------------
# Device memory
# ---------------------------------------------------------------------------

class DeviceBuffer:
    """A typed handle to linear device memory (the driver-API analogue of a
    ``CUdeviceptr``).  Buffers are 1-D — like driver allocations they are a
    span of elements, and kernels index them linearly; ``copy_from_host``
    accepts any host array of matching total size and flattens it.

    Kernels mutate the buffer **in place**: after a launch that bound this
    buffer completes, ``data`` holds the kernel's writes — same object,
    no name matching, no implicit writeback.  Host transfers are explicit
    (:meth:`copy_to_host` returns a defensive copy); the ``_async``
    variants enqueue the transfer on a :class:`Stream` instead, so it
    runs in stream order and participates in scheduling.

    Backings come from the session's :class:`~repro.core.pool.BufferPool`
    when one is attached: ``data`` is a ``size``-element view of a
    size-class backing, and :meth:`free` returns the backing for reuse.
    """

    __slots__ = ("session", "uid", "dtype", "data", "freed", "_backing")

    def __init__(self, session: "HetSession", size: int,
                 dtype: object = np.float32, uid: Optional[str] = None,
                 pool: Optional[BufferPool] = None):
        self.session = session
        self.uid = uid if uid is not None else _next_uid()
        # non-hetIR dtypes (f64, f16, ...) are allocatable for host-side
        # staging — the legacy memcpy surface accepted them — but carry
        # dtype=None and are rejected by the typed Function binding
        try:
            self.dtype: Optional[str] = ir.ir_dtype(dtype)
            np_dt = ir.np_dtype(self.dtype)
        except TypeError:
            self.dtype = None
            np_dt = np.dtype(dtype)
        size = int(size)
        if pool is not None:
            self._backing: Optional[np.ndarray] = pool.take(size, np_dt)
            self.data = self._backing[:size]
        else:
            self._backing = None
            self.data = np.zeros(size, dtype=np_dt)
        self.freed = False

    # -- geometry ----------------------------------------------------------
    @property
    def size(self) -> int:
        return self.data.size

    @property
    def nbytes(self) -> int:
        return self.data.nbytes

    @property
    def np_dtype(self) -> np.dtype:
        return self.data.dtype

    # -- transfers ---------------------------------------------------------
    def copy_from_host(self, host) -> "DeviceBuffer":
        """Explicit H2D: copy ``host`` (any shape, matching total size)
        into this buffer.  Returns ``self`` for chaining."""
        self._check_alive()
        arr = np.asarray(host)
        if arr.size != self.size:
            raise ValueError(
                f"host array has {arr.size} elements, buffer holds "
                f"{self.size}")
        np.copyto(self.data, arr.reshape(-1), casting="same_kind")
        return self

    def copy_to_host(self) -> np.ndarray:
        """Explicit D2H: a defensive host copy of the buffer contents."""
        self._check_alive()
        return self.data.copy()

    # -- asynchronous transfers (stream work items) ------------------------
    def copy_from_host_async(self, host, stream: Optional["Stream"] = None
                             ) -> "CopyRecord":
        """Enqueue an H2D copy on ``stream`` (default stream if None) and
        return a :class:`CopyRecord` future.  The copy executes in stream
        order — work enqueued after it observes the new contents — and
        counts as one unit of scheduled work, so data movement competes
        under the same fair-share policy as kernels.  Like the driver
        APIs' async memcpy, ``host`` must stay unmodified until the copy
        completes (the array is referenced, not staged)."""
        self._check_alive()
        arr = np.asarray(host)
        if arr.size != self.size:
            raise ValueError(
                f"host array has {arr.size} elements, buffer holds "
                f"{self.size}")
        return self.session._enqueue_copy("h2d", self, stream, host=arr)

    def copy_to_host_async(self, stream: Optional["Stream"] = None
                           ) -> "CopyRecord":
        """Enqueue a D2H copy on ``stream``; the returned
        :class:`CopyRecord`'s :meth:`~CopyRecord.result` holds the buffer
        contents *as of the copy's position in the stream* once it
        completes (enqueue before a launch to read the pre-launch
        state — CUDA stream semantics)."""
        self._check_alive()
        return self.session._enqueue_copy("d2h", self, stream)

    def fill(self, value) -> "DeviceBuffer":
        self._check_alive()
        self.data.fill(value)
        return self

    def free(self) -> None:
        """Release the handle (drops the session's uid registration; a
        later restore can no longer re-bind this buffer, and queued work
        that still binds it fails with a use-after-free error when it
        reaches the stream front).  The backing returns to the session's
        buffer pool for reuse.  Idempotent."""
        if self.freed:
            return
        self.session._buffers_by_uid.pop(self.uid, None)
        self.freed = True
        backing, self._backing = self._backing, None
        if backing is not None:
            self.session.pool.release(backing)

    def _check_alive(self) -> None:
        if self.freed:
            raise ValueError(f"buffer {self.uid} has been freed")

    def __repr__(self) -> str:
        state = "freed" if self.freed else f"{self.size}x{self.dtype}"
        return f"<DeviceBuffer {self.uid} {state}>"


# ---------------------------------------------------------------------------
# Events and streams
# ---------------------------------------------------------------------------

class Event:
    """A stream ordering marker (CUDA-event semantics): ``record`` places
    it in a stream's work queue, it *completes* when everything enqueued
    before it on that stream has finished, and other streams can
    :meth:`Stream.wait_event` on it.  Waiting on a never-recorded event is
    a no-op, exactly as in the driver APIs."""

    def __init__(self, session: Optional["HetSession"] = None):
        self._session = session
        self._recorded = False
        self._complete = False
        # bumped on every record: a re-recorded event must only complete
        # at its *latest* record point — stale queue markers from earlier
        # records retire without completing it.  Waits capture the
        # generation current when the wait was issued (CUDA: a wait refers
        # to the most recent record *at wait time*, unaffected by later
        # re-records), and unblock once that record point is reached.
        self._generation = 0
        self._last_retired_generation = 0

    def query(self) -> bool:
        """Non-blocking completion check (retires any ripe queue markers
        first; never executes kernel segments)."""
        if self._session is not None:
            self._session._settle()
        return self._complete

    def synchronize(self) -> bool:
        """Drive the scheduler until this event completes.  Returns False
        if progress stopped on a paused stream (cooperative checkpoint)."""
        if not self._recorded:
            raise RuntimeError("cannot synchronize an event that was "
                               "never recorded")
        return self._session._drain(until=lambda: self._complete)


@dataclass
class _EventRecord:
    """Queue marker: the recording point of an Event (at a specific
    record generation — markers from superseded records are stale)."""
    event: Event
    generation: int


@dataclass
class _EventWait:
    """Queue marker: this stream blocks until the record point ``event``
    had when the wait was issued (``generation``) is reached."""
    event: Event
    generation: int

    def satisfied(self) -> bool:
        return self.event._last_retired_generation >= self.generation


class CopyRecord:
    """Future for an asynchronous host↔device copy: a first-class stream
    work item (the scheduler executes it at the stream front, one
    scheduling unit, traced as ``<h2d>`` / ``<d2h>``), with the same
    future surface as a :class:`LaunchRecord` (``done`` / ``wait``)."""

    __slots__ = ("session", "kind", "buffer", "stream", "seq", "finished",
                 "_host", "_array")

    def __init__(self, session: "HetSession", kind: str,
                 buffer: DeviceBuffer, stream: "Stream",
                 host: Optional[np.ndarray] = None):
        self.session = session
        self.kind = kind                      # "h2d" | "d2h"
        self.buffer = buffer
        self.stream = stream
        self.seq = next(session._seq)
        self.finished = False
        self._host = host
        self._array: Optional[np.ndarray] = None

    def done(self) -> bool:
        return self.finished

    def wait(self) -> bool:
        """Drive the scheduler until this copy completes.  Returns False
        if blocked by paused work."""
        ok = self.session._drain(until=lambda: self.finished)
        return ok and self.finished

    def result(self) -> np.ndarray:
        """The copied host array (D2H only) — waits if still pending."""
        if self.kind != "d2h":
            raise ValueError("result() is only defined for d2h copies")
        if not self.finished and not self.wait():
            raise RuntimeError("d2h copy blocked on paused work")
        return self._array

    def _execute(self) -> None:
        db = self.buffer
        if db.freed:
            raise RuntimeError(
                f"async {self.kind} copy #{self.seq} on stream "
                f"{self.stream.sid}: buffer {db.uid} was freed before the "
                "copy reached the stream front — device memory must stay "
                "alive until queued work that binds it has run")
        if self.kind == "h2d":
            np.copyto(db.data, self._host.reshape(-1), casting="same_kind")
            self._host = None
        else:
            self._array = db.data.copy()
        self.finished = True

    def __repr__(self) -> str:
        state = "finished" if self.finished else "queued"
        return (f"<CopyRecord #{self.seq} {self.kind} {self.buffer.uid} "
                f"stream={self.stream.sid} {state}>")


class Stream:
    """An in-order work queue with genuinely asynchronous execution: the
    session's priority / weighted-fair-share scheduler hands out segments
    to runnable streams.  Within a stream, a launch only *starts* (binds
    its buffers and translates) once everything before it has completed —
    so same-stream dataflow through a :class:`DeviceBuffer` behaves like
    CUDA stream ordering.

    Scheduling knobs (the serving tier's policy surface):

    * ``weight`` — fair-share weight: over any window where a set of
      streams stays backlogged, each receives segment service roughly
      proportional to its weight.  ``0`` opts the stream out of weighted
      competition entirely; it only runs via the starvation guard or when
      nothing else is runnable.
    * ``priority`` — strict tiers: a runnable higher-priority stream is
      always served first (and *preempts* a lower-priority stream's
      multi-segment quantum at the next segment boundary).  Fair share
      applies within a tier.  The starvation guard is the backstop that
      keeps lower tiers alive under sustained high-priority load.
    * ``quantum`` — segments granted per scheduling decision (default 1 =
      finest interleaving; serving fronts raise it to cut scheduler
      overhead per segment).
    """

    def __init__(self, session: "HetSession", sid: int,
                 weight: float = 1.0, priority: int = 0, quantum: int = 1):
        if weight < 0:
            raise ValueError(f"stream weight must be >= 0, got {weight}")
        self.session = session
        self.sid = sid
        self.weight = float(weight)
        self.priority = int(priority)
        self.quantum = max(1, int(quantum))
        self._q: deque = deque()
        #: cooperative per-stream pause: the scheduler stops stepping this
        #: stream's launches (they hold at their current barrier — the
        #: checkpoint hook), while other streams keep running.
        self.paused = False
        self.destroyed = False
        # weighted-fair bookkeeping: virtual time consumed (advances by
        # 1/weight per executed segment) and the pick counter at the last
        # scheduling decision that chose this stream (starvation guard +
        # tie-break).
        self._vtime = 0.0
        self._last_pick = 0

    # -- queue state -------------------------------------------------------
    def query(self) -> bool:
        """True iff all work enqueued on this stream has completed."""
        self.session._settle()
        return not self._q

    def synchronize(self) -> bool:
        """Drive the scheduler until this stream drains.  Returns False if
        progress stopped on paused work."""
        return self.session._drain(until=lambda: not self._q)

    # -- retirement --------------------------------------------------------
    def destroy(self) -> None:
        """Retire the stream: refuse further work and drop it from the
        scheduler's scan set, so long-lived sessions that create many
        short-lived streams keep stepping in O(active streams).  Refuses
        while work is pending (drain first); the default stream cannot be
        destroyed."""
        if self.destroyed:
            return
        self.session._settle()
        if self._q:
            raise RuntimeError(
                f"cannot destroy stream {self.sid}: {len(self._q)} work "
                "item(s) still pending — synchronize() it first")
        if self is self.session.default_stream:
            raise ValueError("the default stream cannot be destroyed")
        self.destroyed = True
        self.session._retire_stream(self)

    def _check_usable(self) -> None:
        if self.destroyed:
            raise RuntimeError(
                f"stream {self.sid} has been destroyed — create a new "
                "stream with session.stream()")

    # -- pause (cooperative checkpoint) ------------------------------------
    def pause(self) -> None:
        self.paused = True

    def resume(self) -> None:
        self.paused = False

    # -- events ------------------------------------------------------------
    def record_event(self, event: Optional[Event] = None) -> Event:
        self._check_usable()
        ev = event if event is not None else Event(self.session)
        ev._session = self.session
        ev._recorded = True
        ev._complete = False
        ev._generation += 1             # invalidates earlier queue markers
        self._q.append(_EventRecord(ev, ev._generation))
        self.session._settle()          # empty queue => completes at once
        return ev

    def wait_event(self, event: Event) -> None:
        """Block this stream's later work until ``event``'s *current*
        record point is reached (CUDA semantics: a later re-record does
        not move an already-issued wait).  A never-recorded or
        already-complete event is a no-op."""
        self._check_usable()
        self.session._settle()
        if not event._recorded \
                or event._last_retired_generation >= event._generation:
            return
        self._q.append(_EventWait(event, event._generation))

    def _enqueue(self, rec) -> None:
        self._check_usable()
        if not self._q:
            # idle -> runnable: catch the virtual clock up so a stream
            # that slept does not monopolize the scheduler on wake
            self._vtime = max(self._vtime, self.session._vclock)
        self._q.append(rec)

    def _charge(self, units: float = 1.0) -> None:
        """Advance virtual time by ``units`` of weighted service."""
        rate = 1.0 / self.weight if self.weight > 0 else _ZERO_WEIGHT_RATE
        self._vtime += units * rate

    def _describe_front(self) -> str:
        if not self._q:
            return "empty"
        item = self._q[0]
        if isinstance(item, _EventWait):
            return "waiting on event"
        if isinstance(item, _EventRecord):
            return "event record"
        if isinstance(item, CopyRecord):
            return f"{item.kind} copy #{item.seq}"
        return f"launch #{item.seq} ({item.program_name})"

    def __repr__(self) -> str:
        flags = " paused" if self.paused else ""
        flags += " destroyed" if self.destroyed else ""
        return (f"<Stream {self.sid} w={self.weight:g} p={self.priority} "
                f"depth={len(self._q)}{flags}>")


# ---------------------------------------------------------------------------
# Launches
# ---------------------------------------------------------------------------

class LaunchRecord:
    """Future for an enqueued kernel launch.

    A record enqueued via :meth:`Function.launch_async` is *lazy*: its
    :class:`~repro.core.engine.Engine` (which snapshots buffer contents
    and translates) materializes only when the launch reaches the front of
    its stream with all prior work done — that is what gives same-stream
    dataflow CUDA semantics.  Accessing ``.engine`` earlier forces
    materialization (used by ``checkpoint`` of a not-yet-started launch).
    """

    def __init__(self, session: "HetSession",
                 function: Optional["Function"], grid: int, block: int,
                 eng_args: Optional[Dict[str, object]],
                 bindings: Dict[str, DeviceBuffer], stream: "Stream",
                 engine: Optional[Engine] = None):
        self.session = session
        self.function = function
        self.grid = grid
        self.block = block
        self._eng_args = eng_args
        self.bindings = dict(bindings)
        self.stream = stream
        self.seq = next(session._seq)
        self._engine = engine
        self.finished = bool(engine is not None and engine.finished)
        self.cancelled = False
        if engine is not None:
            engine.launch.stream_id = stream.sid
            engine.launch.launch_seq = self.seq

    # -- engine materialization -------------------------------------------
    @property
    def started(self) -> bool:
        return self._engine is not None

    @property
    def engine(self) -> Engine:
        if self._engine is None:
            # binding early would snapshot buffer contents *before* prior
            # same-stream work has written them — silently wrong data for
            # this launch and for any checkpoint taken from it.  Only the
            # stream-front launch may bind.
            if self.stream._q and self.stream._q[0] is not self:
                raise RuntimeError(
                    f"launch #{self.seq} ({self.program_name}) has not "
                    "started: it is queued behind other work on stream "
                    f"{self.stream.sid}, and its buffers only bind once "
                    "that work completes — drive the scheduler "
                    "(session.step()/synchronize()) before checkpointing "
                    "or migrating it")
            self._materialize()
        return self._engine

    @property
    def program_name(self) -> str:
        if self._engine is not None:
            return self._engine.program.name
        return self.function.name

    def _materialize(self) -> None:
        s = self.session
        # use-after-free guard: a buffer freed between launch_async and
        # the lazy stream-front bind still *has* a ``.data`` array, so
        # without this check the launch would silently execute against
        # released memory (and its writes would vanish at _finish, which
        # skips freed buffers).  Fail loudly instead.
        for pname, db in self.bindings.items():
            if db.freed:
                raise RuntimeError(
                    f"launch #{self.seq} ({self.program_name}): buffer "
                    f"parameter {pname!r} ({db.uid}) was freed before the "
                    "launch reached the stream front — device memory must "
                    "stay alive until queued work that binds it has run")
        eng = Engine(self.function.program, s.backend, self.grid,
                     self.block, self._eng_args, opt_level=s.opt_level,
                     specialize=s.specialize)
        eng.launch.stream_id = self.stream.sid
        eng.launch.launch_seq = self.seq
        self._engine = eng
        self._eng_args = None
        s.stats["last_opt"] = eng.opt_stats.as_dict()
        s.stats["last_spec_key"] = eng.spec_key
        if eng.spec_key:
            s.stats["specialized_launches"] = \
                s.stats.get("specialized_launches", 0) + 1

    # -- future surface ----------------------------------------------------
    def done(self) -> bool:
        return self.finished

    def wait(self) -> bool:
        """Drive the scheduler until this launch completes (other streams
        make fair-share progress too — host-side sync, not serialization).
        Returns False if blocked by a paused stream or the pause flag."""
        ok = self.session._drain(
            until=lambda: self.finished or self.cancelled)
        return ok and self.finished

    def buffer(self, name: str) -> DeviceBuffer:
        """The DeviceBuffer bound to buffer parameter ``name``."""
        return self.bindings[name]

    def advance(self, max_segments: Optional[int] = None,
                on_segment: Optional[Callable[[Engine], bool]] = None
                ) -> bool:
        """Drive *only this launch* forward by up to ``max_segments``
        segments (None = to completion), returning True iff it finished.

        This is the control-plane stepping primitive the worker-fleet
        layer (:mod:`~repro.core.fleet`) drives over IPC: a coordinator
        hands out bounded segment slices, and between slices the launch
        rests at a barrier — exactly where ``checkpoint`` is legal — so
        drain / rebalance / evacuation policies can interpose without a
        cooperative pause flag.  ``on_segment`` is forwarded to the
        engine's segment-boundary yield hook (fault injectors hang off
        it); segments executed here are charged/traced like scheduler
        steps, so fleet work shows up in ``sched_trace`` and stats.

        The launch must be at its stream front (same rule as lazy
        materialization — prior same-stream work must have written its
        buffers); a cancelled launch cannot be advanced.
        """
        if self.finished:
            return True
        if self.cancelled:
            raise RuntimeError(
                f"launch #{self.seq} ({self.program_name}) was cancelled "
                "— it cannot be advanced")
        s = self.session
        s._settle()
        if not self.stream._q or self.stream._q[0] is not self:
            raise RuntimeError(
                f"launch #{self.seq} ({self.program_name}) is not at the "
                f"front of stream {self.stream.sid} — drain prior work "
                "before single-stepping it")
        eng = self.engine

        def _boundary(e: Engine) -> bool:
            self.stream._charge(1.0)
            s._trace(self.stream, e.program.name, self.seq, e.node_idx)
            s.stats["segments_executed"] += 1
            return bool(on_segment is not None and on_segment(e))

        finished = eng.run(max_segments=max_segments, on_segment=_boundary)
        if finished:
            self.stream._q.popleft()
            self._finish()
            s._settle()
        return finished

    def cancel(self) -> None:
        """Withdraw the launch from its stream (a migrated-away launch
        must not also run to completion on the source)."""
        try:
            self.stream._q.remove(self)
        except ValueError:
            pass
        self.cancelled = True

    def _finish(self) -> None:
        """Completion hook: propagate kernel writes into the bound
        DeviceBuffers *in place* (object identity preserved).  The typed
        binding guarantees matching dtypes on the new surface; a legacy
        buffer whose dtype differs from the kernel param's falls back to
        the old rebind-the-array semantics."""
        self.finished = True
        for name, db in self.bindings.items():
            if db.freed:
                continue
            res = np.asarray(self._engine.result(name))
            if res.dtype == db.data.dtype:
                np.copyto(db.data, res)
            else:
                db.data = res.copy()
                db.dtype = ir.ir_dtype(res.dtype)

    def __repr__(self) -> str:
        state = ("finished" if self.finished else
                 "cancelled" if self.cancelled else
                 "running" if self.started else "queued")
        return (f"<LaunchRecord #{self.seq} {self.program_name} "
                f"stream={self.stream.sid} {state}>")


# ---------------------------------------------------------------------------
# Modules and functions
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ParamInfo:
    """Typed parameter metadata a Function exposes (the driver-API
    analogue of ``cuFuncGetParamInfo``)."""
    name: str
    kind: str       # "buffer" | "scalar"
    dtype: str      # hetIR dtype code ("f32", "i32", ...)


class Function:
    """A launchable kernel entry point with typed parameter metadata.
    Obtained from :meth:`Module.function`; launches go through
    :meth:`launch` / :meth:`launch_async`."""

    def __init__(self, session: "HetSession", program: ir.Program):
        self.session = session
        self.program = program
        self.name = program.name
        self.params: Tuple[ParamInfo, ...] = tuple(
            ParamInfo(p.name,
                      "buffer" if isinstance(p, ir.Ptr) else "scalar",
                      p.dtype)
            for p in program.params)

    def param(self, name: str) -> ParamInfo:
        for p in self.params:
            if p.name == name:
                return p
        raise KeyError(f"{self.name} has no parameter {name!r}")

    # -- launching ---------------------------------------------------------
    def launch_async(self, grid: int, block: int,
                     args: Dict[str, object],
                     stream: Optional[Stream] = None) -> LaunchRecord:
        """Enqueue onto ``stream`` (default stream if None) and return a
        :class:`LaunchRecord` future immediately.  Buffer parameters must
        be :class:`DeviceBuffer` handles of matching dtype; results appear
        in those buffers in place once the launch completes."""
        s = self.session
        t0 = time.perf_counter()
        stream = stream if stream is not None else s.default_stream
        if stream.session is not s:
            raise ValueError("stream belongs to a different session")
        eng_args, bindings = self._bind(args)
        rec = LaunchRecord(s, self, grid, block, eng_args, bindings,
                           stream)
        stream._enqueue(rec)
        s.stats["launches"] += 1
        s.stats["launch_ms"] += (time.perf_counter() - t0) * 1e3
        return rec

    def launch(self, grid: int, block: int, args: Dict[str, object],
               stream: Optional[Stream] = None) -> LaunchRecord:
        """Blocking launch: enqueue, then drive until this launch (and by
        stream order, everything before it) completes."""
        rec = self.launch_async(grid, block, args, stream=stream)
        rec.wait()
        return rec

    def _bind(self, args: Dict[str, object]
              ) -> Tuple[Dict[str, object], Dict[str, DeviceBuffer]]:
        eng_args: Dict[str, object] = {}
        bindings: Dict[str, DeviceBuffer] = {}
        names = set()
        for p in self.program.params:
            names.add(p.name)
            if p.name not in args:
                raise ValueError(f"{self.name}: missing argument {p.name}")
            v = args[p.name]
            if isinstance(p, ir.Ptr):
                if not isinstance(v, DeviceBuffer):
                    raise TypeError(
                        f"{self.name}: parameter {p.name!r} is a buffer — "
                        f"pass a DeviceBuffer from session.alloc() (got "
                        f"{type(v).__name__}); host arrays go through "
                        "buf.copy_from_host()")
                if v.session is not self.session:
                    raise ValueError(
                        f"{self.name}: buffer {p.name!r} belongs to a "
                        "different session")
                v._check_alive()
                if v.dtype != p.dtype:
                    raise TypeError(
                        f"{self.name}: buffer {p.name!r} has dtype "
                        f"{v.dtype}, parameter expects {p.dtype}")
                eng_args[p.name] = v          # Engine unwraps the handle
                bindings[p.name] = v
            else:
                if isinstance(v, DeviceBuffer):
                    raise TypeError(
                        f"{self.name}: parameter {p.name!r} is a scalar, "
                        "got a DeviceBuffer")
                eng_args[p.name] = v
        unknown = set(args) - names
        if unknown:
            raise ValueError(
                f"{self.name}: unknown argument(s) {sorted(unknown)}")
        return eng_args, bindings

    def __repr__(self) -> str:
        sig = ", ".join(f"{p.name}:{p.kind}[{p.dtype}]"
                        for p in self.params)
        return f"<Function {self.name}({sig})>"


class Module:
    """A loaded hetIR "binary": one or more entry points, looked up by
    name via :meth:`function`.  A single-entry module can itself be used
    as the function (``module.launch_async(...)``) — the driver-API
    convenience for the overwhelmingly common one-kernel case."""

    def __init__(self, session: "HetSession",
                 programs: Sequence[ir.Program]):
        self.session = session
        self._functions: Dict[str, Function] = {}
        for prog in programs:
            prog.validate()
            self._functions[prog.name] = Function(session, prog)

    def function(self, name: Optional[str] = None) -> Function:
        if name is None:
            if len(self._functions) != 1:
                raise ValueError(
                    "module has multiple entry points "
                    f"({sorted(self._functions)}); name one")
            return next(iter(self._functions.values()))
        try:
            return self._functions[name]
        except KeyError:
            raise KeyError(
                f"module has no function {name!r} "
                f"(entries: {sorted(self._functions)})") from None

    def functions(self) -> Tuple[str, ...]:
        return tuple(self._functions)

    # single-entry convenience: the module acts as its only function
    @property
    def params(self) -> Tuple[ParamInfo, ...]:
        return self.function().params

    def launch_async(self, *a, **kw) -> LaunchRecord:
        return self.function().launch_async(*a, **kw)

    def launch(self, *a, **kw) -> LaunchRecord:
        return self.function().launch(*a, **kw)

    def __repr__(self) -> str:
        return f"<Module entries={sorted(self._functions)}>"


# ---------------------------------------------------------------------------
# The session (device context)
# ---------------------------------------------------------------------------

class HetSession:
    """One "device context" bound to a backend, with streams, events,
    typed device memory, and migration support."""

    def __init__(self, backend: str = "vectorized",
                 opt_level: Optional[int] = None,
                 cache: Optional[TranslationCache] = None,
                 store: Optional[Union[str, DiskStore]] = None,
                 shared: Optional[Union[str, SharedStore]] = None,
                 specialize: Optional[bool] = None,
                 pool: Optional[Union[BufferPool, bool]] = None,
                 trace_cap: Optional[int] = None,
                 starvation_guard: Optional[int] = None):
        # specialize: None = policy default (HETGPU_SPECIALIZE / auto),
        # True = force launch-time specialization, False = always generic
        self.specialize = specialize
        # pool: None = a default bounded BufferPool (HETGPU_POOL_MAX_BYTES),
        # False = pooling off, or a caller-provided BufferPool instance
        if pool is None or pool is True:
            pool = BufferPool()
        elif pool is False:
            pool = BufferPool(enabled=False)
        self.pool: BufferPool = pool
        if trace_cap is None:
            trace_cap = int(os.environ.get("HETGPU_SCHED_TRACE_CAP",
                                           _DEFAULT_TRACE_CAP))
        # starvation guard period: every Nth scheduling decision serves
        # the longest-waiting runnable stream regardless of priority and
        # weight (0 disables — pure priority/WFQ)
        if starvation_guard is None:
            starvation_guard = int(os.environ.get(
                "HETGPU_STARVATION_GUARD", "32"))
        self.starvation_guard = max(0, int(starvation_guard))
        self.backend_name = backend
        if store is not None and not isinstance(store, DiskStore):
            store = DiskStore(store)
        if shared is not None and not isinstance(shared, SharedStore):
            shared = SharedStore(shared)
        if cache is None and (store is not None or shared is not None):
            # a session opened "against a store": private memory tier,
            # persistent disk tier — translations survive this process —
            # and optionally the cluster fabric underneath
            cache = TranslationCache(store=store, shared=shared)
        elif cache is not None:
            if store is not None:
                if cache.store is None:
                    cache.store = store
                elif cache.store.dir.resolve() != store.dir.resolve():
                    raise ValueError(
                        "cache is already bound to a different store "
                        f"({cache.store.dir}); refusing to silently ignore "
                        f"store={store.dir}")
            if shared is not None:
                if cache.shared is None:
                    cache.shared = shared
                elif cache.shared.dir.resolve() != shared.dir.resolve():
                    raise ValueError(
                        "cache is already bound to a different shared tier "
                        f"({cache.shared.dir}); refusing to silently ignore "
                        f"shared={shared.dir}")
        self.backend: Backend = get_backend(backend, cache=cache)
        self.cache: TranslationCache = self.backend.cache
        self.opt_level = DEFAULT_OPT_LEVEL if opt_level is None \
            else max(0, min(int(opt_level), OPT_MAX))

        # -- object model state -------------------------------------------
        self._functions: Dict[str, Function] = {}
        self._buffers_by_uid: Dict[str, DeviceBuffer] = {}
        self._seq = itertools.count()
        #: the *active* streams — the scheduler's scan set.  Destroyed
        #: streams are removed, so a long-lived serving session that has
        #: created (and retired) thousands of streams still schedules in
        #: O(active).
        self.streams: List[Stream] = []
        self._sid_counter = itertools.count()
        # scheduler state: decision counter (starvation guard + LRU
        # tie-break) and the virtual clock newly-runnable streams sync to
        self._picks = 0
        self._vclock = 0.0
        self.default_stream = self.stream()          # sid 0
        #: scheduler trace: one entry per executed segment or async copy
        #: {"stream", "kernel", "seq", "node_idx"} — the observable
        #: interleaving (tests assert alternation and fair shares on it).
        #: A capped ring: the newest ``trace_cap`` entries are kept and
        #: ``stats["sched_trace_dropped"]`` counts evictions.
        self.sched_trace = TraceRing(trace_cap)
        self.pause_flag = False  # the paper's cooperative pause flag

        # -- legacy shim state --------------------------------------------
        self._named: Dict[str, DeviceBuffer] = {}    # gpu_malloc names
        self._named_shapes: Dict[str, Tuple[int, ...]] = {}  # host shapes
        self._legacy_streams: Dict[int, Stream] = {0: self.default_stream}
        # append-only per-legacy-stream launch history (old `_streams`
        # shape: Dict[int, List[LaunchRecord]])
        self._streams: Dict[int, List[LaunchRecord]] = {0: []}

        self.stats = {"launches": 0, "launch_ms": 0.0, "translate_ms": 0.0,
                      "translation_ms": 0.0,  # deprecated alias, see API.md
                      "segments_executed": 0, "migrations": 0,
                      "async_copies": 0, "sched_trace_dropped": 0,
                      "streams_retired": 0,
                      "cache_hits": 0, "cache_misses": 0,
                      "cache_evictions": 0, "cache_restored": 0,
                      "cache_translated": 0}
        # translate_ms is reported as this session's *delta* over the
        # (possibly shared) cache's lifetime counter
        self._translate_ms_base = float(
            self.cache.stats().get("translate_ms", 0.0))

    # -- cache stats -------------------------------------------------------
    def cache_stats(self) -> Dict[str, object]:
        """Shared translation-cache counters (paper §4.2 JIT cache)."""
        return self.cache.stats()

    def block_stats(self) -> Dict[str, object]:
        """Block-tiled fast-path counters for backends that have one
        (pallas): segment executions that took the ``tiled`` vs ``scalar``
        path and the per-reason refusal histogram.  Empty for backends
        without a tiled path."""
        return dict(getattr(self.backend, "block_stats", None) or {})

    def _sync_cache_stats(self) -> None:
        st = self.cache.stats()
        self.stats["cache_hits"] = st["hits"]
        self.stats["cache_misses"] = st["misses"]
        self.stats["cache_evictions"] = st["evictions"]
        self.stats["cache_restored"] = st["restored"]
        self.stats["cache_translated"] = st["translated"]
        self.stats["translate_ms"] = (
            float(st.get("translate_ms", 0.0)) - self._translate_ms_base)
        # deprecated alias (one release): formerly mistimed the whole
        # launch including execution; now mirrors translate_ms
        self.stats["translation_ms"] = self.stats["translate_ms"]

    # -- module loading ----------------------------------------------------
    def load(self, program: Union[ir.Program, Sequence[ir.Program]]
             ) -> Module:
        """Load one hetIR program (or several) into a :class:`Module`.
        Translation stays lazy, at first launch (paper §4.2 Module
        Loading and JIT)."""
        programs = [program] if isinstance(program, ir.Program) \
            else list(program)
        mod = Module(self, programs)
        self._functions.update(mod._functions)
        return mod

    def function(self, name: str) -> Function:
        """Look up a loaded entry point by name across all modules."""
        try:
            return self._functions[name]
        except KeyError:
            raise KeyError(f"no loaded kernel {name!r} "
                           f"(loaded: {sorted(self._functions)})") from None

    # -- device memory -----------------------------------------------------
    def alloc(self, shape, dtype: object = np.float32) -> DeviceBuffer:
        """Allocate a typed :class:`DeviceBuffer` (zero-initialized).
        ``shape`` may be an int or a tuple — device memory is linear, so
        multi-dim shapes are flattened to their total size.  Backings are
        sub-allocated from the session's bounded :class:`BufferPool`
        (``pool=False`` to opt out), so alloc/free churn under serving
        load reuses memory instead of thrashing the host allocator."""
        size = int(shape) if isinstance(shape, (int, np.integer)) \
            else int(np.prod(shape))
        db = DeviceBuffer(self, size, dtype, pool=self.pool)
        self._buffers_by_uid[db.uid] = db
        return db

    def pool_stats(self) -> Dict[str, object]:
        """Buffer-pool counters (hits/misses/reuse_rate/pooled_bytes)."""
        return self.pool.stats()

    # -- streams and events ------------------------------------------------
    def stream(self, weight: float = 1.0, priority: int = 0,
               quantum: int = 1) -> Stream:
        """Create a new asynchronous stream with fair-share scheduling
        policy: ``weight`` (share of segment service while backlogged;
        0 = guard-only), ``priority`` (strict tiers, higher first), and
        ``quantum`` (segments per scheduling decision)."""
        st = Stream(self, next(self._sid_counter), weight=weight,
                    priority=priority, quantum=quantum)
        self.streams.append(st)
        return st

    def _retire_stream(self, st: Stream) -> None:
        try:
            self.streams.remove(st)
        except ValueError:
            pass
        self.stats["streams_retired"] += 1

    def _enqueue_copy(self, kind: str, db: DeviceBuffer,
                      stream: Optional[Stream],
                      host: Optional[np.ndarray] = None) -> CopyRecord:
        st = stream if stream is not None else self.default_stream
        if st.session is not self:
            raise ValueError("stream belongs to a different session")
        rec = CopyRecord(self, kind, db, st, host=host)
        st._enqueue(rec)
        return rec

    def event(self) -> Event:
        return Event(self)

    # -- the cooperative fair-share segment scheduler ----------------------
    def _settle(self) -> None:
        """Retire every ripe non-launch queue item (event records at queue
        front, waits whose event completed) without executing segments."""
        progressed = True
        while progressed:
            progressed = False
            for st in self.streams:
                while st._q:
                    item = st._q[0]
                    if isinstance(item, _EventRecord):
                        # reaching a record point releases every wait
                        # issued against it (or an earlier one); only the
                        # *latest* record completes the event itself — a
                        # superseded marker retires without doing so
                        ev = item.event
                        ev._last_retired_generation = max(
                            ev._last_retired_generation, item.generation)
                        if item.generation == ev._generation:
                            ev._complete = True
                        st._q.popleft()
                        progressed = True
                    elif isinstance(item, _EventWait):
                        if item.satisfied():
                            st._q.popleft()
                            progressed = True
                        else:
                            break
                    else:
                        break

    def step(self, passes: int = 1) -> bool:
        """Public scheduler stepping: make up to ``passes`` scheduling
        decisions.  Each decision picks one runnable stream under the
        priority / weighted-fair-share policy and advances it by up to its
        ``quantum`` of *segments* (the paper's barrier-to-barrier unit) —
        with equal weights and the default quantum of 1 this degenerates
        to exact round-robin.  Returns True iff any progress was made —
        the hook cooperative serving layers (and the stream tests) drive."""
        progressed = False
        for _ in range(passes):
            if not self._step():
                break
            progressed = True
        return progressed

    def _runnable(self) -> List[Stream]:
        """Streams whose front item is executable work (a launch or an
        async copy) right now.  Callers settle first, so event markers at
        queue fronts have already been retired."""
        return [st for st in self.streams
                if st._q and not st.paused
                and isinstance(st._q[0], (LaunchRecord, CopyRecord))]

    def _pick(self) -> Optional[Stream]:
        """One scheduling decision: highest priority tier first, least
        weighted virtual time within the tier (ties broken
        least-recently-served, then by sid).  Every ``starvation_guard``-th
        decision instead serves the longest-waiting runnable stream
        outright — the guard that keeps zero-weight / low-priority
        streams progressing under sustained load."""
        if self.pause_flag:
            return None
        runnable = self._runnable()
        if not runnable:
            return None
        self._picks += 1
        guard = self.starvation_guard
        if guard and self._picks % guard == 0:
            st = min(runnable, key=lambda s: (s._last_pick, s.sid))
        else:
            # zero-weight streams opt out of competition entirely: they
            # are served by the guard or when nothing weighted is
            # runnable, and never advance the virtual clock
            weighted = [s for s in runnable if s.weight > 0]
            pool_ = weighted or runnable
            top = max(s.priority for s in pool_)
            st = min((s for s in pool_ if s.priority == top),
                     key=lambda s: (s._vtime, s._last_pick, s.sid))
        st._last_pick = self._picks
        if st.weight > 0:
            self._vclock = max(self._vclock, st._vtime)
        return st

    def _preempt_for_higher_priority(self, cur: Stream) -> bool:
        """Mid-quantum yield check (the engine's segment-boundary hook):
        a runnable stream in a strictly higher priority tier takes the
        next scheduling decision."""
        return any(st.priority > cur.priority for st in self._runnable()
                   if st is not cur)

    def _trace(self, st: Stream, kernel: str, seq: int,
               node_idx: int) -> None:
        self.sched_trace.append(
            {"stream": st.sid, "kernel": kernel, "seq": seq,
             "node_idx": node_idx})
        self.stats["sched_trace_dropped"] = self.sched_trace.dropped

    def _step(self) -> bool:
        self._settle()
        st = self._pick()
        if st is None:
            return False
        item = st._q[0]
        if isinstance(item, CopyRecord):
            # data movement is scheduled work: one decision, one copy
            try:
                item._execute()
            except Exception:
                st._q.popleft()     # don't wedge the stream on the error
                raise
            st._q.popleft()
            st._charge(1.0)
            self._trace(st, f"<{item.kind}>", item.seq, -1)
            self.stats["async_copies"] += 1
            self._settle()
            return True
        try:
            eng = item.engine   # lazy copy-in happens here, at start
        except Exception:
            # e.g. a freed-buffer bind: withdraw the poisoned launch so
            # the stream is not permanently wedged, then surface it
            st._q.popleft()
            item.cancelled = True
            raise
        quantum = st.quantum
        executed = 0

        def _boundary(e: Engine) -> bool:
            # segment-boundary yield hook: trace + charge each segment,
            # end the quantum when it is spent or a higher-priority
            # stream became runnable (preemption at the barrier)
            nonlocal executed
            executed += 1
            st._charge(1.0)
            self._trace(st, e.program.name, item.seq, e.node_idx)
            self.stats["segments_executed"] += 1
            return (executed >= quantum
                    or self._preempt_for_higher_priority(st))

        finished = eng.run(on_segment=_boundary)
        if finished:
            st._q.popleft()
            item._finish()
        self._settle()
        return True

    def _drain(self, until: Optional[Callable[[], bool]] = None) -> bool:
        """Drive the scheduler until ``until()`` holds (or, with no
        condition, until every stream drains).  Returns False when
        progress stops on cooperatively paused work (per-stream ``pause``
        or the session ``pause_flag``); raises on a genuine event
        deadlock."""
        t0 = time.perf_counter()
        try:
            while True:
                self._settle()
                if until is not None and until():
                    return True
                pending = [st for st in self.streams if st._q]
                if not pending:
                    return True if until is None else bool(until())
                if self._step():
                    continue
                # no progress: paused work holds the rest, or a deadlock
                if self.pause_flag or any(st.paused for st in pending):
                    return False
                fronts = "; ".join(
                    f"stream {st.sid}: {st._describe_front()}"
                    for st in pending)
                raise RuntimeError(
                    "stream scheduler deadlock — queues are non-empty but "
                    f"nothing is runnable ({fronts})")
        finally:
            self.stats["launch_ms"] += (time.perf_counter() - t0) * 1e3
            self._sync_cache_stats()

    def synchronize(self) -> bool:
        """Drive *all* streams to completion (the old
        ``device_synchronize`` only swept stream 0).  Returns False if
        paused work remains."""
        return self._drain()

    # -- cache warm-up -----------------------------------------------------
    def warmup(self, programs: Iterable, grids: Sequence[Tuple[int, int]]
               = ((2, 32),)) -> Dict[str, object]:
        """Ahead-of-time translate a kernel set (paper §4.2: JIT cost is
        paid per *cluster lifetime* — a node expecting migrated work can
        translate it before the work arrives).

        ``programs`` is an iterable of ``ir.Program`` or ``(ir.Program,
        example_args)`` pairs; ``grids`` is a sequence of ``(grid, block)``
        geometries to specialize for.  When no example args are given they
        are synthesized (unit scalars, zero buffers sized ``grid*block``)
        and any kernel the synthetic args cannot drive is reported —
        warm-up is best-effort by design.  Each warm-up launch runs on
        scratch copies; session buffers are untouched.

        Returns a report: per-kernel status plus how many segments were
        ``restored`` from the disk store versus freshly ``translated``
        (warm restarts should see ``translated == 0``), and — with a
        cluster fabric attached — how many restores were ``fetched`` from
        the shared tier and how many warm-started from the AOT executable
        (``aot_restored``, i.e. with zero XLA compile).
        """
        report: Dict[str, object] = {"kernels": [], "translated": 0,
                                     "restored": 0, "cache_hits": 0,
                                     "fetched": 0, "aot_restored": 0,
                                     "errors": 0}
        for item in programs:
            prog, args = item if isinstance(item, tuple) else (item, None)
            for grid, block in grids:
                before = self.cache.stats()
                entry = {"kernel": prog.name, "grid": grid, "block": block}
                t0 = time.perf_counter()
                try:
                    use_args = dict(args) if args is not None else \
                        _synthesize_args(prog, grid, block)
                    # synthesized args carry made-up unit scalars: never
                    # specialize on them — it would warm (and persist) a
                    # variant no real launch will ask for, and burn one of
                    # the program's specialization-budget slots.  The
                    # generic entries warmed instead are the ones budget
                    # fallbacks and policy-off launches share.  Explicit
                    # example args warm whatever a real launch would run.
                    eng = Engine(prog, self.backend, grid, block, use_args,
                                 opt_level=self.opt_level,
                                 specialize=(False if args is None
                                             else self.specialize))
                    eng.run()
                    entry["status"] = "ok"
                except Exception as exc:  # best-effort: report, don't raise
                    entry["status"] = f"error: {type(exc).__name__}: {exc}"
                    report["errors"] += 1
                after = self.cache.stats()
                for field_ in ("translated", "restored", "aot_restored"):
                    delta = after[field_] - before[field_]
                    entry[field_] = delta
                    report[field_] += delta
                fetched = (after["shared_fetches"]
                           - before["shared_fetches"])
                entry["fetched"] = fetched
                report["fetched"] += fetched
                entry["cache_hits"] = after["hits"] - before["hits"]
                report["cache_hits"] += entry["cache_hits"]
                entry["ms"] = round((time.perf_counter() - t0) * 1e3, 2)
                report["kernels"].append(entry)
        self._sync_cache_stats()
        return report

    # -- checkpoint / restore / migration ----------------------------------
    def checkpoint(self, rec: LaunchRecord) -> bytes:
        """Serialize a launch paused at a barrier (or finished) — the
        migration payload.  Works on in-flight async launches: between
        scheduler steps every launch sits at a barrier by construction."""
        return rec.engine.snapshot().to_bytes()

    def restore(self, kernel: Union[str, Function], blob: bytes,
                stream: Optional[Union[Stream, int]] = None
                ) -> LaunchRecord:
        """Re-instantiate a checkpoint onto a caller-chosen stream
        (default stream if None; a legacy int names an old-style stream).

        Buffer identity: each restored global re-binds the session's live
        :class:`DeviceBuffer` with the snapshot's recorded uid when one
        exists (same size/dtype) — a checkpoint/restore round-trip in one
        session lands results in the *same* buffer objects.  Unknown uids
        get fresh buffers that *adopt* the snapshot uid, so identity stays
        stable across chained migrations."""
        snap = Snapshot.from_bytes(blob)
        fn = kernel if isinstance(kernel, Function) \
            else self.function(kernel)
        eng = Engine.resume(fn.program, self.backend, snap)
        history_key: Optional[int] = None
        if isinstance(stream, (int, np.integer)):
            # legacy int stream: the history view below must use the
            # caller's id, which need not equal the Stream's internal sid
            history_key = int(stream)
            st = self._legacy_stream(history_key)
        elif stream is None:
            st = self.default_stream
        elif stream.session is not self:
            raise ValueError("stream belongs to a different session")
        else:
            st = stream
        bindings: Dict[str, DeviceBuffer] = {}
        for name, arr in eng.state.globals_.items():
            arr_np = np.asarray(arr)
            uid = eng.buffer_uids.get(name)
            db = self._buffers_by_uid.get(uid) if uid else None
            if db is not None and (db.size != arr_np.size
                                   or db.np_dtype != arr_np.dtype
                                   or db.freed):
                db = None
            if db is None:
                db = DeviceBuffer(self, arr_np.size, arr_np.dtype, uid=uid)
                self._buffers_by_uid[db.uid] = db
            # seed with checkpoint contents so host reads before
            # completion observe the paused state
            np.copyto(db.data, arr_np)
            bindings[name] = db
        rec = LaunchRecord(self, fn, snap.num_blocks, snap.block_size,
                           None, bindings, st, engine=eng)
        if eng.finished:
            rec._finish()
        else:
            st._enqueue(rec)
        self._streams.setdefault(
            st.sid if history_key is None else history_key, []).append(rec)
        return rec

    def run_to_completion(self, rec: LaunchRecord) -> None:
        """Drive the scheduler until ``rec`` finishes (equivalent to
        ``rec.wait()``; kept for the pre-driver-API callers)."""
        rec.wait()
        self._sync_cache_stats()

    # ======================================================================
    # Deprecated string-keyed shim (old→new table in docs/API.md)
    # ======================================================================
    def load_kernel(self, program: ir.Program) -> str:
        """Deprecated: use :meth:`load` (returns a :class:`Module`)."""
        _deprecated("load_kernel(program)", "session.load(program)")
        self.load(program)
        return program.name

    def gpu_malloc(self, name: str, shape, dtype=np.float32) -> np.ndarray:
        """Deprecated: use :meth:`alloc` (returns a typed
        :class:`DeviceBuffer` handle instead of registering a name).

        The old surface preserved multi-dim shapes and accepted any numpy
        dtype; the shim keeps both (the returned array is a shape-intact
        *view* of the underlying linear buffer)."""
        _deprecated("gpu_malloc(name, shape)", "session.alloc(shape, dtype)")
        db = self.alloc(shape, dtype)
        self._named[name] = db
        self._named_shapes[name] = (int(shape),) \
            if isinstance(shape, (int, np.integer)) \
            else tuple(int(d) for d in shape)
        return db.data.reshape(self._named_shapes[name])

    def memcpy_h2d(self, name: str, host: np.ndarray) -> None:
        """Deprecated: use :meth:`DeviceBuffer.copy_from_host`."""
        _deprecated("memcpy_h2d(name, host)", "buffer.copy_from_host(host)")
        host = np.asarray(host)
        db = self._named.get(name)
        if db is None or db.size != host.size or db.np_dtype != host.dtype:
            # old memcpy_h2d rebound the name wholesale; emulate
            db = self.alloc(host.size, host.dtype)
            self._named[name] = db
        self._named_shapes[name] = host.shape
        db.copy_from_host(host)

    def memcpy_d2h(self, name: str) -> np.ndarray:
        """Deprecated: use :meth:`DeviceBuffer.copy_to_host`."""
        _deprecated("memcpy_d2h(name)", "buffer.copy_to_host()")
        out = self._named[name].copy_to_host()
        shape = self._named_shapes.get(name)
        return out.reshape(shape) if shape is not None else out

    def _legacy_stream(self, sid: int) -> Stream:
        st = self._legacy_streams.get(sid)
        if st is None:
            st = self.stream()
            self._legacy_streams[sid] = st
        return st

    def launch(self, kernel: str, grid: int, block: int,
               args: Dict[str, object], stream: int = 0,
               blocking: bool = True) -> LaunchRecord:
        """Deprecated: use :meth:`Function.launch` /
        :meth:`Function.launch_async` with DeviceBuffer arguments.

        Shim semantics (unchanged where safe, fixed where lossy): buffer
        params resolve from explicit ``args`` first, then by name against
        ``gpu_malloc`` buffers.  A resolved session buffer — including one
        the caller passed *explicitly* (the old code silently dropped
        those writes) — receives the kernel's writes in place.  A raw host
        array passed explicitly keeps copy-in semantics and is never
        mutated; read results via the record's engine."""
        _deprecated("launch(kernel, ...)",
                    "module.function(name).launch_async(...)")
        fn = self.function(kernel)
        eng_args: Dict[str, object] = {}
        bindings: Dict[str, DeviceBuffer] = {}
        for p in fn.program.params:
            named = self._named.get(p.name) \
                if isinstance(p, ir.Ptr) else None
            if p.name in args:
                v = args[p.name]
                if isinstance(v, DeviceBuffer):
                    eng_args[p.name] = v
                    bindings[p.name] = v
                elif named is not None and isinstance(v, np.ndarray) \
                        and np.shares_memory(v, named.data):
                    # the async-writeback fix: an explicitly passed
                    # session buffer (or a gpu_malloc-returned view of
                    # it) is still a session buffer — identity via
                    # shares_memory because a pooled buffer's views
                    # collapse their ``.base`` to the pool backing
                    eng_args[p.name] = named
                    bindings[p.name] = named
                else:
                    eng_args[p.name] = v
            elif named is not None:
                eng_args[p.name] = named
                bindings[p.name] = named
            else:
                raise ValueError(f"missing argument {p.name}")
        st = self._legacy_stream(stream)
        t0 = time.perf_counter()
        rec = LaunchRecord(self, fn, grid, block, eng_args, bindings, st)
        rec._materialize()      # old surface bound eagerly; tests poke
        st._enqueue(rec)        # rec.engine right after a non-blocking
        self._streams.setdefault(stream, []).append(rec)  # legacy view
        self.stats["launches"] += 1
        self.stats["launch_ms"] += (time.perf_counter() - t0) * 1e3
        if blocking:
            rec.wait()
        self._sync_cache_stats()
        return rec

    def device_synchronize(self, stream: int = 0) -> None:
        """Deprecated: use :meth:`Stream.synchronize` (one stream) or
        :meth:`HetSession.synchronize` (all streams).  Unlike the old
        implementation this *completes* the results: kernel writes land in
        the session buffers (the old path ran the engines but never wrote
        back — non-blocking launches silently dropped their results)."""
        _deprecated("device_synchronize(stream)",
                    "stream.synchronize() / session.synchronize()")
        self._legacy_stream(stream).synchronize()


def _synthesize_args(prog: ir.Program, grid: int,
                     block: int) -> Dict[str, object]:
    """Best-effort example arguments for warm-up launches: unit scalars
    and ``grid*block``-sized zero buffers (covers gid-indexed kernels; a
    kernel needing real geometry scalars should be warmed with explicit
    example args)."""
    args: Dict[str, object] = {}
    for p in prog.params:
        if isinstance(p, ir.Ptr):
            args[p.name] = np.zeros(grid * block, dtype=ir.np_dtype(p.dtype))
        else:
            args[p.name] = ir.np_dtype(p.dtype).type(1)
    return args


def migrate(rec: LaunchRecord, src: HetSession, dst: HetSession,
            kernel: Union[str, Function],
            stream: Optional[Union[Stream, int]] = None) -> LaunchRecord:
    """Live-migrate a launch from one session/backend to another
    (paper §6.3).  Works on in-flight *async* launches: the scheduler only
    ever rests a launch at a barrier, so the checkpoint below is always
    legal.  Returns the resumed launch on ``dst`` (landing on ``stream``,
    default stream if None); the source record is cancelled — the moved
    launch must not also finish on the source.  Timing stats are recorded
    on both sessions.

    Before resuming, the destination's translation cache is preloaded from
    the cluster fabric when one is reachable (its own tiers — local store
    then shared fabric — falling back to the *source's* fabric, then the
    source's local store for fabric-less point-to-point setups): if this
    program has ever been translated for the destination backend within
    the fabric's lifetime — by anyone in the fleet — the migrated launch
    pays near-zero translation cost, the paper's cluster-lifetime JIT
    amortization.

    Specialization keys ride along: the snapshot records the source
    engine's bound-scalar vector, ``Engine.resume`` re-derives the
    identical specialized body from it (never re-consulting the policy),
    and the fingerprint used for the preload below is the *specialized*
    program's — so a mid-kernel checkpoint of a specialized kernel
    restores bit-identical, against warm specialized translations.
    DeviceBuffer uids ride along too: the destination's restored buffers
    adopt them, keeping buffer identity stable across chained hops."""
    t0 = time.perf_counter()
    blob = src.checkpoint(rec)  # capture at barrier
    rec.cancel()
    t1 = time.perf_counter()
    # warm the destination from the persistent tier: the engine's program
    # is the *optimized* body, whose fingerprint is what cache keys carry
    fp = ir.program_fingerprint(rec.engine.program)
    restored = 0
    if dst.cache.store is not None or dst.cache.shared is not None:
        # the destination's own fabric: local store, then shared tier
        restored = dst.cache.preload(backend=dst.backend_name,
                                     fingerprint=fp)
    else:
        # fabric-less destination: fetch from the source's fabric, else
        # fall back to the old point-to-point store handover
        store = src.cache.shared if src.cache.shared is not None \
            else src.cache.store
        if store is not None:
            restored = dst.cache.preload(backend=dst.backend_name,
                                         fingerprint=fp, store=store)
    t2 = time.perf_counter()
    new = dst.restore(kernel, blob, stream=stream)  # reload + reshard
    t3 = time.perf_counter()
    src.stats["migrations"] += 1
    dst.stats["migrations"] += 1
    dst.stats["last_migration"] = {
        "checkpoint_ms": (t1 - t0) * 1e3,
        "warmup_ms": (t2 - t1) * 1e3,
        "restore_ms": (t3 - t2) * 1e3,
        "payload_bytes": len(blob),
        "cache_restored": restored,
    }
    return new
