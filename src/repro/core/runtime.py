"""HetSession — the hetGPU abstraction layer (paper §4.3).

Presents the uniform device API the paper describes: buffer allocation,
kernel launch with CUDA-like ``<<<grid, block>>>`` geometry, streams with
in-order semantics, cooperative checkpoint (pause flag honoured at
barriers), restore, and live migration between backends.  The "JIT
modules" are entries in the shared :class:`~repro.core.cache.
TranslationCache` (paper §4.2), whose hit/miss/eviction counters this
session surfaces via :meth:`HetSession.cache_stats` and ``stats``; kernels
launch through the :mod:`~repro.core.passes` pipeline at the session's
``opt_level``.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from . import hetir as ir
from .backends import get_backend
from .backends.base import Backend
from .cache import TranslationCache
from .engine import Engine
from .passes import DEFAULT_OPT_LEVEL, OPT_MAX
from .state import Snapshot


@dataclass
class _KernelHandle:
    program: ir.Program


@dataclass
class LaunchRecord:
    engine: Engine
    finished: bool = False


class HetSession:
    """One "device context" bound to a backend, with migration support."""

    def __init__(self, backend: str = "vectorized",
                 opt_level: Optional[int] = None,
                 cache: Optional[TranslationCache] = None):
        self.backend_name = backend
        self.backend: Backend = get_backend(backend, cache=cache)
        self.cache: TranslationCache = self.backend.cache
        self.opt_level = DEFAULT_OPT_LEVEL if opt_level is None \
            else max(0, min(int(opt_level), OPT_MAX))
        self._kernels: Dict[str, _KernelHandle] = {}
        self._buffers: Dict[str, np.ndarray] = {}
        self._streams: Dict[int, List[LaunchRecord]] = {0: []}
        self.pause_flag = False  # the paper's cooperative pause flag
        self.stats = {"launches": 0, "translation_ms": 0.0,
                      "migrations": 0, "cache_hits": 0, "cache_misses": 0,
                      "cache_evictions": 0}

    def cache_stats(self) -> Dict[str, object]:
        """Shared translation-cache counters (paper §4.2 JIT cache)."""
        return self.cache.stats()

    def _sync_cache_stats(self) -> None:
        st = self.cache.stats()
        self.stats["cache_hits"] = st["hits"]
        self.stats["cache_misses"] = st["misses"]
        self.stats["cache_evictions"] = st["evictions"]

    # -- module loading ------------------------------------------------
    def load_kernel(self, program: ir.Program) -> str:
        """Register a hetIR "binary".  Translation happens lazily at first
        launch (paper §4.2 Module Loading and JIT)."""
        program.validate()
        self._kernels[program.name] = _KernelHandle(program)
        return program.name

    # -- memory management ----------------------------------------------
    def gpu_malloc(self, name: str, shape, dtype=np.float32) -> np.ndarray:
        buf = np.zeros(shape, dtype=dtype)
        self._buffers[name] = buf
        return buf

    def memcpy_h2d(self, name: str, host: np.ndarray) -> None:
        self._buffers[name] = np.array(host, copy=True)

    def memcpy_d2h(self, name: str) -> np.ndarray:
        return self._buffers[name].copy()

    # -- kernel launch ----------------------------------------------------
    def launch(self, kernel: str, grid: int, block: int,
               args: Dict[str, object], stream: int = 0,
               blocking: bool = True) -> LaunchRecord:
        handle = self._kernels[kernel]
        merged = {}
        for p in handle.program.params:
            if p.name in args:
                merged[p.name] = args[p.name]
            elif isinstance(p, ir.Ptr) and p.name in self._buffers:
                merged[p.name] = self._buffers[p.name]
            else:
                raise ValueError(f"missing argument {p.name}")
        t0 = time.perf_counter()
        eng = Engine(handle.program, self.backend, grid, block, merged,
                     opt_level=self.opt_level)
        rec = LaunchRecord(engine=eng)
        self._streams.setdefault(stream, []).append(rec)
        self.stats["launches"] += 1
        self.stats["last_opt"] = eng.opt_stats.as_dict()
        if blocking:
            rec.finished = eng.run(pause_flag=lambda: self.pause_flag)
            self._writeback(handle.program, eng, args)
        self.stats["translation_ms"] += (time.perf_counter() - t0) * 1e3
        self._sync_cache_stats()
        return rec

    def _writeback(self, program: ir.Program, eng: Engine,
                   args: Dict[str, object]) -> None:
        """Propagate kernel writes back into session buffers."""
        for p in program.buffers():
            if p.name in self._buffers and p.name not in args:
                self._buffers[p.name] = eng.result(p.name)

    def device_synchronize(self, stream: int = 0) -> None:
        for rec in self._streams.get(stream, []):
            if not rec.finished:
                rec.finished = rec.engine.run(
                    pause_flag=lambda: self.pause_flag)

    # -- checkpoint / restore / migration ---------------------------------
    def checkpoint(self, rec: LaunchRecord) -> bytes:
        """Serialize a paused (or finished) launch — the migration payload."""
        return rec.engine.snapshot().to_bytes()

    def restore(self, kernel: str, blob: bytes) -> LaunchRecord:
        snap = Snapshot.from_bytes(blob)
        eng = Engine.resume(self._kernels[kernel].program, self.backend,
                            snap)
        rec = LaunchRecord(engine=eng, finished=eng.finished)
        self._streams[0].append(rec)
        return rec

    def run_to_completion(self, rec: LaunchRecord) -> None:
        rec.finished = rec.engine.run(pause_flag=lambda: self.pause_flag)
        self._sync_cache_stats()


def migrate(rec: LaunchRecord, src: HetSession, dst: HetSession,
            kernel: str) -> LaunchRecord:
    """Live-migrate a launch from one session/backend to another
    (paper §6.3). Returns the resumed launch on ``dst``; timing stats are
    recorded on both sessions."""
    t0 = time.perf_counter()
    blob = src.checkpoint(rec)  # capture at barrier
    t1 = time.perf_counter()
    new = dst.restore(kernel, blob)  # reload + reshard onto new device
    t2 = time.perf_counter()
    src.stats["migrations"] += 1
    dst.stats["migrations"] += 1
    dst.stats.setdefault("last_migration", {})
    dst.stats["last_migration"] = {
        "checkpoint_ms": (t1 - t0) * 1e3,
        "restore_ms": (t2 - t1) * 1e3,
        "payload_bytes": len(blob),
    }
    return new
