"""llama3-405b — dense GQA transformer [arXiv:2407.21783].

126L, d_model 16384, 128 heads (GQA kv=8), d_ff 53248, vocab 128256.
Pure full attention → long_500k is skipped (quadratic).  Optimizer states
run in bf16 so 405B fits a single 256-chip v5e pod (see DESIGN.md §5).
"""
from . import register, register_smoke
from .base import ATTN, DENSE_FFN, BlockSpec, ModelConfig

_BLOCK = BlockSpec(mixer=ATTN, ffn=DENSE_FFN)


@register("llama3-405b")
def config() -> ModelConfig:
    return ModelConfig(
        name="llama3-405b",
        family="dense",
        n_layers=126,
        d_model=16384,
        n_heads=128,
        n_kv_heads=8,
        d_ff=53248,
        vocab_size=128256,
        layer_groups=((126, (_BLOCK,)),),
        rope_theta=500000.0,
        opt_state_dtype="bfloat16",
        subquadratic=False,
    )


@register_smoke("llama3-405b")
def smoke() -> ModelConfig:
    return ModelConfig(
        name="llama3-405b-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=8,
        n_kv_heads=2,
        d_ff=160,
        vocab_size=512,
        layer_groups=((2, (_BLOCK,)),),
        rope_theta=500000.0,
        param_dtype="float32",
        compute_dtype="float32",
        subquadratic=False,
    )
