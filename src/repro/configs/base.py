"""Config system: model architecture + input-shape + parallelism configs.

Every assigned architecture is expressed as a :class:`ModelConfig` built
from *layer groups*: ``[(repeat, [BlockSpec, ...]), ...]``.  A group's body
is a fixed sequence of blocks and the group is ``lax.scan``-stacked
``repeat`` times — this supports homogeneous stacks (llama: 1-block body),
interleaved patterns (recurrentgemma: [rglru, rglru, local_attn] × 8 + a
tail), and alternating patterns (xlstm: [mlstm, slstm] × 6) while keeping
the compiled HLO compact (critical for the 512-device dry-run).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------

# mixer kinds
ATTN = "attn"            # full (causal for LM) GQA attention
SWA = "swa"              # sliding-window GQA attention
RGLRU = "rglru"          # RG-LRU recurrence (+ temporal conv)
MLSTM = "mlstm"          # matrix-LSTM (linear attention w/ forget gates)
SLSTM = "slstm"          # scalar-LSTM
CROSS_ATTN = "cross"     # decoder cross-attention (enc-dec only)

# ffn kinds
DENSE_FFN = "dense"
MOE_FFN = "moe"
NO_FFN = "none"


@dataclass(frozen=True)
class BlockSpec:
    mixer: str = ATTN
    ffn: str = DENSE_FFN
    # whisper decoder blocks carry self-attn AND cross-attn
    cross_attn: bool = False


@dataclass(frozen=True)
class MoECfg:
    n_experts: int
    top_k: int
    d_ff_expert: int
    # capacity factor for dispatch (tokens per expert = factor * T*k/E)
    capacity_factor: float = 1.25
    router_jitter: float = 0.0


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense|moe|hybrid|ssm|vlm|audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    layer_groups: Tuple[Tuple[int, Tuple[BlockSpec, ...]], ...]
    head_dim: Optional[int] = None
    window: Optional[int] = None      # SWA / local-attention window
    moe: Optional[MoECfg] = None
    norm: str = "rmsnorm"             # rmsnorm | layernorm
    act: str = "swiglu"               # swiglu | gelu
    rope_theta: float = 500000.0
    tie_embeddings: bool = False
    # recurrent-block dims
    d_rnn: Optional[int] = None       # RG-LRU width (recurrentgemma: 2560)
    conv_width: int = 4
    # encoder-decoder (whisper)
    encoder_decoder: bool = False
    enc_layers: int = 0
    enc_groups: Tuple = ()
    # modality frontend stub: none | patch | audio
    frontend: str = "none"
    frontend_tokens: int = 0          # image/audio tokens prepended (stub)
    # dtypes
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    # optimizer-state dtype (405b uses bf16 to fit 256 chips — see DESIGN.md)
    opt_state_dtype: str = "float32"
    # long-context capability: sub-quadratic attention available?
    subquadratic: bool = False
    # embedding/logits vocab rows padded to a multiple of this so the vocab
    # dim shards evenly over the 16-wide `model` axis (MaxText-style)
    vocab_pad: int = 128
    # mLSTM training/prefill implementation: "scan" (per-step, baseline) or
    # "chunked" (stabilized chunked gated linear attention — §Perf)
    mlstm_impl: str = "scan"
    mlstm_chunk: int = 128
    # MoE dispatch: "global" (one sort over all tokens — baseline) or
    # "grouped" (per-batch-row routing; shard-local dispatch — §Perf)
    moe_impl: str = "global"
    # attention backward: "autodiff" (scan VJP saves per-chunk probs —
    # baseline) or "flash" (chunked recompute custom-VJP — §Perf)
    attn_vjp: str = "autodiff"

    @property
    def padded_vocab(self) -> int:
        return ((self.vocab_size + self.vocab_pad - 1)
                // self.vocab_pad) * self.vocab_pad

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def blocks(self) -> List[BlockSpec]:
        out: List[BlockSpec] = []
        for repeat, body in self.layer_groups:
            out.extend(list(body) * repeat)
        return out

    def param_count(self) -> int:
        """Total parameters (analytic; used for roofline MODEL_FLOPS)."""
        return _param_count(self)

    def active_param_count(self) -> int:
        """Parameters active per token (MoE: top_k experts only)."""
        return _param_count(self, active_only=True)


def _ffn_params(cfg: ModelConfig, spec: BlockSpec, active_only: bool) -> int:
    if spec.ffn == NO_FFN:
        return 0
    if spec.ffn == MOE_FFN:
        m = cfg.moe
        n_mats = 3 if cfg.act == "swiglu" else 2
        per_expert = n_mats * cfg.d_model * m.d_ff_expert
        router = cfg.d_model * m.n_experts
        n_e = m.top_k if active_only else m.n_experts
        return per_expert * n_e + router
    n_mats = 3 if cfg.act == "swiglu" else 2
    return n_mats * cfg.d_model * cfg.d_ff


def _mixer_params(cfg: ModelConfig, spec: BlockSpec) -> int:
    d, hd = cfg.d_model, cfg.hd
    if spec.mixer in (ATTN, SWA):
        q = d * cfg.n_heads * hd
        kv = 2 * d * cfg.n_kv_heads * hd
        o = cfg.n_heads * hd * d
        n = q + kv + o
        if spec.cross_attn:
            n *= 2
        return n
    if spec.mixer == RGLRU:
        dr = cfg.d_rnn or d
        # in/out proj (x2 branches) + gates + conv
        return 2 * d * dr + dr * d + 2 * dr * dr // 8 + cfg.conv_width * dr
    if spec.mixer == MLSTM:
        # qkv + o + gates, with expansion 2
        de = 2 * d
        return d * de * 3 + de * d + 3 * d * de // 4
    if spec.mixer == SLSTM:
        de = d
        return 4 * d * de + de * d * 2
    raise ValueError(spec.mixer)


def _param_count(cfg: ModelConfig, active_only: bool = False) -> int:
    n = cfg.vocab_size * cfg.d_model  # embed
    if not cfg.tie_embeddings:
        n += cfg.vocab_size * cfg.d_model
    for spec in cfg.blocks():
        n += _mixer_params(cfg, spec) + _ffn_params(cfg, spec, active_only)
        n += 2 * cfg.d_model  # norms
    if cfg.encoder_decoder:
        for _ in range(cfg.enc_layers):
            enc_spec = BlockSpec(mixer=ATTN, ffn=DENSE_FFN)
            n += _mixer_params(cfg, enc_spec) + _ffn_params(
                cfg, enc_spec, active_only) + 2 * cfg.d_model
    return n


# ---------------------------------------------------------------------------
# input shapes (assigned per-arch shape set)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeCfg:
    name: str
    seq_len: int
    global_batch: int
    kind: str                # train | prefill | decode


SHAPES: Dict[str, ShapeCfg] = {
    "train_4k": ShapeCfg("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCfg("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCfg("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCfg("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeCfg) -> Tuple[bool, str]:
    """Whether a cell (arch × shape) runs; reason if skipped."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, ("pure full-attention arch: 500k decode requires "
                       "sub-quadratic attention (see DESIGN.md)")
    return True, ""


# ---------------------------------------------------------------------------
# parallelism plan
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ParallelCfg:
    """Logical parallelism axes and knobs; mapped onto a physical mesh by
    repro.parallel.sharding."""
    fsdp_axes: Tuple[str, ...] = ("pod", "data")  # ZeRO-3 + DP axes
    tp_axis: str = "model"
    # gradient accumulation: microbatches per step (activations fit HBM)
    grad_accum: int = 1
    remat: bool = True
    # sequence-parallel residual stream (long-context shapes)
    seq_shard: bool = False
    # decode-cache sharding: "heads" | "seq" (flash-decoding style)
    kv_shard: str = "heads"
    # logits computed vocab-sharded (avoids full-vocab gather)
    shard_logits: bool = True
    # gradient accumulation/reduction dtype; bf16 halves the per-microbatch
    # gradient reduce-scatter volume (production trade-off — §Perf)
    grad_dtype: str = "float32"


def default_parallel(cfg: ModelConfig, shape: ShapeCfg,
                     data_axis: int = 16) -> ParallelCfg:
    """Baseline parallelism plan per cell (the §Perf hillclimb mutates
    these).

    grad_accum is chosen so the microbatch stays divisible by the data
    axis — otherwise GSPMD can't shard the batch dim and silently
    replicates activations (catastrophic all-reduce traffic).
    """
    grad_accum = 1
    n = cfg.param_count()
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        if n > 1e11:
            want = 16
        elif n > 1e9 and tokens >= 2 ** 20:
            want = 4
        else:
            want = 1
        # largest accum <= want with microbatch % data_axis == 0
        grad_accum = 1
        for a in (16, 8, 4, 2, 1):
            if a <= want and shape.global_batch % a == 0 \
                    and (shape.global_batch // a) % data_axis == 0:
                grad_accum = a
                break
    # sequence-parallel residual: long-context inference shapes, and
    # 100B+-class training (seq-sharded activation checkpoints keep the
    # per-device carry ~1/16th; Megatron-SP style)
    seq_shard = (shape.kind != "train" and shape.seq_len >= 32768) or \
                (shape.kind == "train" and n > 1e11)
    kv_shard = "seq" if (shape.kind == "decode"
                         and cfg.n_kv_heads < 16) else "heads"
    return ParallelCfg(grad_accum=grad_accum, seq_shard=seq_shard,
                       kv_shard=kv_shard)
