"""xlstm-125m — sLSTM + mLSTM blocks [arXiv:2405.04517].

12L, d_model 768, 4H (kv=4), vocab 50304, no separate FFN (d_ff=0; xLSTM
blocks carry internal up/down projections).  Alternating (mlstm, slstm) × 6.
Recurrent state only → long_500k runs (no KV cache at all).
"""
from . import register, register_smoke
from .base import MLSTM, NO_FFN, SLSTM, BlockSpec, ModelConfig

_M = BlockSpec(mixer=MLSTM, ffn=NO_FFN)
_S = BlockSpec(mixer=SLSTM, ffn=NO_FFN)


@register("xlstm-125m")
def config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-125m",
        family="ssm",
        n_layers=12,
        d_model=768,
        n_heads=4,
        n_kv_heads=4,
        d_ff=0,
        vocab_size=50304,
        layer_groups=((6, (_M, _S)),),
        norm="layernorm",
        act="gelu",
        tie_embeddings=True,
        param_dtype="float32",
        compute_dtype="float32",
        subquadratic=True,
    )


@register_smoke("xlstm-125m")
def smoke() -> ModelConfig:
    return ModelConfig(
        name="xlstm-125m-smoke",
        family="ssm",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=0,
        vocab_size=512,
        layer_groups=((1, (_M, _S)),),
        norm="layernorm",
        act="gelu",
        tie_embeddings=True,
        param_dtype="float32",
        compute_dtype="float32",
        subquadratic=True,
    )
