"""whisper-large-v3 — encoder-decoder speech model [arXiv:2212.04356].

32L encoder + 32L decoder, d_model 1280, 20H (MHA kv=20), d_ff 5120,
vocab 51866.  The conv audio frontend is a STUB per the assignment:
``input_specs()`` provides precomputed 1280-d frame embeddings.  Decoder
blocks carry self-attention + cross-attention; decode shapes run the
decoder against cached encoder states.  Full attention → long_500k skipped.
"""
from . import register, register_smoke
from .base import ATTN, DENSE_FFN, BlockSpec, ModelConfig

_DEC = BlockSpec(mixer=ATTN, ffn=DENSE_FFN, cross_attn=True)


@register("whisper-large-v3")
def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-large-v3",
        family="audio",
        n_layers=32,
        d_model=1280,
        n_heads=20,
        n_kv_heads=20,
        d_ff=5120,
        vocab_size=51866,
        layer_groups=((32, (_DEC,)),),
        norm="layernorm",
        act="gelu",
        rope_theta=10000.0,   # (whisper uses learned abs pos; RoPE stands in)
        encoder_decoder=True,
        enc_layers=32,
        frontend="audio",
        subquadratic=False,
    )


@register_smoke("whisper-large-v3")
def smoke() -> ModelConfig:
    return ModelConfig(
        name="whisper-large-v3-smoke",
        family="audio",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        vocab_size=512,
        layer_groups=((2, (_DEC,)),),
        norm="layernorm",
        act="gelu",
        encoder_decoder=True,
        enc_layers=2,
        frontend="audio",
        param_dtype="float32",
        compute_dtype="float32",
        subquadratic=False,
    )
