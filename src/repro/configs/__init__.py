"""Architecture registry: one module per assigned architecture."""
from typing import Callable, Dict

from .base import (ATTN, CROSS_ATTN, DENSE_FFN, MLSTM, MOE_FFN, NO_FFN,
                   RGLRU, SHAPES, SLSTM, SWA, BlockSpec, ModelConfig, MoECfg,
                   ParallelCfg, ShapeCfg, default_parallel, shape_applicable)

_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}
_SMOKE_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}


def register(name: str):
    def deco(fn):
        _REGISTRY[name] = fn
        return fn
    return deco


def register_smoke(name: str):
    def deco(fn):
        _SMOKE_REGISTRY[name] = fn
        return fn
    return deco


def get_config(name: str) -> ModelConfig:
    _ensure_loaded()
    return _REGISTRY[name]()


def get_smoke_config(name: str) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    _ensure_loaded()
    return _SMOKE_REGISTRY[name]()


# best-known §Perf switches (semantics-preserving; see EXPERIMENTS.md):
# chunked mLSTM, group-local MoE dispatch, flash-recompute attention VJP
OPTIMIZED_PROFILE = {
    "mlstm_impl": "chunked",
    "moe_impl": "grouped",
    "attn_vjp": "flash",
}


def get_optimized_config(name: str) -> ModelConfig:
    """The production profile: baseline config + §Perf switches."""
    import dataclasses
    return dataclasses.replace(get_config(name), **OPTIMIZED_PROFILE)


def get_optimized_smoke_config(name: str) -> ModelConfig:
    import dataclasses
    return dataclasses.replace(get_smoke_config(name),
                               **OPTIMIZED_PROFILE)


def list_archs():
    _ensure_loaded()
    return sorted(_REGISTRY)


_LOADED = False


def _ensure_loaded():
    global _LOADED
    if _LOADED:
        return
    from . import (glm4_9b, granite_moe_3b_a800m, h2o_danube_3_4b,  # noqa
                   internvl2_2b, llama3_2_3b, llama3_405b, mixtral_8x22b,
                   recurrentgemma_2b, whisper_large_v3, xlstm_125m)
    _LOADED = True
