"""internvl2-2b — VLM: InternViT frontend (STUB) + InternLM2 backbone
[arXiv:2404.16821].

Backbone: 24L, d_model 2048, 16H (GQA kv=8), d_ff 8192, vocab 92553.
The ViT frontend is a stub per the assignment: ``input_specs()`` provides
precomputed patch embeddings (256 image tokens) that are prepended to the
text sequence.
"""
from . import register, register_smoke
from .base import ATTN, DENSE_FFN, BlockSpec, ModelConfig

_BLOCK = BlockSpec(mixer=ATTN, ffn=DENSE_FFN)


@register("internvl2-2b")
def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-2b",
        family="vlm",
        n_layers=24,
        d_model=2048,
        n_heads=16,
        n_kv_heads=8,
        d_ff=8192,
        vocab_size=92553,
        layer_groups=((24, (_BLOCK,)),),
        rope_theta=1000000.0,
        frontend="patch",
        frontend_tokens=256,
        subquadratic=False,
    )


@register_smoke("internvl2-2b")
def smoke() -> ModelConfig:
    return ModelConfig(
        name="internvl2-2b-smoke",
        family="vlm",
        n_layers=2,
        d_model=64,
        n_heads=8,
        n_kv_heads=2,
        d_ff=160,
        vocab_size=512,
        layer_groups=((2, (_BLOCK,)),),
        frontend="patch",
        frontend_tokens=8,
        param_dtype="float32",
        compute_dtype="float32",
        subquadratic=False,
    )
