"""h2o-danube-3-4b — llama+mistral mix with sliding-window attention
[arXiv:2401.16818].

24L, d_model 3840, 32H (GQA kv=8), d_ff 10240, vocab 32000.  SWA window
4096 → sub-quadratic, so long_500k runs with a ring-buffer KV cache.
"""
from . import register, register_smoke
from .base import DENSE_FFN, SWA, BlockSpec, ModelConfig

_BLOCK = BlockSpec(mixer=SWA, ffn=DENSE_FFN)


@register("h2o-danube-3-4b")
def config() -> ModelConfig:
    return ModelConfig(
        name="h2o-danube-3-4b",
        family="dense",
        n_layers=24,
        d_model=3840,
        n_heads=32,
        n_kv_heads=8,
        d_ff=10240,
        vocab_size=32000,
        layer_groups=((24, (_BLOCK,)),),
        window=4096,
        rope_theta=10000.0,
        subquadratic=True,
    )


@register_smoke("h2o-danube-3-4b")
def smoke() -> ModelConfig:
    return ModelConfig(
        name="h2o-danube-3-4b-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=8,
        n_kv_heads=2,
        d_ff=160,
        vocab_size=512,
        layer_groups=((2, (_BLOCK,)),),
        window=16,
        param_dtype="float32",
        compute_dtype="float32",
        subquadratic=True,
    )
