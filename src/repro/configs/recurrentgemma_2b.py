"""recurrentgemma-2b — Griffin-style hybrid: RG-LRU + local attention, 1:2
attention:recurrence ratio [arXiv:2402.19427].

26L, d_model 2560, 10H (GQA kv=1), d_ff 7680, vocab 256000, d_rnn 2560,
local-attention window 2048.  Pattern: (rglru, rglru, local_attn) × 8 plus
a (rglru, rglru) tail = 26 layers.  Sub-quadratic → long_500k runs with
recurrent state + windowed cache.
"""
from . import register, register_smoke
from .base import DENSE_FFN, RGLRU, SWA, BlockSpec, ModelConfig

_REC = BlockSpec(mixer=RGLRU, ffn=DENSE_FFN)
_LOC = BlockSpec(mixer=SWA, ffn=DENSE_FFN)


@register("recurrentgemma-2b")
def config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-2b",
        family="hybrid",
        n_layers=26,
        d_model=2560,
        n_heads=10,
        n_kv_heads=1,
        d_ff=7680,
        vocab_size=256000,
        layer_groups=((8, (_REC, _REC, _LOC)), (1, (_REC, _REC))),
        window=2048,
        d_rnn=2560,
        conv_width=4,
        rope_theta=10000.0,
        tie_embeddings=True,
        act="gelu",
        subquadratic=True,
    )


@register_smoke("recurrentgemma-2b")
def smoke() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-2b-smoke",
        family="hybrid",
        n_layers=5,
        d_model=64,
        n_heads=4,
        n_kv_heads=1,
        d_ff=128,
        vocab_size=512,
        layer_groups=((1, (_REC, _REC, _LOC)), (1, (_REC, _REC))),
        window=16,
        d_rnn=64,
        conv_width=4,
        tie_embeddings=True,
        act="gelu",
        param_dtype="float32",
        compute_dtype="float32",
        subquadratic=True,
    )
