"""granite-moe-3b-a800m — fine-grained MoE
[hf:ibm-granite/granite-3.0-3b-a800m-base].

32L, d_model 1536, 24H (GQA kv=8), d_ff 512/expert, vocab 49155,
MoE 40 experts top-8.  Full attention → long_500k skipped.
"""
from . import register, register_smoke
from .base import ATTN, MOE_FFN, BlockSpec, ModelConfig, MoECfg

_BLOCK = BlockSpec(mixer=ATTN, ffn=MOE_FFN)


@register("granite-moe-3b-a800m")
def config() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-3b-a800m",
        family="moe",
        n_layers=32,
        d_model=1536,
        n_heads=24,
        n_kv_heads=8,
        d_ff=512,
        vocab_size=49155,
        layer_groups=((32, (_BLOCK,)),),
        moe=MoECfg(n_experts=40, top_k=8, d_ff_expert=512),
        rope_theta=10000.0,
        tie_embeddings=True,
        subquadratic=False,
    )


@register_smoke("granite-moe-3b-a800m")
def smoke() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-3b-a800m-smoke",
        family="moe",
        n_layers=2,
        d_model=48,
        n_heads=6,
        n_kv_heads=2,
        d_ff=32,
        vocab_size=512,
        layer_groups=((2, (_BLOCK,)),),
        moe=MoECfg(n_experts=8, top_k=4, d_ff_expert=32),
        tie_embeddings=True,
        param_dtype="float32",
        compute_dtype="float32",
        subquadratic=False,
    )
