"""mixtral-8x22b — sparse MoE with SWA [arXiv:2401.04088].

56L, d_model 6144, 48H (GQA kv=8), d_ff 16384/expert, vocab 32768,
8 experts top-2, sliding window 4096 → long_500k runs.

EP note (DESIGN.md §5): 8 experts don't divide the 16-wide `model` axis, so
the production plan shards each expert's d_ff over `model` (expert-TP) and
stacks experts; true all-to-all EP is exercised on divisible test meshes.
"""
from . import register, register_smoke
from .base import MOE_FFN, SWA, BlockSpec, ModelConfig, MoECfg

_BLOCK = BlockSpec(mixer=SWA, ffn=MOE_FFN)


@register("mixtral-8x22b")
def config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x22b",
        family="moe",
        n_layers=56,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        d_ff=16384,
        vocab_size=32768,
        layer_groups=((56, (_BLOCK,)),),
        window=4096,
        moe=MoECfg(n_experts=8, top_k=2, d_ff_expert=16384),
        rope_theta=1000000.0,
        opt_state_dtype="bfloat16",
        subquadratic=True,
    )


@register_smoke("mixtral-8x22b")
def smoke() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x22b-smoke",
        family="moe",
        n_layers=2,
        d_model=64,
        n_heads=8,
        n_kv_heads=2,
        d_ff=128,
        vocab_size=512,
        layer_groups=((2, (_BLOCK,)),),
        window=16,
        moe=MoECfg(n_experts=4, top_k=2, d_ff_expert=128),
        param_dtype="float32",
        compute_dtype="float32",
        subquadratic=True,
    )
