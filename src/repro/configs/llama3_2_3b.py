"""llama3.2-3b — small llama3 [hf:meta-llama/Llama-3.2-3B].

28L, d_model 3072, 24H (GQA kv=8), d_ff 8192, vocab 128256, tied embeddings.
"""
from . import register, register_smoke
from .base import ATTN, DENSE_FFN, BlockSpec, ModelConfig

_BLOCK = BlockSpec(mixer=ATTN, ffn=DENSE_FFN)


@register("llama3.2-3b")
def config() -> ModelConfig:
    return ModelConfig(
        name="llama3.2-3b",
        family="dense",
        n_layers=28,
        d_model=3072,
        n_heads=24,
        n_kv_heads=8,
        d_ff=8192,
        vocab_size=128256,
        layer_groups=((28, (_BLOCK,)),),
        rope_theta=500000.0,
        tie_embeddings=True,
        subquadratic=False,
    )


@register_smoke("llama3.2-3b")
def smoke() -> ModelConfig:
    return ModelConfig(
        name="llama3.2-3b-smoke",
        family="dense",
        n_layers=2,
        d_model=48,
        n_heads=6,
        n_kv_heads=2,
        d_ff=128,
        vocab_size=512,
        layer_groups=((2, (_BLOCK,)),),
        tie_embeddings=True,
        param_dtype="float32",
        compute_dtype="float32",
        subquadratic=False,
    )
