"""glm4-9b — dense GQA transformer with aggressive KV compression (kv=2)
[hf:THUDM/glm-4-9b].

40L, d_model 4096, 32H (GQA kv=2), d_ff 13696, vocab 151552.
"""
from . import register, register_smoke
from .base import ATTN, DENSE_FFN, BlockSpec, ModelConfig

_BLOCK = BlockSpec(mixer=ATTN, ffn=DENSE_FFN)


@register("glm4-9b")
def config() -> ModelConfig:
    return ModelConfig(
        name="glm4-9b",
        family="dense",
        n_layers=40,
        d_model=4096,
        n_heads=32,
        n_kv_heads=2,
        d_ff=13696,
        vocab_size=151552,
        layer_groups=((40, (_BLOCK,)),),
        rope_theta=10000.0,
        subquadratic=False,
    )


@register_smoke("glm4-9b")
def smoke() -> ModelConfig:
    return ModelConfig(
        name="glm4-9b-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=8,
        n_kv_heads=2,
        d_ff=192,
        vocab_size=512,
        layer_groups=((2, (_BLOCK,)),),
        param_dtype="float32",
        compute_dtype="float32",
        subquadratic=False,
    )
