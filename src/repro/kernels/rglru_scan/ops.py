"""Public op: RG-LRU scan with custom VJP (backward via the oracle —
linear recurrences transpose cleanly, and the fwd kernel already bounds
activation traffic)."""
from __future__ import annotations

import functools

import jax

from .kernel import rglru_scan_fwd
from .ref import rglru_scan_ref


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def rglru_scan(a, x, h0, interpret: bool = True):
    return rglru_scan_fwd(a, x, h0, interpret=interpret)


def _fwd(a, x, h0, interpret):
    out = rglru_scan_fwd(a, x, h0, interpret=interpret)
    return out, (a, x, h0)


def _bwd(interpret, res, cts):
    a, x, h0 = res
    _, vjp = jax.vjp(lambda a_, x_, h_: rglru_scan_ref(a_, x_, h_),
                     a, x, h0)
    return vjp(cts)


rglru_scan.defvjp(_fwd, _bwd)
