"""Pure-jnp oracle for the RG-LRU recurrence."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rglru_scan_ref(a, x, h0):
    """h_t = a_t * h_{t-1} + x_t.  a,x: [B,S,D]; h0: [B,D].
    Returns (h [B,S,D], h_final [B,D] f32)."""
    def step(h, inp):
        a_t, x_t = inp
        h = a_t.astype(jnp.float32) * h + x_t.astype(jnp.float32)
        return h, h

    hT, hs = jax.lax.scan(step, h0.astype(jnp.float32),
                          (a.transpose(1, 0, 2), x.transpose(1, 0, 2)))
    return hs.transpose(1, 0, 2).astype(a.dtype), hT
