"""RG-LRU linear-recurrence kernel (Pallas TPU).

Computes ``h_t = a_t * h_{t-1} + x_t`` along time for gate/input streams
that were precomputed by the surrounding layer (recurrentgemma's RG-LRU
after its input/recurrence gates).

Grid ``(B, nd, ns)`` — time tiles (``ns``) iterate innermost/sequentially,
so the carry ``h`` lives in VMEM scratch across time tiles for each
(batch, channel-tile).  Within a tile a ``fori_loop`` steps ``bs`` rows;
each step is an elementwise FMA over the [1, bd] lane vector (VPU work —
this kernel is memory-bound by design, its job is to stream a/x exactly
once from HBM instead of lax.scan's per-step roundtrips).

Channel tiles are 128 lanes wide; time tiles default 256 rows (8-sublane
multiples).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(a_ref, x_ref, h0_ref, o_ref, hend_ref, carry_ref, *,
            bs: int, ns: int, seq: int):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        carry_ref[...] = h0_ref[...].astype(jnp.float32)

    a = a_ref[0].astype(jnp.float32)   # [bs, bd]
    x = x_ref[0].astype(jnp.float32)   # [bs, bd]

    def step(t, h):
        # partial tail tile: rows past seq hold garbage — keep the carry
        valid = (si * bs + t) < seq
        h = jnp.where(valid, a[t] * h + x[t], h)
        o_ref[0, t, :] = h.astype(o_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, bs, step, carry_ref[0])
    carry_ref[0] = h

    @pl.when(si == ns - 1)
    def _flush():
        hend_ref[0] = h.astype(hend_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bs", "bd", "interpret"))
def rglru_scan_fwd(a, x, h0, *, bs: int = 256, bd: int = 128,
                   interpret: bool = True):
    """a, x: [B, S, D] (decay, gated input); h0: [B, D].
    Returns (h [B,S,D], h_final [B,D])."""
    B, S, D = a.shape
    bs = min(bs, S)
    bd = min(bd, D)
    ns = pl.cdiv(S, bs)
    nd = pl.cdiv(D, bd)

    kernel = functools.partial(_kernel, bs=bs, ns=ns, seq=S)
    return pl.pallas_call(
        kernel,
        grid=(B, nd, ns),
        in_specs=[
            pl.BlockSpec((1, bs, bd), lambda b, di, si: (b, si, di)),
            pl.BlockSpec((1, bs, bd), lambda b, di, si: (b, si, di)),
            pl.BlockSpec((1, bd), lambda b, di, si: (b, di)),
        ],
        out_specs=[
            pl.BlockSpec((1, bs, bd), lambda b, di, si: (b, si, di)),
            pl.BlockSpec((1, bd), lambda b, di, si: (b, di)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, D), a.dtype),
            jax.ShapeDtypeStruct((B, D), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((1, bd), jnp.float32)],
        interpret=interpret,
    )(a, x, h0)
