"""Grouped (per-expert) matmul kernel — the MoE expert GEMM.

``x [E, C, D] @ w [E, D, F] -> [E, C, F]`` with a row-count vector
``counts [E]`` so tiles past an expert's real token count skip the MXU
entirely (capacity buckets are padded; dispatch guarantees rows >= counts
are zero, so skipped tiles just stay zero).

Grid ``(E, nc, nf, nd)`` with the contraction tiles (nd) innermost; the
f32 accumulator lives in VMEM scratch and flushes on the last nd step.
Tile sizes default to the 128×128 MXU shape.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, w_ref, counts_ref, o_ref, acc_ref, *, bc: int, nd: int):
    e = pl.program_id(0)
    ci = pl.program_id(1)
    di = pl.program_id(3)

    @pl.when(di == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    live = ci * bc < counts_ref[e]   # any real token rows in this c-tile?

    @pl.when(live)
    def _compute():
        x = x_ref[0].astype(jnp.float32)
        w = w_ref[0].astype(jnp.float32)
        acc_ref[...] += jax.lax.dot_general(
            x, w, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(di == nd - 1)
    def _flush():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bc", "bf", "bd", "interpret"))
def moe_gmm_fwd(x, w, counts, *, bc: int = 128, bf: int = 128,
                bd: int = 128, interpret: bool = True):
    """x: [E,C,D]; w: [E,D,F]; counts: [E] int32.  Returns [E,C,F]."""
    E, C, D = x.shape
    F = w.shape[-1]
    bc, bf, bd = min(bc, C), min(bf, F), min(bd, D)
    nc, nf, nd = pl.cdiv(C, bc), pl.cdiv(F, bf), pl.cdiv(D, bd)

    kernel = functools.partial(_kernel, bc=bc, nd=nd)
    return pl.pallas_call(
        kernel,
        grid=(E, nc, nf, nd),
        in_specs=[
            pl.BlockSpec((1, bc, bd), lambda e, ci, fi, di: (e, ci, di)),
            pl.BlockSpec((1, bd, bf), lambda e, ci, fi, di: (e, di, fi)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((1, bc, bf),
                               lambda e, ci, fi, di: (e, ci, fi)),
        out_shape=jax.ShapeDtypeStruct((E, C, F), x.dtype),
        scratch_shapes=[pltpu.VMEM((bc, bf), jnp.float32)],
        interpret=interpret,
    )(x, w, counts)
