"""Pure-jnp oracle for the grouped matmul."""
from __future__ import annotations

import jax.numpy as jnp


def moe_gmm_ref(x, w, counts):
    """x: [E,C,D]; w: [E,D,F]; counts: [E].  Rows past counts[e] are
    treated as dead (zeroed), matching the kernel's tile skipping."""
    E, C, D = x.shape
    rows = jnp.arange(C)[None, :, None]
    live = rows < counts[:, None, None]
    xz = jnp.where(live, x, jnp.zeros_like(x))
    out = jnp.einsum("ecd,edf->ecf", xz.astype(jnp.float32),
                     w.astype(jnp.float32))
    # tile-level skip zeroes whole 128-row tiles with no live rows; partial
    # tiles compute fully (inputs are zero-padded so results match)
    return out.astype(x.dtype)
