"""Public op: grouped expert matmul with oracle VJP."""
from __future__ import annotations

import functools

import jax

from .kernel import moe_gmm_fwd
from .ref import moe_gmm_ref


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def moe_gmm(x, w, counts, interpret: bool = True):
    return moe_gmm_fwd(x, w, counts, interpret=interpret)


def _fwd(x, w, counts, interpret):
    return moe_gmm_fwd(x, w, counts, interpret=interpret), (x, w, counts)


def _bwd(interpret, res, ct):
    x, w, counts = res
    _, vjp = jax.vjp(lambda x_, w_: moe_gmm_ref(x_, w_, counts), x, w)
    dx, dw = vjp(ct)
    return dx, dw, None


moe_gmm.defvjp(_fwd, _bwd)
