"""Chunked gated linear attention — the mLSTM matrix-memory core.

Semantics (per batch-head, unstabilized, f32):

    C_t = f_t * C_{t-1} + i_t * k_t v_t^T          (C: [dk, dv])
    y_t = q_t @ C_t

The chunked form processes time in tiles of ``bt``: within a tile the
intra-chunk term is a decay-masked attention ``(q k^T ∘ Λ) v`` and the
inter-chunk term is ``(λ_t q_t) @ C_in``, with the state updated once per
tile — turning a length-S scan into S/bt MXU-dense steps.  This is the
TPU-native adaptation of mLSTM: matrix units do the heavy lifting, the
recurrence only crosses tile boundaries through VMEM scratch.

Grid ``(BH, nt)`` with nt innermost-sequential; state C [dk, dv] persists
in VMEM scratch.  Gates arrive as per-step log-decay ``lf`` and input gate
``i`` (precomputed by the layer).  The numerically-stabilized variant
(running max) stays in the XLA layer; this kernel is the compute core.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(q_ref, k_ref, v_ref, lf_ref, i_ref, o_ref, cend_ref, c_ref, *,
            bt: int, nt: int):
    ti = pl.program_id(1)

    @pl.when(ti == 0)
    def _init():
        c_ref[...] = jnp.zeros_like(c_ref)

    q = q_ref[0].astype(jnp.float32)          # [bt, dk]
    k = k_ref[0].astype(jnp.float32)          # [bt, dk]
    v = v_ref[0].astype(jnp.float32)          # [bt, dv]
    lf = lf_ref[0].astype(jnp.float32)        # [bt, 1] log forget
    gi = i_ref[0].astype(jnp.float32)         # [bt, 1] input gate

    # cumulative log-decay within the tile: L[t] = sum_{u<=t} lf[u]
    lcum = jnp.cumsum(lf, axis=0)             # [bt, 1]
    total = lcum[bt - 1]                      # [1]

    # inter-chunk: y_inter[t] = exp(L[t]) * q[t] @ C_in
    c_in = c_ref[...]
    y_inter = jnp.exp(lcum) * jax.lax.dot_general(
        q, c_in, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)   # [bt, dv]

    # intra-chunk: decay-masked attention
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [bt,bt]
    # decay weight exp(L[t] - L[u]) * i[u] for u <= t
    ldiff = lcum - lcum.reshape(1, bt)        # [bt, bt] = L[t]-L[u]
    tpos = jax.lax.broadcasted_iota(jnp.int32, (bt, bt), 0)
    upos = jax.lax.broadcasted_iota(jnp.int32, (bt, bt), 1)
    w = jnp.where(upos <= tpos, jnp.exp(ldiff) * gi.reshape(1, bt), 0.0)
    y_intra = jax.lax.dot_general(s * w, v, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)

    o_ref[0] = (y_inter + y_intra).astype(o_ref.dtype)

    # state update: C_out = exp(total) * C_in + sum_u exp(L_end - L[u]) i_u k_u v_u^T
    kw = k * (jnp.exp(total.reshape(1, 1) - lcum) * gi)   # [bt, dk]
    c_ref[...] = jnp.exp(total)[0] * c_in + jax.lax.dot_general(
        kw, v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(ti == nt - 1)
    def _flush():
        cend_ref[0] = c_ref[...].astype(cend_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bt", "interpret"))
def mlstm_chunk_fwd(q, k, v, lf, gi, *, bt: int = 128,
                    interpret: bool = True):
    """q,k: [BH, S, dk]; v: [BH, S, dv]; lf, gi: [BH, S, 1].
    Returns (y [BH,S,dv], C_final [BH,dk,dv])."""
    BH, S, dk = q.shape
    dv = v.shape[-1]
    bt = min(bt, S)
    nt = pl.cdiv(S, bt)

    kernel = functools.partial(_kernel, bt=bt, nt=nt)
    return pl.pallas_call(
        kernel,
        grid=(BH, nt),
        in_specs=[
            pl.BlockSpec((1, bt, dk), lambda b, ti: (b, ti, 0)),
            pl.BlockSpec((1, bt, dk), lambda b, ti: (b, ti, 0)),
            pl.BlockSpec((1, bt, dv), lambda b, ti: (b, ti, 0)),
            pl.BlockSpec((1, bt, 1), lambda b, ti: (b, ti, 0)),
            pl.BlockSpec((1, bt, 1), lambda b, ti: (b, ti, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bt, dv), lambda b, ti: (b, ti, 0)),
            pl.BlockSpec((1, dk, dv), lambda b, ti: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, S, dv), q.dtype),
            jax.ShapeDtypeStruct((BH, dk, dv), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((dk, dv), jnp.float32)],
        interpret=interpret,
    )(q, k, v, lf, gi)
