"""Pure-jnp oracle: per-step gated linear attention scan."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def mlstm_chunk_ref(q, k, v, lf, gi):
    """q,k: [BH,S,dk]; v: [BH,S,dv]; lf,gi: [BH,S,1].
    C_t = exp(lf_t)·C_{t-1} + i_t·k_t v_t^T ;  y_t = q_t @ C_t."""
    BH, S, dk = q.shape
    dv = v.shape[-1]

    def step(C, inp):
        q_t, k_t, v_t, lf_t, i_t = inp     # [BH,dk],[BH,dk],[BH,dv],[BH,1]
        C = jnp.exp(lf_t.astype(jnp.float32))[..., None] * C \
            + (i_t.astype(jnp.float32) * k_t.astype(jnp.float32))[..., None] \
            * v_t.astype(jnp.float32)[:, None, :]
        y = jnp.einsum("bk,bkv->bv", q_t.astype(jnp.float32), C)
        return C, y

    C0 = jnp.zeros((BH, dk, dv), jnp.float32)
    xs = (q.transpose(1, 0, 2), k.transpose(1, 0, 2),
          v.transpose(1, 0, 2), lf.transpose(1, 0, 2),
          gi.transpose(1, 0, 2))
    CT, ys = jax.lax.scan(step, C0, xs)
    return ys.transpose(1, 0, 2).astype(q.dtype), CT
