"""Public op: chunked gated linear attention (mLSTM core) with oracle VJP."""
from __future__ import annotations

import functools

import jax

from .kernel import mlstm_chunk_fwd
from .ref import mlstm_chunk_ref


@functools.partial(jax.custom_vjp, nondiff_argnums=(5,))
def mlstm_chunk(q, k, v, lf, gi, interpret: bool = True):
    return mlstm_chunk_fwd(q, k, v, lf, gi, interpret=interpret)


def _fwd(q, k, v, lf, gi, interpret):
    return mlstm_chunk_fwd(q, k, v, lf, gi, interpret=interpret), \
        (q, k, v, lf, gi)


def _bwd(interpret, res, cts):
    _, vjp = jax.vjp(lambda *a: mlstm_chunk_ref(*a), *res)
    return vjp(cts)


mlstm_chunk.defvjp(_fwd, _bwd)
