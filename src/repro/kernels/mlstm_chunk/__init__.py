from .ops import mlstm_chunk

__all__ = ["mlstm_chunk"]
