# Pallas TPU kernels for the compute hot-spots: flash attention (prefill),
# RG-LRU scan, chunked gated linear attention (mLSTM core), grouped matmul
# (MoE experts), and hetIR-generated kernels (the paper's compiler feeding
# the kernel layer).  Each kernel package: kernel.py (pl.pallas_call +
# BlockSpec), ops.py (jit'd wrapper), ref.py (pure-jnp oracle).
