from .ops import het_kernel

__all__ = ["het_kernel"]
