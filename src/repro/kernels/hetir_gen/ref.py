"""Oracle for hetIR-generated kernels: the scalar interpreter backend."""
from __future__ import annotations

from typing import Dict

import numpy as np

from repro.core import Engine, get_backend
from repro.core import hetir as ir


def het_kernel_ref(program: ir.Program, grid: int, block: int):
    backend = get_backend("interp")

    def run(**args) -> Dict[str, np.ndarray]:
        eng = Engine(program, backend, grid, block, dict(args))
        assert eng.run()
        return {p.name: eng.result(p.name) for p in program.buffers()}

    return run
