"""hetIR-generated Pallas kernels — the paper's compiler feeding kernels/.

``het_kernel(program)`` runs a hetIR "binary" through the core Pallas
backend (one ``pl.pallas_call`` per barrier segment) and returns a callable
with numpy-array semantics.  This is the kernel-layer integration of the
paper's contribution: the same portable binary that executes on the
interpreter and vectorized backends lowers to TPU kernels here.
"""
from __future__ import annotations

from typing import Dict

import numpy as np

from repro.core import Engine, get_backend
from repro.core import hetir as ir


def het_kernel(program: ir.Program, grid: int, block: int):
    """Returns fn(**args) -> dict of output buffers, executed on the
    Pallas backend."""
    backend = get_backend("pallas")

    def run(**args) -> Dict[str, np.ndarray]:
        eng = Engine(program, backend, grid, block, dict(args))
        assert eng.run()
        return {p.name: eng.result(p.name) for p in program.buffers()}

    return run
