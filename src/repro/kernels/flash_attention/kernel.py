"""Flash attention forward kernel (Pallas TPU).

Grid ``(B, H, nq, nk)`` — the two outer dims pick a (batch, head), ``nq``
picks a q tile and ``nk`` sweeps kv tiles *innermost* (TPU grids execute
sequentially in row-major order, so the online-softmax state for a q tile
lives in VMEM scratch across the nk sweep and flushes at ``nk == last``).

BlockSpecs stage ``[bq, d]`` / ``[bk, d]`` tiles in VMEM — d is the lane
axis (128 for all assigned archs), bq/bk default 128 so every matmul hits
the MXU at full tile.  Causal masking skips fully-masked kv tiles via
``pl.when`` (no wasted MXU work past the diagonal); sliding windows skip
tiles left of the band.

The kernel computes in f32 regardless of input dtype (TPU MXU accumulates
f32) and casts on the final flush.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                bq: int, bk: int, causal: bool, window, scale: float,
                nk: int, seq_k: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = qi * bq
    k_start = ki * bk

    # tile-level skip: strictly-future kv tiles (causal) and tiles left of
    # the sliding-window band never touch the MXU
    run = jnp.bool_(True)
    if causal:
        run = jnp.logical_and(run, k_start <= q_start + bq - 1)
    if window is not None:
        run = jnp.logical_and(run, k_start + bk - 1
                              > q_start - window)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)      # [bq, d]
        k = k_ref[0, 0].astype(jnp.float32)      # [bk, d]
        v = v_ref[0, 0].astype(jnp.float32)      # [bk, d]
        # partial tail tile: padded kv rows hold garbage — replace (a
        # multiply would propagate NaNs through 0*NaN)
        kvalid = (k_start + jax.lax.broadcasted_iota(
            jnp.int32, (bk, 1), 0)) < seq_k
        v = jnp.where(kvalid, v, 0.0)
        k = jnp.where(kvalid, k, 0.0)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # [bq, bk]

        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = kpos < seq_k
        if causal:
            mask = jnp.logical_and(mask, kpos <= qpos)
        if window is not None:
            mask = jnp.logical_and(mask, kpos > qpos - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                       # [bq, 1]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                    # [bq, bk]
        alpha = jnp.exp(m_prev - m_new)           # [bq, 1]
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1,
                                                  keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _flush():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                             "interpret"))
def flash_attention_fwd(q, k, v, *, causal: bool = True, window=None,
                        bq: int = 128, bk: int = 128,
                        interpret: bool = True):
    """q,k,v: [B, H, S, d] (kv pre-repeated for GQA).  Returns [B,H,S,d]."""
    B, H, Sq, d = q.shape
    Sk = k.shape[2]
    bq = min(bq, Sq)
    bk = min(bk, Sk)
    nq = pl.cdiv(Sq, bq)
    nk = pl.cdiv(Sk, bk)
    scale = 1.0 / math.sqrt(d)

    kernel = functools.partial(
        _fwd_kernel, bq=bq, bk=bk, causal=causal, window=window,
        scale=scale, nk=nk, seq_k=Sk)

    return pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b, h, qi, ki: (b, h, ki, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b, h, qi, ki: (b, h, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d),
                               lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),   # acc
            pltpu.VMEM((bq, 1), jnp.float32),   # running max
            pltpu.VMEM((bq, 1), jnp.float32),   # running denom
        ],
        interpret=interpret,
    )(q, k, v)
