"""Public op: flash attention with custom VJP.

Forward runs the Pallas kernel; backward recomputes through the jnp oracle
(flash backward kernel is a further optimization — the recompute keeps
activation memory at flash levels, which is the main point on TPU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import flash_attention_fwd
from .ref import attention_ref


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def flash_attention(q, k, v, causal: bool = True, window=None,
                    interpret: bool = True):
    """q,k,v: [B,H,S,d] (repeat GQA kv to H heads first)."""
    return flash_attention_fwd(q, k, v, causal=causal, window=window,
                               interpret=interpret)


def _fwd(q, k, v, causal, window, interpret):
    o = flash_attention_fwd(q, k, v, causal=causal, window=window,
                            interpret=interpret)
    return o, (q, k, v)


def _bwd(causal, window, interpret, res, do):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_: attention_ref(q_, k_, v_, causal=causal,
                                         window=window), q, k, v)
    return vjp(do)


flash_attention.defvjp(_fwd, _bwd)
