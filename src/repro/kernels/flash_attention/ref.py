"""Pure-jnp oracle for flash attention."""
from __future__ import annotations

import math

import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True, window=None):
    """q,k,v: [B,H,S,d]; full-matrix softmax attention."""
    B, H, Sq, d = q.shape
    Sk = k.shape[2]
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(d)
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask = mask & (kpos <= qpos)
    if window is not None:
        mask = mask & (kpos > qpos - window)
    s = jnp.where(mask[None, None], s, -jnp.inf)
    m = jnp.maximum(jnp.max(s, axis=-1, keepdims=True), -1e30)
    p = jnp.exp(s - m)
    l = jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    o = jnp.einsum("bhqk,bhkd->bhqd", p / l, v.astype(jnp.float32))
    return o.astype(q.dtype)
